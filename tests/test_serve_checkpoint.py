"""Smoke + contract tests for `repro.serve.steps` and
`repro.train.checkpoint` — the two subsystems the rest of the suite never
exercised.

Serve: prefill/decode shape & dtype contracts (last-only prefill logits,
decode cache round trip, greedy generation) on tiny configs.
Checkpoint: save → restore must be bit-identical for an arbitrary pytree,
and the validation paths must reject mismatched structures loudly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.types import ModelConfig
from repro.models import model as model_lib
from repro.serve.steps import greedy_generate, make_decode_step, make_prefill_step
from repro.train import checkpoint

CFG = ModelConfig(name="tiny-dense", family="dense", n_layers=2,
                  d_model=64, n_heads=4, n_kv_heads=2, d_ff=256,
                  vocab_size=128, dtype="float32")


@pytest.fixture(scope="module")
def params():
    return model_lib.init(jax.random.PRNGKey(0), CFG)


# --------------------------------------------------------------------- #
# serve steps
# --------------------------------------------------------------------- #
def test_prefill_last_only_shape_and_dtype(params):
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 2,
                                CFG.vocab_size)
    logits = make_prefill_step(CFG)(params, {"tokens": tokens})
    # serving prefill materializes only the last position's logits
    assert logits.shape == (B, 1, CFG.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_prefill_full_logits_when_not_last_only(params):
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 2,
                                CFG.vocab_size)
    logits = make_prefill_step(CFG, last_only=False)(params,
                                                     {"tokens": tokens})
    assert logits.shape == (B, S, CFG.vocab_size)


def test_decode_step_contract(params):
    B, max_len = 2, 16
    caches = model_lib.init_cache(CFG, B, max_len, jnp.float32)
    decode = make_decode_step(CFG)
    tok = jnp.array([3, 5], dtype=jnp.int32)
    logits, new_caches = decode(params, caches, tok, 0)
    assert logits.shape == (B, CFG.vocab_size)
    # cache pytree structure is preserved step to step
    assert (jax.tree_util.tree_structure(new_caches)
            == jax.tree_util.tree_structure(caches))
    for a, b in zip(jax.tree_util.tree_leaves(caches),
                    jax.tree_util.tree_leaves(new_caches)):
        assert a.shape == b.shape and a.dtype == b.dtype


def test_greedy_generate_deterministic_and_in_vocab(params):
    B, S, new = 2, 6, 5
    prompt = jax.random.randint(jax.random.PRNGKey(2), (B, S), 2,
                                CFG.vocab_size)
    out1 = greedy_generate(CFG, params, prompt, max_new=new, max_len=S + new)
    out2 = greedy_generate(CFG, params, prompt, max_new=new, max_len=S + new)
    assert out1.shape == (B, S + new)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    np.testing.assert_array_equal(np.asarray(out1[:, :S]),
                                  np.asarray(prompt))  # prompt echoed
    assert bool(jnp.all((out1 >= 0) & (out1 < CFG.vocab_size)))


# --------------------------------------------------------------------- #
# checkpoint
# --------------------------------------------------------------------- #
def _tree():
    rng = np.random.default_rng(0)
    return {
        "layer0": {"w": jnp.asarray(rng.standard_normal((4, 8)),
                                    dtype=jnp.float32),
                   "b": jnp.zeros((8,), jnp.float32)},
        "embed": jnp.asarray(rng.integers(0, 100, (16, 4)), dtype=jnp.int32),
        "scale": jnp.asarray(rng.standard_normal(3).astype(np.float16)),
    }


def test_checkpoint_roundtrip_bit_identical(tmp_path, params):
    path = str(tmp_path / "ckpt")
    tree = _tree()
    checkpoint.save(path, tree, {"step": 7})
    restored = checkpoint.restore(path, tree)
    assert (jax.tree_util.tree_structure(restored)
            == jax.tree_util.tree_structure(tree))
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert checkpoint.load_meta(path)["meta"] == {"step": 7}
    # real model params round-trip bit-identically too
    mpath = str(tmp_path / "model.npz")
    checkpoint.save(mpath, params)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(
                        checkpoint.restore(mpath, params))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_restore_validates_shapes(tmp_path):
    path = str(tmp_path / "ckpt")
    tree = _tree()
    checkpoint.save(path, tree)
    bad = dict(tree, embed=jnp.zeros((8, 4), jnp.int32))
    with pytest.raises(ValueError, match="shape"):
        checkpoint.restore(path, bad)


def test_checkpoint_restore_rejects_missing_leaf(tmp_path):
    path = str(tmp_path / "ckpt")
    tree = _tree()
    checkpoint.save(path, tree)
    bigger = dict(tree, extra=jnp.zeros((2,), jnp.float32))
    with pytest.raises(KeyError, match="extra"):
        checkpoint.restore(path, bigger)


def test_checkpoint_meta_records_dtypes_and_shapes(tmp_path):
    path = str(tmp_path / "ckpt")
    tree = _tree()
    checkpoint.save(path, tree, {"loss": 1.5})
    meta = checkpoint.load_meta(path)
    assert meta["meta"]["loss"] == 1.5
    assert any(d == "float16" for d in meta["dtypes"].values())
    assert sorted(tuple(s) for s in meta["shapes"].values()) == sorted(
        tuple(np.asarray(leaf).shape)
        for leaf in jax.tree_util.tree_leaves(tree))