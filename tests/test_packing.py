"""Sequence packing tests (paper §3.2.1)."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.data.items import DataItem
from repro.data.packing import greedy_bin_pack, pack_items, pack_tokens


def test_pack_tokens_labels_and_segments():
    seqs = [np.arange(1, 6), np.arange(10, 14)]
    pb = pack_tokens(seqs, budget=16)
    t, lab, seg = pb.tokens[0], pb.labels[0], pb.segment_ids[0]
    assert list(t[:5]) == [1, 2, 3, 4, 5]
    assert list(lab[:4]) == [2, 3, 4, 5]       # next-token within segment
    assert lab[4] == -1                         # no label across boundary
    assert list(seg[:5]) == [1] * 5
    assert list(seg[5:9]) == [2] * 4
    assert all(seg[9:] == 0)                    # padding segment 0
    assert all(lab[9:] == -1)


def test_pack_tokens_truncates_at_budget():
    pb = pack_tokens([np.arange(100)], budget=16)
    assert pb.used == 16
    assert pb.n_items == 1
    assert pb.truncated == 84                   # dropped, but accounted
    assert pb.padding == 0


def test_pack_tokens_accounting_identity():
    """No silent truncation: every input token is placed, counted as
    truncated, or the row is padded — used + truncated == Σ len and
    used + padding == budget, including whole sequences skipped once the
    row is (nearly) full."""
    cases = [
        ([np.arange(5), np.arange(9)], 32),     # all fit, padding left
        ([np.arange(100)], 16),                 # hard overflow
        ([np.arange(10), np.arange(50), np.arange(7)], 16),  # skip tail
        ([np.arange(16), np.arange(3)], 16),    # exact fill, seq skipped
        ([np.arange(1)], 8),                    # len-1 seq unusable
    ]
    for seqs, budget in cases:
        pb = pack_tokens(seqs, budget)
        total = sum(len(s) for s in seqs)
        assert pb.used + pb.truncated == total, (seqs, budget)
        assert pb.used + pb.padding == budget, (seqs, budget)
        assert int((pb.segment_ids[0] > 0).sum()) == pb.used


@given(st.lists(st.integers(1, 80), min_size=1, max_size=12),
       st.integers(8, 64))
@settings(max_examples=100, deadline=None)
def test_pack_tokens_accounting_identity_property(lengths, budget):
    pb = pack_tokens([np.arange(n) for n in lengths], budget)
    assert pb.used + pb.truncated == sum(lengths)
    assert pb.used + pb.padding == budget


def test_pack_items_counts_pre_clip_truncation():
    """Items longer than the whole budget are clipped before token
    generation; the clipped length still counts toward `truncated` so
    the identity holds against the items' true lengths."""
    rng = np.random.default_rng(0)
    items = [DataItem(4, 100, "multi_image", 0),    # 4*8+100 = 132 > 64
             DataItem(1, 10, "single_image", 1)]    # 18
    pb = pack_items(items, budget=64, tokens_per_media_item=8,
                    vocab=128, rng=rng)
    total = sum(it.llm_seq_len(8) for it in items)
    assert pb.used + pb.truncated == total
    assert pb.used + pb.padding == 64


@given(st.lists(st.integers(1, 50), min_size=1, max_size=40),
       st.integers(8, 64))
@settings(max_examples=100, deadline=None)
def test_greedy_bin_pack_properties(lengths, budget):
    bins = greedy_bin_pack(lengths, budget)
    flat = sorted(i for b in bins for i in b)
    assert flat == list(range(len(lengths)))
    for b in bins:
        total = sum(min(lengths[i], budget) for i in b)
        assert total <= budget


def test_positions_restart_per_segment():
    pb = pack_tokens([np.arange(4), np.arange(3)], budget=12)
    pos = pb.positions[0]
    assert list(pos[:4]) == [0, 1, 2, 3]
    assert list(pos[4:7]) == [0, 1, 2]
