"""Sequence packing tests (paper §3.2.1)."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.data.items import DataItem
from repro.data.packing import greedy_bin_pack, pack_tokens


def test_pack_tokens_labels_and_segments():
    seqs = [np.arange(1, 6), np.arange(10, 14)]
    pb = pack_tokens(seqs, budget=16)
    t, lab, seg = pb.tokens[0], pb.labels[0], pb.segment_ids[0]
    assert list(t[:5]) == [1, 2, 3, 4, 5]
    assert list(lab[:4]) == [2, 3, 4, 5]       # next-token within segment
    assert lab[4] == -1                         # no label across boundary
    assert list(seg[:5]) == [1] * 5
    assert list(seg[5:9]) == [2] * 4
    assert all(seg[9:] == 0)                    # padding segment 0
    assert all(lab[9:] == -1)


def test_pack_tokens_truncates_at_budget():
    pb = pack_tokens([np.arange(100)], budget=16)
    assert pb.used == 16
    assert pb.n_items == 1


@given(st.lists(st.integers(1, 50), min_size=1, max_size=40),
       st.integers(8, 64))
@settings(max_examples=100, deadline=None)
def test_greedy_bin_pack_properties(lengths, budget):
    bins = greedy_bin_pack(lengths, budget)
    flat = sorted(i for b in bins for i in b)
    assert flat == list(range(len(lengths)))
    for b in bins:
        total = sum(min(lengths[i], budget) for i in b)
        assert total <= budget


def test_positions_restart_per_segment():
    pb = pack_tokens([np.arange(4), np.arange(3)], budget=12)
    pos = pb.positions[0]
    assert list(pos[:4]) == [0, 1, 2, 3]
    assert list(pos[4:7]) == [0, 1, 2]
