"""MoE observability: drop-rate / load-imbalance stats through the stack.

The convention under test (ISSUE 10): a path that cannot measure reports
NaN — never a fake 0.0 — and NaN becomes ``null`` only at the JSON
boundary (``RuntimeMetrics.snapshot`` via ``nan_to_none``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.types import ModelConfig
from repro.models import model as model_lib
from repro.models.layers import moe
from repro.models.model import FwdCtx
from repro.runtime.metrics import RuntimeMetrics
from repro.train.optim import AdamWConfig, adamw_init
from repro.train.step import make_train_step


def _moe_cfg(**over):
    base = dict(name="t", family="moe", n_layers=2, d_model=32, n_heads=2,
                n_kv_heads=2, d_ff=64, vocab_size=64,
                ffn_pattern=("moe",), n_experts=4, top_k=2,
                dtype="float32", param_dtype="float32")
    base.update(over)
    return ModelConfig(**base)


def _x(cfg, B=2, S=16, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (B, S, cfg.d_model))


def test_capacity_stats_drop_and_imbalance():
    cfg = _moe_cfg()
    params = moe.init(jax.random.PRNGKey(1), cfg)
    x = _x(cfg)
    # tight capacity must drop assignments; generous capacity must not
    _, _, tight = moe.apply_capacity(params, x, cfg, capacity_factor=0.25,
                                     with_stats=True)
    _, _, loose = moe.apply_capacity(params, x, cfg, capacity_factor=8.0,
                                     with_stats=True)
    assert 0.0 < float(tight["drop_rate"]) <= 1.0
    assert float(loose["drop_rate"]) == 0.0
    for st in (tight, loose):
        imb = float(st["imbalance"])
        assert np.isfinite(imb) and imb >= 0.0
    # stats must not change the output or lb_loss contract
    y, lb = moe.apply_capacity(params, x, cfg, capacity_factor=8.0)
    y2, lb2, _ = moe.apply_capacity(params, x, cfg, capacity_factor=8.0,
                                    with_stats=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2))
    np.testing.assert_allclose(float(lb), float(lb2))


def test_dense_and_chunked_stats():
    cfg = _moe_cfg()
    params = moe.init(jax.random.PRNGKey(2), cfg)
    x = _x(cfg)
    _, _, st = moe.apply_dense(params, x, cfg, with_stats=True)
    assert float(st["drop_rate"]) == 0.0          # dense never drops
    assert np.isfinite(float(st["imbalance"]))
    _, _, stc = moe.apply_capacity_chunked(params, x, cfg,
                                           capacity_factor=0.5,
                                           chunk_tokens=8, with_stats=True)
    assert 0.0 <= float(stc["drop_rate"]) <= 1.0
    assert np.isfinite(float(stc["imbalance"]))


def test_forward_aux_carries_moe_stats():
    cfg = _moe_cfg()
    params = model_lib.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 1, 64)
    ctx = FwdCtx(mode="train", attn_impl="naive", capacity_factor=0.5)
    _, _, aux = model_lib.forward(params, cfg, tokens=toks, ctx=ctx)
    assert 0.0 <= float(aux["moe_drop_rate"]) <= 1.0
    assert np.isfinite(float(aux["moe_imbalance"]))
    # a model with no MoE layers reports NaN, not a fake 0.0
    dense = _moe_cfg(ffn_pattern=("dense",), n_experts=0, top_k=0,
                     family="dense")
    dparams = model_lib.init(jax.random.PRNGKey(0), dense)
    _, _, daux = model_lib.forward(dparams, dense, tokens=toks, ctx=ctx)
    assert np.isnan(float(daux["moe_drop_rate"]))
    assert np.isnan(float(daux["moe_imbalance"]))


@pytest.mark.parametrize("has_moe", [True, False])
def test_train_step_metrics_keys(has_moe):
    cfg = _moe_cfg() if has_moe else _moe_cfg(ffn_pattern=("dense",),
                                              n_experts=0, top_k=0,
                                              family="dense")
    params = model_lib.init(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig()
    opt = adamw_init(params)
    step = make_train_step(cfg, opt_cfg,
                           ctx=FwdCtx(mode="train", attn_impl="naive",
                                      capacity_factor=0.5))
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 2, 16), 1, 64)
    batch = {"tokens": toks, "labels": toks}
    _, _, metrics = step(params, opt, batch, 1e-3)
    assert set(metrics) >= {"loss", "moe_drop_rate", "moe_imbalance"}
    assert np.isfinite(float(metrics["loss"]))
    if has_moe:
        assert 0.0 <= float(metrics["moe_drop_rate"]) <= 1.0
        assert np.isfinite(float(metrics["moe_imbalance"]))
    else:
        assert np.isnan(float(metrics["moe_drop_rate"]))
        assert np.isnan(float(metrics["moe_imbalance"]))


def test_runtime_metrics_record_moe_nan_to_none():
    m = RuntimeMetrics(window=8)
    snap = m.snapshot()
    assert snap["moe_drop_rate_mean"] is None        # empty window -> null
    assert snap["moe_imbalance_max"] is None
    m.record_moe(float("nan"), float("nan"))         # NaN observations skipped
    snap = m.snapshot()
    assert snap["moe_drop_rate_mean"] is None
    assert snap["moe_imbalance_max"] is None
    m.record_moe(0.1, 0.5)
    m.record_moe(0.3, 1.5)
    m.record_moe(float("nan"), 0.25)                 # per-field skip
    snap = m.snapshot()
    assert snap["moe_drop_rate_mean"] == pytest.approx(0.2)
    assert snap["moe_drop_rate_last"] == pytest.approx(0.3)
    assert snap["moe_imbalance_max"] == pytest.approx(1.5)
    assert snap["moe_imbalance_mean"] == pytest.approx(0.75)
