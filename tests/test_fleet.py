"""Elastic multi-host execution: fault-injection + recovery harness.

Pins the recovery invariants of `repro.launch.fleet` + the controller's
checkpoint-free roster recovery:

  * bit-identical `pipeline_forward` outputs (and optimizer state) across
    N -> N-1 -> N host transitions (subprocess, forced host devices);
  * exactly-once data delivery under host churn — the committed
    global-batch stream is bit-identical to a fault-free run's, which is
    what loss-trajectory continuity reduces to;
  * degrade-not-crash: a failed search or reshard falls back to the
    surviving roster (only a `damaged` swapper may raise);
  * the divisor-aware fleet mesh fix for `clamped_plan_mesh`'s silent
    replication when the restacked dim doesn't divide the clamped axis.

Differential fleet-vs-single-host equivalence (same seed, no fault ->
same batches + same plan choices) rides along, `test_loader.py` style.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.common.types import ModelConfig
from repro.core.engine import DFLOPEngine
from repro.core.optimizer.search import SearchResult
from repro.core.optimizer.space import (
    ClusterSpec,
    ModuleParallelism,
    ParallelismPlan,
)
from repro.data.host_shard import HostShardedSource, partition_by_host
from repro.data.synthetic import MixedDataset
from repro.launch.fleet import (
    FaultInjector,
    FleetManager,
    MembershipEvent,
    fleet_plan_mesh,
    largest_divisor_leq,
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_devices(script: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


def _plan(tp=1, pp=1, dp=1, n_mb=2):
    return ParallelismPlan(llm=ModuleParallelism(tp, pp, dp), n_mb=n_mb)


# --------------------------------------------------------------------- #
# FleetManager roster lifecycle
# --------------------------------------------------------------------- #
def test_fleet_roster_lifecycle():
    fm = FleetManager(devices=list("abcdefgh"), devices_per_host=2)
    assert (fm.n_hosts, fm.n_alive, fm.n_chips) == (4, 4, 8)
    assert fm.devices() == list("abcdefgh")

    ev = fm.fail(1, step=5)
    assert ev == MembershipEvent("fail", 1, 5, 3)
    assert fm.alive_ids() == [0, 2, 3]
    assert fm.devices() == list("abefgh")      # host order, dead host gone
    assert fm.n_chips == 6

    fm.leave(3)
    assert fm.n_chips == 4
    # events drain once; history keeps everything
    assert [e.kind for e in fm.poll_events()] == ["fail", "leave"]
    assert fm.poll_events() == []
    fm.join(1)
    assert fm.n_chips == 6
    assert [e.kind for e in fm.history] == ["fail", "leave", "join"]

    with pytest.raises(ValueError, match="already down"):
        fm.fail(3)
    with pytest.raises(ValueError, match="already alive"):
        fm.join(0)
    with pytest.raises(KeyError):
        fm.host(99)


def test_fleet_constructor_validation():
    with pytest.raises(ValueError, match="do not split"):
        FleetManager(devices=list("abc"), devices_per_host=2)
    with pytest.raises(ValueError, match="do not split"):
        FleetManager(devices=list("abcd"), n_hosts=3)
    fm = FleetManager(devices=list("abcd"), n_hosts=2)
    assert fm.devices_per_host == 2 and fm.n_hosts == 2


def test_fleet_cluster_spec_tracks_roster():
    fm = FleetManager(devices=list(range(8)), devices_per_host=2)
    template = ClusterSpec(n_chips=256, chips_per_node=16,
                           mem_bytes=int(16e9), name="pod")
    spec = fm.cluster_spec(template)
    assert spec.n_chips == 8
    assert spec.chips_per_node == 2        # per-host TP domain caps it
    assert spec.mem_bytes == template.mem_bytes and spec.name == "pod"
    fm.fail(0)
    assert fm.cluster_spec(template).n_chips == 6
    bare = fm.cluster_spec()
    assert bare.n_chips == 6 and bare.chips_per_node == 2


def test_largest_divisor_leq_properties():
    for n in range(1, 33):
        for limit in range(1, 33):
            d = largest_divisor_leq(n, limit)
            assert n % d == 0 and 1 <= d <= max(limit, 1)
            # maximality: no larger divisor fits
            assert not any(n % k == 0 for k in range(d + 1, min(n, limit) + 1))


# --------------------------------------------------------------------- #
# FaultInjector
# --------------------------------------------------------------------- #
def test_fault_injector_fires_deterministic_schedule():
    fm = FleetManager(devices=list("abcd"), devices_per_host=1)
    inj = FaultInjector(fm, {1: [("fail", 3), ("leave", 2)],
                             4: [("join", 3)]})
    assert inj.on_step(0) == []
    evs = inj.on_step(1)
    assert [e.kind for e in evs] == ["fail", "leave"]
    assert fm.alive_ids() == [0, 1]
    assert inj.on_step(2) == [] and inj.on_step(3) == []
    assert [e.kind for e in inj.on_step(4)] == ["join"]
    assert [e.kind for e in inj.fired] == ["fail", "leave", "join"]
    assert all(e.step in (1, 4) for e in inj.fired)


def test_fault_injector_rejects_unknown_action():
    fm = FleetManager(devices=list("ab"), devices_per_host=1)
    with pytest.raises(ValueError, match="unknown action"):
        FaultInjector(fm, {0: [("explode", 0)]})


# --------------------------------------------------------------------- #
# per-host data sharding: exactly-once under churn
# --------------------------------------------------------------------- #
def test_partition_by_host_roundrobin_union():
    items = list(range(10))
    shards = partition_by_host(items, [0, 2, 5])
    assert shards == {0: [0, 3, 6, 9], 2: [1, 4, 7], 5: [2, 5, 8]}
    # position-ordered union reconstructs the batch for any roster
    for roster in ([0], [1, 2], [3, 1, 4, 0]):
        sh = partition_by_host(items, roster)
        merged = [None] * len(items)
        for h, shard in sh.items():
            pos = [i for i in range(len(items))
                   if roster[i % len(roster)] == h]
            for p, it in zip(pos, shard):
                merged[p] = it
        assert merged == items
    with pytest.raises(ValueError, match="empty roster"):
        partition_by_host(items, [])


def test_host_sharded_source_step_contract():
    src = HostShardedSource(iter([[0, 1, 2, 3]] * 4).__next__, gbs=4)
    with pytest.raises(RuntimeError, match="no step in flight"):
        src.commit()
    with pytest.raises(RuntimeError, match="no step in flight"):
        src.abort()
    src.draw([0])
    with pytest.raises(RuntimeError, match="in flight"):
        src.draw([0])
    src.commit()
    with pytest.raises(ValueError, match="no fleet"):
        src.draw()                      # no roster and no fleet attached
    src2 = HostShardedSource(lambda: [], gbs=2)
    with pytest.raises(RuntimeError, match="exhausted"):
        src2.draw([0])


def test_host_sharded_source_exactly_once_under_churn():
    """Property: whatever kill/revive/abort sequence fires, the committed
    global-batch stream is bit-identical to the fault-free run's — every
    item delivered exactly once, in order, in the same batch grouping."""
    gbs, n_steps = 8, 40

    def make_stream():
        c = iter(range(10_000))
        return lambda: [next(c) for _ in range(gbs)]

    # fault-free reference
    ref = HostShardedSource(make_stream(), gbs=gbs)
    for _ in range(n_steps):
        ref.draw([0])
        ref.commit()

    rng = np.random.default_rng(7)
    fm = FleetManager(devices=list(range(8)), devices_per_host=2)
    src = HostShardedSource(make_stream(), gbs=gbs, fleet=fm)
    while src.n_committed < n_steps:
        shards = src.draw()
        # per-host shards always recombine to the in-flight global batch
        assert sorted(x for s in shards.values() for x in s) \
            == sorted(src.in_flight)
        assert set(shards) == set(fm.alive_ids())
        if rng.random() < 0.3 and fm.n_alive > 1:
            # host dies mid-step: the step aborts, roster shrinks
            fm.fail(fm.alive_ids()[int(rng.integers(fm.n_alive))])
            src.abort()
        else:
            src.commit()
        if fm.n_alive < fm.n_hosts and rng.random() < 0.4:
            dead = [h.host_id for h in fm.hosts if not h.alive]
            fm.join(dead[0])
    assert src.n_aborted > 0, "churn schedule never fired a failure"
    assert src.committed == ref.committed
    ids = [x for b in src.committed for x in b]
    assert len(ids) == len(set(ids)) == gbs * n_steps


# --------------------------------------------------------------------- #
# scheduler roster validation
# --------------------------------------------------------------------- #
def test_scheduler_set_plan_validates_roster():
    eng = _tiny_engine()
    sched = eng.scheduler(plan=_plan(dp=4, n_mb=2))
    sched.set_roster(3)
    with pytest.raises(ValueError, match="roster"):
        sched.set_plan(_plan(dp=4, n_mb=2))
    sched.set_plan(_plan(dp=3, n_mb=2))            # fits: fine
    assert sched.plan.llm.dp == 3
    sched.set_roster(None)                          # disables the check
    sched.set_plan(_plan(dp=4, n_mb=2))


# --------------------------------------------------------------------- #
# controller recovery: re-plan + migrate + degrade-not-crash
# --------------------------------------------------------------------- #
def _tiny_engine(n_chips=4):
    llm = ModelConfig(name="l", family="dense", n_layers=8, d_model=256,
                      n_heads=4, n_kv_heads=4, d_ff=1024, vocab_size=512)
    eng = DFLOPEngine(llm_cfg=llm,
                      cluster=ClusterSpec(n_chips=n_chips,
                                          chips_per_node=n_chips))
    eng.profile(MixedDataset("single_image", seed=0,
                             tokens_per_media_item=64))
    eng.plan(8)
    return eng


def _fleet_controller(n_hosts=4, swapper=None, **kw):
    eng = _tiny_engine(n_chips=n_hosts)
    fleet = FleetManager(devices=list(range(n_hosts)), devices_per_host=1)
    ctl = eng.runtime(8, adaptive=False, auto_replan=False, calibrate=False,
                      trace=False, param_swapper=swapper, fleet=fleet, **kw)
    return ctl, fleet


def test_controller_recovery_replans_for_survivors_and_rejoin():
    ctl, fleet = _fleet_controller()
    ds = MixedDataset("single_image", seed=0, tokens_per_media_item=64)
    assert ctl.scheduler.roster_chips == 4
    ctl.schedule(ds.sample(8))
    assert ctl.plan.chips == 4 and ctl.recoveries == []

    fleet.fail(3, step=1)
    ctl.schedule(ds.sample(8))
    assert ctl.scheduler.roster_chips == 3
    assert ctl.plan.chips <= 3, "plan still sized for the dead host"
    rec = ctl.recoveries[-1]
    assert rec.adopted and not rec.degraded and rec.error is None
    assert rec.n_chips == 3 and rec.events[0].kind == "fail"

    fleet.join(3, step=2)
    ctl.schedule(ds.sample(8))
    assert ctl.scheduler.roster_chips == 4
    assert ctl.plan.chips == 4, "rejoin did not scale the plan back out"
    snap = ctl.metrics.snapshot()["fleet"]
    assert snap["n_host_failures"] == 1 and snap["n_host_joins"] == 1
    assert snap["n_recoveries"] == 2 and snap["n_degraded"] == 0
    assert snap["recovery_mean_s"] is not None
    ctl.close()


def test_controller_recovery_coalesces_simultaneous_events():
    ctl, fleet = _fleet_controller()
    ds = MixedDataset("single_image", seed=0, tokens_per_media_item=64)
    fleet.fail(1)
    fleet.fail(2)
    ctl.schedule(ds.sample(8))
    # two events, ONE recovery, planned for the roster that results
    assert len(ctl.recoveries) == 1
    rec = ctl.recoveries[0]
    assert len(rec.events) == 2 and rec.n_chips == 2
    assert ctl.plan.chips <= 2
    ctl.close()


def test_controller_recovery_degrades_when_search_fails(monkeypatch):
    import repro.runtime.controller as controller_mod

    class _Boom:
        def __init__(self, *a, **kw):
            raise RuntimeError("search backend down")

    ctl, fleet = _fleet_controller()
    ds = MixedDataset("single_image", seed=0, tokens_per_media_item=64)
    old_plan = ctl.plan
    monkeypatch.setattr(controller_mod, "ParallelismOptimizer", _Boom)
    fleet.fail(3)
    out = ctl.schedule(ds.sample(8))     # must not raise
    assert out is not None
    rec = ctl.recoveries[-1]
    assert not rec.adopted and rec.degraded
    assert "search backend down" in rec.error
    assert ctl.plan is old_plan          # stale plan kept, loop alive
    ctl.close()


class _FailingSwapper:
    """swap() and refresh() both fail; `damaged` controls whether the
    controller must fail fast (donated buffers gone) or degrade."""

    def __init__(self, damage):
        self.damaged_after = damage
        self.damaged = False
        self.calls = []

    def swap(self, old, new):
        self.calls.append(("swap", old.as_tuple(), new.as_tuple()))
        self.damaged = self.damaged_after
        raise RuntimeError("transfer failed")

    def refresh(self, plan):
        self.calls.append(("refresh", plan.as_tuple()))
        self.damaged = self.damaged_after
        raise RuntimeError("transfer failed")


def test_controller_recovery_reshard_failure_falls_back_to_stale_layout():
    sw = _FailingSwapper(damage=False)
    ctl, fleet = _fleet_controller(swapper=sw)
    ds = MixedDataset("single_image", seed=0, tokens_per_media_item=64)
    old_plan = ctl.plan
    fleet.fail(3)
    ctl.schedule(ds.sample(8))           # degrade, don't crash
    rec = ctl.recoveries[-1]
    assert not rec.adopted and rec.degraded and rec.reshard is None
    assert "transfer failed" in rec.error
    assert ctl.plan is old_plan
    # fallback chain was exercised: candidate swap, then old-plan refresh
    kinds = [c[0] for c in sw.calls]
    assert kinds in (["swap", "refresh"], ["refresh"])
    ctl.close()


def test_controller_recovery_raises_when_swapper_damaged():
    ctl, fleet = _fleet_controller(swapper=_FailingSwapper(damage=True))
    ds = MixedDataset("single_image", seed=0, tokens_per_media_item=64)
    fleet.fail(3)
    with pytest.raises(RuntimeError, match="transfer failed"):
        ctl.schedule(ds.sample(8))
    ctl.close()


def test_maybe_swap_gates_plan_raced_by_roster_shrink():
    """A background search sized for the pre-failure fleet must be gated,
    not adopted (and not crash set_plan's roster validation)."""
    import concurrent.futures

    from repro.runtime.drift import DriftEvent

    ctl, fleet = _fleet_controller()
    fleet.fail(3)
    ctl.poll_fleet()                     # roster now 3
    big = ParallelismPlan(llm=ModuleParallelism(1, 1, 4), n_mb=2)
    fut = concurrent.futures.Future()
    fut.set_result((DriftEvent("shape-ks", 0.5, 0.2, 8), ctl.engine.dist,
                    SearchResult(big, 1e-9, 5, 5, 0.01), 1e9))
    ctl._replan_future = fut
    assert ctl.maybe_swap() is False
    assert ctl.replans[-1].gated == "roster"
    assert ctl.plan.chips <= 3
    ctl.close()


# --------------------------------------------------------------------- #
# differential: fleet vs single-host, no fault -> identical decisions
# --------------------------------------------------------------------- #
def test_fleet_matches_single_host_when_no_fault_fires():
    ds_a = MixedDataset("mixed", seed=3, tokens_per_media_item=64)
    ds_b = MixedDataset("mixed", seed=3, tokens_per_media_item=64)

    eng_a = _tiny_engine()
    ctl_a = eng_a.runtime(8, adaptive=False, auto_replan=False,
                          calibrate=False, trace=False)
    eng_b = _tiny_engine()
    fleet = FleetManager(devices=list(range(4)), devices_per_host=1)
    ctl_b = eng_b.runtime(8, adaptive=False, auto_replan=False,
                          calibrate=False, trace=False, fleet=fleet)
    src = HostShardedSource(lambda: ds_b.sample(8), gbs=8, fleet=fleet)
    inj = FaultInjector(fleet, {})       # armed, never fires

    for k in range(6):
        items_a = ds_a.sample(8)
        inj.on_step(k)
        src.draw()
        items_b = src.in_flight
        # same seed, same stream: the sharded source must hand the training
        # loop the same global batches ...
        assert [it.item_id for it in items_b] \
            == [it.item_id for it in items_a]
        out_a = ctl_a.schedule(items_a)
        out_b = ctl_b.schedule(items_b)
        src.commit()
        # ... and the fleet-backed controller the same plan + groups
        assert out_b.plan.as_tuple() == out_a.plan.as_tuple()
        assert out_b.groups == out_a.groups
        assert out_b.cmax == pytest.approx(out_a.cmax)
    assert ctl_b.recoveries == [] and inj.fired == []
    ctl_a.close()
    ctl_b.close()


# --------------------------------------------------------------------- #
# device-level invariants (subprocess: forced host device count)
# --------------------------------------------------------------------- #
def test_fleet_plan_mesh_divisor_clamp():
    """The fleet mesh factory clamps each axis to its largest *divisor*
    (stage always divides PP), unlike `clamped_plan_mesh`'s min() clamp —
    the root of the silent-replication bug it fixes."""
    out = run_devices("""
        import jax
        from repro.core.optimizer.space import (ModuleParallelism,
                                                ParallelismPlan)
        from repro.launch.fleet import FleetManager, fleet_plan_mesh
        from repro.launch.reshard import clamped_plan_mesh

        plan = ParallelismPlan(llm=ModuleParallelism(1, 4, 1), n_mb=2)
        # capacity available: exact plan mesh
        mesh = fleet_plan_mesh(plan, jax.devices())
        assert dict(mesh.shape) == {"data": 1, "stage": 4, "model": 1}
        # 3 surviving devices: min() clamp gives stage=3 (does NOT divide
        # pp=4 -> silent replication); divisor clamp gives stage=2
        three = jax.devices()[:3]
        assert dict(clamped_plan_mesh(plan, devices=three).shape)["stage"] == 3
        assert dict(fleet_plan_mesh(plan, three).shape)["stage"] == 2
        # FleetManager routes through the divisor-aware factory
        fm = FleetManager(devices=jax.devices()[:4], devices_per_host=1)
        fm.fail(3)
        assert dict(fm.plan_mesh(plan).shape)["stage"] == 2
        try:
            fleet_plan_mesh(plan, [])
        except ValueError as e:
            assert "empty roster" in str(e)
        else:
            raise AssertionError("empty roster must raise")
        print("OK")
        """)
    assert "OK" in out


def test_fleet_plan_mesh_in_process():
    """Same divisor-clamp invariants on this process's own devices (the
    subprocess twin above isolates the forced device count; this one runs
    under the CI coverage job, whose tier-1 env forces 8 host devices)."""
    jax = pytest.importorskip("jax")
    if len(jax.devices()) < 4:
        pytest.skip("needs >=4 local devices (forced host platform)")
    fm = FleetManager(devices_per_host=1)    # default roster: jax.devices()
    assert fm.n_chips == len(jax.devices())
    plan = _plan(tp=1, pp=4, dp=1, n_mb=2)
    assert dict(fm.plan_mesh(plan).shape) == {"data": 1, "stage": 4,
                                              "model": 1}
    fm.fail(fm.n_hosts - 1, step=0)
    clamped = dict(fleet_plan_mesh(plan, fm.devices()).shape)
    assert plan.llm.pp % clamped["stage"] == 0   # divisor, never min()
    shards = fm.partition_items(list(range(10)))
    assert sorted(sum(shards.values(), [])) == list(range(10))
    assert set(shards) == set(fm.alive_ids())


def test_fleet_reshard_keeps_stage_sharding_on_shrunken_roster():
    """Regression (satellite fix): routing a reshard through the fleet
    mesh keeps stage-stacked params SHARDED over a narrower-but-divisible
    stage axis, where the clamped path silently replicates — including
    the pp=1 `(1, L, ...)` auto-detection edge."""
    out = run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core.pipeline.executor import (stack_stage_params,
                                                  unstack_stage_params)
        from repro.core.optimizer.space import (ModuleParallelism,
                                                ParallelismPlan)
        from repro.launch.fleet import FleetManager
        from repro.launch.reshard import (ParamSwapper, clamped_plan_mesh,
                                          reshard_params)

        def plan(pp):
            return ParallelismPlan(llm=ModuleParallelism(1, pp, 1), n_mb=2)

        W = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4)
        fm = FleetManager(devices=jax.devices()[:4], devices_per_host=1)
        fm.fail(3)                       # 3 survivors; pp=4 can't fit exactly

        # clamped path: stage=3, 4 % 3 != 0 -> replicated (the pinned
        # legacy behaviour this fix routes around)
        got_c, _ = reshard_params(stack_stage_params(W, 4), plan(4), plan(4),
                                  new_mesh=clamped_plan_mesh(
                                      plan(4), devices=fm.devices()),
                                  stage_stacked=True)
        assert got_c.sharding.spec == P()

        # fleet path: stage=2 divides 4 -> params stay sharded
        got_f, _ = reshard_params(stack_stage_params(W, 4), plan(4), plan(4),
                                  stage_stacked=True,
                                  mesh_factory=fm.plan_mesh)
        assert got_f.sharding.spec == P("stage"), got_f.sharding.spec
        np.testing.assert_array_equal(
            np.asarray(unstack_stage_params(got_f)), np.asarray(W))

        # pp=1 (1, L, ...) auto-detect edge through the fleet factory:
        # stage_stacked=None must re-partition, land sharded, and invert
        live = {"p": stack_stage_params(W, 1)}
        sw = ParamSwapper(lambda: live["p"],
                          lambda v: live.update(p=v),
                          stage_stacked=False,     # autodetect inside
                          mesh_factory=fm.plan_mesh)
        new, rep = reshard_params(live["p"], plan(1), plan(4),
                                  mesh_factory=fm.plan_mesh)
        assert rep.restacked and new.shape == (4, 2, 4)
        assert new.sharding.spec == P("stage")
        np.testing.assert_array_equal(
            np.asarray(unstack_stage_params(new)), np.asarray(W))
        print("OK")
        """)
    assert "OK" in out


def test_fleet_pipeline_bit_identical_across_roster_transitions():
    """Tentpole acceptance: `pipeline_forward` outputs are BIT-identical
    across N -> N-1 -> N host transitions, with the live (params, opt)
    pytree migrated checkpoint-free through ParamSwapper.refresh on the
    fleet mesh — and the optimizer state survives exactly."""
    out = run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.pipeline.executor import (build_stage_fn,
                                                  pipeline_forward,
                                                  stack_stage_params)
        from repro.core.optimizer.space import (ModuleParallelism,
                                                ParallelismPlan)
        from repro.launch.fleet import FaultInjector, FleetManager
        from repro.launch.reshard import ParamSwapper

        n_layers, d = 8, 16
        plan = ParallelismPlan(llm=ModuleParallelism(1, 4, 1), n_mb=4)
        fm = FleetManager(devices=jax.devices(), devices_per_host=1)

        W = jax.random.normal(jax.random.PRNGKey(0), (n_layers, d, d)) \\
            * (d ** -0.5)
        stacked = stack_stage_params(W, 4)
        opt_m = jax.random.normal(jax.random.PRNGKey(2), stacked.shape)
        mesh0 = fm.plan_mesh(plan)
        live = {"state": (
            jax.device_put(stacked, NamedSharding(mesh0, P("stage"))),
            jax.device_put(opt_m, NamedSharding(mesh0, P("stage"))))}
        sw = ParamSwapper(lambda: live["state"],
                          lambda s: live.update(state=s),
                          stage_stacked=True, mesh_factory=fm.plan_mesh)

        xs = jax.random.normal(jax.random.PRNGKey(1), (4, 2, 8, d))

        def forward():
            mesh = fm.plan_mesh(plan)
            p = pipeline_forward(mesh, build_stage_fn(
                lambda w, x: jnp.tanh(x @ w), n_layers // 4))
            with mesh:
                return np.asarray(p(live["state"][0], xs))

        ref = forward()                          # 8 hosts, plan on first 4

        # fail host 0 — ITS devices carry the live state, so recovery
        # must migrate, not merely re-index
        inj = FaultInjector(fm, {1: [("fail", 0)], 2: [("join", 0)]})
        inj.on_step(1)
        sw.refresh(plan)                         # checkpoint-free migration
        used = {d.id for l in jax.tree_util.tree_leaves(live["state"])
                for d in l.sharding.device_set}
        dead = {d.id for d in fm.host(0).devices}
        assert not (used & dead), "state still resident on the dead host"
        got = forward()
        assert np.array_equal(got, ref), "N-1 forward != N forward"
        np.testing.assert_array_equal(np.asarray(live["state"][1]),
                                      np.asarray(opt_m))

        inj.on_step(2)                           # host 0 rejoins
        sw.refresh(plan)
        got2 = forward()
        assert np.array_equal(got2, ref), "N recovery forward != original"
        np.testing.assert_array_equal(np.asarray(live["state"][1]),
                                      np.asarray(opt_m))
        assert [e.kind for e in inj.fired] == ["fail", "join"]
        assert len(sw.reports) == 2
        print("OK")
        """)
    assert "OK" in out


def test_fleet_loss_trajectory_continuity_under_churn():
    """Checkpoint-free recovery pins the LOSS TRAJECTORY, not just one
    forward: an emulated training loop whose steps abort on mid-step host
    failure produces the exact loss sequence of the fault-free run."""
    gbs, n_steps = 8, 12
    ds = MixedDataset("mixed", seed=11, tokens_per_media_item=64)

    def emu_loss(batch):
        # deterministic stand-in for a train step: any pure f(batch)
        return float(sum(it.text_len + 31 * it.n_media_items
                         for it in batch))

    ref_src = HostShardedSource(lambda: ds.sample(gbs), gbs=gbs)
    ref_losses = []
    for _ in range(n_steps):
        ref_src.draw([0])
        ref_losses.append(emu_loss(ref_src.in_flight))
        ref_src.commit()

    ds2 = MixedDataset("mixed", seed=11, tokens_per_media_item=64)
    fm = FleetManager(devices=list(range(4)), devices_per_host=1)
    src = HostShardedSource(lambda: ds2.sample(gbs), gbs=gbs, fleet=fm)
    inj = FaultInjector(fm, {3: [("fail", 2)], 7: [("join", 2)],
                             9: [("fail", 1)]})
    losses, k = [], 0
    while len(losses) < n_steps:
        src.draw()
        mid_step = inj.on_step(k)
        k += 1
        if any(e.kind == "fail" for e in mid_step):
            src.abort()                  # step lost with the host
            continue
        losses.append(emu_loss(src.in_flight))
        src.commit()
    assert src.n_aborted == 2
    assert losses == ref_losses


# --------------------------------------------------------------------- #
# end-to-end: elastic train_mllm smoke (slow; subprocess forces devices)
# --------------------------------------------------------------------- #
@pytest.mark.slow
def test_train_mllm_elastic_smoke(tmp_path):
    """The example driver survives kill + revive on an emulated 4-host
    fleet: two checkpoint-free recoveries, exactly-once delivery, loss
    finite, physical migrations recorded."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "examples", "train_mllm.py"),
         "--tiny", "--steps", "8", "--hosts", "4",
         "--fail-host-at", "3", "--revive-host-at", "6"],
        capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    fleet_line = [l for l in r.stdout.splitlines()
                  if l.startswith("[fleet] hosts=")][0]
    assert "failures=1" in fleet_line and "joins=1" in fleet_line
    assert "recoveries=2" in fleet_line and "degraded=0" in fleet_line
    assert "committed=8" in fleet_line and "aborted=0" in fleet_line
    swaps = [l for l in r.stdout.splitlines() if "physical_swaps=" in l][0]
    n_swaps = int(swaps.split("physical_swaps=")[1].split()[0])
    assert n_swaps >= 2, r.stdout
