"""1F1B pipeline simulator tests (paper Fig. 1 / §5.3.5).

The batched wavefront implementation (`simulate_1f1b_batch`) is pinned to
the reference event loop op-for-op: same start/end times bit-for-bit on
random heterogeneous (p, m) instances.  See docs/simulator.md.
"""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.pipeline.simulator import (ideal_bubble_fraction,
                                           simulate_1f1b,
                                           simulate_1f1b_batch,
                                           simulate_bucket_ranks,
                                           simulate_bucket_ranks_batch)


def test_homogeneous_makespan_formula():
    for p, m, f in [(2, 4, 1.0), (4, 6, 0.5), (8, 8, 2.0)]:
        tr = simulate_1f1b(np.full((p, m), f))
        np.testing.assert_allclose(tr.makespan, (m + p - 1) * 3 * f)
        np.testing.assert_allclose(tr.idle_fraction,
                                   ideal_bubble_fraction(p, m))


dur_matrix = st.integers(1, 5).flatmap(
    lambda p: st.integers(1, 8).flatmap(
        lambda m: st.lists(
            st.lists(st.floats(0.01, 5.0), min_size=m, max_size=m),
            min_size=p, max_size=p)))


@given(dur_matrix)
@settings(max_examples=100, deadline=None)
def test_1f1b_invariants(rows):
    fwd = np.array(rows)
    p, m = fwd.shape
    tr = simulate_1f1b(fwd)
    # makespan bounded below by any stage's busy time and by the
    # fwd+bwd critical path of any microbatch
    assert tr.makespan >= tr.stage_busy.max() - 1e-9
    crit = fwd.sum(axis=0) + 2 * fwd.sum(axis=0)
    assert tr.makespan >= crit.max() - 1e-9
    # ops on one stage never overlap
    per_stage = {}
    for kind, s, i, t0, t1 in tr.ops:
        per_stage.setdefault(s, []).append((t0, t1))
    for s, spans in per_stage.items():
        spans.sort()
        for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
            assert b0 >= a1 - 1e-9
    # dependency: F[s,i] starts after F[s-1,i] ends
    f_end = {}
    for kind, s, i, t0, t1 in tr.ops:
        if kind == "F":
            f_end[(s, i)] = t1
    for (s, i), t1 in f_end.items():
        if s > 0:
            assert t1 >= f_end[(s - 1, i)] - 1e-9


# --------------------------------------------------------------------- #
# batched wavefront == reference event loop
# --------------------------------------------------------------------- #
def _assert_batch_matches_reference(fwd: np.ndarray, bwd: np.ndarray):
    """Every instance of a (B, p, m) batch must match the reference
    simulator bit-for-bit, op-for-op."""
    batch = simulate_1f1b_batch(fwd, bwd, record_ops=True)
    for b in range(fwd.shape[0]):
        ref = simulate_1f1b(fwd[b], bwd[b])
        assert np.float64(ref.makespan) == batch.makespan[b]
        assert np.array_equal(ref.stage_busy, batch.stage_busy[b])
        assert np.array_equal(ref.stage_idle, batch.stage_idle[b])
        for kind, s, i, t0, t1 in ref.ops:
            start, end = ((batch.f_start, batch.f_end) if kind == "F"
                          else (batch.b_start, batch.b_end))
            assert start[b][s, i] == t0 and end[b][s, i] == t1


@given(st.integers(1, 6), st.integers(1, 10), st.integers(1, 4),
       st.integers(0, 10_000))
@settings(max_examples=100, deadline=None)
def test_batch_matches_reference_op_for_op(p, m, B, seed):
    rng = np.random.default_rng(seed)
    fwd = rng.uniform(0.01, 5.0, (B, p, m))
    bwd = rng.uniform(0.01, 5.0, (B, p, m))
    _assert_batch_matches_reference(fwd, bwd)


def test_batch_matches_reference_deterministic():
    """Shim-proof variant (runs without hypothesis installed), covering the
    degenerate axes: p=1, m=1, zero backward durations, default bwd."""
    rng = np.random.default_rng(7)
    for p, m, B in [(1, 1, 1), (1, 8, 3), (8, 1, 2), (5, 16, 4), (3, 3, 2)]:
        fwd = rng.uniform(0.01, 5.0, (B, p, m))
        _assert_batch_matches_reference(fwd, rng.uniform(0.01, 5.0, (B, p, m)))
        _assert_batch_matches_reference(fwd, np.zeros_like(fwd))
        _assert_batch_matches_reference(fwd, 2.0 * fwd)


def test_batch_leading_shape_and_homogeneous_formula():
    fwd = np.ones((3, 2, 4, 6))                    # lead (3, 2), p=4, m=6
    tr = simulate_1f1b_batch(fwd)
    assert tr.makespan.shape == (3, 2)
    assert tr.stage_busy.shape == (3, 2, 4)
    np.testing.assert_allclose(tr.makespan, (6 + 4 - 1) * 3.0)
    np.testing.assert_allclose(tr.idle_fraction, ideal_bubble_fraction(4, 6))
    # ops are not materialized unless asked for (the batched scoring path
    # must not allocate B·p·m op tuples)
    assert tr.f_end is None and tr.b_end is None
    assert tr.trace((0, 1)).ops is None


def test_batch_trace_reconstruction():
    rng = np.random.default_rng(3)
    fwd = rng.uniform(0.1, 2.0, (2, 3, 5))
    batch = simulate_1f1b_batch(fwd, record_ops=True)
    for b in range(2):
        ref = simulate_1f1b(fwd[b])
        got = batch.trace(b)
        assert got.ops is not None and len(got.ops) == len(ref.ops)
        assert sorted(got.ops) == sorted(ref.ops)
        assert got.makespan == ref.makespan


def test_bucket_ranks_generator_matches_batch():
    """`simulate_bucket_ranks` is a thin per-rank view of the batched call,
    and the bucket→(mb, rank) layout is bucket i·dp + r."""
    rng = np.random.default_rng(5)
    n_mb, dp, e_pp, l_pp = 3, 4, 1, 2
    e_b = rng.uniform(0.0, 0.5, n_mb * dp)
    l_b = rng.uniform(0.1, 1.0, n_mb * dp)
    batch = simulate_bucket_ranks_batch(e_b, l_b, n_mb=n_mb, dp=dp,
                                        e_pp=e_pp, l_pp=l_pp)
    assert batch.makespan.shape == (dp,)
    for r, tr in enumerate(simulate_bucket_ranks(e_b, l_b, n_mb=n_mb, dp=dp,
                                                 e_pp=e_pp, l_pp=l_pp)):
        assert tr.makespan == batch.makespan[r]
        # rebuild rank r's stage rows by hand (the documented convention)
        rows = np.empty((e_pp + l_pp, n_mb))
        for i in range(n_mb):
            rows[:e_pp, i] = e_b[i * dp + r]
            rows[e_pp:, i] = l_b[i * dp + r]
        fwd = rows / 3.0
        assert simulate_1f1b(fwd, 2.0 * fwd).makespan == tr.makespan


def test_batch_speedup_over_reference():
    """The point of the wavefront: one batched call beats the reference
    loop by well over the acceptance 5× at re-rank-like sizes (same
    machine, same work — robust to CI speed)."""
    import time
    rng = np.random.default_rng(0)
    fwd = rng.uniform(0.1, 2.0, (128, 4, 32))
    simulate_1f1b_batch(fwd[:1])                   # warm the order cache
    t0 = time.perf_counter()
    simulate_1f1b_batch(fwd)
    t_batch = time.perf_counter() - t0
    t0 = time.perf_counter()
    for b in range(128):
        simulate_1f1b(fwd[b])
    t_ref = time.perf_counter() - t0
    assert t_ref / t_batch >= 5.0, (t_ref, t_batch)


def test_heterogeneity_hurts_bubble():
    """The real case of Fig. 1: variable microbatch durations create more
    idle time than the homogeneous ideal."""
    rng = np.random.default_rng(0)
    p, m = 4, 8
    mean = 1.0
    uniform = simulate_1f1b(np.full((p, m), mean))
    skewed = rng.lognormal(0, 0.8, (p, m))
    skewed *= mean / skewed.mean()
    het = simulate_1f1b(skewed)
    assert het.idle_fraction > uniform.idle_fraction
