"""1F1B pipeline simulator tests (paper Fig. 1 / §5.3.5)."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.pipeline.simulator import (ideal_bubble_fraction,
                                           simulate_1f1b)


def test_homogeneous_makespan_formula():
    for p, m, f in [(2, 4, 1.0), (4, 6, 0.5), (8, 8, 2.0)]:
        tr = simulate_1f1b(np.full((p, m), f))
        np.testing.assert_allclose(tr.makespan, (m + p - 1) * 3 * f)
        np.testing.assert_allclose(tr.idle_fraction,
                                   ideal_bubble_fraction(p, m))


dur_matrix = st.integers(1, 5).flatmap(
    lambda p: st.integers(1, 8).flatmap(
        lambda m: st.lists(
            st.lists(st.floats(0.01, 5.0), min_size=m, max_size=m),
            min_size=p, max_size=p)))


@given(dur_matrix)
@settings(max_examples=100, deadline=None)
def test_1f1b_invariants(rows):
    fwd = np.array(rows)
    p, m = fwd.shape
    tr = simulate_1f1b(fwd)
    # makespan bounded below by any stage's busy time and by the
    # fwd+bwd critical path of any microbatch
    assert tr.makespan >= tr.stage_busy.max() - 1e-9
    crit = fwd.sum(axis=0) + 2 * fwd.sum(axis=0)
    assert tr.makespan >= crit.max() - 1e-9
    # ops on one stage never overlap
    per_stage = {}
    for kind, s, i, t0, t1 in tr.ops:
        per_stage.setdefault(s, []).append((t0, t1))
    for s, spans in per_stage.items():
        spans.sort()
        for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
            assert b0 >= a1 - 1e-9
    # dependency: F[s,i] starts after F[s-1,i] ends
    f_end = {}
    for kind, s, i, t0, t1 in tr.ops:
        if kind == "F":
            f_end[(s, i)] = t1
    for (s, i), t1 in f_end.items():
        if s > 0:
            assert t1 >= f_end[(s - 1, i)] - 1e-9


def test_heterogeneity_hurts_bubble():
    """The real case of Fig. 1: variable microbatch durations create more
    idle time than the homogeneous ideal."""
    rng = np.random.default_rng(0)
    p, m = 4, 8
    mean = 1.0
    uniform = simulate_1f1b(np.full((p, m), mean))
    skewed = rng.lognormal(0, 0.8, (p, m))
    skewed *= mean / skewed.mean()
    het = simulate_1f1b(skewed)
    assert het.idle_fraction > uniform.idle_fraction
