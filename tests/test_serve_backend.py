"""Contract tests for the serving-loop / execution-backend split.

Four layers:

  * backend seam: the refactored loop driving `EmulatedBackend` replays
    PR 6's discrete-event stream **byte-for-byte** (differential golden,
    pinned against `tests/golden/fig19_prerefactor.json` captured on the
    pre-refactor ServeEngine);
  * pricing: `PrefillPricer.flush()` invalidates the decode-step
    token-cost fits, not just prefill prices (regression — stale decode
    fits survived a drift re-price before this PR);
  * real substrate: chunked prefill is token-identical to one-shot
    `prefill_into_cache`; a cache-row transferred across devices
    preserves its decode continuation bit-for-bit (subprocess with
    forced host devices); `RealBackend`'s engine-driven generations
    match solo replays, including through a park → re-join preemption;
  * engine policy: decode-slot preemption rescues an urgent request and
    the victim completes after re-joining.

fig22 smoke (tier-1) + acceptance (slow) close the measured
calibrate → drift → re-price loop.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.types import ModelConfig
from repro.core.engine import DFLOPEngine
from repro.core.optimizer.space import ClusterSpec
from repro.data.items import DataItem
from repro.models import model as model_lib
from repro.serve import (PrefillPricer, Request, ServeConfig,
                         extract_cache_row, make_decode_step,
                         merge_cache_row, pow2_chunks, prefill_into_cache,
                         prefill_into_cache_chunked)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN = os.path.join(ROOT, "tests", "golden", "fig19_prerefactor.json")

TPM = 8
ENC = ModelConfig(name="tb-enc", family="vlm-enc", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=0,
                  causal=False, use_rope=False, input_embed_dim=32,
                  has_lm_head=False)
LLM = ModelConfig(name="tb-llm", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=128,
                  dtype="float32")
MAX_LEN = 64


@pytest.fixture(scope="module")
def tiny_engine():
    from repro.data.synthetic import MixedDataset
    eng = DFLOPEngine(llm_cfg=LLM, enc_cfg=ENC, e_seq_len=16,
                      cluster=ClusterSpec(n_chips=4, chips_per_node=4,
                                          mem_bytes=16e9),
                      tokens_per_media_item=TPM)
    eng.profile(MixedDataset("mixed", seed=0, tokens_per_media_item=TPM),
                n_samples=64)
    return eng


@pytest.fixture(scope="module")
def tiny_params():
    return model_lib.init(jax.random.PRNGKey(0), LLM)


def _req(i, *, arrival=0.0, slo=60.0, n_media=1, text=16, max_new=6,
         factor=1.0, modality="single_image"):
    return Request(item=DataItem(n_media, text, modality, i),
                   arrival_s=arrival, slo_s=slo, max_new_tokens=max_new,
                   true_factor=factor)


def _solo_generate(cfg, params, prompt_1d, max_new, max_len=MAX_LEN):
    """Reference: the request never leaves its own B=1 cache."""
    prompt = jnp.asarray(np.asarray(prompt_1d)[None, :], jnp.int32)
    logits, caches = prefill_into_cache(cfg, params, prompt, max_len)
    decode = jax.jit(make_decode_step(cfg))
    toks, pos = [], prompt.shape[1]
    tok = jnp.argmax(logits, axis=-1).reshape(1).astype(jnp.int32)
    for _ in range(max_new):
        toks.append(int(tok[0]))
        logits, caches = decode(params, caches, tok, pos)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        pos += 1
    return toks


# --------------------------------------------------------------------- #
# backend seam: the refactor preserves the emulated event stream
# --------------------------------------------------------------------- #
def test_emulated_backend_stream_identical_to_prerefactor_golden():
    """Differential: fig19's smoke rows through the refactored
    loop + `EmulatedBackend` must be byte-equal (sorted-key JSON) to the
    stream captured on the pre-refactor monolithic ServeEngine."""
    from benchmarks.fig19_serving import run_smoke
    with open(GOLDEN) as f:
        want = json.load(f)
    got = run_smoke(seed=0)
    assert json.dumps(got, sort_keys=True) == \
        json.dumps(want["smoke"], sort_keys=True)


@pytest.mark.slow
def test_emulated_backend_medium_stream_identical_to_prerefactor_golden():
    """Same contract on a longer, queue-saturating stream (one QPS point,
    160 requests) — chunk boundaries, handoff pricing and drift events
    all replay identically."""
    from benchmarks.fig19_serving import run
    with open(GOLDEN) as f:
        want = json.load(f)
    got = run(qps_points=(3.0,), n_requests=160, seed=0)
    assert json.dumps(got, sort_keys=True) == \
        json.dumps(want["medium_qps3_n160"], sort_keys=True)


# --------------------------------------------------------------------- #
# pricing: flush() must invalidate decode fits too (regression)
# --------------------------------------------------------------------- #
def test_flush_invalidates_decode_token_cost_fit(tiny_engine):
    from repro.runtime import OnlineCalibrator
    cal = OnlineCalibrator()
    pricer = PrefillPricer(tiny_engine.perf, TPM, calibrator=cal)
    c = 256
    base = pricer.decode_tok_base_s(c)
    d0 = pricer.decode_tok_s(c)              # memoizes ratio 1.0
    assert d0 == base
    for _ in range(12):                      # teach decode cells 2×
        cal.observe("decode", 256.0, 1, 1.0, 2.0)
    assert pricer.decode_tok_s(c) == d0      # memoized: stale until flush
    pricer.flush()
    assert pricer.decode_tok_s(c) == pytest.approx(base * 2.0, rel=1e-6)
    # prefill prices flush alongside (the pre-existing contract)
    assert pricer.n_flushes == 1


# --------------------------------------------------------------------- #
# real substrate: chunked prefill + cross-device row transfer
# --------------------------------------------------------------------- #
def test_pow2_chunks_cover_length_with_bounded_shape_set():
    for length in (1, 5, 16, 26, 45, 63):
        chunks = pow2_chunks(length, 16)
        assert sum(chunks) == length
        # every chunk is the full chunk size or a power of two below it
        assert all(c == 16 or (c & (c - 1)) == 0 for c in chunks)
    with pytest.raises(ValueError):
        pow2_chunks(4, 0)


def test_chunked_prefill_token_identical_to_one_shot(tiny_params):
    """Satellite contract: `prefill_into_cache_chunked` must hand decode
    the same state as one-shot `prefill_into_cache` — same next token and
    an identical greedy continuation."""
    rng = jax.random.PRNGKey(11)
    for n, length in enumerate((5, 13, 26)):   # 1-chunk, ragged, multi
        prompt = jax.random.randint(jax.random.fold_in(rng, n), (length,),
                                    2, LLM.vocab_size)
        l1, c1 = prefill_into_cache(LLM, tiny_params, prompt[None, :],
                                    MAX_LEN)
        l2, c2 = prefill_into_cache_chunked(LLM, tiny_params,
                                            prompt[None, :], MAX_LEN,
                                            chunk=8)
        assert int(jnp.argmax(l1)) == int(jnp.argmax(l2))
        np.testing.assert_allclose(np.asarray(l1).ravel(),
                                   np.asarray(l2).ravel(),
                                   rtol=1e-5, atol=1e-6)
        solo = _solo_generate(LLM, tiny_params, prompt, 6)
        decode = jax.jit(make_decode_step(LLM))
        tok = jnp.argmax(l2, axis=-1).reshape(1).astype(jnp.int32)
        got, pos = [], length
        for _ in range(6):
            got.append(int(tok[0]))
            l2, c2 = decode(tiny_params, c2, tok, pos)
            tok = jnp.argmax(l2, axis=-1).astype(jnp.int32)
            pos += 1
        assert got == solo


def test_cache_row_transfer_across_devices_bit_exact():
    """Satellite contract (subprocess, forced host devices): a prefilled
    B=1 cache `jax.device_put` to a *different* device, merged into a
    shared decode batch there, is bit-identical to the source and its
    greedy continuation matches the solo run exactly."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.common.types import ModelConfig
        from repro.models import model as model_lib
        from repro.serve import (extract_cache_row, make_decode_step,
                                 merge_cache_row, prefill_into_cache)
        cfg = ModelConfig(name="tb-llm", family="dense", n_layers=2,
                          d_model=64, n_heads=4, n_kv_heads=2, d_ff=256,
                          vocab_size=128, dtype="float32")
        MAX_LEN = 32
        params = model_lib.init(jax.random.PRNGKey(0), cfg)
        d_pre, d_dec = jax.devices()[0], jax.devices()[1]
        assert d_pre != d_dec
        prompt = jax.random.randint(jax.random.PRNGKey(7), (9,), 2,
                                    cfg.vocab_size)

        # solo reference on the prefill device
        def solo():
            l, c = prefill_into_cache(cfg, params, prompt[None, :], MAX_LEN)
            dec = jax.jit(make_decode_step(cfg))
            tok = jnp.argmax(l, -1).reshape(1).astype(jnp.int32)
            toks, pos = [], 9
            for _ in range(6):
                toks.append(int(tok[0]))
                l, c = dec(params, c, tok, pos)
                tok = jnp.argmax(l, -1).astype(jnp.int32)
                pos += 1
            return toks
        want = solo()

        # prefill on device 0, hand the cache off to device 1
        pp = jax.device_put(params, d_pre)
        l, cache = prefill_into_cache(cfg, pp, jax.device_put(
            prompt[None, :], d_pre), MAX_LEN)
        moved = jax.device_put(cache, d_dec)
        for a, b in zip(jax.tree_util.tree_leaves(cache),
                        jax.tree_util.tree_leaves(moved)):
            assert np.array_equal(np.asarray(a), np.asarray(b)), "lossy"
            assert b.devices() == {d_dec}
        shared = jax.device_put(
            model_lib.init_cache(cfg, 2, MAX_LEN, jnp.float32), d_dec)
        shared = merge_cache_row(shared, moved, row=1)
        row = extract_cache_row(shared, 1)
        for a, b in zip(jax.tree_util.tree_leaves(cache),
                        jax.tree_util.tree_leaves(row)):
            assert np.array_equal(np.asarray(a), np.asarray(b)), "merge"
        # decode continuation on the far device, in the shared batch
        pd = jax.device_put(params, d_dec)
        dec = jax.jit(make_decode_step(cfg))
        tok = jnp.asarray([0, int(jnp.argmax(l))], jnp.int32)
        pos = jnp.asarray([0, 9], jnp.int32)
        got = []
        for _ in range(6):
            got.append(int(tok[1]))
            lg, shared = dec(pd, shared, tok, pos)
            tok = jnp.argmax(lg, -1).astype(jnp.int32)
            pos = pos + 1
        assert got == want, (got, want)
        print("OK")
        """)], capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout


# --------------------------------------------------------------------- #
# RealBackend: engine-driven generations match solo replays
# --------------------------------------------------------------------- #
def test_real_backend_engine_tokens_match_solo(tiny_engine, tiny_params):
    """The whole loop — admission, chunked prefill, device handoff,
    continuous-batch decode with join/leave/compaction — must be a no-op
    for the tokens: every request generates exactly its solo sequence."""
    cfg = ServeConfig(n_prefill_workers=1, n_decode_workers=1,
                      decode_slots=2, max_prefill_batch=2)
    serve = tiny_engine.serving(serve_cfg=cfg, backend="real",
                                model_params=tiny_params, max_len=MAX_LEN,
                                chunk=16, warmup=False)
    rng = np.random.default_rng(3)
    reqs = [_req(i, arrival=float(i) * 1e-3,
                 n_media=int(rng.integers(1, 4)),
                 text=int(rng.integers(4, 20)), max_new=5)
            for i in range(6)]
    rep = serve.run(reqs)
    assert rep.n_completed == 6
    assert serve.metrics.n_prefill_chunks > 0    # multi-chunk prefills ran
    assert serve.prediction_log                  # measured feedback flowed
    assert {m for m, _, _ in serve.prediction_log} == {"prefill", "decode"}
    for r in reqs:
        want = _solo_generate(LLM, tiny_params,
                              serve.backend.prompt_for(r), 5)
        assert r.generated == want, r.item.item_id


def test_real_backend_park_rejoin_preserves_generation(tiny_engine,
                                                       tiny_params):
    """Preemption substrate: park a mid-decode row (snapshot before slot
    compaction), decode the survivor, re-join the parked request — its
    full token sequence must still match the solo replay bit-for-bit."""
    from repro.serve.real import RealBackend
    pricer = PrefillPricer(tiny_engine.perf, TPM)
    cfg = ServeConfig(n_prefill_workers=1, n_decode_workers=1,
                      decode_slots=2, max_prefill_batch=2)
    be = RealBackend(LLM, tiny_params, pricer, cfg, max_len=MAX_LEN,
                     chunk=8, warmup=False)
    ra = _req(0, n_media=2, text=10, max_new=6)
    rb = _req(1, n_media=1, text=5, max_new=6)
    solo = {0: _solo_generate(LLM, tiny_params, be.prompt_for(ra), 6),
            1: _solo_generate(LLM, tiny_params, be.prompt_for(rb), 6)}
    be.prefill(0, [ra, rb], s_pad=MAX_LEN)
    for r in (ra, rb):
        be.handoff(r)
        be.join(0, r)
    for _ in range(2):
        be.decode_step(0, [ra, rb])
    be.release(0, ra, park=True)             # preempt A mid-generation
    for _ in range(4):                       # B finishes alone
        be.decode_step(0, [rb])
    be.release(0, rb)
    be.join(0, ra)                           # A re-joins from the park
    for _ in range(4):
        be.decode_step(0, [ra])
    be.release(0, ra)
    assert ra.generated == solo[0]
    assert rb.generated == solo[1]
    assert ra.n_preempted == 0               # engine-level counter only


# --------------------------------------------------------------------- #
# engine policy: decode-slot preemption rescues an urgent request
# --------------------------------------------------------------------- #
def test_preemption_rescues_urgent_request(tiny_engine):
    """Emulated loop, one decode slot: a slack-rich long request is
    parked for an already-late arrival, which finishes first; the victim
    re-joins through the ready queue and still completes."""
    cfg = ServeConfig(n_prefill_workers=1, n_decode_workers=1,
                      decode_slots=1, max_prefill_batch=1,
                      preempt_slack_s=10.0)
    serve = tiny_engine.serving(serve_cfg=cfg, drift=False)
    victim = _req(0, arrival=0.0, slo=1e9, max_new=8, factor=1e6)
    urgent = _req(1, arrival=0.0, slo=0.0, max_new=4)
    rep = serve.run([victim, urgent])
    assert rep.n_completed == 2
    assert serve.n_preemptions >= 1
    assert serve.metrics.n_preemptions >= 1
    assert victim.n_preempted >= 1
    assert urgent.finish_s < victim.finish_s
    assert "decode_preempt" in [e[1] for e in serve.trace._events]


def test_preemption_off_by_default(tiny_engine):
    """`preempt_slack_s=None` must reproduce PR 6 behavior exactly — no
    preemption machinery in the event stream."""
    serve = tiny_engine.serving(serve_cfg=ServeConfig(
        n_prefill_workers=1, n_decode_workers=1, decode_slots=1,
        max_prefill_batch=1), drift=False)
    victim = _req(0, arrival=0.0, slo=1e9, max_new=8, factor=1e6)
    urgent = _req(1, arrival=0.0, slo=0.0, max_new=4)
    serve.run([victim, urgent])
    assert serve.n_preemptions == 0
    assert victim.n_preempted == 0
    assert urgent.finish_s > victim.finish_s     # FIFO-ish completion


# --------------------------------------------------------------------- #
# device pools
# --------------------------------------------------------------------- #
def test_serve_device_pools_contract():
    from repro.launch.mesh import serve_device_pools
    devs = [f"d{i}" for i in range(8)]
    pre, dec = serve_device_pools(2, 3, devices=devs)
    assert pre == ["d0", "d1"] and dec == ["d2", "d3", "d4"]
    assert not set(pre) & set(dec)               # disjoint when possible
    pre, dec = serve_device_pools(2, 2, devices=["d0"])
    assert pre == ["d0", "d0"] and dec == ["d0", "d0"]   # graceful wrap
    with pytest.raises(ValueError):
        serve_device_pools(0, 2, devices=devs)


def test_kv_cache_bytes_scales_linearly():
    from repro.models.layers.attention import kv_cache_bytes
    b1 = kv_cache_bytes(LLM, 1024)
    assert b1 > 0
    assert kv_cache_bytes(LLM, 2048) == pytest.approx(2 * b1)
    assert kv_cache_bytes(LLM, 1024, bytes_per_value=4) == \
        pytest.approx(2 * b1)


# --------------------------------------------------------------------- #
# fig22: smoke (tier-1) + acceptance (slow)
# --------------------------------------------------------------------- #
def test_fig22_smoke():
    from benchmarks.fig22_real_serving import run_smoke
    rows = run_smoke()
    acc = rows[-1]
    assert acc.get("phase") == "acceptance"
    assert acc["reprice_fired"], "video shift did not trip re-price"
    assert acc["err_shrank"], "calibration did not reduce error"
    reports = [r for r in rows if "policy" in r]
    assert {r["policy"] for r in reports} == {"fifo", "slo"}
    assert all(r["n_completed"] == r["n_requests"] == 16 for r in reports)
    assert any(r["n_prefill_chunks"] > 0 for r in reports)


@pytest.mark.slow
def test_fig22_real_serving_acceptance():
    """Headline: on the real loop, re-price fires on the mid-stream video
    shift, emulated-vs-measured error shrinks after calibration, and SLO
    admission beats FIFO goodput at >=1 swept load point."""
    from benchmarks.fig22_real_serving import run
    rows = run()
    acc = rows[-1]
    assert acc.get("phase") == "acceptance"
    assert acc["reprice_fired"], rows
    assert acc["err_shrank"], rows
    assert acc["slo_goodput_win"], rows
