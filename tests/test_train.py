"""Training substrate tests: losses, AdamW, schedule, checkpointing."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.checkpoint import load_meta, restore, save
from repro.train.loss import cross_entropy, masked_cross_entropy
from repro.train.optim import AdamWConfig, adamw_init, adamw_update, cosine_lr


def test_cross_entropy_matches_manual():
    logits = jnp.array([[[2.0, 0.0, -1.0], [0.0, 1.0, 0.0]]])
    labels = jnp.array([[0, -1]])
    got = float(cross_entropy(logits, labels))
    want = -jax.nn.log_softmax(logits[0, 0])[0]
    np.testing.assert_allclose(got, float(want), rtol=1e-6)


def test_cross_entropy_all_masked_is_finite():
    logits = jnp.ones((1, 4, 8))
    labels = jnp.full((1, 4), -1)
    assert np.isfinite(float(cross_entropy(logits, labels)))


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(300):
        grads = jax.grad(lambda p: jnp.sum(jnp.square(p["w"])))(params)
        params, state = adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    huge = {"w": jnp.full(3, 1e9)}
    p2, _ = adamw_update(cfg, params, huge, state)
    assert float(jnp.abs(p2["w"]).max()) < 10.0


def test_cosine_lr_shape():
    f = cosine_lr(1.0, warmup=10, total=100, min_frac=0.1)
    assert float(f(0)) == 0.0
    np.testing.assert_allclose(float(f(10)), 1.0, rtol=1e-5)
    np.testing.assert_allclose(float(f(100)), 0.1, rtol=1e-4)
    assert float(f(55)) < float(f(20))


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ck")
        save(path, tree, meta={"step": 7})
        back = restore(path, tree)
        for x, y in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(back)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert load_meta(path)["meta"]["step"] == 7


def test_checkpoint_shape_mismatch_raises():
    tree = {"a": jnp.zeros((2, 2))}
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ck")
        save(path, tree)
        import pytest
        with pytest.raises(ValueError):
            restore(path, {"a": jnp.zeros((3, 3))})
