"""repro.runtime: telemetry, drift detection, and continuous re-planning.

Covers the tentpole control loop end-to-end plus the async scheduler path
(submit/collect) it builds on.
"""
import json
import time

import numpy as np
import pytest

from repro.common.types import ModelConfig
from repro.core.engine import DFLOPEngine
from repro.core.optimizer.space import (ClusterSpec, ModuleParallelism,
                                        ParallelismPlan)
from repro.core.profiling.data_profiler import ShapeDistribution
from repro.data.synthetic import MixedDataset
from repro.runtime import (DriftDetector, OnlineCalibrator, PageHinkley,
                           RuntimeMetrics, TraceRecorder, ks_distance)

TPM = 64

ENC = ModelConfig(name="e", family="vlm-enc", n_layers=4, d_model=256,
                  n_heads=4, n_kv_heads=4, d_ff=1024, vocab_size=0,
                  causal=False, use_rope=False, input_embed_dim=64,
                  has_lm_head=False)
LLM = ModelConfig(name="l", family="dense", n_layers=8, d_model=512,
                  n_heads=8, n_kv_heads=8, d_ff=2048, vocab_size=8192)


def _engine(mixture="single_image", n_chips=32):
    ds = MixedDataset(mixture, seed=0, tokens_per_media_item=TPM)
    eng = DFLOPEngine(llm_cfg=LLM, enc_cfg=ENC, e_seq_len=64,
                      cluster=ClusterSpec(n_chips=n_chips, chips_per_node=8,
                                          mem_bytes=80e9),
                      tokens_per_media_item=TPM)
    eng.profile(ds, n_samples=512)
    eng.dataset = ds
    return eng


# --------------------------------------------------------------------- #
# trace
# --------------------------------------------------------------------- #
def test_trace_spans_and_chrome_export(tmp_path):
    tr = TraceRecorder(process_name="test")
    tr.name_thread(0, "main")
    with tr.span("outer", cat="step", batch=3):
        with tr.span("inner", cat="scheduler"):
            pass
    tr.instant("marker", args={"k": 1})
    tr.counter("imbalance", 0.25)
    tr.complete("simulated", ts_us=10.0, dur_us=5.0, tid=2)
    path = tr.export(str(tmp_path / "trace.json"))
    doc = json.loads(open(path).read())          # valid JSON round-trip
    evs = doc["traceEvents"]
    by_name = {e["name"]: e for e in evs}
    assert by_name["outer"]["ph"] == "X"
    assert by_name["outer"]["dur"] >= by_name["inner"]["dur"] >= 0
    assert by_name["outer"]["args"] == {"batch": 3}
    assert by_name["marker"]["ph"] == "i"
    assert by_name["imbalance"]["ph"] == "C"
    assert by_name["imbalance"]["args"]["value"] == 0.25
    assert by_name["simulated"] == {"ph": "X", "name": "simulated",
                                    "cat": "runtime", "ts": 10.0, "pid": 1,
                                    "tid": 2, "dur": 5.0}
    assert any(e["ph"] == "M" and e["name"] == "process_name" for e in evs)


def test_trace_disabled_records_nothing():
    tr = TraceRecorder(enabled=False)
    with tr.span("x"):
        pass
    tr.counter("y", 1.0)
    assert len(tr) == 0


def test_trace_bounded_buffer_counts_drops():
    tr = TraceRecorder(max_events=2)
    for _ in range(5):
        tr.instant("e")
    assert len(tr) == 2
    assert tr.dropped == 3
    assert tr.to_chrome()["otherData"]["dropped_events"] == 3


# --------------------------------------------------------------------- #
# metrics
# --------------------------------------------------------------------- #
def test_record_step_distinguishes_unmeasured_from_idle_busy():
    """An explicitly-passed busy_s=0.0 is a fully idle step (bubble 1.0);
    busy_s=None means 'not measured' and defaults to the non-idle
    remainder — the two must not be conflated."""
    m = RuntimeMetrics()
    m.record_step(2.0, idle_s=2.0, busy_s=0.0)        # fully idle step
    assert m.bubble_fraction.last() == 1.0
    m.record_step(2.0, idle_s=0.5)                    # busy not measured
    assert abs(m.bubble_fraction.last() - 0.25) < 1e-9
    m.record_step(2.0, idle_s=0.0)                    # nothing measured
    assert m.bubble_fraction.last() == 0.0


def test_metrics_rolling_and_snapshot():
    m = RuntimeMetrics(window=4)
    for i in range(8):
        m.record_prediction("llm", 1.0, 1.0 + 0.1 * i)
    # window keeps only the last 4 errors: 0.4..0.7
    assert abs(m.pred_error["llm"].mean() - 0.55) < 1e-9
    assert m.pred_error["llm"].count == 8
    m.record_step(2.0, idle_s=0.5, busy_s=1.5, stage_busy=np.array([1.0, 2.0]))
    snap = m.snapshot()
    assert abs(snap["bubble_fraction_mean"] - 0.25) < 1e-9
    assert snap["stage_utilization"] == {0: 0.5, 1: 1.0}
    assert snap["n_steps"] == 1


# --------------------------------------------------------------------- #
# calibration
# --------------------------------------------------------------------- #
def test_calibrator_converges_to_observed_ratio():
    cal = OnlineCalibrator(alpha=0.5, min_obs=2)
    for _ in range(12):
        cal.observe("llm", 1000.0, 4, predicted=1.0, actual=1.5)
    assert abs(cal.correct("llm", 1000.0, 4, 2.0) - 3.0) < 1e-3
    # other (module, bucket, tp) cells untouched
    assert cal.correct("llm", 1000.0, 8, 2.0) == 2.0
    assert cal.correct("encoder", 1000.0, 4, 2.0) == 2.0
    assert cal.residual("llm") > 0.4


def test_calibrator_tracks_regime_change_faster_than_lifetime_mean():
    cal = OnlineCalibrator(alpha=0.25, min_obs=2)
    for _ in range(50):
        cal.observe("llm", 512.0, 1, 1.0, 2.0)    # old regime: 2x slower
    for _ in range(20):
        cal.observe("llm", 512.0, 1, 1.0, 1.0)    # new regime: on-model
    # EWMA forgets the old regime; a lifetime mean would still be ~1.7x
    assert cal.correct("llm", 512.0, 1, 1.0) < 1.1


def test_calibrator_deadband_and_immature_cells():
    cal = OnlineCalibrator(min_obs=3, deadband=0.05)
    cal.observe("llm", 100.0, 1, 1.0, 3.0)
    assert cal.correct("llm", 100.0, 1, 1.0) == 1.0     # n < min_obs
    for _ in range(5):
        cal.observe("llm", 200.0, 1, 1.0, 1.01)
    assert cal.correct("llm", 200.0, 1, 1.0) == 1.0     # inside deadband


# --------------------------------------------------------------------- #
# drift
# --------------------------------------------------------------------- #
def test_page_hinkley_fires_on_mean_shift_not_on_noise():
    rng = np.random.default_rng(0)
    ph = PageHinkley(delta=0.01, threshold=0.5, burn_in=30)
    fired = [ph.update(x) for x in 0.05 + 0.01 * rng.standard_normal(300)]
    assert not any(fired)
    fired = [ph.update(x) for x in 0.5 + 0.01 * rng.standard_normal(100)]
    assert any(fired)


def test_ks_distance_known_values():
    a = np.array([1.0, 2.0, 3.0, 4.0])
    assert ks_distance(a, a) == 0.0
    assert ks_distance(np.zeros(100), np.ones(100)) == 1.0
    rng = np.random.default_rng(0)
    same = ks_distance(rng.normal(0, 1, 500), rng.normal(0, 1, 500))
    shifted = ks_distance(rng.normal(0, 1, 500), rng.normal(2, 1, 500))
    assert same < 0.15 < 0.5 < shifted


def test_drift_detector_fires_on_shape_shift_and_rebases():
    det = DriftDetector(window=128, ks_threshold=0.2, check_every=16,
                        cooldown=64)
    pre = MixedDataset("single_image", seed=0, tokens_per_media_item=TPM)
    post = MixedDataset("video", seed=1, tokens_per_media_item=TPM)
    from repro.core.profiling.data_profiler import DataProfiler
    det.set_reference(DataProfiler(TPM).profile(pre.sample(512)))
    for _ in range(8):
        assert det.observe_items(pre.sample(32), TPM) is None
    ev = None
    for _ in range(16):
        ev = ev or det.observe_items(post.sample(32), TPM)
    assert ev is not None and ev.kind == "shape-ks"
    assert ev.statistic > 0.2
    # after rebasing on the new regime the detector is quiet again
    for _ in range(8):
        det.observe_items(post.sample(32), TPM)
    det.rebase()
    for _ in range(16):
        assert det.observe_items(post.sample(32), TPM) is None


def test_drift_window_distribution_reflects_recent_items():
    det = DriftDetector(window=64)
    ds = MixedDataset("video", seed=0, tokens_per_media_item=TPM)
    det.observe_items(ds.sample(64), TPM)
    dist = det.window_distribution()
    assert len(dist) == 64
    assert dist.mean()[0] >= 8.0          # video items have 8-32 media


# --------------------------------------------------------------------- #
# async scheduler path (submit/collect)
# --------------------------------------------------------------------- #
def test_submit_collect_matches_synchronous_schedule():
    eng = _engine()
    eng.plan(32)
    sched = eng.scheduler(adaptive=False, ilp_time_limit_s=0.05)
    items = eng.dataset.sample(32)
    sync = sched.schedule(items)
    sched.submit(items)
    assert sched.has_pending
    asyn = sched.collect()
    assert not sched.has_pending
    assert asyn.groups == sync.groups
    np.testing.assert_allclose(asyn.cmax, sync.cmax)
    np.testing.assert_allclose(asyn.e_dur, sync.e_dur)


def test_double_submit_raises_and_collect_without_submit_is_none():
    eng = _engine()
    eng.plan(32)
    sched = eng.scheduler(adaptive=False, ilp_time_limit_s=0.05)
    assert sched.collect() is None
    items = eng.dataset.sample(16)
    sched.submit(items)
    with pytest.raises(RuntimeError, match="pending"):
        sched.submit(items)
    assert sched.collect() is not None
    assert sched.collect() is None


def test_observe_does_not_compound_adaptive_and_calibration():
    """Both correctors fed the same raw (predicted, actual) pair would each
    learn ratio r and compound to r² at prediction time; the calibrator must
    observe the residual left after adaptive correction instead."""
    eng = _engine()
    eng.plan(32)
    sched = eng.scheduler(adaptive=True, ilp_time_limit_s=0.05)
    sched.calibration = OnlineCalibrator(min_obs=2)
    for _ in range(20):
        sched.observe("llm", 1000.0, 1.0, 1.5)   # persistent 1.5x deviation
    d = sched.adaptive.correct("llm", 1000.0, 1.0)
    d = sched.calibration.correct("llm", 1000.0, sched.plan.llm.tp, d)
    assert 1.4 < d < 1.65                        # ~r, not r² (2.25)


def test_plan_hot_swap_takes_effect_next_schedule():
    eng = _engine()
    eng.plan(32)
    sched = eng.scheduler(adaptive=False, ilp_time_limit_s=0.05)
    items = eng.dataset.sample(32)
    old = sched.plan
    out1 = sched.schedule(items)
    assert len(out1.groups) == old.n_mb * old.llm.dp
    new_plan = ParallelismPlan(llm=ModuleParallelism(1, 1, 2),
                               encoder=ModuleParallelism(1, 1, 2), n_mb=2)
    sched.set_plan(new_plan)
    out2 = sched.schedule(items)
    assert len(out2.groups) == 4          # n_mb * llm.dp of the new plan
    assert sched.n_buckets == 4


# --------------------------------------------------------------------- #
# controller end-to-end
# --------------------------------------------------------------------- #
def test_controller_detects_drift_replans_and_improves_cmax(tmp_path):
    eng = _engine("single_image")
    eng.plan(64)
    drift = DriftDetector(window=128, ks_threshold=0.2, check_every=32,
                          cooldown=64)
    ctl = eng.runtime(64, adaptive=False, drift=drift,
                      ilp_time_limit_s=0.05)
    stale_plan = ctl.plan
    pre = MixedDataset("single_image", seed=0, tokens_per_media_item=TPM)
    post = MixedDataset("video", seed=1, tokens_per_media_item=TPM)
    for _ in range(4):
        ctl.schedule(pre.sample(64))
    assert ctl.metrics.n_drift_events == 0
    for i in range(12):
        ctl.schedule(post.sample(64))
        if ctl.metrics.n_replans:
            break
        ctl.drain(timeout=60.0)
    assert ctl.metrics.n_drift_events >= 1
    assert ctl.metrics.n_replans >= 1
    assert len(ctl.replans) >= 1
    rec = ctl.replans[0]
    assert rec.swapped
    assert rec.trigger.kind == "shape-ks"
    # post-replan predicted makespan beats the stale plan's on the drifted
    # distribution (per-batch throughput recovery at the paper's scale is
    # asserted by test_fig16_throughput_recovery below)
    assert rec.new_makespan < rec.stale_makespan
    assert ctl.plan.as_tuple() != stale_plan.as_tuple()
    # the swap takes effect: scheduling now uses the new plan's buckets
    out = ctl.schedule(post.sample(64))
    assert out.plan.as_tuple() == ctl.plan.as_tuple()
    # exported trace is valid Chrome-trace JSON with the swap marker
    path = ctl.export_trace(str(tmp_path / "t.json"))
    doc = json.loads(open(path).read())
    names = {e["name"] for e in doc["traceEvents"]}
    assert "plan-swap" in names
    assert "replan-search" in names
    assert "schedule" in names
    ctl.close()


def test_controller_no_replan_when_disabled():
    eng = _engine("single_image")
    eng.plan(64)
    ctl = eng.runtime(64, adaptive=False, auto_replan=False,
                      ilp_time_limit_s=0.05,
                      drift=DriftDetector(window=128, check_every=32,
                                          cooldown=64))
    post = MixedDataset("video", seed=1, tokens_per_media_item=TPM)
    plan0 = ctl.plan
    for _ in range(8):
        ctl.schedule(post.sample(64))
    ctl.drain(timeout=10.0)
    assert ctl.metrics.n_drift_events >= 1       # drift is still observed
    assert ctl.metrics.n_replans == 0            # but no search is launched
    assert ctl.plan is plan0
    ctl.close()


def test_controller_observe_feeds_calibration_and_adaptive():
    eng = _engine("single_image")
    eng.plan(32)
    ctl = eng.runtime(32, adaptive=True, auto_replan=False,
                      ilp_time_limit_s=0.05)
    assert ctl.scheduler.calibration is ctl.calibration
    for _ in range(20):
        ctl.observe("llm", 1000.0, predicted=1.0, actual=1.4)
    # combined adaptive+calibration correction converges to the observed
    # ratio (calibration only holds the post-adaptive residual)
    d = ctl.scheduler.adaptive.correct("llm", 1000.0, 1.0)
    d = ctl.calibration.correct("llm", 1000.0, ctl.plan.llm.tp, d)
    assert 1.3 < d < 1.5
    assert ctl.metrics.pred_error["llm"].mean() > 0.3
    ctl.close()


@pytest.mark.slow
def test_fig16_throughput_recovery():
    """Acceptance demo at the paper's scale: after the injected mid-run
    shift the controller detects drift, re-plans in the background, and the
    hot-swapped plan's predicted pipeline makespan beats the stale plan's.
    Also checks the exported Chrome trace is valid JSON."""
    from benchmarks.fig16_replan import TRACE_PATH, run as fig16_run

    rows = fig16_run(gbs=64, n_pre=4, n_post=18)
    summary = rows[-1]
    assert summary["phase"] == "summary"
    assert summary["n_drift_events"] >= 1
    assert summary["n_replans"] >= 1
    assert summary["swap_iter"] >= 0           # swapped mid-run, not at drain
    assert summary["plan_after"] != summary["plan_before"]
    assert summary["recovery_ratio"] > 1.2
    doc = json.loads(open(TRACE_PATH).read())
    assert {e["name"] for e in doc["traceEvents"]} >= {"schedule",
                                                       "replan-search",
                                                       "plan-swap"}


@pytest.mark.slow
def test_fig16_physical_swap_recovery_net_of_reshard():
    """Physical-swap variant of the fig16 acceptance demo: the hot-swap
    pays a *measured* reshard cost and still recovers — the summary
    reports the ratio net of that cost."""
    from benchmarks.fig16_replan import TRACE_PATH_PHYSICAL, run as fig16_run

    rows = fig16_run(gbs=64, n_pre=4, n_post=18, physical=True)
    summary = rows[-1]
    assert summary["phase"] == "summary"
    assert summary["n_replans"] >= 1
    assert summary["n_physical_swaps"] >= 1
    assert summary["reshard_s_total"] > 0.0
    assert summary["reshard_bytes_moved"] > 0
    # net recovery still clears the bar, and by construction sits at or
    # below the gross ratio
    assert summary["recovery_ratio_net"] > 1.2
    assert summary["recovery_ratio_net"] <= summary["recovery_ratio"]
    doc = json.loads(open(TRACE_PATH_PHYSICAL).read())
    names = {e["name"] for e in doc["traceEvents"]}
    assert "reshard" in names and "plan-swap" in names


def test_controller_pipelined_submit_collect():
    eng = _engine("single_image")
    eng.plan(32)
    ctl = eng.runtime(32, adaptive=False, auto_replan=False,
                      ilp_time_limit_s=0.05)
    ds = eng.dataset
    ctl.submit(ds.sample(32))
    out = ctl.collect()
    assert out is not None
    assert ctl.metrics.n_schedules == 1
    assert ctl.collect() is None
    ctl.close()


def _trace_stream(ctl):
    """Comparable trace view: (ph, name, cat, args) without timestamps."""
    return [(ph, name, cat, args)
            for ph, name, cat, ts, dur, tid, args in ctl.trace._events]


def test_submit_collect_telemetry_parity_with_sync_path():
    """The async path must emit the same trace spans/counters, feed the
    same metrics, and advance the drift window at the same points as
    schedule() — batch for batch."""
    eng = _engine("single_image")
    eng.plan(32)
    ds = eng.dataset
    batches = [ds.sample(32) for _ in range(4)]
    ctl_sync = eng.runtime(32, adaptive=False, auto_replan=False,
                           calibrate=False, ilp_time_limit_s=0.05)
    ctl_async = eng.runtime(32, adaptive=False, auto_replan=False,
                            calibrate=False, ilp_time_limit_s=0.05)
    for items in batches:
        ctl_sync.schedule(items)
    for items in batches:
        ctl_async.submit(items)
        # drift must NOT run ahead of the metrics stream: the submitted
        # batch enters the window only once its ScheduleOutput is collected
        n_before = len(ctl_async.drift._win_seq)
        out = ctl_async.collect()
        assert out is not None
        assert len(ctl_async.drift._win_seq) == n_before + 32

    assert ctl_async.batch_idx == ctl_sync.batch_idx == 4
    assert _trace_stream(ctl_async) == _trace_stream(ctl_sync)
    for name in ("imbalance", "pred_cmax_s", "sched_elapsed_s"):
        s, a = getattr(ctl_sync.metrics, name), getattr(ctl_async.metrics, name)
        assert a.count == s.count == 4
        if name != "sched_elapsed_s":          # elapsed is wall time
            np.testing.assert_allclose(list(a._buf), list(s._buf))
    assert (list(ctl_async.drift._win_seq) == list(ctl_sync.drift._win_seq))
    ctl_sync.close()
    ctl_async.close()
