"""XLA flash attention (custom VJP) vs naive oracle: fwd + gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers.attention import attend_naive, flash_attention_xla

CASES = [
    # B, S, H, KH, D, causal, window, segs
    (2, 128, 4, 2, 32, True, 0, True),
    (1, 96, 4, 1, 32, True, 24, True),
    (2, 64, 4, 4, 32, False, 0, False),
    (1, 128, 8, 8, 64, True, 0, False),
]


def _inputs(B, S, H, KH, D, segs):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KH, D))
    v = jax.random.normal(ks[2], (B, S, KH, D))
    seg = None
    if segs:
        seg = jnp.concatenate([jnp.ones((B, S // 2), jnp.int32),
                               jnp.full((B, S - S // 2), 2, jnp.int32)], 1)
    return q, k, v, seg


@pytest.mark.parametrize("B,S,H,KH,D,causal,window,segs", CASES)
def test_flash_forward_matches_naive(B, S, H, KH, D, causal, window, segs):
    q, k, v, seg = _inputs(B, S, H, KH, D, segs)
    out_n = attend_naive(q, k, v, causal=causal, window=window,
                         seg_q=seg, seg_k=seg)
    out_f = flash_attention_xla(q, k, v, causal=causal, window=window,
                                seg_q=seg, seg_k=seg, block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(out_n), np.asarray(out_f),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("B,S,H,KH,D,causal,window,segs", CASES)
def test_flash_custom_vjp_matches_naive_grads(B, S, H, KH, D, causal,
                                              window, segs):
    q, k, v, seg = _inputs(B, S, H, KH, D, segs)

    def loss(fn):
        def inner(q, k, v):
            o = fn(q, k, v)
            return jnp.sum(jnp.square(o) + o)
        return inner

    fn_n = lambda q, k, v: attend_naive(q, k, v, causal=causal, window=window,
                                        seg_q=seg, seg_k=seg)
    fn_f = lambda q, k, v: flash_attention_xla(
        q, k, v, causal=causal, window=window, seg_q=seg, seg_k=seg,
        block_q=32, block_k=32)
    gn = jax.grad(loss(fn_n), argnums=(0, 1, 2))(q, k, v)
    gf = jax.grad(loss(fn_f), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gn, gf):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-4)


def test_flash_no_quadratic_residuals():
    """The custom VJP must not save S^2 probabilities: check the jaxpr of
    the VJP for any (S, S)-sized residual."""
    S = 256
    q, k, v, _ = _inputs(1, S, 2, 2, 16, False)

    def f(q, k, v):
        return jnp.sum(flash_attention_xla(q, k, v, block_q=32, block_k=32))

    # residuals = closure of the VJP function
    _, vjp = jax.vjp(f, q, k, v)
    leaves = jax.tree_util.tree_leaves(vjp)
    for leaf in leaves:
        if hasattr(leaf, "shape"):
            assert not (leaf.ndim >= 2 and leaf.shape[-1] == S
                        and leaf.shape[-2] == S), \
                f"quadratic residual {leaf.shape}"


# --------------------------------------------------------------------------- #
# decode_pos contract: scalar or (B,), anything else is an error
# --------------------------------------------------------------------------- #
from repro.models.layers.attention import cache_write, check_decode_pos  # noqa: E402


def test_check_decode_pos_scalar_broadcasts():
    pos = check_decode_pos(3, 4)
    np.testing.assert_array_equal(np.asarray(pos), np.full(4, 3))
    vec = check_decode_pos(jnp.arange(4), 4)
    np.testing.assert_array_equal(np.asarray(vec), np.arange(4))


@pytest.mark.parametrize("bad", [jnp.zeros((4, 1), jnp.int32),
                                 jnp.zeros((3,), jnp.int32),
                                 jnp.zeros((1, 4), jnp.int32)])
def test_check_decode_pos_rejects_wrong_shape(bad):
    with pytest.raises(ValueError, match="decode_pos"):
        check_decode_pos(bad, 4)


def test_cache_write_rejects_malformed_pos():
    """A (B, 1) position used to broadcast silently and write KV rows at
    the wrong ring slots; now it raises."""
    B, C, Kh, D = 2, 8, 2, 16
    cache = {"k": jnp.zeros((B, C, Kh, D)), "v": jnp.zeros((B, C, Kh, D)),
             "kpos": jnp.full((B, C), -1, jnp.int32)}
    k_new = jnp.ones((B, 1, Kh, D))
    with pytest.raises(ValueError, match="decode_pos"):
        cache_write(cache, k_new, k_new, jnp.zeros((B, 1), jnp.int32))
    # the two legal forms still work
    out = cache_write(cache, k_new, k_new, 5)
    np.testing.assert_array_equal(np.asarray(out["kpos"][:, 5]), [5, 5])
    out = cache_write(cache, k_new, k_new, jnp.asarray([0, 3]))
    np.testing.assert_array_equal(np.asarray(out["kpos"][0, 0]), 0)
    np.testing.assert_array_equal(np.asarray(out["kpos"][1, 3]), 3)
