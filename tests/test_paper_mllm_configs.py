"""The paper's own MLLM configs (Table 3 + Fig. 9) instantiate and train."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import mllm as mllm_lib
from repro.models.model import FwdCtx

PAPER_MLLMS = ["llava-ov-qwen7b", "llava-ov-llama8b", "qwen2-audio-7b"]


def test_paper_mllms_registered():
    archs = list_archs()
    for a in PAPER_MLLMS:
        assert a in archs


@pytest.mark.parametrize("arch", PAPER_MLLMS)
def test_reduced_paper_mllm_forward(arch):
    spec = get_config(arch)
    desc = spec.reduced_desc()
    params = mllm_lib.init(jax.random.PRNGKey(0), desc)
    B, Tm, Tt = 2, 12, 16
    rng = np.random.default_rng(0)
    batch = {
        "media_embeds": jnp.asarray(
            rng.standard_normal((B, Tm, desc.stub.embed_dim)), jnp.float32),
        "media_mask": jnp.ones((B, Tm), jnp.int32),
        "text_tokens": jnp.asarray(
            rng.integers(1, desc.llm.vocab_size, (B, Tt)), jnp.int32),
        "text_mask": jnp.ones((B, Tt), jnp.int32),
    }
    logits, aux = mllm_lib.forward_train(
        params, desc, batch, ctx=FwdCtx(mode="train", attn_impl="naive"))
    assert logits.shape == (B, Tt, desc.llm.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", PAPER_MLLMS)
def test_paper_mllm_param_scale(arch):
    """Full configs land near their nameplate sizes."""
    spec = get_config(arch)
    n = spec.desc.param_count() / 1e9
    expected = {"llava-ov-qwen7b": 8.0, "llava-ov-llama8b": 8.5,
                "qwen2-audio-7b": 8.3}[arch]
    assert abs(n - expected) / expected < 0.15, n
