"""Contract tests for `repro.serve` — the data-aware serving engine.

Three layers, mirroring the subsystem's own split:

  * admission (pure policy): EDF slack ordering, the no-starvation
    backstop under a sustained adversarial stream, and FIFO degeneration;
  * emulated engine (discrete-event): continuous-batching invariants
    (joins/leaves only at step boundaries, non-overlapping worker steps),
    prefill → KV-handoff → decode timing, drift → re-price wiring,
    metrics plumbing, and run-to-run determinism;
  * real-model substrate (tiny jax model): a request prefilled on a
    "prefill worker" cache, handed off via `merge_cache_row` into a
    shared continuous decode batch, must generate the same tokens as the
    request decoding alone — including after `clear_cache_row` recycles
    its row for a new occupant.

The fig19 acceptance numbers live in the slow tier.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.types import ModelConfig
from repro.core.engine import DFLOPEngine
from repro.core.optimizer.space import ClusterSpec
from repro.data.items import DataItem
from repro.models import model as model_lib
from repro.serve import (FIFOAdmission, PrefillPricer, Request, RequestQueue,
                         ServeConfig, SLOAdmission, clear_cache_row,
                         make_decode_step, merge_cache_row,
                         prefill_into_cache)

TPM = 64

ENC = ModelConfig(name="e", family="vlm-enc", n_layers=4, d_model=256,
                  n_heads=4, n_kv_heads=4, d_ff=1024, vocab_size=0,
                  causal=False, use_rope=False, input_embed_dim=64,
                  has_lm_head=False)
LLM = ModelConfig(name="l", family="dense", n_layers=8, d_model=512,
                  n_heads=8, n_kv_heads=8, d_ff=2048, vocab_size=8192)


@pytest.fixture(scope="module")
def engine():
    from repro.data.synthetic import MixedDataset
    ds = MixedDataset("mixed", seed=0, tokens_per_media_item=TPM)
    eng = DFLOPEngine(llm_cfg=LLM, enc_cfg=ENC, e_seq_len=64,
                      cluster=ClusterSpec(n_chips=16, chips_per_node=8,
                                          mem_bytes=80e9),
                      tokens_per_media_item=TPM)
    eng.profile(ds, n_samples=256)
    return eng


def _req(i, *, arrival=0.0, slo=60.0, n_media=1, text=128,
         modality="single_image", max_new=8, factor=1.0):
    return Request(item=DataItem(n_media, text, modality, i),
                   arrival_s=arrival, slo_s=slo, max_new_tokens=max_new,
                   true_factor=factor)


def _requests(n, seed=0, **kw):
    rng = np.random.default_rng(seed)
    return [_req(i, arrival=float(i) * 0.05,
                 n_media=int(rng.integers(1, 6)),
                 text=int(rng.integers(32, 400)), **kw)
            for i in range(n)]


# --------------------------------------------------------------------- #
# admission policy (pure, no engine)
# --------------------------------------------------------------------- #
def test_request_queue_contract():
    q = RequestQueue()
    reqs = [_req(i, arrival=float(i)) for i in range(4)]
    for r in reqs:
        q.push(r)
    assert q.depth == 4 and q.n_arrived == 4
    assert q.oldest_wait_s(10.0) == 10.0
    q.pop([reqs[1], reqs[3]])               # set semantics, order kept
    assert [r.item.item_id for r in q.pending] == [0, 2]


def test_slo_admission_forces_nearly_due_request(engine):
    """EDF ordering: a feasible request whose deadline is about to become
    infeasible must be admitted ahead of older, slack-rich requests."""
    pricer = PrefillPricer(engine.perf, TPM)
    adm = SLOAdmission(pricer)
    adm.note_batch(1.0)                      # quantum = 1s
    pending = [_req(i, arrival=0.0, slo=500.0) for i in range(8)]
    tight = _req(99, arrival=0.0, slo=0.0)
    # feasible but nearly due: slack just above its remaining work
    tight.slo_s = (pricer.predict(tight, 1024) + pricer.decode_estimate(tight)
                   + 40.0 + 0.5)
    pending.append(tight)
    batch = adm.select(pending, now_s=40.0, max_batch=4)
    assert len(batch) == 4
    assert any(r.item.item_id == 99 for r in batch)
    assert adm.last_n_forced >= 1


def test_slo_admission_no_starvation_under_adversarial_stream(engine):
    """A hopeless request (deadline already infeasible) in a queue that is
    continuously refilled with fresh, cheap, feasible requests must still
    be admitted within the starvation horizon — the aging backstop, not
    the EDF reservation, guarantees it."""
    pricer = PrefillPricer(engine.perf, TPM)
    adm = SLOAdmission(pricer, starvation_horizon=6)
    q = RequestQueue()
    victim = _req(10_000, arrival=0.0, slo=0.0, n_media=6, text=900,
                  modality="video")          # hopeless from the start
    q.push(victim)
    now, rounds_waited, next_id = 0.0, None, 0
    for rnd in range(40):
        for _ in range(8):                   # adversary: endless fresh work
            q.push(_req(next_id, arrival=now, slo=500.0))
            next_id += 1
        batch = adm.select(q.pending, now, max_batch=8)
        assert batch
        q.pop(batch)
        adm.note_batch(1.0)
        now += 1.0
        if any(r is victim for r in batch):
            rounds_waited = rnd
            break
    assert rounds_waited is not None, "victim starved"
    assert rounds_waited <= adm.starvation_horizon + 2


def test_slo_admission_degenerates_to_fifo_on_homogeneous_queue(engine):
    """Identical shapes and loose deadlines: candidate 0 is the FIFO draw
    and all scores tie, so data-aware admission must pick exactly the
    FIFO prefix (graceful degeneration)."""
    pricer = PrefillPricer(engine.perf, TPM)
    adm = SLOAdmission(pricer)
    pending = [_req(i, arrival=float(i), slo=1e6, n_media=2, text=100)
               for i in range(12)]
    batch = adm.select(pending, now_s=12.0, max_batch=4)
    assert [r.item.item_id for r in batch] == [0, 1, 2, 3]


def test_fifo_admission_is_arrival_prefix(engine):
    adm = FIFOAdmission()
    pending = _requests(10)
    batch = adm.select(pending, 0.0, 4)
    assert batch == pending[:4]


def test_pricer_memo_and_flush(engine):
    from repro.runtime import OnlineCalibrator
    cal = OnlineCalibrator()
    pricer = PrefillPricer(engine.perf, TPM, calibrator=cal)
    r = _req(0, n_media=3, text=200)
    p0 = pricer.price(r)
    base, _, s = pricer.base(r)
    for _ in range(12):                      # teach the calibrator 1.5×
        cal.observe("prefill", s, 1, base, base * 1.5)
    assert pricer.price(r) == p0             # memoized: stale until flush
    pricer.flush()
    assert pricer.price(r) > p0 * 1.2        # re-priced under calibration
    assert pricer.n_flushes == 1
    # padding overhead is monotone in the padded length
    assert pricer.pad_extra(r, 4096) >= pricer.pad_extra(r, 1024) >= 0.0
    # decode cost is strictly positive at any context (monotonicity is a
    # property of the hardware model's efficiency curve, not guaranteed)
    assert pricer.decode_tok_s(256) > 0.0 and pricer.decode_tok_s(4096) > 0.0


# --------------------------------------------------------------------- #
# emulated engine: lifecycle, invariants, wiring
# --------------------------------------------------------------------- #
_CFG = ServeConfig(n_prefill_workers=2, n_decode_workers=2, decode_slots=4,
                   max_prefill_batch=4)


def test_engine_completes_all_requests_with_sane_timestamps(engine):
    serve = engine.serving(serve_cfg=_CFG)
    reqs = _requests(32, seed=1)
    rep = serve.run(reqs)
    assert rep.n_completed == 32
    for r in reqs:
        assert r.status == "done"
        assert r.arrival_s <= r.admit_s < r.prefill_done_s
        assert r.prefill_done_s < r.handoff_done_s      # handoff takes time
        assert r.handoff_done_s < r.first_token_s <= r.finish_s
        assert r.tokens_done == r.max_new_tokens
        assert 0 <= r.decode_worker < _CFG.n_decode_workers
        # KV handoff priced as bytes/bandwidth + latency
        np.testing.assert_allclose(r.handoff_done_s - r.prefill_done_s,
                                   serve._handoff_s(r), rtol=1e-9)
    assert rep.makespan_s > 0 and rep.throughput_rps > 0
    assert rep.p99_latency_s >= rep.p50_latency_s > 0


def _decode_steps_by_worker(serve):
    steps = {}
    for ph, name, cat, ts, dur, tid, args in serve.trace._events:
        if name == "decode_step":
            steps.setdefault(tid - 200, []).append((ts / 1e6, dur / 1e6,
                                                    args))
    return steps


def test_continuous_batching_joins_and_leaves_at_step_boundaries(engine):
    """Per decode worker: steps never overlap, every request's first token
    lands exactly at the end of one of its worker's steps, and a request
    never occupies a step that starts before its handoff completed or
    after it finished."""
    serve = engine.serving(serve_cfg=_CFG)
    reqs = _requests(24, seed=2, max_new=6)
    serve.run(reqs)
    steps = _decode_steps_by_worker(serve)
    assert steps, "no decode steps traced"
    for w, evs in steps.items():
        evs.sort()
        for (t0, d0, _), (t1, _, _) in zip(evs, evs[1:]):
            assert t1 >= t0 + d0 - 1e-9      # step boundaries: no overlap
    for r in reqs:
        evs = steps[r.decode_worker]
        ends = [t + d for t, d, _ in evs]
        # first token and finish both coincide with a step boundary
        assert min(abs(e - r.first_token_s) for e in ends) < 1e-9
        assert min(abs(e - r.finish_s) for e in ends) < 1e-9
        # joined no earlier than its handoff: no step containing the
        # request starts before handoff_done_s
        starts = [t for t, d, _ in evs
                  if t + d > r.handoff_done_s + 1e-9 and t < r.finish_s]
        assert all(t >= r.handoff_done_s - 1e-9 for t in starts)


def test_decode_occupancy_never_exceeds_slots(engine):
    serve = engine.serving(serve_cfg=_CFG)
    serve.run(_requests(40, seed=3, max_new=16))
    for evs in _decode_steps_by_worker(serve).values():
        assert all(a["rows"] <= _CFG.decode_slots for _, _, a in evs)


def test_identical_streams_identical_ground_truth_across_policies(engine):
    """The fig19 A/B contract: both policies see bit-identical arrivals
    and oracle factors; only scheduling differs."""
    def stream():
        rng = np.random.default_rng(7)
        return [_req(i, arrival=float(i) * 0.02,
                     n_media=int(rng.integers(1, 8)),
                     text=int(rng.integers(32, 600)),
                     factor=float(rng.lognormal(0, 0.2)), slo=20.0)
                for i in range(48)]

    reps = {}
    for policy in ("fifo", "slo"):
        serve = engine.serving(admission=policy, serve_cfg=_CFG)
        reps[policy] = serve.run(stream())
    assert reps["fifo"].policy == "fifo" and reps["slo"].policy == "slo"
    assert reps["fifo"].n_completed == reps["slo"].n_completed == 48


def test_engine_run_is_deterministic(engine):
    rows = []
    for _ in range(2):
        serve = engine.serving(serve_cfg=_CFG)
        rows.append(serve.run(_requests(32, seed=5)).row())
    assert rows[0] == rows[1]


def test_drift_flushes_admission_prices(engine):
    """A sustained shift in actual/predicted must fire Page–Hinkley and
    re-estimate (flush) the pricer memo — the serving analogue of the
    training loop's drift → re-plan."""
    serve = engine.serving(serve_cfg=_CFG)
    reqs = _requests(96, seed=6)
    for r in reqs[32:]:
        r.true_factor = 1.8                  # post-drift regime
    serve.run(reqs)
    assert serve.n_drift_events >= 1
    assert serve.pricer.n_flushes >= 1
    names = [e[1] for e in serve.trace._events]
    assert "serve_drift_reprice" in names


def test_metrics_snapshot_has_serve_section(engine):
    serve = engine.serving(serve_cfg=_CFG)
    serve.run(_requests(24, seed=8))
    m = serve.metrics
    snap = m.snapshot()["serve"]
    assert m.n_requests == m.n_completed == 24
    assert m.n_handoffs == 24
    assert m.n_prefill_batches == snap["n_prefill_batches"] > 0
    assert snap["n_decode_steps"] > 0
    assert snap["latency_p99_s"] >= snap["latency_p50_s"] > 0
    assert 0 < snap["batch_occupancy_mean"] <= 1.0
    assert snap["n_slo_met"] == m.n_slo_met


# --------------------------------------------------------------------- #
# real-model substrate: KV handoff + continuous batch bit-exactness
# --------------------------------------------------------------------- #
TINY = ModelConfig(name="tiny-dense", family="dense", n_layers=2,
                   d_model=64, n_heads=4, n_kv_heads=2, d_ff=256,
                   vocab_size=128, dtype="float32")
MAX_LEN = 32


@pytest.fixture(scope="module")
def tiny_params():
    return model_lib.init(jax.random.PRNGKey(0), TINY)


def _solo_generate(params, prompt_1d, max_new):
    """Reference: the request never leaves its own B=1 cache."""
    prompt = prompt_1d[None, :]
    logits, caches = prefill_into_cache(TINY, params, prompt, MAX_LEN)
    decode = jax.jit(make_decode_step(TINY))
    toks, pos = [], prompt.shape[1]
    tok = jnp.argmax(logits, axis=-1).reshape(1).astype(jnp.int32)
    for _ in range(max_new):
        toks.append(int(tok[0]))
        logits, caches = decode(params, caches, tok, pos)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        pos += 1
    return toks


def test_handoff_decode_matches_single_request(tiny_params):
    """Two requests prefilled on separate "prefill workers", handed off
    into one continuous decode batch (different lengths, per-row pos),
    must generate exactly the tokens each generates alone."""
    rng = jax.random.PRNGKey(3)
    pa = jax.random.randint(rng, (5,), 2, TINY.vocab_size)
    pb = jax.random.randint(jax.random.fold_in(rng, 1), (9,), 2,
                            TINY.vocab_size)
    max_new = 6
    solo = {0: _solo_generate(tiny_params, pa, max_new),
            1: _solo_generate(tiny_params, pb, max_new)}

    la, ca = prefill_into_cache(TINY, tiny_params, pa[None, :], MAX_LEN)
    lb, cb = prefill_into_cache(TINY, tiny_params, pb[None, :], MAX_LEN)
    shared = model_lib.init_cache(TINY, 2, MAX_LEN, jnp.float32)
    shared = merge_cache_row(shared, ca, row=0)
    shared = merge_cache_row(shared, cb, row=1)
    decode = jax.jit(make_decode_step(TINY))
    tok = jnp.concatenate([jnp.argmax(la, -1).reshape(1),
                           jnp.argmax(lb, -1).reshape(1)]).astype(jnp.int32)
    pos = jnp.array([pa.shape[0], pb.shape[0]], jnp.int32)
    got = {0: [], 1: []}
    for _ in range(max_new):
        got[0].append(int(tok[0]))
        got[1].append(int(tok[1]))
        logits, shared = decode(tiny_params, shared, tok, pos)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        pos = pos + 1
    assert got[0] == solo[0]
    assert got[1] == solo[1]


def test_clear_cache_row_isolates_next_occupant(tiny_params):
    """Continuous batching row recycling: after a request leaves, its row
    is cleared and a *new* request handed into it mid-flight; the new
    occupant's tokens must match its solo run (no stale KV leaks), and
    the surviving row must be unaffected by the join."""
    rng = jax.random.PRNGKey(4)
    pa = jax.random.randint(rng, (4,), 2, TINY.vocab_size)
    pb = jax.random.randint(jax.random.fold_in(rng, 1), (7,), 2,
                            TINY.vocab_size)
    pc = jax.random.randint(jax.random.fold_in(rng, 2), (6,), 2,
                            TINY.vocab_size)
    solo_b = _solo_generate(tiny_params, pb, 8)
    solo_c = _solo_generate(tiny_params, pc, 4)

    la, ca = prefill_into_cache(TINY, tiny_params, pa[None, :], MAX_LEN)
    lb, cb = prefill_into_cache(TINY, tiny_params, pb[None, :], MAX_LEN)
    shared = model_lib.init_cache(TINY, 2, MAX_LEN, jnp.float32)
    shared = merge_cache_row(shared, ca, row=0)
    shared = merge_cache_row(shared, cb, row=1)
    decode = jax.jit(make_decode_step(TINY))
    tok = jnp.concatenate([jnp.argmax(la, -1).reshape(1),
                           jnp.argmax(lb, -1).reshape(1)]).astype(jnp.int32)
    pos = jnp.array([pa.shape[0], pb.shape[0]], jnp.int32)
    got_b = []
    for _ in range(4):                       # A and B decode together
        got_b.append(int(tok[1]))
        logits, shared = decode(tiny_params, shared, tok, pos)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        pos = pos + 1
    # step boundary: A leaves, row 0 cleared, C joins via handoff
    shared = clear_cache_row(shared, 0)
    lc, cc = prefill_into_cache(TINY, tiny_params, pc[None, :], MAX_LEN)
    shared = merge_cache_row(shared, cc, row=0)
    tok = tok.at[0].set(jnp.argmax(lc, -1).reshape(()).astype(jnp.int32))
    pos = pos.at[0].set(pc.shape[0])
    got_c = []
    for _ in range(4):                       # B continues, C starts fresh
        got_b.append(int(tok[1]))
        got_c.append(int(tok[0]))
        logits, shared = decode(tiny_params, shared, tok, pos)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        pos = pos + 1
    assert got_b == solo_b                   # B never saw the join/leave
    assert got_c == solo_c                   # C never saw A's leftovers


# --------------------------------------------------------------------- #
# fig19: smoke (tier-1) + acceptance (slow)
# --------------------------------------------------------------------- #
def test_fig19_smoke():
    from benchmarks.fig19_serving import run_smoke
    rows = run_smoke()
    summaries = [r for r in rows if r.get("summary")]
    assert len(summaries) == 1
    reports = [r for r in rows if not r.get("summary")]
    assert {r["policy"] for r in reports} == {"fifo", "slo"}
    assert all(r["n_completed"] == r["n_requests"] == 48 for r in reports)
    assert all(r["goodput_rps"] > 0 for r in reports)


@pytest.mark.slow
def test_fig19_serving_acceptance():
    """Headline: data-aware admission reaches ≥1.2× goodput at
    lower-or-equal p99 than FIFO at ≥2 of the swept QPS points."""
    from benchmarks.fig19_serving import run
    rows = run()
    summaries = [r for r in rows if r.get("summary")]
    assert len(summaries) >= 3
    wins = [r for r in summaries
            if r["goodput_ratio"] >= 1.2 and r["p99_slo_s"] <= r["p99_fifo_s"]]
    assert len(wins) >= 2, summaries
