"""Physical plan hot-swap: `repro.launch.reshard` + controller gating.

Device-level equivalence (bit-identical pipeline outputs across (tp, pp)
transitions) lives in test_multidevice.py (forced-host-device subprocess);
here: the layout transforms, the cost model, and the controller's
amortized-cost gate — all on the default single device.
"""
import concurrent.futures
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.optimizer.search import SearchResult
from repro.core.optimizer.space import ModuleParallelism, ParallelismPlan
from repro.core.pipeline.executor import stack_stage_params, unstack_stage_params
from repro.launch.reshard import (
    ParamSwapper,
    ReshardReport,
    clamped_plan_mesh,
    estimate_reshard_s,
    param_bytes,
    plan_mesh,
    reshard_params,
)
from repro.runtime.drift import DriftEvent

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _plan(tp=1, pp=1, dp=1, n_mb=2):
    return ParallelismPlan(llm=ModuleParallelism(tp, pp, dp), n_mb=n_mb)


# --------------------------------------------------------------------- #
# layout transforms
# --------------------------------------------------------------------- #
def test_stack_stage_params_generalized_restack():
    W = jnp.arange(8 * 3 * 3, dtype=jnp.float32).reshape(8, 3, 3)
    s4 = stack_stage_params(W, 4)
    assert s4.shape == (4, 2, 3, 3)
    # re-stack 4 -> 2 equals stacking flat -> 2 directly
    np.testing.assert_array_equal(
        np.asarray(stack_stage_params(s4, 2, from_p=4)),
        np.asarray(stack_stage_params(W, 2)))
    # from_p=1 means "stacked with a single stage", not "flat"
    s1 = stack_stage_params(W, 1)
    assert s1.shape == (1, 8, 3, 3)
    np.testing.assert_array_equal(
        np.asarray(stack_stage_params(s1, 4, from_p=1)), np.asarray(s4))
    # unstack inverts any stacking
    np.testing.assert_array_equal(np.asarray(unstack_stage_params(s4)),
                                  np.asarray(W))
    with pytest.raises(AssertionError, match="not divisible"):
        stack_stage_params(W, 3)


def test_plan_mesh_shape_and_device_shortfall():
    mesh = plan_mesh(_plan(tp=1, pp=1, dp=1))
    assert dict(mesh.shape) == {"data": 1, "stage": 1, "model": 1}
    with pytest.raises(ValueError, match="devices"):
        plan_mesh(_plan(tp=8, pp=4, dp=2))
    # the clamped factory fits the same plan onto whatever exists
    clamped = clamped_plan_mesh(_plan(tp=8, pp=4, dp=2))
    assert np.prod(list(clamped.shape.values())) <= jax.device_count()


def test_reshard_params_report_and_bytes():
    params = {"w": jnp.ones((4, 8), jnp.float32),
              "b": jnp.zeros((8,), jnp.float32)}
    total = param_bytes(params)
    new, rep = reshard_params(params, _plan(), _plan())
    assert isinstance(rep, ReshardReport)
    assert rep.bytes_total == total == 160
    assert rep.bytes_moved == total        # fresh placement moves all bytes
    assert rep.elapsed_s >= 0.0 and rep.n_leaves == 2 and not rep.restacked
    assert rep.old_plan == rep.new_plan == _plan().as_tuple()
    # placing again onto the SAME layout moves nothing
    _, rep2 = reshard_params(new, _plan(), _plan())
    assert rep2.bytes_moved == 0
    np.testing.assert_array_equal(np.asarray(new["w"]),
                                  np.asarray(params["w"]))


def test_reshard_params_autodetects_pp1_stacking():
    """A (1, L, ...) pytree under a pp=1 plan is still stage-stacked: the
    default stage_stacked=None must re-partition it for a larger PP, not
    replicate it with a stale leading dim."""
    W = jnp.arange(24, dtype=jnp.float32).reshape(8, 3)
    stacked1 = stack_stage_params(W, 1)               # (1, 8, 3)
    new, rep = reshard_params(stacked1, _plan(pp=1), _plan(pp=4),
                              mesh_factory=clamped_plan_mesh)
    assert rep.restacked and new.shape == (4, 2, 3)
    np.testing.assert_array_equal(np.asarray(unstack_stage_params(new)),
                                  np.asarray(W))


def test_reshard_params_restack_raises_on_non_divisible():
    stacked = {"w": jnp.ones((4, 2, 3), jnp.float32)}   # 8 layers, pp=4
    with pytest.raises(ValueError, match="not divisible"):
        reshard_params(stacked, _plan(pp=4), _plan(pp=3),
                       stage_stacked=True)


def test_estimate_reshard_s_linear_in_bytes():
    assert estimate_reshard_s(0, latency_s=0.25) == 0.25
    assert estimate_reshard_s(10**11, bandwidth_bytes_per_s=1e11,
                              latency_s=0.0) == 1.0


# --------------------------------------------------------------------- #
# ParamSwapper
# --------------------------------------------------------------------- #
def _swapper(params, **kw):
    live = {"p": params}
    sw = ParamSwapper(lambda: live["p"], lambda v: live.update(p=v), **kw)
    return sw, live


def test_swapper_estimate_prefers_measured_bandwidth():
    # configured bandwidth of 1 B/s prices the 16 KiB pytree at hours;
    # one measured swap replaces it with the real (far higher) bandwidth
    sw, _ = _swapper({"w": jnp.ones((64, 64))}, bandwidth_bytes_per_s=1.0,
                     latency_s=0.0)
    assert sw.estimate_cost_s(_plan(), _plan()) == pytest.approx(64 * 64 * 4)
    rep = sw.swap(_plan(), _plan())
    assert sw.reports == [rep] and rep.bytes_moved > 0
    measured = sw.estimate_cost_s(_plan(), _plan())
    assert 0.0 < measured < 10.0
    # the estimate is sized to the pytree being priced, not a raw mean of
    # past elapsed times: pricing from a history of one cheap swap must
    # scale with measured bandwidth (bytes/elapsed), hence equal here
    assert measured == pytest.approx(
        rep.bytes_total / (rep.bytes_moved / rep.elapsed_s))


def test_swapper_compatibility_gates():
    sw, _ = _swapper({"w": jnp.ones((4, 2, 3))}, stage_stacked=True,
                     mesh_factory=clamped_plan_mesh)
    assert sw.compatible(_plan(pp=4), _plan(pp=2))        # 8 % 2 == 0
    assert not sw.compatible(_plan(pp=4), _plan(pp=3))    # 8 % 3 != 0
    sw_strictmesh, _ = _swapper({"w": jnp.ones((4, 2, 3))})
    assert not sw_strictmesh.compatible(_plan(), _plan(tp=8, dp=4))
    # non-strict (emulation) mode falls back to re-placement instead
    sw2, live2 = _swapper({"w": jnp.ones((4, 2, 3))}, stage_stacked=True,
                          strict=False, mesh_factory=clamped_plan_mesh)
    assert sw2.compatible(_plan(pp=4), _plan(pp=3))
    rep = sw2.swap(_plan(pp=4), _plan(pp=3))
    assert not rep.restacked and rep.bytes_moved > 0
    assert live2["p"]["w"].shape == (4, 2, 3)             # layout kept


def test_swapper_updates_live_params_via_callbacks():
    W = jnp.arange(24, dtype=jnp.float32).reshape(8, 3)
    sw, live = _swapper(stack_stage_params(W, 4), stage_stacked=True,
                        mesh_factory=clamped_plan_mesh)
    rep = sw.swap(_plan(pp=4), _plan(pp=2))
    assert rep.restacked
    assert live["p"].shape == (2, 4, 3)
    np.testing.assert_array_equal(np.asarray(unstack_stage_params(live["p"])),
                                  np.asarray(W))


# --------------------------------------------------------------------- #
# controller integration: amortized gate + physical swap + found-guard
# --------------------------------------------------------------------- #
def _controller(swapper=None, horizon=50):
    from repro.core.engine import DFLOPEngine
    from repro.common.types import ModelConfig
    from repro.core.optimizer.space import ClusterSpec
    from repro.data.synthetic import MixedDataset

    llm = ModelConfig(name="l", family="dense", n_layers=8, d_model=256,
                      n_heads=4, n_kv_heads=4, d_ff=1024, vocab_size=512)
    eng = DFLOPEngine(llm_cfg=llm, cluster=ClusterSpec(n_chips=4,
                                                       chips_per_node=4))
    eng.profile(MixedDataset("single_image", seed=0,
                             tokens_per_media_item=64))
    eng.plan(8)
    return eng.runtime(8, adaptive=False, auto_replan=False, calibrate=False,
                       param_swapper=swapper, swap_horizon_batches=horizon)


def _inject_result(ctl, res, stale):
    """Hand maybe_swap() a finished background search."""
    fut = concurrent.futures.Future()
    event = DriftEvent("shape-ks", 0.5, 0.2, 8)
    fut.set_result((event, ctl.engine.dist, res, stale))
    ctl._replan_future = fut


def test_maybe_swap_guards_not_found_search():
    ctl = _controller()
    _inject_result(ctl, SearchResult(None, float("nan"), 5, 0, 0.01), 1.25)
    assert ctl.maybe_swap() is False
    rec = ctl.replans[-1]
    assert rec.new_makespan == float("inf")
    assert not rec.swapped and rec.plan_tuple is None and rec.gated is None
    ctl.close()


def test_maybe_swap_physical_swap_records_reshard():
    sw, live = _swapper({"w": jnp.ones((256, 256))}, latency_s=0.0)
    ctl = _controller(sw)
    better = _plan(n_mb=4)
    _inject_result(ctl, SearchResult(better, 0.5, 5, 5, 0.01), 1.0)
    assert ctl.maybe_swap() is True
    assert ctl.plan is better
    assert ctl.metrics.n_physical_swaps == 1
    assert ctl.metrics.n_replans == 1
    assert ctl.metrics.reshard_s.last() == sw.reports[-1].elapsed_s
    rec = ctl.replans[-1]
    assert rec.swapped and rec.reshard is sw.reports[-1]
    names = {e[1] for e in ctl.trace._events}
    assert "reshard" in names and "plan-swap" in names
    assert "reshard_s" in names                    # counter track
    ctl.close()


def test_maybe_swap_gates_on_amortized_reshard_cost():
    # cost model says the reshard takes ~1e9 s: no finite horizon of
    # per-batch savings can amortize it -> the swap must NOT happen.
    sw, _ = _swapper({"w": jnp.ones((8, 8))}, latency_s=1e9)
    ctl = _controller(sw, horizon=50)
    stale_plan = ctl.plan
    _inject_result(ctl, SearchResult(_plan(n_mb=4), 0.5, 5, 5, 0.01), 1.0)
    assert ctl.maybe_swap() is False
    assert ctl.plan is stale_plan
    assert ctl.metrics.n_replans == 0 and ctl.metrics.n_physical_swaps == 0
    rec = ctl.replans[-1]
    assert rec.gated == "amortization" and not rec.swapped
    assert rec.plan_tuple is not None              # the plan WAS found
    assert "swap-gated" in {e[1] for e in ctl.trace._events}
    ctl.close()


def test_maybe_swap_gates_on_incompatible_transition():
    sw, _ = _swapper({"w": jnp.ones((4, 2, 3))}, stage_stacked=True,
                     latency_s=0.0)
    ctl = _controller(sw)
    _inject_result(ctl, SearchResult(_plan(pp=3), 0.5, 5, 5, 0.01), 1.0)
    assert ctl.maybe_swap() is False
    assert ctl.replans[-1].gated == "incompatible"
    ctl.close()


class _FailingSwapper:
    """Reshard hook that always fails; optionally reports the live
    buffers as consumed by a donated transfer."""

    def __init__(self, damage: bool):
        self._damage = damage
        self.damaged = False

    def swap(self, old_plan, new_plan):
        self.damaged = self._damage
        raise RuntimeError("transfer blew up")


def test_maybe_swap_recovers_from_non_destructive_reshard_failure():
    ctl = _controller(_FailingSwapper(damage=False))
    stale_plan = ctl.plan
    _inject_result(ctl, SearchResult(_plan(n_mb=4), 0.5, 5, 5, 0.01), 1.0)
    assert ctl.maybe_swap() is False           # stale plan kept, loop alive
    assert ctl.plan is stale_plan
    assert ctl.replans[-1].gated == "reshard-error"
    names = {e[1] for e in ctl.trace._events}
    assert "reshard-error" in names
    # no "reshard" slice for a re-layout that never happened: trace
    # consumers count those as physical swaps
    assert "reshard" not in names
    ctl.close()


def test_maybe_swap_fails_fast_when_donation_consumed_live_buffers():
    ctl = _controller(_FailingSwapper(damage=True))
    _inject_result(ctl, SearchResult(_plan(n_mb=4), 0.5, 5, 5, 0.01), 1.0)
    with pytest.raises(RuntimeError, match="transfer blew up"):
        ctl.maybe_swap()                       # training state is gone:
    ctl.close()                                # never continue silently


def test_submit_defers_physical_swap_to_explicit_boundary():
    """submit() runs concurrently with the previous step: a physical
    re-layout there would be clobbered by the step's write-back, so with
    a param_swapper the adoption must wait for an explicit maybe_swap()
    at a true step boundary."""
    from repro.data.synthetic import MixedDataset

    sw, _ = _swapper({"w": jnp.ones((8, 8))}, latency_s=0.0)
    ctl = _controller(sw)
    better = _plan(n_mb=4)
    _inject_result(ctl, SearchResult(better, 0.5, 5, 5, 0.01), 1.0)
    items = MixedDataset("single_image", seed=0,
                         tokens_per_media_item=64).sample(8)
    ctl.submit(items)
    assert ctl.metrics.n_physical_swaps == 0     # not adopted mid-flight
    assert ctl.plan is not better
    assert ctl.collect() is not None
    assert ctl.maybe_swap() is True              # explicit boundary adopts
    assert ctl.metrics.n_physical_swaps == 1 and ctl.plan is better
    ctl.close()


def test_maybe_swap_without_swapper_is_logical_only():
    ctl = _controller(None)
    better = _plan(n_mb=4)
    _inject_result(ctl, SearchResult(better, 0.5, 5, 5, 0.01), 1.0)
    assert ctl.maybe_swap() is True
    assert ctl.metrics.n_physical_swaps == 0
    assert ctl.plan is better
    ctl.close()


# --------------------------------------------------------------------- #
# end-to-end smoke: train_mllm --replan --trace over a mid-run shift on
# forced host devices must perform a physical swap and trace it (the CI
# `reshard-smoke` job runs exactly this test)
# --------------------------------------------------------------------- #
@pytest.mark.slow
def test_train_mllm_physical_swap_smoke(tmp_path):
    trace_path = str(tmp_path / "trace.json")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "examples", "train_mllm.py"),
         "--tiny", "--steps", "24", "--shift-at", "6", "--replan",
         "--trace", trace_path],
        capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "physical_swaps=" in r.stdout
    n_swaps = int(r.stdout.split("physical_swaps=")[1].split()[0])
    assert n_swaps >= 1, r.stdout
    doc = json.loads(open(trace_path).read())
    names = {e["name"] for e in doc["traceEvents"]}
    assert "reshard" in names, sorted(names)
    assert "plan-swap" in names
    reshard_evs = [e for e in doc["traceEvents"] if e["name"] == "reshard"]
    assert all(ev["ph"] == "X" and ev["dur"] >= 0 for ev in reshard_evs)
