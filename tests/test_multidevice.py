"""Multi-device behaviours (subprocess: forced host device count).

XLA fixes the device count at first jax init, and the suite must keep the
default single device for everything else — so these run in subprocesses.
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_devices(script: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


def test_vocab_parallel_ce_matches_dense():
    out = run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.sharding.vocab_ce import make_vocab_parallel_ce
        from repro.train.loss import cross_entropy
        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((2,4), ("data","model"))
        B,S,D,V = 4, 16, 32, 64
        h = jax.random.normal(jax.random.PRNGKey(0), (B,S,D))
        w = jax.random.normal(jax.random.PRNGKey(1), (D,V)) * 0.1
        labels = jax.random.randint(jax.random.PRNGKey(2), (B,S), -1, V)
        ce = make_vocab_parallel_ce(mesh, ("data",), ("model",), V, tied=False)
        with mesh:
            got = float(ce(w, h, labels))
            g1 = jax.grad(lambda w: ce(w, h, labels))(w)
        want = float(cross_entropy(jnp.einsum("bsd,dv->bsv", h, w), labels))
        g2 = jax.grad(lambda w: cross_entropy(
            jnp.einsum("bsd,dv->bsv", h, w), labels))(w)
        np.testing.assert_allclose(got, want, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=1e-4, atol=1e-6)
        print("OK")
        """)
    assert "OK" in out


def test_inter_model_communicator_preserves_values():
    out = run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.communicator import make_communicator
        from repro.sharding.partition import AxisAssignment
        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((2,4), ("data","model"))
        enc = AxisAssignment(batch=("data","model"), tensor=())
        llm = AxisAssignment(batch=("data",), tensor=("model",))
        comm = make_communicator(mesh, enc, llm)
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 6, 16))
        xs = jax.device_put(x, NamedSharding(mesh, P(("data","model"))))
        with mesh:
            y = jax.jit(comm)(xs)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-6)
        # Output follows the LLM layout — but only where this jax version
        # lets with_sharding_constraint control a jit *boundary* (older
        # GSPMD overrides boundary output shardings via propagation; the
        # constraint still binds intermediates, the communicator's actual
        # position in a step function).  Feature-probe first.
        probe = jax.jit(lambda v: jax.lax.with_sharding_constraint(
            v, NamedSharding(mesh, P("data", None, None))))
        with mesh:
            honors = probe(xs).sharding.spec[0] in (("data",), "data")
        if honors:
            assert y.sharding.spec[0] in (("data",), "data"), y.sharding.spec
        print("OK")
        """)
    assert "OK" in out


def test_pipeline_executor_matches_sequential():
    out = run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.pipeline.executor import (build_stage_fn,
                                                  pipeline_forward,
                                                  stack_stage_params)
        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((4,), ("stage",))
        n_layers, d = 8, 16
        key = jax.random.PRNGKey(0)
        W = jax.random.normal(key, (n_layers, d, d)) * (d ** -0.5)

        def layer(w, x):
            return jnp.tanh(x @ w)

        stage_fn = build_stage_fn(lambda lp, h: layer(lp, h), 2)
        stacked = stack_stage_params(W, 4)
        m, mb, S = 4, 2, 8
        xs = jax.random.normal(jax.random.PRNGKey(1), (m, mb, S, d))
        pipe = pipeline_forward(mesh, stage_fn)
        with mesh:
            got = pipe(jax.device_put(stacked, NamedSharding(mesh, P("stage"))),
                       xs)
        # sequential reference
        ref = xs
        for i in range(n_layers):
            ref = jnp.tanh(ref @ W[i])
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        # differentiable
        g = jax.grad(lambda W4: jnp.sum(pipe(W4, xs)))(
            jax.device_put(stacked, NamedSharding(mesh, P("stage"))))
        assert np.isfinite(np.asarray(jax.tree_util.tree_leaves(g)[0])).all()
        print("OK")
        """)
    assert "OK" in out


def test_reshard_params_preserves_pipeline_outputs():
    """Property: `pipeline_forward` outputs are bit-identical before vs.
    after `reshard_params` across a chain of (tp, pp) transitions —
    including pp values that re-partition layers (4->2, 2->8, 8->1,
    1->2) — and each transition moves exactly the param bytes."""
    out = run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.pipeline.executor import (build_stage_fn,
                                                  pipeline_forward,
                                                  stack_stage_params)
        from repro.core.optimizer.space import (ModuleParallelism,
                                                ParallelismPlan)
        from repro.launch.reshard import plan_mesh, reshard_params

        n_layers, d = 8, 16
        W = jax.random.normal(jax.random.PRNGKey(0), (n_layers, d, d)) \\
            * (d ** -0.5)
        m, mb, S = 4, 2, 8
        xs = jax.random.normal(jax.random.PRNGKey(1), (m, mb, S, d))

        def layer(w, x):
            return jnp.tanh(x @ w)

        def plan(dp, pp, tp):
            return ParallelismPlan(llm=ModuleParallelism(tp, pp, dp),
                                   n_mb=m)

        def run_pipe(stacked, pl):
            mesh = plan_mesh(pl)
            pipe = pipeline_forward(
                mesh, build_stage_fn(layer, n_layers // pl.llm.pp))
            with mesh:
                return np.asarray(pipe(stacked, xs))

        ref = xs
        for i in range(n_layers):
            ref = jnp.tanh(ref @ W[i])
        ref = np.asarray(ref)

        p0 = plan(1, 4, 2)
        params = jax.device_put(stack_stage_params(W, 4),
                                NamedSharding(plan_mesh(p0), P("stage")))
        out0 = run_pipe(params, p0)
        assert np.array_equal(out0, ref), "pp=4 pipeline != sequential"

        total = int(sum(l.nbytes
                        for l in jax.tree_util.tree_leaves(params)))
        prev = p0
        for (dp, pp, tp) in [(1, 4, 1), (2, 2, 1), (1, 8, 1), (1, 1, 4),
                             (1, 2, 2), (1, 4, 1)]:
            nxt = plan(dp, pp, tp)
            params, rep = reshard_params(params, prev, nxt,
                                         stage_stacked=True)
            got = run_pipe(params, nxt)
            assert np.array_equal(got, out0), (prev.llm, nxt.llm)
            # ReshardReport sanity: a layout transition moves every byte
            assert rep.bytes_moved == rep.bytes_total == total, rep
            assert rep.elapsed_s >= 0.0
            assert rep.restacked == (prev.llm.pp != pp)
            prev = nxt
        print("OK")
        """)
    assert "OK" in out


def test_reshard_clamped_mesh_replicates_non_divisible_stage():
    """Emulation path: a clamped mesh can be narrower than the plan's PP
    (pp=3 on a 2-wide stage axis) — the reshard must fall back to
    replication instead of failing device_put on a non-divisible
    P('stage') sharding."""
    out = run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.pipeline.executor import (stack_stage_params,
                                                  unstack_stage_params)
        from repro.core.optimizer.space import (ModuleParallelism,
                                                ParallelismPlan)
        from repro.launch.reshard import clamped_plan_mesh, reshard_params

        W = jnp.arange(6 * 4, dtype=jnp.float32).reshape(6, 4)
        old = ParallelismPlan(llm=ModuleParallelism(1, 1, 1))
        new = ParallelismPlan(llm=ModuleParallelism(1, 3, 1))
        mesh = clamped_plan_mesh(new, devices=jax.devices()[:2])
        assert dict(mesh.shape)["stage"] == 2
        got, rep = reshard_params(stack_stage_params(W, 1), old, new,
                                  stage_stacked=True, new_mesh=mesh)
        assert rep.restacked and got.shape == (3, 2, 4)
        assert got.sharding.spec == jax.sharding.PartitionSpec()
        np.testing.assert_array_equal(
            np.asarray(unstack_stage_params(got)), np.asarray(W))
        print("OK")
        """)
    assert "OK" in out


@pytest.mark.slow
def test_dryrun_smoke_small_mesh():
    """A miniature dry-run on 8 host devices: gemma reduced config lowers
    and compiles with the production code path."""
    out = run_devices("""
        import jax, dataclasses
        from repro.configs import get_config
        from repro.common.types import INPUT_SHAPES, ShapeSpec
        from repro.launch import dryrun as D
        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((2, 4), ("data", "model"))
        spec = get_config("gemma-2b")
        spec = dataclasses.replace(spec, desc=spec.reduced_desc())
        shape = ShapeSpec("mini", 256, 16, "train")
        jitted, args, extra = D.build_train(spec, shape, mesh)
        with mesh:
            co = jitted.lower(*args).compile()
        print("compiled OK", co.memory_analysis().temp_size_in_bytes > 0)
        """, n=8)
    assert "compiled OK" in out


def test_ep_shard_map_moe_matches_dense():
    """Expert-parallel shard_map MoE (§Perf iteration 7) vs the dense
    oracle (high capacity factor -> no drops)."""
    out = run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.common.types import ModelConfig
        from repro.models.layers import moe
        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((2,4), ("data","model"))
        cfg = ModelConfig(name="m", family="moe", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=97,
                          ffn_pattern=("moe",), n_experts=8, top_k=2,
                          dtype="float32")
        p = moe.init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64))
        y_ref, _ = moe.apply_dense(p, x, cfg)
        with mesh:
            y_ep, lb = jax.jit(lambda p, x: moe.apply_ep_shard_map(
                p, x, cfg, (mesh, ("data",), ("model",)),
                capacity_factor=8.0))(p, x)
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_ep),
                                   rtol=2e-3, atol=2e-3)
        g = jax.grad(lambda p: jnp.sum(moe.apply_ep_shard_map(
            p, x, cfg, (mesh, ("data",), ("model",)),
            capacity_factor=8.0)[0]**2))(p)
        assert all(np.isfinite(np.asarray(l)).all()
                   for l in jax.tree_util.tree_leaves(g))
        print("OK")
        """)
    assert "OK" in out


def test_sharded_mamba_scan_matches_plain():
    out = run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models.layers.mamba import ssm_scan_xla, ssm_scan_sharded
        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((2,4), ("data","model"))
        B,S,di,N = 4, 32, 16, 8
        ks = jax.random.split(jax.random.PRNGKey(0), 6)
        u = jax.random.normal(ks[0], (B,S,di))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B,S,di)))
        Bt = jax.random.normal(ks[2], (B,S,N))
        Ct = jax.random.normal(ks[3], (B,S,N))
        A = -jnp.exp(jax.random.normal(ks[4], (di,N))*0.3)
        Dd = jax.random.normal(ks[5], (di,))
        y0, h0 = ssm_scan_xla(u, dt, Bt, Ct, A, Dd)
        ctx = (mesh, ("data",), ("model",))
        with mesh:
            y1, h1 = jax.jit(lambda *a: ssm_scan_sharded(*a, ctx))(
                u, dt, Bt, Ct, A, Dd)
            g0 = jax.grad(lambda u: jnp.sum(
                ssm_scan_xla(u, dt, Bt, Ct, A, Dd)[0]**2))(u)
            g1 = jax.grad(lambda u: jnp.sum(
                ssm_scan_sharded(u, dt, Bt, Ct, A, Dd, ctx)[0]**2))(u)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(g0), np.asarray(g1),
                                   rtol=2e-4, atol=2e-5)
        print("OK")
        """)
    assert "OK" in out


def test_tp_expert_shard_map_moe_non_divisible():
    """E ∤ model-axis fallback: TP-sharded experts with local dispatch
    (mixtral 8e / granite 40e on a 16-wide axis)."""
    out = run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.common.types import ModelConfig
        from repro.models.layers import moe
        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((2,4), ("data","model"))
        cfg = ModelConfig(name="m", family="moe", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=97,
                          ffn_pattern=("moe",), n_experts=6, top_k=2,
                          dtype="float32")       # 6 experts over 4-wide axis
        p = moe.init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64))
        y_ref, _ = moe.apply_dense(p, x, cfg)
        with mesh:
            y_tp, _ = jax.jit(lambda p, x: moe.apply_ep_shard_map(
                p, x, cfg, (mesh, ("data",), ("model",)),
                capacity_factor=8.0))(p, x)
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_tp),
                                   rtol=2e-3, atol=2e-3)
        print("OK")
        """)
    assert "OK" in out
