"""Chunked WKV6 (§Perf iteration 8) vs the sequential oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers.rwkv6 import wkv_chunked, wkv_scan_xla


def _inputs(B, S, H, M, seed=0, decay_scale=1.0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    r = jax.random.normal(ks[0], (B, S, H, M))
    k = jax.random.normal(ks[1], (B, S, H, M))
    v = jax.random.normal(ks[2], (B, S, H, M))
    dec = jax.random.normal(ks[3], (B, S, H, M)) * decay_scale - 1.0
    logw = -jnp.exp(dec)
    u = jax.random.normal(ks[4], (H, M)) * 0.2
    return r, k, v, logw, u


@pytest.mark.parametrize("B,S,H,M,chunk", [
    (1, 64, 2, 16, 16),
    (2, 96, 3, 32, 32),
    (1, 128, 2, 64, 64),
    (1, 50, 2, 16, 32),        # non-divisible chunk -> picks divisor
])
def test_chunked_matches_sequential(B, S, H, M, chunk):
    r, k, v, logw, u = _inputs(B, S, H, M)
    y0, s0 = wkv_scan_xla(r, k, v, jnp.exp(logw), u)
    y1, s1 = wkv_chunked(r, k, v, logw, u, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1),
                               rtol=2e-4, atol=2e-4)


def test_chunked_gradients_match():
    r, k, v, logw, u = _inputs(1, 64, 2, 32)
    g0 = jax.grad(lambda k: jnp.sum(
        wkv_scan_xla(r, k, v, jnp.exp(logw), u)[0] ** 2))(k)
    g1 = jax.grad(lambda k: jnp.sum(
        wkv_chunked(r, k, v, logw, u, chunk=16)[0] ** 2))(k)
    np.testing.assert_allclose(np.asarray(g0), np.asarray(g1),
                               rtol=1e-3, atol=1e-3)


def test_chunked_stable_under_extreme_decay():
    """log-space exponents are always <= 0: no overflow even when the decay
    annihilates the state within a chunk."""
    r, k, v, _, u = _inputs(1, 64, 2, 16)
    logw = jnp.full((1, 64, 2, 16), -12.0)
    y, s = wkv_chunked(r, k, v, logw, u, chunk=32)
    assert np.isfinite(np.asarray(y)).all()
    assert np.isfinite(np.asarray(s)).all()


def test_chunked_state_handoff():
    """Chunk boundary must not leak: half-by-half == full run."""
    r, k, v, logw, u = _inputs(1, 64, 2, 16)
    y_full, s_full = wkv_chunked(r, k, v, logw, u, chunk=16)
    y_a, s_a = wkv_chunked(r[:, :32], k[:, :32], v[:, :32], logw[:, :32],
                           u, chunk=16)
    y_b, s_b = wkv_chunked(r[:, 32:], k[:, 32:], v[:, 32:], logw[:, 32:],
                           u, chunk=16, state0=s_a)
    np.testing.assert_allclose(np.asarray(y_full[:, 32:]), np.asarray(y_b),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s_b),
                               rtol=2e-4, atol=2e-4)
