"""Data-aware 3D Parallelism Optimizer tests (paper §3.3, Algorithm 1)."""
import numpy as np
import pytest

from repro.common.types import ModelConfig
from repro.core.engine import DFLOPEngine
from repro.core.optimizer.space import (ClusterSpec, ParallelismPlan,
                                        enumerate_configs, find_combs)
from repro.data.synthetic import MixedDataset

ENC = ModelConfig(name="enc", family="vlm-enc", n_layers=12, d_model=512,
                  n_heads=8, n_kv_heads=8, d_ff=2048, vocab_size=0,
                  causal=False, use_rope=False, has_lm_head=False)
LLM = ModelConfig(name="llm", family="dense", n_layers=16, d_model=1024,
                  n_heads=16, n_kv_heads=4, d_ff=4096, vocab_size=32000)


def _engine(n_chips=32, mem=16e9):
    ds = MixedDataset("mixed", seed=0, tokens_per_media_item=64)
    eng = DFLOPEngine(llm_cfg=LLM, enc_cfg=ENC, e_seq_len=196,
                      cluster=ClusterSpec(n_chips, 16, mem_bytes=mem),
                      tokens_per_media_item=64)
    return eng.profile(ds)


def test_find_combs_products():
    for n in (8, 24, 96):
        for c in find_combs(n, max_tp=16):
            assert c.tp * c.pp * c.dp == n
            assert c.tp in (1, 2, 4, 8, 16)


def test_enumerate_configs_chip_conservation():
    cluster = ClusterSpec(16, 8)
    for ep, lp, sched in enumerate_configs(cluster, has_encoder=True):
        assert sched == "1f1b"
        assert ep.chips + lp.chips == 16
    for ep, lp, sched in enumerate_configs(cluster, has_encoder=False):
        assert ep is None and lp.chips == 16


def test_enumerate_configs_schedule_families():
    from repro.core.optimizer.space import SCHEDULES
    cluster = ClusterSpec(16, 8)
    seen = set()
    for ep, lp, sched in enumerate_configs(cluster, has_encoder=True,
                                           schedules=SCHEDULES):
        seen.add(sched)
        if sched == "encoder_fill":
            # encoder is colocated on the LLM ranks: same tp/dp, pp=1
            assert lp.chips == 16
            assert (ep.tp, ep.pp, ep.dp) == (lp.tp, 1, lp.dp)
            assert lp.pp >= 2
        else:
            assert ep.chips + lp.chips == 16
    assert seen == set(SCHEDULES)
    with pytest.raises(ValueError):
        list(enumerate_configs(cluster, has_encoder=True,
                               schedules=("bogus",)))


def test_search_returns_feasible_plan():
    eng = _engine()
    res = eng.plan(gbs=64)
    assert res.found
    plan = res.plan
    assert plan.chips == 32
    assert plan.n_mb >= 1
    assert np.isfinite(res.makespan) and res.makespan > 0


def test_search_dominates_every_uniform_baseline_with_partitioning():
    """θ* must beat (or match) any *partitioned* configuration; uniform
    colocated baselines live outside Θ (Eq. 3) so they are compared in the
    benchmarks instead."""
    eng = _engine()
    res = eng.plan(gbs=64)
    opt = __import__("repro.core.optimizer.search",
                     fromlist=["ParallelismOptimizer"])
    # re-run search with history to confirm the min was taken
    from repro.core.optimizer.search import ParallelismOptimizer
    o = ParallelismOptimizer(eng.cluster, eng.perf, keep_history=True)
    res2 = o.search(eng.dist, 64)
    assert res2.found
    best_from_history = min(t for _, t in res2.history)
    np.testing.assert_allclose(res2.makespan, best_from_history, rtol=1e-9)


def test_memory_constraint_prunes():
    """With a tiny memory cap, fewer configurations are feasible; with an
    impossible cap, none are."""
    rich = _engine(mem=64e9).plan(gbs=64)
    poor = _engine(mem=2e9).plan(gbs=64)
    none = _engine(mem=1e6).plan(gbs=64)
    assert rich.n_feasible >= poor.n_feasible
    assert not none.found
    # more memory never hurts the optimum
    assert rich.makespan <= poor.makespan + 1e-12


def test_optimizer_latency_subsecond_at_1024_chips():
    """Fig. 16a: optimizer overhead stays in the hundreds of ms."""
    ds = MixedDataset("mixed", seed=0, tokens_per_media_item=64)
    eng = DFLOPEngine(llm_cfg=LLM, enc_cfg=ENC, e_seq_len=196,
                      cluster=ClusterSpec(1024, 16),
                      tokens_per_media_item=64).profile(ds)
    res = eng.plan(gbs=512)
    assert res.found
    assert res.elapsed_s < 5.0          # CPU-container headroom; paper: <0.2s


def test_expected_objective_prefers_balanced_under_variance():
    eng = _engine()
    eng.objective = "expected"
    res = eng.plan(gbs=64)
    assert res.found
