"""Online Microbatch Scheduler: unit + property tests (paper §3.4)."""
import itertools

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.scheduler.ilp import solve_makespan_bnb
from repro.core.scheduler.lpt import (cmax, lower_bound, lpt_assign_batch,
                                      lpt_schedule)

durations = st.lists(
    st.tuples(st.floats(0.0, 10.0), st.floats(0.0, 10.0)),
    min_size=1, max_size=24)


def _split(pairs):
    e = np.array([p[0] for p in pairs])
    l = np.array([p[1] for p in pairs])
    return e, l


@given(durations, st.integers(1, 6))
@settings(max_examples=200, deadline=None)
def test_lpt_partition_invariants(pairs, m):
    e, l = _split(pairs)
    groups = lpt_schedule(e, l, m)
    # every item assigned exactly once
    flat = sorted(i for g in groups for i in g)
    assert flat == list(range(len(e)))
    # objective within Graham-style bound of the lower bound
    assert cmax(e, l, groups) <= 2.0 * lower_bound(e, l, m) + 1e-9


@given(st.lists(durations, min_size=1, max_size=3), st.integers(1, 6),
       st.integers(0, 100))
@settings(max_examples=60, deadline=None)
def test_lpt_assign_batch_matches_per_trial(rows_pairs, m, seed):
    """The vectorized-over-trials LPT (the search objectives' hot path)
    must reproduce `lpt_schedule(refine=False)` assignment-for-assignment,
    and its load matrices must equal the per-bucket duration sums."""
    rng = np.random.default_rng(seed)
    n = max(len(p) for p in rows_pairs)
    T = len(rows_pairs)
    e = rng.uniform(0.0, 10.0, (T, n))
    l = rng.uniform(0.01, 10.0, (T, n))
    for t, pairs in enumerate(rows_pairs):     # overlay hypothesis values
        for i, (pe, pl) in enumerate(pairs):
            e[t, i] = pe
            l[t, i] = pl
    assign, loads_e, loads_l = lpt_assign_batch(e, l, m)
    for t in range(T):
        want = np.empty(n, dtype=np.int64)
        for j, g in enumerate(lpt_schedule(e[t], l[t], m, refine=False)):
            for i in g:
                want[i] = j
        np.testing.assert_array_equal(assign[t], want)
        for j in range(m):
            sel = assign[t] == j
            np.testing.assert_allclose(loads_e[t, j], e[t][sel].sum(),
                                       rtol=1e-12, atol=1e-12)
            np.testing.assert_allclose(loads_l[t, j], l[t][sel].sum(),
                                       rtol=1e-12, atol=1e-12)


def test_lpt_assign_batch_matches_per_trial_deterministic():
    """Shim-proof variant: random heterogeneous rows, plus the edge cases
    the vectorized head-prefill must handle (zero LLM durations disable
    it, n < m leaves buckets empty, duplicate durations tie)."""
    rng = np.random.default_rng(11)
    cases = []
    for T, n, m in [(1, 1, 1), (3, 40, 7), (2, 5, 9), (4, 64, 64)]:
        e = rng.uniform(0.0, 10.0, (T, n))
        l = rng.uniform(0.01, 10.0, (T, n))
        cases.append((e, l, m))
    e, l, m = cases[1]
    z = e.copy(), l.copy()
    z[1][:, 3] = 0.0                              # a zero-LLM item
    cases.append((z[0], z[1], m))
    dup = np.full((2, 12), 2.0)
    cases.append((0.0 * dup, dup, 5))             # all items identical
    for e, l, m in cases:
        assign, loads_e, loads_l = lpt_assign_batch(e, l, m)
        for t in range(len(e)):
            want = np.empty(e.shape[1], dtype=np.int64)
            for j, g in enumerate(lpt_schedule(e[t], l[t], m, refine=False)):
                for i in g:
                    want[i] = j
            np.testing.assert_array_equal(assign[t], want)


@given(durations, st.integers(1, 4))
@settings(max_examples=60, deadline=None)
def test_bnb_no_worse_than_lpt(pairs, m):
    e, l = _split(pairs)
    res = solve_makespan_bnb(e, l, m, time_limit_s=0.2)
    flat = sorted(i for g in res.groups for i in g)
    assert flat == list(range(len(e)))
    assert res.cmax <= cmax(e, l, lpt_schedule(e, l, m)) + 1e-9
    assert res.cmax >= lower_bound(e, l, m) - 1e-9


def _brute_force(e, l, m):
    n = len(e)
    best = float("inf")
    for assign in itertools.product(range(m), repeat=n):
        ge = np.zeros(m)
        gl = np.zeros(m)
        for i, b in enumerate(assign):
            ge[b] += e[i]
            gl[b] += l[i]
        best = min(best, max(ge.max(), gl.max()))
    return best


@pytest.mark.parametrize("seed", range(5))
def test_bnb_optimal_small(seed):
    rng = np.random.default_rng(seed)
    n, m = 7, 3
    e = rng.uniform(0, 1, n)
    l = rng.uniform(0, 2, n)
    res = solve_makespan_bnb(e, l, m, time_limit_s=5.0)
    assert res.optimal
    np.testing.assert_allclose(res.cmax, _brute_force(e, l, m), rtol=1e-9)


def test_imbalance_below_one_percent_at_large_gbs():
    """Fig. 16b claim: at GBS 2048 the hybrid solver stays within 1% of the
    load lower bound."""
    rng = np.random.default_rng(0)
    gbs, m = 2048, 32
    e = rng.lognormal(0, 1, gbs) * 0.01
    l = rng.lognormal(0.5, 0.8, gbs) * 0.02
    res = solve_makespan_bnb(e, l, m, time_limit_s=0.5)
    lb = lower_bound(e, l, m)
    assert res.cmax / lb - 1.0 < 0.01


def test_scheduler_beats_random_on_heterogeneous_items():
    from repro.core.engine import DFLOPEngine
    from repro.core.optimizer.space import (ClusterSpec, ModuleParallelism,
                                            ParallelismPlan)
    from repro.data.synthetic import MixedDataset
    from repro.common.types import ModelConfig

    cfg = ModelConfig(name="t", family="dense", n_layers=4, d_model=256,
                      n_heads=4, n_kv_heads=4, d_ff=512, vocab_size=1024)
    ds = MixedDataset("mixed", seed=0, tokens_per_media_item=32)
    eng = DFLOPEngine(llm_cfg=cfg, cluster=ClusterSpec(16, 16),
                      tokens_per_media_item=32).profile(ds)
    plan = ParallelismPlan(llm=ModuleParallelism(1, 1, 2), n_mb=4)
    sched = eng.scheduler(plan=plan, adaptive=False, ilp_time_limit_s=0.1)
    items = ds.sample(64)
    balanced = sched.schedule(items)
    random = sched.schedule_random(items)
    assert balanced.cmax <= random.cmax
    assert balanced.imbalance < 0.05
