"""Per-architecture smoke tests (deliverable f).

For every assigned architecture: instantiate a REDUCED variant of the same
family (<=2-ish layers, d_model<=256, <=4 experts), run one forward and one
train step on CPU, assert output shapes and finiteness; decoder archs also
run a decode step against a reduced cache.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.types import MLLMConfig
from repro.configs import ASSIGNED, get_config
from repro.models import mllm as mllm_lib
from repro.models import model as model_lib
from repro.models.model import FwdCtx
from repro.train.optim import AdamWConfig, adamw_init
from repro.train.step import make_train_step

B, S = 2, 32


def _decoder_batch(cfg, n_mb=1):
    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg.vocab_size, size=(n_mb, B, S)).astype(np.int32)
    labels = np.roll(toks, -1, axis=-1)
    labels[..., -1] = -1
    return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}


def _audio_batch(cfg, n_mb=1):
    rng = np.random.default_rng(0)
    emb = rng.standard_normal((n_mb, B, S, cfg.input_embed_dim)).astype(np.float32)
    labels = rng.integers(0, cfg.vocab_size, size=(n_mb, B, S)).astype(np.int32)
    labels[..., ::2] = -1           # only masked positions predicted
    return {"frame_embeds": jnp.asarray(emb), "labels": jnp.asarray(labels)}


def _mllm_batch(mcfg: MLLMConfig, n_mb=1):
    rng = np.random.default_rng(0)
    Tm, Tt = 16, S
    de = mcfg.stub.embed_dim
    media = rng.standard_normal((n_mb, B, Tm, de)).astype(np.float32)
    toks = rng.integers(1, mcfg.llm.vocab_size, size=(n_mb, B, Tt)).astype(np.int32)
    labels = np.roll(toks, -1, axis=-1)
    labels[..., -1] = -1
    return {
        "media_embeds": jnp.asarray(media),
        "media_mask": jnp.ones((n_mb, B, Tm), jnp.int32),
        "text_tokens": jnp.asarray(toks),
        "text_mask": jnp.ones((n_mb, B, Tt), jnp.int32),
        "labels": jnp.asarray(labels),
    }


def _batch_for(desc, n_mb=1):
    if isinstance(desc, MLLMConfig):
        return _mllm_batch(desc, n_mb)
    if desc.input_embed_dim > 0:
        return _audio_batch(desc, n_mb)
    return _decoder_batch(desc, n_mb)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_forward(arch):
    spec = get_config(arch)
    desc = spec.reduced_desc()
    params = (mllm_lib.init if isinstance(desc, MLLMConfig)
              else model_lib.init)(jax.random.PRNGKey(0), desc)
    ctx = FwdCtx(mode="train", attn_impl="naive", moe_impl="dense")
    batch = jax.tree.map(lambda a: a[0], _batch_for(desc))
    if isinstance(desc, MLLMConfig):
        logits, _ = mllm_lib.forward_train(params, desc, batch, ctx=ctx)
        assert logits.shape == (B, S, desc.llm.vocab_size)
    elif desc.input_embed_dim > 0:
        logits, _, _ = model_lib.forward(params, desc,
                                         embeds=batch["frame_embeds"], ctx=ctx)
        assert logits.shape == (B, S, desc.vocab_size)
    else:
        logits, _, _ = model_lib.forward(params, desc, tokens=batch["tokens"],
                                         ctx=ctx)
        assert logits.shape == (B, S, desc.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_train_step(arch):
    spec = get_config(arch)
    desc = spec.reduced_desc()
    params = (mllm_lib.init if isinstance(desc, MLLMConfig)
              else model_lib.init)(jax.random.PRNGKey(0), desc)
    opt = adamw_init(params)
    ctx = FwdCtx(mode="train", attn_impl="naive", moe_impl="dense")
    step = jax.jit(make_train_step(desc, AdamWConfig(lr=1e-3), ctx=ctx))
    batch = _batch_for(desc, n_mb=2)
    new_params, new_opt, metrics = step(params, opt, batch, 1e-3)
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(params),
        jax.tree_util.tree_leaves(new_params)))
    assert delta > 0


@pytest.mark.parametrize("arch", [a for a in ASSIGNED
                                  if get_config(a).llm_cfg.is_decoder])
def test_reduced_decode_step(arch):
    spec = get_config(arch)
    desc = spec.reduced_desc()
    cfg = desc.llm if isinstance(desc, MLLMConfig) else desc
    params = model_lib.init(jax.random.PRNGKey(0), cfg) \
        if not isinstance(desc, MLLMConfig) \
        else mllm_lib.init(jax.random.PRNGKey(0), desc)["llm"]
    caches = model_lib.init_cache(cfg, B, 64, kv_dtype=jnp.float32)
    tok = jnp.ones((B,), jnp.int32)
    logits, caches, _ = model_lib.decode_step(params, cfg, tok, caches, 0)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
