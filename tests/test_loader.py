"""Differential test harness for the data path (compose → schedule → pack).

Pins the loader/composer contracts the rest of the stack leans on:

  * `ScheduledLoader` prefetch and sync modes yield batch-for-batch
    identical `PackedBatch` streams and `ScheduleOutput`s (the async
    overlap is an implementation detail, never a semantic one);
  * composer-enabled epochs are exact permutations of FIFO epochs —
    every item exactly once;
  * no item waits more than `max_staleness` batches in the reorder
    window (EDF reservation, including the lockstep-aging initial fill);
  * the fig18 acceptance numbers (slow tier).
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.common.types import ModelConfig
from repro.core.engine import DFLOPEngine
from repro.core.optimizer.space import (ClusterSpec, ModuleParallelism,
                                        ParallelismPlan)
from repro.data.composer import LookaheadComposer, sorted_runs
from repro.data.items import DataItem
from repro.data.loader import ScheduledLoader
from repro.data.synthetic import MixedDataset
from repro.runtime import RuntimeMetrics

TPM = 64

ENC = ModelConfig(name="e", family="vlm-enc", n_layers=4, d_model=256,
                  n_heads=4, n_kv_heads=4, d_ff=1024, vocab_size=0,
                  causal=False, use_rope=False, input_embed_dim=64,
                  has_lm_head=False)
LLM = ModelConfig(name="l", family="dense", n_layers=8, d_model=512,
                  n_heads=8, n_kv_heads=8, d_ff=2048, vocab_size=8192)

PLAN = ParallelismPlan(llm=ModuleParallelism(1, 1, 2),
                       encoder=ModuleParallelism(1, 1, 1), n_mb=2)


@pytest.fixture(scope="module")
def engine():
    ds = MixedDataset("mixed", seed=0, tokens_per_media_item=TPM)
    eng = DFLOPEngine(llm_cfg=LLM, enc_cfg=ENC, e_seq_len=64,
                      cluster=ClusterSpec(n_chips=16, chips_per_node=8,
                                          mem_bytes=80e9),
                      tokens_per_media_item=TPM)
    eng.profile(ds, n_samples=256)
    return eng


def _loader(engine, *, prefetch, random_baseline=False, compose_window=0,
            gbs=8, seed=3, item_source=None, metrics=None,
            dataset_seed=7, compose_prefetch=True):
    """A fresh loader with its own scheduler/dataset/composer so the two
    modes under comparison share no mutable state."""
    ds = MixedDataset("mixed", seed=dataset_seed, tokens_per_media_item=TPM)
    sched = engine.scheduler(plan=PLAN, adaptive=False,
                             ilp_time_limit_s=0.02)
    composer = (LookaheadComposer(sched, gbs=gbs, window=compose_window)
                if compose_window else None)
    return ScheduledLoader(ds, sched, gbs=gbs, token_budget=256,
                           vocab_size=512, random_baseline=random_baseline,
                           seed=seed, prefetch=prefetch, composer=composer,
                           compose_prefetch=compose_prefetch,
                           item_source=item_source, metrics=metrics)


def _take(loader, k):
    out = []
    it = iter(loader)
    for _ in range(k):
        batch = next(it, None)
        if batch is None:
            break
        out.append((batch, loader.last_schedule))
    return out


def _assert_streams_equal(a, b):
    assert len(a) == len(b)
    for (ba, sa), (bb, sb) in zip(a, b):
        for key in ("tokens", "labels", "segment_ids", "positions"):
            np.testing.assert_array_equal(ba[key], bb[key], err_msg=key)
        assert sa.groups == sb.groups
        assert sa.cmax == sb.cmax
        assert sa.solver == sb.solver


# --------------------------------------------------------------------- #
# prefetch ≡ sync
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("random_baseline", [False, True],
                         ids=["scheduled", "random"])
def test_prefetch_matches_sync(engine, random_baseline):
    sync = _take(_loader(engine, prefetch=False,
                         random_baseline=random_baseline), 6)
    pre = _take(_loader(engine, prefetch=True,
                        random_baseline=random_baseline), 6)
    _assert_streams_equal(sync, pre)


def test_prefetch_matches_sync_with_composer(engine):
    sync = _take(_loader(engine, prefetch=False, compose_window=2), 6)
    pre = _take(_loader(engine, prefetch=True, compose_window=2), 6)
    _assert_streams_equal(sync, pre)


# --------------------------------------------------------------------- #
# compose-prefetch thread ≡ inline composition
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("prefetch", [False, True], ids=["sync", "prefetch"])
def test_compose_prefetch_matches_inline(engine, prefetch):
    """The window refill running off the caller thread must be a pure
    latency optimization: batch-for-batch identical tensors AND schedules
    versus inline composition, in both loader modes."""
    inline = _take(_loader(engine, prefetch=prefetch, compose_window=3,
                           compose_prefetch=False), 8)
    threaded = _take(_loader(engine, prefetch=prefetch, compose_window=3,
                             compose_prefetch=True), 8)
    _assert_streams_equal(inline, threaded)


def test_compose_prefetch_finite_source_exactly_once(engine):
    """Thread + drain on a finite epoch: terminates, and the composed
    epoch is still an exact permutation (no lost or duplicated items at
    the queue/drain boundary)."""
    ds = MixedDataset("mixed", seed=13, tokens_per_media_item=TPM)
    source = [ds.sample(8) for _ in range(7)]
    inline = _take(_loader(engine, prefetch=False, compose_window=2,
                           compose_prefetch=False, item_source=source), 99)
    threaded = _take(_loader(engine, prefetch=False, compose_window=2,
                             compose_prefetch=True, item_source=source), 99)
    assert len(inline) == 7
    _assert_streams_equal(inline, threaded)


def test_compose_prefetch_worker_error_surfaces_on_caller(engine):
    """An exception inside the compose worker must re-raise on the caller
    thread, not hang the queue or die silently on a daemon thread."""
    def bad_source():
        ds = MixedDataset("mixed", seed=13, tokens_per_media_item=TPM)
        yield ds.sample(8)
        yield ds.sample(8)
        raise RuntimeError("upstream storage failure")

    loader = _loader(engine, prefetch=False, compose_window=2,
                     compose_prefetch=True, item_source=bad_source())
    with pytest.raises(RuntimeError, match="upstream storage failure"):
        _take(loader, 99)


def test_compose_prefetch_early_abandon_stops_worker(engine):
    """Dropping the iterator mid-epoch must release the worker (stop
    event) instead of leaving it blocked on a full queue forever."""
    import threading
    import time
    loader = _loader(engine, prefetch=False, compose_window=2)
    it = iter(loader)
    next(it)
    it.close()                      # fires the generator's finally → stop
    for _ in range(100):            # worker re-checks stop every 0.1s
        alive = [t for t in threading.enumerate()
                 if t.name == "compose-prefetch" and t.is_alive()]
        if not alive:
            break
        time.sleep(0.05)
    assert not alive


def test_finite_source_prefetch_matches_sync_and_terminates(engine):
    ds = MixedDataset("mixed", seed=11, tokens_per_media_item=TPM)
    source = [ds.sample(8) for _ in range(5)]
    sync = _take(_loader(engine, prefetch=False, item_source=source), 99)
    pre = _take(_loader(engine, prefetch=True, item_source=source), 99)
    assert len(sync) == 5
    _assert_streams_equal(sync, pre)


# --------------------------------------------------------------------- #
# composer epoch = permutation of FIFO epoch
# --------------------------------------------------------------------- #
def test_composer_epoch_is_exact_permutation_of_fifo(engine):
    ds = MixedDataset("mixed", seed=5, tokens_per_media_item=TPM)
    source = [ds.sample(8) for _ in range(9)]
    fifo = list(_loader(engine, prefetch=False,
                        item_source=source)._item_batches())
    composed = list(_loader(engine, prefetch=False, compose_window=3,
                            item_source=source)._item_batches())
    fifo_ids = [it.item_id for b in fifo for it in b]
    comp_ids = [it.item_id for b in composed for it in b]
    assert sorted(fifo_ids) == sorted(comp_ids)          # exact permutation
    assert len(set(comp_ids)) == len(comp_ids)           # exactly once
    assert sum(len(b) for b in composed) == 9 * 8


def test_loader_surfaces_truncation_to_metrics(engine):
    metrics = RuntimeMetrics()
    # budget far below typical item length → guaranteed truncation
    ds = MixedDataset("video", seed=2, tokens_per_media_item=TPM)
    sched = engine.scheduler(plan=PLAN, adaptive=False,
                             ilp_time_limit_s=0.02)
    loader = ScheduledLoader(ds, sched, gbs=8, token_budget=64,
                             vocab_size=512, seed=0, prefetch=False,
                             metrics=metrics)
    _take(loader, 3)
    assert loader.total_truncated > 0
    assert metrics.n_truncated_tokens == loader.total_truncated
    assert metrics.truncated_tokens.count == 3   # one per global batch


# --------------------------------------------------------------------- #
# composer invariants (fast fake-duration scheduler)
# --------------------------------------------------------------------- #
class _FakeSched:
    """Duck-typed stand-in: plan + per-item durations, no perf model."""

    def __init__(self, plan=PLAN, tpm=4):
        self.plan = plan
        self.tpm = tpm
        self.mode = "train"

    def item_durations(self, items, plan=None):
        e = np.array([it.encoder_batch() for it in items], float) + 0.1
        l = np.array([it.llm_seq_len(self.tpm) for it in items], float)
        return e, l


def _stream(n, seed=0):
    rng = np.random.default_rng(seed)
    return [DataItem(int(rng.integers(1, 9)), int(rng.integers(4, 200)),
                     "multi_image", i) for i in range(n)]


def _run_composer(items, *, gbs, window, max_staleness=None, plan=PLAN):
    """Drive a composer over `items` in gbs-sized pushes; returns
    (batches, wait) where wait[id] = composes spent in the window."""
    comp = LookaheadComposer(_FakeSched(plan), gbs=gbs, window=window,
                             max_staleness=max_staleness)
    entered, waits, batches = {}, {}, []

    def emit(batch):
        for it in batch:
            waits[it.item_id] = comp.batch_idx - 1 - entered[it.item_id]
        batches.append(batch)

    for s in range(0, len(items), gbs):
        cohort = items[s:s + gbs]
        for it in cohort:
            entered[it.item_id] = comp.batch_idx
        comp.push(cohort)
        while comp.ready:
            emit(comp.compose())
    for b in comp.drain():
        emit(b)
    return batches, waits, comp


def _check_invariants(items, batches, waits, comp):
    out_ids = [it.item_id for b in batches for it in b]
    assert sorted(out_ids) == sorted(it.item_id for it in items)
    assert len(set(out_ids)) == len(out_ids)
    assert max(waits.values()) <= comp.max_staleness
    # full batches except possibly the tail of the drain
    assert all(len(b) == comp.gbs for b in batches[:-1])


@given(st.integers(1, 4), st.integers(3, 10), st.integers(1, 12),
       st.integers(0, 3))
@settings(max_examples=40, deadline=None)
def test_composer_exactly_once_and_staleness_property(window, staleness,
                                                      n_cohorts, seed):
    staleness = max(staleness, window - 1)
    gbs = 6
    items = _stream(n_cohorts * gbs, seed)
    batches, waits, comp = _run_composer(items, gbs=gbs, window=window,
                                         max_staleness=staleness)
    _check_invariants(items, batches, waits, comp)


@pytest.mark.parametrize("window,staleness", [(1, 1), (2, 2), (3, 2),
                                              (4, 3), (4, 8)])
def test_composer_exactly_once_and_staleness(window, staleness):
    """Deterministic twin of the property test (hypothesis optional)."""
    gbs = 6
    items = _stream(12 * gbs, seed=window + staleness)
    batches, waits, comp = _run_composer(items, gbs=gbs, window=window,
                                         max_staleness=staleness)
    _check_invariants(items, batches, waits, comp)


def test_composer_initial_fill_lockstep_respects_staleness():
    """The whole W·gbs fill ages in lockstep — naive 'force at the bound'
    would need W·gbs seats in one batch; the EDF reservation must spread
    them instead."""
    gbs, window = 4, 4
    items = _stream(40 * gbs, seed=123)
    # tightest legal bound: max_staleness = window - 1
    batches, waits, comp = _run_composer(items, gbs=gbs, window=window,
                                         max_staleness=window - 1)
    _check_invariants(items, batches, waits, comp)


def test_composer_push_overfill_raises():
    comp = LookaheadComposer(_FakeSched(), gbs=4, window=2)
    comp.push(_stream(8))
    with pytest.raises(ValueError):
        comp.push(_stream(1, seed=1))


def test_composer_validates_staleness_vs_window():
    with pytest.raises(ValueError):
        LookaheadComposer(_FakeSched(), gbs=4, window=4, max_staleness=2)
    with pytest.raises(ValueError):
        LookaheadComposer(_FakeSched(), gbs=4, window=0)


def test_composer_flush_reprices_window_on_plan_change(engine):
    sched = engine.scheduler(plan=PLAN, adaptive=False,
                             ilp_time_limit_s=0.02)
    ds = MixedDataset("mixed", seed=9, tokens_per_media_item=TPM)
    comp = LookaheadComposer(sched, gbs=8, window=2)
    comp.push(ds.sample(8))
    comp.push(ds.sample(8))
    comp.compose()
    priced_under_old = [(en.e, en.l) for en in comp._entries]
    assert all(e >= 0 for e, _ in priced_under_old)
    # hot-swap to a different TP degree: durations must change
    sched.set_plan(ParallelismPlan(llm=ModuleParallelism(2, 1, 2),
                                   encoder=ModuleParallelism(1, 1, 1),
                                   n_mb=2))
    comp.flush_plan()
    assert comp.n_flushes == 1
    assert all(en.e < 0 for en in comp._entries)         # invalidated
    comp.compose()
    assert comp._plan_key == sched.plan.as_tuple()


def test_composer_auto_flushes_without_explicit_flush(engine):
    """Even if the controller forgets flush_plan(), compose() re-checks
    the plan identity — composition never prices under a stale θ*."""
    sched = engine.scheduler(plan=PLAN, adaptive=False,
                             ilp_time_limit_s=0.02)
    ds = MixedDataset("mixed", seed=9, tokens_per_media_item=TPM)
    comp = LookaheadComposer(sched, gbs=8, window=2)
    comp.push(ds.sample(8))
    comp.push(ds.sample(8))
    comp.compose()
    new_plan = ParallelismPlan(llm=ModuleParallelism(2, 1, 2),
                               encoder=ModuleParallelism(1, 1, 1), n_mb=2)
    sched.set_plan(new_plan)
    comp.push(ds.sample(8))
    comp.compose()
    assert comp._plan_key == new_plan.as_tuple()


def test_controller_wires_composer_telemetry_and_flush(engine):
    ctl = engine.runtime(8, plan=PLAN, adaptive=False, calibrate=False,
                         auto_replan=False, ilp_time_limit_s=0.02,
                         compose_window=2)
    comp = ctl.composer
    assert comp is not None and comp.trace is ctl.trace \
        and comp.metrics is ctl.metrics
    ds = MixedDataset("mixed", seed=4, tokens_per_media_item=TPM)
    ctl.composer.push(ds.sample(8))
    batch = ctl.compose(ds.sample(8))
    assert len(batch) == 8
    assert ctl.metrics.n_composed == 1
    assert ctl.metrics.compose_pred_gain.count == 1
    ctl.close()


def test_controller_compose_draw_warms_full_window(engine):
    """ctl.compose(draw=...) must fill the whole W·gbs lookahead on the
    first call and hold it at capacity thereafter — per-step composition
    with real lookahead, no caller-side pre-fill."""
    ctl = engine.runtime(8, plan=PLAN, adaptive=False, calibrate=False,
                         auto_replan=False, ilp_time_limit_s=0.02,
                         compose_window=3)
    ds = MixedDataset("mixed", seed=4, tokens_per_media_item=TPM)
    drawn = []

    def draw():
        b = ds.sample(8)
        drawn.append(b)
        return b

    batch = ctl.compose(draw=draw)
    assert len(batch) == 8
    assert len(drawn) == 3                       # warmed W batches
    assert ctl.composer.pending == 2 * 8         # window minus one batch
    ctl.compose(draw=draw)
    assert len(drawn) == 4                       # steady state: one draw
    # no cold-window marker on the draw path
    assert not any(e[1] == "compose-cold-window"
                   for e in ctl.trace._events)
    ctl.close()


def test_controller_compose_cold_window_is_marked(engine):
    """Per-step push of a single cohort never fills the window — zero
    lookahead; the controller must flag it rather than silently
    degenerate to FIFO."""
    ctl = engine.runtime(8, plan=PLAN, adaptive=False, calibrate=False,
                         auto_replan=False, ilp_time_limit_s=0.02,
                         compose_window=4)
    ds = MixedDataset("mixed", seed=4, tokens_per_media_item=TPM)
    ctl.compose(ds.sample(8))
    assert any(e[1] == "compose-cold-window" for e in ctl.trace._events)
    ctl.close()


def test_materialize_shapes_and_masks():
    """Tensorization contract of the stub frontend (the path
    `examples/train_mllm.build_batches` feeds the model from)."""
    tpm = 8
    ds = MixedDataset("mixed", seed=1, tokens_per_media_item=tpm)
    items = ds.sample(4)
    batch = ds.materialize(items, embed_dim=16, vocab_size=64,
                           max_media=32, max_text=48)
    assert batch["media_embeds"].shape == (4, 32, 16)
    assert batch["text_tokens"].shape == (4, 48)
    for i, it in enumerate(items):
        assert batch["media_mask"][i].sum() == min(it.n_media_items * tpm, 32)
        t = min(it.text_len, 48)
        assert batch["text_mask"][i].sum() == t
        # labels are next-token within the text span, -1 elsewhere
        assert (batch["labels"][i, :t - 1] >= 0).all()
        assert (batch["labels"][i, t - 1:] == -1).all()


def test_item_shapes_matches_paper_keying():
    from repro.data.items import item_shapes
    it = DataItem(3, 100, "multi_image", 0)
    b, s = item_shapes(it, tokens_per_media_item=8)
    assert (b, s) == (3, 3 * 8 + 100)


def test_sorted_runs_are_contiguous_and_capped():
    durs = list(np.random.default_rng(0).random(20))
    runs = sorted_runs(durs, k=5, max_candidates=6)
    assert 1 <= len(runs) <= 6
    order = list(np.argsort(-np.asarray(durs), kind="stable"))
    for run in runs:
        s = order.index(run[0])
        assert list(run) == order[s:s + 5]       # contiguous in sorted order
    assert sorted_runs(durs, k=21) == []


# --------------------------------------------------------------------- #
# fig18 acceptance (slow tier)
# --------------------------------------------------------------------- #
@pytest.mark.slow
def test_fig18_composer_acceptance():
    from benchmarks.fig18_composer import run
    rows = run(n_batches=48)
    summaries = {r["window"]: r for r in rows if r.get("summary")}
    assert any(r["fifo_over_composed_makespan"] >= 1.15
               for W, r in summaries.items() if W <= 4)
    best = summaries[4]
    assert best["recompiles_composed"] < best["recompiles_fifo"]
