"""Optional-hypothesis shim for the property-based tests.

`hypothesis` is not part of the minimal environment; importing it at
module top level used to abort collection of four whole test files.  This
shim degrades gracefully: with hypothesis installed it re-exports the real
``given``/``settings``/``st``; without it, ``@given`` turns the test into
an explicit skip while the rest of the module still collects and runs.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Absorbs any strategy-construction chain: attribute access, calls
        (st.integers(1, 5).flatmap(...).map(...)) all return the stub."""

        def __getattr__(self, name):
            return self

        def __call__(self, *a, **k):
            return self

    st = _AnyStrategy()

    def settings(*_a, **_k):
        return lambda fn: fn

    def given(*_a, **_k):
        def deco(fn):
            # Zero-arg wrapper (not functools.wraps: pytest would follow
            # __wrapped__ back to the parametrised signature and demand
            # fixtures for the strategy arguments).
            def skipper():
                pytest.skip("hypothesis not installed")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco
