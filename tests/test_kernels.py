"""Pallas kernel validation: shape/dtype sweeps vs. pure-jnp oracles.

Kernels execute in interpret mode on CPU (the exact TPU program, run
op-by-op) and must match ``repro.kernels.ref`` to float tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

jax.config.update("jax_enable_x64", False)


def _mk_qkv(key, B, S, H, KH, D, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype=jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KH, D), dtype=jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KH, D), dtype=jnp.float32)
    return q.astype(dtype), k.astype(dtype), v.astype(dtype)


def _segments(key, B, S, n_seg):
    """Contiguous segments 1..n_seg (0 = padding tail)."""
    lens = jax.random.randint(key, (B, n_seg), 1, max(2, S // n_seg + 1))
    seg = np.zeros((B, S), np.int32)
    for b in range(B):
        cur = 0
        for i, L in enumerate(np.asarray(lens)[b]):
            L = int(L)
            seg[b, cur:cur + L] = i + 1
            cur += L
            if cur >= S:
                break
    return jnp.asarray(seg)


ATTN_CASES = [
    # (B, S, H, KH, D, causal, window, dtype)
    (1, 128, 4, 4, 64, True, 0, jnp.float32),
    (2, 256, 8, 2, 64, True, 0, jnp.float32),       # GQA
    (2, 128, 4, 1, 64, True, 0, jnp.float32),       # MQA
    (1, 256, 4, 4, 128, True, 64, jnp.float32),     # sliding window
    (2, 128, 4, 2, 64, False, 0, jnp.float32),      # bidirectional (encoder)
    (1, 128, 4, 2, 64, True, 0, jnp.bfloat16),
    (1, 96, 2, 2, 32, True, 0, jnp.float32),        # non-pow2 seq
]


@pytest.mark.parametrize("B,S,H,KH,D,causal,window,dtype", ATTN_CASES)
def test_packed_flash_attention(B, S, H, KH, D, causal, window, dtype):
    key = jax.random.PRNGKey(42)
    q, k, v = _mk_qkv(key, B, S, H, KH, D, dtype)
    seg = _segments(jax.random.PRNGKey(7), B, S, n_seg=3)
    got = ops.packed_flash_attention(q, k, v, segment_ids=seg, causal=causal,
                                     window=window, block_q=64, block_k=64)
    want = ref.packed_attention_ref(q, k, v, causal=causal, window=window,
                                    seg_q=seg, seg_k=seg)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_packed_flash_attention_respects_boundaries():
    """Tokens must not attend across packing boundaries: identical segment
    content -> identical outputs regardless of what is packed after it."""
    B, S, H, D = 1, 128, 2, 32
    key = jax.random.PRNGKey(0)
    q, k, v = _mk_qkv(key, B, S, H, H, D, jnp.float32)
    seg = jnp.asarray(np.r_[np.ones(64), np.full(64, 2)].astype(np.int32))[None]
    out = ops.packed_flash_attention(q, k, v, segment_ids=seg,
                                     block_q=32, block_k=32)
    # replace the second segment with garbage; first segment output unchanged
    q2 = q.at[:, 64:].set(123.0)
    k2 = k.at[:, 64:].set(-7.0)
    v2 = v.at[:, 64:].set(0.5)
    out2 = ops.packed_flash_attention(q2, k2, v2, segment_ids=seg,
                                      block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(out[:, :64]),
                               np.asarray(out2[:, :64]), rtol=1e-5, atol=1e-5)


RWKV_CASES = [
    (1, 64, 2, 32, 32, jnp.float32),
    (2, 128, 4, 64, 32, jnp.float32),
    (1, 96, 2, 64, 48, jnp.float32),                 # non-pow2 seq/chunk
    (1, 64, 2, 32, 64, jnp.bfloat16),
]


@pytest.mark.parametrize("B,S,H,M,chunk,dtype", RWKV_CASES)
def test_rwkv6_scan(B, S, H, M, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    r = jax.random.normal(ks[0], (B, S, H, M)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, H, M)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, H, M)).astype(dtype)
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, M))).astype(dtype)
    u = (jax.random.normal(ks[4], (H, M)) * 0.1).astype(dtype)
    y, s = ops.rwkv6_scan(r, k, v, w, u, chunk=chunk)
    y_ref, s_ref = ref.rwkv6_scan_ref(r, k, v, w, u)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=tol, atol=tol)


MAMBA_CASES = [
    (1, 64, 64, 8, 32, 32, jnp.float32),
    (2, 128, 128, 16, 64, 64, jnp.float32),
    (1, 96, 64, 16, 48, 32, jnp.float32),
    (1, 64, 128, 16, 32, 128, jnp.bfloat16),
]


@pytest.mark.parametrize("B,S,di,N,chunk,c_blk,dtype", MAMBA_CASES)
def test_mamba_scan(B, S, di, N, chunk, c_blk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(5), 6)
    u = jax.random.normal(ks[0], (B, S, di)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, di)) - 1).astype(dtype)
    B_t = jax.random.normal(ks[2], (B, S, N)).astype(dtype)
    C_t = jax.random.normal(ks[3], (B, S, N)).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[4], (di, N)) * 0.3)
    D = jax.random.normal(ks[5], (di,))
    y, _ = ops.mamba_scan(u, dt, B_t, C_t, A, D, chunk=chunk, c_blk=c_blk)
    y_ref, _ = ref.mamba_scan_ref(u, dt, B_t, C_t, A, D)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), rtol=tol, atol=tol)


# --------------------------------------------------------------------------- #
# Backwards: the fused Pallas custom-vjp kernels vs jax.grad of the oracles
# --------------------------------------------------------------------------- #
from repro.kernels import blocking                               # noqa: E402
from repro.kernels import packed_flash_attention as pfa          # noqa: E402


def _loss_through(fn):
    """Scalar loss with non-trivial cotangents at every output position."""
    def go(*args):
        y = fn(*args)
        return jnp.sum(jnp.sin(y.astype(jnp.float32)))
    return go


ATTN_GRAD_CASES = [
    # (B, S, H, KH, D, causal, window, dtype)
    (1, 64, 4, 2, 32, True, 0, jnp.float32),      # GQA
    (2, 64, 2, 2, 32, False, 0, jnp.float32),     # bidirectional
    (1, 96, 2, 1, 32, True, 48, jnp.float32),     # MQA, window spans 32-blocks
    (1, 127, 2, 2, 32, True, 0, jnp.float32),     # prime length (pad path)
    (1, 64, 2, 2, 32, True, 0, jnp.bfloat16),
]


@pytest.mark.parametrize("B,S,H,KH,D,causal,window,dtype", ATTN_GRAD_CASES)
def test_attention_grad_matches_oracle(B, S, H, KH, D, causal, window, dtype):
    q, k, v = _mk_qkv(jax.random.PRNGKey(11), B, S, H, KH, D, dtype)
    seg = _segments(jax.random.PRNGKey(13), B, S, n_seg=2)

    def f_pallas(q, k, v):
        return ops.packed_flash_attention(q, k, v, segment_ids=seg,
                                          causal=causal, window=window,
                                          block_q=32, block_k=32)

    def f_ref(q, k, v):
        return ref.packed_attention_ref(q, k, v, causal=causal, window=window,
                                        seg_q=seg, seg_k=seg)

    got = jax.grad(_loss_through(f_pallas), argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(_loss_through(f_ref), argnums=(0, 1, 2))(q, k, v)
    tol = 5e-2 if dtype == jnp.bfloat16 else 5e-4
    for g, w, name in zip(got, want, ("dq", "dk", "dv")):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32),
                                   rtol=tol, atol=tol, err_msg=name)


def test_attention_bwd_fully_masked_query_tile():
    """A query tile whose segment id matches no key exercises the l > 0
    guard: exact-zero outputs and exact-zero dq for those rows, finite
    gradients everywhere, and agreement with the oracle."""
    B, KH, G, S, D = 1, 2, 1, 64, 32
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (B, KH, G, S, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, KH, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, KH, S, D), jnp.float32)
    # first 32 queries live in a segment no key belongs to -> with
    # block_q=32 the whole first query tile is fully masked
    seg_q = jnp.asarray(np.r_[np.full(32, 7), np.ones(32)].astype(np.int32))[None]
    seg_k = jnp.ones((B, S), jnp.int32)

    def f(q, k, v):
        return pfa.packed_flash_attention_bkgsd(
            q, k, v, seg_q, seg_k, causal=True, window=0,
            block_q=32, block_k=32, interpret=True)

    y = f(q, k, v)
    np.testing.assert_array_equal(np.asarray(y[:, :, :, :32]), 0.0)
    dq, dk, dv = jax.grad(_loss_through(f), argnums=(0, 1, 2))(q, k, v)
    for g in (dq, dk, dv):
        assert np.all(np.isfinite(np.asarray(g)))
    np.testing.assert_array_equal(np.asarray(dq[:, :, :, :32]), 0.0)

    # the oracle agrees on the surviving rows' gradients
    def f_ref(q, k, v):
        qf = q.transpose(0, 3, 1, 2, 4).reshape(B, S, KH * G, D)
        kf = k.transpose(0, 2, 1, 3)
        vf = v.transpose(0, 2, 1, 3)
        return ref.packed_attention_ref(qf, kf, vf, causal=True,
                                        seg_q=seg_q, seg_k=seg_k)

    dq_ref, dk_ref, dv_ref = jax.grad(
        _loss_through(f_ref), argnums=(0, 1, 2))(q, k, v)
    np.testing.assert_allclose(np.asarray(dq), np.asarray(dq_ref),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dk_ref),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(dv), np.asarray(dv_ref),
                               rtol=5e-4, atol=5e-4)


def test_gqa_kv_head_mapping():
    """ops.py regression: query head h must read kv head h // G.  With
    uniform attention and per-kv-head constant values, head h's output is
    exactly its kv head's constant."""
    B, S, KH, G, D = 1, 32, 2, 2, 16
    H = KH * G
    q = jnp.zeros((B, S, H, D))
    k = jnp.zeros((B, S, KH, D))
    v = jnp.broadcast_to(
        jnp.arange(1, KH + 1, dtype=jnp.float32)[None, None, :, None],
        (B, S, KH, D))
    out = ops.packed_flash_attention(q, k, v, block_q=16, block_k=16)
    want = jnp.repeat(jnp.arange(1, KH + 1, dtype=jnp.float32), G)
    np.testing.assert_allclose(
        np.asarray(out),
        np.broadcast_to(np.asarray(want)[None, None, :, None], out.shape),
        rtol=1e-6, atol=1e-6)
    # and on random inputs the full H != KH path matches the oracle
    q, k, v = _mk_qkv(jax.random.PRNGKey(2), 2, 64, 8, 2, 32, jnp.float32)
    got = ops.packed_flash_attention(q, k, v, block_q=32, block_k=32)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.packed_attention_ref(q, k, v)),
        rtol=2e-5, atol=2e-5)


def test_pick_block_prime_lengths_no_extra_grid_steps():
    """The shared pad helper must never add a whole extra block: the grid
    runs exactly ceil(s / b) steps even for prime lengths."""
    for s in (1, 63, 64, 96, 127, 257, 509):
        for tgt in (32, 64, 128, 512):
            b, padded = blocking.pick_block(s, tgt)
            assert 1 <= b <= max(1, tgt) and padded >= s
            assert padded % b == 0
            assert padded // b == -(-s // b), (s, tgt, b, padded)


MAMBA_GRAD_CASES = [
    (1, 64, 32, 8, 32, 32),
    (2, 67, 24, 8, 32, 16),        # prime seq, non-multiple channels
    (1, 32, 17, 4, 16, 8),
]


@pytest.mark.parametrize("B,S,di,N,chunk,c_blk", MAMBA_GRAD_CASES)
def test_mamba_grad_matches_oracle(B, S, di, N, chunk, c_blk):
    ks = jax.random.split(jax.random.PRNGKey(21), 6)
    u = jax.random.normal(ks[0], (B, S, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, di)) - 1)
    B_t = jax.random.normal(ks[2], (B, S, N))
    C_t = jax.random.normal(ks[3], (B, S, N))
    A = -jnp.exp(jax.random.normal(ks[4], (di, N)) * 0.3)
    D = jax.random.normal(ks[5], (di,))

    def f_pallas(u, dt, B_t, C_t, A, D):
        y, _ = ops.mamba_scan(u, dt, B_t, C_t, A, D, chunk=chunk, c_blk=c_blk)
        return y

    def f_ref(u, dt, B_t, C_t, A, D):
        y, _ = ref.mamba_scan_ref(u, dt, B_t, C_t, A, D)
        return y

    args = (u, dt, B_t, C_t, A, D)
    got = jax.grad(_loss_through(f_pallas), argnums=tuple(range(6)))(*args)
    want = jax.grad(_loss_through(f_ref), argnums=tuple(range(6)))(*args)
    for g, w, name in zip(got, want, ("du", "ddt", "dB", "dC", "dA", "dD")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-3, atol=1e-3, err_msg=name)


@pytest.mark.parametrize("B,S,H,M,chunk", [(1, 64, 2, 32, 32),
                                           (2, 61, 2, 16, 16)])
def test_rwkv6_grad_matches_oracle(B, S, H, M, chunk):
    """Gradients through y AND the final state (the s_final cotangent
    seeds the adjoint state at the last chunk)."""
    ks = jax.random.split(jax.random.PRNGKey(23), 5)
    r = jax.random.normal(ks[0], (B, S, H, M))
    k = jax.random.normal(ks[1], (B, S, H, M))
    v = jax.random.normal(ks[2], (B, S, H, M))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, M)))
    u = jax.random.normal(ks[4], (H, M)) * 0.1

    def loss(fn):
        def go(r, k, v, w, u):
            y, s = fn(r, k, v, w, u)
            return (jnp.sum(jnp.sin(y.astype(jnp.float32)))
                    + jnp.sum(jnp.cos(s.astype(jnp.float32))))
        return go

    args = (r, k, v, w, u)
    got = jax.grad(loss(lambda *a: ops.rwkv6_scan(*a, chunk=chunk)),
                   argnums=tuple(range(5)))(*args)
    want = jax.grad(loss(ref.rwkv6_scan_ref), argnums=tuple(range(5)))(*args)
    for g, wv, name in zip(got, want, ("dr", "dk", "dv", "dw", "du")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(wv),
                                   rtol=1e-3, atol=1e-3, err_msg=name)


def test_model_grad_through_pallas_impls():
    """End-to-end: jax.grad through a hybrid model with
    attn_impl/ssm_impl = "pallas" matches the reference impls."""
    from repro.common.types import ModelConfig
    from repro.models import model as model_lib
    from repro.models.model import FwdCtx

    cfg = ModelConfig(name="t", family="hybrid", n_layers=3, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
                      layer_pattern=("attention", "mamba", "rwkv6"),
                      rwkv_head_dim=32, ssm_d_state=8,
                      dtype="float32", param_dtype="float32")
    params = model_lib.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 1, 64)

    def loss(params, ctx):
        out, _, _ = model_lib.forward(params, cfg, tokens=toks, ctx=ctx)
        return jnp.mean(jnp.sin(out.astype(jnp.float32)))

    ctx_p = FwdCtx(mode="train", attn_impl="pallas", ssm_impl="pallas",
                   attn_block=32)
    ctx_r = FwdCtx(mode="train", attn_impl="naive", ssm_impl="xla")
    g_p = jax.grad(loss)(params, ctx_p)
    g_r = jax.grad(loss)(params, ctx_r)
    flat_p, _ = jax.tree_util.tree_flatten_with_path(g_p)
    flat_r, _ = jax.tree_util.tree_flatten_with_path(g_r)
    for (path, a), (_, b) in zip(flat_p, flat_r):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=2e-3, atol=2e-3, err_msg=jax.tree_util.keystr(path))
