"""Pallas kernel validation: shape/dtype sweeps vs. pure-jnp oracles.

Kernels execute in interpret mode on CPU (the exact TPU program, run
op-by-op) and must match ``repro.kernels.ref`` to float tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

jax.config.update("jax_enable_x64", False)


def _mk_qkv(key, B, S, H, KH, D, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype=jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KH, D), dtype=jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KH, D), dtype=jnp.float32)
    return q.astype(dtype), k.astype(dtype), v.astype(dtype)


def _segments(key, B, S, n_seg):
    """Contiguous segments 1..n_seg (0 = padding tail)."""
    lens = jax.random.randint(key, (B, n_seg), 1, max(2, S // n_seg + 1))
    seg = np.zeros((B, S), np.int32)
    for b in range(B):
        cur = 0
        for i, L in enumerate(np.asarray(lens)[b]):
            L = int(L)
            seg[b, cur:cur + L] = i + 1
            cur += L
            if cur >= S:
                break
    return jnp.asarray(seg)


ATTN_CASES = [
    # (B, S, H, KH, D, causal, window, dtype)
    (1, 128, 4, 4, 64, True, 0, jnp.float32),
    (2, 256, 8, 2, 64, True, 0, jnp.float32),       # GQA
    (2, 128, 4, 1, 64, True, 0, jnp.float32),       # MQA
    (1, 256, 4, 4, 128, True, 64, jnp.float32),     # sliding window
    (2, 128, 4, 2, 64, False, 0, jnp.float32),      # bidirectional (encoder)
    (1, 128, 4, 2, 64, True, 0, jnp.bfloat16),
    (1, 96, 2, 2, 32, True, 0, jnp.float32),        # non-pow2 seq
]


@pytest.mark.parametrize("B,S,H,KH,D,causal,window,dtype", ATTN_CASES)
def test_packed_flash_attention(B, S, H, KH, D, causal, window, dtype):
    key = jax.random.PRNGKey(42)
    q, k, v = _mk_qkv(key, B, S, H, KH, D, dtype)
    seg = _segments(jax.random.PRNGKey(7), B, S, n_seg=3)
    got = ops.packed_flash_attention(q, k, v, segment_ids=seg, causal=causal,
                                     window=window, block_q=64, block_k=64)
    want = ref.packed_attention_ref(q, k, v, causal=causal, window=window,
                                    seg_q=seg, seg_k=seg)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_packed_flash_attention_respects_boundaries():
    """Tokens must not attend across packing boundaries: identical segment
    content -> identical outputs regardless of what is packed after it."""
    B, S, H, D = 1, 128, 2, 32
    key = jax.random.PRNGKey(0)
    q, k, v = _mk_qkv(key, B, S, H, H, D, jnp.float32)
    seg = jnp.asarray(np.r_[np.ones(64), np.full(64, 2)].astype(np.int32))[None]
    out = ops.packed_flash_attention(q, k, v, segment_ids=seg,
                                     block_q=32, block_k=32)
    # replace the second segment with garbage; first segment output unchanged
    q2 = q.at[:, 64:].set(123.0)
    k2 = k.at[:, 64:].set(-7.0)
    v2 = v.at[:, 64:].set(0.5)
    out2 = ops.packed_flash_attention(q2, k2, v2, segment_ids=seg,
                                      block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(out[:, :64]),
                               np.asarray(out2[:, :64]), rtol=1e-5, atol=1e-5)


RWKV_CASES = [
    (1, 64, 2, 32, 32, jnp.float32),
    (2, 128, 4, 64, 32, jnp.float32),
    (1, 96, 2, 64, 48, jnp.float32),                 # non-pow2 seq/chunk
    (1, 64, 2, 32, 64, jnp.bfloat16),
]


@pytest.mark.parametrize("B,S,H,M,chunk,dtype", RWKV_CASES)
def test_rwkv6_scan(B, S, H, M, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    r = jax.random.normal(ks[0], (B, S, H, M)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, H, M)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, H, M)).astype(dtype)
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, M))).astype(dtype)
    u = (jax.random.normal(ks[4], (H, M)) * 0.1).astype(dtype)
    y, s = ops.rwkv6_scan(r, k, v, w, u, chunk=chunk)
    y_ref, s_ref = ref.rwkv6_scan_ref(r, k, v, w, u)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=tol, atol=tol)


MAMBA_CASES = [
    (1, 64, 64, 8, 32, 32, jnp.float32),
    (2, 128, 128, 16, 64, 64, jnp.float32),
    (1, 96, 64, 16, 48, 32, jnp.float32),
    (1, 64, 128, 16, 32, 128, jnp.bfloat16),
]


@pytest.mark.parametrize("B,S,di,N,chunk,c_blk,dtype", MAMBA_CASES)
def test_mamba_scan(B, S, di, N, chunk, c_blk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(5), 6)
    u = jax.random.normal(ks[0], (B, S, di)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, di)) - 1).astype(dtype)
    B_t = jax.random.normal(ks[2], (B, S, N)).astype(dtype)
    C_t = jax.random.normal(ks[3], (B, S, N)).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[4], (di, N)) * 0.3)
    D = jax.random.normal(ks[5], (di,))
    y, _ = ops.mamba_scan(u, dt, B_t, C_t, A, D, chunk=chunk, c_blk=c_blk)
    y_ref, _ = ref.mamba_scan_ref(u, dt, B_t, C_t, A, D)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), rtol=tol, atol=tol)
