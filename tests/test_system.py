"""End-to-end system behaviour tests: DFLOP profile -> plan -> schedule ->
train, loss decreases, packed-vs-unpacked equivalence, decode==train."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.common.types import ModelConfig
from repro.core.engine import DFLOPEngine
from repro.core.optimizer.space import (ClusterSpec, ModuleParallelism,
                                        ParallelismPlan)
from repro.data.loader import ScheduledLoader
from repro.data.synthetic import MixedDataset
from repro.models import model as model_lib
from repro.models.model import FwdCtx
from repro.train.optim import AdamWConfig, adamw_init
from repro.train.step import make_train_step

CFG = ModelConfig(name="sys", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                  dtype="float32")


def test_full_pipeline_trains_and_loss_decreases():
    ds = MixedDataset("text", seed=0, tokens_per_media_item=4)
    eng = DFLOPEngine(llm_cfg=CFG, cluster=ClusterSpec(8, 8),
                      tokens_per_media_item=4).profile(ds)
    res = eng.plan(gbs=32)
    assert res.found
    plan = ParallelismPlan(llm=ModuleParallelism(1, 1, 1), n_mb=2)
    sched = eng.scheduler(plan=plan, adaptive=False, ilp_time_limit_s=0.05)
    loader = ScheduledLoader(ds, sched, gbs=8, token_budget=256,
                             vocab_size=CFG.vocab_size, prefetch=True)
    params = model_lib.init(jax.random.PRNGKey(0), CFG)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(CFG, AdamWConfig(lr=3e-3),
                                   ctx=FwdCtx(mode="train",
                                              attn_impl="chunked")))
    it = iter(loader)
    losses = []
    for _ in range(12):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt, m = step(params, opt, batch, 3e-3)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-4:]) < np.mean(losses[:4])


def test_packed_equals_unpacked_forward():
    """Packing with segment masking must reproduce per-sequence outputs."""
    params = model_lib.init(jax.random.PRNGKey(0), CFG)
    rng = np.random.default_rng(0)
    a = rng.integers(1, CFG.vocab_size, 10)
    b = rng.integers(1, CFG.vocab_size, 6)
    ctx = FwdCtx(mode="train", attn_impl="chunked", remat=False)
    # separate
    la, _, _ = model_lib.forward(params, CFG, tokens=jnp.asarray(a)[None],
                                 ctx=ctx)
    lb, _, _ = model_lib.forward(params, CFG, tokens=jnp.asarray(b)[None],
                                 ctx=ctx)
    # packed
    toks = np.zeros(16, np.int32)
    toks[:10], toks[10:16] = a, b
    seg = np.r_[np.full(10, 1), np.full(6, 2)].astype(np.int32)
    pos = np.r_[np.arange(10), np.arange(6)].astype(np.int32)
    lp, _, _ = model_lib.forward(params, CFG, tokens=jnp.asarray(toks)[None],
                                 segment_ids=jnp.asarray(seg)[None],
                                 positions=jnp.asarray(pos)[None], ctx=ctx)
    np.testing.assert_allclose(np.asarray(lp[0, :10]), np.asarray(la[0]),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(lp[0, 10:16]), np.asarray(lb[0]),
                               rtol=2e-3, atol=2e-3)


def test_async_scheduling_overlap():
    """submit/collect must produce the same partition as synchronous."""
    ds = MixedDataset("mixed", seed=3, tokens_per_media_item=16)
    eng = DFLOPEngine(llm_cfg=CFG, cluster=ClusterSpec(8, 8),
                      tokens_per_media_item=16).profile(ds)
    plan = ParallelismPlan(llm=ModuleParallelism(1, 1, 2), n_mb=2)
    # small instance + generous limit -> both solves reach the optimum, so
    # sync and async results are comparable despite wall-clock cutoffs
    sched = eng.scheduler(plan=plan, adaptive=False, ilp_time_limit_s=2.0)
    items = ds.sample(10)
    sync = sched.schedule(items)
    sched.submit(items)
    a = sched.collect()
    assert a is not None
    assert sorted(i for g in a.groups for i in g) == list(range(10))
    np.testing.assert_allclose(a.cmax, sync.cmax, rtol=1e-6)
