"""Adaptive Correction tests (paper §3.4.3 / Fig. 15)."""
import numpy as np

from repro.core.scheduler.adaptive import AdaptiveCorrection


def test_learns_systematic_deviation():
    corr = AdaptiveCorrection(window=1000)
    # shape bucket 1024 consistently runs 1.5x slower than predicted
    for _ in range(10):
        corr.observe("llm", 1000.0, predicted_dur=1.0, actual_dur=1.5)
    assert abs(corr.correct("llm", 1000.0, 2.0) - 3.0) < 1e-6
    # other buckets untouched
    assert corr.correct("llm", 64.0, 2.0) == 2.0


def test_small_deviations_not_applied():
    corr = AdaptiveCorrection(window=1000, deviation_threshold=0.05)
    for _ in range(10):
        corr.observe("llm", 1000.0, 1.0, 1.02)
    assert corr.correct("llm", 1000.0, 2.0) == 2.0


def test_cost_benefit_deactivation():
    """When observed deviations stay below the monitoring cost, the tracker
    turns itself off (Fig. 15's negative-net-speedup region)."""
    corr = AdaptiveCorrection(monitoring_cost=0.04, window=32)
    for _ in range(64):
        corr.observe("llm", 512.0, 1.0, 1.01)   # 1% anomaly < 4% cost
    assert not corr.enabled
    # high-anomaly workload keeps it on
    corr2 = AdaptiveCorrection(monitoring_cost=0.04, window=32)
    for _ in range(64):
        corr2.observe("llm", 512.0, 1.0, 1.5)
    assert corr2.enabled
    assert corr2.net_speedup() > 0


def test_probe_reenables_after_deviations_return():
    """Deactivation is not one-way: after `probe_interval` quiet
    observations a probe window re-runs the cost-benefit test and turns
    the tracker back on when deviations are large again."""
    corr = AdaptiveCorrection(monitoring_cost=0.04, window=32,
                              probe_interval=64, probe_window=8)
    for _ in range(32):
        corr.observe("llm", 512.0, 1.0, 1.005)   # negligible deviations
    assert not corr.enabled
    # deviations return while the tracker is off; the probe must catch them
    for _ in range(64 + 8):
        corr.observe("llm", 2048.0, 1.0, 1.6)
    assert corr.enabled
    assert not corr.probing
    # and the re-enabled tracker learns the new bucket's correction
    assert corr.correct("llm", 2048.0, 1.0) > 1.5


def test_probe_stays_off_when_deviations_stay_small():
    corr = AdaptiveCorrection(monitoring_cost=0.04, window=32,
                              probe_interval=64, probe_window=8)
    for _ in range(32):
        corr.observe("llm", 512.0, 1.0, 1.005)
    assert not corr.enabled
    for _ in range(64 + 8):
        corr.observe("llm", 512.0, 1.0, 1.005)   # still quiet: probe closes
    assert not corr.enabled


def test_bucketing_is_logarithmic():
    assert AdaptiveCorrection.bucket(1000) == AdaptiveCorrection.bucket(1100)
    assert AdaptiveCorrection.bucket(1000) != AdaptiveCorrection.bucket(3000)
