"""Profiling Engine tests (paper §3.2): interpolation + data profiler."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.common.types import ModelConfig
from repro.core.profiling.analytic import AnalyticBackend, V5E
from repro.core.profiling.data_profiler import DataProfiler
from repro.core.profiling.flops import module_flops
from repro.core.profiling.interpolation import GridInterpolator
from repro.core.profiling.model_profiler import ModelProfiler
from repro.data.items import DataItem
from repro.data.synthetic import MixedDataset


def test_interpolator_exact_on_grid():
    ax = [np.array([1.0, 2.0, 4.0]), np.array([1.0, 8.0])]
    vals = np.arange(6, dtype=float).reshape(3, 2)
    g = GridInterpolator(ax, vals)
    for i, a in enumerate(ax[0]):
        for j, b in enumerate(ax[1]):
            np.testing.assert_allclose(g(a, b), vals[i, j])


def test_interpolator_linear_between_points():
    g = GridInterpolator([np.array([0.0, 10.0])], np.array([0.0, 100.0]))
    np.testing.assert_allclose(g(2.5), 25.0)


@given(st.floats(-100, 1000))
@settings(max_examples=100, deadline=None)
def test_interpolator_clamped_extrapolation(x):
    g = GridInterpolator([np.array([1.0, 2.0, 3.0])],
                         np.array([5.0, 7.0, 6.0]))
    v = g(x)
    assert 5.0 - 1e-9 <= v <= 7.0 + 1e-9


@given(st.lists(st.tuples(st.floats(0, 50), st.floats(0, 50)),
                min_size=2, max_size=6, unique_by=lambda t: t[0]))
@settings(max_examples=50, deadline=None)
def test_interpolator_within_hull(pts):
    pts = sorted(pts)
    xs = np.array([p[0] for p in pts])
    if np.any(np.diff(xs) <= 0):
        return
    ys = np.array([p[1] for p in pts])
    g = GridInterpolator([xs], ys)
    q = (xs[0] + xs[-1]) / 2
    assert ys.min() - 1e-6 <= g(q) <= ys.max() + 1e-6


def test_flops_split_attention_vs_linear():
    """Attention FLOPs scale ~quadratically with seq, linear FLOPs
    linearly — the distinction §3.2.1 profiles separately."""
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=256,
                      n_heads=4, n_kv_heads=4, d_ff=1024, vocab_size=1000)
    f1 = module_flops(cfg, 1, 1024)
    f2 = module_flops(cfg, 1, 2048)
    assert 3.5 < f2.attn / f1.attn < 4.5          # ~s^2
    assert 1.9 < f2.lin / f1.lin < 2.1            # ~s


def test_profiler_duration_monotone_in_shape():
    cfg = ModelConfig(name="t", family="dense", n_layers=4, d_model=512,
                      n_heads=8, n_kv_heads=8, d_ff=2048, vocab_size=32000)
    prof = ModelProfiler(AnalyticBackend(V5E))
    mp = prof.profile_llm(cfg)
    durs = [mp.duration(1, s, 4) for s in (512, 1024, 4096, 16384)]
    assert all(a < b for a, b in zip(durs, durs[1:]))


def test_fig2_effect_tp_efficiency_drops_at_small_shapes():
    """The paper's Fig. 2: per-chip efficiency at tp=16 is worse for small
    effective batches than large ones."""
    enc = ModelConfig(name="e", family="vlm-enc", n_layers=12, d_model=768,
                      n_heads=12, n_kv_heads=12, d_ff=3072, vocab_size=0,
                      causal=False, has_lm_head=False)
    b = AnalyticBackend(V5E)
    eff = {bs: b.throughput(enc, bs, 729, 16) / 16 /
           b.throughput(enc, bs, 729, 1) for bs in (1, 64)}
    assert eff[1] < eff[64] + 1e-9


def test_data_profiler_shapes_and_mean():
    items = [DataItem(2, 100), DataItem(4, 300)]
    dp = DataProfiler(tokens_per_media_item=10)
    dist = dp.profile(items)
    np.testing.assert_allclose(dist.mean(), (3.0, (120 + 340) / 2))


def test_data_profiler_architecture_dependence():
    """Same dataset, different connector budgets -> different distributions
    (§3.2.2's point)."""
    ds = MixedDataset("mixed", seed=0)
    d1 = DataProfiler(49).profile_sampler(ds, 512)
    ds2 = MixedDataset("mixed", seed=0)
    d2 = DataProfiler(196).profile_sampler(ds2, 512)
    assert d2.mean()[1] > d1.mean()[1]


def test_mixture_heterogeneity_ordering():
    """Fig. 11b: mixed/video datasets are more heterogeneous than
    multi-image."""
    cvs = {}
    for mix in ("multi_image", "video", "mixed"):
        ds = MixedDataset(mix, seed=1)
        cvs[mix] = DataProfiler(196).profile_sampler(ds, 2048).heterogeneity()
    assert cvs["mixed"] > cvs["multi_image"]
