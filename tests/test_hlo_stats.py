"""HLO analyzer tests — the roofline's measurement backbone."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_stats import analyze, shape_bytes


def test_shape_bytes():
    assert shape_bytes("f32[4,8]{1,0}") == 128
    assert shape_bytes("bf16[10]") == 20
    assert shape_bytes("(s32[], f32[2,2]{1,0})") == 4 + 16
    assert shape_bytes("pred[7]") == 7


def test_dot_flops_simple():
    A = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    B = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    co = jax.jit(lambda a, b: a @ b).lower(A, B).compile()
    st = analyze(co.as_text())
    np.testing.assert_allclose(st.flops, 2 * 64 * 128 * 32)


def test_scan_trip_count_multiplies_flops():
    A = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def f(x):
        def body(c, _):
            return c @ c, None
        out, _ = jax.lax.scan(body, x, None, length=12)
        return out

    st = analyze(jax.jit(f).lower(A).compile().as_text())
    np.testing.assert_allclose(st.flops, 12 * 2 * 32 ** 3)
    assert 12 in st.while_trips.values()


def test_nested_scan_multiplies():
    A = jax.ShapeDtypeStruct((16, 16), jnp.float32)

    def f(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ c2, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=4)
        return out

    st = analyze(jax.jit(f).lower(A).compile().as_text())
    np.testing.assert_allclose(st.flops, 12 * 2 * 16 ** 3)


def test_hbm_bytes_positive_and_scaled():
    A = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    st1 = analyze(jax.jit(lambda x: x + 1).lower(A).compile().as_text())
    assert st1.hbm_bytes >= 2 * 256 * 256 * 4   # read + write at least
