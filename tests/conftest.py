import os
import sys

# Make `benchmarks.*` importable regardless of how pytest was invoked
# (the tier-1 command only puts src/ on PYTHONPATH).
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
