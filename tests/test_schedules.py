"""Schedule-family tests (docs/schedules.md): topology simulators pinned
op-for-op against their reference event loops, plan validation, joint
search, scheduler/composer threading, and the EDF + empty-window-metrics
bugfix regressions that ride along in the same PR."""
import time

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.common.types import ModelConfig
from repro.core.engine import DFLOPEngine
from repro.core.optimizer.space import (SCHEDULES, VIRTUAL_CHUNKS,
                                        ClusterSpec, ModuleParallelism,
                                        ParallelismPlan)
from repro.core.pipeline.simulator import (encoder_fill_topology,
                                           interleaved_topology,
                                           reference_schedule_times,
                                           simulate_1f1b_batch,
                                           simulate_bucket_ranks_batch,
                                           simulate_encoder_fill,
                                           simulate_interleaved,
                                           simulate_schedule_batch)
from repro.core.scheduler.online import ScheduleOutput, _solver_durations
from repro.data.composer import LookaheadComposer, edf_forced_count
from repro.data.items import DataItem
from repro.data.synthetic import MixedDataset
from repro.runtime.metrics import RollingStat, RuntimeMetrics, nan_to_none

ENC = ModelConfig(name="enc", family="vlm-enc", n_layers=12, d_model=512,
                  n_heads=8, n_kv_heads=8, d_ff=2048, vocab_size=0,
                  causal=False, use_rope=False, has_lm_head=False)
LLM = ModelConfig(name="llm", family="dense", n_layers=16, d_model=1024,
                  n_heads=16, n_kv_heads=4, d_ff=4096, vocab_size=32000)


@pytest.fixture(scope="module")
def engine():
    ds = MixedDataset("mixed", seed=0, tokens_per_media_item=64)
    eng = DFLOPEngine(llm_cfg=LLM, enc_cfg=ENC, e_seq_len=196,
                      cluster=ClusterSpec(16, 8, mem_bytes=80e9),
                      tokens_per_media_item=64)
    return eng.profile(ds)


# --------------------------------------------------------------------- #
# batched wavefront == reference event loop, op for op
# --------------------------------------------------------------------- #
@settings(max_examples=25, deadline=None)
@given(st.data())
def test_interleaved_batch_matches_reference_op_for_op(data):
    p = data.draw(st.integers(1, 4))
    m = p * data.draw(st.integers(1, 3))
    v = data.draw(st.integers(2, 3))
    seed = data.draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    fwd = rng.uniform(0.1, 3.0, (2, p, m))
    bwd = rng.uniform(0.1, 5.0, (2, p, m))
    tr = simulate_schedule_batch("interleaved", fwd, bwd, v=v,
                                 record_ops=True)
    topo = interleaved_topology(p, m, v)
    for b in range(2):
        start, end = reference_schedule_times(topo, fwd[b], bwd[b])
        np.testing.assert_array_equal(tr.op_start[b], start)
        np.testing.assert_array_equal(tr.op_end[b], end)
        assert tr.makespan[b] == end.max()


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_encoder_fill_batch_matches_reference_op_for_op(data):
    p = data.draw(st.integers(1, 4))
    m = data.draw(st.integers(1, 6))
    seed = data.draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    fwd = rng.uniform(0.1, 3.0, (2, p, m))
    bwd = rng.uniform(0.1, 5.0, (2, p, m))
    e_fwd = rng.uniform(0.01, 1.0, (2, p, m))
    e_bwd = rng.uniform(0.01, 2.0, (2, p, m))
    tr = simulate_schedule_batch("encoder_fill", fwd, bwd, e_fwd=e_fwd,
                                 e_bwd=e_bwd, record_ops=True)
    topo = encoder_fill_topology(p, m)
    for b in range(2):
        start, end = reference_schedule_times(topo, fwd[b], bwd[b],
                                              e_fwd[b], e_bwd[b])
        np.testing.assert_array_equal(tr.op_start[b], start)
        np.testing.assert_array_equal(tr.op_end[b], end)
        assert tr.makespan[b] == end.max()


def test_schedule_batch_1f1b_is_bitwise_identical():
    """schedule="1f1b" must BE the historical wavefront — same floats."""
    rng = np.random.default_rng(0)
    fwd = rng.uniform(0.1, 3.0, (4, 3, 6))
    bwd = rng.uniform(0.1, 5.0, (4, 3, 6))
    a = simulate_1f1b_batch(fwd, bwd, record_ops=True)
    b = simulate_schedule_batch("1f1b", fwd, bwd, record_ops=True)
    np.testing.assert_array_equal(a.makespan, b.makespan)
    np.testing.assert_array_equal(a.stage_busy, b.stage_busy)
    np.testing.assert_array_equal(a.f_end, b.f_end)
    np.testing.assert_array_equal(a.b_end, b.b_end)
    # ... and through the scheduler-bucket convention as well
    e_b = rng.uniform(0.1, 2.0, 8)
    l_b = rng.uniform(0.1, 2.0, 8)
    kw = dict(n_mb=4, dp=2, e_pp=1, l_pp=2)
    d = simulate_bucket_ranks_batch(e_b, l_b, **kw)
    s = simulate_bucket_ranks_batch(e_b, l_b, schedule="1f1b", **kw)
    np.testing.assert_array_equal(d.makespan, s.makespan)
    with pytest.raises(ValueError):
        simulate_schedule_batch("1f1b", fwd, bwd, e_fwd=fwd)


def test_interleaved_homogeneous_closed_form():
    """Homogeneous microbatches: makespan = (m + (p−1)/v) · (f + b)."""
    for p, mult, v, f, b in [(2, 1, 2, 1.0, 2.0), (4, 2, 2, 0.5, 1.5),
                             (3, 2, 3, 2.0, 2.0)]:
        m = p * mult
        tr = simulate_interleaved(np.full((p, m), f), np.full((p, m), b),
                                  v=v)
        expected = (m + (p - 1) / v) * (f + b)
        np.testing.assert_allclose(tr.makespan, expected, rtol=1e-12)
        # strictly better than plain 1F1B whenever there is a bubble
        if p > 1:
            plain = simulate_1f1b_batch(np.full((1, p, m), f),
                                        np.full((1, p, m), b))
            assert tr.makespan < float(plain.makespan[0])


def test_encoder_fill_zero_encoder_degenerates_to_1f1b():
    rng = np.random.default_rng(7)
    fwd = rng.uniform(0.1, 3.0, (3, 4, 8))
    bwd = rng.uniform(0.1, 5.0, (3, 4, 8))
    zero = np.zeros_like(fwd)
    ef = simulate_schedule_batch("encoder_fill", fwd, bwd, e_fwd=zero,
                                 e_bwd=zero)
    plain = simulate_1f1b_batch(fwd, bwd)
    np.testing.assert_array_equal(ef.makespan, plain.makespan)


def test_encoder_fill_fills_bubbles_below_serial_bound():
    """Homogeneous case: the encoder chunks ride inside the warmup/drain
    bubbles, so the makespan sits strictly between the LLM-only pipeline
    and the conservative fully-serial closed form."""
    p, m, f, b, ef, eb = 4, 8, 1.0, 2.0, 0.25, 0.5
    tr = simulate_encoder_fill(np.full((p, m), f), np.full((p, m), b),
                               np.full((p, m), ef), np.full((p, m), eb))
    llm_only = (m + p - 1) * (f + b)
    serial = (m + p - 1) * (f + b + ef + eb)
    assert llm_only < tr.makespan < serial


# --------------------------------------------------------------------- #
# plan axis: validation, bubble arithmetic, chip accounting
# --------------------------------------------------------------------- #
def test_plan_schedule_validation():
    lp = ModuleParallelism(2, 4, 1)
    with pytest.raises(ValueError, match="unknown schedule"):
        ParallelismPlan(llm=lp, n_mb=8, schedule="gpipe")
    with pytest.raises(ValueError, match="divisible"):
        ParallelismPlan(llm=lp, n_mb=6, schedule="interleaved")
    with pytest.raises(ValueError, match="depth >= 2"):
        ParallelismPlan(llm=ModuleParallelism(2, 1, 4), n_mb=4,
                        schedule="interleaved")
    with pytest.raises(ValueError, match="needs an encoder"):
        ParallelismPlan(llm=lp, n_mb=8, schedule="encoder_fill")
    with pytest.raises(ValueError, match="colocates"):
        ParallelismPlan(llm=lp, encoder=ModuleParallelism(1, 1, 1), n_mb=8,
                        schedule="encoder_fill")


def test_plan_schedule_properties():
    lp = ModuleParallelism(2, 4, 1)
    ep = ModuleParallelism(2, 1, 1)
    p1 = ParallelismPlan(llm=lp, encoder=ep, n_mb=8)
    pi = ParallelismPlan(llm=lp, n_mb=8, schedule="interleaved")
    pe = ParallelismPlan(llm=lp, encoder=ep, n_mb=8,
                         schedule="encoder_fill")
    assert (p1.pipeline_depth, p1.bubble_slots) == (5, 4)
    assert (pi.pipeline_depth, pi.bubble_slots) == (4, 3 / VIRTUAL_CHUNKS)
    # encoder_fill: the encoder holds no stages and occupies no extra chips
    assert (pe.pipeline_depth, pe.bubble_slots) == (4, 3)
    assert pe.chips == lp.chips and p1.chips == lp.chips + ep.chips
    # θ widens: the 8-tuple carries the family, so every as_tuple()
    # consumer (composer plan key, controller records, reshard reports)
    # distinguishes schedule-only plan changes
    assert p1.as_tuple()[-1] == "1f1b" and pe.as_tuple()[-1] == "encoder_fill"
    assert p1.as_tuple()[:-1] == pe.as_tuple()[:-1]


# --------------------------------------------------------------------- #
# joint search over the schedule axis
# --------------------------------------------------------------------- #
def test_search_single_family_restrictions(engine):
    for fam in SCHEDULES:
        res = engine.plan(gbs=32, schedules=(fam,))
        assert res.found, fam
        assert res.plan.schedule == fam
        if fam == "interleaved":
            assert res.plan.n_mb % res.plan.pipeline_depth == 0
            assert (res.plan.llm.pp * VIRTUAL_CHUNKS
                    <= engine.perf.llm.cfg.n_layers)
        if fam == "encoder_fill":
            lp = res.plan.llm
            assert res.plan.encoder == ModuleParallelism(lp.tp, 1, lp.dp)
            assert lp.chips == engine.cluster.n_chips


def test_search_joint_schedule_family(engine):
    base = engine.plan(gbs=32, schedules=("1f1b",))
    joint = engine.plan(gbs=32)          # the default IS the joint search
    assert base.plan.schedule == "1f1b"
    assert joint.found and joint.plan.schedule in SCHEDULES
    # the 1f1b-only winner stays in the joint candidate space, so the
    # joint optimum can only improve on (or match) it
    assert joint.makespan <= base.makespan * (1 + 1e-9)


# --------------------------------------------------------------------- #
# scheduler: solver durations + step_makespan across families
# --------------------------------------------------------------------- #
def test_solver_durations_encoder_fill_combines_serially():
    lp = ModuleParallelism(1, 4, 1)
    plan = ParallelismPlan(llm=lp, encoder=ModuleParallelism(1, 1, 1),
                           n_mb=4, schedule="encoder_fill")
    e = np.array([4.0, 8.0])
    l = np.array([1.0, 2.0])
    se, sl = _solver_durations(plan, e, l)
    np.testing.assert_allclose(se, l + e / 4)
    np.testing.assert_allclose(sl, se)   # max(Σc, Σc) degenerates to Σc
    # staged families keep the two module loads independent
    se, sl = _solver_durations(ParallelismPlan(llm=lp, n_mb=4), e, l)
    assert se is e and sl is l


def test_step_makespan_uses_family_bubble_slots():
    lp = ModuleParallelism(1, 4, 1)
    out = dict(groups=[], lower_bound=1.0, solver="lpt", elapsed_s=0.0,
               e_dur=np.zeros(1), l_dur=np.zeros(1))
    s1 = ScheduleOutput(cmax=2.0, plan=ParallelismPlan(llm=lp, n_mb=8),
                        **out)
    si = ScheduleOutput(cmax=2.0, plan=ParallelismPlan(
        llm=lp, n_mb=8, schedule="interleaved"), **out)
    assert s1.step_makespan == (8 + 3) * 2.0
    assert si.step_makespan == (8 + 3 / VIRTUAL_CHUNKS) * 2.0
    assert si.step_makespan < s1.step_makespan


def test_scheduler_balances_combined_load_under_encoder_fill(engine):
    plan = ParallelismPlan(llm=ModuleParallelism(1, 4, 2),
                           encoder=ModuleParallelism(1, 1, 2), n_mb=2,
                           schedule="encoder_fill")
    sched = engine.scheduler(plan=plan, adaptive=False,
                             ilp_time_limit_s=0.02)
    ds = MixedDataset("mixed", seed=3, tokens_per_media_item=64)
    out = sched.schedule(ds.sample(32))
    assert len(out.groups) == plan.n_buckets
    comb = out.l_dur + out.e_dur / plan.llm.pp
    loads = [comb[g].sum() for g in out.groups]
    assert np.isclose(out.cmax, max(loads))
    assert out.step_makespan >= out.cmax


# --------------------------------------------------------------------- #
# composer: schedule-only plan change must flush and re-price
# --------------------------------------------------------------------- #
class _CountingSched:
    mode = "train"

    def __init__(self, plan):
        self.plan = plan
        self.n_duration_calls = 0

    def set_plan(self, plan):
        self.plan = plan

    def item_durations(self, items, plan=None):
        self.n_duration_calls += 1
        e = np.array([it.encoder_batch() for it in items], float) + 0.1
        return e, np.array([it.llm_seq_len(4) for it in items], float)


def test_composer_reprices_on_schedule_only_plan_change():
    lp = ModuleParallelism(1, 2, 1)
    plan_a = ParallelismPlan(llm=lp, encoder=ModuleParallelism(1, 1, 1),
                             n_mb=2)
    plan_b = ParallelismPlan(llm=lp, encoder=ModuleParallelism(1, 1, 1),
                             n_mb=2, schedule="encoder_fill")
    assert plan_a.as_tuple() != plan_b.as_tuple()    # the widened θ key
    sched = _CountingSched(plan_a)
    comp = LookaheadComposer(sched, gbs=4, window=2)
    comp.push([DataItem(1 + i % 3, 16 + i, "single_image", i)
               for i in range(8)])
    comp.compose()
    assert sched.n_duration_calls == 1
    comp.compose()                       # survivors already priced
    comp.push([DataItem(2, 20, "single_image", 100 + i) for i in range(8)])
    assert sched.n_duration_calls == 1 or comp.pending == 0
    # schedule-only hot-swap, controller "forgot" flush_plan(): the
    # as_tuple() identity check must re-price the whole window anyway
    sched.set_plan(plan_b)
    before = sched.n_duration_calls
    comp.compose()
    assert sched.n_duration_calls == before + 1
    assert comp._plan_key == plan_b.as_tuple()
    # the explicit flush path keeps working too
    comp.flush_plan()
    assert comp.n_flushes == 1
    assert all(en.e < 0 for en in comp._entries)


# --------------------------------------------------------------------- #
# EDF reservation: O(n) allocation regardless of slack magnitude
# --------------------------------------------------------------------- #
def _edf_naive(slack, per_step):
    slack = np.maximum(np.asarray(slack, dtype=np.int64), 0)
    best = 0
    for j in range(int(slack.max()) + 1):
        best = max(best, int((slack <= j).sum()) - j * per_step)
    return max(best, 0)


def test_edf_forced_count_large_slack_no_giant_allocation():
    """Regression: np.bincount over raw slack allocated O(max slack) —
    one relaxed deadline (slack ~1e9) meant gigabytes.  The horizon clip
    must keep this instant and exact."""
    t0 = time.monotonic()
    assert edf_forced_count([0, 10 ** 9], per_step=1) == 1
    assert edf_forced_count([0, 0, 10 ** 12, 10 ** 12], per_step=1) == 2
    assert edf_forced_count([10 ** 9] * 8, per_step=2) == 0
    assert edf_forced_count([0, 1, 10 ** 9, -5], per_step=0) == 4
    assert time.monotonic() - t0 < 1.0


def test_edf_forced_count_horizon_clip_is_exact():
    rng = np.random.default_rng(11)
    for _ in range(200):
        n = int(rng.integers(1, 12))
        per_step = int(rng.integers(1, 5))
        slack = rng.integers(-2, 10, n)
        assert edf_forced_count(slack, per_step) == \
            _edf_naive(slack, per_step)
        # adding huge-slack entries must not change the forced count when
        # they land beyond the forcing horizon
        fat = np.concatenate([slack, [10 ** 9, 10 ** 10]])
        assert edf_forced_count(fat, per_step) == \
            _edf_naive(np.minimum(fat, 64), per_step)


class _FakePricer:
    def base(self, r):
        return r.cost, r.cost, r.seq

    def price(self, r):
        return r.cost

    def predict(self, r, s_pad):
        return r.cost

    def decode_estimate(self, r):
        return 0.0


class _FakeReq:
    def __init__(self, rid, deadline_s, cost=1.0, seq=64):
        self.rid = rid
        self.arrival_s = 0.0
        self.cost = cost
        self.seq = seq
        self._deadline = deadline_s

    def slack_s(self, now_s, work_s):
        return self._deadline - now_s - work_s


def test_slo_admission_survives_relaxed_deadlines():
    """Serving-side consumer of the EDF fix: requests with effectively
    unbounded SLOs (slack ~1e9 admission rounds) must not blow up the
    reservation, and the due request still ships first."""
    from repro.serve.admission import SLOAdmission
    adm = SLOAdmission(_FakePricer())
    # request 0 is deadline-feasible but due now (slack < one admission
    # round); the rest have effectively unbounded SLOs
    pending = [_FakeReq(0, deadline_s=1.2)] + \
        [_FakeReq(i, deadline_s=1e9, seq=64 + i) for i in range(1, 12)]
    t0 = time.monotonic()
    picked = adm.select(pending, now_s=0.0, max_batch=4)
    assert time.monotonic() - t0 < 1.0
    assert len(picked) == 4
    assert any(r.rid == 0 for r in picked)
    assert adm.last_n_forced >= 1


# --------------------------------------------------------------------- #
# empty-window metrics: NaN, rendered as absent — never a fake 0.0
# --------------------------------------------------------------------- #
def test_rolling_stat_empty_window_is_nan_not_zero():
    s = RollingStat()
    assert np.isnan(s.mean()) and np.isnan(s.max())
    assert np.isnan(s.last()) and np.isnan(s.quantile(0.99))
    s.add(0.0)                           # a measured zero is a real value
    assert s.mean() == 0.0 and s.last() == 0.0 and s.quantile(0.5) == 0.0
    assert nan_to_none(float("nan")) is None
    assert nan_to_none(0.0) == 0.0 and nan_to_none(7) == 7


def test_metrics_snapshot_reports_missing_stats_as_none():
    import json
    m = RuntimeMetrics()
    snap = m.snapshot()
    assert snap["imbalance_mean"] is None
    assert snap["step_time_mean_s"] is None
    assert snap["serve"]["latency_p99_s"] is None
    json.dumps(snap)                     # strictly JSON-serializable
    assert "NaN" not in json.dumps(snap)
    m.record_step(step_time_s=2.0, idle_s=0.5)
    snap = m.snapshot()
    assert snap["step_time_mean_s"] == 2.0
    assert snap["bubble_fraction_mean"] == pytest.approx(0.25)


def test_serve_report_row_maps_nan_to_none():
    from repro.serve.engine import ServeReport
    rep = ServeReport(policy="fifo", n_requests=4, n_completed=0,
                      n_slo_met=0, makespan_s=1.0, goodput_rps=0.0,
                      throughput_rps=0.0, p50_latency_s=float("nan"),
                      p99_latency_s=float("nan"), mean_ttft_s=float("nan"),
                      mean_queue_depth=2.0, mean_occupancy=float("nan"),
                      n_prefill_batches=1, n_decode_steps=0,
                      n_drift_events=0, n_compiles=1)
    row = rep.row()
    assert row["p99_latency_s"] is None and row["mean_ttft_s"] is None
    assert row["mean_queue_depth"] == 2.0 and row["n_completed"] == 0


# --------------------------------------------------------------------- #
# bench snapshots: --check schema validation + fig20 smoke/acceptance
# --------------------------------------------------------------------- #
def _bench_snapshot_module():
    import importlib.util
    from pathlib import Path
    path = Path(__file__).resolve().parent.parent / "tools" / \
        "bench_snapshot.py"
    spec = importlib.util.spec_from_file_location("bench_snapshot", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_bench_snapshot_check_passes_on_committed_files():
    mod = _bench_snapshot_module()
    assert mod.check() == []


def test_bench_snapshot_check_rejects_bad_snapshots(tmp_path, monkeypatch):
    mod = _bench_snapshot_module()
    monkeypatch.setattr(mod, "REPO", tmp_path)
    probs = mod.check(["BENCH_train.json"])
    assert probs and "missing" in probs[0]
    (tmp_path / "BENCH_train.json").write_text(
        '{"git": "abc", "figures": {"fig20": {"module": "m", "args": {}, '
        '"wall_s": 1.0, "headline": [{"sim_speedup": NaN}]}}}')
    probs = mod.check(["BENCH_train.json"])
    assert probs and "non-finite" in probs[0]
    (tmp_path / "BENCH_train.json").write_text(
        '{"git": "abc", "figures": {"fig20": {"module": "m", '
        '"wall_s": 1.0, "headline": []}}}')
    probs = mod.check(["BENCH_train.json"])
    assert any("missing 'args'" in p for p in probs)
    assert any("non-empty" in p for p in probs)


def test_fig20_smoke():
    """Tier-1: both searches + the emulation loop run end to end; the
    summary row carries the acceptance-bearing fields."""
    from benchmarks.fig20_schedules import run_smoke
    rows = run_smoke()
    summary = rows[-1]
    assert summary.get("summary") is True
    assert summary["joint_schedule"] in SCHEDULES
    assert summary["sim_speedup"] > 0 and summary["pred_speedup"] > 0
    systems = {r["system"] for r in rows if "system" in r}
    assert systems == {"1f1b", "joint"}


@pytest.mark.slow
def test_fig20_schedule_search_acceptance():
    """Acceptance (ISSUE 7): joint schedule search reaches ≥1.1× lower
    emulated step makespan than 1F1B-only on the encoder-heavy mixture,
    with a strictly lower emulated bubble fraction."""
    from benchmarks.fig20_schedules import run
    summary = run()[-1]
    assert summary["sim_speedup"] >= 1.1
    assert summary["bubble_joint"] < summary["bubble_1f1b"]
