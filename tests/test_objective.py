"""Objective-subsystem tests (paper Eq. 1): unit, property, and regression.

Property harness (via the `_hypothesis_compat` shim) for the invariants the
pluggable objectives must satisfy across the whole plan/schedule stack:

  * balanced-quantile ≤ expected-random on the same sampled batches
    (pointwise per trial, hence at every quantile) — the Online Scheduler
    never does worse than the random-assignment baseline;
  * all objectives collapse to `mean_makespan` under a degenerate
    (single-shape) distribution with one item per bucket;
  * scaling chips through data parallelism at fixed shapes never increases
    the predicted makespan;
  * the balanced score is monotone in its quantile q.

Cross-validation anchors the predictions to the discrete-event 1F1B
simulator rather than to each other, and a regression test pins the
small-GBS fig16 scenario the balanced-quantile objective exists to fix.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.common.types import ModelConfig
from repro.core.engine import DFLOPEngine
from repro.core.optimizer.makespan import mean_makespan
from repro.core.optimizer.objective import (
    BalancedQuantileObjective,
    ExpectedRandomObjective,
    MeanObjective,
    Objective,
    OBJECTIVE_NAMES,
    corrected_item_durations,
    get_objective,
)
from repro.core.optimizer.search import ParallelismOptimizer
from repro.core.optimizer.space import (ClusterSpec, ModuleParallelism,
                                        ParallelismPlan)
from repro.core.pipeline.simulator import simulate_1f1b
from repro.core.profiling.data_profiler import ShapeDistribution
from repro.core.scheduler.online import OnlineMicrobatchScheduler
from repro.data.items import DataItem
from repro.data.synthetic import MixedDataset
from repro.runtime.calibration import OnlineCalibrator

TPM = 64

LLM = ModelConfig(name="l", family="dense", n_layers=8, d_model=512,
                  n_heads=8, n_kv_heads=8, d_ff=2048, vocab_size=8192)
ENC = ModelConfig(name="e", family="vlm-enc", n_layers=4, d_model=256,
                  n_heads=4, n_kv_heads=4, d_ff=1024, vocab_size=0,
                  causal=False, use_rope=False, input_embed_dim=64,
                  has_lm_head=False)

_CTX = {}


def ctx():
    """Lazily-built shared perf models + distributions (module cache —
    hypothesis tests cannot take function-scoped pytest fixtures)."""
    if not _CTX:
        fat = {"single_image": 0.7, "video": 0.3}
        llm_eng = DFLOPEngine(
            llm_cfg=LLM, cluster=ClusterSpec(16, 8, mem_bytes=80e9),
            tokens_per_media_item=TPM)
        llm_eng.profile(MixedDataset(fat, seed=0, tokens_per_media_item=TPM),
                        n_samples=256)
        mm_eng = DFLOPEngine(
            llm_cfg=LLM, enc_cfg=ENC, e_seq_len=64,
            cluster=ClusterSpec(16, 8, mem_bytes=80e9),
            tokens_per_media_item=TPM)
        mm_eng.profile(MixedDataset(fat, seed=1, tokens_per_media_item=TPM),
                       n_samples=256)
        _CTX["llm_eng"] = llm_eng
        _CTX["mm_eng"] = mm_eng
        _CTX["perf"] = llm_eng.perf           # encoder-less PerfModel
        _CTX["mm_perf"] = mm_eng.perf
        _CTX["dist"] = llm_eng.dist           # fat-tailed empirical dist
        _CTX["mm_dist"] = mm_eng.dist
    return _CTX


def llm_plan(tp, pp, dp, n_mb):
    return ParallelismPlan(llm=ModuleParallelism(tp, pp, dp), n_mb=n_mb)


# --------------------------------------------------------------------- #
# registry / construction
# --------------------------------------------------------------------- #
def test_get_objective_names_aliases_and_passthrough():
    assert isinstance(get_objective("mean"), MeanObjective)
    assert isinstance(get_objective("expected"), ExpectedRandomObjective)
    assert isinstance(get_objective("expected-random"), ExpectedRandomObjective)
    bq = get_objective("balanced-quantile", n_trials=4, q=0.5)
    assert isinstance(bq, BalancedQuantileObjective)
    assert bq.n_trials == 4 and bq.q == 0.5
    assert get_objective(bq) is bq
    # kwargs a class does not accept are dropped (uniform caller config)
    assert isinstance(get_objective("mean", n_trials=4, q=0.5), MeanObjective)
    with pytest.raises(ValueError, match="unknown objective"):
        get_objective("makespan")
    with pytest.raises(ValueError, match="quantile"):
        BalancedQuantileObjective(q=1.5)
    with pytest.raises(ValueError, match="solver"):
        BalancedQuantileObjective(solver="cplex")
    # reconfiguring an instance re-validates (and never mutates the source)
    src = BalancedQuantileObjective(q=0.9)
    with pytest.raises(ValueError, match="quantile"):
        get_objective(src, q=1.5)
    assert get_objective(src, q=0.5).q == 0.5
    assert src.q == 0.9
    assert set(OBJECTIVE_NAMES) == {"mean", "expected-random",
                                    "balanced-quantile"}


def test_plan_n_buckets():
    assert llm_plan(2, 2, 3, 4).n_buckets == 12
    sched_m = OnlineMicrobatchScheduler(llm_plan(1, 1, 2, 2), ctx()["perf"],
                                        TPM).n_buckets
    assert sched_m == 4


# --------------------------------------------------------------------- #
# property: balanced ≤ random on the same samples
# --------------------------------------------------------------------- #
# (n_mb, dp) kept small enough that the hybrid BnB certifies optimality,
# so per-trial dominance over *any* assignment — random included — is a
# theorem, not a heuristic.
_SMALL_M = st.sampled_from([(1, 1), (1, 2), (2, 1), (1, 3), (3, 1)])


@given(_SMALL_M, st.sampled_from([1, 2, 4]), st.integers(1, 2),
       st.integers(4, 8), st.integers(0, 40))
@settings(max_examples=25, deadline=None, derandomize=True)
def test_balanced_leq_random_on_same_samples(nmb_dp, tp, pp, gbs, seed):
    c = ctx()
    n_mb, dp = nmb_dp
    plan = llm_plan(tp, pp, dp, n_mb)
    bal = BalancedQuantileObjective(n_trials=6, solver="hybrid",
                                    time_limit_s=10.0, score="pipeline")
    rnd = ExpectedRandomObjective(n_trials=6, score="pipeline")
    rb = bal.evaluate_samples(c["perf"], plan, c["dist"], gbs, seed=seed)
    rr = rnd.evaluate_samples(c["perf"], plan, c["dist"], gbs, seed=seed)
    # same seed → same sampled batches per trial; optimal partition ≤ the
    # random round-robin partition on each of them
    assert np.all(rb.samples <= rr.samples + 1e-12)
    # pointwise dominance ⇒ dominance at every order statistic
    for q in (0.0, 0.5, 0.9, 1.0):
        assert np.quantile(rb.samples, q) <= np.quantile(rr.samples, q) + 1e-12


def test_balanced_leq_random_deterministic():
    """Shim-proof variant of the dominance property (runs without
    hypothesis installed)."""
    c = ctx()
    for seed, (tp, pp, dp, n_mb, gbs) in enumerate(
            [(1, 2, 3, 1, 8), (2, 1, 2, 1, 6), (4, 2, 1, 2, 7)]):
        plan = llm_plan(tp, pp, dp, n_mb)
        bal = BalancedQuantileObjective(n_trials=8, solver="hybrid",
                                        time_limit_s=10.0, score="pipeline")
        rnd = ExpectedRandomObjective(n_trials=8, score="pipeline")
        rb = bal.evaluate_samples(c["perf"], plan, c["dist"], gbs, seed=seed)
        rr = rnd.evaluate_samples(c["perf"], plan, c["dist"], gbs, seed=seed)
        assert np.all(rb.samples <= rr.samples + 1e-12)
        assert rb.score <= np.quantile(rr.samples, bal.q) + 1e-12


# --------------------------------------------------------------------- #
# property: degenerate distribution collapses every objective to mean
# --------------------------------------------------------------------- #
@given(st.sampled_from([1, 2, 4]), st.integers(1, 2), st.integers(1, 3),
       st.integers(1, 3), st.floats(200.0, 4000.0), st.integers(0, 10))
@settings(max_examples=25, deadline=None, derandomize=True)
def test_degenerate_distribution_equals_mean(tp, pp, dp, n_mb, shape, seed):
    c = ctx()
    plan = llm_plan(tp, pp, dp, n_mb)
    gbs = plan.n_buckets                     # one item per bucket
    deg = ShapeDistribution(np.zeros(7), np.full(7, shape))
    ref = MeanObjective().evaluate(c["perf"], plan, deg, gbs)
    assert np.isclose(ref, mean_makespan(c["perf"], plan, 0.0, shape, gbs),
                      rtol=1e-9)
    for obj in (BalancedQuantileObjective(n_trials=4, q=0.9),
                BalancedQuantileObjective(n_trials=4, q=0.25,
                                          score="pipeline"),
                ExpectedRandomObjective(n_trials=4),
                ExpectedRandomObjective(n_trials=4, score="pipeline")):
        val = obj.evaluate(c["perf"], plan, deg, gbs, seed=seed)
        assert np.isclose(val, ref, rtol=1e-9), (obj.name, val, ref)


# --------------------------------------------------------------------- #
# property: chips scaling (dp doubling at fixed shapes) never hurts
# --------------------------------------------------------------------- #
@given(st.sampled_from([1, 2, 4]), st.integers(1, 2), st.integers(1, 2),
       st.sampled_from([2, 4]), st.integers(8, 24), st.integers(0, 20))
@settings(max_examples=25, deadline=None, derandomize=True)
def test_makespan_non_increasing_as_chips_scale(tp, pp, dp, n_mb, gbs, seed):
    c = ctx()
    small = llm_plan(tp, pp, dp, n_mb)
    big = llm_plan(tp, pp, 2 * dp, n_mb // 2)    # 2× chips, same buckets
    for obj in (MeanObjective(),
                BalancedQuantileObjective(n_trials=4, score="pipeline"),
                ExpectedRandomObjective(n_trials=4, score="pipeline")):
        t_small = obj.evaluate(c["perf"], small, c["dist"], gbs, seed=seed)
        t_big = obj.evaluate(c["perf"], big, c["dist"], gbs, seed=seed)
        assert t_big <= t_small + 1e-12, (obj.name, t_big, t_small)


# --------------------------------------------------------------------- #
# property: quantile monotone in q
# --------------------------------------------------------------------- #
@given(st.floats(0.0, 1.0), st.floats(0.0, 1.0), st.integers(0, 30),
       st.sampled_from(["simulate", "pipeline"]))
@settings(max_examples=25, deadline=None, derandomize=True)
def test_quantile_monotone_in_q(q1, q2, seed, score):
    c = ctx()
    lo, hi = min(q1, q2), max(q1, q2)
    plan = llm_plan(2, 2, 2, 2)
    t_lo = BalancedQuantileObjective(n_trials=6, q=lo, score=score).evaluate(
        c["perf"], plan, c["dist"], 16, seed=seed)
    t_hi = BalancedQuantileObjective(n_trials=6, q=hi, score=score).evaluate(
        c["perf"], plan, c["dist"], 16, seed=seed)
    assert t_lo <= t_hi + 1e-12


def test_quantile_monotone_deterministic():
    c = ctx()
    plan = llm_plan(2, 2, 2, 2)
    scores = [BalancedQuantileObjective(n_trials=8, q=q).evaluate(
        c["perf"], plan, c["dist"], 16, seed=3) for q in (0.0, 0.5, 0.9, 1.0)]
    assert all(a <= b + 1e-12 for a, b in zip(scores, scores[1:]))
    assert scores[-1] > scores[0]            # fat tail: max > min trial


# --------------------------------------------------------------------- #
# cross-validation against the 1F1B simulator
# --------------------------------------------------------------------- #
def test_trial_makespan_simulate_matches_simulator_exactly():
    """The objective's per-trial score IS a simulate_1f1b run: rebuild the
    per-rank stage rows by hand (the benchmarks' bucket→(mb, rank) layout)
    and compare exactly."""
    obj = BalancedQuantileObjective(n_trials=1, score="simulate")
    plan = ParallelismPlan(llm=ModuleParallelism(1, 2, 2),
                           encoder=ModuleParallelism(1, 1, 2), n_mb=2)
    rng = np.random.default_rng(0)
    e = rng.uniform(0.0, 0.3, 9)
    l = rng.uniform(0.1, 1.0, 9)
    groups = [[0, 1], [2], [3, 4, 5], [6, 7, 8]]      # m = 4
    got = obj.trial_makespan(plan, groups, e, l)
    e_b = np.array([e[g].sum() for g in groups])
    l_b = np.array([l[g].sum() for g in groups])
    want = 0.0
    for r in range(2):                                # dp ranks
        fwd = np.empty((3, 2))                        # p = 1 + 2 stages
        for i in range(2):                            # n_mb
            b = i * 2 + r
            fwd[0, i] = e_b[b]
            fwd[1:, i] = l_b[b]
        fwd = fwd / 3.0                               # bwd_over_fwd = 2
        want = max(want, simulate_1f1b(fwd, 2.0 * fwd).makespan)
    np.testing.assert_allclose(got, want, rtol=1e-12)


def test_pipeline_score_upper_bounds_simulate():
    """(N_mb + depth − 1)·C_max is the homogeneous-worst-case envelope of
    the 1F1B simulation: ≥ always (simulator monotone in durations), equal
    when every bucket is identical."""
    c = ctx()
    for plan, gbs in ((llm_plan(1, 2, 4, 2), 32), (llm_plan(2, 4, 2, 4), 32),
                      (llm_plan(1, 1, 8, 2), 24)):
        pipe = BalancedQuantileObjective(n_trials=8, score="pipeline")
        sim = BalancedQuantileObjective(n_trials=8, score="simulate")
        rp = pipe.evaluate_samples(c["perf"], plan, c["dist"], gbs, seed=2)
        rs = sim.evaluate_samples(c["perf"], plan, c["dist"], gbs, seed=2)
        assert np.all(rs.samples <= rp.samples * (1 + 1e-9))
        # balanced buckets keep the envelope tight — the closed form stays
        # a usable scoring mode for the dominance property harness
        assert np.all(rp.samples <= rs.samples * 1.35)
    deg = ShapeDistribution(np.zeros(3), np.full(3, 1024.0))
    plan = llm_plan(2, 2, 2, 2)
    rp = BalancedQuantileObjective(n_trials=3, score="pipeline").evaluate(
        c["perf"], plan, deg, plan.n_buckets)
    rs = BalancedQuantileObjective(n_trials=3, score="simulate").evaluate(
        c["perf"], plan, deg, plan.n_buckets)
    np.testing.assert_allclose(rp, rs, rtol=1e-9)


@given(st.sampled_from([1, 2]), st.integers(1, 3), st.integers(1, 2),
       st.integers(6, 20), st.integers(0, 25))
@settings(max_examples=25, deadline=None, derandomize=True)
def test_simulate_score_bracketed_by_simulator_bounds(pp, dp, n_mb, gbs,
                                                      seed):
    """For small random plans the predicted step makespan must agree with
    simulate_1f1b's structural bounds on the same per-microbatch durations:
    ≥ the busiest-rank lower bound, ≤ the homogeneous envelope."""
    c = ctx()
    plan = llm_plan(2, pp, dp, n_mb)
    sim = BalancedQuantileObjective(n_trials=4, score="simulate")
    pipe = BalancedQuantileObjective(n_trials=4, score="pipeline")
    rs = sim.evaluate_samples(c["perf"], plan, c["dist"], gbs, seed=seed)
    rp = pipe.evaluate_samples(c["perf"], plan, c["dist"], gbs, seed=seed)
    assert np.all(rs.samples <= rp.samples * (1 + 1e-9))
    assert np.all(rs.samples > 0)


# --------------------------------------------------------------------- #
# seed plumbing (nondeterminism fix)
# --------------------------------------------------------------------- #
def test_search_seed_reproduces_and_perturbs():
    c = ctx()
    eng = c["llm_eng"]
    kw = dict(objective="balanced-quantile", n_trials=4,
              refine_expected_top_k=8)
    a = ParallelismOptimizer(eng.cluster, eng.perf, seed=7, **kw).search(
        eng.dist, 16)
    b = ParallelismOptimizer(eng.cluster, eng.perf, seed=7, **kw).search(
        eng.dist, 16)
    assert a.plan.as_tuple() == b.plan.as_tuple()
    assert a.makespan == b.makespan
    plan = a.plan
    obj = BalancedQuantileObjective(n_trials=4)
    s7 = obj.evaluate_samples(eng.perf, plan, eng.dist, 16, seed=7)
    s8 = obj.evaluate_samples(eng.perf, plan, eng.dist, 16, seed=8)
    assert not np.array_equal(s7.samples, s8.samples)
    np.testing.assert_array_equal(
        s7.samples,
        obj.evaluate_samples(eng.perf, plan, eng.dist, 16, seed=7).samples)


def test_distinct_seeds_perturb_monte_carlo_ranks():
    """Small n_trials + a fat-tailed distribution: the Monte-Carlo ranking
    of near-tied plans must depend on the seed (it silently never did when
    expected_makespan hardcoded seed=0)."""
    c = ctx()
    plans = [llm_plan(2, 2, 2, i) for i in (1, 2, 3, 4)]
    obj = ExpectedRandomObjective(n_trials=2)
    orders = set()
    for seed in range(8):
        scores = [obj.evaluate(c["perf"], p, c["dist"], 16, seed=seed)
                  for p in plans]
        orders.add(tuple(int(i) for i in np.argsort(scores)))
    assert len(orders) > 1


# --------------------------------------------------------------------- #
# calibration-coupled search (the tentpole's second half)
# --------------------------------------------------------------------- #
def _mature_calibrator(ratio: float, tps=(1, 2, 4, 8), module="llm"):
    cal = OnlineCalibrator(min_obs=2, deadband=0.02)
    for tp in tps:
        for exp in range(2, 16):              # buckets 4 .. 32768
            for _ in range(3):
                cal.observe(module, float(2 ** exp), tp, 1.0, ratio)
    return cal


def test_correct_array_matches_scalar_correct():
    cal = _mature_calibrator(1.4)
    shapes = np.array([3.0, 17.0, 900.0, 5000.0, 0.5])
    durs = np.array([0.1, 0.2, 0.3, 0.4, 0.5])
    got = cal.correct_array("llm", shapes, 2, durs)
    want = [cal.correct("llm", float(s), 2, float(d))
            for s, d in zip(shapes, durs)]
    np.testing.assert_allclose(got, want, rtol=1e-12)
    # unknown (module, tp) cells leave durations untouched
    np.testing.assert_array_equal(
        cal.correct_array("encoder", shapes, 2, durs), durs)


def test_search_tables_match_scheduler_corrected_predictions():
    """Acceptance: `ParallelismOptimizer.search()` with a calibrator must
    see the *same* corrected durations `OnlineMicrobatchScheduler` predicts
    on identical shapes."""
    c = ctx()
    perf = c["perf"]
    cal = _mature_calibrator(1.5)
    S, gbs = 1000.0, 6
    deg = ShapeDistribution(np.zeros(16), np.full(16, S))
    cluster = ClusterSpec(16, 8, mem_bytes=80e9)
    opt = ParallelismOptimizer(cluster, perf, calibrator=cal)
    opt_raw = ParallelismOptimizer(cluster, perf)
    l_tab, e_tab = opt.build_tables(deg, gbs)
    l_raw, _ = opt_raw.build_tables(deg, gbs)
    assert e_tab is None
    # table shape(k=gbs) == S: entry must be exactly scheduler's prediction
    np.testing.assert_allclose(l_tab.shapes[gbs - 1], S, rtol=1e-12)
    plan = llm_plan(2, 2, 3, 2)
    sched = OnlineMicrobatchScheduler(plan, perf, TPM, calibration=cal)
    _, l_dur = sched.item_durations([DataItem(0, int(S))])
    np.testing.assert_allclose(l_tab.dur[2][gbs - 1] / plan.llm.pp, l_dur[0],
                               rtol=1e-12)
    # and it is the calibrated refinement of the raw table
    np.testing.assert_allclose(l_tab.dur[2], l_raw.dur[2] * 1.5, rtol=1e-12)
    # the Monte-Carlo path shares the same duration function
    e_it, l_it = corrected_item_durations(perf, plan, np.zeros(1),
                                          np.array([S]), corrector=cal)
    np.testing.assert_allclose(l_it[0], l_dur[0], rtol=1e-12)


def test_calibrator_fallback_covers_aggregate_table_shapes():
    """The scheduler only ever observes per-item shapes, but the
    mean-shape tables ask about *aggregate* bucket sizes (shape(k) for
    small k is far beyond any observed bucket).  Those entries must borrow
    the mean item shape's ratio so a uniform runtime slowdown reaches the
    whole table, not just its item-scale tail."""
    c = ctx()
    eng = c["llm_eng"]
    cal = OnlineCalibrator(min_obs=2, deadband=0.02)
    mean_seq = eng.dist.mean()[1]
    for tp in (1, 2, 4, 8):
        for _ in range(3):
            cal.observe("llm", mean_seq, tp, 1.0, 1.5)
    raw = ParallelismOptimizer(eng.cluster, eng.perf).search(eng.dist, 8)
    cald = ParallelismOptimizer(eng.cluster, eng.perf,
                                calibrator=cal).search(eng.dist, 8)
    np.testing.assert_allclose(cald.makespan, raw.makespan * 1.5, rtol=1e-6)


def test_calibrated_search_shifts_makespan_and_controller_sees_it():
    c = ctx()
    eng = c["llm_eng"]
    cal = _mature_calibrator(1.5)
    raw = ParallelismOptimizer(eng.cluster, eng.perf).search(eng.dist, 16)
    cald = ParallelismOptimizer(eng.cluster, eng.perf,
                                calibrator=cal).search(eng.dist, 16)
    # uniform 1.5× slowdown on every LLM bucket scales the (LLM-bound)
    # optimum by the same factor
    np.testing.assert_allclose(cald.makespan, raw.makespan * 1.5, rtol=1e-6)
    # the controller evaluates stale-vs-new with the same corrector
    ctl = eng.runtime(16, auto_replan=False)
    ctl.calibration.cells = cal.cells
    stale = ctl._plan_makespan(raw.plan, eng.dist)
    np.testing.assert_allclose(
        stale, MeanObjective().evaluate(eng.perf, raw.plan, eng.dist, 16,
                                        corrector=cal), rtol=1e-12)
    ctl.close()


# --------------------------------------------------------------------- #
# search-level behaviour of the sampling objectives
# --------------------------------------------------------------------- #
def test_balanced_search_never_worse_than_mean_pick_under_own_objective():
    """The re-rank candidate set always contains the mean objective's
    winner (and its N_mb), so the balanced search result dominates it under
    the balanced score — the expansion over fewer-bucket plans is a free
    win, never a loss."""
    c = ctx()
    eng = c["mm_eng"]
    mean_res = ParallelismOptimizer(eng.cluster, eng.perf).search(eng.dist, 16)
    opt = ParallelismOptimizer(eng.cluster, eng.perf,
                               objective="balanced-quantile", n_trials=6,
                               seed=3)
    bq_res = opt.search(eng.dist, 16)
    score_of_mean_pick = opt.objective_obj.evaluate(
        eng.perf, mean_res.plan, eng.dist, 16, seed=3)
    assert bq_res.makespan <= score_of_mean_pick + 1e-12
    assert bq_res.plan.chips == eng.cluster.n_chips


def test_objective_instance_accepted_by_optimizer_and_engine():
    c = ctx()
    eng = c["llm_eng"]
    obj = BalancedQuantileObjective(n_trials=3, q=0.5)
    res = ParallelismOptimizer(eng.cluster, eng.perf,
                               objective=obj).search(eng.dist, 8)
    assert res.found
    eng2 = DFLOPEngine(llm_cfg=LLM, cluster=eng.cluster,
                       tokens_per_media_item=TPM)
    eng2.perf, eng2.dist = eng.perf, eng.dist
    eng2.objective = "balanced-quantile"
    assert eng2.plan(8, n_trials=3).found
    # plan() pins the resolved objective (incl. non-default quantile) back
    # onto the engine, and the controller's like-for-like evaluation scores
    # with that configuration — only n_trials follows replan_n_trials
    eng2.objective = "balanced-quantile"
    plan = eng2.plan(8, quantile=0.5, n_trials=3).plan
    assert isinstance(eng2.objective, BalancedQuantileObjective)
    assert eng2.objective.q == 0.5
    ctl = eng2.runtime(8, auto_replan=False, calibrate=False, trace=False,
                       replan_n_trials=3)
    np.testing.assert_allclose(
        ctl._plan_makespan(plan, eng2.dist),
        eng2.objective.evaluate(eng2.perf, plan, eng2.dist, 8,
                                seed=ctl._replan_seed),
        rtol=1e-12)
    ctl.close()


# --------------------------------------------------------------------- #
# regression: the small-GBS fig16 failure mode (the bug this PR fixes)
# --------------------------------------------------------------------- #
@pytest.mark.slow
def test_large_gbs_rerank_simulates_and_stays_sharp():
    """GBS 2048 smoke (the regime the old `max_sim_buckets` fallback
    scored with the homogeneous closed form): the balanced-quantile search
    must complete with the batched simulate estimator — no fallback
    remains — and its pick's simulated p90 step makespan must not regress
    against the mean objective's pick."""
    from benchmarks.common import POD_CLUSTER, engine_for
    from benchmarks.fig17_objective import MIXTURE, evaluate_plan

    # the fallback (and its knob) are gone: every GBS uses one estimator
    assert not hasattr(BalancedQuantileObjective(), "max_sim_buckets")
    assert not hasattr(BalancedQuantileObjective(), "effective_score")

    gbs = 2048
    eng = engine_for("llava-ov-llama8b", POD_CLUSTER, mixture=MIXTURE, seed=0)
    picks = {}
    for obj in ("mean", "balanced-quantile"):
        opt = ParallelismOptimizer(eng.cluster, eng.perf, mode=eng.mode,
                                   objective=obj, n_trials=16,
                                   refine_expected_top_k=8)
        res = opt.search(eng.dist, gbs)
        assert res.found
        picks[obj] = res.plan
    sims = {obj: evaluate_plan(eng, plan, gbs, n_eval=6)
            for obj, plan in picks.items()}
    bq_p90 = np.quantile(sims["balanced-quantile"], 0.9)
    mean_p90 = np.quantile(sims["mean"], 0.9)
    # at this scale the objectives have converged (fig17): the guard is
    # "no score regression beyond simulation sampling noise", not strict
    # dominance — that is the GBS-16 test below
    assert bq_p90 <= mean_p90 * 1.05, (picks, bq_p90, mean_p90)


@pytest.mark.slow
def test_small_gbs_balanced_pick_not_worse_than_mean_pick_simulated():
    """GBS 16, fat-tailed video-heavy mixture, pod scale: the mean-shape
    objective overrates ~1-item-per-bucket plans; the balanced-quantile
    pick's simulated (simulate_1f1b) p90 step makespan must not exceed the
    mean pick's."""
    from benchmarks.common import POD_CLUSTER, engine_for
    from benchmarks.fig17_objective import MIXTURE, evaluate_plan

    gbs = 16
    eng = engine_for("llava-ov-llama8b", POD_CLUSTER, mixture=MIXTURE, seed=0)
    picks = {}
    for obj in ("mean", "balanced-quantile"):
        opt = ParallelismOptimizer(eng.cluster, eng.perf, mode=eng.mode,
                                   objective=obj, n_trials=16,
                                   refine_expected_top_k=16)
        picks[obj] = opt.search(eng.dist, gbs).plan
    sims = {obj: evaluate_plan(eng, plan, gbs, n_eval=20)
            for obj, plan in picks.items()}
    bq_p90 = np.quantile(sims["balanced-quantile"], 0.9)
    mean_p90 = np.quantile(sims["mean"], 0.9)
    assert bq_p90 <= mean_p90 * (1 + 1e-6), (picks, bq_p90, mean_p90)
