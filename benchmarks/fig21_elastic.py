"""Fig. 21 (extension): makespan recovery after a host loss — elastic
re-plan + reshard vs naive stall.

Kills one emulated host at step ``fail_at`` and repairs it
``repair_steps`` steps later, then compares two policies completing the
same ``n_steps`` global batches in virtual time:

  * **naive stall** — synchronous training cannot proceed without the
    lost host: the run stalls for the whole outage, then finishes every
    step at the full-fleet rate t_N (what a checkpoint-restore pipeline
    without elasticity effectively does, minus the restore cost — a
    *favourable* baseline).
  * **elastic** — the `repro.runtime` controller drains the membership
    event, re-plans for the surviving roster (measured search wall time),
    migrates live params through the `ParamSwapper` path (measured
    reshard when enough local devices exist, bytes/bandwidth model
    otherwise), and keeps training at the degraded rate t_{N-1} until the
    host returns, paying a second measured recovery at rejoin.

Both runs schedule *identical* batches; per-step virtual durations are
the scheduler's plan-aware ``step_makespan`` predictions, so the curve
isolates the policy difference (stall vs degraded progress) plus the
measured recovery costs.  The outage's wall-clock length is the same for
both policies by construction — the elastic run's recovery + degraded
steps define it — so the comparison reduces to: is the work done during
the outage worth more than the rejoin recovery cost?

Summary headline: ``speedup = makespan_naive_s / makespan_elastic_s``
(> 1 = re-plan + reshard beats naive stall; the fig21 acceptance).
"""
from __future__ import annotations

import os

from benchmarks.common import engine_for
from repro.core.optimizer.space import ClusterSpec
from repro.data.host_shard import HostShardedSource
from repro.data.synthetic import MixedDataset
from repro.launch.fleet import FaultInjector, FleetManager

TRACE_PATH = os.path.join(os.path.dirname(__file__), "results",
                          "fig21_elastic_trace.json")


def _maybe_physical_swapper(fleet, n_layers, pp):
    """Measured reshard path when the process has enough local devices to
    host the fleet; (None, modeled_cost_fn) otherwise."""
    import jax

    from repro.launch.reshard import (ParamSwapper, estimate_reshard_s,
                                      param_bytes)
    from benchmarks.fig16_replan import _synthetic_stacked_params

    params, stacked = _synthetic_stacked_params(n_layers, pp)
    if len(jax.devices()) >= fleet.n_hosts * fleet.devices_per_host:
        live = {"p": params}
        sw = ParamSwapper(lambda: live["p"], lambda p: live.update(p=p),
                          stage_stacked=stacked, strict=False,
                          mesh_factory=fleet.plan_mesh)
        return sw, None
    return None, lambda: estimate_reshard_s(param_bytes(params))


def run(arch: str = "internvl2-2b", gbs: int = 32, n_steps: int = 24,
        fail_at: int = 6, repair_steps: int = 8, n_hosts: int = 8,
        seed: int = 0, recovery_wall_s: float | None = None):
    assert 0 < fail_at and fail_at + repair_steps < n_steps, \
        "the outage must open and close inside the run"
    cluster = ClusterSpec(n_chips=n_hosts, chips_per_node=n_hosts)
    eng = engine_for(arch, cluster, mixture="mixed", seed=seed)
    res0 = eng.plan(gbs)
    assert res0.found, f"{arch} has no feasible plan on {n_hosts} chips"

    import jax
    devices = (list(jax.devices()) if len(jax.devices()) >= n_hosts
               else list(range(n_hosts)))
    fleet = FleetManager(devices=devices[:n_hosts], devices_per_host=1)
    swapper, modeled_cost = _maybe_physical_swapper(
        fleet, eng.llm_cfg.n_layers, eng.plan_result.plan.llm.pp)
    ctl = eng.runtime(gbs, adaptive=False, auto_replan=False,
                      calibrate=False, ilp_time_limit_s=0.05,
                      param_swapper=swapper, fleet=fleet)
    plan_n = ctl.plan
    # the naive policy runs every step under the full-fleet plan
    naive_sched = eng.scheduler(plan=plan_n, adaptive=False,
                                ilp_time_limit_s=0.05)
    injector = FaultInjector(fleet, {fail_at: [("fail", n_hosts - 1)],
                                     fail_at + repair_steps:
                                         [("join", n_hosts - 1)]})
    ds = MixedDataset("mixed", seed=seed, tokens_per_media_item=eng.tokens_per_media_item)
    src = HostShardedSource(lambda: ds.sample(gbs), gbs=gbs, fleet=fleet,
                            keep_committed=False)

    rows, cum_e, cum_n = [], 0.0, 0.0
    recovery_costs = []
    for i in range(n_steps):
        injector.on_step(i)
        src.draw()
        items = src.in_flight
        n_rec_before = len(ctl.recoveries)
        out = ctl.schedule(items)        # poll_fleet -> recovery runs here
        src.commit()
        rec_cost = 0.0
        for rec in ctl.recoveries[n_rec_before:]:
            if recovery_wall_s is not None:   # pinned cost -> deterministic
                rec_cost += recovery_wall_s   # snapshot (cf. fig16's
                continue                      # step_wall_s)
            rec_cost += rec.elapsed_s
            if modeled_cost is not None:
                rec_cost += modeled_cost()   # no devices: model the reshard
        recovery_costs.append(rec_cost)
        t_e = float(out.step_makespan)
        t_n = float(naive_sched.schedule(items).step_makespan)
        in_outage = fail_at <= i < fail_at + repair_steps
        cum_e += rec_cost + t_e
        # naive: stalls through the outage (it still pays those steps
        # after repair); the stall length is the elastic side's outage
        # wall-clock, added when the outage closes below
        cum_n += t_n
        rows.append({
            "figure": "fig21", "iter": i,
            "phase": ("outage" if in_outage else
                      "pre" if i < fail_at else "post"),
            "n_alive": fleet.n_alive,
            "plan": list(out.plan.as_tuple()),
            "recovery_cost_s": rec_cost,
            "t_elastic_s": t_e, "t_naive_s": t_n,
            "cum_elastic_s": cum_e, "cum_naive_s": cum_n,
        })
    # outage wall-clock = elastic recovery at failure + degraded steps
    outage_rows = [r for r in rows if r["phase"] == "outage"]
    stall_s = float(sum(r["recovery_cost_s"] + r["t_elastic_s"]
                        for r in outage_rows))
    cum_n += stall_s
    makespan_e, makespan_n = cum_e, cum_n

    recs = ctl.recoveries
    summary = {
        "figure": "fig21", "iter": -1, "phase": "summary", "summary": True,
        "n_hosts": n_hosts, "fail_at": fail_at,
        "repair_steps": repair_steps,
        "plan_full": list(plan_n.as_tuple()),
        "plan_degraded": (list(recs[0].new_plan_tuple)
                          if recs and recs[0].new_plan_tuple else
                          list(plan_n.as_tuple())),
        "n_recoveries": len(recs),
        "n_degraded": sum(r.degraded for r in recs),
        "recovery_cost_total_s": float(sum(recovery_costs)),
        "recovery_wall_s": recovery_wall_s,
        "reshard_measured": swapper is not None and recovery_wall_s is None,
        "stall_s": stall_s,
        "makespan_naive_s": makespan_n,
        "makespan_elastic_s": makespan_e,
        "speedup": makespan_n / max(makespan_e, 1e-12),
    }
    rows.append(summary)
    os.makedirs(os.path.dirname(TRACE_PATH), exist_ok=True)
    ctl.export_trace(TRACE_PATH)
    ctl.close()
    return rows


def run_smoke():
    """CI smoke: one failure + one rejoin on a tiny run — pins the full
    recover-while-training loop without the sweep's wall time."""
    return run(gbs=16, n_steps=8, fail_at=2, repair_steps=3, n_hosts=4)


if __name__ == "__main__":
    for r in run():
        if r["phase"] in ("summary",) or r["iter"] % 4 == 0:
            print(r)
