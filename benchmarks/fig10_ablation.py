"""Fig. 10: ablation — incrementally adding DFLOP components to the baseline
(optimizer-only, scheduler-only, full)."""
from __future__ import annotations

from benchmarks.common import POD_CLUSTER, engine_for, run_system

ARCHS = ["llava-ov-llama8b", "llava-ov-qwen7b", "internvl2-2b"]


def run(gbs: int = 128, n_iters: int = 6):
    rows = []
    for arch in ARCHS:
        eng = engine_for(arch, POD_CLUSTER)
        eng.plan(gbs)
        base = run_system(eng, "baseline", gbs, n_iters=n_iters)
        for system in ("sched-only", "opt-only", "dflop"):
            r = run_system(eng, system, gbs, n_iters=n_iters)
            rows.append({
                "figure": "fig10",
                "arch": arch,
                "system": system,
                "gain_vs_baseline": (r["throughput_tokens_per_s"]
                                     / base["throughput_tokens_per_s"]),
            })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
