"""Fig. 19 (extension): data-aware serving — goodput vs p99 latency.

Open-loop load generator over the emulated serving cluster
(`repro.serve.ServeEngine`): Poisson arrivals at a swept QPS, modalities
drawn from the sticky-Markov bursty single-image ↔ video stream of
fig18 (`bursty_stream`) — runs of cheap requests with embedded bursts of
32-frame video requests, the arrival pattern a data-blind batcher
handles worst.

Per QPS point the *same* request stream (identical arrivals, shapes,
SLOs and oracle heterogeneity factors) is served under

  * ``fifo`` — admit in arrival order (vLLM-style data-blind batcher);
  * ``slo``  — `SLOAdmission`: EDF deadline reservation + homogeneous
    `sorted_runs` candidates scored by work-normalized padded batch cost.

Both pay identical execution rules (pow2 padding, compile buckets, KV
handoff, continuous-batch decode), so any gap is pure admission policy.
A mid-stream drift (video requests get slower) exercises the
calibrate → Page–Hinkley → re-price loop on both sides.

Headline (acceptance, pinned by the slow test in
``tests/test_serve_engine.py`` and snapshotted to ``BENCH_serving.json``):
data-aware admission reaches **≥ 1.2× goodput at lower-or-equal p99**
than FIFO at ≥ 2 of the swept QPS points.

Per-request SLO: ``slo_floor_s + slo_scale ×`` the request's *ideal*
service time (unpadded prefill + expected decode at mean context) — fat
requests get proportionally more budget, so the SLO itself is not the
discriminator; queueing and padding waste are.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from benchmarks.common import DEFAULT_CLUSTER, engine_for
from benchmarks.fig18_composer import bursty_stream
from repro.runtime.metrics import nan_to_none
from repro.serve import PrefillPricer, Request, ServeConfig

QPS_POINTS = (3.0, 4.0, 5.0)

MODALITY_BIAS = {"single_image": 1.0, "multi_image": 1.1, "video": 1.3}


def bursty_requests(n: int, qps: float, *, tpm: int, pricer: PrefillPricer,
                    seed: int = 0, p_stay: float = 0.6,
                    heavy_frac: float = 0.25, max_new_tokens: int = 32,
                    slo_scale: float = 6.0, slo_floor_s: float = 2.0,
                    noise_sigma: float = 0.10, drift_at: float = 0.5,
                    drift_bias: float = 1.6) -> List[Request]:
    """Open-loop request stream: Poisson arrivals at `qps`, bursty
    modalities, per-request oracle factors (modality bias × lognormal
    noise; video slows by `drift_bias` after the `drift_at` fraction of
    the stream — the drift the engine must detect and re-price for).
    Deterministic in `seed`: policies replay bit-identical ground truth."""
    items = bursty_stream(n, tpm=tpm, seed=seed, p_stay=p_stay,
                          heavy_frac=heavy_frac)
    rng = np.random.default_rng([seed, 19])
    arrivals = np.cumsum(rng.exponential(1.0 / qps, size=n))
    out: List[Request] = []
    for i, (it, t) in enumerate(zip(items, arrivals)):
        factor = MODALITY_BIAS.get(it.modality, 1.0) \
            * float(rng.lognormal(0.0, noise_sigma))
        if it.modality == "video" and i >= drift_at * n:
            factor *= drift_bias
        req = Request(item=it, arrival_s=float(t), slo_s=0.0,
                      max_new_tokens=max_new_tokens, true_factor=factor)
        base, _, _ = pricer.base(req)
        ideal = base + pricer.decode_estimate(req)
        req.slo_s = slo_floor_s + slo_scale * ideal
        out.append(req)
    return out


def run(arch: str = "llava-ov-llama8b", qps_points: Sequence[float] = QPS_POINTS,
        n_requests: int = 500, seed: int = 0, serve_cfg: Optional[ServeConfig] = None,
        **stream_kw) -> List[Dict]:
    """Sweep QPS × {fifo, slo}; returns fig rows + per-QPS summary rows."""
    eng = engine_for(arch, DEFAULT_CLUSTER, mixture="mixed", seed=seed)
    cfg = serve_cfg if serve_cfg is not None else ServeConfig()
    tpm = eng.tokens_per_media_item
    # calibration-free pricer: used only to derive per-request ideal SLOs
    slo_pricer = PrefillPricer(eng.perf, tpm, tp=cfg.tp)
    rows: List[Dict] = []
    for qps in qps_points:
        reports = {}
        for policy in ("fifo", "slo"):
            serve = eng.serving(admission=policy, serve_cfg=cfg)
            reqs = bursty_requests(n_requests, qps, tpm=tpm,
                                   pricer=slo_pricer, seed=seed, **stream_kw)
            rep = serve.run(reqs)
            reports[policy] = rep
            rows.append({"figure": "fig19", "qps": qps, **rep.row()})
        f, s = reports["fifo"], reports["slo"]
        # ServeReport.row() already maps missing stats (no completions) to
        # None — do the same here so the summary row stays valid JSON and
        # an overloaded point renders as "no p99", never a perfect 0 ms.
        rows.append({
            "figure": "fig19", "qps": qps, "summary": True,
            "goodput_ratio": s.goodput_rps / max(f.goodput_rps, 1e-12),
            "p99_fifo_s": nan_to_none(f.p99_latency_s),
            "p99_slo_s": nan_to_none(s.p99_latency_s),
            "slo_met_fifo": f.n_slo_met, "slo_met_slo": s.n_slo_met,
        })
    return rows


def run_smoke(seed: int = 0) -> List[Dict]:
    """Tier-1 CI variant: one low-QPS point, short stream, tiny knobs —
    exercises the full admission → prefill → handoff → decode loop in
    well under a second of wall clock."""
    return run(qps_points=(2.0,), n_requests=48, seed=seed,
               serve_cfg=ServeConfig(n_prefill_workers=1,
                                     n_decode_workers=1,
                                     decode_slots=4, max_prefill_batch=4))


if __name__ == "__main__":
    for r in run():
        print(r)
