"""Fig. 4: stage-wise duration distributions across data items under random
assignment (the heterogeneity the Online Scheduler removes)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import POD_CLUSTER, engine_for


def run(arch: str = "llava-ov-qwen7b", n: int = 2048):
    eng = engine_for(arch, POD_CLUSTER)
    eng.plan(gbs=128)
    sched = eng.scheduler(adaptive=False)
    items = eng.dataset.sample(n)
    e_dur, l_dur = sched.item_durations(items)
    rows = []
    for name, d in (("encoder", e_dur), ("llm", l_dur)):
        d = d[d > 0]
        rows.append({
            "figure": "fig4", "stage": name,
            "mean_s": float(np.mean(d)), "std_s": float(np.std(d)),
            "p5_s": float(np.percentile(d, 5)),
            "p95_s": float(np.percentile(d, 95)),
            "cv": float(np.std(d) / np.mean(d)),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
