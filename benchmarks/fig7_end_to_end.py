"""Fig. 7: end-to-end training throughput, DFLOP vs data-agnostic baseline.

Paper claim: 1.2x–3.6x GPU-throughput gain across MLLM configurations.
"""
from __future__ import annotations

from benchmarks.common import POD_CLUSTER, engine_for, run_system

ARCHS = ["llava-ov-qwen7b", "llava-ov-llama8b", "internvl2-2b"]


def run(n_iters: int = 6, gbs: int = 128):
    rows = []
    for arch in ARCHS:
        eng = engine_for(arch, POD_CLUSTER)
        eng.plan(gbs)
        base = run_system(eng, "baseline", gbs, n_iters=n_iters)
        dflop = run_system(eng, "dflop", gbs, n_iters=n_iters)
        gain = (dflop["throughput_tokens_per_s"]
                / base["throughput_tokens_per_s"])
        rows.append({
            "figure": "fig7",
            "arch": arch,
            "baseline_tok_s": base["throughput_tokens_per_s"],
            "dflop_tok_s": dflop["throughput_tokens_per_s"],
            "gain": gain,
            "baseline_plan": base["plan"],
            "dflop_plan": dflop["plan"],
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
