"""Fig. 17 (extension): search-objective comparison across global batch size.

Sweeps GBS over {8 .. 2048} on a fat-tailed, video-heavy mixture (70%
single-image items, 30% video items carrying 8–32 frames each) and runs
the Parallelism Optimizer once per objective — ``mean`` (Algorithm 1),
``expected-random`` (Eq. 1 Monte-Carlo over random assignment) and
``balanced-quantile`` (LPT-balanced assignment scored at p90).  Each
objective's chosen plan is then evaluated by *simulation*: fresh global
batches are balanced by the real Online Scheduler and played through the
discrete-event 1F1B simulator (`simulate_1f1b`), exactly the
`benchmarks.common.simulate_iteration` harness the end-to-end figures use.

The point of the figure is the small-GBS regime: with ~1 item per bucket,
the mean-shape estimate prices the fat tail into *no* bucket while the
random-assignment Monte-Carlo prices it into *every* slot — both mis-rank
plans, and `balanced-quantile` flips the plan choice to one whose simulated
p90 step makespan is strictly lower.  At large GBS the bootstrap smooths
the tail and the three objectives converge on the same plans.

Reported per (GBS, objective): the chosen plan θ, its objective score, and
the mean/p90 of the simulated step makespans.  The summary rows give the
mean-vs-balanced simulated-makespan ratio per GBS.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import POD_CLUSTER, engine_for, simulate_iteration
from repro.core.optimizer.search import ParallelismOptimizer

MIXTURE = {"single_image": 0.7, "video": 0.3}    # fat-tailed (Fig. 11b axis)
OBJECTIVES = ("mean", "expected-random", "balanced-quantile")


def evaluate_plan(eng, plan, gbs: int, n_eval: int) -> np.ndarray:
    """Simulated step makespans of `plan` on fresh scheduler-balanced
    batches (the ground truth the objectives try to predict)."""
    sched = eng.scheduler(plan=plan, adaptive=False, ilp_time_limit_s=0.05)
    return np.array([
        simulate_iteration(plan, sched, eng.dataset.sample(gbs),
                           random_assign=False, seed=it).step_time
        for it in range(n_eval)])


def run(arch: str = "llava-ov-llama8b", gbs_sweep=(8, 16, 32, 64, 128, 256,
                                                   512, 1024, 2048),
        n_trials: int = 16, n_eval: int = 12, seed: int = 0):
    eng = engine_for(arch, POD_CLUSTER, mixture=MIXTURE, seed=seed)
    rows = []
    for gbs in gbs_sweep:
        sims = {}
        # small-GBS step makespans are tail-dominated and cheap to simulate:
        # spend more draws there so the comparison is not sampling noise.
        n_draws = max(n_eval, 256 // gbs)
        for obj in OBJECTIVES:
            opt = ParallelismOptimizer(
                eng.cluster, eng.perf, mode=eng.mode, objective=obj,
                n_trials=n_trials, seed=seed,
                refine_expected_top_k=8 if gbs > 256 else 16)
            res = opt.search(eng.dist, gbs)
            ts = evaluate_plan(eng, res.plan, gbs, n_draws)
            sims[obj] = ts
            rows.append({
                "figure": "fig17", "gbs": gbs, "objective": obj,
                "plan": list(res.plan.as_tuple()),
                "objective_score_s": float(res.makespan),
                "search_elapsed_s": float(res.elapsed_s),
                "sim_makespan_mean_s": float(ts.mean()),
                "sim_makespan_p90_s": float(np.quantile(ts, 0.9)),
            })
        rows.append({
            "figure": "fig17", "gbs": gbs, "objective": "summary",
            "mean_over_balanced_p90":
                float(np.quantile(sims["mean"], 0.9)
                      / np.quantile(sims["balanced-quantile"], 0.9)),
            "mean_over_balanced_mean":
                float(sims["mean"].mean()
                      / sims["balanced-quantile"].mean()),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
