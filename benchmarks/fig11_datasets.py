"""Fig. 11: robustness across dataset compositions (multi-image / video /
mixed) — baselines degrade with heterogeneity, DFLOP stays flat."""
from __future__ import annotations

from benchmarks.common import POD_CLUSTER, engine_for, run_system


def run(arch: str = "llava-ov-llama8b", gbs: int = 128, n_iters: int = 5):
    rows = []
    for mixture in ("multi_image", "video", "mixed"):
        eng = engine_for(arch, POD_CLUSTER, mixture=mixture)
        eng.plan(gbs)
        base = run_system(eng, "baseline", gbs, n_iters=n_iters)
        dflop = run_system(eng, "dflop", gbs, n_iters=n_iters)
        rows.append({
            "figure": "fig11", "arch": arch, "dataset": mixture,
            "heterogeneity_cv": eng.dist.heterogeneity(),
            "baseline_tok_s": base["throughput_tokens_per_s"],
            "dflop_tok_s": dflop["throughput_tokens_per_s"],
            "gain": dflop["throughput_tokens_per_s"]
            / base["throughput_tokens_per_s"],
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
