"""Fig. 22 (extension): the real serving loop — measured durations close
the calibrate → drift → re-price loop.

fig19 validated the data-aware serving *policies* against oracle
durations; this figure swaps the `EmulatedBackend` for the
`RealBackend` and runs the same admission → chunked prefill →
device-to-device KV handoff → continuous-batch decode loop on an actual
jit'd model (tiny dense LLM on the host platform; CI forces multiple
host devices so the prefill/decode pools are genuinely disaggregated).

What it demonstrates (the PR's acceptance criteria, pinned by the slow
test in tests/test_serve_backend.py):

  * **measured feedback end to end** — every prefill batch and decode
    step feeds its measured wall duration into the `OnlineCalibrator`'s
    "prefill"/"decode" cells; the perf model predicts accelerator-seconds
    for the profiled arch while the host executes wall-seconds, so the
    calibrator's per-bucket ratios are a live unit conversion the
    admission policy prices through;
  * **re-price fires on a mid-stream video shift** — after ``drift_at``
    the stream turns video-heavy, opening shape buckets the calibrator
    has never observed; their residuals blow up, Page–Hinkley fires and
    `PrefillPricer.flush()` re-estimates both prefill prices and decode
    fits under the post-shift calibration;
  * **error shrinks** — late-run |corrected/actual − 1| (from
    `ServeEngine.prediction_log`, whole-run, not the rolling window) is
    below the early-run error;
  * **SLO admission beats FIFO on goodput** at ≥ 1 swept load point.

Durations here are *measured*, so rows are not bit-deterministic like
fig19's — the snapshot check validates shape, and the acceptance
assertions are load-relative (SLOs and arrival rates derive from
``RealBackend.warmup()`` unit costs, so the figure is machine-speed
independent).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.common.types import ModelConfig
from repro.core.optimizer.space import ClusterSpec
from repro.data.items import DataItem
from repro.runtime.drift import PageHinkley
from repro.runtime.metrics import nan_to_none
from repro.serve import Request, ServeConfig

TPM = 8

ENC = ModelConfig(name="fig22-enc", family="vlm-enc", n_layers=2,
                  d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                  vocab_size=0, causal=False, use_rope=False,
                  input_embed_dim=32, has_lm_head=False)
LLM = ModelConfig(name="fig22-llm", family="dense", n_layers=2,
                  d_model=64, n_heads=4, n_kv_heads=2, d_ff=256,
                  vocab_size=128, dtype="float32")

LOAD_POINTS = (0.6, 1.1)      # arrival rate / measured service capacity


def build_engine(seed: int = 0):
    """Tiny profiled DFLOP engine: the perf model prices admission, the
    real jax model executes."""
    from repro.core.engine import DFLOPEngine
    from repro.data.synthetic import MixedDataset
    eng = DFLOPEngine(llm_cfg=LLM, enc_cfg=ENC, e_seq_len=16,
                      cluster=ClusterSpec(n_chips=4, chips_per_node=4,
                                          mem_bytes=16e9),
                      tokens_per_media_item=TPM)
    eng.profile(MixedDataset("mixed", seed=seed,
                             tokens_per_media_item=TPM), n_samples=64)
    return eng


def shifted_items(n: int, seed: int, drift_at: float) -> List[DataItem]:
    """Bursty single-image stream that turns video-heavy after the
    ``drift_at`` fraction — the shift opens larger (never-calibrated)
    shape buckets mid-run, which is what must trip the re-price path."""
    rng = np.random.default_rng([seed, 22])
    items = []
    for i in range(n):
        if i >= drift_at * n and rng.random() < 0.7:
            items.append(DataItem(int(rng.integers(4, 7)),
                                  int(rng.integers(16, 33)), "video", i))
        else:
            items.append(DataItem(int(rng.integers(1, 3)),
                                  int(rng.integers(8, 25)),
                                  "single_image", i))
    return items


def make_requests(items: Sequence[DataItem], arrivals: Sequence[float],
                  slos: Sequence[float], max_new: int) -> List[Request]:
    """Fresh Request objects (engine runs mutate them) over shared
    descriptors — both policies replay the identical stream."""
    return [Request(item=it, arrival_s=float(t), slo_s=float(slo),
                    max_new_tokens=max_new)
            for it, t, slo in zip(items, arrivals, slos)]


def run(load_points: Sequence[float] = LOAD_POINTS, n_requests: int = 48,
        seed: int = 0, max_new_tokens: int = 6, drift_at: float = 0.5,
        serve_cfg: Optional[ServeConfig] = None, max_len: int = 128,
        chunk: int = 16, slo_scale: float = 3.0,
        devices=None) -> List[Dict]:
    """Sweep load × {fifo, slo} on the real loop; returns report rows,
    per-load summary rows, and one overall acceptance summary row."""
    from repro.models import model as model_lib
    import jax
    eng = build_engine(seed)
    cfg = serve_cfg if serve_cfg is not None else ServeConfig(
        n_prefill_workers=1, n_decode_workers=1, decode_slots=4,
        max_prefill_batch=4)
    params = model_lib.init(jax.random.PRNGKey(seed), LLM)
    items = shifted_items(n_requests, seed, drift_at)

    # one probe engine up front: its measured unit costs anchor SLOs and
    # arrival rates in wall seconds, so acceptance is machine-independent
    probe_serve = eng.serving(serve_cfg=cfg, backend="real",
                              model_params=params, max_len=max_len,
                              chunk=chunk, devices=devices, trace=False)
    unit = probe_serve.backend.unit_costs
    probe_reqs = make_requests(items, [0.0] * len(items),
                               [1e9] * len(items), max_new_tokens)
    probe_serve.backend.probe(probe_reqs, n_shapes=4)
    pricer = probe_serve.pricer
    handoff = probe_serve.backend.handoff_s_mean()
    ideal = [pricer.price(r) + handoff + pricer.decode_estimate(r)
             for r in probe_reqs]
    # SLO floor in measured units (a handful of decode steps), not wall
    # constants — keeps the pressure point machine-speed independent
    slo_floor = 15.0 * unit["decode_step_s"]
    slos = [slo_floor + slo_scale * v for v in ideal]
    # service capacity: amortized per-request cost at full decode occupancy
    t_req = float(np.mean(
        [r.item.llm_seq_len(TPM) * unit["prefill_s_per_tok"]
         + max_new_tokens * unit["decode_step_s"] / cfg.decode_slots
         for r in probe_reqs]))
    preempt_slack = 20.0 * unit["decode_step_s"]

    rng = np.random.default_rng([seed, 2222])
    rows: List[Dict] = []
    fired_any, err_pairs, wins = 0, [], 0
    for load in load_points:
        qps = load / max(t_req, 1e-9)
        arrivals = np.cumsum(rng.exponential(1.0 / qps, size=n_requests))
        reports, logs = {}, {}
        for policy in ("fifo", "slo"):
            serve = eng.serving(
                admission=policy,
                serve_cfg=ServeConfig(
                    **{**cfg.__dict__, "preempt_slack_s": preempt_slack}),
                backend="real", model_params=params, max_len=max_len,
                chunk=chunk, devices=devices, trace=False,
                drift=PageHinkley(delta=0.005, threshold=0.5, burn_in=8))
            serve.backend.probe(probe_reqs, n_shapes=4)
            reqs = make_requests(items, arrivals, slos, max_new_tokens)
            rep = serve.run(reqs)
            reports[policy] = rep
            logs[policy] = serve
            rows.append({"figure": "fig22", "load": load,
                         "qps": round(qps, 2), **rep.row(),
                         "n_preemptions": serve.n_preemptions,
                         "n_prefill_chunks":
                             serve.metrics.n_prefill_chunks})
        f, s = reports["fifo"], reports["slo"]
        # calibration convergence: early- vs late-run relative error of
        # the corrected predictions against measured durations
        errs = [abs(c / a - 1.0) if a > 0 else np.nan
                for m, c, a in logs["slo"].prediction_log if m == "prefill"]
        q = max(len(errs) // 4, 1)
        err_early = float(np.nanmedian(errs[:q]))
        err_late = float(np.nanmedian(errs[-q:]))
        err_pairs.append((err_early, err_late))
        fired_any += s.n_drift_events
        wins += (s.goodput_rps > f.goodput_rps)
        rows.append({
            "figure": "fig22", "load": load, "summary": True,
            "goodput_fifo_rps": nan_to_none(f.goodput_rps),
            "goodput_slo_rps": nan_to_none(s.goodput_rps),
            "goodput_ratio": s.goodput_rps / max(f.goodput_rps, 1e-12),
            "p99_fifo_s": nan_to_none(f.p99_latency_s),
            "p99_slo_s": nan_to_none(s.p99_latency_s),
            "drift_events_slo": s.n_drift_events,
            "err_early": nan_to_none(err_early),
            "err_late": nan_to_none(err_late),
        })
    rows.append({
        "figure": "fig22", "summary": True, "phase": "acceptance",
        "reprice_fired": bool(fired_any),
        "err_shrank": bool(any(l < e for e, l in err_pairs)),
        "slo_goodput_win": bool(wins >= 1),
        "n_load_points": len(load_points),
    })
    return rows


def run_smoke(seed: int = 0) -> List[Dict]:
    """Tier-1 CI variant: one load point, short stream, tiny knobs — a
    full real-loop pass (warmup + probe + serve) in a few seconds."""
    return run(load_points=(0.9,), n_requests=16, seed=seed,
               max_new_tokens=4, max_len=64, chunk=16,
               serve_cfg=ServeConfig(n_prefill_workers=1,
                                     n_decode_workers=1, decode_slots=2,
                                     max_prefill_batch=2))


if __name__ == "__main__":
    for r in run():
        print(r)
