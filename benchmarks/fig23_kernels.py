"""Fig. 23 (extension): kernel-tier predict-vs-measure validation.

DFLOP's premise is that the planner's duration predictions track what the
hardware does, but until this figure nothing compared *measured* kernel
time against the analytic tables (``core.profiling``) that every
plan/schedule/composition decision is priced from.  Fig. 23 closes that
loop at the lowest layer: it microbenchmarks the three Pallas kernels
(packed flash attention, mamba selective scan, RWKV6 WKV) forward and
forward+backward across the profiler's pow2 shape buckets — the same
``shape_bucket`` keys ``runtime.calibration`` corrects with — and reports
the measured-vs-analytic ratio per bucket (``docs/kernels.md``).

Host-unit normalization (see ``repro.kernels.bench``): one geomean unit
per (kernel, direction) folds out the host constant (CPU interpret mode is
~1e6× a v5e; a real TPU is ~1×), so the per-bucket ratio validates
*shape-scaling fidelity* — the property the planner's relative decisions
depend on.  The same measurements are seeded into ``OnlineCalibrator``
cells (module "llm", the online scheduler's decoder key), maturing every
touched cell past ``min_obs`` so the search prices those buckets from
measured kernel time immediately.

Headline (acceptance, snapshotted to ``BENCH_train.json`` and pinned by
``bench_snapshot --check``): every benchmarked bucket's ratio is finite
and within the declared band — by construction the geomean of each group
is exactly 1, so the band bounds how far any single bucket's scaling
deviates from the FLOP model.
"""
from __future__ import annotations

import json
import math
import os
from typing import Dict, List, Optional, Sequence

from repro.kernels.bench import bench_kernel, normalize, seed_calibrator
from repro.runtime.calibration import OnlineCalibrator

KERNELS = ("attention", "mamba", "rwkv6")

# |log(ratio)| ≤ log(BAND): a bucket may deviate at most BAND× from the
# FLOP model's scaling.  Interpret-mode timings are noisy (Python dispatch
# amortizes differently across sizes), so the band is wide; on a real TPU
# the same harness should hold a much tighter one.
BAND = 8.0


def run(seqs: Sequence[int] = (128, 256, 512), iters: int = 3,
        kernels: Sequence[str] = KERNELS, band: float = BAND,
        out_dir: Optional[str] = None) -> List[Dict]:
    """Bench ``kernels`` × ``seqs`` fwd/fwd+bwd; returns ratio rows + a
    summary row carrying the band acceptance booleans."""
    rows: List[Dict] = []
    for kernel in kernels:
        rows.extend(bench_kernel(kernel, seqs, iters=iters))
    normalize(rows)

    cal = OnlineCalibrator()
    n_obs = seed_calibrator(cal, rows)
    mature = [c for c in cal.cells.values() if c.n >= cal.min_obs]

    ratios = [r["ratio"] for r in rows]
    finite = all(math.isfinite(x) for x in ratios)
    within = finite and all(1.0 / band <= x <= band for x in ratios)
    fig_rows: List[Dict] = [{
        "figure": "fig23", "kernel": r["kernel"], "direction": r["direction"],
        "tokens": r["tokens"], "bucket": r["bucket"], "flops": r["flops"],
        "analytic_s": r["analytic_s"], "measured_s": r["measured_s"],
        "unit": r["unit"], "ratio": r["ratio"],
    } for r in rows]
    # measured fields are wall-clock noise: the summary row pins only the
    # structural facts (coverage + band acceptance), like fig22
    fig_rows.append({
        "figure": "fig23", "summary": True,
        "kernels": list(kernels), "seqs": [int(s) for s in seqs],
        "n_rows": len(rows),
        "n_buckets": len({(r["kernel"], r["direction"], r["bucket"])
                          for r in rows}),
        "band": band,
        "ratios_finite": finite,
        "ratios_within_band": within,
        "calibrator_obs": n_obs,
        "calibrator_cells_mature": len(mature),
    })
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, "fig23_kernels.json")
        with open(path, "w", encoding="utf-8") as f:
            json.dump(fig_rows, f, indent=2)
        print(f"wrote {path}")
    return fig_rows


def run_smoke() -> List[Dict]:
    """Tier-1 CI entry: tiny shapes, 2 iterations (~seconds)."""
    return run(seqs=(64, 128), iters=2)


if __name__ == "__main__":
    out = run(out_dir="benchmarks/results")
    print(json.dumps(out[-1], indent=2))
