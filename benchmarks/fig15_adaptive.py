"""Fig. 15: cost-benefit of Adaptive Correction with injected anomalies.

The paper injects synthetic delays into a subset of *input shapes* (rare
shapes hitting slow kernels); anomaly rate = fraction of items affected,
magnitude = latency delta relative to the predicted duration.  Net speedup =
avoided mis-scheduling − monitoring cost (~4%); the mechanism must stay off
when that is negative and on when positive.
"""
from __future__ import annotations

from collections import Counter

import numpy as np

from benchmarks.common import POD_CLUSTER, engine_for
from repro.core.scheduler.adaptive import AdaptiveCorrection
from repro.core.scheduler.lpt import cmax, lpt_schedule

MONITOR_COST = 0.04


def _anomalous_buckets(items, sched, rate, rng):
    """Rarest shape buckets covering ~`rate` of the items (paper §3.4.3:
    'a small subset of rare input shapes')."""
    buckets = [AdaptiveCorrection.bucket(it.llm_seq_len(sched.tpm))
               for it in items]
    freq = Counter(buckets)
    order = sorted(freq, key=freq.get)
    chosen, covered = set(), 0
    for b in order:
        if covered / len(items) >= rate:
            break
        chosen.add(b)
        covered += freq[b]
    return chosen


def run(arch: str = "llava-ov-llama8b", gbs: int = 128, n_iters: int = 20):
    eng = engine_for(arch, POD_CLUSTER)
    eng.plan(gbs)
    rows = []
    rng = np.random.default_rng(0)
    probe = eng.dataset.sample(4096)
    for rate, rate_name in ((0.01, "low"), (0.03, "medium"), (0.05, "high")):
        for magnitude in (0.25, 0.5, 1.0):
            corr = AdaptiveCorrection(monitoring_cost=MONITOR_COST,
                                      window=256)
            sched = eng.scheduler(adaptive=False, ilp_time_limit_s=0.05)
            sched.adaptive = corr
            anomalous = _anomalous_buckets(probe, sched, rate, rng)
            uncorr_gap = corr_gap = 0.0
            cnt = 0
            for it_idx in range(n_iters):
                items = eng.dataset.sample(gbs)
                e_dur, l_dur = sched.item_durations(items)
                true_l = l_dur.copy()
                for i, item in enumerate(items):
                    if AdaptiveCorrection.bucket(
                            item.llm_seq_len(sched.tpm)) in anomalous:
                        true_l[i] *= (1 + magnitude)
                out = sched.schedule(items)        # uses corrected preds
                for i, item in enumerate(items):
                    sched.observe("llm", item.llm_seq_len(sched.tpm),
                                  float(l_dur[i]), float(true_l[i]))
                if it_idx < n_iters // 2:
                    continue                        # warm-up
                oracle = cmax(e_dur, true_l,
                              lpt_schedule(e_dur, true_l, sched.n_buckets))
                got = cmax(e_dur, true_l, out.groups)
                # what an uncorrected scheduler would have done
                naive = cmax(e_dur, true_l,
                             lpt_schedule(e_dur, l_dur, sched.n_buckets))
                corr_gap += got / max(oracle, 1e-12) - 1.0
                uncorr_gap += naive / max(oracle, 1e-12) - 1.0
                cnt += 1
            benefit = (uncorr_gap - corr_gap) / max(cnt, 1)
            rows.append({
                "figure": "fig15", "rate": rate_name, "magnitude": magnitude,
                "tracker_enabled": corr.enabled,
                "correction_benefit": benefit,
                "net_speedup": benefit - MONITOR_COST if corr.enabled
                else 0.0,
            })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
