"""Fig. 13: GPU idle time from pipeline bubbles (paper: DFLOP cuts measured
idle time by 82–84% vs PyTorch/Megatron on the mixed dataset)."""
from __future__ import annotations

from benchmarks.common import POD_CLUSTER, engine_for, run_system
from repro.core.pipeline.simulator import ideal_bubble_fraction


def run(arch: str = "llava-ov-llama8b", gbs: int = 128, n_iters: int = 8):
    eng = engine_for(arch, POD_CLUSTER)
    eng.plan(gbs)
    base = run_system(eng, "baseline", gbs, n_iters=n_iters)
    dflop = run_system(eng, "dflop", gbs, n_iters=n_iters)
    e, l = dflop["plan"][1], dflop["plan"][4]
    p_df = (e or 0) + l
    rows = [{
        "figure": "fig13",
        "arch": arch,
        "baseline_idle_s": base["idle_time_s"],
        "dflop_idle_s": dflop["idle_time_s"],
        "idle_reduction": 1.0 - dflop["idle_time_s"] / max(base["idle_time_s"], 1e-12),
        "baseline_idle_fraction": base["idle_fraction"],
        "dflop_idle_fraction": dflop["idle_fraction"],
        "dflop_ideal_bubble": ideal_bubble_fraction(p_df, dflop["plan"][6]),
    }]
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
