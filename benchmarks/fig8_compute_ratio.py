"""Fig. 8: DFLOP's gain vs the encoder/LLM computational-load ratio.

Paper: "the performance advantage of DFLOP amplifies as the computational
loads between the two modules become more balanced."  We sweep the ratio by
varying the connector token budget (more media tokens -> heavier encoder).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import POD_CLUSTER, engine_for, run_system
from repro.configs import get_config
from repro.core.profiling.flops import module_flops


def run(gbs: int = 128, n_iters: int = 4):
    rows = []
    for arch in ("llava-ov-qwen7b", "llava-ov-llama8b", "internvl2-2b",
                 "qwen2-audio-7b"):
        spec = get_config(arch)
        eng = engine_for(arch, POD_CLUSTER)
        eng.plan(gbs)
        base = run_system(eng, "baseline", gbs, n_iters=n_iters)
        dflop = run_system(eng, "dflop", gbs, n_iters=n_iters)
        # FLOP ratio at the dataset mean shapes
        mean_b, mean_s = eng.dist.mean()
        e_fl = module_flops(spec.desc.encoder, mean_b,
                            spec.desc.stub.n_tokens, mode="train").total
        l_fl = module_flops(spec.desc.llm, 1, mean_s, mode="train").total
        rows.append({
            "figure": "fig8", "arch": arch,
            "enc_llm_flop_ratio": e_fl / l_fl,
            "gain": dflop["throughput_tokens_per_s"]
            / base["throughput_tokens_per_s"],
        })
    rows.sort(key=lambda r: r["enc_llm_flop_ratio"])
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
