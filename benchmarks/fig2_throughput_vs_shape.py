"""Fig. 2: throughput variation with input shape × TP degree.

Paper's motivation figure: encoder throughput degrades with TP at small
effective batch; LLM throughput varies with sequence length × TP.  Here the
curves come from the calibrated v5e analytic backend (the same model the
Profiling Engine interpolates).
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core.profiling.analytic import AnalyticBackend, V5E


def run():
    backend = AnalyticBackend(V5E)
    spec = get_config("llava-ov-qwen7b")
    enc, llm = spec.desc.encoder, spec.desc.llm
    rows = []
    base_e = {b: backend.throughput(enc, b, spec.desc.stub.n_tokens, 1,
                                    mode="train") for b in (1, 2, 4, 8, 16, 32)}
    base_l = {s: backend.throughput(llm, 1, s, 1, mode="train")
              for s in (512, 2048, 8192, 32768)}
    for tp in (1, 2, 4, 8, 16):
        for b in (1, 2, 4, 8, 16, 32):
            thr = backend.throughput(enc, b, spec.desc.stub.n_tokens, tp,
                                     mode="train")
            rows.append({"figure": "fig2a", "module": "encoder(siglip)",
                         "eff_batch": b, "tp": tp,
                         "per_chip_flops_per_s": thr / tp,
                         "tp_efficiency": thr / tp / base_e[b]})
        for s in (512, 2048, 8192, 32768):
            thr = backend.throughput(llm, 1, s, tp, mode="train")
            rows.append({"figure": "fig2b", "module": "llm(qwen2.5-7b)",
                         "seq_len": s, "tp": tp,
                         "per_chip_flops_per_s": thr / tp,
                         "tp_efficiency": thr / tp / base_l[s]})
    return rows


def degradation_summary(rows):
    """Per-chip TP=16 vs TP=1 efficiency at the smallest shape (the paper's
    headline effect: small fragments under-utilize at high TP)."""
    enc = {(r["eff_batch"], r["tp"]): r["tp_efficiency"]
           for r in rows if r["figure"] == "fig2a"}
    return enc[(1, 16)]


if __name__ == "__main__":
    for r in run():
        print(r)
