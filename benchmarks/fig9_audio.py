"""Fig. 9: cross-modal generalization — Qwen2-Audio-style MLLM.

Paper: 2x–4x throughput gain on the audio modality, attributed to the
pooled connector balancing encoder/LLM compute.
"""
from __future__ import annotations

from benchmarks.common import POD_CLUSTER, engine_for, run_system


def run(gbs: int = 128, n_iters: int = 6):
    eng = engine_for("qwen2-audio-7b", POD_CLUSTER, mixture="audio")
    eng.plan(gbs)
    base = run_system(eng, "baseline", gbs, n_iters=n_iters)
    dflop = run_system(eng, "dflop", gbs, n_iters=n_iters)
    return [{
        "figure": "fig9",
        "arch": "qwen2-audio-7b",
        "gain": dflop["throughput_tokens_per_s"]
        / base["throughput_tokens_per_s"],
        "baseline_tok_s": base["throughput_tokens_per_s"],
        "dflop_tok_s": dflop["throughput_tokens_per_s"],
    }]


if __name__ == "__main__":
    for r in run():
        print(r)
