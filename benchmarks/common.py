"""Shared benchmark setup: perf models, clusters, and the system simulator.

Benchmarks evaluate three *systems* on the same workload, mirroring §5:

  * ``baseline``    — data-agnostic uniform 3D parallelism (best feasible
                      (tp, pp) grid point, Megatron/PyTorch-style) + random
                      microbatch assignment.
  * ``dflop``       — Data-aware 3D Parallelism Optimizer plan + Online
                      Microbatch Scheduler (hybrid ILP/LPT).
  * ablations      — ``opt-only`` (DFLOP plan + random microbatches) and
                      ``sched-only`` (baseline plan + balanced microbatches),
                      reproducing Fig. 10.

End-to-end iteration time comes from the discrete-event 1F1B simulator fed
with per-bucket stage durations predicted by the Profiling Engine's models —
the same machinery the DFLOP components themselves use, evaluated on
*different* random global batches than the ones the optimizer saw.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.configs import get_config
from repro.core.engine import DFLOPEngine
from repro.core.optimizer.space import ClusterSpec, ModuleParallelism, ParallelismPlan
from repro.core.pipeline.simulator import simulate_bucket_ranks_batch
from repro.core.profiling.analytic import AnalyticBackend, V5E
from repro.core.scheduler.online import OnlineMicrobatchScheduler
from repro.data.synthetic import MixedDataset

BWD_OVER_FWD = 2.0


def engine_for(arch_id: str, cluster: ClusterSpec, mixture: str = "mixed",
               seed: int = 0, n_samples: int = 1024) -> DFLOPEngine:
    spec = get_config(arch_id)
    ds = MixedDataset(mixture, seed=seed,
                      tokens_per_media_item=spec.tokens_per_media_item or 196)
    eng = DFLOPEngine(
        llm_cfg=spec.llm_cfg,
        enc_cfg=spec.desc.encoder if spec.is_mllm else None,
        e_seq_len=spec.desc.stub.n_tokens if spec.is_mllm else 0,
        cluster=cluster,
        tokens_per_media_item=spec.tokens_per_media_item or 196,
        backend=AnalyticBackend(V5E),
    )
    eng.profile(ds, n_samples=n_samples)
    eng.dataset = ds
    return eng


def best_uniform_baseline(eng: DFLOPEngine, gbs: int):
    """Grid-tuned uniform plan ('manually tuned following best practices')."""
    best, best_T = None, float("inf")
    for tp in (1, 2, 4, 8, 16):
        for pp in (1, 2, 4, 8):
            res = eng.baseline_plan(gbs, tp=tp, pp=pp)
            if res.found and res.makespan < best_T:
                best, best_T = res, res.makespan
    return best


@dataclass
class IterStats:
    step_time: float
    idle_time: float            # summed over stages & dp ranks
    busy_time: float
    stage_busy: np.ndarray      # (p,) mean across ranks
    stage_flops: np.ndarray
    tokens: int


def simulate_iteration(plan: ParallelismPlan,
                       sched: OnlineMicrobatchScheduler,
                       items, *, random_assign: bool, seed: int = 0,
                       mode: str = "train") -> IterStats:
    """Play one scheduled global batch through the pipeline simulator
    (the plan's own schedule family — 1F1B, interleaved, or encoder_fill).

    Bucket durations come from `ScheduleOutput.e_dur/l_dur` (already
    per-stage: the scheduler divides by the module's PP degree); the
    bucket→(mb, rank) layout, per-stage rows and fwd/bwd split live in
    `simulate_bucket_ranks_batch` — the same code path the search
    objectives score with, so figures and objective predictions share one
    model.  All dp ranks are simulated in a single vectorized call (no op
    recording — see `docs/simulator.md`)."""
    out = (sched.schedule_random(items, seed=seed) if random_assign
           else sched.schedule(items))
    n_mb, dp = plan.n_mb, plan.llm.dp
    e_dur, l_dur = out.e_dur, out.l_dur
    e_pp = plan.encoder.pp if plan.encoder else 0
    e_b = np.array([float(e_dur[g].sum()) if len(g) else 0.0
                    for g in out.groups])
    l_b = np.array([float(l_dur[g].sum()) if len(g) else 0.0
                    for g in out.groups])
    ranks = simulate_bucket_ranks_batch(e_b, l_b, n_mb=n_mb, dp=dp,
                                        e_pp=e_pp, l_pp=plan.llm.pp,
                                        bwd_over_fwd=BWD_OVER_FWD,
                                        backward=(mode == "train"),
                                        schedule=plan.schedule)
    step_time = float(ranks.makespan.max())
    idle = float(ranks.total_idle.sum())
    busy = float(ranks.stage_busy.sum())
    stage_busy_acc = ranks.stage_busy.sum(axis=0)
    tokens = sum(it.llm_seq_len(sched.tpm) for it in items)
    # stage FLOPs (fwd+bwd) for Fig. 14 stage-throughput
    perf = sched.perf
    e_fl = sum(perf.encoder.flops(it.encoder_batch(), perf.encoder.fixed_seq,
                                  "train").total
               for it in items) if perf.encoder and plan.encoder else 0.0
    l_fl = sum(perf.llm.flops(1, it.llm_seq_len(sched.tpm), "train").total
               for it in items)
    # per-CHIP stage FLOPs (Fig. 14 compares chip utilization across stages)
    stage_fl = []
    if plan.schedule == "encoder_fill":
        # encoder replicated on the LLM ranks: l_pp stages, each retiring
        # its share of both modules' work on the LLM's own chips.
        chips = max(plan.llm.chips / plan.llm.pp, 1)
        stage_fl = [(e_fl + l_fl) / plan.llm.pp / chips] * plan.llm.pp
    else:
        if plan.encoder:
            chips = max(plan.encoder.chips / e_pp, 1)
            stage_fl += [e_fl / e_pp / chips] * e_pp
        chips = max(plan.llm.chips / plan.llm.pp, 1)
        stage_fl += [l_fl / plan.llm.pp / chips] * plan.llm.pp
    return IterStats(step_time, idle, busy, stage_busy_acc / dp,
                     np.asarray(stage_fl), tokens)


def run_system(eng: DFLOPEngine, system: str, gbs: int, *, n_iters: int = 8,
               seed: int = 1) -> Dict:
    """system in {baseline, dflop, opt-only, sched-only}."""
    if system in ("baseline", "sched-only"):
        res = best_uniform_baseline(eng, gbs)
    else:
        res = eng.plan_result or eng.plan(gbs)
    plan = res.plan
    sched = eng.scheduler(plan=plan, adaptive=False, ilp_time_limit_s=0.1)
    random_assign = system in ("baseline", "opt-only")
    rng = np.random.default_rng(seed)
    stats: List[IterStats] = []
    for i in range(n_iters):
        items = eng.dataset.sample(gbs)
        stats.append(simulate_iteration(plan, sched, items,
                                        random_assign=random_assign,
                                        seed=int(rng.integers(1 << 31))))
    tokens = sum(s.tokens for s in stats)
    total_time = sum(s.step_time for s in stats)
    p = len(stats[0].stage_busy)
    return {
        "system": system,
        "plan": plan.as_tuple(),
        "throughput_tokens_per_s": tokens / total_time,
        "step_time_s": total_time / n_iters,
        "idle_time_s": sum(s.idle_time for s in stats) / n_iters,
        "busy_time_s": sum(s.busy_time for s in stats) / n_iters,
        "idle_fraction": (sum(s.idle_time for s in stats)
                          / max(sum(s.idle_time + s.busy_time for s in stats),
                                1e-12)),
        "stage_throughputs": [
            list(s.stage_flops / np.maximum(s.stage_busy, 1e-12))
            for s in stats],
        "n_stages": p,
    }


DEFAULT_CLUSTER = ClusterSpec(n_chips=32, chips_per_node=8, mem_bytes=80e9,
                              name="4-node 8xA100-like")
POD_CLUSTER = ClusterSpec(n_chips=256, chips_per_node=16, mem_bytes=16e9,
                          name="v5e pod")
