"""Fig. 20 (extension): schedule-family search on encoder-heavy mixtures.

DFLOP's planner (Algorithm 1) repartitions (tp, pp, dp, n_mb) under a
fixed 1F1B schedule.  This figure adds the *schedule family* to the search
(``docs/schedules.md``): Megatron-style interleaved virtual stages and
Optimus-style encoder-in-bubble (``encoder_fill``), searched jointly with
the partition via ``ParallelismOptimizer(schedules=...)``.

Two searches over the same profiled engine and shape distribution:

  * ``1f1b``  — the historical fixed-schedule search
    (``schedules=("1f1b",)``), exactly what every earlier figure ran;
  * ``joint`` — all of ``space.SCHEDULES``; the optimizer may keep 1F1B,
    interleave it, or replicate the encoder onto the LLM ranks.

Each winning plan is then **emulated**: real sampled global batches from
the encoder-heavy mixture, balanced by the real Online Scheduler, played
through the event-driven schedule simulator
(`benchmarks.common.simulate_iteration` — the same per-op wavefront the
property tests pin against the reference event loops).  Reported per
system: predicted (search) makespan, emulated step time, and emulated
bubble fraction; the summary row carries the ratios.

Headline (acceptance, pinned by the slow test in
``tests/test_schedules.py`` and snapshotted to ``BENCH_train.json``):
the jointly-searched schedule reaches **≥ 1.1× lower emulated step
makespan** than the 1F1B-restricted search on an encoder-heavy mixture,
with a strictly lower emulated bubble fraction.

Why encoder-heavy: a video-dominated mixture puts a large fraction of the
step's FLOPs in the encoder, so under 1F1B either (a) dedicated encoder
stages deepen the pipeline (more bubble slots) or (b) few encoder chips
bottleneck the first stage.  ``encoder_fill`` dissolves the trade-off —
the encoder rides the LLM ranks inside bubbles that 1F1B pays anyway —
and interleaving shrinks whatever warmup/drain remains.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from benchmarks.common import DEFAULT_CLUSTER, engine_for, simulate_iteration
from repro.core.optimizer.space import SCHEDULES, ClusterSpec

SYSTEMS = {"1f1b": ("1f1b",), "joint": SCHEDULES}


def run(arch: str = "llava-ov-llama8b", gbs: int = 16, n_iters: int = 8,
        mixture: str = "video", seed: int = 0,
        cluster: Optional[ClusterSpec] = None) -> List[Dict]:
    """Search {1f1b-only, joint} × emulate; returns fig rows + a summary."""
    cluster = cluster if cluster is not None else DEFAULT_CLUSTER
    eng = engine_for(arch, cluster, mixture=mixture, seed=seed)
    rng = np.random.default_rng([seed, 20])
    # every system replays the *same* sampled global batches
    batches = [eng.dataset.sample(gbs) for _ in range(n_iters)]
    iter_seeds = [int(rng.integers(1 << 31)) for _ in range(n_iters)]

    rows: List[Dict] = []
    emu: Dict[str, float] = {}
    bubble: Dict[str, float] = {}
    for system, scheds in SYSTEMS.items():
        res = eng.plan(gbs, schedules=scheds)
        assert res.found, f"{system}: no feasible plan"
        plan = res.plan
        sched = eng.scheduler(plan=plan, adaptive=False,
                              ilp_time_limit_s=0.05)
        stats = [simulate_iteration(plan, sched, items,
                                    random_assign=False, seed=s)
                 for items, s in zip(batches, iter_seeds)]
        emu[system] = float(np.mean([st.step_time for st in stats]))
        idle = sum(st.idle_time for st in stats)
        busy = sum(st.busy_time for st in stats)
        bubble[system] = idle / max(idle + busy, 1e-12)
        rows.append({
            "figure": "fig20", "system": system,
            "schedules_searched": list(scheds),
            "plan": list(plan.as_tuple()),
            "schedule": plan.schedule,
            "pred_makespan_s": res.makespan,
            "emulated_step_s": emu[system],
            "emulated_bubble_fraction": bubble[system],
        })
    rows.append({
        "figure": "fig20", "summary": True, "mixture": mixture,
        "gbs": gbs, "n_chips": cluster.n_chips,
        "joint_schedule": rows[1]["schedule"],
        "sim_speedup": emu["1f1b"] / max(emu["joint"], 1e-12),
        "bubble_1f1b": bubble["1f1b"], "bubble_joint": bubble["joint"],
        "pred_speedup": (rows[0]["pred_makespan_s"]
                         / max(rows[1]["pred_makespan_s"], 1e-12)),
    })
    return rows


def run_smoke(seed: int = 0) -> List[Dict]:
    """Tier-1 CI variant: tiny batch count, same acceptance regime —
    exercises both searches and the emulation loop in seconds."""
    return run(gbs=16, n_iters=2, seed=seed)


if __name__ == "__main__":
    for r in run():
        print(r)
