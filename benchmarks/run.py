"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per artifact and writes the
full JSON to benchmarks/results/benchmarks.json.

    PYTHONPATH=src python -m benchmarks.run [--only fig7,fig13]
"""
from __future__ import annotations

import argparse
import json
import os
import time


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    from benchmarks import (  # noqa: E402  (heavy imports after argparse)
        fig2_throughput_vs_shape,
        fig4_stage_durations,
        fig7_end_to_end,
        fig8_compute_ratio,
        fig9_audio,
        fig10_ablation,
        fig11_datasets,
        fig12_scaling,
        fig13_bubbles,
        fig14_stage_throughput,
        fig15_adaptive,
        fig16_replan,
        fig17_objective,
        fig18_composer,
        roofline,
        tab4_overhead,
    )

    modules = {
        "fig2": fig2_throughput_vs_shape,
        "fig4": fig4_stage_durations,
        "fig7": fig7_end_to_end,
        "fig8": fig8_compute_ratio,
        "fig9": fig9_audio,
        "fig10": fig10_ablation,
        "fig11": fig11_datasets,
        "fig12": fig12_scaling,
        "fig13": fig13_bubbles,
        "fig14": fig14_stage_throughput,
        "fig15": fig15_adaptive,
        "fig16": fig16_replan,
        "fig17": fig17_objective,
        "fig18": fig18_composer,
        "tab4": tab4_overhead,
        "roofline": roofline,
    }
    only = set(args.only.split(",")) if args.only else None

    all_rows = {}
    print("name,us_per_call,derived")
    for name, mod in modules.items():
        if only and name not in only:
            continue
        t0 = time.monotonic()
        try:
            rows = mod.run()
        except Exception as e:  # noqa: BLE001
            print(f"{name},ERROR,{type(e).__name__}: {e}")
            continue
        dt_us = (time.monotonic() - t0) * 1e6
        all_rows[name] = rows
        for r in rows:
            derived = ";".join(f"{k}={_fmt(v)}" for k, v in r.items()
                               if k not in ("figure",))
            print(f"{name},{dt_us / max(len(rows), 1):.0f},{derived}")
    out = os.path.join(os.path.dirname(__file__), "results")
    os.makedirs(out, exist_ok=True)
    with open(os.path.join(out, "benchmarks.json"), "w") as f:
        json.dump(all_rows, f, indent=1, default=str)


if __name__ == "__main__":
    main()
