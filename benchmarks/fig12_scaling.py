"""Fig. 12: cluster scalability — the DFLOP/baseline gap widens with node
count (straggler mitigation + richer search space)."""
from __future__ import annotations

import dataclasses

from benchmarks.common import engine_for, run_system
from repro.core.optimizer.space import ClusterSpec


def run(arch: str = "llava-ov-llama8b", n_iters: int = 4):
    rows = []
    for n_chips in (32, 64, 128, 256):
        cluster = ClusterSpec(n_chips=n_chips, chips_per_node=16,
                              mem_bytes=16e9)
        gbs = max(64, n_chips)
        eng = engine_for(arch, cluster)
        eng.plan(gbs)
        base = run_system(eng, "baseline", gbs, n_iters=n_iters)
        dflop = run_system(eng, "dflop", gbs, n_iters=n_iters)
        rows.append({
            "figure": "fig12", "arch": arch, "n_chips": n_chips, "gbs": gbs,
            "baseline_tok_s": base["throughput_tokens_per_s"],
            "dflop_tok_s": dflop["throughput_tokens_per_s"],
            "gain": dflop["throughput_tokens_per_s"]
            / base["throughput_tokens_per_s"],
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
