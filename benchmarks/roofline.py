"""§Roofline: three-term roofline analysis from the dry-run artifacts.

    compute term    = HLO_FLOPs(per chip) / peak_FLOP/s
    memory term     = HLO_bytes(per chip) / HBM_bw
    collective term = collective_bytes(per chip) / link_bw

HLO statistics come from ``repro.launch.hlo_stats`` (post-SPMD, per-device,
while-trip-count corrected).  Hardware constants: TPU v5e — 197 TFLOP/s
bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load_records(dryrun_dir: str = DRYRUN_DIR):
    recs = []
    for fn in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def roofline_row(rec: dict) -> dict:
    hlo = rec["hlo"]
    n = rec["n_chips"]
    t_compute = hlo["flops"] / PEAK_FLOPS          # per-chip flops already
    t_memory = hlo["hbm_bytes"] / HBM_BW
    t_coll = hlo["total_collective_bytes"] / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    model_fl_chip = rec["model_flops"] / n
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"],
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "bound": dominant,
        "step_lower_bound_s": max(terms.values()),
        "model_flops_per_chip": model_fl_chip,
        "useful_flop_ratio": model_fl_chip / max(hlo["flops"], 1.0),
        "peak_mem_gb": rec["memory"]["peak_per_chip"] / 1e9,
        "fits_16gb": rec.get("fits_16gb"),
        "compile_s": rec.get("compile_s"),
        "mfu_bound": model_fl_chip / PEAK_FLOPS / max(terms.values()) if
        max(terms.values()) > 0 else 0.0,
    }


def run(mesh: str = "16x16"):
    rows = []
    for rec in load_records():
        if not rec.get("ok") or rec.get("skipped"):
            if rec.get("skipped"):
                rows.append({"arch": rec["arch"], "shape": rec["shape"],
                             "mesh": rec["mesh"], "bound": "skipped",
                             "reason": rec.get("reason", "")})
            continue
        if mesh and rec["mesh"] != mesh:
            continue
        rows.append(roofline_row(rec))
    return rows


def summary_table(rows):
    lines = ["arch,shape,mesh,bound,compute_s,memory_s,collective_s,"
             "useful_flop_ratio,mfu_bound,peak_gb,fits"]
    for r in rows:
        if r["bound"] == "skipped":
            continue
        lines.append(
            f"{r['arch']},{r['shape']},{r['mesh']},{r['bound']},"
            f"{r['compute_s']:.4g},{r['memory_s']:.4g},"
            f"{r['collective_s']:.4g},{r['useful_flop_ratio']:.3f},"
            f"{r['mfu_bound']:.3f},{r['peak_mem_gb']:.2f},{r['fits_16gb']}")
    return "\n".join(lines)


if __name__ == "__main__":
    print(summary_table(run(mesh="")))
