"""Fig. 14: distribution of per-stage throughput — DFLOP achieves higher
mean and lower variance across pipeline stages."""
from __future__ import annotations

import numpy as np

from benchmarks.common import POD_CLUSTER, engine_for, run_system


def run(arch: str = "llava-ov-llama8b", gbs: int = 128, n_iters: int = 8):
    eng = engine_for(arch, POD_CLUSTER)
    eng.plan(gbs)
    rows = []
    for system in ("baseline", "dflop"):
        r = run_system(eng, system, gbs, n_iters=n_iters)
        flat = np.array(r["stage_throughputs"]).reshape(-1)
        rows.append({
            "figure": "fig14", "arch": arch, "system": system,
            "stage_thr_mean": float(flat.mean()),
            "stage_thr_std": float(flat.std()),
            "stage_thr_cv": float(flat.std() / flat.mean()),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
