"""Fig. 16 (extension): throughput recovery via continuous re-planning.

Injects a mid-run shape-distribution shift (single-image mixture → video
mixture: media counts jump from 1 to 8–32 per item) and runs the
`repro.runtime` control loop over it.  The drift detector fires on the KS
distance between the profiled reference distribution and the recent shape
window, `ParallelismOptimizer.search()` re-runs in the background over
that window, and the new plan is hot-swapped between global batches.

Reported per phase: predicted makespan of the *active* plan vs. a
scheduler pinned to the *stale* pre-shift plan on identical batches.  The
summary row gives the recovery ratio (stale / re-planned makespan after
the shift).  A Chrome trace of the run is written next to the results.

``physical=True`` additionally threads a live stage-stacked param pytree
(a scaled-down stand-in for the LLM stack — real arrays, real re-stack +
`device_put`, emulated on the local devices) through a
`repro.launch.reshard.ParamSwapper`: the hot-swap then pays a *measured*
reshard cost, the controller gates on its amortization, and the summary
reports recovery **net of** that cost (`recovery_ratio_net`) alongside
the gross ratio — layout reconfiguration modeled, not assumed free.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import POD_CLUSTER, engine_for
from repro.data.synthetic import MixedDataset

TRACE_PATH = os.path.join(os.path.dirname(__file__), "results",
                          "fig16_replan_trace.json")
TRACE_PATH_PHYSICAL = os.path.join(os.path.dirname(__file__), "results",
                                   "fig16_replan_physical_trace.json")


def _synthetic_stacked_params(n_layers: int, pp: int, width: int = 128):
    """Stage-stacked stand-in for the LLM stack: one (L, width, width)
    leaf per weight family.  Real arrays so the reshard's re-stack and
    device placement do real work; width is scaled down so the benchmark
    stays light (the report's bytes are for the stand-in)."""
    import jax
    from repro.core.pipeline.executor import stack_stage_params

    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    flat = {f"w{i}": jax.random.normal(k, (n_layers, width, width))
            for i, k in enumerate(keys)}
    if n_layers % pp:
        return flat, False                    # un-stackable pp: flat leaves
    return stack_stage_params(flat, pp), True


def run(arch: str = "llava-ov-llama8b", gbs: int = 64,
        n_pre: int = 6, n_post: int = 24, seed: int = 0,
        step_wall_s: float = 0.15, physical: bool = False,
        swap_horizon_batches: int = 50):
    """step_wall_s emulates the accelerator step each iteration overlaps:
    the paper's background re-plan lands *during* training, so the loop
    must spend wall time per batch the way a real run would (scheduling
    itself is now sub-ms and no longer provides it)."""
    eng = engine_for(arch, POD_CLUSTER, mixture="single_image", seed=seed)
    eng.plan(gbs)
    swapper = None
    live = None
    if physical:
        from repro.launch.reshard import ParamSwapper, clamped_plan_mesh

        pp0 = eng.plan_result.plan.llm.pp
        params, stacked = _synthetic_stacked_params(
            eng.llm_cfg.n_layers, pp0)
        live = {"params": params}
        swapper = ParamSwapper(lambda: live["params"],
                               lambda p: live.update(params=p),
                               stage_stacked=stacked, strict=False,
                               mesh_factory=clamped_plan_mesh)
    ctl = eng.runtime(gbs, adaptive=False, ilp_time_limit_s=0.05,
                      param_swapper=swapper,
                      swap_horizon_batches=swap_horizon_batches)
    stale_plan = ctl.plan
    # identical predictions, pinned to the pre-shift plan for comparison
    stale_sched = eng.scheduler(plan=stale_plan, adaptive=False,
                                ilp_time_limit_s=0.05)

    tpm = eng.tokens_per_media_item
    pre_ds = MixedDataset("single_image", seed=seed,
                          tokens_per_media_item=tpm)
    post_ds = MixedDataset("video", seed=seed + 1,
                           tokens_per_media_item=tpm)

    rows = []
    swap_iter = None
    for i in range(n_pre + n_post):
        phase = "pre" if i < n_pre else "post"
        items = (pre_ds if phase == "pre" else post_ds).sample(gbs)
        out = ctl.schedule(items)
        if step_wall_s:
            time.sleep(step_wall_s)       # the "training step" runs here
        if swap_iter is None and ctl.metrics.n_replans > 0:
            swap_iter = i
        stale_out = stale_sched.schedule(items)
        rows.append({
            "figure": "fig16", "iter": i, "phase": phase,
            "replanned": ctl.metrics.n_replans > 0,
            "makespan_active_s": float(out.step_makespan),
            "makespan_stale_s": float(stale_out.step_makespan),
            "imbalance": float(out.imbalance),
        })
    # make sure an in-flight search lands before summarizing
    ctl.drain(timeout=60.0)

    post_rows = [r for r in rows if r["phase"] == "post"]
    recovered = [r for r in post_rows if r["replanned"]]
    stale_mean = float(np.mean([r["makespan_stale_s"] for r in post_rows]))
    active_mean = (float(np.mean([r["makespan_active_s"] for r in recovered]))
                   if recovered else stale_mean)
    summary = {
        "figure": "fig16", "iter": -1, "phase": "summary",
        "plan_before": list(stale_plan.as_tuple()),
        "plan_after": list(ctl.plan.as_tuple()),
        "swap_iter": swap_iter if swap_iter is not None else -1,
        "n_drift_events": ctl.metrics.n_drift_events,
        "n_replans": ctl.metrics.n_replans,
        "post_shift_stale_makespan_s": stale_mean,
        "post_shift_replanned_makespan_s": active_mean,
        "recovery_ratio": stale_mean / max(active_mean, 1e-12),
    }
    if physical:
        # recovery net of reshard: the one-off re-layout cost is amortized
        # over the batches that actually ran under the recovered plan.
        reshard_total = float(sum(r.elapsed_s for r in swapper.reports))
        effective = active_mean + reshard_total / max(len(recovered), 1)
        summary.update({
            "n_physical_swaps": ctl.metrics.n_physical_swaps,
            "reshard_s_total": reshard_total,
            "reshard_bytes_moved": int(sum(r.bytes_moved
                                           for r in swapper.reports)),
            "post_shift_replanned_makespan_net_s": effective,
            "recovery_ratio_net": stale_mean / max(effective, 1e-12),
        })
    rows.append(summary)
    trace_path = TRACE_PATH_PHYSICAL if physical else TRACE_PATH
    os.makedirs(os.path.dirname(trace_path), exist_ok=True)
    ctl.export_trace(trace_path)
    ctl.close()
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
    print()
    for r in run(physical=True):
        if r["phase"] == "summary":
            print(r)
