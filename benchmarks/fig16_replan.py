"""Fig. 16 (extension): throughput recovery via continuous re-planning.

Injects a mid-run shape-distribution shift (single-image mixture → video
mixture: media counts jump from 1 to 8–32 per item) and runs the
`repro.runtime` control loop over it.  The drift detector fires on the KS
distance between the profiled reference distribution and the recent shape
window, `ParallelismOptimizer.search()` re-runs in the background over
that window, and the new plan is hot-swapped between global batches.

Reported per phase: predicted makespan of the *active* plan vs. a
scheduler pinned to the *stale* pre-shift plan on identical batches.  The
summary row gives the recovery ratio (stale / re-planned makespan after
the shift).  A Chrome trace of the run is written next to the results.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import POD_CLUSTER, engine_for
from repro.data.synthetic import MixedDataset

TRACE_PATH = os.path.join(os.path.dirname(__file__), "results",
                          "fig16_replan_trace.json")


def run(arch: str = "llava-ov-llama8b", gbs: int = 64,
        n_pre: int = 6, n_post: int = 24, seed: int = 0,
        step_wall_s: float = 0.15):
    """step_wall_s emulates the accelerator step each iteration overlaps:
    the paper's background re-plan lands *during* training, so the loop
    must spend wall time per batch the way a real run would (scheduling
    itself is now sub-ms and no longer provides it)."""
    eng = engine_for(arch, POD_CLUSTER, mixture="single_image", seed=seed)
    eng.plan(gbs)
    ctl = eng.runtime(gbs, adaptive=False, ilp_time_limit_s=0.05)
    stale_plan = ctl.plan
    # identical predictions, pinned to the pre-shift plan for comparison
    stale_sched = eng.scheduler(plan=stale_plan, adaptive=False,
                                ilp_time_limit_s=0.05)

    tpm = eng.tokens_per_media_item
    pre_ds = MixedDataset("single_image", seed=seed,
                          tokens_per_media_item=tpm)
    post_ds = MixedDataset("video", seed=seed + 1,
                           tokens_per_media_item=tpm)

    rows = []
    swap_iter = None
    for i in range(n_pre + n_post):
        phase = "pre" if i < n_pre else "post"
        items = (pre_ds if phase == "pre" else post_ds).sample(gbs)
        out = ctl.schedule(items)
        if step_wall_s:
            time.sleep(step_wall_s)       # the "training step" runs here
        if swap_iter is None and ctl.metrics.n_replans > 0:
            swap_iter = i
        stale_out = stale_sched.schedule(items)
        rows.append({
            "figure": "fig16", "iter": i, "phase": phase,
            "replanned": ctl.metrics.n_replans > 0,
            "makespan_active_s": float(out.step_makespan),
            "makespan_stale_s": float(stale_out.step_makespan),
            "imbalance": float(out.imbalance),
        })
    # make sure an in-flight search lands before summarizing
    ctl.drain(timeout=60.0)

    post_rows = [r for r in rows if r["phase"] == "post"]
    recovered = [r for r in post_rows if r["replanned"]]
    stale_mean = float(np.mean([r["makespan_stale_s"] for r in post_rows]))
    active_mean = (float(np.mean([r["makespan_active_s"] for r in recovered]))
                   if recovered else stale_mean)
    rows.append({
        "figure": "fig16", "iter": -1, "phase": "summary",
        "plan_before": list(stale_plan.as_tuple()),
        "plan_after": list(ctl.plan.as_tuple()),
        "swap_iter": swap_iter if swap_iter is not None else -1,
        "n_drift_events": ctl.metrics.n_drift_events,
        "n_replans": ctl.metrics.n_replans,
        "post_shift_stale_makespan_s": stale_mean,
        "post_shift_replanned_makespan_s": active_mean,
        "recovery_ratio": stale_mean / max(active_mean, 1e-12),
    })
    os.makedirs(os.path.dirname(TRACE_PATH), exist_ok=True)
    ctl.export_trace(TRACE_PATH)
    ctl.close()
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
