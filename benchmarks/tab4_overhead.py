"""Table 4 / Fig. 16: DFLOP component overhead.

Fig. 16a: optimizer latency vs GPUs × GBS (paper: <200 ms at 1024 GPUs).
Fig. 16b: scheduler latency vs GBS (ILP -> LPT fallback at 2048; <1% gap).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import engine_for
from repro.core.optimizer.space import ClusterSpec


def run():
    rows = []
    # --- Fig 16a: optimizer latency ----------------------------------- #
    for n_chips in (64, 256, 512, 1024):
        cluster = ClusterSpec(n_chips=n_chips, chips_per_node=16,
                              mem_bytes=16e9)
        eng = engine_for("llava-ov-llama8b", cluster)
        for gbs in (256, 1024):
            res = eng.plan(gbs)
            rows.append({
                "figure": "fig16a", "n_chips": n_chips, "gbs": gbs,
                "optimizer_ms": res.elapsed_s * 1e3,
                "n_configs": res.n_configs,
            })
    # --- Fig 16b: scheduler latency + imbalance vs GBS ----------------- #
    eng = engine_for("llava-ov-llama8b",
                     ClusterSpec(n_chips=256, chips_per_node=16))
    eng.plan(256)
    sched = eng.scheduler(adaptive=False, ilp_time_limit_s=0.5)
    for gbs in (128, 512, 2048):
        items = eng.dataset.sample(gbs)
        out = sched.schedule(items)
        rows.append({
            "figure": "fig16b", "gbs": gbs,
            "scheduler_ms": out.elapsed_s * 1e3,
            "solver": out.solver,
            "imbalance_vs_lb": out.imbalance,
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
