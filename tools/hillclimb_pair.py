"""Measure roofline terms for one (arch, shape) with optional overrides."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, json, dataclasses
import jax
from repro.configs import get_config
from repro.common.types import INPUT_SHAPES
from repro.launch import dryrun as D
from repro.launch.hlo_stats import analyze
from repro.launch.mesh import make_production_mesh

arch, shape, kind = sys.argv[1], sys.argv[2], sys.argv[3]
overrides = dict(kv.split("=") for kv in sys.argv[4:])

if "ssm_impl" in overrides or "attn_block" in overrides:
    import repro.models.model as M
    orig = M.FwdCtx
    if "ssm_impl" in overrides:
        # route rwkv to chunked via ctx.ssm_impl
        pass
if "attn_block" in overrides:
    import repro.models.layers.attention as A
    bq = int(overrides["attn_block"])
    _orig_flash = A.flash_attention_xla
    def flash(q, k, v, **kw):
        kw["block_q"] = bq; kw["block_k"] = bq
        return _orig_flash(q, k, v, **kw)
    A.flash_attention_xla = flash
if "ssm_impl" in overrides:
    # patch the train ctx builder to use the chosen ssm impl
    _bt = D.build_train
    import repro.models.model as M
    _orig_fwd = M.forward
    val = overrides["ssm_impl"]
    import functools
    def fwd(params, cfg, **kw):
        ctx = kw.get("ctx")
        if ctx is not None:
            ctx = dataclasses.replace(ctx, ssm_impl=val)
            kw["ctx"] = ctx
        return _orig_fwd(params, cfg, **kw)
    M.forward = fwd
if "n_mb" in overrides:
    D.N_MB[arch] = int(overrides["n_mb"])

spec = get_config(arch)
mesh = make_production_mesh()
builder = {"train": D.build_train, "prefill": D.build_prefill,
           "decode": D.build_decode}[kind]
jitted, args, extra = builder(spec, INPUT_SHAPES[shape], mesh)
with mesh:
    co = jitted.lower(*args).compile()
ma = co.memory_analysis()
st = analyze(co.as_text())
peak = (ma.argument_size_in_bytes + ma.output_size_in_bytes
        + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
print(json.dumps({
    "arch": arch, "shape": shape, "overrides": overrides,
    "compute_s": st.flops / 197e12,
    "memory_s": st.hbm_bytes / 819e9,
    "collective_s": st.total_collective_bytes / 50e9,
    "peak_gb": peak / 1e9,
}))
