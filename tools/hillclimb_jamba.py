"""Hillclimb jamba-v0.1-52b x train_4k: measure roofline terms per variant."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, dataclasses, json
import jax
from repro.configs import get_config
from repro.common.types import INPUT_SHAPES
from repro.launch import dryrun as D
from repro.launch.hlo_stats import analyze
from repro.launch.mesh import make_production_mesh

variant = sys.argv[1]
spec = get_config("jamba-v0.1-52b")
mesh = make_production_mesh()

if variant == "nmb8":
    D.N_MB["jamba-v0.1-52b"] = 8
elif variant == "nmb32":
    D.N_MB["jamba-v0.1-52b"] = 32
elif variant == "no-expert-fsdp":
    _orig = D.make_assignment
    def make_assignment(mesh, spec, **kw):
        ma = _orig(mesh, spec, **kw)
        llm = dataclasses.replace(ma.llm, fsdp_exclude=(r"/moe/w_",))
        return dataclasses.replace(ma, llm=llm)
    D.make_assignment = make_assignment
elif variant == "nmb8+no-expert-fsdp":
    D.N_MB["jamba-v0.1-52b"] = 8
    _orig = D.make_assignment
    def make_assignment(mesh, spec, **kw):
        ma = _orig(mesh, spec, **kw)
        llm = dataclasses.replace(ma.llm, fsdp_exclude=(r"/moe/w_",))
        return dataclasses.replace(ma, llm=llm)
    D.make_assignment = make_assignment

jitted, args, extra = D.build_train(spec, INPUT_SHAPES["train_4k"], mesh)
with mesh:
    co = jitted.lower(*args).compile()
ma = co.memory_analysis()
st = analyze(co.as_text())
peak = (ma.argument_size_in_bytes + ma.output_size_in_bytes
        + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
print(json.dumps({
    "variant": variant,
    "compute_s": st.flops / 197e12,
    "memory_s": st.hbm_bytes / 819e9,
    "collective_s": st.total_collective_bytes / 50e9,
    "peak_gb": peak / 1e9,
}))
