#!/usr/bin/env python
"""Docs health check: internal links + docstring examples.

Two passes (the link check is dependency-free; the doctest pass imports
the listed modules, which need numpy + jax installed — the CI docs job
installs both):

  1. every relative markdown link in README.md, docs/*.md and
     benchmarks/README.md must resolve to a file in the repo (http(s)
     links are not fetched), and the documented entry points must exist;
  2. ``doctest`` runs over the modules listed in ``DOCTEST_MODULES``
     (docstring examples are part of the docs — they must execute).

Run from the repo root:  python tools/check_docs.py
CI runs this in the ``docs`` job (.github/workflows/ci.yml).
"""
from __future__ import annotations

import doctest
import importlib
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# markdown files whose links must resolve
DOC_FILES = (
    [REPO / "README.md", REPO / "benchmarks" / "README.md"]
    + sorted((REPO / "docs").glob("*.md"))
)

# files the docs system itself promises exist
REQUIRED = [
    "docs/ARCHITECTURE.md",
    "docs/simulator.md",
    "docs/schedules.md",
    "docs/objectives.md",
    "docs/resharding.md",
    "docs/data.md",
    "docs/serving.md",
    "docs/fleet.md",
    "docs/kernels.md",
    "benchmarks/README.md",
]

# modules whose docstring examples must pass (keep in sync with the
# modules that carry ``>>>`` examples)
DOCTEST_MODULES = [
    "repro.core.pipeline.simulator",
    "repro.core.optimizer.makespan",
    "repro.core.optimizer.space",
    "repro.launch.reshard",
    "repro.launch.fleet",
    "repro.data.composer",
    "repro.data.host_shard",
    "repro.serve.request",
    "repro.serve.admission",
    "repro.serve.engine",
    "repro.serve.backend",
    "repro.serve.steps",
    "repro.kernels.blocking",
]

# [text](target) — excluding images; target split from an optional title
_LINK = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")


def check_links() -> list:
    errors = []
    for req in REQUIRED:
        if not (REPO / req).is_file():
            errors.append(f"missing required doc: {req}")
    for md in DOC_FILES:
        if not md.is_file():
            errors.append(f"doc file listed but absent: {md}")
            continue
        text = md.read_text(encoding="utf-8")
        for target in _LINK.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path = target.split("#", 1)[0]
            if not path:                      # pure in-page anchor
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                errors.append(
                    f"{md.relative_to(REPO)}: broken link -> {target}")
    return errors


def run_doctests() -> list:
    sys.path.insert(0, str(REPO / "src"))
    errors = []
    for name in DOCTEST_MODULES:
        try:
            mod = importlib.import_module(name)
        except Exception as exc:              # pragma: no cover
            errors.append(f"doctest: cannot import {name}: {exc!r}")
            continue
        result = doctest.testmod(mod)
        if result.failed:
            errors.append(f"doctest: {result.failed} failure(s) in {name}")
        print(f"doctest {name}: {result.attempted} example(s), "
              f"{result.failed} failed")
    return errors


def main() -> int:
    errors = check_links() + run_doctests()
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    n_links = sum(1 for _ in DOC_FILES)
    if not errors:
        print(f"docs OK: {n_links} markdown files link-checked, "
              f"{len(DOCTEST_MODULES)} modules doctested")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
