"""Dump the largest HLO buffers for one dry-run combo (debugging aid)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
import jax
from repro.configs import get_config
from repro.common.types import INPUT_SHAPES
from repro.launch import dryrun as D
from repro.launch.hlo_stats import parse_module, shape_bytes
from repro.launch.mesh import make_production_mesh

arch, shape, kind = sys.argv[1], sys.argv[2], sys.argv[3]
spec = get_config(arch)
mesh = make_production_mesh(multi_pod=(len(sys.argv) > 4))
builder = {"train": D.build_train, "prefill": D.build_prefill, "decode": D.build_decode}[kind]
jitted, args, extra = builder(spec, INPUT_SHAPES[shape], mesh)
with mesh:
    co = jitted.lower(*args).compile()
ma = co.memory_analysis()
print("arg GB:", ma.argument_size_in_bytes/1e9, "out:", ma.output_size_in_bytes/1e9,
      "temp:", ma.temp_size_in_bytes/1e9, "alias:", ma.alias_size_in_bytes/1e9)
comps, entry = parse_module(co.as_text())
allops = []
for c in comps.values():
    for op in c.ops:
        b = shape_bytes(op.shape)
        if b > 100e6:
            allops.append((b, c.name[:24], op.opcode, op.shape[:120]))
allops.sort(reverse=True)
seen = set()
for b, cn, oc, sh in allops:
    key = (oc, sh)
    if key in seen: continue
    seen.add(key)
    print(f"{b/1e9:7.2f}GB {oc:18s} {cn:24s} {sh}")
    if len(seen) > 14: break
