#!/usr/bin/env python
"""Persist per-PR benchmark headline numbers as BENCH_*.json snapshots.

The ROADMAP's perf-trajectory item: benchmark claims used to live only in
commit messages, invisible to the next re-anchor.  This tool runs the
headline benchmarks and writes their *summary* rows (the acceptance-bearing
numbers, not the full row dumps) to committed JSON files at the repo root:

  * ``BENCH_train.json``   — fig16 (drift re-plan recovery), fig17
    (objective sweep), fig18 (lookahead composer), fig20 (schedule-family
    search), fig21 (elastic host-loss recovery vs naive stall), fig23
    (kernel-tier predict-vs-measure: measured ratios are wall clock, so
    only the band-acceptance booleans are expected to reproduce);
  * ``BENCH_serving.json`` — fig19 (data-aware serving goodput/p99) and
    fig22 (real-backend serving: measured drift → re-price loop; its rows
    are wall-clock measurements, so only the acceptance booleans are
    expected to reproduce).

Run from the repo root (about a minute of wall clock):

    PYTHONPATH=src python tools/bench_snapshot.py            # all
    PYTHONPATH=src python tools/bench_snapshot.py --only serving
    PYTHONPATH=src python tools/bench_snapshot.py --check    # validate only

``--check`` validates the committed snapshots without re-running anything
(tier-1 CI): strict JSON (no NaN/Infinity literals — missing stats must be
null), the expected top-level shape, and a non-empty headline per figure.

Snapshots are deterministic (fixed seeds, virtual-time emulations) up to
wall-clock-dependent fields, which are excluded from the summary rows the
benchmarks emit; re-running on an unchanged tree should reproduce the
committed numbers.  Compare against the previous snapshot in git before
overwriting expectations.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path
from typing import List

REPO = Path(__file__).resolve().parent.parent

# snapshot -> {figure: (module, run kwargs)}; kwargs shrink the slowest
# sweeps to snapshot scale while keeping the acceptance-bearing regimes
SNAPSHOTS = {
    "BENCH_train.json": {
        "fig16": ("benchmarks.fig16_replan", {"step_wall_s": 0.05}),
        "fig17": ("benchmarks.fig17_objective",
                  {"gbs_sweep": (32, 128, 512), "n_trials": 8,
                   "n_eval": 8}),
        "fig18": ("benchmarks.fig18_composer", {"n_batches": 48}),
        "fig20": ("benchmarks.fig20_schedules", {"n_iters": 4}),
        "fig21": ("benchmarks.fig21_elastic", {"recovery_wall_s": 0.05}),
        "fig23": ("benchmarks.fig23_kernels", {"seqs": (64, 128), "iters": 2}),
    },
    "BENCH_serving.json": {
        "fig19": ("benchmarks.fig19_serving", {}),
        "fig22": ("benchmarks.fig22_real_serving", {}),
    },
}

# figure-specific headline invariants enforced by --check: keys that must
# be present in some headline row, and keys that must also be truthy.
# fig22 rows are *measured* (wall-clock), so only its load-independent
# acceptance booleans are pinned — the drift re-price must have fired and
# calibration must have reduced prediction error; the goodput A/B is
# load-noise-sensitive and is pinned by the slow test instead.
HEADLINE_REQUIRED = {
    "fig22": {"present": ("reprice_fired", "err_shrank", "slo_goodput_win"),
              "truthy": ("reprice_fired", "err_shrank")},
    # fig23 rows are measured kernel timings; the pinned invariant is the
    # band acceptance — every benchmarked bucket's measured-vs-analytic
    # ratio finite and within the declared band.
    "fig23": {"present": ("ratios_finite", "ratios_within_band", "band"),
              "truthy": ("ratios_finite", "ratios_within_band")},
}


def _is_summary(row: dict) -> bool:
    return bool(row.get("summary")) or row.get("phase") == "summary" \
        or row.get("objective") == "summary"


def _git_head() -> str:
    try:
        return subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                              cwd=REPO, capture_output=True, text=True,
                              check=True).stdout.strip()
    except Exception:
        return "unknown"


def snapshot(name: str, figures: dict) -> dict:
    import importlib
    out = {"git": _git_head(), "figures": {}}
    for fig, (module, kwargs) in figures.items():
        mod = importlib.import_module(module)
        t0 = time.time()
        rows = mod.run(**kwargs)
        headline = [r for r in rows if _is_summary(r)]
        assert headline, f"{fig}: no summary rows to snapshot"
        out["figures"][fig] = {
            "module": module,
            "args": {k: list(v) if isinstance(v, tuple) else v
                     for k, v in kwargs.items()},
            "wall_s": round(time.time() - t0, 2),
            "headline": headline,
        }
        print(f"{name}: {fig} -> {len(headline)} summary row(s) "
              f"({out['figures'][fig]['wall_s']}s)")
    return out


def _reject_nonfinite(_name: str):
    raise ValueError(f"non-finite literal {_name!r} in snapshot — missing "
                     "stats must be null, never NaN/Infinity")


def check(names=None) -> List[str]:
    """Validate committed BENCH_*.json snapshots; returns problems found.

    Strict JSON (``NaN``/``Infinity`` literals rejected — `json.dumps`
    happily emits them but they are not JSON, and a missing stat must be
    ``null``), the expected top-level shape, and per figure a non-empty
    ``headline`` list of objects.
    """
    problems: List[str] = []
    for name in (SNAPSHOTS if names is None else names):
        path = REPO / name
        if not path.is_file():
            problems.append(f"{name}: missing (run tools/bench_snapshot.py)")
            continue
        try:
            data = json.loads(path.read_text(encoding="utf-8"),
                              parse_constant=_reject_nonfinite)
        except ValueError as e:
            problems.append(f"{name}: invalid JSON: {e}")
            continue
        if not isinstance(data, dict) or "figures" not in data \
                or "git" not in data:
            problems.append(f"{name}: expected {{git, figures}} object")
            continue
        missing = set(SNAPSHOTS[name]) - set(data["figures"])
        if missing:
            problems.append(f"{name}: missing figure(s) "
                            f"{sorted(missing)} (re-run the snapshot)")
        for fig, entry in data["figures"].items():
            for key in ("module", "args", "wall_s", "headline"):
                if key not in entry:
                    problems.append(f"{name}: {fig}: missing {key!r}")
            headline = entry.get("headline")
            if not (isinstance(headline, list) and headline
                    and all(isinstance(r, dict) for r in headline)):
                problems.append(
                    f"{name}: {fig}: headline must be a non-empty "
                    "list of summary rows")
                continue
            req = HEADLINE_REQUIRED.get(fig)
            if req is None:
                continue
            rows = [r for r in headline
                    if all(k in r for k in req["present"])]
            if not rows:
                problems.append(
                    f"{name}: {fig}: no headline row carries "
                    f"{list(req['present'])}")
            elif not all(any(r[k] for r in rows) for k in req["truthy"]):
                problems.append(
                    f"{name}: {fig}: acceptance invariant(s) "
                    f"{list(req['truthy'])} not met in the snapshot")
    return problems


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: train,serving (default: all)")
    ap.add_argument("--check", action="store_true",
                    help="validate committed snapshots, run nothing")
    args = ap.parse_args()
    only = {s.strip() for s in args.only.split(",") if s.strip()}
    names = [n for n in SNAPSHOTS
             if not only or n.removeprefix("BENCH_").removesuffix(".json")
             in only]
    if args.check:
        problems = check(names)
        for p in problems:
            print(f"CHECK FAIL: {p}")
        if not problems:
            print(f"ok: {', '.join(names)}")
        return 1 if problems else 0
    sys.path.insert(0, str(REPO / "src"))
    sys.path.insert(0, str(REPO))
    for name, figures in SNAPSHOTS.items():
        key = name.removeprefix("BENCH_").removesuffix(".json")
        if only and key not in only:
            continue
        data = snapshot(name, figures)
        path = REPO / name
        path.write_text(json.dumps(data, indent=2, sort_keys=True) + "\n",
                        encoding="utf-8")
        print(f"wrote {path.relative_to(REPO)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
