"""Serving example: data-aware admission + disaggregated continuous batching.

Three parts, mirroring the `repro.serve` split (see docs/serving.md):

  1. **Real-model substrate** (tiny dense model): requests are prefilled
     one at a time on a "prefill worker" (`prefill_into_cache`, exact
     length, no padding), handed off into a shared continuous decode
     batch (`merge_cache_row`), decode rows advance per-request position
     clocks, and a finished row is recycled for a new request
     (`clear_cache_row`) without disturbing its neighbours.

  2. **Emulated engine** (no model, virtual time): a bursty multimodal
     request stream served under FIFO vs. data-aware (`SLOAdmission`)
     admission on the same emulated cluster — the fig19 A/B in miniature,
     printing goodput / p99 / drift events per policy.

  3. **Real backend** (the same control loop, jit'd executor): the engine
     drives `RealBackend` — chunked prefill, device-to-device KV handoff,
     pow2-bucketed continuous decode — and every measured wall duration
     feeds the calibrator; the fig22 loop in miniature, printing measured
     completions, compiles, prefill chunks and calibrated cells.

    PYTHONPATH=src python examples/serve_mllm.py
"""
import os
import sys
import time

import jax
import jax.numpy as jnp

# the fig19 stream generator lives in benchmarks/, which is a repo-root
# package — make `python examples/serve_mllm.py` work from anywhere
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.common.types import ModelConfig

TINY = ModelConfig(name="tiny-dense", family="dense", n_layers=2,
                   d_model=64, n_heads=4, n_kv_heads=2, d_ff=256,
                   vocab_size=128, dtype="float32")


def continuous_batching_demo():
    from repro.models import model as model_lib
    from repro.serve import (clear_cache_row, make_decode_step,
                             merge_cache_row, prefill_into_cache)

    max_len, max_new = 32, 6
    params = model_lib.init(jax.random.PRNGKey(0), TINY)
    rng = jax.random.PRNGKey(1)
    prompts = [jax.random.randint(jax.random.fold_in(rng, i), (n,), 2,
                                  TINY.vocab_size)
               for i, n in enumerate((5, 9, 6))]

    decode = jax.jit(make_decode_step(TINY))
    shared = model_lib.init_cache(TINY, 2, max_len, jnp.float32)

    # prefill A and B on the "prefill pool", hand both off
    (la, ca), (lb, cb) = (prefill_into_cache(TINY, params, p[None, :],
                                             max_len)
                          for p in prompts[:2])
    shared = merge_cache_row(shared, ca, row=0)
    shared = merge_cache_row(shared, cb, row=1)
    tok = jnp.concatenate([jnp.argmax(la, -1).reshape(1),
                           jnp.argmax(lb, -1).reshape(1)]).astype(jnp.int32)
    pos = jnp.array([prompts[0].shape[0], prompts[1].shape[0]], jnp.int32)
    out = {0: [], 1: [], 2: []}
    for _ in range(max_new):                 # A and B decode together
        out[0].append(int(tok[0])), out[1].append(int(tok[1]))
        logits, shared = decode(params, shared, tok, pos)
        tok, pos = jnp.argmax(logits, -1).astype(jnp.int32), pos + 1
    print(f"request A done: {out[0]}")

    # step boundary: A leaves, its row is recycled for C (KV handoff)
    shared = clear_cache_row(shared, 0)
    lc, cc = prefill_into_cache(TINY, params, prompts[2][None, :], max_len)
    shared = merge_cache_row(shared, cc, row=0)
    tok = tok.at[0].set(jnp.argmax(lc, -1).reshape(()).astype(jnp.int32))
    pos = pos.at[0].set(prompts[2].shape[0])
    for _ in range(max_new):                 # B continues, C starts fresh
        out[2].append(int(tok[0])), out[1].append(int(tok[1]))
        logits, shared = decode(params, shared, tok, pos)
        tok, pos = jnp.argmax(logits, -1).astype(jnp.int32), pos + 1
    print(f"request B done: {out[1]}")
    print(f"request C done: {out[2]} (joined mid-flight in A's row)")


def emulated_engine_demo():
    from benchmarks.common import DEFAULT_CLUSTER, engine_for
    from benchmarks.fig19_serving import bursty_requests
    from repro.serve import PrefillPricer, ServeConfig

    eng = engine_for("llava-ov-llama8b", DEFAULT_CLUSTER, mixture="mixed",
                     seed=0)
    cfg = ServeConfig(n_prefill_workers=2, n_decode_workers=2,
                      decode_slots=8, max_prefill_batch=8)
    slo_pricer = PrefillPricer(eng.perf, eng.tokens_per_media_item)
    for policy in ("fifo", "slo"):
        serve = eng.serving(admission=policy, serve_cfg=cfg)
        reqs = bursty_requests(160, qps=4.0, tpm=eng.tokens_per_media_item,
                               pricer=slo_pricer, seed=0)
        t0 = time.time()
        rep = serve.run(reqs)
        print(f"{policy:5s}  goodput {rep.goodput_rps:6.3f} req/s  "
              f"p99 {rep.p99_latency_s:7.2f}s  "
              f"slo-met {rep.n_slo_met:3d}/{rep.n_requests}  "
              f"drift-events {rep.n_drift_events}  "
              f"compiles {rep.n_compiles}  "
              f"({time.time() - t0:.2f}s wall)")


def real_backend_demo():
    import numpy as np

    from repro.core.optimizer.space import ClusterSpec
    from repro.data.items import DataItem
    from repro.models import model as model_lib
    from repro.runtime.drift import PageHinkley
    from repro.serve import Request, ServeConfig

    tpm = 8
    enc = ModelConfig(name="tiny-enc", family="vlm-enc", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                      vocab_size=0, causal=False, use_rope=False,
                      input_embed_dim=32, has_lm_head=False)
    from repro.core.engine import DFLOPEngine
    from repro.data.synthetic import MixedDataset
    eng = DFLOPEngine(llm_cfg=TINY, enc_cfg=enc, e_seq_len=16,
                      cluster=ClusterSpec(n_chips=4, chips_per_node=4,
                                          mem_bytes=16e9),
                      tokens_per_media_item=tpm)
    eng.profile(MixedDataset("mixed", seed=0, tokens_per_media_item=tpm),
                n_samples=64)
    params = model_lib.init(jax.random.PRNGKey(0), TINY)
    serve = eng.serving(
        serve_cfg=ServeConfig(n_prefill_workers=1, n_decode_workers=1,
                              decode_slots=2, max_prefill_batch=2),
        backend="real", model_params=params, max_len=64, chunk=16,
        drift=PageHinkley(burn_in=6, threshold=0.5))
    rng = np.random.default_rng(0)
    reqs = [Request(item=DataItem(int(rng.integers(1, 4)),
                                  int(rng.integers(8, 25)),
                                  "single_image", i),
                    arrival_s=float(i) * 1e-3, slo_s=60.0,
                    max_new_tokens=4)
            for i in range(8)]
    serve.backend.probe(reqs)                # calibrate wall-second units
    t0 = time.time()
    rep = serve.run(reqs)
    cells = {m for (m, _, _) in serve.calibrator.cells}
    print(f"real backend ({serve.backend.name}): "
          f"{rep.n_completed}/{rep.n_requests} completed  "
          f"compiles {rep.n_compiles}  "
          f"prefill-chunks {serve.metrics.n_prefill_chunks}  "
          f"calibrated modules {sorted(cells)}  "
          f"({time.time() - t0:.2f}s wall)")
    print(f"first request generated tokens: {reqs[0].generated}")


def main():
    print("== continuous batching on a real (tiny) model ==")
    continuous_batching_demo()
    print("\n== emulated cluster: FIFO vs data-aware admission ==")
    emulated_engine_demo()
    print("\n== real backend: the measured serving loop ==")
    real_backend_demo()


if __name__ == "__main__":
    main()
