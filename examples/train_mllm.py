"""End-to-end driver: train a ~100M-param MLLM with DFLOP for a few hundred
steps on synthetic mixed multimodal data, comparing the Online Microbatch
Scheduler against random (data-agnostic) assignment.

Scheduling runs through the `repro.runtime` control loop: every step's
wall time feeds back into calibration + drift detection, and `--trace`
exports a Chrome trace (load in https://ui.perfetto.dev) of the run.
`--replan` additionally lets the controller re-plan in the background and
hot-swap θ* when the data distribution drifts — and the swap is
*physical*: the live (params, opt) pytree is threaded through a
`repro.launch.reshard.ParamSwapper`, so an adopted plan re-lays-out the
training state on device (clamped onto however many local devices exist)
and the reshard lands in the trace and metrics.  `--shift-at K` switches
the data mixture single-image → video at step K to force a mid-run drift.

`--hosts N` runs the loop *elastically* on an emulated fleet: the local
devices (force more with ``XLA_FLAGS=--xla_force_host_platform_device_count``)
split into N hosts owned by a `repro.launch.fleet.FleetManager`, each
global batch is sharded per host with exactly-once accounting, and
`--fail-host-at K` / `--revive-host-at K` drive a `FaultInjector` that
kills / revives the last host at those steps — the controller recovers
checkpoint-free (re-plan for the survivors + live param migration).

    PYTHONPATH=src python examples/train_mllm.py [--steps 200] [--random]
        [--trace runtime_trace.json] [--replan] [--shift-at 8]
        [--hosts 4 --fail-host-at 6 --revive-host-at 12]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.types import MLLMConfig, ModalityStub, ModelConfig
from repro.core.engine import DFLOPEngine
from repro.core.optimizer.space import ClusterSpec, ModuleParallelism, ParallelismPlan
from repro.data.synthetic import MixedDataset
from repro.data.host_shard import HostShardedSource
from repro.launch.fleet import FaultInjector, FleetManager
from repro.launch.reshard import ParamSwapper, clamped_plan_mesh
from repro.runtime import DriftDetector
from repro.models import mllm as mllm_lib
from repro.models.model import FwdCtx
from repro.train import checkpoint
from repro.train.optim import AdamWConfig, adamw_init, cosine_lr
from repro.train.step import make_train_step

ENC = ModelConfig(name="enc-100m", family="vlm-enc", n_layers=6, d_model=384,
                  n_heads=6, n_kv_heads=6, d_ff=1536, vocab_size=0,
                  causal=False, use_rope=False, input_embed_dim=64,
                  has_lm_head=False, dtype="float32")
LLM = ModelConfig(name="llm-100m", family="dense", n_layers=8, d_model=512,
                  n_heads=8, n_kv_heads=4, d_ff=2048, vocab_size=8192,
                  dtype="float32")
MCFG = MLLMConfig(name="mllm-100m", encoder=ENC, llm=LLM,
                  stub=ModalityStub("vision", 16, 64), connector_hidden=512,
                  tokens_per_item_out=4)

TPM = 4          # connector tokens per media item
GBS = 16
MAX_MEDIA = 8 * 16       # encoder tokens cap
MAX_TEXT = 384


def build_batches(ds, plan, items, groups, n_mb, vocab_size=LLM.vocab_size):
    """Tensorize scheduler groups -> (n_mb, rows, ...) MLLM batch."""
    dp = plan.llm.dp
    rows = []
    for i in range(n_mb):
        row_items = []
        for r in range(dp):
            row_items += [items[j] for j in groups[i * dp + r]]
        rows.append(row_items or [items[0]])
    # pad rows to a power of two so batch shapes (and therefore jit
    # compilations) stay stable across steps; XLA CPU recompiles cost
    # minutes at this model size
    per_row = max(len(r) for r in rows)
    per_row = 1 << (per_row - 1).bit_length()
    batches = []
    for row_items in rows:
        row_items = (row_items * per_row)[:per_row]
        batches.append(ds.materialize(row_items, embed_dim=64,
                                      vocab_size=vocab_size,
                                      max_media=MAX_MEDIA, max_text=MAX_TEXT))
    return {k: jnp.asarray(np.stack([b[k] for b in batches]))
            for k in batches[0]}


def tiny_configs():
    """Sub-1M-param variant for smoke tests: compiles in seconds on CPU
    while exercising the identical control-loop + reshard code paths."""
    enc = ModelConfig(name="enc-tiny", family="vlm-enc", n_layers=2,
                      d_model=96, n_heads=4, n_kv_heads=4, d_ff=384,
                      vocab_size=0, causal=False, use_rope=False,
                      input_embed_dim=64, has_lm_head=False, dtype="float32")
    llm = ModelConfig(name="llm-tiny", family="dense", n_layers=2,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=512,
                      vocab_size=1024, dtype="float32")
    mcfg = MLLMConfig(name="mllm-tiny", encoder=enc, llm=llm,
                      stub=ModalityStub("vision", 16, 64),
                      connector_hidden=128, tokens_per_item_out=4)
    return enc, llm, mcfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--random", action="store_true",
                    help="random (data-agnostic) microbatch assignment")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--trace", default="",
                    help="export a Chrome trace of the run to this path")
    ap.add_argument("--replan", action="store_true",
                    help="enable background re-planning on drift, with "
                         "physical param resharding on plan hot-swap")
    ap.add_argument("--shift-at", type=int, default=0,
                    help="switch the data mixture single-image -> video at "
                         "this step (0 = keep the mixed stream)")
    ap.add_argument("--objective", default="mean",
                    choices=["mean", "expected-random", "balanced-quantile"],
                    help="search objective used by background re-planning")
    ap.add_argument("--compose-window", type=int, default=0,
                    help="lookahead batch composition over a window of "
                         "this many global batches (0 = FIFO draws)")
    ap.add_argument("--max-staleness", type=int, default=0,
                    help="max batches an item may wait in the compose "
                         "window (0 = default, 2x the window)")
    ap.add_argument("--tiny", action="store_true",
                    help="sub-1M-param model (CI smoke: compiles in "
                         "seconds, same control-loop code paths)")
    ap.add_argument("--hosts", type=int, default=0,
                    help="split the local devices into this many emulated "
                         "hosts and run elastically (0 = single-host)")
    ap.add_argument("--fail-host-at", type=int, default=0,
                    help="kill the last emulated host at this step "
                         "(requires --hosts; 0 = no failure)")
    ap.add_argument("--revive-host-at", type=int, default=0,
                    help="revive the killed host at this step")
    args = ap.parse_args()
    if (args.fail_host_at or args.revive_host_at) and not args.hosts:
        ap.error("--fail-host-at/--revive-host-at need --hosts")
    if args.hosts and args.random:
        ap.error("--random bypasses the controller, so fleet recovery "
                 "(poll_fleet) would never run; drop one of the two flags")
    if args.hosts and args.compose_window:
        ap.error("--hosts draws through the per-host sharded source; "
                 "combine it with --compose-window is not supported yet")
    if args.random and args.replan:
        ap.error("--random bypasses the control loop (schedule_random "
                 "never reaches the controller), so --replan would only "
                 "adopt plans at exit; drop one of the two flags")

    enc_cfg, llm_cfg, mcfg = tiny_configs() if args.tiny else (ENC, LLM, MCFG)
    if args.shift_at:
        ds = MixedDataset("single_image", seed=0, tokens_per_media_item=TPM)
        post_ds = MixedDataset("video", seed=1, tokens_per_media_item=TPM)
    else:
        ds = MixedDataset("mixed", seed=0, tokens_per_media_item=TPM)
        post_ds = None
    eng = DFLOPEngine(llm_cfg=llm_cfg, enc_cfg=enc_cfg, e_seq_len=16,
                      cluster=ClusterSpec(n_chips=16, chips_per_node=16),
                      tokens_per_media_item=TPM,
                      objective=args.objective)
    eng.profile(ds)
    plan = ParallelismPlan(llm=ModuleParallelism(1, 1, 1),
                           encoder=ModuleParallelism(1, 1, 1), n_mb=4)

    params = mllm_lib.init(jax.random.PRNGKey(0), mcfg)
    opt = adamw_init(params)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params))
    print(f"[model] {n_params/1e6:.1f}M params  "
          f"devices={jax.device_count()}")

    # The controller reaches the live (params, opt) state through this
    # holder: a plan hot-swap physically re-lays-out both (optimizer state
    # moves with the parameters) on the plan's mesh, clamped onto the
    # local devices.
    live = {"state": (params, opt)}
    fleet = injector = None
    if args.hosts:
        fleet = FleetManager(n_hosts=args.hosts)
        schedule = {}
        victim = fleet.n_hosts - 1
        if args.fail_host_at:
            schedule[args.fail_host_at] = [("fail", victim)]
        if args.revive_host_at:
            schedule[args.revive_host_at] = [("join", victim)]
        injector = FaultInjector(fleet, schedule)
        print(f"[fleet] {fleet.n_hosts} hosts x "
              f"{fleet.devices_per_host} devices  schedule={schedule}")
    swapper = ParamSwapper(
        lambda: live["state"], lambda s: live.update(state=s),
        # fleet runs migrate onto the surviving roster; single-host runs
        # keep the device-count clamp
        mesh_factory=fleet.plan_mesh if fleet else clamped_plan_mesh)
    # tighter drift window than the default so a --shift-at demo fires
    # within a few global batches at GBS 16
    drift = DriftDetector(window=128, check_every=32, cooldown=64)
    ctl = eng.runtime(GBS, plan=plan, adaptive=True, ilp_time_limit_s=0.05,
                      auto_replan=args.replan, drift=drift,
                      param_swapper=swapper,
                      compose_window=args.compose_window,
                      max_staleness=args.max_staleness or None,
                      fleet=fleet)
    sched = ctl.scheduler
    composer = ctl.composer

    lr_fn = cosine_lr(1e-3, warmup=20, total=args.steps)
    step = jax.jit(make_train_step(
        mcfg, AdamWConfig(lr=1e-3),
        ctx=FwdCtx(mode="train", attn_impl="chunked")))

    hsrc = None
    if fleet is not None:
        current = {"ds": ds}
        hsrc = HostShardedSource(lambda: current["ds"].sample(GBS), GBS,
                                 fleet=fleet, keep_committed=False)

    losses, pred_cmax = [], []
    t0 = time.time()
    for k in range(args.steps):
        active_ds = post_ds if (post_ds and k >= args.shift_at) else ds
        if injector is not None:
            injector.on_step(k)      # roster mutates before this step draws
        if hsrc is not None:
            current["ds"] = active_ds
            shards = hsrc.draw()     # per-host split over the alive roster
            items = hsrc.in_flight
        elif composer is not None:
            # refills the window to capacity (first call warms the full
            # W-batch lookahead), then emits one composed batch
            items = ctl.compose(draw=lambda: active_ds.sample(GBS))
        else:
            items = active_ds.sample(GBS)
        out = (sched.schedule_random(items, seed=k) if args.random
               else ctl.schedule(items))       # may physically swap `live`
        pred_cmax.append(out.cmax)
        batch = build_batches(active_ds, out.plan, items, out.groups,
                              out.plan.n_mb, vocab_size=llm_cfg.vocab_size)
        params, opt = live["state"]
        ts = time.time()
        params, opt, m = step(params, opt, batch, lr_fn(k))
        m["loss"].block_until_ready()
        ctl.observe_step(out, time.time() - ts)
        # NaN (no MoE layers / unmeasured dispatch) is skipped, not recorded
        ctl.metrics.record_moe(float(m["moe_drop_rate"]),
                               float(m["moe_imbalance"]))
        live["state"] = (params, opt)
        if hsrc is not None:
            hsrc.commit()            # step survived: batch delivered once
        losses.append(float(m["loss"]))
        if k % 25 == 0:
            print(f"step {k:4d}  loss={losses[-1]:.3f}  "
                  f"pred C_max={out.cmax:.4f}s  solver={out.solver}")
    dt = time.time() - t0
    mode = "random" if args.random else "dflop"
    snap = ctl.metrics.snapshot()

    def fmt(key, scale=1.0, spec=".4f"):
        # snapshot stats are None when their window is empty ("no data")
        v = snap[key]
        return "n/a" if v is None else f"{v * scale:{spec}}"

    print(f"[{mode}] {args.steps} steps in {dt:.1f}s; "
          f"loss {losses[0]:.3f} -> {np.mean(losses[-10:]):.3f}; "
          f"mean predicted C_max {np.mean(pred_cmax):.4f}s")
    print(f"[runtime] imbalance={fmt('imbalance_mean')}  "
          f"sched_overhead={fmt('sched_elapsed_mean_s', 1e3, '.2f')}ms  "
          f"drift_events={snap['n_drift_events']}  "
          f"replans={snap['n_replans']}  "
          f"physical_swaps={snap['n_physical_swaps']}  "
          f"reshard_mean_s={fmt('reshard_mean_s')}  "
          f"moe_drop={fmt('moe_drop_rate_mean')}  "
          f"moe_imbalance={fmt('moe_imbalance_max')}")
    if fleet is not None:
        fl = snap["fleet"]
        print(f"[fleet] hosts={fleet.n_alive}/{fleet.n_hosts}  "
              f"failures={fl['n_host_failures']}  "
              f"joins={fl['n_host_joins']}  "
              f"recoveries={fl['n_recoveries']}  "
              f"degraded={fl['n_degraded']}  "
              f"committed={hsrc.n_committed}  aborted={hsrc.n_aborted}")
    if composer is not None:
        print(f"[compose] batches={snap['n_composed']}  "
              f"pred_gain_mean={fmt('compose_pred_gain_mean', 1.0, '.3f')}  "
              f"forced_items={snap['n_forced_items']}  "
              f"overhead={fmt('compose_elapsed_mean_s', 1e3, '.2f')}ms")
    if args.trace:
        print(f"chrome trace written to {ctl.export_trace(args.trace)}")
    ctl.close()
    params, opt = live["state"]
    if args.ckpt:
        checkpoint.save(args.ckpt, params, {"steps": args.steps,
                                            "loss": losses[-1]})
        print(f"checkpoint written to {args.ckpt}")


if __name__ == "__main__":
    main()
