"""End-to-end driver: train a ~100M-param MLLM with DFLOP for a few hundred
steps on synthetic mixed multimodal data, comparing the Online Microbatch
Scheduler against random (data-agnostic) assignment.

Scheduling runs through the `repro.runtime` control loop: every step's
wall time feeds back into calibration + drift detection, and `--trace`
exports a Chrome trace (load in https://ui.perfetto.dev) of the run.
`--replan` additionally lets the controller re-plan in the background and
hot-swap θ* when the data distribution drifts (here the plan is pinned
tiny for single-host training, so swaps mainly demonstrate the mechanics).

    PYTHONPATH=src python examples/train_mllm.py [--steps 200] [--random]
        [--trace runtime_trace.json] [--replan]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.types import MLLMConfig, ModalityStub, ModelConfig
from repro.core.engine import DFLOPEngine
from repro.core.optimizer.space import ClusterSpec, ModuleParallelism, ParallelismPlan
from repro.data.synthetic import MixedDataset
from repro.models import mllm as mllm_lib
from repro.models.model import FwdCtx
from repro.train import checkpoint
from repro.train.optim import AdamWConfig, adamw_init, cosine_lr
from repro.train.step import make_train_step

ENC = ModelConfig(name="enc-100m", family="vlm-enc", n_layers=6, d_model=384,
                  n_heads=6, n_kv_heads=6, d_ff=1536, vocab_size=0,
                  causal=False, use_rope=False, input_embed_dim=64,
                  has_lm_head=False, dtype="float32")
LLM = ModelConfig(name="llm-100m", family="dense", n_layers=8, d_model=512,
                  n_heads=8, n_kv_heads=4, d_ff=2048, vocab_size=8192,
                  dtype="float32")
MCFG = MLLMConfig(name="mllm-100m", encoder=ENC, llm=LLM,
                  stub=ModalityStub("vision", 16, 64), connector_hidden=512,
                  tokens_per_item_out=4)

TPM = 4          # connector tokens per media item
GBS = 16
MAX_MEDIA = 8 * 16       # encoder tokens cap
MAX_TEXT = 384


def build_batches(ds, plan, items, groups, n_mb):
    """Tensorize scheduler groups -> (n_mb, rows, ...) MLLM batch."""
    dp = plan.llm.dp
    rows = []
    for i in range(n_mb):
        row_items = []
        for r in range(dp):
            row_items += [items[j] for j in groups[i * dp + r]]
        rows.append(row_items or [items[0]])
    per_row = max(len(r) for r in rows)
    batches = []
    for row_items in rows:
        row_items = (row_items + row_items)[:per_row]
        batches.append(ds.materialize(row_items, embed_dim=64,
                                      vocab_size=LLM.vocab_size,
                                      max_media=MAX_MEDIA, max_text=MAX_TEXT))
    return {k: jnp.asarray(np.stack([b[k] for b in batches]))
            for k in batches[0]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--random", action="store_true",
                    help="random (data-agnostic) microbatch assignment")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--trace", default="",
                    help="export a Chrome trace of the run to this path")
    ap.add_argument("--replan", action="store_true",
                    help="enable background re-planning on drift")
    ap.add_argument("--objective", default="mean",
                    choices=["mean", "expected-random", "balanced-quantile"],
                    help="search objective used by background re-planning")
    args = ap.parse_args()

    ds = MixedDataset("mixed", seed=0, tokens_per_media_item=TPM)
    eng = DFLOPEngine(llm_cfg=LLM, enc_cfg=ENC, e_seq_len=16,
                      cluster=ClusterSpec(n_chips=16, chips_per_node=16),
                      tokens_per_media_item=TPM,
                      objective=args.objective)
    eng.profile(ds)
    plan = ParallelismPlan(llm=ModuleParallelism(1, 1, 1),
                           encoder=ModuleParallelism(1, 1, 1), n_mb=4)
    ctl = eng.runtime(GBS, plan=plan, adaptive=True, ilp_time_limit_s=0.05,
                      auto_replan=args.replan)
    sched = ctl.scheduler

    params = mllm_lib.init(jax.random.PRNGKey(0), MCFG)
    opt = adamw_init(params)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params))
    print(f"[model] {n_params/1e6:.1f}M params")
    lr_fn = cosine_lr(1e-3, warmup=20, total=args.steps)
    step = jax.jit(make_train_step(
        MCFG, AdamWConfig(lr=1e-3),
        ctx=FwdCtx(mode="train", attn_impl="chunked")))

    losses, pred_cmax = [], []
    t0 = time.time()
    for k in range(args.steps):
        items = ds.sample(GBS)
        out = (sched.schedule_random(items, seed=k) if args.random
               else ctl.schedule(items))
        pred_cmax.append(out.cmax)
        batch = build_batches(ds, out.plan, items, out.groups, out.plan.n_mb)
        ts = time.time()
        params, opt, m = step(params, opt, batch, lr_fn(k))
        m["loss"].block_until_ready()
        ctl.observe_step(out, time.time() - ts)
        losses.append(float(m["loss"]))
        if k % 25 == 0:
            print(f"step {k:4d}  loss={losses[-1]:.3f}  "
                  f"pred C_max={out.cmax:.4f}s  solver={out.solver}")
    dt = time.time() - t0
    mode = "random" if args.random else "dflop"
    snap = ctl.metrics.snapshot()
    print(f"[{mode}] {args.steps} steps in {dt:.1f}s; "
          f"loss {losses[0]:.3f} -> {np.mean(losses[-10:]):.3f}; "
          f"mean predicted C_max {np.mean(pred_cmax):.4f}s")
    print(f"[runtime] imbalance={snap['imbalance_mean']:.4f}  "
          f"sched_overhead={snap['sched_elapsed_mean_s'] * 1e3:.2f}ms  "
          f"drift_events={snap['n_drift_events']}  "
          f"replans={snap['n_replans']}")
    if args.trace:
        print(f"chrome trace written to {ctl.export_trace(args.trace)}")
    ctl.close()
    if args.ckpt:
        checkpoint.save(args.ckpt, params, {"steps": args.steps,
                                            "loss": losses[-1]})
        print(f"checkpoint written to {args.ckpt}")


if __name__ == "__main__":
    main()
