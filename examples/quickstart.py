"""Quickstart: DFLOP end-to-end on a tiny MLLM (CPU, ~1 minute).

Profiles a synthetic mixed multimodal dataset, plans the parallelism with
the Data-aware Optimizer, then trains a tiny decoder with the Online
Microbatch Scheduler feeding balanced, sequence-packed microbatches.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp

from repro.common.types import ModelConfig
from repro.core.engine import DFLOPEngine
from repro.core.optimizer.space import ClusterSpec, ModuleParallelism, ParallelismPlan
from repro.data.loader import ScheduledLoader
from repro.data.synthetic import MixedDataset
from repro.models import model as model_lib
from repro.models.model import FwdCtx
from repro.train.optim import AdamWConfig, adamw_init
from repro.train.step import make_train_step


def main():
    cfg = ModelConfig(name="tiny", family="dense", n_layers=4, d_model=128,
                      n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=512,
                      dtype="float32")
    ds = MixedDataset("mixed", seed=0, tokens_per_media_item=8)

    # 1) Profiling Engine + Data-aware Optimizer (analytic backend)
    cluster = ClusterSpec(n_chips=64, chips_per_node=16)
    eng = DFLOPEngine(llm_cfg=cfg, cluster=cluster, tokens_per_media_item=8)
    eng.profile(ds)
    res = eng.plan(gbs=64)
    print(f"[plan] theta*={res.plan.as_tuple()}  expected makespan="
          f"{res.makespan:.4f}s  ({res.n_configs} configs, "
          f"{res.elapsed_s*1e3:.0f} ms)")

    # 2) Online Microbatch Scheduler feeding a real training loop (the local
    #    run uses a single-host plan: dp=1, N_mb microbatches)
    local_plan = ParallelismPlan(llm=ModuleParallelism(1, 1, 1), n_mb=4)
    sched = eng.scheduler(plan=local_plan, adaptive=True,
                          ilp_time_limit_s=0.05)
    loader = ScheduledLoader(ds, sched, gbs=16, token_budget=512,
                             vocab_size=cfg.vocab_size)

    params = model_lib.init(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=3e-3),
        ctx=FwdCtx(mode="train", attn_impl="chunked")))

    it = iter(loader)
    t0 = time.time()
    for k in range(20):
        batch = {k2: jnp.asarray(v) for k2, v in next(it).items()}
        params, opt, m = step(params, opt, batch, 3e-3)
        if k % 5 == 0:
            sc = loader.last_schedule
            print(f"step {k:3d}  loss={float(m['loss']):.3f}  "
                  f"schedule: solver={sc.solver} imbalance={sc.imbalance:.4f}")
    print(f"[done] 20 steps in {time.time()-t0:.1f}s  "
          f"final loss {float(m['loss']):.3f}")


if __name__ == "__main__":
    main()
