"""Serving example: batched greedy decoding with KV caches across families.

Runs a tiny dense (sliding-window) model and a tiny hybrid (Mamba+attention)
model through prefill-free incremental decoding, demonstrating the serving
substrate the decode_32k / long_500k dry-run shapes lower.

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp

from repro.common.types import ModelConfig
from repro.models import model as model_lib
from repro.serve.steps import greedy_generate

CONFIGS = [
    ModelConfig(name="tiny-swa", family="dense", n_layers=4, d_model=128,
                n_heads=4, n_kv_heads=1, d_ff=512, vocab_size=512,
                attention_kind="sliding", window_size=32, dtype="float32"),
    ModelConfig(name="tiny-hybrid", family="hybrid", n_layers=4, d_model=128,
                n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=512,
                layer_pattern=("mamba", "attention"), dtype="float32"),
    ModelConfig(name="tiny-rwkv", family="ssm", n_layers=2, d_model=128,
                n_heads=0, n_kv_heads=0, d_ff=512, vocab_size=512,
                layer_pattern=("rwkv6",), rwkv_head_dim=32, dtype="float32"),
]


def main():
    B, prompt_len, max_new = 4, 16, 32
    for cfg in CONFIGS:
        params = model_lib.init(jax.random.PRNGKey(0), cfg)
        prompt = jax.random.randint(jax.random.PRNGKey(1), (B, prompt_len),
                                    2, cfg.vocab_size)
        t0 = time.time()
        out = greedy_generate(cfg, params, prompt, max_new=max_new,
                              max_len=prompt_len + max_new)
        dt = time.time() - t0
        assert out.shape == (B, prompt_len + max_new)
        assert bool(jnp.all(out >= 0))
        print(f"{cfg.name:12s} generated {B}x{max_new} tokens in {dt:.2f}s "
              f"({B*max_new/dt:.0f} tok/s incl. compile) "
              f"sample: {out[0, prompt_len:prompt_len+8].tolist()}")


if __name__ == "__main__":
    main()
