"""Planner inspection: run the Profiling Engine + Data-aware Optimizer for a
paper-scale MLLM on a v5e pod and print the chosen plan vs tuned baselines —
the paper's Fig. 3 offline phase, end to end.

    PYTHONPATH=src python examples/plan_inspector.py [--arch llava-ov-qwen7b]
"""
import argparse

from repro.configs import get_config, list_archs
from repro.core.engine import DFLOPEngine
from repro.core.optimizer.space import ClusterSpec
from repro.core.profiling.analytic import AnalyticBackend, V5E
from repro.data.synthetic import MixedDataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llava-ov-qwen7b",
                    choices=list_archs())
    ap.add_argument("--chips", type=int, default=256)
    ap.add_argument("--gbs", type=int, default=256)
    ap.add_argument("--objective", default="mean",
                    choices=["mean", "expected-random", "balanced-quantile"],
                    help="search objective (balanced-quantile is "
                         "heterogeneity-aware — try it at small --gbs)")
    ap.add_argument("--seed", type=int, default=0,
                    help="Monte-Carlo seed for the sampling objectives")
    args = ap.parse_args()

    spec = get_config(args.arch)
    ds = MixedDataset("mixed", seed=0,
                      tokens_per_media_item=spec.tokens_per_media_item or 196)
    eng = DFLOPEngine(
        llm_cfg=spec.llm_cfg,
        enc_cfg=spec.desc.encoder if spec.is_mllm else None,
        e_seq_len=spec.desc.stub.n_tokens if spec.is_mllm else 0,
        cluster=ClusterSpec(n_chips=args.chips, chips_per_node=16),
        tokens_per_media_item=spec.tokens_per_media_item or 196,
        backend=AnalyticBackend(V5E))
    eng.profile(ds)
    mb, ms = eng.dist.mean()
    print(f"[data]  mean enc batch {mb:.1f} items, mean LLM seq {ms:.0f} "
          f"tokens, heterogeneity CV={eng.dist.heterogeneity():.2f}")

    eng.objective = args.objective
    res = eng.plan(args.gbs, seed=args.seed)
    e_tp, e_pp, e_dp, l_tp, l_pp, l_dp, n_mb = res.plan.as_tuple()
    print(f"[theta*] encoder (tp={e_tp}, pp={e_pp}, dp={e_dp})  "
          f"llm (tp={l_tp}, pp={l_pp}, dp={l_dp})  N_mb={n_mb}")
    print(f"[theta*] expected makespan {res.makespan:.4f}s  "
          f"searched {res.n_configs} configs / {res.n_feasible} feasible "
          f"in {res.elapsed_s*1e3:.0f} ms")

    # baselines are scored by the mean-shape estimate; compare them against
    # the chosen plan under the same estimator so the ratios are
    # like-for-like even when a sampling objective picked the plan.
    from repro.core.optimizer.objective import MeanObjective
    ref = MeanObjective().evaluate(eng.perf, res.plan, eng.dist, args.gbs)
    print("[baselines] uniform (tp, pp) grid, memory-feasible only:")
    for tp in (1, 2, 4, 8, 16):
        for pp in (1, 2, 4):
            b = eng.baseline_plan(args.gbs, tp=tp, pp=pp)
            if b.found and b.makespan != float("inf"):
                print(f"    tp={tp:2d} pp={pp}: makespan {b.makespan:.4f}s "
                      f"({b.makespan/ref:.2f}x DFLOP)")


if __name__ == "__main__":
    main()
