from repro.models import model as model_lib
from repro.models import mllm as mllm_lib

__all__ = ["model_lib", "mllm_lib"]
