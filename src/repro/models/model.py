"""Generic transformer stack covering all assigned families.

One parameter/apply convention serves dense, MoE, SSM (RWKV6), hybrid
(Jamba: Mamba+attention interleave with MoE-every-other-layer), encoder-only
(HuBERT) and VLM-backbone models.  Layers are *stacked per pattern position*
and iterated with ``lax.scan`` over blocks (compile-time critical at 512
devices); heterogeneous patterns (Jamba's period-8 interleave) unroll within
the block and scan across blocks.

Params tree:
    embed/w            (vocab, d)          [if vocab_size > 0]
    in_proj/w          (input_embed_dim,d) [if input_embed_dim > 0]
    blocks/pos{j}/...  stacked (n_blocks, ...) per pattern position j
    final_norm/scale
    unembed/w          (d, vocab)          [if has_lm_head and not tied]
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.common.types import FFNKind, LayerKind, ModelConfig
from repro.models.layers import attention, embed, ffn, mamba, moe, norms, rwkv6


@dataclass
class FwdCtx:
    """Per-call forward options (static except decode_pos)."""

    mode: str = "train"              # train | prefill | decode
    attn_impl: str = "chunked"       # naive | chunked | pallas
    attn_block: int = 512            # flash (block_q, block_k) tile
    ssm_impl: str = "xla"            # xla | pallas
    moe_impl: str = "capacity"       # dense | capacity
    capacity_factor: float = 2.0
    moe_chunk_tokens: int = 0        # >0: chunked+checkpointed dispatch
    moe_constrain: Optional[Callable] = None
    logits_constrain: Optional[Callable] = None   # e.g. shard vocab dim
    block_constrain: Optional[Callable] = None    # ZeRO-3 per-block weight
                                                  # gather (bwd: reduce-scatter)
    hidden_constrain: Optional[Callable] = None   # pin (B,S,d) activation
                                                  # sharding per block
    shard_ctx: Any = None            # (mesh, batch_axes, model_axes) for
                                     # shard_map'd recurrent scans
    return_hidden: bool = False      # skip the LM head (vocab-parallel CE)
    decode_pos: Any = None           # traced scalar in decode mode
    remat: bool = True


# --------------------------------------------------------------------------- #
# Layer init / apply
# --------------------------------------------------------------------------- #
def _layer_init(key, cfg: ModelConfig, kind: LayerKind, ffn_kind: FFNKind,
                dtype):
    d = cfg.d_model
    k1, k2 = jax.random.split(key)
    p: dict = {"ln1": norms.rms_init(d, dtype)}
    if kind == LayerKind.ATTENTION:
        p["attn"] = attention.init(k1, cfg, dtype)
    elif kind == LayerKind.MAMBA:
        p["mamba"] = mamba.init(k1, cfg, dtype)
    elif kind == LayerKind.RWKV6:
        p["rwkv"] = rwkv6.init(k1, cfg, dtype)
        p["ln2"] = norms.rms_init(d, dtype)
        return p                      # rwkv block has its own channel mix
    p["ln2"] = norms.rms_init(d, dtype)
    if ffn_kind == FFNKind.MOE:
        p["moe"] = moe.init(k2, cfg, dtype)
    else:
        p["ffn"] = ffn.init(k2, cfg, dtype)
    return p


def _layer_apply(lp, x, cfg: ModelConfig, kind: LayerKind, ffn_kind: FFNKind,
                 ctx: FwdCtx, cache, positions, segment_ids):
    """Returns (x, new_cache, lb, moe_stats); moe_stats is None for
    non-MoE layers and a (drop_rate, imbalance) pair (possibly NaN —
    shard_map dispatch doesn't measure) for MoE layers."""
    lb = jnp.zeros((), jnp.float32)
    moe_stats = None
    h = norms.rms_apply(lp["ln1"], x, cfg.norm_eps)
    if kind == LayerKind.ATTENTION:
        attn_cache = cache.get("attn") if cache else None
        y, new_attn_cache = attention.apply(
            lp["attn"], h, cfg, positions=positions, segment_ids=segment_ids,
            cache=attn_cache, decode_pos=ctx.decode_pos, impl=ctx.attn_impl,
            block=ctx.attn_block)
        new_cache = {"attn": new_attn_cache} if cache else None
    elif kind == LayerKind.MAMBA:
        m_cache = cache.get("mamba") if cache else None
        # chunked selective scan only outside training: its closed-form
        # intra-chunk tensor is cheap to run but expensive to keep as
        # autodiff residuals (remat recompute makes them all live)
        m_impl = ctx.ssm_impl
        if ctx.mode == "train" and m_impl == "chunked":
            m_impl = "xla"
        y, new_m = mamba.apply(lp["mamba"], h, cfg, cache=m_cache,
                               impl=m_impl,
                               shard_ctx=None if m_cache is not None
                               else ctx.shard_ctx)
        new_cache = {"mamba": new_m} if cache else None
    elif kind == LayerKind.RWKV6:
        r_cache = cache.get("rwkv") if cache else None
        y, new_r = rwkv6.time_mix(lp["rwkv"], h, cfg, cache=r_cache,
                                  impl=ctx.ssm_impl)
        x = x + y
        h2 = norms.rms_apply(lp["ln2"], x, cfg.norm_eps)
        y2, new_r2 = rwkv6.channel_mix(lp["rwkv"], h2, cfg, cache=new_r)
        new_cache = {"rwkv": new_r2} if cache else None
        return x + y2, new_cache, lb, moe_stats
    else:
        raise ValueError(kind)
    x = x + y
    h2 = norms.rms_apply(lp["ln2"], x, cfg.norm_eps)
    if ffn_kind == FFNKind.MOE:
        y2, lb, st = moe.apply(lp["moe"], h2, cfg, impl=ctx.moe_impl,
                               capacity_factor=ctx.capacity_factor,
                               constrain=ctx.moe_constrain,
                               chunk_tokens=ctx.moe_chunk_tokens,
                               shard_ctx=ctx.shard_ctx, with_stats=True)
        moe_stats = (jax.lax.stop_gradient(st["drop_rate"]),
                     jax.lax.stop_gradient(st["imbalance"]))
    else:
        y2 = ffn.apply(lp["ffn"], h2, cfg)
    return x + y2, new_cache, lb, moe_stats


# --------------------------------------------------------------------------- #
# Model init
# --------------------------------------------------------------------------- #
def init(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    period = cfg.block_period
    n_blocks = cfg.n_layers // period
    kinds, ffns = cfg.layer_kinds, cfg.ffn_kinds
    keys = jax.random.split(key, cfg.n_layers + 3)

    params: dict = {}
    if cfg.vocab_size > 0 and cfg.input_embed_dim == 0:
        params["embed"] = embed.init(keys[-1], cfg.vocab_size, cfg.d_model, dtype)
    if cfg.input_embed_dim > 0:
        params["in_proj"] = {
            "w": (jax.random.normal(keys[-2], (cfg.input_embed_dim, cfg.d_model))
                  * cfg.input_embed_dim ** -0.5).astype(dtype)}

    blocks: dict = {}
    for j in range(period):
        per_block = [
            _layer_init(keys[b * period + j], cfg, kinds[j], ffns[j], dtype)
            for b in range(n_blocks)
        ]
        blocks[f"pos{j}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per_block)
    params["blocks"] = blocks
    params["final_norm"] = norms.rms_init(cfg.d_model, dtype)
    if cfg.has_lm_head and cfg.vocab_size > 0 and not cfg.tie_embeddings:
        params["unembed"] = embed.unembed_init(keys[-3], cfg.d_model,
                                               cfg.vocab_size, dtype)
    return params


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               kv_dtype=jnp.bfloat16):
    """Stacked per-position caches matching the params layout."""
    period = cfg.block_period
    n_blocks = cfg.n_layers // period
    kinds = cfg.layer_kinds
    caches: dict = {}
    for j in range(period):
        kind = kinds[j]
        if kind == LayerKind.ATTENTION:
            c = {"attn": attention.init_cache(cfg, batch, max_len, kv_dtype)}
        elif kind == LayerKind.MAMBA:
            c = {"mamba": mamba.init_cache(cfg, batch)}
        else:
            c = {"rwkv": rwkv6.init_cache(cfg, batch)}
        caches[f"pos{j}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_blocks,) + a.shape), c)
    return caches


# --------------------------------------------------------------------------- #
# Forward
# --------------------------------------------------------------------------- #
def forward(params, cfg: ModelConfig, *, tokens=None, embeds=None,
            positions=None, segment_ids=None, caches=None,
            ctx: Optional[FwdCtx] = None):
    """Returns (logits_or_hidden, new_caches, aux dict)."""
    ctx = ctx or FwdCtx()
    compute_dtype = jnp.dtype(cfg.dtype)

    if embeds is not None:
        x = embeds.astype(compute_dtype)
        if "in_proj" in params:
            x = jnp.einsum("bse,ed->bsd", x,
                           params["in_proj"]["w"].astype(compute_dtype))
    else:
        x = embed.encode(params["embed"], tokens, compute_dtype)

    B, S = x.shape[0], x.shape[1]
    if positions is None and ctx.mode != "decode":
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    period = cfg.block_period
    kinds, ffns = cfg.layer_kinds, cfg.ffn_kinds

    def block_body(carry, xs):
        x, lb, drop, imb = carry
        bp, bc = xs
        if ctx.hidden_constrain is not None:
            # anchor the activation layout every block: stops SPMD sharding
            # drift (e.g. MQA's unshardable kv head replicating the batch)
            x = ctx.hidden_constrain(x)
        new_bc = {} if bc is not None else None
        for j in range(period):
            cache_j = bc[f"pos{j}"] if bc is not None else None
            lp = bp[f"pos{j}"]
            if ctx.block_constrain is not None:
                # ZeRO-3: gather THIS layer's FSDP-sharded weights just
                # before use (loop-variant — the scan slices a different
                # block each iteration, so the all-gather is not hoisted;
                # per-position granularity keeps only one layer's gathered
                # weights live).  Its VJP reduce-scatters dW.
                lp = ctx.block_constrain(lp, j)

            x, nc, l, st = _layer_apply(lp, x, cfg, kinds[j], ffns[j],
                                        ctx, cache_j, positions, segment_ids)
            if new_bc is not None:
                new_bc[f"pos{j}"] = nc
            lb = lb + l
            if st is not None:
                # mean drop across MoE layers; worst-layer imbalance (the
                # straggler expert matmul).  NaN (shard_map: unmeasured)
                # propagates through both — never coerced to 0.0.
                drop = drop + st[0]
                imb = jnp.maximum(imb, st[1])
        return (x, lb, drop, imb), new_bc

    body = block_body
    if ctx.mode == "train" and cfg.remat and ctx.remat:
        body = jax.checkpoint(block_body, prevent_cse=False)

    zero = jnp.zeros((), jnp.float32)
    carry0 = (x, zero, zero, zero)       # (x, lb, moe drop sum, moe imb max)
    n_blocks = cfg.n_layers // period
    if cfg.scan_layers and caches is not None and ctx.mode == "decode":
        # decode: keep the stacked caches in the scan CARRY and update the
        # current block's slice in place — scan xs/ys would double-buffer
        # the whole multi-GB cache (input and output live simultaneously).
        def decode_body(carry, xs):
            x_lb, caches_all = carry
            bp, i = xs
            bc = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
                caches_all)
            new_x_lb, new_bc = body(x_lb, (bp, bc))
            caches_all = jax.tree.map(
                lambda a, nc: jax.lax.dynamic_update_index_in_dim(
                    a, nc.astype(a.dtype), i, 0),
                caches_all, new_bc)
            return (new_x_lb, caches_all), None

        ((x, lb, drop, imb), new_caches), _ = jax.lax.scan(
            decode_body, (carry0, caches),
            (params["blocks"], jnp.arange(n_blocks)))
    elif cfg.scan_layers:
        (x, lb, drop, imb), new_caches = jax.lax.scan(
            body, carry0, (params["blocks"], caches))
    else:
        new_list = []
        carry = carry0
        for b in range(n_blocks):
            bp = jax.tree.map(lambda a: a[b], params["blocks"])
            bc = jax.tree.map(lambda a: a[b], caches) if caches is not None else None
            carry, nc = body(carry, (bp, bc))
            new_list.append(nc)
        x, lb, drop, imb = carry
        new_caches = None
        if caches is not None:
            new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *new_list)

    x = norms.rms_apply(params["final_norm"], x, cfg.norm_eps)
    n_moe_layers = sum(1 for f in ffns if f == FFNKind.MOE)
    total_moe = n_moe_layers * n_blocks         # MoE applications per forward
    nan = jnp.full((), jnp.nan, jnp.float32)
    aux = {
        "lb_loss": lb / max(1, n_moe_layers),
        # NaN (not 0.0) when the model has no MoE layers at all
        "moe_drop_rate": drop / total_moe if total_moe else nan,
        "moe_imbalance": imb if total_moe else nan,
    }
    if ctx.return_hidden or not (cfg.has_lm_head and cfg.vocab_size > 0):
        return x, new_caches, aux
    if cfg.tie_embeddings:
        logits = embed.decode(params["embed"], x)
    else:
        logits = embed.unembed(params["unembed"], x)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    if ctx.logits_constrain is not None:
        logits = ctx.logits_constrain(logits)
    return logits, new_caches, aux


def decode_step(params, cfg: ModelConfig, token, caches, pos,
                ctx: Optional[FwdCtx] = None):
    """One decode step. token: (B,) int32 (or (B,1)); pos: scalar int, or a
    (B,) array of per-row positions when batch rows hold independent
    requests at different depths (continuous batching — see repro.serve)."""
    ctx = ctx or FwdCtx(mode="decode", remat=False)
    ctx.mode = "decode"
    ctx.decode_pos = pos
    if token.ndim == 1:
        token = token[:, None]
    logits, new_caches, aux = forward(params, cfg, tokens=token,
                                      caches=caches, ctx=ctx)
    return logits[:, 0], new_caches, aux
