"""MLLM composition: modality encoder -> connector -> LLM (paper §2.1).

This is the object DFLOP optimizes: two architecturally distinct modules with
independent sharding plans, bridged by a connector whose boundary reshard is
the TPU realization of the paper's Inter-model Communicator (§4).

Batch convention (modality frontend stubbed per assignment):
    media_embeds : (B, T_media, embed_dim)  precomputed patch/frame embeds
    media_mask   : (B, T_media)             1 = real media token
    text_tokens  : (B, T_text) int32
    text_mask    : (B, T_text)              1 = real text token
    labels       : (B, T_text) int32        next-token targets (-1 = ignore)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.types import MLLMConfig
from repro.models import model as model_lib
from repro.models.layers import embed as embed_lib
from repro.models.model import FwdCtx


def init(key, mcfg: MLLMConfig):
    ke, kc, kl = jax.random.split(key, 3)
    de, dl = mcfg.encoder.d_model, mcfg.llm.d_model
    dtype = jnp.dtype(mcfg.llm.param_dtype)
    connector: dict = {}
    if mcfg.connector_hidden:
        connector["w1"] = (jax.random.normal(kc, (de, mcfg.connector_hidden))
                           * de ** -0.5).astype(dtype)
        connector["w2"] = (jax.random.normal(jax.random.fold_in(kc, 1),
                                             (mcfg.connector_hidden, dl))
                           * mcfg.connector_hidden ** -0.5).astype(dtype)
    else:
        connector["w1"] = (jax.random.normal(kc, (de, dl)) * de ** -0.5).astype(dtype)
    return {
        "encoder": model_lib.init(ke, mcfg.encoder),
        "connector": connector,
        "llm": model_lib.init(kl, mcfg.llm),
    }


def apply_connector(params, h, mcfg: MLLMConfig):
    w1 = params["w1"].astype(h.dtype)
    if "w2" in params:
        h = jax.nn.gelu(jnp.einsum("bsd,dh->bsh", h, w1))
        return jnp.einsum("bsh,hd->bsd", h, params["w2"].astype(h.dtype))
    return jnp.einsum("bsd,dh->bsh", h, w1)


def encode_media(params, mcfg: MLLMConfig, media_embeds, media_mask=None,
                 ctx: Optional[FwdCtx] = None, communicator=None):
    """Encoder + connector. Returns LLM-space media tokens (B, T_out, dl).

    `ctx` here is the ENCODER's forward context (the encoder may carry its
    own sharding constraints under DFLOP's heterogeneous plans)."""
    ctx = ctx or FwdCtx(mode="train")
    seg = None
    if media_mask is not None:
        # mask -> segment ids: padding gets segment 0, real tokens segment 1.
        # (multi-image packing can supply richer ids via media_mask directly.)
        seg = media_mask.astype(jnp.int32)
    h, _, _ = model_lib.forward(params["encoder"], mcfg.encoder,
                                embeds=media_embeds, segment_ids=seg, ctx=ctx)
    if communicator is not None:
        # Inter-model Communicator: reshard encoder output from the encoder's
        # data-parallel layout to the LLM's (paper Fig. 6).
        h = communicator(h)
    h = apply_connector(params["connector"], h, mcfg)
    if mcfg.tokens_per_item_out:
        t_in = h.shape[1]
        factor = max(1, t_in // mcfg.tokens_per_item_out)
        if factor > 1:
            b, _, d = h.shape
            h = h[:, : (t_in // factor) * factor]
            h = h.reshape(b, t_in // factor, factor, d).mean(axis=2)
    return h


def forward_train(params, mcfg: MLLMConfig, batch, ctx: Optional[FwdCtx] = None,
                  communicator=None, enc_ctx: Optional[FwdCtx] = None):
    """Full multimodal forward: returns (logits over text span, aux).

    `enc_ctx` (optional) carries encoder-specific sharding constraints —
    DFLOP's independent per-module parallelism."""
    ctx = ctx or FwdCtx(mode="train")
    media = encode_media(params, mcfg, batch["media_embeds"],
                         batch.get("media_mask"), ctx=enc_ctx or ctx,
                         communicator=communicator)
    llm_cfg = mcfg.llm
    compute_dtype = jnp.dtype(llm_cfg.dtype)
    text_emb = embed_lib.encode(params["llm"]["embed"],
                                batch["text_tokens"], compute_dtype)
    x = jnp.concatenate([media.astype(compute_dtype), text_emb], axis=1)
    B, T_m = media.shape[0], media.shape[1]
    T_t = text_emb.shape[1]
    positions = jnp.broadcast_to(jnp.arange(T_m + T_t)[None], (B, T_m + T_t))
    seg = None
    if "media_mask" in batch and "text_mask" in batch:
        m_seg = jnp.ones((B, T_m), jnp.int32)
        t_seg = jnp.where(batch["text_mask"] > 0, 1, 0).astype(jnp.int32)
        seg = jnp.concatenate([m_seg, t_seg], axis=1)
    logits, _, aux = model_lib.forward(params["llm"], llm_cfg, embeds=x,
                                       positions=positions, segment_ids=seg,
                                       ctx=ctx)
    return logits[:, T_m:], aux
