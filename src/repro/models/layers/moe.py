"""Mixture-of-Experts FFN: top-k routing with capacity-based dispatch.

Two equivalent dispatch paths:

  * ``dense``    — every expert processes every token, masked combine.
                   O(E/top_k) FLOP inflation; used as the correctness oracle.
  * ``capacity`` — GShard/Switch-style: tokens are scattered into a fixed
                   (E, C, d) buffer (C = ceil(T·k/E·capacity_factor)), expert
                   matmuls run as one batched einsum, results gathered back.
                   Active-FLOPs faithful; the expert dim is sharded over the
                   tensor axes (expert parallelism) — XLA emits the
                   all-to-alls that GPU frameworks issue explicitly.

Both return a Switch-style load-balance auxiliary loss (needed by the
router to keep the capacity path's drop rate near zero).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
from repro.common import compat
import jax.numpy as jnp

from repro.common.types import ModelConfig
from repro.models.layers.ffn import GATED, _act


def init(key, cfg: ModelConfig, dtype=jnp.float32):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    kr, kg, ku, kd = jax.random.split(key, 4)
    s_in, s_out = d ** -0.5, ff ** -0.5
    p = {
        "router": (jax.random.normal(kr, (d, E)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(ku, (E, d, ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(kd, (E, ff, d)) * s_out).astype(dtype),
    }
    if cfg.activation in GATED:
        p["w_gate"] = (jax.random.normal(kg, (E, d, ff)) * s_in).astype(dtype)
    return p


def _route(params, x2d, cfg: ModelConfig):
    """x2d: (T, d) -> top-k weights/indices + load-balance loss."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                  # (T, E)
    top_w, top_e = jax.lax.top_k(probs, cfg.top_k)           # (T, k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)
    # Switch load-balance loss: E * sum_e f_e * p_e
    E = cfg.n_experts
    f = jnp.mean(jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=(0, 1))
    p_mean = jnp.mean(probs, axis=0)
    lb_loss = E * jnp.sum(f * p_mean)
    return top_w, top_e, lb_loss


def _load_imbalance(top_e, E: int):
    """Expert-load imbalance: ``E · max_e(f_e) − 1`` over the routed
    assignment fractions f (0 = perfectly uniform, E − 1 = one expert takes
    everything).  Same f as the Switch lb loss, so the two agree on what
    "load" means."""
    f = jnp.mean(jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=(0, 1))
    return E * jnp.max(f) - 1.0


def _expert_ffn(params, xe, cfg: ModelConfig):
    """xe: (E, C, d) -> (E, C, d), batched over experts."""
    up = jnp.einsum("ecd,edf->ecf", xe, params["w_up"].astype(xe.dtype))
    if cfg.activation in GATED:
        gate = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"].astype(xe.dtype))
        h = _act(cfg.activation, gate) * up
    else:
        h = _act(cfg.activation, up)
    return jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(xe.dtype))


def apply_dense(params, x, cfg: ModelConfig, *, with_stats: bool = False):
    """Oracle path: (B,S,d) -> (B,S,d), every expert sees every token."""
    B, S, d = x.shape
    x2d = x.reshape(B * S, d)
    top_w, top_e, lb_loss = _route(params, x2d, cfg)
    y_all = _expert_ffn(params, jnp.broadcast_to(x2d[None], (cfg.n_experts, B * S, d)),
                        cfg)                                  # (E, T, d)
    combine = jnp.zeros((B * S, cfg.n_experts), jnp.float32)
    combine = combine.at[jnp.arange(B * S)[:, None], top_e].add(top_w)
    y = jnp.einsum("te,etd->td", combine.astype(x.dtype), y_all)
    if with_stats:
        stats = {"drop_rate": jnp.zeros((), jnp.float32),   # dense never drops
                 "imbalance": _load_imbalance(top_e, cfg.n_experts)}
        return y.reshape(B, S, d), lb_loss, stats
    return y.reshape(B, S, d), lb_loss


def apply_capacity(params, x, cfg: ModelConfig, *, capacity_factor: float = 1.25,
                   constrain: Optional[Callable] = None,
                   with_stats: bool = False):
    """Scatter/gather dispatch with fixed per-expert capacity.

    With ``with_stats`` also returns {"drop_rate", "imbalance"} — the
    fraction of (token, expert) assignments silently zeroed by the capacity
    clip, and the routed-load skew (``_load_imbalance``), the two
    quantities the duration model needs to price MoE layers."""
    B, S, d = x.shape
    T, E, k = B * S, cfg.n_experts, cfg.top_k
    x2d = x.reshape(T, d)
    top_w, top_e, lb_loss = _route(params, x2d, cfg)

    C = int(max(1, -(-T * k * capacity_factor // E)))        # ceil
    flat_e = top_e.reshape(-1)                               # (T*k,)
    flat_w = top_w.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)
    # position of each (token, expert) pair within its expert's buffer
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)          # (T*k, E)
    pos = (jnp.cumsum(oh, axis=0) - 1)                       # running count
    flat_pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = flat_pos < C
    flat_w = jnp.where(keep, flat_w, 0.0)
    slot = jnp.where(keep, flat_pos, C - 1)                  # clip (weight=0)

    xe = jnp.zeros((E, C, d), x.dtype)
    xe = xe.at[flat_e, slot].add(jnp.where(keep[:, None], x2d[flat_t], 0))
    if constrain is not None:
        xe = constrain(xe)
    ye = _expert_ffn(params, xe, cfg)                        # (E, C, d)
    if constrain is not None:
        ye = constrain(ye)
    y = jnp.zeros((T, d), x.dtype)
    y = y.at[flat_t].add(ye[flat_e, slot] * flat_w[:, None].astype(x.dtype))
    if with_stats:
        stats = {
            "drop_rate": 1.0 - jnp.sum(keep.astype(jnp.float32)) / (T * k),
            "imbalance": _load_imbalance(top_e, E),
        }
        return y.reshape(B, S, d), lb_loss, stats
    return y.reshape(B, S, d), lb_loss


def apply_capacity_chunked(params, x, cfg: ModelConfig, *,
                           capacity_factor: float = 1.25, constrain=None,
                           chunk_tokens: int = 8192, with_stats: bool = False):
    """Token-chunked dispatch: bounds the (T·k, d) gather/scatter working set
    (which XLA otherwise materializes replicated) to one chunk; each chunk is
    checkpointed so backward recomputes instead of saving chunk residuals."""
    B, S, d = x.shape
    T = B * S
    c = min(chunk_tokens, T)
    while T % c:
        c -= 1
    n_chunks = T // c
    if n_chunks == 1:
        return apply_capacity(params, x, cfg,
                              capacity_factor=capacity_factor,
                              constrain=constrain, with_stats=with_stats)
    x2d = x.reshape(n_chunks, 1, c, d)
    zero = jnp.zeros((), jnp.float32)

    def chunk_fn(carry, xc):
        lb_c, drop_c, imb_c = carry
        if with_stats:
            y, lb, st = apply_capacity(params, xc, cfg,
                                       capacity_factor=capacity_factor,
                                       constrain=constrain, with_stats=True)
            return (lb_c + lb, drop_c + st["drop_rate"],
                    jnp.maximum(imb_c, st["imbalance"])), y
        y, lb = apply_capacity(params, xc, cfg,
                               capacity_factor=capacity_factor,
                               constrain=constrain)
        return (lb_c + lb, drop_c, imb_c), y

    body = jax.checkpoint(chunk_fn, prevent_cse=False)
    (lb, drop, imb), ys = jax.lax.scan(body, (zero, zero, zero), x2d)
    y = ys.reshape(B, S, d)
    if with_stats:
        # mean drop over chunks; worst-chunk imbalance (that's the chunk
        # whose expert matmul is the straggler)
        return y, lb / n_chunks, {"drop_rate": drop / n_chunks,
                                  "imbalance": imb}
    return y, lb / n_chunks


def apply_ep_shard_map(params, x, cfg: ModelConfig, shard_ctx, *,
                       capacity_factor: float = 1.25):
    """True expert parallelism via shard_map (Megatron-style EP×TP).

    Requires n_experts % model-axis size == 0.  Activations are replicated
    over the model axes; every shard routes the full local token set, keeps
    only the assignments for its resident experts, computes them locally and
    psums the partial combine — ONE (tokens, d) all-reduce per layer instead
    of the SPMD partitioner's per-dispatch gather storm (measured 7 TB/step
    on jamba-52B; see EXPERIMENTS.md §Perf).  Returns None if inapplicable.
    """
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.sharding.partition import sanitize_spec

    mesh, b_axes, m_axes = shard_ctx
    E = cfg.n_experts
    msize = int(np.prod([mesh.shape[a] for a in m_axes], initial=1))
    if not m_axes or msize == 1:
        return None
    if E % msize != 0:
        # experts don't divide the model axes (mixtral 8e / granite 40e on a
        # 16-wide axis): TP-sharded experts instead — every shard owns ALL
        # experts' ff-slices, dispatch is fully local, one psum combines.
        if cfg.d_ff % msize == 0:
            return _apply_tp_shard_map(params, x, cfg, shard_ctx,
                                       capacity_factor=capacity_factor)
        return None
    B, S, d = x.shape
    E_loc = E // msize
    maxis = m_axes[0] if len(m_axes) == 1 else m_axes

    x_spec = sanitize_spec(P(tuple(b_axes) or None, None, None), x.shape, mesh)
    w_e = P(tuple(m_axes), None, None)
    in_specs = {"router": P(None, None), "w_up": w_e, "w_down": w_e}
    if "w_gate" in params:
        in_specs["w_gate"] = w_e

    def local(p_l, x_l):
        Bl, Sl, _ = x_l.shape
        T = Bl * Sl
        x2d = x_l.reshape(T, d)
        top_w, top_e, lb = _route(p_l, x2d, cfg)     # replicated over model
        C = int(max(1, -(-T * cfg.top_k * capacity_factor // E)))
        shard = jax.lax.axis_index(m_axes[0])
        for a in m_axes[1:]:
            shard = shard * mesh.shape[a] + jax.lax.axis_index(a)
        offset = shard * E_loc
        flat_e = top_e.reshape(-1) - offset          # local expert ids
        flat_w = top_w.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(T), cfg.top_k)
        mine = (flat_e >= 0) & (flat_e < E_loc)
        e_clip = jnp.clip(flat_e, 0, E_loc - 1)
        oh = jax.nn.one_hot(jnp.where(mine, e_clip, E_loc), E_loc + 1,
                            dtype=jnp.int32)[:, :E_loc]
        pos = jnp.cumsum(oh, axis=0) - 1
        flat_pos = jnp.take_along_axis(pos, e_clip[:, None], axis=1)[:, 0]
        keep = mine & (flat_pos < C)
        slot = jnp.where(keep, flat_pos, C - 1)
        xe = jnp.zeros((E_loc, C, d), x_l.dtype)
        xe = xe.at[e_clip, slot].add(jnp.where(keep[:, None], x2d[flat_t], 0))
        ye = _expert_ffn(p_l, xe, cfg)
        w_eff = jnp.where(keep, flat_w, 0.0).astype(x_l.dtype)
        y = jnp.zeros((T, d), x_l.dtype)
        y = y.at[flat_t].add(ye[e_clip, slot] * w_eff[:, None])
        y = jax.lax.psum(y, maxis)                   # combine expert shards
        # lb differs per batch shard: average so the scalar is replicated
        for a in b_axes:
            lb = jax.lax.pmean(lb, a)
        return y.reshape(Bl, Sl, d), lb

    sm = compat.shard_map(local, mesh=mesh,
                       in_specs=({k: in_specs[k] for k in params}, x_spec),
                       out_specs=(x_spec, P()), check_vma=False)
    # lb is computed identically on every shard (replicated routing)
    y, lb = sm(params, x)
    return y, lb


def _apply_tp_shard_map(params, x, cfg: ModelConfig, shard_ctx, *,
                        capacity_factor: float = 1.25):
    """TP-sharded experts with local dispatch (E ∤ model axes).

    Each model shard holds every expert's d_ff/msize slice; the scatter/
    gather dispatch runs on local (batch-sharded, model-replicated) tokens —
    no partitioner-inserted gathers — and the only collective is the psum
    that sums the ff partial products."""
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.sharding.partition import sanitize_spec

    mesh, b_axes, m_axes = shard_ctx
    E, d = cfg.n_experts, cfg.d_model
    maxis = m_axes[0] if len(m_axes) == 1 else m_axes
    x_spec = sanitize_spec(P(tuple(b_axes) or None, None, None), x.shape, mesh)
    w_up_spec = P(None, None, tuple(m_axes))       # (E, d, ff/m)
    w_dn_spec = P(None, tuple(m_axes), None)       # (E, ff/m, d)
    in_specs = {"router": P(None, None), "w_up": w_up_spec,
                "w_down": w_dn_spec}
    if "w_gate" in params:
        in_specs["w_gate"] = w_up_spec

    def local(p_l, x_l):
        Bl, Sl, _ = x_l.shape
        T = Bl * Sl
        x2d = x_l.reshape(T, d)
        top_w, top_e, lb = _route(p_l, x2d, cfg)
        C = int(max(1, -(-T * cfg.top_k * capacity_factor // E)))
        flat_e = top_e.reshape(-1)
        flat_w = top_w.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(T), cfg.top_k)
        oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        pos = jnp.cumsum(oh, axis=0) - 1
        flat_pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
        keep = flat_pos < C
        slot = jnp.where(keep, flat_pos, C - 1)
        xe = jnp.zeros((E, C, d), x_l.dtype)
        xe = xe.at[flat_e, slot].add(jnp.where(keep[:, None], x2d[flat_t], 0))
        # expert FFN on the local ff slice; psum sums ff partials
        up = jnp.einsum("ecd,edf->ecf", xe, p_l["w_up"].astype(xe.dtype))
        if "w_gate" in p_l:
            gate = jnp.einsum("ecd,edf->ecf", xe,
                              p_l["w_gate"].astype(xe.dtype))
            h = _act(cfg.activation, gate) * up
        else:
            h = _act(cfg.activation, up)
        ye = jnp.einsum("ecf,efd->ecd", h, p_l["w_down"].astype(xe.dtype))
        ye = jax.lax.psum(ye, maxis)
        w_eff = jnp.where(keep, flat_w, 0.0).astype(x_l.dtype)
        y = jnp.zeros((T, d), x_l.dtype)
        y = y.at[flat_t].add(ye[flat_e, slot] * w_eff[:, None])
        for a in b_axes:
            lb = jax.lax.pmean(lb, a)
        return y.reshape(Bl, Sl, d), lb

    sm = compat.shard_map(local, mesh=mesh,
                       in_specs=({k: in_specs[k] for k in params}, x_spec),
                       out_specs=(x_spec, P()), check_vma=False)
    return sm(params, x)


def apply(params, x, cfg: ModelConfig, *, impl: str = "capacity",
          capacity_factor: float = 1.25, constrain=None,
          chunk_tokens: int = 0, shard_ctx=None, with_stats: bool = False):
    """Dispatch to a MoE path; ``with_stats`` appends a
    {"drop_rate", "imbalance"} dict to the (y, lb) return.  The shard_map
    paths don't measure their (per-shard) dispatch — their stats are NaN,
    never a fake 0.0 (the RuntimeMetrics convention)."""
    if impl == "dense":
        return apply_dense(params, x, cfg, with_stats=with_stats)
    if impl == "ep" and shard_ctx is not None:
        out = apply_ep_shard_map(params, x, cfg, shard_ctx,
                                 capacity_factor=capacity_factor)
        if out is not None:
            if with_stats:
                nan = jnp.full((), jnp.nan, jnp.float32)
                return out[0], out[1], {"drop_rate": nan, "imbalance": nan}
            return out
        # experts don't divide the model axes: fall through
    if chunk_tokens:
        return apply_capacity_chunked(params, x, cfg,
                                      capacity_factor=capacity_factor,
                                      constrain=constrain,
                                      chunk_tokens=chunk_tokens,
                                      with_stats=with_stats)
    return apply_capacity(params, x, cfg, capacity_factor=capacity_factor,
                          constrain=constrain, with_stats=with_stats)
