"""Normalization layers (RMSNorm / LayerNorm), pure functional."""
from __future__ import annotations

import jax.numpy as jnp


def rms_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rms_apply(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jnp.reciprocal(jnp.sqrt(var + eps))
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def ln_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype=dtype), "bias": jnp.zeros((d,), dtype=dtype)}


def ln_apply(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jnp.reciprocal(jnp.sqrt(var + eps))
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dtype)


def group_norm_heads(x, n_heads: int, eps: float = 1e-5):
    """GroupNorm over head groups for the RWKV6 output (no learned affine)."""
    b, s, d = x.shape
    xh = x.reshape(b, s, n_heads, d // n_heads).astype(jnp.float32)
    mu = jnp.mean(xh, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xh - mu), axis=-1, keepdims=True)
    y = (xh - mu) * jnp.reciprocal(jnp.sqrt(var + eps))
    return y.reshape(b, s, d).astype(x.dtype)
