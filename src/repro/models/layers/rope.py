"""Rotary position embeddings."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float = 10_000.0):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                       # (hd/2,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
