"""GQA/MQA attention with packing-aware masking, sliding window and caches.

Three interchangeable implementations of the same math:

  * ``naive``   — materializes the full score matrix (oracle, small shapes)
  * ``chunked`` — pure-XLA flash attention: double ``lax.scan`` over
                  (q-block, kv-block) tiles with online softmax.  This is the
                  implementation the multi-pod dry-run lowers (bounded memory
                  at 32k sequence length, no Pallas custom-calls on CPU).
  * ``pallas``  — the TPU Pallas kernel (``repro.kernels.packed_flash_attention``),
                  validated in interpret mode against ``naive``.

Segment-id masking implements the paper's sequence packing (§3.2.1):
"Attention operations ... must process each original instance separately to
maintain causal integrity."
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.types import ModelConfig
from repro.models.layers.rope import apply_rope

NEG_INF = -1e30


# --------------------------------------------------------------------------- #
# Params
# --------------------------------------------------------------------------- #
def init(key, cfg: ModelConfig, dtype=jnp.float32):
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "wq": (jax.random.normal(kq, (d, h, hd)) * s).astype(dtype),
        "wk": (jax.random.normal(kk, (d, kh, hd)) * s).astype(dtype),
        "wv": (jax.random.normal(kv, (d, kh, hd)) * s).astype(dtype),
        "wo": (jax.random.normal(ko, (h, hd, d)) * (h * hd) ** -0.5).astype(dtype),
    }


# --------------------------------------------------------------------------- #
# Masking
# --------------------------------------------------------------------------- #
def make_mask(qpos, kpos, *, causal: bool, window: int,
              seg_q=None, seg_k=None):
    """Boolean mask (broadcast batch, Sq, Sk). True = attend."""
    m = jnp.ones(qpos.shape[-1:] + kpos.shape[-1:], dtype=bool)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window and window > 0:
        m &= qpos[:, None] - kpos[None, :] < window
    m = m[None]  # add batch dim
    if seg_q is not None and seg_k is not None:
        m = m & (seg_q[:, :, None] == seg_k[:, None, :])
    return m


# --------------------------------------------------------------------------- #
# Naive oracle
# --------------------------------------------------------------------------- #
def attend_naive(q, k, v, *, causal=True, window=0, seg_q=None, seg_k=None,
                 q_offset=0, scale: Optional[float] = None):
    """q: (B,Sq,H,D); k,v: (B,Sk,Kh,D). Returns (B,Sq,H,D)."""
    B, Sq, H, D = q.shape
    Sk, Kh = k.shape[1], k.shape[2]
    G = H // Kh
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Sq, Kh, G, D)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Sk)
    mask = make_mask(qpos, kpos, causal=causal, window=window,
                     seg_q=seg_q, seg_k=seg_k)          # (B?,Sq,Sk)
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    # fully-masked rows (e.g. padding segments) -> zero output
    any_valid = jnp.any(mask, axis=-1)[:, None, None, :, None]
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    out = jnp.where(any_valid.transpose(0, 3, 1, 2, 4), out, 0.0)
    return out.reshape(B, Sq, H, D).astype(q.dtype)


# --------------------------------------------------------------------------- #
# Chunked XLA flash attention (custom VJP: FlashAttention-2-style backward)
# --------------------------------------------------------------------------- #
def _pick_block(s: int, target: int) -> int:
    b = min(s, target)
    while s % b:
        b -= 1
    return b


def flash_attention_xla(q, k, v, *, causal=True, window=0, seg_q=None,
                        seg_k=None, q_offset=0, scale=None,
                        block_q=512, block_k=512):
    """Flash attention built from nested lax.scans, with a custom VJP.

    Without the custom VJP, differentiating the scan forward saves the
    per-block probabilities as residuals — the full S^2 attention matrix
    (8+ GB at 32k) — defeating the chunked formulation.  The backward pass
    recomputes p block-by-block from the saved log-sum-exp instead
    (FlashAttention-2), so train-time memory stays O(S * block)."""
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    if seg_q is None:
        seg_q = jnp.zeros(q.shape[:2], jnp.int32)
    if seg_k is None:
        seg_k = jnp.zeros(k.shape[:2], jnp.int32)
    return _flash(q, k, v, seg_q, seg_k, causal, window, q_offset, scale,
                  block_q, block_k)


def _blockify(q, k, v, seg_q, seg_k, block_q, block_k):
    B, Sq, H, D = q.shape
    Sk, Kh = k.shape[1], k.shape[2]
    G = H // Kh
    bq, bk = _pick_block(Sq, block_q), _pick_block(Sk, block_k)
    nq, nk = Sq // bq, Sk // bk
    qb = q.reshape(B, nq, bq, Kh, G, D).astype(jnp.float32)
    kb = k.reshape(B, nk, bk, Kh, D).astype(jnp.float32)
    vb = v.reshape(B, nk, bk, Kh, D).astype(jnp.float32)
    sqb = seg_q.reshape(B, nq, bq)
    skb = seg_k.reshape(B, nk, bk)
    return qb, kb, vb, sqb, skb, (B, Sq, H, D, Sk, Kh, G, bq, bk, nq, nk)


def _block_scores(q_i, k_j, qpos, kpos, sq_i, sk_j, causal, window, scale):
    """q_i: (B,bq,Kh,G,D); k_j: (B,bk,Kh,D) -> masked scores (B,Kh,G,bq,bk)."""
    s = jnp.einsum("bqkgd,bskd->bkgqs", q_i, k_j) * scale
    mask = make_mask(qpos, kpos, causal=causal, window=window,
                     seg_q=sq_i, seg_k=sk_j)
    return s, mask


def _flash_fwd_impl(q, k, v, seg_q, seg_k, causal, window, q_offset, scale,
                    block_q, block_k):
    qb, kb, vb, sqb, skb, dims = _blockify(q, k, v, seg_q, seg_k,
                                           block_q, block_k)
    B, Sq, H, D, Sk, Kh, G, bq, bk, nq, nk = dims

    def q_block(_, qi):
        q_i = qb[:, qi]
        sq_i = sqb[:, qi]
        qpos = q_offset + qi * bq + jnp.arange(bq)
        m0 = jnp.full((B, Kh, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Kh, G, bq), jnp.float32)
        a0 = jnp.zeros((B, Kh, G, bq, D), jnp.float32)

        def kv_block(c, ki):
            m, l, acc = c
            kpos = ki * bk + jnp.arange(bk)
            s, mask = _block_scores(q_i, kb[:, ki], qpos, kpos, sq_i,
                                    skb[:, ki], causal, window, scale)
            s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + \
                jnp.einsum("bkgqs,bskd->bkgqd", p, vb[:, ki])
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        out = jnp.where((l > 0)[..., None], out, 0.0)
        lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), NEG_INF)
        return None, (out.transpose(0, 3, 1, 2, 4), lse)

    _, (outs, lses) = jax.lax.scan(q_block, None, jnp.arange(nq))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, D).astype(q.dtype)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(B, Kh, G, Sq)
    return out, lse


def _flash_bwd_impl(q, k, v, seg_q, seg_k, out, lse, dout, causal, window,
                    q_offset, scale, block_q, block_k):
    qb, kb, vb, sqb, skb, dims = _blockify(q, k, v, seg_q, seg_k,
                                           block_q, block_k)
    B, Sq, H, D, Sk, Kh, G, bq, bk, nq, nk = dims
    dob = dout.reshape(B, nq, bq, Kh, G, D).astype(jnp.float32)
    # delta_i = rowsum(dout_i * out_i)
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), -1)
    delta = delta.reshape(B, nq, bq, Kh, G).transpose(0, 3, 4, 1, 2)
    lseb = lse.reshape(B, Kh, G, nq, bq)

    def p_block(qi, ki):
        q_i = qb[:, qi]
        qpos = q_offset + qi * bq + jnp.arange(bq)
        kpos = ki * bk + jnp.arange(bk)
        s, mask = _block_scores(q_i, kb[:, ki], qpos, kpos, sqb[:, qi],
                                skb[:, ki], causal, window, scale)
        p = jnp.exp(s - lseb[:, :, :, qi][..., None])
        p = jnp.where(mask[:, None, None, :, :], p, 0.0)
        dp = jnp.einsum("bqkgd,bskd->bkgqs", dob[:, qi], vb[:, ki])
        ds = p * (dp - delta[:, :, :, qi][..., None]) * scale
        return p, ds

    def dq_block(_, qi):
        def inner(dq_i, ki):
            p, ds = p_block(qi, ki)
            return dq_i + jnp.einsum("bkgqs,bskd->bqkgd", ds, kb[:, ki]), None
        dq0 = jnp.zeros((B, bq, Kh, G, D), jnp.float32)
        dq_i, _ = jax.lax.scan(inner, dq0, jnp.arange(nk))
        return None, dq_i

    _, dqs = jax.lax.scan(dq_block, None, jnp.arange(nq))
    dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, D)

    def dkv_block(_, ki):
        def inner(c, qi):
            dk_j, dv_j = c
            p, ds = p_block(qi, ki)
            dv_j = dv_j + jnp.einsum("bkgqs,bqkgd->bskd", p, dob[:, qi])
            dk_j = dk_j + jnp.einsum("bkgqs,bqkgd->bskd", ds, qb[:, qi])
            return (dk_j, dv_j), None
        z = jnp.zeros((B, bk, Kh, D), jnp.float32)
        (dk_j, dv_j), _ = jax.lax.scan(inner, (z, z), jnp.arange(nq))
        return None, (dk_j, dv_j)

    _, (dks, dvs) = jax.lax.scan(dkv_block, None, jnp.arange(nk))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, Sk, Kh, D)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, Sk, Kh, D)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9, 10))
def _flash(q, k, v, seg_q, seg_k, causal, window, q_offset, scale,
           block_q, block_k):
    out, _ = _flash_fwd_impl(q, k, v, seg_q, seg_k, causal, window, q_offset,
                             scale, block_q, block_k)
    return out


def _flash_fwd_rule(q, k, v, seg_q, seg_k, causal, window, q_offset, scale,
                    block_q, block_k):
    out, lse = _flash_fwd_impl(q, k, v, seg_q, seg_k, causal, window,
                               q_offset, scale, block_q, block_k)
    return out, (q, k, v, seg_q, seg_k, out, lse)


def _flash_bwd_rule(causal, window, q_offset, scale, block_q, block_k,
                    res, dout):
    q, k, v, seg_q, seg_k, out, lse = res
    dq, dk, dv = _flash_bwd_impl(q, k, v, seg_q, seg_k, out, lse, dout,
                                 causal, window, q_offset, scale,
                                 block_q, block_k)
    return dq, dk, dv, None, None


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


# --------------------------------------------------------------------------- #
# Decode against a KV cache
# --------------------------------------------------------------------------- #
def attend_cache(q, cache_k, cache_v, kpos, pos, *, window=0, scale=None):
    """Single-step decode. q: (B,1,H,D); cache_k/v: (B,C,Kh,D); kpos: (B,C).

    ``pos`` is a scalar (lockstep batch) or a ``(B,)`` array — continuous
    batching mixes requests at different decode positions in one batch, so
    each row carries its own validity mask ``kpos[b] <= pos[b]``: a row
    only ever attends to its own request's cache entries, never to stale
    slots left by a request that previously occupied the row.

    The cache stays in its storage dtype end-to-end: upcasting it (or
    requesting f32 dot accumulation on the CPU backend) materializes an fp32
    copy of the entire stacked cache — XLA hoists the convert out of the
    layer loop.  Scores dot accumulates in the cache dtype (D ≤ 256 terms),
    softmax runs in fp32 on the small score tensor, and the p·V reduction
    accumulates in the cache dtype (p sums to 1; relative error ~1e-3 in
    bf16 — the standard serving trade-off, exact when caches are fp32)."""
    B, _, H, D = q.shape
    C, Kh = cache_k.shape[1], cache_k.shape[2]
    G = H // Kh
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Kh, G, D).astype(cache_k.dtype)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, cache_k).astype(jnp.float32) * scale
    pos_b = check_decode_pos(pos, B)[:, None]                       # (B, 1)
    valid = (kpos >= 0) & (kpos <= pos_b)
    if window and window > 0:
        valid &= pos_b - kpos < window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(cache_v.dtype), cache_v)
    return out.reshape(B, 1, H, D).astype(q.dtype)


# --------------------------------------------------------------------------- #
# Cache plumbing
# --------------------------------------------------------------------------- #
def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """KV cache for one attention layer. Sliding-window archs use a ring
    buffer of size window (TPU-friendly: fixed shapes, modular write).

    ``kpos`` is per-row ``(batch, C)``: batch rows hold independent
    requests under continuous batching, each with its own position clock
    and validity mask."""
    C = min(max_len, cfg.window_size) if cfg.window_size else max_len
    return {
        "k": jnp.zeros((batch, C, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, C, cfg.n_kv_heads, cfg.head_dim), dtype),
        "kpos": jnp.full((batch, C), -1, jnp.int32),
    }


def kv_cache_bytes(cfg: ModelConfig, seq_len: int,
                   bytes_per_value: int = 2) -> float:
    """Bytes of live KV state for one request at context ``seq_len``
    (K + V across all layers) — the payload a prefill→decode handoff
    moves, priced by the serving loop as bytes/bandwidth + latency.
    Lives next to `init_cache` so the transfer cost model and the cache
    layout can never drift apart."""
    kv_heads = cfg.n_kv_heads or cfg.n_heads or 1
    head_dim = cfg.head_dim or (cfg.d_model // max(cfg.n_heads, 1))
    return 2.0 * cfg.n_layers * kv_heads * head_dim \
        * bytes_per_value * seq_len


def check_decode_pos(pos, B: int):
    """Enforce the decode-position contract: a scalar (all rows advance in
    lockstep) or a ``(B,)`` vector of per-row positions (continuous
    batching).  Returns the ``(B,)`` int32 form; any other shape raises —
    silently broadcasting e.g. a ``(B, 1)`` or wrong-batch array would
    write KV rows at the wrong slots with no error."""
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        return jnp.broadcast_to(pos, (B,))
    if pos.shape != (B,):
        raise ValueError(
            f"decode_pos must be a scalar or shape ({B},), got {pos.shape}")
    return pos


def cache_write(cache, k_new, v_new, pos):
    """Write one token (k_new: (B,1,Kh,D)) at each row's ring slot
    ``pos % C``.  ``pos``: scalar (all rows in lockstep) or ``(B,)``
    per-row positions (continuous batching)."""
    B, C = cache["k"].shape[0], cache["k"].shape[1]
    pos_b = check_decode_pos(pos, B)
    slot = pos_b % C
    rows = jnp.arange(B)
    return {
        "k": cache["k"].at[rows, slot].set(k_new[:, 0].astype(cache["k"].dtype)),
        "v": cache["v"].at[rows, slot].set(v_new[:, 0].astype(cache["v"].dtype)),
        "kpos": cache["kpos"].at[rows, slot].set(pos_b),
    }


# --------------------------------------------------------------------------- #
# Layer apply
# --------------------------------------------------------------------------- #
def apply(params, x, cfg: ModelConfig, *, positions=None, segment_ids=None,
          cache=None, decode_pos=None, impl: str = "chunked",
          block: int = 512):
    """Self-attention layer.

    Train/prefill: cache is None, x is (B,S,d).
    Decode: cache is the layer cache, x is (B,1,d), decode_pos a scalar
    or a (B,) array of per-row positions (continuous batching).
    Returns (y, new_cache).
    """
    B, S, d = x.shape
    window = cfg.window_size if cfg.attention_kind == "sliding" else 0
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))

    if cache is not None:
        pos = check_decode_pos(decode_pos, B)
        if cfg.use_rope:
            q = apply_rope(q, pos[:, None], cfg.rope_theta)
            k = apply_rope(k, pos[:, None], cfg.rope_theta)
        cache = cache_write(cache, k, v, pos)
        out = attend_cache(q, cache["k"], cache["v"], cache["kpos"], pos,
                           window=window)
    else:
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        if cfg.use_rope:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        if impl == "naive":
            out = attend_naive(q, k, v, causal=cfg.causal, window=window,
                               seg_q=segment_ids, seg_k=segment_ids)
        elif impl == "pallas":
            from repro.kernels import ops as kops
            out = kops.packed_flash_attention(
                q, k, v, segment_ids=segment_ids, causal=cfg.causal,
                window=window)
        else:
            out = flash_attention_xla(q, k, v, causal=cfg.causal,
                                      window=window, seg_q=segment_ids,
                                      seg_k=segment_ids,
                                      block_q=block, block_k=block)

    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return y, cache
