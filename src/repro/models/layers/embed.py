"""Token embedding / unembedding."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init(key, vocab: int, d: int, dtype=jnp.float32, scale: float = 0.02):
    return {"w": (jax.random.normal(key, (vocab, d)) * scale).astype(dtype)}


def encode(params, tokens, dtype=None):
    w = params["w"]
    out = jnp.take(w, tokens, axis=0)
    return out.astype(dtype) if dtype is not None else out


def decode(params, h):
    return jnp.einsum("bsd,vd->bsv", h, params["w"].astype(h.dtype))


def unembed_init(key, d: int, vocab: int, dtype=jnp.float32):
    return {"w": (jax.random.normal(key, (d, vocab)) * d ** -0.5).astype(dtype)}


def unembed(params, h):
    return jnp.einsum("bsd,dv->bsv", h, params["w"].astype(h.dtype))
