"""RWKV-6 "Finch" block: data-dependent-decay linear attention + channel mix.

Time mixing (per head, head_dim M):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (state: M x M)
    y_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
with per-channel decay w_t = exp(-exp(decay + lora(x'_t))) — the
data-dependent decay that distinguishes Finch from RWKV-5.

Token-shift interpolations use the paper's low-rank DDLerp (rank 32, five
targets: w, k, v, r, g).  The XLA path scans over time; the TPU target is
the chunked Pallas kernel (``repro.kernels.rwkv6_scan``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.types import ModelConfig

LORA_RANK = 32
DECAY_LORA_RANK = 64
MIX_NAMES = ("w", "k", "v", "r", "g")


def n_heads(cfg: ModelConfig) -> int:
    return cfg.d_model // cfg.rwkv_head_dim


def init(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    h, m = n_heads(cfg), cfg.rwkv_head_dim
    ks = jax.random.split(key, 12)
    s = d ** -0.5
    p = {
        "wr": (jax.random.normal(ks[0], (d, d)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, d)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, d)) * s).astype(dtype),
        "wg": (jax.random.normal(ks[3], (d, d)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[4], (d, d)) * s).astype(dtype),
        # DDLerp: base mixes + shared rank-32 lora over the 5 targets
        "mix_base": jnp.full((5, d), 0.5, dtype),
        "mix_x": jnp.full((d,), 0.5, dtype),
        "lora_a": (jax.random.normal(ks[5], (d, 5, LORA_RANK)) * s).astype(dtype),
        "lora_b": (jax.random.normal(ks[6], (5, LORA_RANK, d)) * LORA_RANK ** -0.5).astype(dtype),
        # data-dependent decay
        "decay_base": jnp.linspace(-6.0, -1.0, d).astype(dtype),
        "decay_lora_a": (jax.random.normal(ks[7], (d, DECAY_LORA_RANK)) * s).astype(dtype),
        "decay_lora_b": (jax.random.normal(ks[8], (DECAY_LORA_RANK, d))
                         * DECAY_LORA_RANK ** -0.5).astype(dtype),
        "time_first": (jax.random.normal(ks[9], (h, m)) * 0.1).astype(dtype),
        # channel mixing
        "cm_mix": jnp.full((2, d), 0.5, dtype),
        "cm_wk": (jax.random.normal(ks[10], (d, cfg.d_ff)) * s).astype(dtype),
        "cm_wv": (jax.random.normal(ks[11], (cfg.d_ff, d)) * cfg.d_ff ** -0.5).astype(dtype),
        "cm_wr": (jax.random.normal(jax.random.fold_in(key, 99), (d, d)) * s).astype(dtype),
    }
    return p


def _shift(x, prev):
    """Token shift: x_{t-1}, with `prev` as the t=-1 row. x: (B,S,d)."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _ddlerp(params, x, xprev):
    """Data-dependent interpolation -> five mixed inputs (B,S,5,d)."""
    dx = xprev - x
    xx = x + dx * params["mix_x"].astype(x.dtype)
    a = jnp.tanh(jnp.einsum("bsd,dfr->bsfr", xx, params["lora_a"].astype(x.dtype)))
    offs = jnp.einsum("bsfr,frd->bsfd", a, params["lora_b"].astype(x.dtype))
    mix = params["mix_base"].astype(x.dtype)[None, None] + offs      # (B,S,5,d)
    return x[:, :, None] + dx[:, :, None] * mix


def wkv_scan_xla(r, k, v, w, u, state0=None):
    """Sequential WKV6 recurrence.

    r,k,v,w: (B, S, H, M); u: (H, M).  Returns y: (B,S,H,M) and the final
    state (B,H,M,M), indexed [key_dim, value_dim].
    """
    B, S, H, M = r.shape
    f32 = jnp.float32
    s0 = state0 if state0 is not None else jnp.zeros((B, H, M, M), f32)
    u32 = u.astype(f32)

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhk,bhkm->bhm", r_t, s + u32[None, :, :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, y

    xs = tuple(t.astype(f32).transpose(1, 0, 2, 3) for t in (r, k, v, w))
    s_final, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3), s_final


def wkv_chunked(r, k, v, logw, u, *, chunk: int = 32, state0=None):
    """Chunked (FLA-style) WKV6: sequential only across chunks.

    Within a chunk the recurrence is evaluated in closed form —
        y_t = (r_t ⊙ e^{P_t}) S_0  +  Σ_{j<t} Σ_m r_tm k_jm e^{P_tm − L_jm} v_j
              + (r_t ⊙ u ⊙ k_t) · v_t
    with L_t = Σ_{s≤t} log w_s and P_t = L_{t−1} — batched tensor ops instead
    of a 4096-step scan, cutting the per-step HBM state round-trips by the
    chunk factor and turning the work MXU/VPU-shaped.  All exponents are
    ≤ 0 (P_t − L_j for j < t sums only logs of w ∈ (0,1)), so the log-space
    form is unconditionally stable.

    r,k,v,logw: (B, S, H, M); u: (H, M).  Returns (y, final state).
    """
    B, S, H, M = r.shape
    c = min(chunk, S)
    while S % c:
        c -= 1
    n = S // c
    f32 = jnp.float32

    def to_chunks(x):
        return x.astype(f32).reshape(B, n, c, H, M).transpose(1, 0, 3, 2, 4)

    rc, kc, vc, lwc = map(to_chunks, (r, k, v, logw))      # (n,B,H,c,M)
    u32 = u.astype(f32)
    s0 = state0 if state0 is not None else jnp.zeros((B, H, M, M), f32)

    tri = jnp.tril(jnp.ones((c, c), bool), k=-1)           # j < t

    def chunk_step(S0, inp):
        r_, k_, v_, lw_ = inp                              # (B,H,c,M)
        L = jnp.cumsum(lw_, axis=2)                        # L_t
        P = L - lw_                                        # L_{t-1}
        # inter-chunk
        y = jnp.einsum("bhtm,bhmn->bhtn", r_ * jnp.exp(P), S0)
        # intra-chunk: E_{tjm} = exp(P_t - L_j), masked to j < t
        E = jnp.exp(P[:, :, :, None, :] - L[:, :, None, :, :])
        E = jnp.where(tri[None, None, :, :, None], E, 0.0)
        A = jnp.einsum("bhtm,bhjm,bhtjm->bhtj", r_, k_, E)
        y = y + jnp.einsum("bhtj,bhjn->bhtn", A, v_)
        # current-token bonus
        diag = jnp.sum(r_ * k_ * u32[None, :, None, :], axis=-1)
        y = y + diag[..., None] * v_
        # state hand-off: S' = e^{L_c} ⊙ S0 + Σ_j (k_j e^{L_c - L_j}) v_j^T
        Lc = L[:, :, -1:, :]                               # (B,H,1,M)
        S_new = jnp.exp(Lc[:, :, 0, :, None]) * S0 + jnp.einsum(
            "bhjm,bhjn->bhmn", k_ * jnp.exp(Lc - L), v_)
        return S_new, y

    s_final, ys = jax.lax.scan(chunk_step, s0, (rc, kc, vc, lwc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, S, H, M)
    return y, s_final


def init_cache(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    h, m = n_heads(cfg), cfg.rwkv_head_dim
    return {
        "tm_prev": jnp.zeros((batch, d), jnp.float32),
        "cm_prev": jnp.zeros((batch, d), jnp.float32),
        "wkv": jnp.zeros((batch, h, m, m), jnp.float32),
    }


def time_mix(params, x, cfg: ModelConfig, *, cache=None, impl: str = "xla"):
    """RWKV6 attention replacement. x: (B,S,d). Returns (y, new_cache)."""
    from repro.models.layers.norms import group_norm_heads

    B, S, d = x.shape
    h, m = n_heads(cfg), cfg.rwkv_head_dim
    prev = cache["tm_prev"].astype(x.dtype) if cache is not None \
        else jnp.zeros((B, d), x.dtype)
    xprev = _shift(x, prev)
    mixed = _ddlerp(params, x, xprev)                        # (B,S,5,d)
    x_w, x_k, x_v, x_r, x_g = [mixed[:, :, i] for i in range(5)]

    r = jnp.einsum("bsd,de->bse", x_r, params["wr"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", x_k, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", x_v, params["wv"].astype(x.dtype))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", x_g, params["wg"].astype(x.dtype)))

    dlo = jnp.tanh(jnp.einsum("bsd,dr->bsr", x_w, params["decay_lora_a"].astype(x.dtype)))
    dec = params["decay_base"].astype(jnp.float32) + \
        jnp.einsum("bsr,rd->bsd", dlo, params["decay_lora_b"].astype(x.dtype)).astype(jnp.float32)
    logw = -jnp.exp(dec)                                     # log of decay
    w = jnp.exp(logw)                                        # (B,S,d) in (0,1)

    rh = r.reshape(B, S, h, m)
    kh = k.reshape(B, S, h, m)
    vh = v.reshape(B, S, h, m)
    wh = w.reshape(B, S, h, m)
    state0 = cache["wkv"] if cache is not None else None
    if impl == "pallas" and cache is None:
        from repro.kernels import ops as kops
        y, s_final = kops.rwkv6_scan(rh, kh, vh, wh, params["time_first"])
    elif impl == "chunked":
        y, s_final = wkv_chunked(rh, kh, vh, logw.reshape(B, S, h, m),
                                 params["time_first"], state0=state0)
    else:
        y, s_final = wkv_scan_xla(rh, kh, vh, wh, params["time_first"], state0)
    y = y.reshape(B, S, d).astype(x.dtype)
    y = group_norm_heads(y, h) * g
    out = jnp.einsum("bsd,de->bse", y, params["wo"].astype(x.dtype))
    new_cache = None
    if cache is not None:
        new_cache = {"tm_prev": x[:, -1].astype(jnp.float32),
                     "cm_prev": cache["cm_prev"], "wkv": s_final}
    return out, new_cache


def channel_mix(params, x, cfg: ModelConfig, *, cache=None):
    """RWKV squared-relu channel mixing with token shift."""
    B, S, d = x.shape
    prev = cache["cm_prev"].astype(x.dtype) if cache is not None \
        else jnp.zeros((B, d), x.dtype)
    xprev = _shift(x, prev)
    mk = params["cm_mix"][0].astype(x.dtype)
    mr = params["cm_mix"][1].astype(x.dtype)
    xk = x + (xprev - x) * mk
    xr = x + (xprev - x) * mr
    kk = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, params["cm_wk"].astype(x.dtype))))
    out = jnp.einsum("bsf,fd->bsd", kk, params["cm_wv"].astype(x.dtype))
    gate = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, params["cm_wr"].astype(x.dtype)))
    out = out * gate
    new_cache = None
    if cache is not None:
        new_cache = dict(cache)
        new_cache["cm_prev"] = x[:, -1].astype(jnp.float32)
    return out, new_cache
