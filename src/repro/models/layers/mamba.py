"""Mamba-1 selective-state-space block (Jamba's SSM half).

Recurrence (per channel c, state dim n):
    h_t = exp(dt_t * A) ⊙ h_{t-1} + (dt_t * x_t) ⊗ B_t
    y_t = h_t · C_t + D ⊙ x_t

The XLA path scans over time (TPU target uses the Pallas chunked kernel in
``repro.kernels.mamba_scan``).  Decode carries (conv window, ssm state).
"""
from __future__ import annotations

import math

import jax
from repro.common import compat
import jax.numpy as jnp

from repro.common.types import ModelConfig


def dims(cfg: ModelConfig):
    di = cfg.ssm_expand * cfg.d_model
    dt_rank = max(1, math.ceil(cfg.d_model / 16))
    return di, dt_rank, cfg.ssm_d_state, cfg.ssm_d_conv


def init(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    di, R, N, K = dims(cfg)
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    A = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))
    return {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * di)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (di, K)) * K ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": (jax.random.normal(ks[2], (di, R + 2 * N)) * di ** -0.5).astype(dtype),
        "dt_proj": (jax.random.normal(ks[3], (R, di)) * R ** -0.5).astype(dtype),
        "dt_bias": jnp.full((di,), -4.6, dtype),   # softplus^-1(0.01)
        "A_log": jnp.log(A).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "out_proj": (jax.random.normal(ks[5], (di, d)) * di ** -0.5).astype(dtype),
    }


def _conv_step(conv_state, x_t, conv_w, conv_b):
    """conv_state: (B, K-1, di); x_t: (B, di) -> (y_t, new_state)."""
    window = jnp.concatenate([conv_state, x_t[:, None]], axis=1)   # (B,K,di)
    y = jnp.einsum("bkc,ck->bc", window, conv_w.astype(x_t.dtype)) + conv_b
    return y, window[:, 1:]


def causal_conv(x, conv_w, conv_b):
    """x: (B, S, di) depthwise causal conv along S."""
    B, S, di = x.shape
    K = conv_w.shape[1]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    # stack K shifted views: y_t = sum_k w[:,k] * x_{t-K+1+k}
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for k in range(K):
        y = y + xp[:, k:k + S].astype(jnp.float32) * conv_w[:, k].astype(jnp.float32)
    return (y + conv_b.astype(jnp.float32)).astype(x.dtype)


def ssm_scan_xla(u, dt, B_t, C_t, A, D):
    """Sequential selective scan.

    u, dt: (B, S, di); B_t, C_t: (B, S, N); A: (di, N); D: (di,)
    Returns y: (B, S, di) and final state (B, di, N).
    """
    b, S, di = u.shape
    N = A.shape[1]

    def step(h, inp):
        u_t, dt_t, b_t, c_t = inp
        decay = jnp.exp(dt_t[..., None] * A[None])            # (B, di, N)
        h = h * decay + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bcn,bn->bc", h, c_t)
        return h, y

    h0 = jnp.zeros((b, di, N), jnp.float32)
    xs = (u.astype(jnp.float32).swapaxes(0, 1),
          dt.astype(jnp.float32).swapaxes(0, 1),
          B_t.astype(jnp.float32).swapaxes(0, 1),
          C_t.astype(jnp.float32).swapaxes(0, 1))
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = ys.swapaxes(0, 1) + u.astype(jnp.float32) * D.astype(jnp.float32)[None, None]
    return y.astype(u.dtype), h_final


def ssm_scan_chunked(u, dt, B_t, C_t, A, D, *, chunk: int = 32, h0=None):
    """Chunked selective scan (same idea as the chunked WKV6).

    With T_t = Σ_{s≤t} dt_s (per channel), the recurrence solves to
        y_tc = Σ_n C_tn [ e^{A_cn T_tc} h0_cn
                          + Σ_{j≤t} e^{A_cn (T_tc − T_jc)} dt_jc u_jc B_jn ]
    Exponents are ≤ 0 (A < 0, T monotone), so the closed intra-chunk form is
    stable; the sequential dependency survives only across chunks — cutting
    the per-timestep HBM state round-trips by the chunk factor.

    u, dt: (B, S, di); B_t, C_t: (B, S, N); A: (di, N); D: (di,).
    Returns (y (B,S,di), final state (B,di,N))."""
    b, S, di = u.shape
    N = A.shape[1]
    c = min(chunk, S)
    while S % c:
        c -= 1
    n = S // c
    f32 = jnp.float32

    uc = u.astype(f32).reshape(b, n, c, di).transpose(1, 0, 2, 3)
    dtc = dt.astype(f32).reshape(b, n, c, di).transpose(1, 0, 2, 3)
    Bc = B_t.astype(f32).reshape(b, n, c, N).transpose(1, 0, 2, 3)
    Cc = C_t.astype(f32).reshape(b, n, c, N).transpose(1, 0, 2, 3)
    A32 = A.astype(f32)
    h_init = h0 if h0 is not None else jnp.zeros((b, di, N), f32)
    tri = jnp.tril(jnp.ones((c, c), bool))        # j <= t

    def chunk_step(h, inp):
        u_, dt_, b_, c_ = inp                      # (b,c,di) / (b,c,N)
        T = jnp.cumsum(dt_, axis=1)                # (b,c,di)
        # inter-chunk: y_inter_tc = sum_n C_tn e^{A_cn T_tc} h_cn
        decay_T = jnp.exp(T[..., None] * A32[None, None])    # (b,c,di,N)
        y = jnp.einsum("btn,btcn,bcn->btc", c_, decay_T, h)
        # intra-chunk: E_{tjcn} = e^{A_cn (T_t - T_j)}, j <= t
        dT = T[:, :, None, :] - T[:, None, :, :]             # (b,t,j,di)
        E = jnp.exp(dT[..., None] * A32[None, None, None])   # (b,t,j,di,N)
        E = jnp.where(tri[None, :, :, None, None], E, 0.0)
        w = (dt_ * u_)                                        # (b,j,di)
        y = y + jnp.einsum("btn,btjcn,bjc,bjn->btc", c_, E, w, b_)
        y = y + u_ * D.astype(f32)[None, None]
        # state hand-off
        Tc = T[:, -1:, :]                                     # (b,1,di)
        dTc = Tc[:, 0][:, None, :] - T                        # (b,c,di)
        Ec = jnp.exp(dTc[..., None] * A32[None, None])        # (b,c,di,N)
        h = h * jnp.exp(Tc[:, 0][..., None] * A32[None]) + \
            jnp.einsum("bjcn,bjc,bjn->bcn", Ec, w, b_)
        return h, y

    h_final, ys = jax.lax.scan(chunk_step, h_init, (uc, dtc, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3).reshape(b, S, di)
    return y.astype(u.dtype), h_final


def ssm_scan_sharded(u, dt, B_t, C_t, A, D, shard_ctx, chunked=False):
    """shard_map-wrapped selective scan.

    Under plain SPMD, the scan's backward re-shards the (shared-across-
    channels) B_t/C_t cotangents EVERY timestep — millions of tiny
    all-reduces at 4k+ sequence length.  Inside shard_map each model shard
    scans its channel slice locally and the cotangent psum happens ONCE per
    layer (shard_map's transpose rule for replicated inputs)."""
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.sharding.partition import sanitize_spec

    mesh, b_axes, m_axes = shard_ctx
    chan = tuple(m_axes) or None
    spec_u = sanitize_spec(P(tuple(b_axes) or None, None, chan),
                           u.shape, mesh)
    spec_bc = sanitize_spec(P(tuple(b_axes) or None, None, None),
                            B_t.shape, mesh)
    spec_A = sanitize_spec(P(chan, None), A.shape, mesh)
    spec_D = sanitize_spec(P(chan), D.shape, mesh)
    spec_h = sanitize_spec(P(tuple(b_axes) or None, chan, None),
                           (u.shape[0], u.shape[2], A.shape[1]), mesh)

    inner = (ssm_scan_chunked if chunked else ssm_scan_xla)
    fn = compat.shard_map(inner, mesh=mesh,
                       in_specs=(spec_u, spec_u, spec_bc, spec_bc,
                                 spec_A, spec_D),
                       out_specs=(spec_u, spec_h), check_vma=False)
    return fn(u, dt, B_t, C_t, A, D)


def init_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    di, R, N, K = dims(cfg)
    return {
        "conv": jnp.zeros((batch, K - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, N), jnp.float32),
    }


def _project(params, x, cfg: ModelConfig):
    di, R, N, K = dims(cfg)
    xz = jnp.einsum("...d,de->...e", x, params["in_proj"].astype(x.dtype))
    return jnp.split(xz, [di], axis=-1)    # u, z


def _bcdt(params, u, cfg: ModelConfig):
    di, R, N, K = dims(cfg)
    proj = jnp.einsum("...c,ce->...e", u, params["x_proj"].astype(u.dtype))
    dt_low, B_t, C_t = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("...r,rc->...c", dt_low, params["dt_proj"].astype(u.dtype))
        + params["dt_bias"].astype(u.dtype))
    return dt, B_t, C_t


def apply(params, x, cfg: ModelConfig, *, cache=None, impl: str = "xla",
          shard_ctx=None):
    """x: (B, S, d) train/prefill, or (B, 1, d) decode with cache."""
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    D = params["D"]
    if cache is not None:
        x_t = x[:, 0]
        u, z = _project(params, x_t, cfg)
        u_c, conv_state = _conv_step(cache["conv"], u, params["conv_w"],
                                     params["conv_b"])
        u_c = jax.nn.silu(u_c)
        dt, B_t, C_t = _bcdt(params, u_c, cfg)
        decay = jnp.exp(dt.astype(jnp.float32)[..., None] * A[None])
        h = cache["ssm"] * decay + (dt * u_c).astype(jnp.float32)[..., None] \
            * B_t.astype(jnp.float32)[:, None, :]
        y = jnp.einsum("bcn,bn->bc", h, C_t.astype(jnp.float32))
        y = y + u_c.astype(jnp.float32) * D.astype(jnp.float32)[None]
        y = y.astype(x.dtype) * jax.nn.silu(z)
        out = jnp.einsum("bc,cd->bd", y, params["out_proj"].astype(x.dtype))
        return out[:, None], {"conv": conv_state, "ssm": h}

    u, z = _project(params, x, cfg)
    u = jax.nn.silu(causal_conv(u, params["conv_w"], params["conv_b"]))
    dt, B_t, C_t = _bcdt(params, u, cfg)
    if impl == "pallas":
        from repro.kernels import ops as kops
        y, _ = kops.mamba_scan(u, dt, B_t, C_t, A, D)
    elif shard_ctx is not None:
        y, _ = ssm_scan_sharded(u, dt, B_t, C_t, A, D, shard_ctx,
                                chunked=(impl == "chunked"))
    elif impl == "chunked":
        y, _ = ssm_scan_chunked(u, dt, B_t, C_t, A, D)
    else:
        y, _ = ssm_scan_xla(u, dt, B_t, C_t, A, D)
    y = y * jax.nn.silu(z)
    return jnp.einsum("bsc,cd->bsd", y, params["out_proj"].astype(x.dtype)), None
