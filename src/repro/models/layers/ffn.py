"""Dense feed-forward blocks (SwiGLU / GeGLU / GELU / ReLU^2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.types import ModelConfig

GATED = ("swiglu", "geglu")


def _act(name: str, x):
    if name == "swiglu":
        return jax.nn.silu(x)
    if name == "geglu":
        return jax.nn.gelu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu_sq":
        return jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name}")


def init(key, cfg: ModelConfig, dtype=jnp.float32, d_ff: int | None = None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    kg, ku, kd = jax.random.split(key, 3)
    s_in, s_out = d ** -0.5, ff ** -0.5
    p = {
        "w_up": (jax.random.normal(ku, (d, ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(kd, (ff, d)) * s_out).astype(dtype),
    }
    if cfg.activation in GATED:
        p["w_gate"] = (jax.random.normal(kg, (d, ff)) * s_in).astype(dtype)
    return p


def apply(params, x, cfg: ModelConfig):
    w_up = params["w_up"].astype(x.dtype)
    up = jnp.einsum("bsd,df->bsf", x, w_up)
    if cfg.activation in GATED:
        gate = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(x.dtype))
        h = _act(cfg.activation, gate) * up
    else:
        h = _act(cfg.activation, up)
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"].astype(x.dtype))
