"""Lookahead data-aware batch composition (extension of §3.4).

The Online Microbatch Scheduler balances items *within* a global batch the
loader already drew — but the draw itself is FIFO and data-blind.  On a
bursty stream (e.g. a run of video-heavy items inside a single-image
corpus) every FIFO batch mixes a few fat items into many thin ones, and
the fat item pins the bottleneck bucket no matter how well the scheduler
partitions: ``C_max >= max_i d_i`` is a *composition* property, not a
scheduling one.  `LookaheadComposer` attacks that remaining headroom by
maintaining a bounded reorder window of ``window · gbs`` items over the
stream and assembling each global batch from it.

Scoring uses the exact duration path the scheduler and optimizer already
share (`objective.corrected_item_durations` via
``scheduler.item_durations``), an LPT partition (`lpt_assign_batch`) and
the event-driven 1F1B simulator (`simulate_bucket_ranks_batch`) — all
candidates for one batch are scored in a single vectorized wavefront
call.  The greedy criterion is the *work-normalized* predicted step
makespan (makespan per second of compute the batch retires): minimizing
the raw makespan is myopic — it perpetually defers fat items, which then
force mixed batches when staleness binds — whereas time-per-work is the
greedy rule whose per-batch optimum minimizes the epoch sum ``Σ_t
makespan_t`` for a fixed total work.  Raw-makespan scoring remains
available as ``score="makespan"``.

Hard guarantees, property-pinned in ``tests/test_loader.py``:

  * **exactly-once** — every pushed item appears in exactly one composed
    batch; ``drain()`` empties the window at end of stream, so a finite
    epoch is an exact permutation of the FIFO epoch;
  * **bounded staleness** — an item waits at most ``max_staleness``
    ``compose()`` calls in the window.  Forcing only items *at* the
    bound is not enough (the initial window fill ages in lockstep, so
    more than gbs items can hit the bound in the same batch): each
    compose instead reserves EDF-style — it force-includes the
    ``max_j (n_j − j·gbs)`` smallest-slack items, where ``n_j`` counts
    items within ``j`` batches of their deadline, which keeps every
    future deadline feasible.  Soundness needs the window capacity
    ``W·gbs ≤ (max_staleness+1)·gbs`` (``max_staleness ≥ window − 1``,
    validated) and is why ``push`` refuses to overfill the window.

Composition is also *shape-aware*: each candidate's padded-shape bucket
(power-of-two row item count × power-of-two max media count — the compile
key a dynamic-padding input pipeline buckets by, cf.
``examples/train_mllm.build_batches``) is predicted from its LPT
partition, and candidates that would open a bucket no previous batch
compiled for are penalized by ``recompile_penalty``.  A FIFO loader on a
bursty stream walks through every intermediate mixture ratio and
compiles for each; the composer snaps batches onto the few buckets it
has already paid for.

A plan hot-swap invalidates the cached per-item durations
(``flush_plan()``, called by `RuntimeController.maybe_swap`); the
composer additionally re-checks the scheduler's plan identity on every
``compose()``, so composition never targets a stale θ* even if the
controller forgets to flush.

>>> e = [5.0, 1.0, 4.0, 2.0]                       # dominant durations
>>> sorted_runs(e, k=2, max_candidates=8)          # sorted: items 0,2,3,1
[(0, 2), (2, 3), (3, 1)]
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.pipeline.simulator import simulate_bucket_ranks_batch
from repro.core.scheduler.lpt import lpt_assign_batch
from repro.data.items import DataItem


def sorted_runs(dominant: Sequence[float], k: int,
                max_candidates: int = 64) -> List[Tuple[int, ...]]:
    """Candidate index groups: contiguous length-``k`` runs of the items
    sorted by descending dominant duration.

    Contiguous runs in sorted order are the maximally homogeneous subsets
    — a run never skips an intermediate item, so its internal spread is
    minimal, which is what makes it balanceable into equal buckets.  When
    there are more runs than ``max_candidates`` they are strided evenly
    (first and last run always included).
    """
    order = np.argsort(-np.asarray(dominant, dtype=np.float64),
                       kind="stable")
    n = len(order)
    if k <= 0 or n < k:
        return []
    starts = np.arange(n - k + 1)
    if len(starts) > max_candidates:
        starts = np.unique(np.linspace(0, n - k, max_candidates,
                                       dtype=np.int64))
    return [tuple(int(j) for j in order[s:s + k]) for s in starts]


def edf_forced_count(slack: Sequence[int], per_step: int) -> int:
    """How many smallest-slack entries an EDF reservation must ship *now*
    to keep every future deadline feasible.

    ``slack[i]`` is the number of emission steps entry ``i`` can still
    wait (0 = must go in the next batch; negative clamps to 0) and
    ``per_step`` entries leave per step.  With ``n_j`` = count of entries
    within ``j`` steps of their deadline, feasibility of *all* deadlines
    needs ``max_j (n_j − j·per_step)`` departures immediately — forcing
    only slack-0 entries is not enough when many entries age in lockstep.
    Shared by the training-side `LookaheadComposer` (staleness deadlines)
    and the serving-side SLO admission (latency deadlines), so the two
    control loops cannot drift apart on the reservation rule.

    >>> edf_forced_count([0, 0, 1, 5], per_step=2)
    2
    >>> edf_forced_count([1, 1, 5, 5], per_step=2)   # next step fits both
    0
    >>> edf_forced_count([0, 10**9], per_step=1)     # huge slack: O(n) mem
    1
    """
    slack = np.maximum(np.asarray(slack, dtype=np.int64), 0)
    n = len(slack)
    if n == 0:
        return 0
    # `np.bincount` allocates O(max slack) — one relaxed deadline (slack
    # ~1e9) would allocate gigabytes.  Beyond the forcing horizon
    # H = ceil(n/per_step) slack can never force: for j >= H,
    # n_j − j·per_step <= n − n <= 0, so clipping to H changes no j < H
    # term and adds only non-positive ones — the count is exact.
    horizon = -(-n // max(int(per_step), 1))
    slack = np.minimum(slack, horizon)
    n_j = np.cumsum(np.bincount(slack))
    return int(max(0, (n_j - np.arange(len(n_j)) * per_step).max()))


def _pow2(x: int) -> int:
    """Smallest power of two >= max(x, 1).

    >>> [_pow2(x) for x in (0, 1, 2, 3, 9)]
    [1, 1, 2, 4, 16]
    """
    return 1 << max(int(x) - 1, 0).bit_length()


@dataclass
class ComposeStats:
    """Telemetry of one ``compose()`` call (mirrored into the runtime
    trace/metrics when the composer is attached to a `RuntimeController`)."""

    batch_idx: int
    window_fill: int             # items in the window before composing
    n_forced: int                # staleness-forced inclusions
    n_candidates: int
    chosen_makespan_s: float     # predicted step makespan of the pick
    fifo_makespan_s: float       # same metric for the FIFO candidate
    chosen_score: float          # work-normalized makespan (lower=better)
    fifo_score: float
    max_age: int                 # oldest emitted item's age, in batches
    elapsed_s: float
    shape_key: tuple = ()        # (rows_pow2, media_pow2) compile bucket
    novel_shape: bool = False    # batch opened a new compile bucket

    @property
    def pred_gain(self) -> float:
        """Predicted FIFO-over-chosen step-makespan ratio (>1 = the
        composed batch is predicted cheaper than the FIFO draw)."""
        return self.fifo_makespan_s / max(self.chosen_makespan_s, 1e-12)


class _Entry:
    __slots__ = ("item", "age", "e", "l")

    def __init__(self, item: DataItem):
        self.item = item
        self.age = 0                 # compose() calls survived in-window
        self.e = -1.0                # cached durations under the active
        self.l = -1.0                # plan; <0 = not computed / flushed


class LookaheadComposer:
    """Compose global batches from a bounded lookahead window.

    ``scheduler`` is an `OnlineMicrobatchScheduler` (duck-typed: the
    composer uses its ``plan`` and ``item_durations``) — predictions
    therefore flow through adaptive correction + online calibration
    exactly as schedule-time predictions do.
    """

    def __init__(self, scheduler, *, gbs: int, window: int = 4,
                 max_staleness: Optional[int] = None,
                 max_candidates: int = 64, score: str = "work-normalized",
                 recompile_penalty: float = 0.15,
                 bwd_over_fwd: float = 2.0):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        if score not in ("work-normalized", "makespan"):
            raise ValueError(f"score must be 'work-normalized' or "
                             f"'makespan', got {score!r}")
        self.scheduler = scheduler
        self.gbs = gbs
        self.window = window
        # Default: an item may sit out one full window turnover in each
        # direction before it is forced out.
        self.max_staleness = (2 * window if max_staleness is None
                              else max_staleness)
        if self.max_staleness < max(window - 1, 1):
            # capacity argument: all W·gbs in-window items could be within
            # max_staleness batches of their deadline simultaneously, and
            # only gbs leave per batch
            raise ValueError(
                f"max_staleness must be >= max(window - 1, 1) = "
                f"{max(window - 1, 1)}, got {self.max_staleness}")
        self.max_candidates = max_candidates
        self.score = score
        # relative score penalty for opening a compile bucket no previous
        # batch used (0 disables shape-aware composition)
        self.recompile_penalty = recompile_penalty
        self.bwd_over_fwd = bwd_over_fwd
        self._entries: List[_Entry] = []
        self._seen_shapes: set = set()
        self._plan_key = None
        self.batch_idx = 0
        self.n_flushes = 0
        self.last_stats: Optional[ComposeStats] = None
        # optional runtime hooks, attached by RuntimeController
        self.trace = None
        self.metrics = None

    # ------------------------------------------------------------------ #
    @property
    def capacity(self) -> int:
        return self.window * self.gbs

    @property
    def pending(self) -> int:
        """Items currently held back in the window."""
        return len(self._entries)

    @property
    def ready(self) -> bool:
        """Window full — steady-state trigger: push one global batch,
        then compose while ready (the loader's loop)."""
        return len(self._entries) >= self.capacity

    def push(self, items: Sequence[DataItem]) -> None:
        """Admit items into the window.  Overfilling past ``window·gbs``
        would void the staleness guarantee (the EDF reservation's
        capacity argument needs at most ``(max_staleness+1)·gbs`` items
        in flight), so it is rejected — compose first."""
        if len(self._entries) + len(items) > self.capacity:
            raise ValueError(
                f"push of {len(items)} items would overfill the window "
                f"({len(self._entries)}/{self.capacity}); compose() "
                f"batches out first")
        self._entries.extend(_Entry(it) for it in items)

    def flush_plan(self) -> None:
        """Invalidate cached durations after a plan hot-swap, so the next
        ``compose()`` re-prices the whole window under the new θ*."""
        for en in self._entries:
            en.e = en.l = -1.0
        self._plan_key = None
        self.n_flushes += 1

    # ------------------------------------------------------------------ #
    def _refresh_durations(self) -> None:
        plan = self.scheduler.plan
        key = plan.as_tuple()
        if key != self._plan_key:
            # plan changed under us (hot-swap without flush_plan) — never
            # compose against a stale θ*
            for en in self._entries:
                en.e = en.l = -1.0
            self._plan_key = key
        fresh = [en for en in self._entries if en.e < 0.0]
        if not fresh:
            return
        e, l = self.scheduler.item_durations([en.item for en in fresh])
        for en, ei, li in zip(fresh, e, l):
            en.e = float(ei)
            en.l = float(li)

    def _score_candidates(self, cands: List[Tuple[int, ...]],
                          e: np.ndarray, l: np.ndarray,
                          media: np.ndarray
                          ) -> Tuple[np.ndarray, np.ndarray, List[tuple]]:
        """(makespan, score, shape_key) per candidate — one LPT + one
        schedule wavefront (the plan's own family) over the candidate set."""
        plan = self.scheduler.plan
        idx = np.asarray(cands, dtype=np.int64)
        e_s, l_s = e[idx], l[idx]                      # (C, n)
        m = plan.n_buckets
        assign, e_b, l_b = lpt_assign_batch(e_s, l_s, m)
        e_pp = plan.encoder.pp if plan.encoder else 0
        tr = simulate_bucket_ranks_batch(
            e_b, l_b, n_mb=plan.n_mb, dp=plan.llm.dp, e_pp=e_pp,
            l_pp=plan.llm.pp, bwd_over_fwd=self.bwd_over_fwd,
            backward=(getattr(self.scheduler, "mode", "train") == "train"),
            schedule=plan.schedule)
        makespan = tr.makespan.max(axis=-1)            # slowest dp rank
        if self.score == "makespan":
            scores = makespan.copy()
        else:
            # work-normalized: predicted step time per second of compute
            # the batch retires (1/utilization up to the chip count)
            busy = tr.stage_busy.sum(axis=(-2, -1))
            scores = makespan / np.maximum(busy, 1e-12)
        # compile bucket per candidate: pow2 of the fattest LPT row ×
        # pow2 of the batch's max media count — what a dynamic-padding
        # pipeline keys its jit cache on (train_mllm.build_batches)
        keys = []
        for c in range(assign.shape[0]):
            rows = int(np.bincount(assign[c], minlength=m).max())
            keys.append((_pow2(rows), _pow2(int(media[idx[c]].max()))))
        if self.recompile_penalty > 0.0:
            novel = np.array([k not in self._seen_shapes for k in keys])
            scores = scores * (1.0 + self.recompile_penalty * novel)
        return makespan, scores, keys

    def compose(self) -> List[DataItem]:
        """Emit one global batch (≤ gbs items; smaller only while
        draining a finite stream)."""
        if not self._entries:
            raise RuntimeError("compose() on an empty window")
        t0 = time.monotonic()
        self._refresh_durations()
        n = min(self.gbs, len(self._entries))
        window_fill = len(self._entries)
        # EDF reservation: slack = batches left before an entry's
        # deadline; n_j entries have slack <= j but only j·gbs seats
        # leave before then, so max_j (n_j − j·gbs) smallest-slack
        # entries must ship now to keep every deadline feasible (this
        # subsumes the "slack 0 goes now" rule and never exceeds gbs
        # while the window invariant n_j <= (j+1)·gbs holds)
        slack = np.array([self.max_staleness - en.age
                          for en in self._entries])
        need = edf_forced_count(slack, self.gbs)
        order = np.argsort(slack, kind="stable")      # ties: arrival order
        forced = sorted(int(i) for i in order[:min(need, n)])
        forced_set = set(forced)
        pool = [i for i in range(len(self._entries)) if i not in forced_set]
        k = n - len(forced)
        e = np.array([en.e for en in self._entries])
        l = np.array([en.l for en in self._entries])
        media = np.array([en.item.n_media_items for en in self._entries])
        # candidate 0 is always the FIFO draw (oldest k pool entries —
        # arrival order — on top of the forced prefix), so ties resolve
        # toward FIFO and the stats always carry the baseline's score
        cands: List[Tuple[int, ...]] = [tuple(forced) + tuple(pool[:k])]
        if k > 0:
            dominant = np.maximum(e, l)[pool]
            for run in sorted_runs(dominant, k, self.max_candidates):
                cands.append(tuple(forced) + tuple(pool[j] for j in run))
        makespan, scores, keys = self._score_candidates(cands, e, l, media)
        best = int(np.argmin(scores))
        chosen = cands[best]
        chosen_set = set(chosen)
        batch = [self._entries[i].item for i in chosen]
        max_age = max(self._entries[i].age for i in chosen)
        survivors = [en for i, en in enumerate(self._entries)
                     if i not in chosen_set]
        for en in survivors:
            en.age += 1
        self._entries = survivors
        novel = keys[best] not in self._seen_shapes
        self._seen_shapes.add(keys[best])
        self.last_stats = ComposeStats(
            batch_idx=self.batch_idx, window_fill=window_fill,
            n_forced=len(forced), n_candidates=len(cands),
            chosen_makespan_s=float(makespan[best]),
            fifo_makespan_s=float(makespan[0]),
            chosen_score=float(scores[best]), fifo_score=float(scores[0]),
            max_age=max_age, elapsed_s=time.monotonic() - t0,
            shape_key=keys[best], novel_shape=novel)
        self.batch_idx += 1
        self._record(self.last_stats)
        return batch

    def drain(self) -> Iterator[List[DataItem]]:
        """Empty the window at end of stream (exactly-once: the final
        batch may be smaller than gbs)."""
        while self._entries:
            yield self.compose()

    # ------------------------------------------------------------------ #
    def _record(self, st: ComposeStats) -> None:
        if self.trace is not None:
            self.trace.complete(
                "compose", self.trace.now_us() - st.elapsed_s * 1e6,
                st.elapsed_s * 1e6, cat="compose",
                args={"batch": st.batch_idx, "window_fill": st.window_fill,
                      "n_forced": st.n_forced, "max_age": st.max_age})
            self.trace.counter("compose_pred_gain", st.pred_gain)
            self.trace.counter("compose_window_fill", st.window_fill)
            self.trace.counter("compose_shape_buckets",
                               len(self._seen_shapes))
        if self.metrics is not None:
            self.metrics.record_compose(st)
