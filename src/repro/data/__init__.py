from repro.data.items import DataItem, item_shapes
from repro.data.synthetic import MixedDataset, MIXTURES
from repro.data.packing import pack_items, PackedBatch

# NOTE: repro.data.loader imports the scheduler (which imports the profiler,
# which imports repro.data.items) — import it directly as
# `from repro.data.loader import ScheduledLoader` to avoid a package cycle.

__all__ = ["DataItem", "item_shapes", "MixedDataset", "MIXTURES",
           "pack_items", "PackedBatch"]
