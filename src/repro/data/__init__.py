from repro.data.items import DataItem, item_shapes
from repro.data.synthetic import MixedDataset, MIXTURES
from repro.data.packing import pack_items, PackedBatch

# NOTE: repro.data.loader and repro.data.composer import the scheduler
# (which imports the profiler, which imports repro.data.items) — import
# them directly as `from repro.data.loader import ScheduledLoader` /
# `from repro.data.composer import LookaheadComposer` to avoid a package
# cycle.

__all__ = ["DataItem", "item_shapes", "MixedDataset", "MIXTURES",
           "pack_items", "PackedBatch"]
