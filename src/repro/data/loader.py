"""Scheduled data loader: DFLOP scheduler groups -> packed tensor batches.

Integration point of the Online Microbatch Scheduler with the input
pipeline (paper Fig. 3: "integrated into the data loading pipeline").  Each
global batch of DataItems is partitioned into m = N_mb · L_dp buckets by the
scheduler; bucket (i, r) becomes row r of microbatch i, sequence-packed to a
fixed token budget.  Scheduling of batch t+1 overlaps step t via
`scheduler.submit/collect`.

With a `LookaheadComposer` (``composer=``) the item flow becomes
compose → schedule → pack: raw draws feed the composer's reorder window
and the loader consumes *composed* global batches.  By default
(``compose_prefetch=True``) the window refill runs on a background
thread — global batch t+1 is pushed and composed while batch t is being
scored/scheduled — with a depth-2 queue for backpressure; set
``compose_prefetch=False`` to compose inline on the caller thread.  See
``docs/data.md``.

Determinism contract (pinned by ``tests/test_loader.py``): prefetch and
sync modes — and compose-prefetch vs. inline composition — yield
batch-for-batch identical streams.  The two rng streams
(schedule_random seeds vs. packing token draws) are split per concern —
a single shared stream would be consumed in a different interleaving by
the two modes.  The compose worker is the *only* thread touching the
composer, and window ordering never depends on consumer timing, so
threading shifts when composition happens, not what it produces.
"""
from __future__ import annotations

from typing import Iterable, Iterator, Optional, Sequence

import numpy as np

from repro.core.scheduler.online import OnlineMicrobatchScheduler, ScheduleOutput
from repro.data.items import DataItem
from repro.data.packing import pack_items
from repro.data.synthetic import MixedDataset


class ScheduledLoader:
    def __init__(self, dataset: Optional[MixedDataset],
                 scheduler: OnlineMicrobatchScheduler, *,
                 gbs: int, token_budget: int, vocab_size: int,
                 random_baseline: bool = False, seed: int = 0,
                 prefetch: bool = True,
                 composer=None,
                 compose_prefetch: bool = True,
                 item_source: Optional[Iterable[Sequence[DataItem]]] = None,
                 metrics=None):
        """composer: optional `repro.data.composer.LookaheadComposer`.
        compose_prefetch: refill/compose the window on a background
        thread (batch t+1 composed while t is scored); False composes
        inline on the caller thread.  Streams are identical either way.
        item_source: optional finite iterable of item batches replacing
        ``dataset.global_batches(gbs)`` (epoch semantics: at exhaustion
        the composer window is drained, so every item is emitted exactly
        once).  metrics: optional `RuntimeMetrics` — per-global-batch
        truncated-token counts land there (``record_pack``)."""
        assert dataset is not None or item_source is not None, \
            "need a dataset or an item_source"
        self.dataset = dataset
        self.scheduler = scheduler
        self.gbs = gbs
        self.budget = token_budget
        self.vocab = vocab_size
        self.random_baseline = random_baseline
        # split streams: seeds for schedule_random vs token draws for
        # pack_items — the sync and prefetch paths interleave the two
        # concerns differently, so sharing one stream would break the
        # mode-equivalence contract
        self._seed_rng = np.random.default_rng(seed)
        self._pack_rng = np.random.default_rng([seed, 1])
        self.prefetch = prefetch
        self.composer = composer
        self.compose_prefetch = compose_prefetch
        self.item_source = item_source
        self.metrics = metrics
        self.last_schedule: Optional[ScheduleOutput] = None
        self.last_truncated: int = 0
        self.total_truncated: int = 0

    # ------------------------------------------------------------------ #
    def _schedule(self, items) -> ScheduleOutput:
        if self.random_baseline:
            return self.scheduler.schedule_random(
                items, seed=int(self._seed_rng.integers(1 << 31)))
        return self.scheduler.schedule(items)

    def _build(self, items: Sequence[DataItem], out: ScheduleOutput) -> dict:
        n_mb = self.scheduler.plan.n_mb
        dp = self.scheduler.plan.llm.dp
        m = n_mb * dp
        groups = out.groups
        assert len(groups) == m
        tokens = np.zeros((n_mb, dp, self.budget), np.int32)
        labels = np.full((n_mb, dp, self.budget), -1, np.int32)
        seg = np.zeros((n_mb, dp, self.budget), np.int32)
        pos = np.zeros((n_mb, dp, self.budget), np.int32)
        truncated = 0
        for g_idx, g in enumerate(groups):
            i, r = divmod(g_idx, dp)
            packed = pack_items([items[j] for j in g], self.budget,
                                self.scheduler.tpm, self.vocab,
                                self._pack_rng)
            truncated += packed.truncated
            tokens[i, r] = packed.tokens[0]
            labels[i, r] = packed.labels[0]
            seg[i, r] = packed.segment_ids[0]
            pos[i, r] = packed.positions[0]
        self.last_truncated = truncated
        self.total_truncated += truncated
        if self.metrics is not None:
            self.metrics.record_pack(truncated)
        return {"tokens": tokens, "labels": labels,
                "segment_ids": seg, "positions": pos}

    # ------------------------------------------------------------------ #
    def _compose_stream(self, gen) -> Iterator[Sequence[DataItem]]:
        """Background-thread composition: the window refill (push raw
        draws, compose ready batches, drain at exhaustion) runs off the
        caller thread, so global batch t+1 is composed while batch t is
        being scored/scheduled.  A depth-2 queue provides backpressure;
        the worker is the only thread touching the composer and executes
        the exact push/compose/drain sequence of the inline path, so the
        emitted stream is bit-identical (pinned by tests/test_loader.py).
        Worker exceptions are re-raised on the caller; abandoning the
        generator early stops the worker via the stop event."""
        import queue as _queue
        import threading
        q: "_queue.Queue" = _queue.Queue(maxsize=2)
        stop = threading.Event()
        _END = object()

        def _put(x) -> bool:
            while not stop.is_set():
                try:
                    q.put(x, timeout=0.1)
                    return True
                except _queue.Full:
                    continue
            return False

        def _work():
            try:
                for raw in gen:
                    self.composer.push(raw)
                    while self.composer.ready:
                        if not _put(self.composer.compose()):
                            return
                for b in self.composer.drain():
                    if not _put(b):
                        return
                _put(_END)
            except BaseException as exc:   # surface on the caller thread
                _put(exc)

        worker = threading.Thread(target=_work, name="compose-prefetch",
                                  daemon=True)
        worker.start()
        try:
            while True:
                got = q.get()
                if got is _END:
                    return
                if isinstance(got, BaseException):
                    raise got
                yield got
        finally:
            stop.set()

    def _item_batches(self) -> Iterator[Sequence[DataItem]]:
        """Upstream global batches: FIFO draws, optionally re-composed
        through the lookahead window (inline or on the compose-prefetch
        thread)."""
        gen = (iter(self.item_source) if self.item_source is not None
               else self.dataset.global_batches(self.gbs))
        if self.composer is None:
            yield from gen
            return
        if self.compose_prefetch:
            yield from self._compose_stream(gen)
            return
        for raw in gen:
            self.composer.push(raw)
            while self.composer.ready:
                yield self.composer.compose()
        # finite stream: exactly-once requires emptying the window
        yield from self.composer.drain()

    def __iter__(self) -> Iterator[dict]:
        gen = self._item_batches()
        if not self.prefetch:
            for items in gen:
                out = self._schedule(items)
                self.last_schedule = out
                yield self._build(items, out)
            return
        # async: schedule batch t+1 while the caller runs step t
        try:
            items = next(gen)
        except StopIteration:
            return
        if self.random_baseline:
            pending_items, pending_out = items, self._schedule(items)
        else:
            self.scheduler.submit(items)
            pending_items, pending_out = items, None
        while True:
            if pending_out is None:
                pending_out = self.scheduler.collect()
            items_next = next(gen, None)
            next_out = None
            if items_next is not None:
                if self.random_baseline:
                    next_out = self._schedule(items_next)
                else:
                    self.scheduler.submit(items_next)
            out, cur_items = pending_out, pending_items
            pending_items = items_next
            pending_out = next_out
            self.last_schedule = out
            yield self._build(cur_items, out)
            if pending_items is None:
                return
