"""Scheduled data loader: DFLOP scheduler groups -> packed tensor batches.

Integration point of the Online Microbatch Scheduler with the input
pipeline (paper Fig. 3: "integrated into the data loading pipeline").  Each
global batch of DataItems is partitioned into m = N_mb · L_dp buckets by the
scheduler; bucket (i, r) becomes row r of microbatch i, sequence-packed to a
fixed token budget.  Scheduling of batch t+1 overlaps step t via
`scheduler.submit/collect`.
"""
from __future__ import annotations

from typing import Iterator, Optional, Sequence

import numpy as np

from repro.core.scheduler.online import OnlineMicrobatchScheduler, ScheduleOutput
from repro.data.items import DataItem
from repro.data.packing import pack_items
from repro.data.synthetic import MixedDataset


class ScheduledLoader:
    def __init__(self, dataset: MixedDataset,
                 scheduler: OnlineMicrobatchScheduler, *,
                 gbs: int, token_budget: int, vocab_size: int,
                 random_baseline: bool = False, seed: int = 0,
                 prefetch: bool = True):
        self.dataset = dataset
        self.scheduler = scheduler
        self.gbs = gbs
        self.budget = token_budget
        self.vocab = vocab_size
        self.random_baseline = random_baseline
        self.rng = np.random.default_rng(seed)
        self.prefetch = prefetch
        self.last_schedule: Optional[ScheduleOutput] = None

    # ------------------------------------------------------------------ #
    def _schedule(self, items) -> ScheduleOutput:
        if self.random_baseline:
            return self.scheduler.schedule_random(items, seed=int(self.rng.integers(1 << 31)))
        return self.scheduler.schedule(items)

    def _build(self, items: Sequence[DataItem], out: ScheduleOutput) -> dict:
        n_mb = self.scheduler.plan.n_mb
        dp = self.scheduler.plan.llm.dp
        m = n_mb * dp
        groups = out.groups
        assert len(groups) == m
        tokens = np.zeros((n_mb, dp, self.budget), np.int32)
        labels = np.full((n_mb, dp, self.budget), -1, np.int32)
        seg = np.zeros((n_mb, dp, self.budget), np.int32)
        pos = np.zeros((n_mb, dp, self.budget), np.int32)
        for g_idx, g in enumerate(groups):
            i, r = divmod(g_idx, dp)
            packed = pack_items([items[j] for j in g], self.budget,
                                self.scheduler.tpm, self.vocab, self.rng)
            tokens[i, r] = packed.tokens[0]
            labels[i, r] = packed.labels[0]
            seg[i, r] = packed.segment_ids[0]
            pos[i, r] = packed.positions[0]
        return {"tokens": tokens, "labels": labels,
                "segment_ids": seg, "positions": pos}

    # ------------------------------------------------------------------ #
    def __iter__(self) -> Iterator[dict]:
        gen = self.dataset.global_batches(self.gbs)
        if not self.prefetch:
            for items in gen:
                out = self._schedule(items)
                self.last_schedule = out
                yield self._build(items, out)
            return
        # async: schedule batch t+1 while the caller runs step t
        items = next(gen)
        if self.random_baseline:
            pending_items, pending_out = items, self._schedule(items)
        else:
            self.scheduler.submit(items)
            pending_items, pending_out = items, None
        while True:
            if pending_out is None:
                pending_out = self.scheduler.collect()
            items_next = next(gen)
            if not self.random_baseline:
                self.scheduler.submit(items_next)
            out, cur_items = pending_out, pending_items
            pending_items = items_next
            pending_out = self._schedule(items_next) if self.random_baseline else None
            self.last_schedule = out
            yield self._build(cur_items, out)
