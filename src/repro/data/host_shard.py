"""Per-host data sharding with exactly-once delivery under host churn.

An emulated fleet (`repro.launch.fleet.FleetManager`) splits each global
batch across its *alive* hosts.  Membership churn threatens the loader's
exactly-once contract in two directions:

  * a host that **fails mid-step** takes its shard down with it — under
    synchronous data parallelism the whole step's gradient is lost, so
    every item of that step must be re-delivered (at-least-once is not
    enough: it must be the *same* items, in the *same* global-batch
    grouping, or the loss trajectory forks from the fault-free run);
  * a **re-partition** after join/leave must not duplicate or drop the
    items already buffered for the old roster.

Both reduce to atomic step semantics on one queue:

  ``draw()``   — take the next ``gbs`` items off the stream and partition
                 them over the alive roster (round-robin by position, so
                 the *global batch content* is roster-independent — only
                 the per-host split changes with membership);
  ``commit()`` — the step's allreduce completed on every alive host: the
                 batch is final, account it delivered;
  ``abort()``  — the step died (host failure mid-step): requeue the
                 **whole** step at the front, so the next ``draw()`` —
                 typically over the survivors — re-delivers the identical
                 global batch.

Because aborted steps requeue in full and in order, the *committed*
global-batch stream is bit-identical to a fault-free run's — which is
what lets `tests/test_fleet.py` pin loss-trajectory continuity across
checkpoint-free recovery instead of merely bounding divergence.
"""
from __future__ import annotations

from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence


def partition_by_host(items: Sequence, host_ids: Sequence[int]) -> Dict[int, list]:
    """Round-robin split of one global batch over the alive hosts.

    Position-based and deterministic: item ``i`` goes to host
    ``host_ids[i % len(host_ids)]``.  The union (in position order) is
    always the input batch, so re-partitioning the same batch over a
    different roster changes *who loads what*, never *what the step
    trains on*.

    >>> partition_by_host(list("abcdef"), [0, 2, 3])
    {0: ['a', 'd'], 2: ['b', 'e'], 3: ['c', 'f']}
    >>> partition_by_host([], [1])
    {1: []}
    """
    if not host_ids:
        raise ValueError("cannot partition over an empty roster")
    shards: Dict[int, list] = {h: [] for h in host_ids}
    for i, it in enumerate(items):
        shards[host_ids[i % len(host_ids)]].append(it)
    return shards


class HostShardedSource:
    """Exactly-once global-batch source for an elastic fleet.

    ``source`` is a zero-arg callable returning the next chunk of the
    underlying stream (any length >= 1; e.g. ``lambda: ds.sample(gbs)``
    or an epoch iterator's ``next``).  Items queue in stream order;
    ``draw()`` is only ever satisfied from the queue front, so requeued
    (aborted) items win over fresh ones and ordering is preserved.

    >>> stream = iter(range(100))
    >>> src = HostShardedSource(lambda: [next(stream) for _ in range(4)],
    ...                         gbs=4)
    >>> src.draw([0, 1])
    {0: [0, 2], 1: [1, 3]}
    >>> src.abort()               # host 1 died mid-step
    >>> src.draw([0])             # identical batch, survivors only
    {0: [0, 1, 2, 3]}
    >>> src.commit()
    >>> src.draw([0, 2]); src.commit()
    {0: [4, 6], 2: [5, 7]}
    >>> src.n_committed, src.committed[0]
    (2, [0, 1, 2, 3])
    """

    def __init__(self, source: Callable[[], Sequence], gbs: int, *,
                 fleet=None, keep_committed: bool = True):
        """``fleet``: optional `FleetManager`; when set, ``draw()`` may be
        called without a roster and uses ``fleet.alive_ids()``.
        ``keep_committed=False`` drops the committed-batch history (tests
        keep it to assert bit-identical streams; long runs should not)."""
        if gbs < 1:
            raise ValueError(f"gbs must be >= 1, got {gbs}")
        self.source = source
        self.gbs = gbs
        self.fleet = fleet
        self.keep_committed = keep_committed
        self._queue: Deque = deque()
        self._in_flight: Optional[List] = None
        self.committed: List[list] = []     # committed global batches, in order
        self.n_drawn = 0
        self.n_committed = 0
        self.n_aborted = 0

    # ------------------------------------------------------------------ #
    @property
    def in_flight(self) -> Optional[List]:
        """The uncommitted step's global batch (None between steps)."""
        return list(self._in_flight) if self._in_flight is not None else None

    def draw(self, host_ids: Optional[Sequence[int]] = None) -> Dict[int, list]:
        """Take the next global batch and shard it over ``host_ids``
        (default: the attached fleet's alive roster).  Exactly one step
        may be in flight: the previous ``draw()`` must have been
        ``commit()``-ed or ``abort()``-ed first."""
        if self._in_flight is not None:
            raise RuntimeError("previous step still in flight; "
                               "commit() or abort() it before drawing")
        if host_ids is None:
            if self.fleet is None:
                raise ValueError("no host_ids given and no fleet attached")
            host_ids = self.fleet.alive_ids()
        while len(self._queue) < self.gbs:
            chunk = list(self.source())
            if not chunk:
                raise RuntimeError("source exhausted before a full "
                                   f"global batch ({len(self._queue)}"
                                   f"/{self.gbs} items queued)")
            self._queue.extend(chunk)
        batch = [self._queue.popleft() for _ in range(self.gbs)]
        self._in_flight = batch
        self.n_drawn += 1
        return partition_by_host(batch, list(host_ids))

    def commit(self) -> None:
        """Finalize the in-flight step: its batch is delivered exactly
        once and will never be re-drawn."""
        if self._in_flight is None:
            raise RuntimeError("commit() with no step in flight")
        if self.keep_committed:
            self.committed.append(self._in_flight)
        self._in_flight = None
        self.n_committed += 1

    def abort(self) -> None:
        """Roll the in-flight step back: requeue its *entire* batch at the
        queue front (synchronous DP — a lost shard loses the step), so the
        next ``draw()`` re-delivers the identical global batch."""
        if self._in_flight is None:
            raise RuntimeError("abort() with no step in flight")
        self._queue.extendleft(reversed(self._in_flight))
        self._in_flight = None
        self.n_aborted += 1
