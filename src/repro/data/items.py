"""Data item abstraction: the unit the Online Microbatch Scheduler balances.

A training instance is characterized (for scheduling purposes) by the two
shape dimensions the paper identifies (§3.2.2):
  * the encoder's effective batch contribution  b(d) = number of media items
    (images / video frames) — each media item is E_seq_len encoder tokens;
  * the LLM's sequence-length contribution      s(d) = connector output
    tokens + text tokens (sequence packing makes the LLM batch 1).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class DataItem:
    n_media_items: int          # images / sampled frames in the instance
    text_len: int               # text tokens
    modality: str = "single_image"
    item_id: int = -1

    def encoder_batch(self) -> int:
        return self.n_media_items

    def llm_seq_len(self, tokens_per_media_item: int) -> int:
        return self.n_media_items * tokens_per_media_item + self.text_len


def item_shapes(item: DataItem, tokens_per_media_item: int) -> tuple[int, int]:
    """(b(d), s(d)) — the two quantities DFLOP's models are keyed on."""
    return item.encoder_batch(), item.llm_seq_len(tokens_per_media_item)
