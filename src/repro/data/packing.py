"""Sequence packing (paper §3.2.1): concatenate instances into one sequence.

"We employ sequence packing for the LLM to concatenate instances,
effectively fixing the batch size to 1 while making L_seq_len highly
variable."  Segment ids preserve per-instance causal integrity (consumed by
the packed flash-attention mask).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import numpy as np

from repro.data.items import DataItem


@dataclass
class PackedBatch:
    """One packed microbatch: token budget `budget`, padded to it.

    Token accounting is conserved, never silent: every input token is
    either placed (``used``) or dropped at the budget boundary
    (``truncated``), and the row is padded back up to the budget
    (``padding``) — so ``used + truncated == Σ len(seq)`` and
    ``used + padding == budget`` (pinned in ``tests/test_packing.py``).
    """

    tokens: np.ndarray        # (1, budget) int32
    labels: np.ndarray        # (1, budget) int32, -1 = ignore
    segment_ids: np.ndarray   # (1, budget) int32, 0 = padding
    positions: np.ndarray     # (1, budget) int32, restart per segment
    n_items: int
    used: int
    truncated: int = 0        # input tokens dropped at the budget boundary

    @property
    def padding(self) -> int:
        return self.tokens.shape[-1] - self.used


def pack_tokens(sequences: Sequence[np.ndarray], budget: int,
                pad_id: int = 0) -> PackedBatch:
    """Pack token sequences into one row of `budget` tokens.  Overflow is
    truncated (callers size the budget from the scheduler) but *counted*:
    ``PackedBatch.truncated`` carries every dropped input token, including
    whole sequences skipped once the row is (nearly) full."""
    tokens = np.full((budget,), pad_id, np.int32)
    labels = np.full((budget,), -1, np.int32)
    seg = np.zeros((budget,), np.int32)
    pos = np.zeros((budget,), np.int32)
    total = sum(len(s) for s in sequences)
    cur = 0
    n = 0
    for s_idx, s in enumerate(sequences):
        s = np.asarray(s, np.int32)
        take = min(len(s), budget - cur)
        if take <= 1:
            break
        tokens[cur:cur + take] = s[:take]
        labels[cur:cur + take - 1] = s[1:take]
        seg[cur:cur + take] = s_idx + 1
        pos[cur:cur + take] = np.arange(take)
        cur += take
        n += 1
    return PackedBatch(tokens[None], labels[None], seg[None], pos[None], n,
                       cur, truncated=total - cur)


def pack_items(items: Sequence[DataItem], budget: int,
               tokens_per_media_item: int, vocab: int,
               rng: np.random.Generator) -> PackedBatch:
    """Pack DataItems (media tokens become placeholder token 1 spans).

    Items longer than the whole budget are clipped *before* token
    generation (no point materializing tokens that cannot fit), but the
    clipped length still counts toward ``PackedBatch.truncated`` so the
    accounting identity holds against the items' true lengths."""
    seqs = []
    pre_clipped = 0
    for it in items:
        full = it.llm_seq_len(tokens_per_media_item)
        L = min(full, budget)
        pre_clipped += full - L
        seqs.append(rng.integers(2, max(3, vocab), size=L))
    pb = pack_tokens(seqs, budget)
    pb.truncated += pre_clipped
    return pb


def greedy_bin_pack(lengths: Sequence[int], budget: int) -> List[List[int]]:
    """First-fit-decreasing packing of item lengths into budget-sized bins.
    Returns item-index groups (used by the data loader to build microbatch
    rows once the scheduler has fixed the groups)."""
    order = np.argsort(lengths)[::-1]
    bins: List[List[int]] = []
    space: List[int] = []
    for i in order:
        L = min(int(lengths[i]), budget)
        placed = False
        for b, s in enumerate(space):
            if s >= L:
                bins[b].append(int(i))
                space[b] -= L
                placed = True
                break
        if not placed:
            bins.append([int(i)])
            space.append(budget - L)
    return bins
