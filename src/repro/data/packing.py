"""Sequence packing (paper §3.2.1): concatenate instances into one sequence.

"We employ sequence packing for the LLM to concatenate instances,
effectively fixing the batch size to 1 while making L_seq_len highly
variable."  Segment ids preserve per-instance causal integrity (consumed by
the packed flash-attention mask).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import numpy as np

from repro.data.items import DataItem


@dataclass
class PackedBatch:
    """One packed microbatch: token budget `budget`, padded to it."""

    tokens: np.ndarray        # (1, budget) int32
    labels: np.ndarray        # (1, budget) int32, -1 = ignore
    segment_ids: np.ndarray   # (1, budget) int32, 0 = padding
    positions: np.ndarray     # (1, budget) int32, restart per segment
    n_items: int
    used: int


def pack_tokens(sequences: Sequence[np.ndarray], budget: int,
                pad_id: int = 0) -> PackedBatch:
    """Pack token sequences into one row of `budget` tokens (truncating the
    overflow — callers size the budget from the scheduler)."""
    tokens = np.full((budget,), pad_id, np.int32)
    labels = np.full((budget,), -1, np.int32)
    seg = np.zeros((budget,), np.int32)
    pos = np.zeros((budget,), np.int32)
    cur = 0
    n = 0
    for s_idx, s in enumerate(sequences):
        s = np.asarray(s, np.int32)
        take = min(len(s), budget - cur)
        if take <= 1:
            break
        tokens[cur:cur + take] = s[:take]
        labels[cur:cur + take - 1] = s[1:take]
        seg[cur:cur + take] = s_idx + 1
        pos[cur:cur + take] = np.arange(take)
        cur += take
        n += 1
    return PackedBatch(tokens[None], labels[None], seg[None], pos[None], n, cur)


def pack_items(items: Sequence[DataItem], budget: int,
               tokens_per_media_item: int, vocab: int,
               rng: np.random.Generator) -> PackedBatch:
    """Pack DataItems (media tokens become placeholder token 1 spans)."""
    seqs = []
    for it in items:
        L = min(it.llm_seq_len(tokens_per_media_item), budget)
        seqs.append(rng.integers(2, max(3, vocab), size=L))
    return pack_tokens(seqs, budget)


def greedy_bin_pack(lengths: Sequence[int], budget: int) -> List[List[int]]:
    """First-fit-decreasing packing of item lengths into budget-sized bins.
    Returns item-index groups (used by the data loader to build microbatch
    rows once the scheduler has fixed the groups)."""
    order = np.argsort(lengths)[::-1]
    bins: List[List[int]] = []
    space: List[int] = []
    for i in order:
        L = min(int(lengths[i]), budget)
        placed = False
        for b, s in enumerate(space):
            if s >= L:
                bins[b].append(int(i))
                space[b] -= L
                placed = True
                break
        if not placed:
            bins.append([int(i)])
            space.append(budget - L)
    return bins
