"""Synthetic multimodal datasets mirroring the paper's mixed workload (Table 2).

The paper's composite dataset mixes single-image (LLaVA-Wild / AI2D /
InfographicVQA), multi-image (M4-Instruct) and video (LLaVA-Video) items.
We reproduce its *shape statistics*: per-item media-item counts and text
lengths drawn from per-modality distributions, with the mixture weights of
Table 2 (65k / 60k / 60k -> 0.35 / 0.32 / 0.33).

`MixedDataset` yields `DataItem`s (for the scheduler) and can materialize
tensor batches (stub embeddings + token ids) for actual training.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.data.items import DataItem


@dataclass(frozen=True)
class ModalityProfile:
    name: str
    media_lo: int
    media_hi: int               # inclusive; uniform over [lo, hi]
    text_lo: int
    text_hi: int


# media counts: single image = 1 (hi-res tiling handled by tokens/item);
# M4-Instruct interleaves 2-8 images; video = 8-32 sampled frames.
PROFILES = {
    "single_image": ModalityProfile("single_image", 1, 1, 64, 1024),
    "multi_image": ModalityProfile("multi_image", 2, 8, 128, 1536),
    "video": ModalityProfile("video", 8, 32, 64, 768),
    "text": ModalityProfile("text", 0, 0, 256, 4096),
    "audio": ModalityProfile("audio", 1, 4, 64, 768),
}

MIXTURES: Dict[str, Dict[str, float]] = {
    # paper Table 2 composition
    "mixed": {"single_image": 0.35, "multi_image": 0.32, "video": 0.33},
    "multi_image": {"multi_image": 1.0},
    "video": {"video": 1.0},
    "single_image": {"single_image": 1.0},
    "audio": {"audio": 0.7, "text": 0.3},
    "text": {"text": 1.0},
}


class MixedDataset:
    """Infinite sampler of DataItems with a fixed modality mixture."""

    def __init__(self, mixture: str | Dict[str, float] = "mixed",
                 seed: int = 0, tokens_per_media_item: int = 196):
        self.mixture = MIXTURES[mixture] if isinstance(mixture, str) else mixture
        self.names = sorted(self.mixture)
        self.probs = np.array([self.mixture[n] for n in self.names])
        self.probs = self.probs / self.probs.sum()
        self.rng = np.random.default_rng(seed)
        self.tokens_per_media_item = tokens_per_media_item
        self._next_id = 0

    def sample(self, n: int) -> List[DataItem]:
        kinds = self.rng.choice(len(self.names), size=n, p=self.probs)
        items = []
        for k in kinds:
            prof = PROFILES[self.names[k]]
            media = int(self.rng.integers(prof.media_lo, prof.media_hi + 1)) \
                if prof.media_hi else 0
            text = int(self.rng.integers(prof.text_lo, prof.text_hi + 1))
            items.append(DataItem(media, text, self.names[k], self._next_id))
            self._next_id += 1
        return items

    def global_batches(self, gbs: int) -> Iterator[List[DataItem]]:
        while True:
            yield self.sample(gbs)

    # ------------------------------------------------------------------ #
    def materialize(self, items: Sequence[DataItem], *, embed_dim: int,
                    vocab_size: int, max_media: int, max_text: int,
                    seed: int = 0) -> dict:
        """Tensorize items into a padded multimodal batch (stub frontend)."""
        rng = np.random.default_rng(seed)
        B = len(items)
        t_media = max_media
        media = np.zeros((B, t_media, embed_dim), np.float32)
        media_mask = np.zeros((B, t_media), np.int32)
        text = np.zeros((B, max_text), np.int32)
        text_mask = np.zeros((B, max_text), np.int32)
        labels = np.full((B, max_text), -1, np.int32)
        tpm = self.tokens_per_media_item
        for i, it in enumerate(items):
            m = min(it.n_media_items * tpm, t_media)
            media[i, :m] = rng.standard_normal((m, embed_dim)) * 0.02
            media_mask[i, :m] = 1
            t = min(it.text_len, max_text)
            toks = rng.integers(1, vocab_size, size=t)
            text[i, :t] = toks
            text_mask[i, :t] = 1
            labels[i, : t - 1] = toks[1:]
        return {
            "media_embeds": media,
            "media_mask": media_mask,
            "text_tokens": text,
            "text_mask": text_mask,
            "labels": labels,
        }
