"""Real execution backend: the serving loop drives the jit'd executor.

`EmulatedBackend` prices the serving physics from the perf model;
`RealBackend` *runs* them on a jax model and reports measured wall-clock
durations, closing the ROADMAP's "serving: from emulation to the real
executor" loop — the measurements feed the same calibrator → Page–
Hinkley → re-price path fig19 exercises with oracle durations (fig22
does it against silicon).

Execution substrate (all from `repro.serve.steps`):

  * **prefill** — per-request, at the prompt's exact length, chunked via
    `pow2_chunks` + `prefill_into_cache_chunked`: every chunk is a jitted
    `lax.scan` of `decode_step`, so the handoff is numerically the same
    path decode continues on (token-identical to a solo run);
  * **handoff** — `jax.device_put` of the request's B=1 cache pytree from
    its prefill worker's device to a decode worker's device
    (disaggregated pools via `repro.launch.mesh.serve_device_pools`; on
    an emulated fleet each worker owns a forced host device);
  * **decode** — per-worker continuous batch at ``decode_slots`` rows,
    occupied rows compacted to a prefix and the step jitted per pow2
    occupancy bucket (the same buckets `SLOAdmission` reasons about);
    `merge_cache_row`/`clear_cache_row`/`extract_cache_row` implement
    join, leave and preemption-park.

Shape discipline: prefill compiles ≤ 1 + log2(chunk) chunk shapes,
decode ≤ log2(slots) + 1 occupancy buckets per device — ``warmup()``
pre-compiles the whole set so measured durations never include compile
time.  ``probe()`` seeds the calibrator's "prefill"/"decode" cells with
a few measured shapes (the perf model predicts accelerator-seconds, the
host executes wall-seconds; without a probe the first admission rounds
price in the wrong unit system by orders of magnitude).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.composer import _pow2
from repro.launch.mesh import serve_device_pools
from repro.models import model as model_lib
from repro.models.layers.attention import kv_cache_bytes
from repro.serve.backend import (DecodeOutcome, ExecutionBackend,
                                 PrefillOutcome)
from repro.serve.request import Request
from repro.serve.steps import (_chunk_scan_fn, clear_cache_row,
                               extract_cache_row, merge_cache_row,
                               pow2_chunks)

# one jitted "slice rows → decode → write back" per (cfg, occupancy
# bucket); module-level so every backend instance (and repeated fig22
# runs in one process) share compiled executables
_DECODE_FNS: dict = {}


def _decode_bucket_fn(cfg, n_pad: int):
    key = (cfg, int(n_pad))
    fn = _DECODE_FNS.get(key)
    if fn is not None:
        return fn

    def step(params, caches, tok, pos):
        # cache leaves are (n_blocks, B, ...); run only the occupied pow2
        # prefix and write the updated rows back into the full cache
        part = jax.tree.map(lambda a: a[:, :n_pad], caches)
        logits, new_part, _ = model_lib.decode_step(
            params, cfg, tok[:n_pad], part, pos[:n_pad])
        full = jax.tree.map(lambda f, p: f.at[:, :n_pad].set(p),
                            caches, new_part)
        return logits, full

    fn = _DECODE_FNS[key] = jax.jit(step)
    return fn


class _Prefilled:
    """A prefilled request awaiting handoff/join: its B=1 cache, the
    argmax first token, the prompt length, and the device it lives on."""

    __slots__ = ("cache", "tok0", "length", "device")

    def __init__(self, cache, tok0, length, device):
        self.cache, self.tok0 = cache, tok0
        self.length, self.device = length, device


class _WorkerState:
    """One decode worker's device-resident continuous batch.  Occupied
    slots are always the prefix [0, n_active) — `release` compacts by
    moving the last row into the freed slot."""

    def __init__(self, device, cfg, slots, max_len, kv_dtype):
        self.device = device
        self.caches = jax.device_put(
            model_lib.init_cache(cfg, slots, max_len, kv_dtype), device)
        self.tok = np.zeros(slots, np.int32)
        self.pos = np.zeros(slots, np.int32)
        self.reqs: List[Optional[Request]] = [None] * slots
        self.n_active = 0


class RealBackend(ExecutionBackend):
    """Measured jit'd execution behind the backend-agnostic serving loop.

    The loop calls eagerly (prefill at admission, decode at each step
    boundary); each call runs on this process's devices, blocks, and
    returns its measured wall duration, which the loop replays on the
    virtual clock and feeds to the calibrator."""

    name = "real"
    observes_decode = True

    def __init__(self, model_cfg, params, pricer, serve_cfg, *,
                 max_len: int = 128, chunk: int = 16,
                 kv_dtype=jnp.float32, devices=None, warmup: bool = True):
        self.cfg = model_cfg
        self.pricer = pricer
        self.serve = serve_cfg
        self.max_len = int(max_len)
        self.chunk = int(chunk)
        self.kv_dtype = kv_dtype
        self.prefill_devs, self.decode_devs = serve_device_pools(
            serve_cfg.n_prefill_workers, serve_cfg.n_decode_workers, devices)
        self._params: Dict = {}
        for d in {*self.prefill_devs, *self.decode_devs}:
            self._params[d] = jax.device_put(params, d)
        self._workers = [
            _WorkerState(d, model_cfg, serve_cfg.decode_slots, self.max_len,
                         kv_dtype) for d in self.decode_devs]
        self._pre: Dict[int, _Prefilled] = {}     # id(req) -> prefilled
        self._parked: Dict[int, _Prefilled] = {}  # id(req) -> preempted
        self._slot: Dict[int, int] = {}           # id(req) -> worker slot
        self._seen_shapes: set = set()
        self._rr = 0                              # handoff target rotation
        self.unit_costs: Dict[str, float] = {}
        if warmup:
            self.warmup()

    # ------------------------------------------------------------------ #
    def prompt_for(self, req: Request) -> np.ndarray:
        """Deterministic synthetic prompt for a request: the engine's
        requests are shape descriptors (`DataItem`), not token streams, so
        the backend materializes tokens from (item_id, seq len) — solo
        replays in tests regenerate the identical prompt."""
        seq = req.item.llm_seq_len(self.pricer.tpm)
        length = max(1, min(int(seq), self.max_len - req.max_new_tokens - 1))
        rng = np.random.default_rng([int(req.item.item_id), 1223])
        return rng.integers(2, self.cfg.vocab_size, size=length,
                            dtype=np.int64).astype(np.int32)

    def _timed(self, fn, *args):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        return out, time.perf_counter() - t0

    # ------------------------------------------------------------------ #
    def prefill(self, worker: int, batch: Sequence[Request],
                s_pad: int) -> PrefillOutcome:
        dev = self.prefill_devs[worker % len(self.prefill_devs)]
        params = self._params[dev]
        fn = _chunk_scan_fn(self.cfg)
        chunks: List[float] = []
        actuals: List[float] = []
        n_new = 0
        for r in batch:
            prompt = self.prompt_for(r)
            caches = jax.device_put(
                model_lib.init_cache(self.cfg, 1, self.max_len,
                                     self.kv_dtype), dev)
            toks = jax.device_put(jnp.asarray(prompt[None, :], jnp.int32),
                                  dev)
            logits, pos0, req_s = None, 0, 0.0
            for clen in pow2_chunks(len(prompt), self.chunk):
                (logits, caches), dt = self._timed(
                    fn, params, caches, toks[:, pos0:pos0 + clen],
                    jnp.int32(pos0))
                pos0 += clen
                req_s += dt
                chunks.append(dt)
                key = ("prefill", dev.id, clen)
                if key not in self._seen_shapes:
                    self._seen_shapes.add(key)
                    n_new += 1
            tok0 = int(jnp.argmax(logits[0]))
            self._pre[id(r)] = _Prefilled(caches, tok0, len(prompt), dev)
            actuals.append(req_s)
        return PrefillOutcome(duration_s=float(sum(chunks)),
                              per_request_actual=tuple(actuals),
                              chunks=tuple(chunks), n_new_shapes=n_new)

    # ------------------------------------------------------------------ #
    def handoff(self, req: Request) -> float:
        """Device-to-device transfer of the request's B=1 cache pytree to
        a (round-robin) decode device; returns the measured seconds."""
        art = self._pre[id(req)]
        dev = self.decode_devs[self._rr % len(self.decode_devs)]
        self._rr += 1
        moved, dt = self._timed(lambda c: jax.device_put(c, dev), art.cache)
        art.cache, art.device = moved, dev
        return dt

    def handoff_s_mean(self) -> float:
        # admission-slack estimate only (the real transfer is measured)
        return kv_cache_bytes(self.cfg, 1024, self.serve.kv_bytes_per_value) \
            / (self.serve.kv_bandwidth_gbps * 1e9) + self.serve.kv_latency_s

    # ------------------------------------------------------------------ #
    def join(self, worker: int, req: Request) -> None:
        w = self._workers[worker]
        art = self._parked.pop(id(req), None)
        if art is None:
            art = self._pre.pop(id(req))
        cache = art.cache
        if art.device != w.device:       # joined a different worker than
            cache = jax.device_put(cache, w.device)   # the handoff target
        slot = w.n_active
        w.caches = merge_cache_row(w.caches, cache, row=slot)
        w.tok[slot] = art.tok0
        w.pos[slot] = art.length
        w.reqs[slot] = req
        self._slot[id(req)] = slot
        w.n_active += 1

    def decode_step(self, worker: int,
                    active: Sequence[Request]) -> DecodeOutcome:
        w = self._workers[worker]
        n = len(active)
        assert n == w.n_active, (n, w.n_active)
        n_pad = min(_pow2(n), self.serve.decode_slots)
        fn = _decode_bucket_fn(self.cfg, n_pad)
        tok = jax.device_put(jnp.asarray(w.tok), w.device)
        pos = jax.device_put(jnp.asarray(w.pos), w.device)
        (logits, w.caches), dt = self._timed(
            fn, self._params[w.device], w.caches, tok, pos)
        n_new = 0
        key = ("decode", w.device.id, n_pad)
        if key not in self._seen_shapes:
            self._seen_shapes.add(key)
            n_new += 1
        log = np.asarray(logits)
        for r in active:
            slot = self._slot[id(r)]
            r.generated.append(int(w.tok[slot]))   # the token fed this step
            w.tok[slot] = int(np.argmax(log[slot]))
            w.pos[slot] += 1
        return DecodeOutcome(duration_s=dt, n_new_shapes=n_new)

    def release(self, worker: int, req: Request, park: bool = False) -> None:
        w = self._workers[worker]
        slot = self._slot.pop(id(req))
        if park:
            # snapshot the row before compaction overwrites it; the parked
            # state re-joins (possibly on another worker) bit-for-bit
            self._parked[id(req)] = _Prefilled(
                extract_cache_row(w.caches, slot), int(w.tok[slot]),
                int(w.pos[slot]), w.device)
        w.n_active -= 1
        last = w.n_active
        if slot != last:                 # compact: move last row into slot
            w.caches = merge_cache_row(w.caches, w.caches, row=slot,
                                       src_row=last)
            moved = w.reqs[last]
            w.reqs[slot] = moved
            self._slot[id(moved)] = slot
            w.tok[slot] = w.tok[last]
            w.pos[slot] = w.pos[last]
        w.caches = clear_cache_row(w.caches, last)
        w.reqs[last] = None

    # ------------------------------------------------------------------ #
    def warmup(self) -> Dict[str, float]:
        """Compile the bounded jit shape set up front (chunk sizes per
        prefill device, occupancy buckets per decode device) so measured
        serving durations exclude compile time, and record post-compile
        unit costs (`unit_costs`) — fig22 derives machine-independent SLOs
        and arrival rates from them."""
        sizes = sorted({self.chunk} | {1 << k for k in
                                       range((self.chunk - 1).bit_length())})
        fn = _chunk_scan_fn(self.cfg)
        for dev in dict.fromkeys(self.prefill_devs):
            params = self._params[dev]
            caches = jax.device_put(
                model_lib.init_cache(self.cfg, 1, self.max_len,
                                     self.kv_dtype), dev)
            for clen in sizes:
                toks = jax.device_put(
                    jnp.full((1, clen), 2, jnp.int32), dev)
                _, dt = self._timed(fn, params, caches, toks, jnp.int32(0))
                _, dt = self._timed(fn, params, caches, toks, jnp.int32(0))
                if clen == self.chunk:
                    self.unit_costs["prefill_s_per_tok"] = dt / clen
        buckets = sorted({min(_pow2(k), self.serve.decode_slots)
                          for k in range(1, self.serve.decode_slots + 1)})
        for w in self._workers:
            params = self._params[w.device]
            tok = jax.device_put(jnp.zeros(self.serve.decode_slots,
                                           jnp.int32), w.device)
            pos = jax.device_put(jnp.zeros(self.serve.decode_slots,
                                           jnp.int32), w.device)
            caches = jax.device_put(
                model_lib.init_cache(self.cfg, self.serve.decode_slots,
                                     self.max_len, self.kv_dtype), w.device)
            for b in buckets:
                step = _decode_bucket_fn(self.cfg, b)
                _, dt = self._timed(step, params, caches, tok, pos)
                _, dt = self._timed(step, params, caches, tok, pos)
                self.unit_costs[f"decode_step_s_b{b}"] = dt
        self.unit_costs["decode_step_s"] = \
            self.unit_costs[f"decode_step_s_b{buckets[-1]}"]
        return self.unit_costs

    def probe(self, requests: Sequence[Request], *, n_shapes: int = 4,
              n_obs: int = 2) -> None:
        """Seed the pricer's calibrator with measured (prefill, decode)
        observations for up to ``n_shapes`` distinct request shapes, then
        flush the pricer so admission prices in wall seconds from the
        first round.  The perf model predicts accelerator-seconds for the
        profiled arch while the backend measures host wall-seconds — the
        calibrator's per-bucket ratios are exactly the unit conversion,
        but only after at least one observation per bucket."""
        cal = self.pricer.calibrator
        if cal is None:
            return
        seen, reps = set(), []
        for r in requests:
            k = self.pricer.shapes(r)
            if k not in seen:
                seen.add(k)
                reps.append(r)
            if len(reps) >= n_shapes:
                break
        dev = self.prefill_devs[0]
        params = self._params[dev]
        fn = _chunk_scan_fn(self.cfg)
        for r in reps:
            base, _, s = self.pricer.base(r)
            prompt = self.prompt_for(r)
            toks = jax.device_put(jnp.asarray(prompt[None, :], jnp.int32),
                                  dev)
            for _ in range(n_obs):
                caches = jax.device_put(
                    model_lib.init_cache(self.cfg, 1, self.max_len,
                                         self.kv_dtype), dev)
                pos0, total = 0, 0.0
                for clen in pow2_chunks(len(prompt), self.chunk):
                    (_, caches), dt = self._timed(
                        fn, params, caches, toks[:, pos0:pos0 + clen],
                        jnp.int32(pos0))
                    pos0 += clen
                    total += dt
                cal.observe("prefill", s, self.pricer.tp, base, total)
                # decode at occupancy 1, context = the request's seq len
                step = _decode_bucket_fn(self.cfg, 1)
                w = self._workers[0]
                tok = jax.device_put(jnp.zeros(self.serve.decode_slots,
                                               jnp.int32), w.device)
                pos = jax.device_put(
                    jnp.full(self.serve.decode_slots, len(prompt),
                             jnp.int32), w.device)
                dcaches = jax.device_put(
                    model_lib.init_cache(self.cfg, self.serve.decode_slots,
                                         self.max_len, self.kv_dtype),
                    w.device)
                _, ddt = self._timed(step, self._params[w.device], dcaches,
                                     tok, pos)
                cal.observe("decode", float(_pow2(int(s))), self.pricer.tp,
                            self.pricer.decode_tok_base_s(float(s)), ddt)
        self.pricer.flush()
