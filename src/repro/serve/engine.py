"""Data-aware serving loop: admission → prefill pool → KV handoff →
continuous-batch decode pool, backend-agnostic.

DFLOP's training loop (profile → plan → schedule → observe → re-plan)
maps onto inference as:

  * **profile**  — the same `PerfModel` prices per-request prefill cost
    (`PrefillPricer`, via ``e_dur``/``l_dur``) and per-token decode cost
    (decode-mode FLOPs, affine in the context length);
  * **schedule** — the admission policy forms prefill batches
    (`SLOAdmission`: EDF deadline reservation + homogeneous-run scoring;
    `FIFOAdmission`: arrival order);
  * **observe**  — every executed prefill batch feeds the
    `OnlineCalibrator` with (predicted base, actual) and the residual
    stream into a `PageHinkley` drift test;
  * **re-plan**  — a drift event flushes the pricer's memoized admission
    prices (prefill *and* decode fits) so they are re-estimated under
    the post-drift calibration.

The loop owns virtual time, SLO accounting and every policy decision;
*execution physics* live behind a pluggable `ExecutionBackend`
(`repro.serve.backend`): `EmulatedBackend` replays PR 6's discrete-event
model bit-identically (oracle ``true_factor`` durations, numpy + heapq,
no wall clock), while `RealBackend` (`repro.serve.real`) runs jit'd
prefill/decode steps on an emulated device fleet and feeds *measured*
wall-clock durations through the same calibrator/drift/re-price path.
Real execution is eager — the backend runs each batch when the loop
admits it and the measured duration is replayed on the virtual clock —
so both backends share one event loop and one telemetry surface.

Disaggregation follows DistTrain's phase split: prefill and decode run on
*separate* worker pools with an explicit KV-handoff step (priced as
bytes/bandwidth + latency when emulated; an actual device-to-device
cache transfer when real).  Decode is continuously batched — requests
join and leave a worker's batch only at step boundaries, and the batch is
padded to a power-of-two occupancy so the jit cache sees a bounded set of
shapes (each novel (pool, bucket) pays a compile).

Two loop-level policies only make sense against a backend boundary:

  * **chunked prefill** — a backend may split a batch into chunks
    (`PrefillOutcome.chunks`); the loop schedules each chunk as its own
    event, so decode steps interleave with a long prompt's prefill
    instead of stalling behind it;
  * **decode-slot preemption** (``preempt_slack_s``) — at a step
    boundary, if a ready request's SLO slack is below the threshold and
    the worker is full, the active request with the most slack is parked
    (``release(park=True)``; the backend preserves its generation state)
    and the urgent request takes the slot.

>>> ServeConfig(decode_slots=8).decode_slots
8
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.data.composer import _pow2
from repro.serve.admission import FIFOAdmission, PrefillPricer, SLOAdmission
from repro.serve.backend import (EmulatedBackend, ExecutionBackend,
                                 PrefillOutcome)
from repro.serve.request import (DECODING, DONE, HANDOFF, PREFILLING,
                                 Request, RequestQueue)


@dataclass(frozen=True)
class ServeConfig:
    """Serving cluster + engine knobs (shared by both backends)."""

    n_prefill_workers: int = 2
    n_decode_workers: int = 2
    decode_slots: int = 8            # continuous-batch rows per decode worker
    max_prefill_batch: int = 8
    tp: int = 1                      # per-worker tensor parallelism
    compile_s: float = 0.25          # opening a novel (pool, shape) bucket
    kv_bandwidth_gbps: float = 64.0  # prefill → decode interconnect
    kv_latency_s: float = 0.002
    kv_bytes_per_value: int = 2      # bf16 KV cache
    # decode-slot preemption for SLO rescue: a ready request whose slack
    # drops below this threshold may evict the slack-richest active row
    # at a step boundary.  None disables (the PR 6 behavior).
    preempt_slack_s: Optional[float] = None


@dataclass
class ServeReport:
    """Headline numbers of one `ServeEngine.run` (fig19 rows come from
    this; percentiles over *all* completions, not the metrics window)."""

    policy: str
    n_requests: int
    n_completed: int
    n_slo_met: int
    makespan_s: float
    goodput_rps: float               # SLO-met completions per second
    throughput_rps: float
    p50_latency_s: float
    p99_latency_s: float
    mean_ttft_s: float
    mean_queue_depth: float
    mean_occupancy: float
    n_prefill_batches: int
    n_decode_steps: int
    n_drift_events: int
    n_compiles: int

    def row(self) -> dict:
        """JSON-safe dict: missing stats (NaN — e.g. p99 latency with zero
        completions) become None/absent, never a fake 0.0."""
        from repro.runtime.metrics import nan_to_none
        return {k: nan_to_none(v) for k, v in self.__dict__.items()}


class _DecodeWorker:
    __slots__ = ("idx", "active", "busy")

    def __init__(self, idx: int):
        self.idx = idx
        self.active: List[Request] = []
        self.busy = False                  # a decode_step event is in flight


class ServeEngine:
    """Event-driven admission/batching loop over a live request stream."""

    def __init__(self, pricer: PrefillPricer, cfg: ServeConfig = ServeConfig(),
                 *, backend: Optional[ExecutionBackend] = None,
                 admission=None, calibrator=None, drift=None,
                 trace=None, metrics=None):
        """``backend``: the `ExecutionBackend` executing (or emulating)
        prefill/handoff/decode; default `EmulatedBackend` over ``pricer``.
        ``admission``: policy with ``select(pending, now_s, max_batch)``
        and ``note_batch(duration_s)`` (default: `SLOAdmission` around
        ``pricer``).  ``calibrator``/``drift``/``trace``/``metrics`` are
        the runtime-layer hooks (`OnlineCalibrator`, `PageHinkley`,
        `TraceRecorder`, `RuntimeMetrics`); any may be None."""
        self.pricer = pricer
        self.cfg = cfg
        self.backend = backend if backend is not None \
            else EmulatedBackend(pricer, cfg)
        self.admission = admission if admission is not None \
            else SLOAdmission(pricer, handoff_s=self.backend.handoff_s_mean())
        self.calibrator = calibrator
        self.drift = drift
        self.trace = trace
        self.metrics = metrics
        self.queue = RequestQueue()
        self.n_drift_events = 0
        self.n_compiles = 0
        self.n_preemptions = 0
        #: (module, corrected prediction, actual) per observation — the
        #: whole run, unlike the metrics' rolling window (fig22 compares
        #: early- vs late-run error to show calibration converging).
        self.prediction_log: List[Tuple[str, float, float]] = []
        self._prefill_busy = [False] * cfg.n_prefill_workers
        self._decode = [_DecodeWorker(i) for i in range(cfg.n_decode_workers)]
        self._ready: List[Request] = []    # handoff done, awaiting a slot
        self._completed: List[Request] = []
        self._heap: List[tuple] = []
        self._seq = 0                      # heap tie-break, keeps FIFO order

    # ------------------------------------------------------------------ #
    def _handoff_s(self, req: Request) -> float:
        return self.backend.handoff(req)

    def _push(self, t: float, kind: str, payload=None) -> None:
        heapq.heappush(self._heap, (t, self._seq, kind, payload))
        self._seq += 1

    def _note_compiles(self, n_new: int) -> None:
        if n_new:
            self.n_compiles += n_new
            if self.metrics is not None:
                self.metrics.n_serve_compiles += n_new

    # ------------------------------------------------------------------ #
    def run(self, requests: Sequence[Request]) -> ServeReport:
        """Serve a finite open-loop stream to completion."""
        if self.metrics is not None:
            self.metrics.n_requests += len(requests)
        for r in sorted(requests, key=lambda r: r.arrival_s):
            self._push(r.arrival_s, "arrival", r)
        while self._heap:
            t, _, kind, payload = heapq.heappop(self._heap)
            if kind == "arrival":
                self.queue.push(payload)
                self._try_admit(t)
            elif kind == "prefill_chunk":
                self._on_prefill_chunk(t, *payload)
            elif kind == "prefill_done":
                self._on_prefill_done(t, *payload)
            elif kind == "handoff_done":
                self._on_handoff_done(t, payload)
            elif kind == "decode_step":
                self._decode_step(t, payload)
        return self._report(requests)

    # ------------------------------------------------------------------ #
    # Prefill pool
    def _try_admit(self, t: float) -> None:
        for w in range(self.cfg.n_prefill_workers):
            if self._prefill_busy[w]:
                continue
            batch = self.admission.select(self.queue.pending, t,
                                          self.cfg.max_prefill_batch)
            if not batch:
                return
            depth = self.queue.depth
            self.queue.pop(batch)
            s_pad = _pow2(max(self.pricer.base(r)[2] for r in batch))
            for r in batch:
                r.status = PREFILLING
                r.admit_s = t
            out = self.backend.prefill(w, batch, s_pad)
            dur = out.duration_s
            self._note_compiles(out.n_new_shapes)
            self._prefill_busy[w] = True
            self.admission.note_batch(dur)
            if self.metrics is not None:
                self.metrics.record_admission(depth, len(batch), dur)
            if self.trace is not None:
                self.trace.complete("prefill", t * 1e6, dur * 1e6,
                                    cat="serve", tid=100 + w,
                                    args={"batch": len(batch),
                                          "s_pad": s_pad, "queue": depth})
                self.trace.counter("serve_queue_depth", depth - len(batch))
            if len(out.chunks) > 1:
                # chunked prefill: each chunk is its own event, so decode
                # steps interleave with a long prompt on the virtual clock
                self._push(t + out.chunks[0], "prefill_chunk",
                           (w, batch, out, 0))
            else:
                self._push(t + dur, "prefill_done", (w, batch, out))

    def _on_prefill_chunk(self, t: float, w: int, batch: List[Request],
                          out: PrefillOutcome, i: int) -> None:
        if self.metrics is not None:
            self.metrics.n_prefill_chunks += 1
        if self.trace is not None:
            self.trace.complete("prefill_chunk", (t - out.chunks[i]) * 1e6,
                                out.chunks[i] * 1e6, cat="serve",
                                tid=100 + w, args={"chunk": i,
                                                   "of": len(out.chunks)})
        if i + 1 < len(out.chunks):
            self._push(t + out.chunks[i + 1], "prefill_chunk",
                       (w, batch, out, i + 1))
        else:
            self._on_prefill_done(t, w, batch, out)

    def _on_prefill_done(self, t: float, w: int, batch: List[Request],
                         out: PrefillOutcome) -> None:
        self._prefill_busy[w] = False
        for r, actual in zip(batch, out.per_request_actual):
            r.status = HANDOFF
            r.prefill_done_s = t
            self._observe(r, actual)
            if self.metrics is not None:
                self.metrics.n_handoffs += 1
            self._push(t + self.backend.handoff(r), "handoff_done", r)
        self._try_admit(t)

    def _observe(self, r: Request, actual: float) -> None:
        """observe → (maybe) re-estimate: calibration learns the residual
        heterogeneity the perf model can't see; Page–Hinkley watches the
        post-calibration residual stream and a fire flushes the memoized
        admission prices (re-priced under the new calibration).
        ``actual`` comes from the backend: oracle-scaled base (emulated)
        or a measured wall-clock share (real)."""
        base, _, s = self.pricer.base(r)
        if self.calibrator is not None:
            corrected = self.calibrator.correct("prefill", s,
                                                self.pricer.tp, base)
            self.calibrator.observe("prefill", s, self.pricer.tp, base,
                                    actual)
        else:
            corrected = base
        self.prediction_log.append(("prefill", corrected, actual))
        if self.metrics is not None:
            self.metrics.record_prediction("prefill", corrected, actual)
        if self.drift is not None:
            if self.drift.update(abs(actual / corrected - 1.0)):
                self.n_drift_events += 1
                self.pricer.flush()
                self.drift.reset()
                if self.metrics is not None:
                    self.metrics.n_drift_events += 1
                if self.trace is not None:
                    self.trace.instant("serve_drift_reprice", cat="serve")

    # ------------------------------------------------------------------ #
    # Decode pool (continuous batching)
    def _on_handoff_done(self, t: float, r: Request) -> None:
        r.status = DECODING
        r.handoff_done_s = t
        self._ready.append(r)
        # wake every idle worker: each pulls its share of the ready list at
        # its (immediate) step boundary; surplus wakes are no-ops
        for dw in self._decode:
            if not dw.busy:
                dw.busy = True
                self._push(t, "decode_step", dw.idx)

    def _decode_slack_s(self, r: Request, t: float) -> float:
        """SLO slack if the request decoded its remaining budget now."""
        _, _, s = self.pricer.base(r)
        rem = (r.max_new_tokens - r.tokens_done) \
            * self.pricer.decode_tok_s(s + r.tokens_done)
        return r.deadline_s - t - rem

    def _maybe_preempt(self, t: float, dw: _DecodeWorker) -> None:
        """SLO rescue at a step boundary: park the slack-richest active
        row for a ready request about to miss its deadline.  The backend
        preserves the victim's generation state (``park=True``); it
        re-joins through the normal ready queue."""
        if (self.cfg.preempt_slack_s is None or not self._ready
                or len(dw.active) < self.cfg.decode_slots):
            return
        urgent = min(self._ready, key=lambda r: self._decode_slack_s(r, t))
        u_slack = self._decode_slack_s(urgent, t)
        if u_slack > self.cfg.preempt_slack_s:
            return
        victim = max(dw.active, key=lambda r: self._decode_slack_s(r, t))
        # only evict a row that is comfortably safer than the threshold —
        # equal-slack swaps would ping-pong without rescuing anyone
        if self._decode_slack_s(victim, t) <= max(u_slack,
                                                  self.cfg.preempt_slack_s):
            return
        dw.active.remove(victim)
        self.backend.release(dw.idx, victim, park=True)
        victim.n_preempted += 1
        self._ready.append(victim)
        self._ready.remove(urgent)
        self._ready.insert(0, urgent)      # urgent takes the freed slot
        self.n_preemptions += 1
        if self.metrics is not None:
            self.metrics.n_preemptions += 1
        if self.trace is not None:
            self.trace.instant("decode_preempt", cat="serve",
                               args={"worker": dw.idx})

    def _decode_step(self, t: float, idx: int) -> None:
        dw = self._decode[idx]
        # join/leave ONLY here — a step boundary of this worker
        self._maybe_preempt(t, dw)
        while self._ready and len(dw.active) < self.cfg.decode_slots:
            r = self._ready.pop(0)
            r.decode_worker = idx
            dw.active.append(r)
            self.backend.join(idx, r)
        if not dw.active:
            dw.busy = False
            return
        out = self.backend.decode_step(idx, dw.active)
        dur = out.duration_s
        self._note_compiles(out.n_new_shapes)
        n = len(dw.active)
        self._observe_decode(dw, dur)
        end = t + dur
        finished = []
        for r in dw.active:
            r.tokens_done += 1
            if r.first_token_s < 0:
                r.first_token_s = end
            if r.tokens_done >= r.max_new_tokens:
                r.status = DONE
                r.finish_s = end
                finished.append(r)
        if finished:
            dw.active = [r for r in dw.active if r.status != DONE]
            for r in finished:
                self.backend.release(idx, r)
                self._completed.append(r)
                if self.metrics is not None:
                    self.metrics.record_completion(r.latency_s, r.ttft_s,
                                                   r.slo_met)
        if self.metrics is not None:
            self.metrics.record_decode_step(n / self.cfg.decode_slots, dur)
        if self.trace is not None:
            self.trace.complete("decode_step", t * 1e6, dur * 1e6,
                                cat="serve", tid=200 + idx,
                                args={"rows": n, "finished": len(finished)})
            self.trace.counter("serve_occupancy",
                               n / self.cfg.decode_slots)
        self._push(end, "decode_step", idx)

    def _observe_decode(self, dw: _DecodeWorker, dur: float) -> None:
        """Feed a *measured* decode-step duration into the calibrator's
        "decode" cells (apportioned over rows by their raw predicted
        share).  Only backends that measure (``observes_decode``) feed
        this — observing the emulation's own oracle would be circular."""
        if not self.backend.observes_decode or dur <= 0:
            return
        rows = []
        corrected = 0.0
        raw_tot = 0.0
        for r in dw.active:
            _, _, s = self.pricer.base(r)
            c = s + r.tokens_done
            shape = float(_pow2(int(c)))
            raw = self.pricer.decode_tok_base_s(c)
            if self.calibrator is not None:
                corrected += self.calibrator.correct("decode", shape,
                                                     self.pricer.tp, raw)
            else:
                corrected += raw
            rows.append((shape, raw))
            raw_tot += raw
        if self.calibrator is not None and raw_tot > 0:
            for shape, raw in rows:
                self.calibrator.observe("decode", shape, self.pricer.tp,
                                        raw, dur * raw / raw_tot)
        self.prediction_log.append(("decode", corrected, dur))
        if self.metrics is not None:
            self.metrics.record_prediction("decode", corrected, dur)

    # ------------------------------------------------------------------ #
    def _report(self, requests: Sequence[Request]) -> ServeReport:
        done = self._completed
        # no completions → latency stats are *missing* (NaN), not 0.0: a
        # fully-overloaded run must not report a perfect p99 (row() maps
        # NaN to None so JSON consumers see them as absent).
        nan = float("nan")
        lat = np.array([r.latency_s for r in done]) if done else None
        ttft = np.array([r.ttft_s for r in done if r.ttft_s >= 0])
        makespan = max((r.finish_s for r in done), default=0.0)
        n_slo = sum(r.slo_met for r in done)
        m = self.metrics
        return ServeReport(
            policy=getattr(self.admission, "name", "custom"),
            n_requests=len(requests),
            n_completed=len(done),
            n_slo_met=n_slo,
            makespan_s=makespan,
            goodput_rps=n_slo / max(makespan, 1e-12),
            throughput_rps=len(done) / max(makespan, 1e-12),
            p50_latency_s=float(np.quantile(lat, 0.5)) if lat is not None else nan,
            p99_latency_s=float(np.quantile(lat, 0.99)) if lat is not None else nan,
            mean_ttft_s=float(ttft.mean()) if len(ttft) else nan,
            mean_queue_depth=m.queue_depth.mean() if m else nan,
            mean_occupancy=m.batch_occupancy.mean() if m else nan,
            n_prefill_batches=m.n_prefill_batches if m else 0,
            n_decode_steps=m.n_decode_steps if m else 0,
            n_drift_events=self.n_drift_events,
            n_compiles=self.n_compiles,
        )
