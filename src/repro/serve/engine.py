"""Data-aware serving engine: admission → prefill pool → KV handoff →
continuous-batch decode pool, as a deterministic discrete-event emulation.

DFLOP's training loop (profile → plan → schedule → observe → re-plan)
maps onto inference as:

  * **profile**  — the same `PerfModel` prices per-request prefill cost
    (`PrefillPricer`, via ``e_dur``/``l_dur``) and per-token decode cost
    (decode-mode FLOPs, affine in the context length);
  * **schedule** — the admission policy forms prefill batches
    (`SLOAdmission`: EDF deadline reservation + homogeneous-run scoring;
    `FIFOAdmission`: arrival order);
  * **observe**  — every executed prefill batch feeds the
    `OnlineCalibrator` with (predicted base, actual) and the residual
    stream into a `PageHinkley` drift test;
  * **re-plan**  — a drift event flushes the pricer's memoized admission
    prices so they are re-estimated under the post-drift calibration.

Disaggregation follows DistTrain's phase split: prefill and decode run on
*separate* emulated worker pools with an explicit KV-handoff step priced
as bytes/bandwidth + latency.  Decode is continuously batched — requests
join and leave a worker's batch only at step boundaries, and the batch is
padded to a power-of-two occupancy so a real jit cache would see a
bounded set of shapes (each novel (pool, bucket) pays ``compile_s``, same
convention as the composer's recompile penalty).

Ground truth comes from each request's ``true_factor`` (drawn by the load
generator: per-modality bias × lognormal noise): actual durations are
predicted *base* durations scaled by it, plus deterministic padding
overhead.  Identical request streams therefore produce bit-identical
ground truth under any admission policy — the fig19 A/B is exact.

Virtual time is seconds; nothing here touches a wall clock, so runs are
reproducible and fast (numpy + heapq only).

>>> ServeConfig(decode_slots=8).decode_slots
8
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.data.composer import _pow2
from repro.serve.admission import FIFOAdmission, PrefillPricer, SLOAdmission
from repro.serve.request import (DECODING, DONE, HANDOFF, PREFILLING,
                                 Request, RequestQueue)


@dataclass(frozen=True)
class ServeConfig:
    """Emulated serving cluster + engine knobs."""

    n_prefill_workers: int = 2
    n_decode_workers: int = 2
    decode_slots: int = 8            # continuous-batch rows per decode worker
    max_prefill_batch: int = 8
    tp: int = 1                      # per-worker tensor parallelism
    compile_s: float = 0.25          # opening a novel (pool, shape) bucket
    kv_bandwidth_gbps: float = 64.0  # prefill → decode interconnect
    kv_latency_s: float = 0.002
    kv_bytes_per_value: int = 2      # bf16 KV cache


@dataclass
class ServeReport:
    """Headline numbers of one `ServeEngine.run` (fig19 rows come from
    this; percentiles over *all* completions, not the metrics window)."""

    policy: str
    n_requests: int
    n_completed: int
    n_slo_met: int
    makespan_s: float
    goodput_rps: float               # SLO-met completions per second
    throughput_rps: float
    p50_latency_s: float
    p99_latency_s: float
    mean_ttft_s: float
    mean_queue_depth: float
    mean_occupancy: float
    n_prefill_batches: int
    n_decode_steps: int
    n_drift_events: int
    n_compiles: int

    def row(self) -> dict:
        """JSON-safe dict: missing stats (NaN — e.g. p99 latency with zero
        completions) become None/absent, never a fake 0.0."""
        from repro.runtime.metrics import nan_to_none
        return {k: nan_to_none(v) for k, v in self.__dict__.items()}


class _DecodeWorker:
    __slots__ = ("idx", "active", "busy")

    def __init__(self, idx: int):
        self.idx = idx
        self.active: List[Request] = []
        self.busy = False                  # a decode_step event is in flight


class ServeEngine:
    """Event-driven admission/batching loop over a live request stream."""

    def __init__(self, pricer: PrefillPricer, cfg: ServeConfig = ServeConfig(),
                 *, admission=None, calibrator=None, drift=None,
                 trace=None, metrics=None):
        """``admission``: policy with ``select(pending, now_s, max_batch)``
        and ``note_batch(duration_s)`` (default: `SLOAdmission` around
        ``pricer``).  ``calibrator``/``drift``/``trace``/``metrics`` are
        the runtime-layer hooks (`OnlineCalibrator`, `PageHinkley`,
        `TraceRecorder`, `RuntimeMetrics`); any may be None."""
        self.pricer = pricer
        self.cfg = cfg
        self.admission = admission if admission is not None \
            else SLOAdmission(pricer, handoff_s=self._handoff_s_mean())
        self.calibrator = calibrator
        self.drift = drift
        self.trace = trace
        self.metrics = metrics
        self.queue = RequestQueue()
        self.n_drift_events = 0
        self.n_compiles = 0
        self._prefill_busy = [False] * cfg.n_prefill_workers
        self._decode = [_DecodeWorker(i) for i in range(cfg.n_decode_workers)]
        self._ready: List[Request] = []    # handoff done, awaiting a slot
        self._seen_prefill_shapes: set = set()
        self._seen_decode_shapes: set = set()
        self._completed: List[Request] = []
        self._heap: List[tuple] = []
        self._seq = 0                      # heap tie-break, keeps FIFO order

    # ------------------------------------------------------------------ #
    def _kv_bytes(self, seq_len: int) -> float:
        c = self.pricer.perf.llm.cfg
        kv_heads = c.n_kv_heads or c.n_heads or 1
        head_dim = c.head_dim or (c.d_model // max(c.n_heads, 1))
        return 2.0 * c.n_layers * kv_heads * head_dim \
            * self.cfg.kv_bytes_per_value * seq_len

    def _handoff_s(self, req: Request) -> float:
        _, _, s = self.pricer.base(req)
        return (self._kv_bytes(s) / (self.cfg.kv_bandwidth_gbps * 1e9)
                + self.cfg.kv_latency_s)

    def _handoff_s_mean(self) -> float:
        """Rough per-request handoff estimate for admission slack."""
        return self._kv_bytes(1024) / (self.cfg.kv_bandwidth_gbps * 1e9) \
            + self.cfg.kv_latency_s

    def _push(self, t: float, kind: str, payload=None) -> None:
        heapq.heappush(self._heap, (t, self._seq, kind, payload))
        self._seq += 1

    # ------------------------------------------------------------------ #
    def run(self, requests: Sequence[Request]) -> ServeReport:
        """Serve a finite open-loop stream to completion."""
        if self.metrics is not None:
            self.metrics.n_requests += len(requests)
        for r in sorted(requests, key=lambda r: r.arrival_s):
            self._push(r.arrival_s, "arrival", r)
        while self._heap:
            t, _, kind, payload = heapq.heappop(self._heap)
            if kind == "arrival":
                self.queue.push(payload)
                self._try_admit(t)
            elif kind == "prefill_done":
                self._on_prefill_done(t, *payload)
            elif kind == "handoff_done":
                self._on_handoff_done(t, payload)
            elif kind == "decode_step":
                self._decode_step(t, payload)
        return self._report(requests)

    # ------------------------------------------------------------------ #
    # Prefill pool
    def _try_admit(self, t: float) -> None:
        for w in range(self.cfg.n_prefill_workers):
            if self._prefill_busy[w]:
                continue
            batch = self.admission.select(self.queue.pending, t,
                                          self.cfg.max_prefill_batch)
            if not batch:
                return
            depth = self.queue.depth
            self.queue.pop(batch)
            s_pad = _pow2(max(self.pricer.base(r)[2] for r in batch))
            dur = 0.0
            for r in batch:
                r.status = PREFILLING
                r.admit_s = t
                base, _, _ = self.pricer.base(r)
                dur += base * r.true_factor + self.pricer.pad_extra(r, s_pad)
            key = (_pow2(len(batch)), s_pad)
            if key not in self._seen_prefill_shapes:
                self._seen_prefill_shapes.add(key)
                dur += self.cfg.compile_s
                self.n_compiles += 1
                if self.metrics is not None:
                    self.metrics.n_serve_compiles += 1
            self._prefill_busy[w] = True
            self.admission.note_batch(dur)
            if self.metrics is not None:
                self.metrics.record_admission(depth, len(batch), dur)
            if self.trace is not None:
                self.trace.complete("prefill", t * 1e6, dur * 1e6,
                                    cat="serve", tid=100 + w,
                                    args={"batch": len(batch),
                                          "s_pad": s_pad, "queue": depth})
                self.trace.counter("serve_queue_depth", depth - len(batch))
            self._push(t + dur, "prefill_done", (w, batch))

    def _on_prefill_done(self, t: float, w: int, batch: List[Request]) -> None:
        self._prefill_busy[w] = False
        for r in batch:
            r.status = HANDOFF
            r.prefill_done_s = t
            self._observe(r)
            if self.metrics is not None:
                self.metrics.n_handoffs += 1
            self._push(t + self._handoff_s(r), "handoff_done", r)
        self._try_admit(t)

    def _observe(self, r: Request) -> None:
        """observe → (maybe) re-estimate: calibration learns the residual
        heterogeneity the perf model can't see; Page–Hinkley watches the
        post-calibration residual stream and a fire flushes the memoized
        admission prices (re-priced under the new calibration)."""
        base, _, s = self.pricer.base(r)
        actual = base * r.true_factor
        if self.calibrator is not None:
            corrected = self.calibrator.correct("prefill", s,
                                                self.pricer.tp, base)
            self.calibrator.observe("prefill", s, self.pricer.tp, base,
                                    actual)
        else:
            corrected = base
        if self.metrics is not None:
            self.metrics.record_prediction("prefill", corrected, actual)
        if self.drift is not None:
            if self.drift.update(abs(actual / corrected - 1.0)):
                self.n_drift_events += 1
                self.pricer.flush()
                self.drift.reset()
                if self.metrics is not None:
                    self.metrics.n_drift_events += 1
                if self.trace is not None:
                    self.trace.instant("serve_drift_reprice", cat="serve")

    # ------------------------------------------------------------------ #
    # Decode pool (continuous batching)
    def _on_handoff_done(self, t: float, r: Request) -> None:
        r.status = DECODING
        r.handoff_done_s = t
        self._ready.append(r)
        # wake every idle worker: each pulls its share of the ready list at
        # its (immediate) step boundary; surplus wakes are no-ops
        for dw in self._decode:
            if not dw.busy:
                dw.busy = True
                self._push(t, "decode_step", dw.idx)

    def _decode_step(self, t: float, idx: int) -> None:
        dw = self._decode[idx]
        # join/leave ONLY here — a step boundary of this worker
        while self._ready and len(dw.active) < self.cfg.decode_slots:
            r = self._ready.pop(0)
            r.decode_worker = idx
            dw.active.append(r)
        if not dw.active:
            dw.busy = False
            return
        n = len(dw.active)
        pad = _pow2(n) / n                 # pow2-bucketed batch occupancy
        dur = 0.0
        for r in dw.active:
            _, _, s = self.pricer.base(r)
            c = s + r.tokens_done
            dur += self.pricer.decode_tok_s(c) * r.true_factor
        dur *= pad
        key = _pow2(n)
        if key not in self._seen_decode_shapes:
            self._seen_decode_shapes.add(key)
            dur += self.cfg.compile_s
            self.n_compiles += 1
            if self.metrics is not None:
                self.metrics.n_serve_compiles += 1
        end = t + dur
        finished = []
        for r in dw.active:
            r.tokens_done += 1
            if r.first_token_s < 0:
                r.first_token_s = end
            if r.tokens_done >= r.max_new_tokens:
                r.status = DONE
                r.finish_s = end
                finished.append(r)
        if finished:
            dw.active = [r for r in dw.active if r.status != DONE]
            for r in finished:
                self._completed.append(r)
                if self.metrics is not None:
                    self.metrics.record_completion(r.latency_s, r.ttft_s,
                                                   r.slo_met)
        if self.metrics is not None:
            self.metrics.record_decode_step(n / self.cfg.decode_slots, dur)
        if self.trace is not None:
            self.trace.complete("decode_step", t * 1e6, dur * 1e6,
                                cat="serve", tid=200 + idx,
                                args={"rows": n, "finished": len(finished)})
            self.trace.counter("serve_occupancy",
                               n / self.cfg.decode_slots)
        self._push(end, "decode_step", idx)

    # ------------------------------------------------------------------ #
    def _report(self, requests: Sequence[Request]) -> ServeReport:
        done = self._completed
        # no completions → latency stats are *missing* (NaN), not 0.0: a
        # fully-overloaded run must not report a perfect p99 (row() maps
        # NaN to None so JSON consumers see them as absent).
        nan = float("nan")
        lat = np.array([r.latency_s for r in done]) if done else None
        ttft = np.array([r.ttft_s for r in done if r.ttft_s >= 0])
        makespan = max((r.finish_s for r in done), default=0.0)
        n_slo = sum(r.slo_met for r in done)
        m = self.metrics
        return ServeReport(
            policy=getattr(self.admission, "name", "custom"),
            n_requests=len(requests),
            n_completed=len(done),
            n_slo_met=n_slo,
            makespan_s=makespan,
            goodput_rps=n_slo / max(makespan, 1e-12),
            throughput_rps=len(done) / max(makespan, 1e-12),
            p50_latency_s=float(np.quantile(lat, 0.5)) if lat is not None else nan,
            p99_latency_s=float(np.quantile(lat, 0.99)) if lat is not None else nan,
            mean_ttft_s=float(ttft.mean()) if len(ttft) else nan,
            mean_queue_depth=m.queue_depth.mean() if m else nan,
            mean_occupancy=m.batch_occupancy.mean() if m else nan,
            n_prefill_batches=m.n_prefill_batches if m else 0,
            n_decode_steps=m.n_decode_steps if m else 0,
            n_drift_events=self.n_drift_events,
            n_compiles=self.n_compiles,
        )
