"""Execution backends behind the backend-agnostic serving loop.

PR 6's `ServeEngine` fused *policy* (admission, SLO accounting, the
calibrator/drift/re-price feedback) with *execution* (how long a prefill
batch, KV handoff or decode step actually takes).  This module is the
seam between the two: `ServeEngine` owns the event loop and every policy
decision; an `ExecutionBackend` owns only the physics —

  * `EmulatedBackend` — the PR 6 discrete-event emulation, extracted
    verbatim: durations are perf-model base costs scaled by each
    request's oracle ``true_factor`` plus deterministic padding and
    compile-bucket penalties.  Bit-identical to the pre-refactor engine
    (pinned by the fig19 golden differential test).
  * `RealBackend` (`repro.serve.real`) — jit'd prefill/decode steps on a
    tiny-to-real jax model, compiled per pow2 shape bucket, with
    device-to-device KV cache-row transfer; durations are *measured*
    wall-clock seconds, which is what lets the calibrator/drift loop
    close against silicon instead of the oracle.

The outcome structs carry everything the loop needs to keep its virtual
clock and telemetry: total duration, per-request actual durations (the
calibrator observation stream), per-chunk durations (chunked prefill
interleaves with decode at chunk boundaries) and how many novel compile
buckets the call opened.

>>> PrefillOutcome(1.5, (1.0, 0.5), chunks=(1.5,)).duration_s
1.5
>>> DecodeOutcome(0.25).n_new_shapes
0
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

from repro.data.composer import _pow2
from repro.models.layers.attention import kv_cache_bytes
from repro.serve.request import Request


@dataclass(frozen=True)
class PrefillOutcome:
    """One executed prefill batch.

    ``per_request_actual`` aligns with the batch order and feeds the
    calibrator (`ServeEngine._observe`); ``chunks`` are per-chunk
    durations summing to ``duration_s`` — a single entry means the batch
    ran one-shot and the loop schedules it exactly as PR 6 did."""

    duration_s: float
    per_request_actual: Tuple[float, ...]
    chunks: Tuple[float, ...] = ()
    n_new_shapes: int = 0


@dataclass(frozen=True)
class DecodeOutcome:
    """One continuous-batch decode step across a worker's active rows."""

    duration_s: float
    n_new_shapes: int = 0


class ExecutionBackend:
    """What the serving loop delegates: execution physics, nothing else.

    The loop guarantees the call protocol: ``prefill`` for an admitted
    batch, then ``handoff`` per request, then ``join`` → repeated
    ``decode_step`` → ``release`` on a decode worker.  ``release`` with
    ``park=True`` is a preemption — the backend must preserve the
    request's generation state for a later re-``join``."""

    name = "abstract"
    #: True when decode durations are measurements worth feeding the
    #: calibrator ("decode" cells); the emulation's oracle durations are
    #: already the predictions, so observing them would be circular.
    observes_decode = False

    def prefill(self, worker: int, batch: Sequence[Request],
                s_pad: int) -> PrefillOutcome:
        raise NotImplementedError

    def handoff(self, req: Request) -> float:
        """Move one request's KV state prefill → decode; returns seconds."""
        raise NotImplementedError

    def handoff_s_mean(self) -> float:
        """Rough per-request handoff estimate for admission slack."""
        raise NotImplementedError

    def join(self, worker: int, req: Request) -> None:
        """Request takes a decode slot on ``worker`` (step boundary)."""

    def decode_step(self, worker: int, active: Sequence[Request]) -> DecodeOutcome:
        raise NotImplementedError

    def release(self, worker: int, req: Request, park: bool = False) -> None:
        """Request leaves its slot: finished (``park=False``) or preempted
        (``park=True`` — state must survive for a re-join)."""


class EmulatedBackend(ExecutionBackend):
    """PR 6's discrete-event execution model, verbatim.

    Durations are pure functions of the perf model, each request's oracle
    ``true_factor``, pow2 padding and first-touch compile buckets — the
    float operation *order* below is the pre-refactor engine's, which is
    what keeps fig19 rows byte-equal across the refactor."""

    name = "emulated"

    def __init__(self, pricer, cfg):
        self.pricer = pricer
        self.cfg = cfg
        self._seen_prefill_shapes: set = set()
        self._seen_decode_shapes: set = set()

    # ------------------------------------------------------------------ #
    def _kv_bytes(self, seq_len: int) -> float:
        return kv_cache_bytes(self.pricer.perf.llm.cfg, seq_len,
                              self.cfg.kv_bytes_per_value)

    def prefill(self, worker: int, batch: Sequence[Request],
                s_pad: int) -> PrefillOutcome:
        dur = 0.0
        actuals: List[float] = []
        for r in batch:
            base, _, _ = self.pricer.base(r)
            dur += base * r.true_factor + self.pricer.pad_extra(r, s_pad)
            actuals.append(base * r.true_factor)
        key = (_pow2(len(batch)), s_pad)
        n_new = 0
        if key not in self._seen_prefill_shapes:
            self._seen_prefill_shapes.add(key)
            dur += self.cfg.compile_s
            n_new = 1
        return PrefillOutcome(duration_s=dur, per_request_actual=tuple(actuals),
                              chunks=(dur,), n_new_shapes=n_new)

    def handoff(self, req: Request) -> float:
        _, _, s = self.pricer.base(req)
        return (self._kv_bytes(s) / (self.cfg.kv_bandwidth_gbps * 1e9)
                + self.cfg.kv_latency_s)

    def handoff_s_mean(self) -> float:
        return self._kv_bytes(1024) / (self.cfg.kv_bandwidth_gbps * 1e9) \
            + self.cfg.kv_latency_s

    def decode_step(self, worker: int, active: Sequence[Request]) -> DecodeOutcome:
        n = len(active)
        pad = _pow2(n) / n                 # pow2-bucketed batch occupancy
        dur = 0.0
        for r in active:
            _, _, s = self.pricer.base(r)
            c = s + r.tokens_done
            dur += self.pricer.decode_tok_s(c) * r.true_factor
        dur *= pad
        key = _pow2(n)
        n_new = 0
        if key not in self._seen_decode_shapes:
            self._seen_decode_shapes.add(key)
            dur += self.cfg.compile_s
            n_new = 1
        return DecodeOutcome(duration_s=dur, n_new_shapes=n_new)
