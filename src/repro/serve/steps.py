"""Serving steps: prefill (forward over the prompt) and batched decode.

Decode shapes in the assignment lower `serve_step`: ONE new token against a
KV cache of `seq_len` — the cache arrays are step inputs/outputs so the
dry-run shards them like real serving state.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.common.types import ModelConfig
from repro.models import model as model_lib
from repro.models.model import FwdCtx


def make_prefill_step(cfg: ModelConfig, ctx: Optional[FwdCtx] = None,
                      last_only: bool = True) -> Callable:
    """prefill(params, batch) -> logits.

    Serving prefill only needs the *last* position's logits (next-token
    sampling) — materializing the (B, S, vocab) tensor at 32k × 200k-vocab
    would be tens of GB per chip for no reason.  Encoder-only models
    (`causal=False`) keep the full output (their "prefill" is encoding)."""
    import dataclasses

    ctx = ctx or FwdCtx(mode="prefill", remat=False)
    if last_only and cfg.is_decoder and cfg.has_lm_head:
        ctx = dataclasses.replace(ctx, return_hidden=True)

    def prefill(params, batch):
        if "tokens" in batch:
            out, _, _ = model_lib.forward(params, cfg,
                                          tokens=batch["tokens"],
                                          segment_ids=batch.get("segment_ids"),
                                          ctx=ctx)
        else:
            out, _, _ = model_lib.forward(params, cfg,
                                          embeds=batch["frame_embeds"],
                                          ctx=ctx)
        if ctx.return_hidden:
            from repro.models.layers import embed as embed_lib
            h_last = out[:, -1:]
            if cfg.tie_embeddings or "unembed" not in params:
                return embed_lib.decode(params["embed"], h_last)
            return embed_lib.unembed(params["unembed"], h_last)
        return out

    return prefill


def make_decode_step(cfg: ModelConfig, ctx: Optional[FwdCtx] = None) -> Callable:
    """decode(params, caches, tokens (B,), pos ()) -> (logits, caches)."""
    import dataclasses

    base_ctx = ctx

    def decode(params, caches, tokens, pos):
        ctx = dataclasses.replace(base_ctx, mode="decode", remat=False) \
            if base_ctx is not None else FwdCtx(mode="decode", remat=False)
        logits, new_caches, _ = model_lib.decode_step(params, cfg, tokens,
                                                      caches, pos, ctx=ctx)
        return logits, new_caches

    return decode


def greedy_generate(cfg: ModelConfig, params, prompt, max_new: int,
                    max_len: int, kv_dtype=jnp.float32):
    """Simple batched greedy decoding driver (examples/serving)."""
    B, S = prompt.shape
    caches = model_lib.init_cache(cfg, B, max_len, kv_dtype)
    decode = jax.jit(make_decode_step(cfg))
    tok = prompt[:, 0]
    out = [tok]
    logits = None
    for t in range(S + max_new - 1):
        logits, caches = decode(params, caches, tok, t)
        if t + 1 < S:
            tok = prompt[:, t + 1]
        else:
            tok = jnp.argmax(logits, axis=-1).astype(prompt.dtype)
        out.append(tok)
    return jnp.stack(out, axis=1)
