"""Serving steps: prefill (forward over the prompt) and batched decode.

Decode shapes in the assignment lower `serve_step`: ONE new token against a
KV cache of `seq_len` — the cache arrays are step inputs/outputs so the
dry-run shards them like real serving state.

Continuous batching (repro.serve.engine drives this at emulation scale;
the helpers here are the real-model substrate):

  * the decode batch dimension holds *independent requests* — ``pos`` may
    be a ``(B,)`` array of per-row positions, and each row's attention
    only sees its own cache entries (per-row ``kpos`` validity masks, see
    `repro.models.layers.attention.attend_cache`);
  * requests join/leave the batch only between decode steps:
    `clear_cache_row` resets a vacated row and `merge_cache_row` copies a
    prefilled single-request cache into it (the KV handoff of
    prefill/decode disaggregation);
  * `prefill_into_cache` is the prefill-worker half: one request at its
    exact length (no padding), returning the last-token logits plus the
    cache to hand off.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.common.types import ModelConfig
from repro.models import model as model_lib
from repro.models.model import FwdCtx


def make_prefill_step(cfg: ModelConfig, ctx: Optional[FwdCtx] = None,
                      last_only: bool = True) -> Callable:
    """prefill(params, batch) -> logits.

    Serving prefill only needs the *last* position's logits (next-token
    sampling) — materializing the (B, S, vocab) tensor at 32k × 200k-vocab
    would be tens of GB per chip for no reason.  Encoder-only models
    (`causal=False`) keep the full output (their "prefill" is encoding).

    Batched prompts are right-padded to a shared S; ``batch["lengths"]``
    ((B,) actual prompt lengths) selects each request's *own* last valid
    position — without it row b's "last token" would be padding for every
    request shorter than the batch max."""
    import dataclasses

    ctx = ctx or FwdCtx(mode="prefill", remat=False)
    if last_only and cfg.is_decoder and cfg.has_lm_head:
        ctx = dataclasses.replace(ctx, return_hidden=True)

    def prefill(params, batch):
        if "tokens" in batch:
            out, _, _ = model_lib.forward(params, cfg,
                                          tokens=batch["tokens"],
                                          segment_ids=batch.get("segment_ids"),
                                          ctx=ctx)
        else:
            out, _, _ = model_lib.forward(params, cfg,
                                          embeds=batch["frame_embeds"],
                                          ctx=ctx)
        if ctx.return_hidden:
            from repro.models.layers import embed as embed_lib
            lengths = batch.get("lengths")
            if lengths is not None:
                idx = jnp.clip(jnp.asarray(lengths, jnp.int32) - 1, 0,
                               out.shape[1] - 1)
                h_last = jnp.take_along_axis(out, idx[:, None, None], axis=1)
            else:
                h_last = out[:, -1:]
            if cfg.tie_embeddings or "unembed" not in params:
                return embed_lib.decode(params["embed"], h_last)
            return embed_lib.unembed(params["unembed"], h_last)
        return out

    return prefill


def make_decode_step(cfg: ModelConfig, ctx: Optional[FwdCtx] = None) -> Callable:
    """decode(params, caches, tokens (B,), pos () or (B,)) -> (logits, caches).

    A ``(B,)`` pos array decodes a continuous batch: rows advance their own
    position clocks, so requests at different depths share one step."""
    import dataclasses

    base_ctx = ctx

    def decode(params, caches, tokens, pos):
        ctx = dataclasses.replace(base_ctx, mode="decode", remat=False) \
            if base_ctx is not None else FwdCtx(mode="decode", remat=False)
        logits, new_caches, _ = model_lib.decode_step(params, cfg, tokens,
                                                      caches, pos, ctx=ctx)
        return logits, new_caches

    return decode


def greedy_generate(cfg: ModelConfig, params, prompt, max_new: int,
                    max_len: int, kv_dtype=jnp.float32):
    """Simple batched greedy decoding driver (examples/serving)."""
    B, S = prompt.shape
    caches = model_lib.init_cache(cfg, B, max_len, kv_dtype)
    decode = jax.jit(make_decode_step(cfg))
    tok = prompt[:, 0]
    out = [tok]
    logits = None
    for t in range(S + max_new - 1):
        logits, caches = decode(params, caches, tok, t)
        if t + 1 < S:
            tok = prompt[:, t + 1]
        else:
            tok = jnp.argmax(logits, axis=-1).astype(prompt.dtype)
        out.append(tok)
    return jnp.stack(out, axis=1)


# --------------------------------------------------------------------------- #
# Disaggregated prefill/decode: KV handoff between worker pools
# --------------------------------------------------------------------------- #
def prefill_into_cache(cfg: ModelConfig, params, prompt, max_len: int,
                       kv_dtype=jnp.float32, ctx: Optional[FwdCtx] = None):
    """Prefill-worker step: run one request's prompt (B, S) — typically
    B = 1, exact length, no padding — through the cached decode path,
    returning ``(last_logits (B, vocab), caches)``.

    The returned cache is the KV state to hand off to a decode worker
    (`merge_cache_row`); the logits sample the first generated token.
    Teacher-forcing through `decode_step` keeps prefill and decode on the
    *same* numerical path, which is what makes the handoff bit-exact
    (tests/test_serve_engine.py pins continued decode against a request
    that never left its private cache)."""
    B, S = prompt.shape
    caches = model_lib.init_cache(cfg, B, max_len, kv_dtype)
    decode = jax.jit(make_decode_step(cfg, ctx))
    logits = None
    for t in range(S):
        logits, caches = decode(params, caches, prompt[:, t], t)
    return logits, caches


def pow2_chunks(length: int, chunk: int) -> list:
    """Decompose a prompt length into a bounded set of chunk sizes: full
    ``chunk``-token blocks, then a descending power-of-two decomposition
    of the remainder.  Any length therefore compiles at most
    ``1 + log2(chunk)`` distinct chunk shapes ({chunk} ∪ {pow2 < chunk})
    — the chunked-prefill analogue of the engine's pow2 batch buckets.

    >>> pow2_chunks(45, 16)
    [16, 16, 8, 4, 1]
    >>> sum(pow2_chunks(45, 16))
    45
    """
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    out = []
    rem = int(length)
    while rem >= chunk:
        out.append(chunk)
        rem -= chunk
    tail = []
    p = 1
    while rem:
        if rem & p:
            tail.append(p)
            rem -= p
        p <<= 1
    out.extend(reversed(tail))
    return out


# one jitted chunk-scan per model config; jax's own jit cache handles the
# per-(B, chunk_len, cache) shape specializations under it
_CHUNK_FNS: dict = {}


def _chunk_scan_fn(cfg: ModelConfig) -> Callable:
    fn = _CHUNK_FNS.get(cfg)
    if fn is not None:
        return fn

    def chunk_step(params, caches, toks, pos0):
        # toks: (B, clen); pos0: scalar int32 (dynamic — offsets don't
        # recompile).  Teacher-force the chunk through decode_step via
        # lax.scan: same numerical path as one-shot prefill_into_cache.
        def body(c, xs):
            tok_t, p_t = xs
            logits, c2, _ = model_lib.decode_step(params, cfg, tok_t, c, p_t)
            return c2, logits

        steps = toks.shape[1]
        xs = (jnp.moveaxis(toks, 1, 0),
              pos0 + jnp.arange(steps, dtype=jnp.int32))
        caches, logits_seq = jax.lax.scan(body, caches, xs)
        return logits_seq[-1], caches

    fn = _CHUNK_FNS[cfg] = jax.jit(chunk_step)
    return fn


def prefill_into_cache_chunked(cfg: ModelConfig, params, prompt,
                               max_len: int, kv_dtype=jnp.float32,
                               chunk: int = 16):
    """`prefill_into_cache`, split into `pow2_chunks`-sized jitted scans.

    Token-identical to the one-shot version (same per-token decode path,
    pinned by tests/test_serve_backend.py) but each chunk returns to the
    caller, so a serving loop can interleave decode steps with a long
    prompt's prefill instead of stalling behind it.  Returns
    ``(last_logits (B, vocab), caches)``."""
    B, S = prompt.shape
    caches = model_lib.init_cache(cfg, B, max_len, kv_dtype)
    fn = _chunk_scan_fn(cfg)
    toks = jnp.asarray(prompt, jnp.int32)
    logits, pos0 = None, 0
    for clen in pow2_chunks(S, chunk):
        logits, caches = fn(params, caches, toks[:, pos0:pos0 + clen],
                            jnp.int32(pos0))
        pos0 += clen
    return logits, caches


def extract_cache_row(caches, row: int):
    """Inverse of `merge_cache_row`: slice batch row ``row`` out of a
    stacked cache pytree as a B=1 cache — the KV state that leaves with a
    preempted request (decode-slot preemption parks it; a later re-join
    merges it back) or rides a device-to-device handoff."""
    return jax.tree.map(lambda a: a[:, row:row + 1], caches)


def clear_cache_row(caches, row: int):
    """Reset batch row ``row`` of a stacked cache pytree to the fresh-init
    state (zeros for KV/SSM state, −1 for ``kpos`` validity) — called when
    a request leaves the continuous batch so the next occupant never sees
    its predecessor's entries.  Leaf layout: (n_blocks, B, ...)."""
    def reset(a):
        fill = -1 if jnp.issubdtype(a.dtype, jnp.integer) else 0
        return a.at[:, row].set(fill)

    return jax.tree.map(reset, caches)


def merge_cache_row(dst, src, row: int, src_row: int = 0):
    """KV handoff: copy request ``src_row`` of a prefill-worker cache into
    batch row ``row`` of a decode-worker cache.

    The source's sequence capacity may be smaller than the destination's
    (prefill caches are sized to the prompt): entries land in the leading
    destination slots, which is exact because slot = pos % C and prefill
    only wrote pos < C_src ≤ C_dst.  Ring-buffer (sliding-window) caches
    clamp both capacities to the window, so their slot maps agree too.
    The row is reset first — stale entries past the source capacity must
    not survive the handoff."""
    def place(d, s):
        s_r = s[:, src_row].astype(d.dtype)
        if d.shape[2:] == s.shape[2:]:
            return d.at[:, row].set(s_r)
        fill = -1 if jnp.issubdtype(d.dtype, jnp.integer) else 0
        d = d.at[:, row].set(fill)
        return d.at[:, row, : s.shape[2]].set(s_r)

    return jax.tree.map(place, dst, src)
