from repro.serve.admission import FIFOAdmission, PrefillPricer, SLOAdmission
from repro.serve.backend import (DecodeOutcome, EmulatedBackend,
                                 ExecutionBackend, PrefillOutcome)
from repro.serve.engine import ServeConfig, ServeEngine, ServeReport
from repro.serve.request import Request, RequestQueue
from repro.serve.steps import (clear_cache_row, extract_cache_row,
                               greedy_generate, make_decode_step,
                               make_prefill_step, merge_cache_row,
                               pow2_chunks, prefill_into_cache,
                               prefill_into_cache_chunked)

__all__ = [
    "FIFOAdmission", "PrefillPricer", "SLOAdmission",
    "DecodeOutcome", "EmulatedBackend", "ExecutionBackend", "PrefillOutcome",
    "ServeConfig", "ServeEngine", "ServeReport",
    "Request", "RequestQueue",
    "clear_cache_row", "extract_cache_row", "greedy_generate",
    "make_decode_step", "make_prefill_step", "merge_cache_row",
    "pow2_chunks", "prefill_into_cache", "prefill_into_cache_chunked",
]


def __getattr__(name):
    # RealBackend imports jax device plumbing; keep it lazy so the
    # emulation-only path stays importable without touching device state
    if name == "RealBackend":
        from repro.serve.real import RealBackend
        return RealBackend
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
