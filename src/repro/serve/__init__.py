from repro.serve.admission import FIFOAdmission, PrefillPricer, SLOAdmission
from repro.serve.engine import ServeConfig, ServeEngine, ServeReport
from repro.serve.request import Request, RequestQueue
from repro.serve.steps import (clear_cache_row, greedy_generate,
                               make_decode_step, make_prefill_step,
                               merge_cache_row, prefill_into_cache)

__all__ = [
    "FIFOAdmission", "PrefillPricer", "SLOAdmission",
    "ServeConfig", "ServeEngine", "ServeReport",
    "Request", "RequestQueue",
    "clear_cache_row", "greedy_generate", "make_decode_step",
    "make_prefill_step", "merge_cache_row", "prefill_into_cache",
]
