"""Admission policies: which queued requests form the next prefill batch.

This is the serving-side counterpart of `repro.data.composer` — the same
insight (a data-blind draw mixes fat multimodal items into thin ones and
pays for the mix) applied to a latency-bounded queue instead of a
staleness-bounded reorder window:

  * deadline slack replaces ``max_staleness``: each pending request's
    slack is measured in *expected batch durations* and the shared
    `edf_forced_count` reservation force-admits the requests whose
    deadlines would otherwise become infeasible — the composer's
    no-starvation argument carries over verbatim (slack is monotonically
    non-increasing in time, so every request is eventually forced);
  * candidates are `sorted_runs` over the non-forced pool, keyed by LLM
    sequence length — prefill batches are padded to a power-of-two max
    length, so contiguous runs of similar-length requests minimize
    padding waste exactly as homogeneous compose windows minimize
    bottleneck skew;
  * scoring is work-normalized (padded batch duration per second of
    useful prefill work), with a `recompile_penalty` for opening a
    (rows, padded-seq) compile bucket no earlier batch paid for.

`PrefillPricer` is the shared pricing oracle: predicted base durations
come from the profiled `PerfModel` (`e_dur`/`l_dur`, the same duration
path training scheduling uses) refined by the `OnlineCalibrator`, and are
memoized per request — re-priced only when drift flushes the memo
(`flush()`), which is the engine's "drift-triggered re-estimation".

>>> from repro.data.composer import edf_forced_count
>>> edf_forced_count([0, 3, 3, 3], per_step=4)   # one request is due now
1
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.profiling.flops import module_flops
from repro.data.composer import _pow2, edf_forced_count, sorted_runs

from repro.serve.request import Request


class PrefillPricer:
    """Predicted serving costs under the profiled perf model.

    ``price()`` (calibrator-refined base prefill cost) is memoized by the
    request's shape key (b(d), s(d)) — base durations and calibration
    corrections are pure functions of those shapes, so the memo is exact.
    A shape is priced once when admission first scores it and re-priced
    only after ``flush()``.  The memo is deliberate — it makes drift
    events *mean* something mechanically (stale prices persist until the
    drift detector fires) and keeps admission scoring O(new shapes) per
    batch.
    """

    def __init__(self, perf, tokens_per_media_item: int, *, tp: int = 1,
                 calibrator=None):
        self.perf = perf
        self.tpm = tokens_per_media_item
        self.tp = int(tp)
        self.calibrator = calibrator
        self._base: Dict[Tuple[int, int], Tuple[float, float, int]] = {}
        self._lpad: Dict[int, float] = {}
        self._price: Dict[Tuple[int, int], float] = {}
        self._decode_fit: Dict[int, float] = {}   # pow2 ctx bucket -> ratio
        self.n_flushes = 0
        # decode FLOPs are affine in the cache length (one token against a
        # kv of c): fit fl(c) = fl0 + fl1*c from two exact evaluations
        f1 = module_flops(perf.llm.cfg, 1, 1, mode="decode", cache_len=1.0)
        f2 = module_flops(perf.llm.cfg, 1, 1, mode="decode", cache_len=2.0)
        self._fl1 = f2.total - f1.total
        self._fl0 = f1.total - self._fl1

    # ------------------------------------------------------------------ #
    def shapes(self, req: Request) -> Tuple[int, int]:
        """(encoder effective batch, LLM seq len) — §3.2.2's (b(d), s(d))."""
        return req.item.encoder_batch(), req.item.llm_seq_len(self.tpm)

    def base(self, req: Request) -> Tuple[float, float, int]:
        """(total base prefill s, LLM part s, seq len) — pure perf model,
        calibration-free (the oracle scales this to produce actuals)."""
        b, s = self.shapes(req)
        hit = self._base.get((b, s))
        if hit is None:
            e = self.perf.e_dur(b, self.tp, "prefill")
            l = self.perf.l_dur(s, self.tp, "prefill")
            hit = self._base[(b, s)] = (e + l, l, s)
        return hit

    def l_pad(self, s_pad: int) -> float:
        hit = self._lpad.get(s_pad)
        if hit is None:
            hit = self._lpad[s_pad] = self.perf.l_dur(s_pad, self.tp,
                                                      "prefill")
        return hit

    def pad_extra(self, req: Request, s_pad: int) -> float:
        """Deterministic padding overhead: the LLM prefill runs at the
        batch's padded length, not the request's own."""
        _, l, s = self.base(req)
        return max(self.l_pad(s_pad) - l, 0.0)

    def price(self, req: Request) -> float:
        """Calibrator-refined base prefill cost (memoized, see class doc)."""
        key = self.shapes(req)
        hit = self._price.get(key)
        if hit is None:
            base, _, s = self.base(req)
            hit = base
            if self.calibrator is not None:
                hit = self.calibrator.correct("prefill", s, self.tp, base)
            self._price[key] = hit
        return hit

    def predict(self, req: Request, s_pad: int) -> float:
        """Predicted cost of this request inside a batch padded to s_pad."""
        return self.price(req) + self.pad_extra(req, s_pad)

    # ------------------------------------------------------------------ #
    def decode_tok_base_s(self, cache_len: float) -> float:
        """Raw perf-model one-token decode cost at context `cache_len`
        (affine FLOPs fit / achievable throughput) — calibration-free."""
        fl = self._fl0 + self._fl1 * max(cache_len, 1.0)
        return fl / self.perf.llm.thr_all(max(cache_len, 1.0), self.tp)

    def decode_tok_s(self, cache_len: float) -> float:
        """Predicted one-token decode step cost at context `cache_len`:
        the raw fit refined by the calibrator's "decode" cells.  The
        per-pow2-context-bucket ratio is memoized (`_decode_fit`) exactly
        like prefill prices — stale until ``flush()`` — so a drift fire
        re-estimates *both* halves of the serving cost model.  Without
        decode observations (the emulation never feeds any) the ratio is
        identically 1.0 and this is bit-equal to the raw fit."""
        base = self.decode_tok_base_s(cache_len)
        if self.calibrator is None:
            return base
        b = _pow2(int(max(cache_len, 1.0)))
        ratio = self._decode_fit.get(b)
        if ratio is None:
            ratio = self._decode_fit[b] = self.calibrator.correct(
                "decode", float(b), self.tp, 1.0)
        return base * ratio

    def decode_estimate(self, req: Request) -> float:
        """Expected total decode time: max_new steps at the mean context."""
        _, _, s = self.base(req)
        mid = s + req.max_new_tokens / 2.0
        return req.max_new_tokens * self.decode_tok_s(mid)

    def flush(self) -> None:
        """Drop memoized *prices* — prefill prices AND decode-step
        token-cost fits — so both are re-estimated under the post-drift
        calibration (a drift fire that re-priced prefill but kept stale
        decode fits would mis-score every decode_estimate).  Base
        durations are calibration-free and stay cached."""
        self._price.clear()
        self._decode_fit.clear()
        self.n_flushes += 1


class FIFOAdmission:
    """Data-blind baseline: admit the oldest pending requests."""

    name = "fifo"

    def select(self, pending: Sequence[Request], now_s: float,
               max_batch: int) -> List[Request]:
        return list(pending[:max_batch])

    def note_batch(self, duration_s: float) -> None:
        pass


class SLOAdmission:
    """Latency-SLO-bounded lookahead admission (data-aware)."""

    name = "slo"

    def __init__(self, pricer: PrefillPricer, *, handoff_s: float = 0.0,
                 recompile_penalty: float = 0.15, max_candidates: int = 32,
                 quantum_alpha: float = 0.25, starvation_horizon: int = 8):
        self.pricer = pricer
        self.handoff_s = handoff_s       # engine's mean KV-handoff estimate
        self.recompile_penalty = recompile_penalty
        self.max_candidates = max_candidates
        self.quantum_alpha = quantum_alpha
        # admission rounds a deadline-infeasible ("hopeless") request may
        # wait before it is force-admitted anyway (no-starvation backstop)
        self.starvation_horizon = starvation_horizon
        self._quantum: Optional[float] = None   # EWMA batch duration
        self._seen_shapes: set = set()
        self.last_n_forced = 0
        self.last_n_candidates = 0

    # ------------------------------------------------------------------ #
    def note_batch(self, duration_s: float) -> None:
        """Observed prefill batch duration — the slack quantum (how many
        seconds one admission round retires)."""
        if self._quantum is None:
            self._quantum = duration_s
        else:
            self._quantum += self.quantum_alpha * (duration_s - self._quantum)

    def _batch_score(self, reqs: List[Request]) -> Tuple[float, tuple]:
        s_pad = _pow2(max(self.pricer.base(r)[2] for r in reqs))
        dur = sum(self.pricer.predict(r, s_pad) for r in reqs)
        work = sum(self.pricer.price(r) for r in reqs)
        score = dur / max(work, 1e-12)
        key = (_pow2(len(reqs)), s_pad)
        if self.recompile_penalty > 0.0 and key not in self._seen_shapes:
            score *= 1.0 + self.recompile_penalty
        return score, key

    def select(self, pending: Sequence[Request], now_s: float,
               max_batch: int) -> List[Request]:
        if not pending:
            return []
        n = min(max_batch, len(pending))
        p = self.pricer
        # per-request slack, in units of expected admission rounds
        remaining = np.array([p.predict(r, _pow2(p.base(r)[2]))
                              + self.handoff_s + p.decode_estimate(r)
                              for r in pending])
        quantum = self._quantum if self._quantum else float(
            np.mean([p.price(r) for r in pending[:n]])) * n
        quantum = max(quantum, 1e-9)
        slack_s = np.array([r.slack_s(now_s, w)
                            for r, w in zip(pending, remaining)])
        # Deadline-feasible requests carry EDF slack in units of admission
        # rounds.  Infeasible ("hopeless") requests are *excluded* from the
        # deadline reservation — forcing them would spend the batch on
        # requests that miss their SLO either way, which is exactly how a
        # saturated queue degenerates to FIFO — and instead age toward an
        # admission-round starvation horizon, so slack is monotonically
        # non-increasing in time for every request and no request starves.
        waited_b = np.floor(np.array([now_s - r.arrival_s
                                      for r in pending]) / quantum)
        slack_b = np.where(
            slack_s >= 0.0,
            np.floor(slack_s / quantum),
            np.maximum(self.starvation_horizon - waited_b, 0.0)).astype(int)
        need = edf_forced_count(slack_b, n)
        # Aging quota: at most half the batch is deadline/age-forced.  An
        # uncapped reservation floods every batch under sustained overload
        # (all slack clamps to 0) and the policy degenerates to FIFO right
        # where reordering matters most; with the cap, every batch keeps
        # homogeneous-run seats (throughput) while the quota still drains
        # forced requests at a strictly positive rate (no starvation —
        # forced order is by slack then arrival, so an aged request's
        # position in the forced queue is monotonically non-increasing).
        forced_cap = max(1, n // 2)
        order = np.argsort(slack_b, kind="stable")       # ties: arrival
        forced = sorted(int(i) for i in order[:min(need, forced_cap)])
        forced_set = set(forced)
        pool = [i for i in range(len(pending)) if i not in forced_set]
        k = n - len(forced)
        # candidate 0 is the FIFO draw, so ties resolve toward FIFO and
        # the policy degenerates gracefully when all prices agree
        cands: List[Tuple[int, ...]] = [tuple(forced) + tuple(pool[:k])]
        if k > 0:
            seqs = [float(p.base(pending[i])[2]) for i in pool]
            for run in sorted_runs(seqs, k, self.max_candidates):
                cands.append(tuple(forced) + tuple(pool[j] for j in run))
        best, best_score, best_key = None, float("inf"), ()
        for c in cands:
            reqs = [pending[i] for i in c]
            score, key = self._batch_score(reqs)
            if score < best_score:
                best, best_score, best_key = c, score, key
        self._seen_shapes.add(best_key)
        self.last_n_forced = len(forced)
        self.last_n_candidates = len(cands)
        return [pending[i] for i in best]
