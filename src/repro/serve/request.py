"""Serving request lifecycle and admission queue.

A request is a `DataItem` (the same two shape dimensions DFLOP's training
models are keyed on — encoder media items and LLM sequence length) plus
serving state: arrival time, a latency SLO, a decode budget, and the
timestamps the engine stamps as the request moves through

    QUEUED -> PREFILLING -> HANDOFF -> DECODING -> DONE

All times are *virtual* seconds on the emulated cluster clock (the engine
is a discrete-event emulation, cf. `repro.core.pipeline.simulator`); the
trace recorder renders them as microseconds.

>>> from repro.data.items import DataItem
>>> r = Request(item=DataItem(1, 128, "single_image", 0), arrival_s=0.0,
...             slo_s=2.0, max_new_tokens=4)
>>> q = RequestQueue()
>>> q.push(r); q.depth
1
>>> q.pop([r]); q.depth
0
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

from repro.data.items import DataItem

QUEUED = "queued"
PREFILLING = "prefilling"
HANDOFF = "handoff"
DECODING = "decoding"
DONE = "done"


@dataclass
class Request:
    """One inference request on the emulated cluster.

    ``true_factor`` is the oracle's per-request heterogeneity multiplier
    (modality bias x sampled noise): *actual* durations are predicted base
    durations scaled by it.  The load generator draws it per request id so
    two policies replayed on the same stream face bit-identical ground
    truth; the engine never reads it for admission decisions — only the
    calibrator may learn its per-shape-bucket mean from observations.
    """

    item: DataItem
    arrival_s: float
    slo_s: float                      # end-to-end deadline over arrival
    max_new_tokens: int
    true_factor: float = 1.0

    status: str = QUEUED
    admit_s: float = -1.0             # admission into a prefill batch
    prefill_done_s: float = -1.0
    handoff_done_s: float = -1.0
    first_token_s: float = -1.0
    finish_s: float = -1.0
    tokens_done: int = 0
    decode_worker: int = -1
    n_preempted: int = 0              # decode-slot evictions (SLO rescue)
    #: generated token ids — filled by real backends only (the emulation
    #: never materializes tokens); used to pin continuous-batch decode
    #: token-identical to a solo run of the same prompt
    generated: List[int] = field(default_factory=list)

    @property
    def deadline_s(self) -> float:
        return self.arrival_s + self.slo_s

    @property
    def latency_s(self) -> float:
        """End-to-end latency (finish − arrival); −1 while in flight."""
        return self.finish_s - self.arrival_s if self.finish_s >= 0 else -1.0

    @property
    def ttft_s(self) -> float:
        """Time to first decoded token; −1 while pre-decode."""
        return (self.first_token_s - self.arrival_s
                if self.first_token_s >= 0 else -1.0)

    @property
    def slo_met(self) -> bool:
        return 0 <= self.latency_s <= self.slo_s

    def slack_s(self, now_s: float, remaining_work_s: float = 0.0) -> float:
        """Seconds of schedule slack left before the deadline becomes
        infeasible, after accounting for the work the request still needs
        (predicted prefill + handoff + decode).  Negative = already late."""
        return self.deadline_s - now_s - remaining_work_s


class RequestQueue:
    """Arrival-ordered admission queue.

    Arrival order is the only structure the queue itself imposes — FIFO
    admission takes a prefix, data-aware admission reorders a *view* of
    the pending list (never the queue), so the no-starvation property is
    enforced by the admission policy's EDF reservation, not by the
    container (see `repro.serve.admission`).
    """

    def __init__(self):
        self._pending: List[Request] = []
        self.n_arrived = 0

    # ------------------------------------------------------------------ #
    @property
    def depth(self) -> int:
        return len(self._pending)

    @property
    def pending(self) -> List[Request]:
        """Live view, arrival-ordered; callers must not mutate."""
        return self._pending

    def push(self, req: Request) -> None:
        req.status = QUEUED
        self._pending.append(req)
        self.n_arrived += 1

    def pop(self, batch: Sequence[Request]) -> None:
        """Remove an admitted batch (set semantics: order-independent)."""
        chosen = set(id(r) for r in batch)
        self._pending = [r for r in self._pending if id(r) not in chosen]

    def oldest_wait_s(self, now_s: float) -> float:
        return now_s - self._pending[0].arrival_s if self._pending else 0.0
