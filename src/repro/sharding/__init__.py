from repro.sharding.partition import (
    AxisAssignment,
    ModuleAssignment,
    sanitize_spec,
    param_specs,
    opt_state_specs,
    named,
    activation_spec,
    tokens_spec,
)

__all__ = [
    "AxisAssignment",
    "ModuleAssignment",
    "sanitize_spec",
    "param_specs",
    "opt_state_specs",
    "named",
    "activation_spec",
    "tokens_spec",
]
