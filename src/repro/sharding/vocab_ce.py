"""Vocab-parallel cross-entropy (Megatron-style) via shard_map.

For 100k–256k vocabularies, letting the SPMD partitioner choose a strategy
for the (tokens, vocab) logits + CE + dW backward is fragile: it tends to
materialize a replicated fp32 logits tensor (8+ GB at 128k tokens).  This
kernel-level formulation makes the efficient strategy explicit:

  * every model-axis shard computes logits for its vocab slice only
    (local einsum, no comm);
  * softmax statistics reduce with pmax/psum over the model axis
    (tokens-sized messages, not logits-sized);
  * the gold logit is found by local one-hot masking against the shard's
    vocab offset, then psum;
  * shard_map's transpose rules produce the partial-dW + psum(data) backward
    (the 131 MB all-reduce, never an 8 GB all-gather).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional, Tuple

import jax
from repro.common import compat
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def make_vocab_parallel_ce(mesh: Mesh, batch_axes: Tuple[str, ...],
                           model_axes: Tuple[str, ...], vocab: int,
                           tied: bool) -> Optional[Callable]:
    """Returns ce(w, h, labels) -> mean NLL, or None if not applicable.

    w: (vocab, d) when tied (embedding table) else (d, vocab).
    h: (B, S, d) replicated over model axes, batch-sharded over batch_axes.
    labels: (B, S) int32, -1 = ignore.
    """
    if not model_axes:
        return None
    msize = 1
    for a in model_axes:
        msize *= mesh.shape[a]
    if msize == 1 or vocab % msize != 0:
        return None
    maxis = model_axes[0] if len(model_axes) == 1 else model_axes
    v_local = vocab // msize

    # tokens (B·S flattened) shard over the batch axes: divisibility holds
    # whenever B·S is a multiple of the dp degree (true even at batch 1 for
    # non-trivial sequence lengths)
    w_spec = P(tuple(model_axes), None) if tied else P(None, tuple(model_axes))
    h_spec = P(tuple(batch_axes) or None, None)
    l_spec = P(tuple(batch_axes) or None)

    def local_fn(w_l, h_l, labels_l):
        h32 = h_l.astype(jnp.float32)
        if tied:
            logits = jnp.einsum("td,vd->tv", h32, w_l.astype(jnp.float32))
        else:
            logits = jnp.einsum("td,dv->tv", h32, w_l.astype(jnp.float32))
        # global softmax statistics over the sharded vocab.  The max is a
        # gradient-free stabilizer; pmax has no JVP rule, so gather the
        # per-shard maxima (tokens-sized) and reduce locally instead.
        local_max = jnp.max(jax.lax.stop_gradient(logits), axis=-1)
        mx = jnp.max(jax.lax.all_gather(local_max, maxis), axis=0)  # (B, S)
        ex_sum = jax.lax.psum(
            jnp.sum(jnp.exp(logits - mx[..., None]), axis=-1), maxis)
        lse = jnp.log(ex_sum) + mx
        # gold logit: local one-hot against this shard's vocab offset
        shard = jax.lax.axis_index(model_axes[0])
        for a in model_axes[1:]:
            shard = shard * mesh.shape[a] + jax.lax.axis_index(a)
        offset = shard * v_local
        ids = jnp.clip(labels_l, 0) - offset
        onehot = ids[..., None] == jax.lax.broadcasted_iota(
            jnp.int32, labels_l.shape + (v_local,), labels_l.ndim)
        gold = jax.lax.psum(
            jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1), maxis)
        nll = lse - gold
        mask = (labels_l >= 0).astype(jnp.float32)
        loss_sum = jnp.sum(nll * mask)
        count = jnp.sum(mask)
        # reduce over every mesh axis so the scalar is replicated
        for a in batch_axes:
            loss_sum = jax.lax.psum(loss_sum, a)
            count = jax.lax.psum(count, a)
        # model-axis contributions are already identical (post-psum)
        return loss_sum / jnp.maximum(count, 1.0)

    sm = compat.shard_map(local_fn, mesh=mesh,
                       in_specs=(w_spec, h_spec, l_spec),
                       out_specs=P(), check_vma=False)

    def ce(w, h, labels):
        d = h.shape[-1]
        return sm(w, h.reshape(-1, d), labels.reshape(-1))

    return ce
