"""Logical-axis sharding: per-module axis assignments -> PartitionSpecs.

This is the TPU-native realization of DFLOP's "independent 3D parallelism
per module" (paper §4): instead of disjoint NCCL process groups, each module
(modality encoder vs. LLM) gets its own *axis assignment* — which mesh axes
shard the batch dimension and which shard tensor dimensions (heads / ffn /
experts / vocab).  The Data-aware 3D Parallelism Optimizer searches over
these assignments; the XLA SPMD partitioner emits the boundary collectives
that the paper's Inter-model Communicator performs explicitly.

Example (mesh ("data","model") = (16,16)):
    encoder: AxisAssignment(batch=("data","model"), tensor=())   # E_dp=256, E_tp=1
    llm:     AxisAssignment(batch=("data",), tensor=("model",))  # L_dp=16,  L_tp=16
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.pytree import tree_map_with_path_str


@dataclass(frozen=True)
class AxisAssignment:
    """Mesh-axis roles for one module."""

    batch: Tuple[str, ...] = ("data",)
    tensor: Tuple[str, ...] = ("model",)
    # Optional ZeRO axes: optimizer state (and, with fsdp=True, params) get an
    # extra sharding over these axes on their largest replicated dim.
    zero: Tuple[str, ...] = ()
    fsdp: bool = False
    # path regexes kept OUT of FSDP (resident, tensor-sharded only); vocab
    # tables are always excluded (see param_specs)
    fsdp_exclude: Tuple[str, ...] = ()

    def dp(self, mesh: Mesh) -> int:
        return int(np.prod([mesh.shape[a] for a in self.batch], initial=1))

    def tp(self, mesh: Mesh) -> int:
        return int(np.prod([mesh.shape[a] for a in self.tensor], initial=1))


@dataclass(frozen=True)
class ModuleAssignment:
    """Per-module assignments for an MLLM (encoder may differ from LLM)."""

    llm: AxisAssignment
    encoder: Optional[AxisAssignment] = None

    def for_module(self, module: str) -> AxisAssignment:
        if module == "encoder" and self.encoder is not None:
            return self.encoder
        return self.llm


# --------------------------------------------------------------------------- #
# Spec sanitation
# --------------------------------------------------------------------------- #
def _axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes], initial=1))


def sanitize_spec(spec: P, shape: Sequence[int], mesh: Mesh) -> P:
    """Drop shardings that do not divide the dim (replicate instead)."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        size = _axes_size(mesh, entry)
        if size > 1 and (i >= len(shape) or shape[i] % size != 0):
            # keep the LARGEST contiguous subsequence of the axes tuple that
            # still divides the dim (e.g. batch 16 over ("pod","data")=(2,16)
            # must keep ("data",)=16, not the ("pod",)=2 prefix)
            if isinstance(entry, tuple):
                best, best_size = None, 1
                n_ax = len(entry)
                for lo in range(n_ax):
                    for hi in range(lo + 1, n_ax + 1):
                        sub = entry[lo:hi]
                        ssize = _axes_size(mesh, sub)
                        if shape[i] % ssize == 0 and ssize > best_size:
                            best, best_size = sub, ssize
                out.append(best)
            else:
                out.append(None)
        else:
            out.append(entry)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


# --------------------------------------------------------------------------- #
# Parameter rules (path-pattern based, maxtext-style)
# --------------------------------------------------------------------------- #
# Each rule: (regex on param path, spec builder given (assignment, ndim)).
# Specs are written for the *unstacked* layer shape; scanned-layer stacking
# prepends a None (layer) dim, handled by `_with_layer_dims`.
def _t(a: AxisAssignment):
    return a.tensor if a.tensor else None


_RULES = [
    # embeddings / unembedding: shard vocab over tensor axes
    (r"(^|/)embed/w$", lambda a: P(_t(a), None)),
    (r"(^|/)unembed/w$", lambda a: P(None, _t(a))),
    (r"(^|/)pos_embed/w$", lambda a: P(None, None)),
    # attention
    (r"/attn/wq$", lambda a: P(None, _t(a), None)),
    (r"/attn/wk$", lambda a: P(None, _t(a), None)),
    (r"/attn/wv$", lambda a: P(None, _t(a), None)),
    (r"/attn/wo$", lambda a: P(_t(a), None, None)),
    # dense ffn
    (r"/ffn/w_gate$", lambda a: P(None, _t(a))),
    (r"/ffn/w_up$", lambda a: P(None, _t(a))),
    (r"/ffn/w_down$", lambda a: P(_t(a), None)),
    # MoE: expert dim over tensor axes when divisible (expert parallelism),
    # sanitize_spec falls back to ffn sharding via the trailing entries.
    (r"/moe/w_gate$", lambda a: P(_t(a), None, None)),
    (r"/moe/w_up$", lambda a: P(_t(a), None, None)),
    (r"/moe/w_down$", lambda a: P(_t(a), None, None)),
    (r"/moe/router$", lambda a: P(None, None)),
    # mamba
    (r"/mamba/in_proj$", lambda a: P(None, _t(a))),
    (r"/mamba/out_proj$", lambda a: P(_t(a), None)),
    (r"/mamba/conv_w$", lambda a: P(_t(a), None)),
    (r"/mamba/conv_b$", lambda a: P(_t(a))),
    (r"/mamba/x_proj$", lambda a: P(_t(a), None)),
    (r"/mamba/dt_proj$", lambda a: P(None, _t(a))),
    (r"/mamba/dt_bias$", lambda a: P(_t(a))),
    (r"/mamba/A_log$", lambda a: P(_t(a), None)),
    (r"/mamba/D$", lambda a: P(_t(a))),
    # rwkv6
    (r"/rwkv/wo$", lambda a: P(_t(a), None)),
    (r"/rwkv/w[rkvg]$", lambda a: P(None, _t(a))),
    (r"/rwkv/cm_wk$", lambda a: P(None, _t(a))),
    (r"/rwkv/cm_wv$", lambda a: P(_t(a), None)),
    (r"/rwkv/cm_wr$", lambda a: P(None, _t(a))),
    (r"/rwkv/time_first$", lambda a: P(_t(a), None)),
    (r"/rwkv/(decay_)?lora_[ab]$", lambda a: P(None, None)),
    (r"/rwkv/(mix_|decay_base)", lambda a: P(None)),
    # connector (MLLM projector)
    (r"/connector/w\d$", lambda a: P(None, None)),
    # norms / biases / scalars: replicated
    (r".*", lambda a: None),
]


def _spec_for_path(path: str, assignment: AxisAssignment) -> Optional[P]:
    for pat, builder in _RULES:
        if re.search(pat, path):
            return builder(assignment)
    return None


def _module_of(path: str) -> str:
    if path.startswith("encoder/") or "/encoder/" in path:
        return "encoder"
    return "llm"


def param_specs(params: Any, assignment: ModuleAssignment, mesh: Mesh) -> Any:
    """PartitionSpec pytree matching `params` (handles scanned layer dims)."""

    def rule(path: str, leaf) -> P:
        a = assignment.for_module(_module_of(path))
        spec = _spec_for_path(path, a)
        if spec is None:
            spec = P()
        # MoE expert weights: expert-dim sharding when E divides the tensor
        # axes, else shard the FFN dim (granite 40e / mixtral 8e vs a
        # 16-wide model axis — DESIGN.md §4).
        m = re.search(r"/moe/(w_gate|w_up|w_down)$", path)
        if m and a.tensor:
            tsize = _axes_size(mesh, tuple(a.tensor))
            E = leaf.shape[-3]
            if E % tsize == 0:
                spec = P(tuple(a.tensor), None, None)
            elif m.group(1) == "w_down":       # (E, ff, d)
                spec = P(None, tuple(a.tensor), None)
            else:                              # (E, d, ff)
                spec = P(None, None, tuple(a.tensor))
        # scanned layers stack params with 1–2 leading dims (block, layer);
        # align the spec to the *trailing* dims of the leaf.
        ndim = leaf.ndim if hasattr(leaf, "ndim") else len(leaf.shape)
        pad = ndim - len(spec)
        if pad > 0:
            spec = P(*([None] * pad), *spec)
        elif pad < 0:
            spec = P(*list(spec)[-ndim:] if ndim else [])
        spec = sanitize_spec(spec, leaf.shape, mesh)
        # FSDP-shard everything except the (un)embedding tables: their
        # gradient is a contraction over *all* tokens, and a ZeRO-sharded
        # weight forces SPMD to all-gather the (tokens, vocab) cotangent —
        # vocab-sharded-only weights psum a small partial dW instead.
        is_vocab_table = re.search(r"(^|/)(embed|unembed)/w$", path) is not None
        excluded = is_vocab_table or any(re.search(p, path)
                                         for p in a.fsdp_exclude)
        if a.fsdp and a.zero and not excluded:
            spec = _with_zero(spec, leaf.shape, mesh, a.zero)
        return spec

    return tree_map_with_path_str(rule, params)


def _with_zero(spec: P, shape: Sequence[int], mesh: Mesh, zero_axes: Tuple[str, ...]) -> P:
    """Add ZeRO axes to the largest dim that is unsharded and divisible."""
    if not zero_axes:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for e in entries:
        if e is None:
            continue
        used.update([e] if isinstance(e, str) else e)
    if used & set(zero_axes):
        return spec          # already ZeRO/FSDP-sharded on these axes
    zsize = _axes_size(mesh, tuple(zero_axes))
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if entries[i] is None and shape[i] % zsize == 0 and shape[i] >= zsize:
            entries[i] = tuple(zero_axes)
            return P(*entries)
    return spec


def opt_state_specs(params: Any, pspecs: Any, assignment: ModuleAssignment,
                    mesh: Mesh) -> Any:
    """Optimizer-moment specs: param specs + ZeRO sharding over `zero` axes."""

    def rule(path_leaf, spec_leaf):
        path, leaf = path_leaf
        a = assignment.for_module(_module_of(path))
        return _with_zero(spec_leaf, leaf.shape, mesh, a.zero)

    from repro.common.pytree import tree_paths

    flat_params = tree_paths(params)
    flat_specs = jax.tree_util.tree_leaves(
        pspecs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_params) == len(flat_specs)
    out_flat = [rule(pl, sl) for pl, sl in zip(flat_params, flat_specs)]
    treedef = jax.tree_util.tree_structure(
        pspecs, is_leaf=lambda x: isinstance(x, P))
    return jax.tree_util.tree_unflatten(treedef, out_flat)


# --------------------------------------------------------------------------- #
# Activation specs
# --------------------------------------------------------------------------- #
def tokens_spec(a: AxisAssignment, extra_dims: int = 1) -> P:
    """(batch, seq, ...) tokens: batch sharded over the module's batch axes."""
    return P(tuple(a.batch) if a.batch else None, *([None] * extra_dims))


def activation_spec(a: AxisAssignment, ndim: int = 3) -> P:
    """(batch, seq, d_model): d replicated; heads shard inside attention."""
    return P(tuple(a.batch) if a.batch else None, *([None] * (ndim - 1)))
