"""deepseek-7b [dense] — 30L d_model=4096 32H (kv=32, MHA) d_ff=11008
vocab=102400, llama-arch.  [arXiv:2401.02954]"""
from repro.common.types import ModelConfig
from repro.configs.common import ArchSpec, register

CFG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab_size=102400,
    activation="swiglu",
    rope_theta=10_000.0,
)

SPEC = register(ArchSpec(
    arch_id="deepseek-7b",
    desc=CFG,
    citation="arXiv:2401.02954 (DeepSeek LLM)",
    notes="Pure full attention: long_500k skipped (quadratic prefill; the "
          "source model has no sliding-window/sparse variant).",
))
