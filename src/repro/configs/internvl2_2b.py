"""internvl2-2b [vlm] — InternViT encoder + InternLM2-1.8b backbone:
24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.  [arXiv:2404.16821]

This is the assigned arch where DFLOP applies in full: a modality encoder
feeding an LLM.  Per the carve-out, the ViT *patchifier* is a stub
(``input_specs`` supplies 1024-dim patch embeddings); the InternViT-300M
transformer (24L d=1024) and the InternLM2 backbone are implemented.
InternVL's pixel-shuffle reduces 1024 patches/image to 256 LLM tokens —
captured by the connector's 4x downsample.
"""
from repro.common.types import MLLMConfig, ModalityStub, ModelConfig
from repro.configs.common import ArchSpec, register

PATCH_EMBED_DIM = 1024
PATCHES_PER_IMAGE = 1024            # 448x448 / 14 -> 32x32 patches
LLM_TOKENS_PER_IMAGE = 256          # pixel-shuffle 4x reduction

ENCODER = ModelConfig(
    name="internvit-300m",
    family="vlm-enc",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=0,
    causal=False,
    use_rope=False,
    activation="gelu",
    input_embed_dim=PATCH_EMBED_DIM,
    has_lm_head=False,
)

LLM = ModelConfig(
    name="internlm2-1.8b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    activation="swiglu",
    rope_theta=1_000_000.0,
)

CFG = MLLMConfig(
    name="internvl2-2b",
    encoder=ENCODER,
    llm=LLM,
    stub=ModalityStub("vision", PATCHES_PER_IMAGE, PATCH_EMBED_DIM),
    connector_hidden=2048,
    tokens_per_item_out=LLM_TOKENS_PER_IMAGE,
)

SPEC = register(ArchSpec(
    arch_id="internvl2-2b",
    desc=CFG,
    citation="arXiv:2404.16821 (InternVL 1.5/2)",
    notes="Full DFLOP applies: independent (tp, pp, dp) per module + "
          "inter-model communicator at the connector boundary. decode "
          "shapes exercise the LLM backbone; long_500k skipped (full "
          "attention).",
    tokens_per_media_item=LLM_TOKENS_PER_IMAGE,
))
