"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8 experts top-2, sliding-window attention (W=4096).
[arXiv:2401.04088]"""
from repro.common.types import ModelConfig
from repro.configs.common import ArchSpec, register

CFG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    layer_pattern=("attention",),
    ffn_pattern=("moe",),
    n_experts=8,
    top_k=2,
    activation="swiglu",
    attention_kind="sliding",
    window_size=4096,
    rope_theta=1_000_000.0,
)

SPEC = register(ArchSpec(
    arch_id="mixtral-8x7b",
    desc=CFG,
    citation="arXiv:2401.04088 (Mixtral of Experts)",
    notes="Native sliding-window attention -> long_500k runs with a "
          "ring-buffer KV cache of W=4096. 8 experts < 16-wide model axis: "
          "expert weights shard on the FFN dim (see DESIGN.md).",
))
