"""hubert-xlarge [audio] — 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504,
encoder-only (wav2vec2-style backbone).  [arXiv:2106.07447]

Modality-frontend carve-out: the mel/conv feature extractor is a STUB —
``input_specs`` supplies 512-dim frame embeddings; this config implements the
transformer backbone + masked-prediction head (504 codebook units).
"""
from repro.common.types import ModelConfig
from repro.configs.common import ArchSpec, register

FRAME_EMBED_DIM = 512     # conv feature extractor output (stubbed)

CFG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,                 # HuBERT codebook units
    causal=False,                   # bidirectional encoder
    use_rope=False,                 # conv positional embedding (stubbed)
    activation="gelu",
    input_embed_dim=FRAME_EMBED_DIM,
    tie_embeddings=False,
)

SPEC = register(ArchSpec(
    arch_id="hubert-xlarge",
    desc=CFG,
    citation="arXiv:2106.07447 (HuBERT)",
    notes="Encoder-only: no decode step — decode_32k and long_500k are "
          "documented skips (DESIGN.md §4). train_4k = masked prediction "
          "over 4k frames; prefill_32k = pure encoding forward. Also serves "
          "as the audio-encoder stage of the Qwen2-Audio-style MLLM (Fig. 9).",
))
