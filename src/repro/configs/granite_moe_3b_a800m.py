"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) expert
d_ff=512 vocab=49155, MoE 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from repro.common.types import ModelConfig
from repro.configs.common import ArchSpec, register

CFG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,                    # per-expert FFN width
    vocab_size=49155,
    layer_pattern=("attention",),
    ffn_pattern=("moe",),
    n_experts=40,
    top_k=8,
    activation="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
)

SPEC = register(ArchSpec(
    arch_id="granite-moe-3b-a800m",
    desc=CFG,
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
    notes="Fine-grained MoE: 40 small experts, top-8 routing, every layer MoE. "
          "40 experts do not divide the 16-wide model axis, so expert "
          "parallelism falls back to FFN-dim sharding (see DESIGN.md).",
))
