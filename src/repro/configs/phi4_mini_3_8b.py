"""phi4-mini-3.8b [dense] — 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064, RoPE + SwiGLU + GQA.  [arXiv:2412.08905]"""
from repro.common.types import ModelConfig
from repro.configs.common import ArchSpec, register

CFG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=200064,
    activation="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
)

SPEC = register(ArchSpec(
    arch_id="phi4-mini-3.8b",
    desc=CFG,
    citation="arXiv:2412.08905 (Phi-4)",
    notes="Large vocab (200k) makes the unembed matmul + vocab-sharded "
          "embedding a significant roofline term. long_500k skipped "
          "(full attention).",
))
