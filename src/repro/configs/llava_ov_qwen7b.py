"""LLaVA-OneVision (Qwen-2.5 7B) — the paper's primary evaluated MLLM
(Table 3): SigLIP-SO400M encoder + Qwen2.5-7B backbone.  [arXiv:2408.03326]
"""
from repro.common.types import MLLMConfig, ModalityStub, ModelConfig
from repro.configs.common import ArchSpec, register

PATCH_EMBED_DIM = 1152              # SigLIP patch embedding (stubbed patchifier)
PATCHES_PER_IMAGE = 729             # 384/14 = 27x27
LLM_TOKENS_PER_IMAGE = 196          # LLaVA-OV bilinear pool per tile/frame

ENCODER = ModelConfig(
    name="siglip-so400m",
    family="vlm-enc",
    n_layers=27,
    d_model=1152,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4304,
    vocab_size=0,
    causal=False,
    use_rope=False,
    activation="gelu",
    input_embed_dim=PATCH_EMBED_DIM,
    has_lm_head=False,
)

LLM = ModelConfig(
    name="qwen2.5-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    activation="swiglu",
    rope_theta=1_000_000.0,
)

CFG = MLLMConfig(
    name="llava-ov-qwen7b",
    encoder=ENCODER,
    llm=LLM,
    stub=ModalityStub("vision", PATCHES_PER_IMAGE, PATCH_EMBED_DIM),
    connector_hidden=3584,
    tokens_per_item_out=LLM_TOKENS_PER_IMAGE,
)

SPEC = register(ArchSpec(
    arch_id="llava-ov-qwen7b",
    desc=CFG,
    citation="arXiv:2408.03326 (LLaVA-OneVision) + arXiv:2412.15115 (Qwen2.5)",
    notes="Paper Table 3 configuration; used by the Fig. 7/10/13 benchmarks.",
    tokens_per_media_item=LLM_TOKENS_PER_IMAGE,
))
