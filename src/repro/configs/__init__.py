from repro.configs.common import (
    ASSIGNED,
    ArchSpec,
    get_config,
    list_archs,
    register,
)

__all__ = ["ASSIGNED", "ArchSpec", "get_config", "list_archs", "register"]
