"""Architecture registry: ArchSpec + shape-support rules."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Union

from repro.common.types import (
    INPUT_SHAPES,
    MLLMConfig,
    ModalityStub,
    ModelConfig,
    ShapeSpec,
    reduced,
)

ModelDesc = Union[ModelConfig, MLLMConfig]


@dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    desc: ModelDesc
    citation: str
    notes: str = ""
    tokens_per_media_item: int = 0     # connector output tokens per media item

    @property
    def is_mllm(self) -> bool:
        return isinstance(self.desc, MLLMConfig)

    @property
    def llm_cfg(self) -> ModelConfig:
        return self.desc.llm if self.is_mllm else self.desc

    def reduced_desc(self) -> ModelDesc:
        if self.is_mllm:
            m: MLLMConfig = self.desc
            return dataclasses.replace(
                m,
                name=m.name + "-smoke",
                encoder=reduced(m.encoder, input_embed_dim=min(
                    64, m.encoder.input_embed_dim or 64)),
                llm=reduced(m.llm),
                stub=ModalityStub(m.stub.modality, min(m.stub.n_tokens, 16),
                                  min(m.stub.embed_dim, 64)),
                connector_hidden=min(m.connector_hidden, 64)
                if m.connector_hidden else 0,
                tokens_per_item_out=min(m.tokens_per_item_out, 8)
                if m.tokens_per_item_out else 0,
            )
        return reduced(self.desc)

    # ------------------------------------------------------------------ #
    def shape_support(self, shape: ShapeSpec) -> str:
        """'train' | 'prefill' | 'decode' | 'skip: <reason>'."""
        cfg = self.llm_cfg
        encoder_only = not cfg.is_decoder
        if shape.kind == "train":
            return "train"
        if shape.kind == "prefill":
            return "prefill"
        # decode shapes
        if encoder_only:
            return "skip: encoder-only architecture has no decode step"
        if shape.name == "long_500k" and not cfg.supports_long_context:
            return ("skip: pure full-attention architecture; 500k context "
                    "requires sub-quadratic sequence mixing")
        return "decode"

    def supported_shapes(self) -> Dict[str, str]:
        return {name: self.shape_support(spec)
                for name, spec in INPUT_SHAPES.items()}


_REGISTRY: Dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    _REGISTRY[spec.arch_id] = spec
    return spec


def get_config(arch_id: str) -> ArchSpec:
    _ensure_loaded()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch '{arch_id}'. known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def list_archs(assigned_only: bool = False) -> list[str]:
    _ensure_loaded()
    if assigned_only:
        return [a for a in sorted(_REGISTRY) if a in ASSIGNED]
    return sorted(_REGISTRY)


ASSIGNED = (
    "granite-moe-3b-a800m", "rwkv6-7b", "deepseek-7b", "hubert-xlarge",
    "phi4-mini-3.8b", "jamba-v0.1-52b", "starcoder2-15b", "gemma-2b",
    "internvl2-2b", "mixtral-8x7b",
)

_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    import importlib

    modules = [a.replace("-", "_").replace(".", "_") for a in ASSIGNED]
    modules += ["llava_ov_qwen7b", "llava_ov_llama8b", "qwen2_audio_7b"]
    for m in modules:
        importlib.import_module(f"repro.configs.{m}")
