"""starcoder2-15b [dense] — 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152, GQA + RoPE.  [arXiv:2402.19173]"""
from repro.common.types import ModelConfig
from repro.configs.common import ArchSpec, register

CFG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    activation="gelu",              # StarCoder2 uses non-gated GELU MLP
    rope_theta=100_000.0,
)

SPEC = register(ArchSpec(
    arch_id="starcoder2-15b",
    desc=CFG,
    citation="arXiv:2402.19173 (StarCoder2)",
    notes="Largest dense assigned arch; kv=4 heads shard at most 4-way. "
          "long_500k skipped (the 15b variant is full-attention in the "
          "source release; 4k-window SWA exists only for 3b/7b).",
))
