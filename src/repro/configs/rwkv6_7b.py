"""rwkv6-7b [ssm] — 32L d_model=4096 (attention-free) d_ff=14336
vocab=65536. "Finch": data-dependent decay.  [arXiv:2404.05892]"""
from repro.common.types import ModelConfig
from repro.configs.common import ArchSpec, register

CFG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=14336,
    vocab_size=65536,
    layer_pattern=("rwkv6",),
    rwkv_head_dim=64,
    use_rope=False,
)

SPEC = register(ArchSpec(
    arch_id="rwkv6-7b",
    desc=CFG,
    citation="arXiv:2404.05892 (RWKV-6 'Finch')",
    notes="Attention-free: O(1) decode state (64x64 per head per layer). "
          "long_500k runs natively. DFLOP's attention-side profiling split "
          "maps to the WKV recurrence vs. projection/channel-mix split.",
))
