"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16 experts top-2, Mamba:attention 1:7 interleave.
[arXiv:2403.19887]

Layer structure (period 8, matching the paper's Jamba block): attention at
in-block index 4, Mamba elsewhere; MoE replaces the FFN on every other layer.
"""
from repro.common.types import ModelConfig
from repro.configs.common import ArchSpec, register

CFG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    layer_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attention", "mamba", "mamba", "mamba"),
    ffn_pattern=("dense", "moe"),
    n_experts=16,
    top_k=2,
    activation="swiglu",
    use_rope=False,                  # Jamba attention layers use no RoPE
    ssm_d_state=16,
    ssm_d_conv=4,
    ssm_expand=2,
)

SPEC = register(ArchSpec(
    arch_id="jamba-v0.1-52b",
    desc=CFG,
    citation="arXiv:2403.19887 (Jamba)",
    notes="Hybrid: 4 attention layers of 32 -> decode state is Mamba states "
          "+ 4 KV caches; long_500k runs (sub-quadratic prefill dominated by "
          "Mamba scan; decode reads 4 x 500k KV). 16 experts divide the "
          "16-wide model axis -> true expert parallelism.",
))
