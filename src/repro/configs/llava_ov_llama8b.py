"""LLaVA-OneVision (Llama-3 8B) — paper Table 3 / the Fig. 11–14 workhorse:
SigLIP encoder + Llama-3-8B backbone.  [arXiv:2408.03326, arXiv:2407.21783]
"""
from repro.common.types import MLLMConfig, ModalityStub, ModelConfig
from repro.configs.common import ArchSpec, register
from repro.configs.llava_ov_qwen7b import ENCODER, LLM_TOKENS_PER_IMAGE, \
    PATCHES_PER_IMAGE, PATCH_EMBED_DIM
from repro.common.types import ModalityStub

LLM = ModelConfig(
    name="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    activation="swiglu",
    rope_theta=500_000.0,
)

CFG = MLLMConfig(
    name="llava-ov-llama8b",
    encoder=ENCODER,
    llm=LLM,
    stub=ModalityStub("vision", PATCHES_PER_IMAGE, PATCH_EMBED_DIM),
    connector_hidden=4096,
    tokens_per_item_out=LLM_TOKENS_PER_IMAGE,
)

SPEC = register(ArchSpec(
    arch_id="llava-ov-llama8b",
    desc=CFG,
    citation="arXiv:2408.03326 (LLaVA-OneVision) + arXiv:2407.21783 (Llama 3)",
    notes="Paper's micro-experiment configuration (Figs. 11-14).",
    tokens_per_media_item=LLM_TOKENS_PER_IMAGE,
))
