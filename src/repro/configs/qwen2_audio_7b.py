"""Qwen2-Audio 7B — the paper's cross-modal generalization case (§5.3.1,
Fig. 9): Whisper-style audio encoder + Qwen2-7B backbone, with an average-
pooling connector that shrinks audio tokens before the LLM (the property the
paper credits for its balanced compute split).  [arXiv:2407.10759]
"""
from repro.common.types import MLLMConfig, ModalityStub, ModelConfig
from repro.configs.common import ArchSpec, register

FRAME_EMBED_DIM = 128               # mel filterbank frames (stubbed frontend)
FRAMES_PER_CLIP = 1500              # 30 s @ 50 Hz after conv
LLM_TOKENS_PER_CLIP = 375           # 4x average pooling

ENCODER = ModelConfig(
    name="qwen2-audio-encoder",
    family="audio-enc",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=0,
    causal=False,
    use_rope=False,
    activation="gelu",
    input_embed_dim=FRAME_EMBED_DIM,
    has_lm_head=False,
)

LLM = ModelConfig(
    name="qwen2-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    activation="swiglu",
    rope_theta=1_000_000.0,
)

CFG = MLLMConfig(
    name="qwen2-audio-7b",
    encoder=ENCODER,
    llm=LLM,
    stub=ModalityStub("audio", FRAMES_PER_CLIP, FRAME_EMBED_DIM),
    connector_hidden=0,
    tokens_per_item_out=LLM_TOKENS_PER_CLIP,
)

SPEC = register(ArchSpec(
    arch_id="qwen2-audio-7b",
    desc=CFG,
    citation="arXiv:2407.10759 (Qwen2-Audio)",
    notes="Audio MLLM for the Fig. 9 generalization benchmark; the 4x pooled "
          "connector balances encoder/LLM compute.",
    tokens_per_media_item=LLM_TOKENS_PER_CLIP,
))
