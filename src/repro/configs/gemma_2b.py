"""gemma-2b [dense] — 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000,
GeGLU, head_dim=256.  [arXiv:2403.08295]"""
from repro.common.types import ModelConfig
from repro.configs.common import ArchSpec, register

CFG = ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,                   # MQA on the 2b variant
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    activation="geglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
)

SPEC = register(ArchSpec(
    arch_id="gemma-2b",
    desc=CFG,
    citation="arXiv:2403.08295 (Gemma)",
    notes="MQA: the single KV head replicates under TP (kv dim unshardable); "
          "decode is KV-bandwidth-light. 256k vocab dominates params (525M "
          "embed). long_500k skipped (full attention).",
))
