"""Small pytree helpers used across the framework."""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


def tree_size(tree: Any) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree: Any) -> int:
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree_util.tree_leaves(tree)
    )


def tree_cast(tree: Any, dtype) -> Any:
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def tree_zeros_like(tree: Any) -> Any:
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a: Any, b: Any) -> Any:
    return jax.tree.map(jnp.add, a, b)


def tree_scale(tree: Any, s) -> Any:
    return jax.tree.map(lambda x: x * s, tree)


def tree_allfinite(tree: Any):
    leaves = [jnp.all(jnp.isfinite(x)) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.all(jnp.stack(leaves))


def global_norm(tree: Any):
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree_util.tree_leaves(tree))
    return jnp.sqrt(sq)


def tree_paths(tree: Any) -> list[tuple[str, Any]]:
    """Flatten with '/'-joined string paths (dict keys / indices)."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        out.append(("/".join(parts), leaf))
    return out


def tree_map_with_path_str(fn: Callable[[str, Any], Any], tree: Any) -> Any:
    """Map a function of (path_string, leaf) over a pytree."""

    def _wrap(path, leaf):
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        return fn("/".join(parts), leaf)

    return jax.tree_util.tree_map_with_path(_wrap, tree)
