from repro.common.types import (
    AttentionKind,
    FFNKind,
    LayerKind,
    ModelConfig,
    MLLMConfig,
    ShapeSpec,
    INPUT_SHAPES,
)
from repro.common import pytree

__all__ = [
    "AttentionKind",
    "FFNKind",
    "LayerKind",
    "ModelConfig",
    "MLLMConfig",
    "ShapeSpec",
    "INPUT_SHAPES",
    "pytree",
]
