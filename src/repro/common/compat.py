"""JAX version compatibility shims.

The codebase targets the current `jax.shard_map` / `jax.sharding.AxisType`
API; older releases (e.g. 0.4.x) expose shard_map only under
`jax.experimental.shard_map` and have no AxisType.  Route through these
helpers instead of feature-detecting at every call site.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, **kw):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    if "check_vma" in kw:                   # renamed from check_rep
        kw["check_rep"] = kw.pop("check_vma")
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)
