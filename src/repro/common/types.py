"""Central configuration types for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig`` (single
transformer stack) or ``MLLMConfig`` (modality encoder + connector + LLM,
the composition DFLOP optimizes).  Configs are plain frozen dataclasses so
they hash/compare and can be staged into jit closures safely.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple


class LayerKind(str, enum.Enum):
    """Sequence-mixing block of a layer."""

    ATTENTION = "attention"
    MAMBA = "mamba"
    RWKV6 = "rwkv6"


class AttentionKind(str, enum.Enum):
    FULL = "full"            # full causal (or bidirectional for encoders)
    SLIDING = "sliding"      # sliding-window causal attention


class FFNKind(str, enum.Enum):
    DENSE = "dense"
    MOE = "moe"


@dataclass(frozen=True)
class ModelConfig:
    """One transformer stack (decoder LLM, encoder, or SSM/hybrid)."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm-llm
    n_layers: int
    d_model: int
    n_heads: int                     # query heads (0 for attention-free)
    n_kv_heads: int                  # kv heads (GQA); == n_heads for MHA
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # --- sequence mixing -------------------------------------------------
    layer_pattern: Tuple[str, ...] = ("attention",)   # tiled over n_layers
    attention_kind: str = "full"
    window_size: int = 0             # >0 with attention_kind == "sliding"
    causal: bool = True              # False for encoder-only (hubert)
    rope_theta: float = 10_000.0
    use_rope: bool = True
    # --- feed-forward ----------------------------------------------------
    activation: str = "swiglu"       # swiglu | geglu | gelu | relu_sq | rwkv
    ffn_pattern: Tuple[str, ...] = ("dense",)         # tiled over n_layers
    n_experts: int = 0
    top_k: int = 0
    # --- SSM (mamba) -----------------------------------------------------
    ssm_d_state: int = 16
    ssm_d_conv: int = 4
    ssm_expand: int = 2
    # --- RWKV6 -----------------------------------------------------------
    rwkv_head_dim: int = 64
    # --- inputs / outputs -------------------------------------------------
    input_embed_dim: int = 0         # >0: consume precomputed embeddings
                                     # (modality-frontend stub) via in_proj
    has_lm_head: bool = True         # False: return final hidden states
    # --- misc ------------------------------------------------------------
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    dtype: str = "bfloat16"          # activation / compute dtype
    param_dtype: str = "float32"
    remat: bool = True               # checkpoint each layer in training
    scan_layers: bool = True         # stack layer params + lax.scan

    # ------------------------------------------------------------------ #
    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def layer_kinds(self) -> Tuple[LayerKind, ...]:
        pat = tuple(LayerKind(k) for k in self.layer_pattern)
        reps = math.ceil(self.n_layers / len(pat))
        return (pat * reps)[: self.n_layers]

    @property
    def ffn_kinds(self) -> Tuple[FFNKind, ...]:
        pat = tuple(FFNKind(k) for k in self.ffn_pattern)
        reps = math.ceil(self.n_layers / len(pat))
        return (pat * reps)[: self.n_layers]

    @property
    def is_attention_free(self) -> bool:
        return all(k != LayerKind.ATTENTION for k in self.layer_kinds)

    @property
    def is_homogeneous(self) -> bool:
        """All layers identical -> layer params can be stacked and scanned."""
        return len(set(self.layer_kinds)) == 1 and len(set(self.ffn_kinds)) == 1

    @property
    def block_period(self) -> int:
        """Smallest tiling period of (layer_pattern, ffn_pattern)."""
        period = _lcm(len(self.layer_pattern), len(self.ffn_pattern))
        return period if self.n_layers % period == 0 else self.n_layers

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing (SSM / hybrid / sliding window)."""
        if self.is_attention_free:
            return True
        if any(k != LayerKind.ATTENTION for k in self.layer_kinds):
            return True  # hybrid
        return self.attention_kind == AttentionKind.SLIDING.value

    @property
    def is_decoder(self) -> bool:
        return self.causal

    # -- parameter counting (exact, mirrors init) ----------------------- #
    def param_count(self) -> int:
        d = self.d_model
        total = self.vocab_size * d                       # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d                  # unembed
        total += d                                        # final norm
        for kind, ffn in zip(self.layer_kinds, self.ffn_kinds):
            total += 2 * d                                # two norms
            if kind == LayerKind.ATTENTION:
                hd = self.head_dim
                total += d * self.n_heads * hd            # wq
                total += 2 * d * self.n_kv_heads * hd     # wk, wv
                total += self.n_heads * hd * d            # wo
            elif kind == LayerKind.MAMBA:
                di = self.ssm_expand * d
                total += d * 2 * di                       # in_proj
                total += di * self.ssm_d_conv             # conv
                total += di * (2 * self.ssm_d_state + 1)  # x_proj(B,C,dt) low-rank part
                total += di + di                          # A_log (di x N folded), D  (approx: di*N)
                total += di * self.ssm_d_state            # A_log actual
                total += di * d                           # out_proj
            elif kind == LayerKind.RWKV6:
                h = d // self.rwkv_head_dim
                total += 5 * d * d                        # r,k,v,g,o projections
                total += 2 * d * 5 * 32                   # ddlerp lora a/b
                total += 2 * d * 64                       # decay lora a/b
                total += 5 * d + d + d + 2 * d            # mixes + decay_base
                total += h * self.rwkv_head_dim           # time_first (u)
                total += 2 * d * self.d_ff + d * d        # channel mix k,v,r
                continue                                  # rwkv has no extra FFN
            if ffn == FFNKind.MOE:
                n_mat = 3 if self.activation in ("swiglu", "geglu") else 2
                total += self.n_experts * n_mat * d * self.d_ff
                total += d * self.n_experts               # router
            else:
                n_mat = 3 if self.activation in ("swiglu", "geglu") else 2
                total += n_mat * d * self.d_ff
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts only)."""
        if self.n_experts == 0:
            return self.param_count()
        d, n_mat = self.d_model, 3 if self.activation in ("swiglu", "geglu") else 2
        moe_layers = sum(1 for f in self.ffn_kinds if f == FFNKind.MOE)
        inactive = moe_layers * (self.n_experts - self.top_k) * n_mat * d * self.d_ff
        return self.param_count() - inactive


@dataclass(frozen=True)
class ModalityStub:
    """Stubbed modality frontend: input_specs() provides embeddings directly.

    Per assignment, conv/mel frontends (audio) and ViT patchifiers (VLM) are
    NOT implemented; the encoder transformer backbone consumes precomputed
    frame/patch embeddings of shape (batch, n_tokens, embed_dim).
    """

    modality: str            # "vision" | "audio"
    n_tokens: int            # tokens per item emitted by the frontend
    embed_dim: int


@dataclass(frozen=True)
class MLLMConfig:
    """Encoder -> connector -> LLM composition (what DFLOP optimizes)."""

    name: str
    encoder: ModelConfig
    llm: ModelConfig
    stub: ModalityStub
    connector_hidden: int = 0        # 0 -> linear projector, else 2-layer MLP
    tokens_per_item_out: int = 0     # connector may downsample (0 -> keep)

    @property
    def vocab_size(self) -> int:
        return self.llm.vocab_size

    def param_count(self) -> int:
        total = self.encoder.param_count() + self.llm.param_count()
        de, dl = self.encoder.d_model, self.llm.d_model
        if self.connector_hidden:
            total += de * self.connector_hidden + self.connector_hidden * dl
        else:
            total += de * dl
        return total


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


INPUT_SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A smoke-test-sized variant of the same family (<=2 layers, d<=512)."""
    d_model = min(cfg.d_model, 256)
    head_dim = 32 if cfg.n_heads else 0
    n_heads = min(cfg.n_heads, 4) if cfg.n_heads else 0
    n_kv = max(1, min(cfg.n_kv_heads, n_heads)) if cfg.n_heads else 0
    period = len(cfg.layer_pattern)
    n_layers = min(cfg.n_layers, max(2, period)) if period > 2 else 2
    base = dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 512),
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        window_size=min(cfg.window_size, 64) if cfg.window_size else 0,
        rwkv_head_dim=32 if cfg.layer_pattern[0] == "rwkv6" else cfg.rwkv_head_dim,
        dtype="float32",
        param_dtype="float32",
    )
    return dataclasses.replace(base, **overrides) if overrides else base
