"""Checkpointing: pytree <-> .npz + JSON metadata (no external deps)."""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import numpy as np

from repro.common.pytree import tree_paths


def save(path: str, tree: Any, meta: Optional[dict] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = tree_paths(tree)
    arrays = {p: np.asarray(leaf) for p, leaf in flat}
    np.savez(path if path.endswith(".npz") else path + ".npz", **arrays)
    meta_path = (path[:-4] if path.endswith(".npz") else path) + ".meta.json"
    with open(meta_path, "w") as f:
        json.dump({"meta": meta or {},
                   "dtypes": {p: str(a.dtype) for p, a in arrays.items()},
                   "shapes": {p: list(a.shape) for p, a in arrays.items()}},
                  f, indent=1)


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of `like` (shapes/dtypes validated)."""
    npz = np.load(path if path.endswith(".npz") else path + ".npz")
    flat_like = tree_paths(like)
    leaves = []
    for p, ref in flat_like:
        if p not in npz:
            raise KeyError(f"checkpoint missing leaf {p}")
        arr = npz[p]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"{p}: shape {arr.shape} != expected {ref.shape}")
        leaves.append(jax.numpy.asarray(arr, dtype=ref.dtype))
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_meta(path: str) -> dict:
    meta_path = (path[:-4] if path.endswith(".npz") else path) + ".meta.json"
    with open(meta_path) as f:
        return json.load(f)
