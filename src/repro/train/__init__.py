from repro.train.loss import cross_entropy, masked_cross_entropy
from repro.train.optim import AdamWConfig, adamw_init, adamw_update, cosine_lr
from repro.train.step import make_train_step, make_loss_fn
from repro.train import checkpoint

__all__ = [
    "cross_entropy",
    "masked_cross_entropy",
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "cosine_lr",
    "make_train_step",
    "make_loss_fn",
    "checkpoint",
]
