"""AdamW with decoupled weight decay + cosine schedule (pure functions)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.common.pytree import global_norm


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.zeros_like, zeros),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(cfg: AdamWConfig, params, grads, state, lr=None):
    lr = cfg.lr if lr is None else lr
    step = state["step"] + 1
    if cfg.grad_clip:
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mhat = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2 and cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tree = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tree, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tree, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tree, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}


def cosine_lr(base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 *
                         (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)

    return schedule
