"""Losses."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits, labels, *, z_loss: float = 0.0):
    """Mean CE over labels >= 0 (packed padding uses -1)."""
    return masked_cross_entropy(logits, labels, labels >= 0, z_loss=z_loss)


def masked_cross_entropy(logits, labels, mask, *, z_loss: float = 0.0):
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    # gather-free gold lookup: a take_along_axis over a vocab-sharded logits
    # tensor forces an all-gather under SPMD; the one-hot masked sum
    # partitions cleanly (elementwise + psum over the sharded vocab dim).
    vocab = logits.shape[-1]
    onehot = jnp.clip(labels, 0)[..., None] == jax.lax.broadcasted_iota(
        jnp.int32, labels.shape + (vocab,), labels.ndim)
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    return (nll * mask).sum() / denom


def token_accuracy(logits, labels):
    pred = jnp.argmax(logits, axis=-1)
    mask = (labels >= 0).astype(jnp.float32)
    correct = (pred == labels).astype(jnp.float32) * mask
    return correct.sum() / jnp.maximum(mask.sum(), 1.0)
