"""Train-step builders: per-family loss + microbatch-scan gradient
accumulation.

The global batch arrives pre-partitioned by the Online Microbatch Scheduler
into N_mb microbatches (leading axis); the step scans over them accumulating
fp32 gradients — the TPU realization of the paper's pipeline microbatching
degrees of freedom (which items share a microbatch is the scheduler's
decision; the step consumes whatever composition it produced).
"""
from __future__ import annotations

from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro.common.types import MLLMConfig, ModelConfig
from repro.models import mllm as mllm_lib
from repro.models import model as model_lib
from repro.models.model import FwdCtx
from repro.train.loss import cross_entropy
from repro.train.optim import AdamWConfig, adamw_update

ModelDesc = Union[ModelConfig, MLLMConfig]

LB_LOSS_WEIGHT = 0.01


def _head_weight(cfg, params):
    """(weight, tied) for the LM head of a decoder param tree."""
    if cfg.tie_embeddings or "unembed" not in params:
        return params["embed"]["w"], True
    return params["unembed"]["w"], False


def make_loss_fn(desc: ModelDesc, ctx: Optional[FwdCtx] = None,
                 communicator=None, vocab_ce: Optional[Callable] = None,
                 enc_ctx: Optional[FwdCtx] = None,
                 with_aux: bool = False) -> Callable:
    """vocab_ce: optional vocab-parallel CE `ce(w, h, labels)` — when given,
    the forward returns hidden states and the head+CE run sharded
    (repro.sharding.vocab_ce).  With ``with_aux`` the loss fn returns
    (loss, aux) for ``jax.value_and_grad(..., has_aux=True)`` so the train
    step can surface the forward's observability aux (MoE drop rate /
    imbalance) without a second forward."""
    ctx = ctx or FwdCtx(mode="train")
    if vocab_ce is not None:
        import dataclasses
        ctx = dataclasses.replace(ctx, return_hidden=True)

    def finish(ce, aux):
        loss = ce + LB_LOSS_WEIGHT * aux["lb_loss"]
        return (loss, aux) if with_aux else loss

    if isinstance(desc, MLLMConfig):
        def loss_fn(params, mb):
            logits, aux = mllm_lib.forward_train(params, desc, mb, ctx=ctx,
                                                 communicator=communicator,
                                                 enc_ctx=enc_ctx)
            if vocab_ce is not None:
                # with return_hidden, forward_train yields the text-span
                # hidden states; head + CE run vocab-parallel
                w, _ = _head_weight(desc.llm, params["llm"])
                ce = vocab_ce(w, logits, mb["labels"])
            else:
                ce = cross_entropy(logits, mb["labels"])
            return finish(ce, aux)
        return loss_fn

    if desc.input_embed_dim > 0:
        # encoder-only masked prediction (HuBERT-style): labels -1 = unmasked
        def loss_fn(params, mb):
            out, _, aux = model_lib.forward(
                params, desc, embeds=mb["frame_embeds"],
                segment_ids=mb.get("segment_ids"), ctx=ctx)
            if vocab_ce is not None:
                w, _ = _head_weight(desc, params)
                ce = vocab_ce(w, out, mb["labels"])
            else:
                ce = cross_entropy(out, mb["labels"])
            return finish(ce, aux)
        return loss_fn

    def loss_fn(params, mb):
        out, _, aux = model_lib.forward(
            params, desc, tokens=mb["tokens"],
            positions=mb.get("positions"),
            segment_ids=mb.get("segment_ids"), ctx=ctx)
        if vocab_ce is not None:
            w, _ = _head_weight(desc, params)
            ce = vocab_ce(w, out, mb["labels"])
        else:
            ce = cross_entropy(out, mb["labels"])
        return finish(ce, aux)
    return loss_fn


def make_train_step(desc: ModelDesc, opt_cfg: AdamWConfig,
                    ctx: Optional[FwdCtx] = None, communicator=None,
                    vocab_ce: Optional[Callable] = None,
                    enc_ctx: Optional[FwdCtx] = None,
                    donate: bool = True) -> Callable:
    """step(params, opt_state, batch, lr) -> (params, opt_state, metrics).

    `batch` leaves carry a leading (N_mb,) microbatch axis."""
    loss_fn = make_loss_fn(desc, ctx, communicator, vocab_ce=vocab_ce,
                           enc_ctx=enc_ctx, with_aux=True)

    def train_step(params, opt_state, batch, lr):
        n_mb = jax.tree_util.tree_leaves(batch)[0].shape[0]
        zero = jnp.zeros((), jnp.float32)

        def mb_step(carry, mb):
            loss_sum, drop_sum, imb_max, grads = carry
            (l, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            grads = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), grads, g)
            drop_sum = drop_sum + aux["moe_drop_rate"]
            imb_max = jnp.maximum(imb_max, aux["moe_imbalance"])
            return (loss_sum + l, drop_sum, imb_max, grads), None

        init = (zero, zero, zero,
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
        (loss_sum, drop_sum, imb_max, grads), _ = jax.lax.scan(
            mb_step, init, batch)
        grads = jax.tree.map(lambda g: g / n_mb, grads)
        new_params, new_opt = adamw_update(opt_cfg, params, grads, opt_state,
                                           lr=lr)
        # NaN-preserving aggregates (no-MoE models report NaN, never 0.0)
        metrics = {"loss": loss_sum / n_mb,
                   "moe_drop_rate": drop_sum / n_mb,
                   "moe_imbalance": imb_max}
        return new_params, new_opt, metrics

    return train_step
