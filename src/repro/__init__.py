"""repro — DFLOP: data-driven multimodal LLM training pipeline optimization, in JAX.

Faithful JAX/TPU reproduction of:
  "DFLOP: A Data-driven Framework for Multimodal LLM Training Pipeline
   Optimization" (An et al., CS.DC 2026)

Package layout:
  repro.core      — the paper's contribution (profiling engine, data-aware
                    3D parallelism optimizer, online microbatch scheduler,
                    pipeline executor/simulator, inter-model communicator)
  repro.runtime   — telemetry & continuous re-planning: trace recorder,
                    rolling metrics, online calibration, drift detection,
                    RuntimeController (background re-plan + plan hot-swap)
  repro.models    — pure-functional JAX model substrate (dense / MoE / SSM /
                    hybrid / encoder / VLM families)
  repro.kernels   — Pallas TPU kernels (packed flash attention, RWKV6 scan,
                    Mamba selective scan) with jnp reference oracles
  repro.sharding  — logical-axis sharding rules -> NamedSharding
  repro.data      — synthetic multimodal data pipeline + sequence packing
  repro.train     — loss / AdamW / grad-accum trainer / checkpointing
  repro.serve     — KV caches, prefill/decode steps
  repro.configs   — assigned architecture configs (+ the paper's own MLLMs)
  repro.launch    — production mesh, multi-pod dry-run, train driver
"""

__version__ = "0.1.0"
