"""DFLOP façade: profile → plan → schedule (paper Fig. 3).

    engine = DFLOPEngine(enc_cfg, llm_cfg, cluster, tokens_per_media_item)
    engine.profile(dataset)                  # Profiling Engine (§3.2)
    plan = engine.plan(gbs)                  # Data-aware Optimizer (§3.3)
    sched = engine.scheduler()               # Online Scheduler (§3.4)
    for batch_items in loader:
        out = sched.schedule(batch_items)    # index groups -> data loader

Closed-loop operation (repro.runtime) adds observe → re-plan on top:

    ctl = engine.runtime(gbs)                # RuntimeController
    for batch_items in loader:
        out = ctl.schedule(batch_items)      # drift-checked, hot-swappable
        ...run step, measure...
        ctl.observe_step(out, measured_s)    # telemetry + drift feedback
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from repro.common.types import ModelConfig
from repro.core.optimizer.objective import Objective
from repro.core.optimizer.search import ParallelismOptimizer, SearchResult
from repro.core.optimizer.space import ClusterSpec, ParallelismPlan
from repro.core.profiling.analytic import AnalyticBackend, HardwareSpec, V5E
from repro.core.profiling.data_profiler import DataProfiler, ShapeDistribution
from repro.core.profiling.model_profiler import (
    Backend,
    ModelProfiler,
    PerfModel,
)
from repro.core.scheduler.adaptive import AdaptiveCorrection
from repro.core.scheduler.online import OnlineMicrobatchScheduler


@dataclass
class DFLOPEngine:
    llm_cfg: ModelConfig
    cluster: ClusterSpec
    tokens_per_media_item: int = 196
    enc_cfg: Optional[ModelConfig] = None
    e_seq_len: int = 729                 # encoder tokens per media item
    backend: Optional[Backend] = None
    mode: str = "train"
    # search objective: "mean" (Algorithm 1), "expected-random" (Monte-Carlo
    # over random assignment), "balanced-quantile" (heterogeneity-aware
    # LPT-balanced p90), or an `objective.Objective` *instance* — pass an
    # instance to pin non-default config (e.g. quantile) so background
    # re-plans score plans the same way the initial search did.
    objective: "str | Objective" = "mean"

    perf: Optional[PerfModel] = None
    dist: Optional[ShapeDistribution] = None
    plan_result: Optional[SearchResult] = None

    # ------------------------------------------------------------------ #
    def profile(self, dataset=None, items: Optional[Sequence] = None,
                n_samples: int = 2048) -> "DFLOPEngine":
        """Run Model Profiler + Data Profiler (they run concurrently in the
        paper; both are sub-minute here)."""
        backend = self.backend or AnalyticBackend(V5E)
        tp_max = self.cluster.chips_per_node
        tps = [t for t in (1, 2, 4, 8, 16, 32) if t <= tp_max]
        profiler = ModelProfiler(backend, tp_degrees=tps, mode=self.mode)
        self.perf = profiler.profile_mllm(self.enc_cfg, self.llm_cfg,
                                          self.e_seq_len)
        dp = DataProfiler(self.tokens_per_media_item)
        if items is not None:
            self.dist = dp.profile(items)
        elif dataset is not None:
            self.dist = dp.profile_sampler(dataset, n_samples)
        else:
            self.dist = ShapeDistribution(np.ones(1), np.full(1, 1024.0))
        return self

    # ------------------------------------------------------------------ #
    def plan(self, gbs: int, **kw) -> SearchResult:
        """Run the optimizer.  kw forwards to `ParallelismOptimizer` —
        notably ``calibrator=`` (couple the search to runtime calibration),
        ``seed=`` (Monte-Carlo draw) and ``quantile=``/``n_trials=``.
        The resolved objective instance is pinned back onto
        ``self.objective`` so background re-plans (`runtime()`) score plans
        under the same configuration the initial search used."""
        assert self.perf is not None, "call profile() first"
        kw.setdefault("objective", self.objective)
        opt = ParallelismOptimizer(self.cluster, self.perf, mode=self.mode,
                                   **kw)
        self.objective = opt.objective_obj
        self.plan_result = opt.search(self.dist, gbs)
        return self.plan_result

    def baseline_plan(self, gbs: int, tp: int, pp: int) -> SearchResult:
        opt = ParallelismOptimizer(self.cluster, self.perf, mode=self.mode)
        return opt.baseline_uniform(self.dist, gbs, tp, pp)

    # ------------------------------------------------------------------ #
    def scheduler(self, plan: Optional[ParallelismPlan] = None,
                  adaptive: bool = True,
                  ilp_time_limit_s: float = 0.25) -> OnlineMicrobatchScheduler:
        plan = plan or (self.plan_result.plan if self.plan_result else None)
        assert plan is not None, "call plan() first or pass a plan"
        corr = AdaptiveCorrection() if adaptive else None
        return OnlineMicrobatchScheduler(
            plan, self.perf, self.tokens_per_media_item,
            ilp_time_limit_s=ilp_time_limit_s, adaptive=corr, mode=self.mode)

    # ------------------------------------------------------------------ #
    def runtime(self, gbs: int, *, plan: Optional[ParallelismPlan] = None,
                adaptive: bool = True, calibrate: bool = True,
                trace: bool = True, drift=None, auto_replan: bool = True,
                min_improvement: float = 0.02,
                replan_n_trials: int = 8,
                ilp_time_limit_s: float = 0.25,
                param_swapper=None,
                swap_horizon_batches: int = 50,
                compose_window: int = 0,
                max_staleness: Optional[int] = None,
                fleet=None):
        """Closed control loop: returns a `repro.runtime.RuntimeController`
        wrapping this engine + a fresh scheduler.  Plans first if needed.

        ``param_swapper`` (see `repro.launch.reshard.ParamSwapper`) threads
        the training loop's *live* params through the controller: a plan
        hot-swap then physically re-lays-out parameters on device, gated on
        amortized reshard cost over ``swap_horizon_batches``.

        ``compose_window=W`` > 0 attaches a lookahead batch composer
        (`repro.data.composer.LookaheadComposer`) holding a ``W·gbs``
        reorder window; ``max_staleness`` bounds how many batches an item
        may wait in it (default ``2·W``).  The controller wires the
        composer's telemetry and flushes its window pricing on plan
        hot-swaps; feed it via ``ctl.compose(draw=...)`` or
        ``ScheduledLoader(composer=ctl.composer)``.

        ``fleet`` (see `repro.launch.fleet.FleetManager`) makes the loop
        *elastic*: the controller drains membership events at batch
        boundaries (`poll_fleet`) and recovers checkpoint-free — re-plan
        for the surviving roster, migrate live params via
        ``param_swapper`` (use ``mesh_factory=fleet.plan_mesh``), degrade
        instead of crashing when either fails."""
        from repro.runtime import (DriftDetector, OnlineCalibrator,
                                   RuntimeController, RuntimeMetrics,
                                   TraceRecorder)
        if plan is None:
            if self.plan_result is None or self.plan_result.plan is None:
                self.plan(gbs)
            plan = self.plan_result.plan
        sched = self.scheduler(plan=plan, adaptive=adaptive,
                               ilp_time_limit_s=ilp_time_limit_s)
        composer = None
        if compose_window > 0:
            from repro.data.composer import LookaheadComposer
            composer = LookaheadComposer(sched, gbs=gbs,
                                         window=compose_window,
                                         max_staleness=max_staleness)
        return RuntimeController(
            self, sched, gbs,
            trace=TraceRecorder(enabled=trace),
            metrics=RuntimeMetrics(),
            calibration=OnlineCalibrator() if calibrate else None,
            drift=drift if drift is not None else DriftDetector(),
            auto_replan=auto_replan, min_improvement=min_improvement,
            replan_n_trials=replan_n_trials,
            param_swapper=param_swapper,
            swap_horizon_batches=swap_horizon_batches,
            composer=composer,
            fleet=fleet)

    # ------------------------------------------------------------------ #
    def serving(self, *, admission: str = "slo", serve_cfg=None,
                calibrate: bool = True, trace: bool = True,
                drift=True, backend="emulated", model_params=None,
                model_cfg=None, max_len: int = 128, chunk: int = 16,
                devices=None, warmup: bool = True):
        """Serving-side closed loop: returns a `repro.serve.ServeEngine`
        whose admission pricing runs through this engine's profiled
        `PerfModel` (``profile()`` first).  ``admission``: ``"slo"``
        (data-aware `SLOAdmission`) or ``"fifo"`` (baseline); the trace /
        metrics / calibrator / Page–Hinkley wiring mirrors ``runtime()``.

        ``backend`` selects the execution layer: ``"emulated"`` (PR 6's
        discrete-event model), ``"real"`` (jit'd prefill/decode via
        `repro.serve.real.RealBackend` — requires ``model_params``, and
        ``model_cfg`` when it differs from ``llm_cfg``; ``max_len`` /
        ``chunk`` / ``devices`` / ``warmup`` pass through), or an
        `ExecutionBackend` *factory* ``f(pricer, cfg) -> backend``.
        ``drift`` may be a bool or a ready `PageHinkley` (the real loop
        usually wants a shorter burn-in than the emulation's default).
        The real loop widens the calibrator's ratio clip: its "prefill"
        cells convert perf-model accelerator-seconds into measured host
        wall-seconds, a ratio far beyond the in-family default of 8×."""
        assert self.perf is not None, "call profile() first"
        from repro.runtime import (OnlineCalibrator, RuntimeMetrics,
                                   TraceRecorder)
        from repro.runtime.drift import PageHinkley
        from repro.serve import (FIFOAdmission, PrefillPricer, ServeConfig,
                                 ServeEngine)
        cfg = serve_cfg if serve_cfg is not None else ServeConfig()
        if not calibrate:
            cal = None
        elif backend == "real":
            cal = OnlineCalibrator(max_ratio=1e9, min_obs=1)
        else:
            cal = OnlineCalibrator()
        pricer = PrefillPricer(self.perf, self.tokens_per_media_item,
                               tp=cfg.tp, calibrator=cal)
        if backend == "emulated":
            be = None                    # ServeEngine's EmulatedBackend
        elif backend == "real":
            assert model_params is not None, "real backend needs params"
            from repro.serve.real import RealBackend
            be = RealBackend(model_cfg if model_cfg is not None
                             else self.llm_cfg, model_params, pricer, cfg,
                             max_len=max_len, chunk=chunk, devices=devices,
                             warmup=warmup)
        else:
            be = backend(pricer, cfg)
        ph = drift if isinstance(drift, PageHinkley) \
            else (PageHinkley() if drift else None)
        eng = ServeEngine(
            pricer, cfg, backend=be,
            admission=(FIFOAdmission() if admission == "fifo" else None),
            calibrator=cal,
            drift=ph,
            trace=TraceRecorder(enabled=trace,
                                process_name="dflop-serve"),
            metrics=RuntimeMetrics())
        return eng
