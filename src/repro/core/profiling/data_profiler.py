"""Data Profiler (§3.2.2): empirical input-shape distribution of the dataset.

"The Data Profiler first identifies the varying input dimensions for both
the modality encoder and the LLM. It then performs random sampling across
the dataset, calculating the precise input shapes for each sampled item
within the target architecture to construct empirical histograms."

The model-specific transformation (media item -> connector tokens) is
captured by `tokens_per_media_item`, so the same dataset yields different
distributions per architecture — exactly the paper's point.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from repro.data.items import DataItem


@dataclass
class ShapeDistribution:
    """Per-item (b(d), s(d)) samples + histogram views."""

    enc_batches: np.ndarray     # (n,) encoder effective batch per item
    llm_seqs: np.ndarray        # (n,) LLM packed-seq contribution per item

    def mean(self) -> tuple[float, float]:
        return float(self.enc_batches.mean()), float(self.llm_seqs.mean())

    def histogram(self, which: str = "llm", bins: int = 32):
        data = self.llm_seqs if which == "llm" else self.enc_batches
        return np.histogram(data, bins=bins)

    def variance(self, which: str = "llm") -> float:
        data = self.llm_seqs if which == "llm" else self.enc_batches
        return float(np.var(data))

    def heterogeneity(self) -> float:
        """Coefficient of variation of the LLM seq-len (Fig. 11b proxy)."""
        return float(np.std(self.llm_seqs) / max(np.mean(self.llm_seqs), 1e-9))

    def __len__(self) -> int:
        return len(self.llm_seqs)


class DataProfiler:
    def __init__(self, tokens_per_media_item: int):
        self.tokens_per_media_item = tokens_per_media_item

    def shapes_of(self, item: DataItem) -> tuple[int, int]:
        return (item.encoder_batch(),
                item.llm_seq_len(self.tokens_per_media_item))

    def profile(self, items: Sequence[DataItem],
                n_samples: Optional[int] = None,
                seed: int = 0) -> ShapeDistribution:
        items = list(items)
        if n_samples is not None and n_samples < len(items):
            rng = np.random.default_rng(seed)
            idx = rng.choice(len(items), size=n_samples, replace=False)
            items = [items[i] for i in idx]
        shapes = np.array([self.shapes_of(it) for it in items], np.float64)
        if len(shapes) == 0:
            shapes = np.zeros((0, 2))
        return ShapeDistribution(shapes[:, 0], shapes[:, 1])

    def profile_sampler(self, dataset, n_samples: int = 2048) -> ShapeDistribution:
        """Sample from a MixedDataset-like object with .sample(n)."""
        return self.profile(dataset.sample(n_samples))
