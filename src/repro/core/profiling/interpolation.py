"""Multilinear interpolation over rectilinear grids.

The Model Profiler (§3.2.1) measures throughput/memory on a sparse grid of
input shapes × TP degrees and predicts intermediate shapes by linear
interpolation — "we model activation memory via linear interpolation based on
the effective batch size ... and sequence length".  Extrapolation clamps to
the hull (conservative for memory, flat for throughput).
"""
from __future__ import annotations

from typing import Sequence

import numpy as np


class GridInterpolator:
    """f: R^k -> R sampled on an outer-product grid of sorted axis points."""

    def __init__(self, axes: Sequence[np.ndarray], values: np.ndarray):
        self.axes = [np.asarray(a, dtype=np.float64) for a in axes]
        self.values = np.asarray(values, dtype=np.float64)
        if tuple(len(a) for a in self.axes) != self.values.shape:
            raise ValueError(
                f"grid shape {tuple(len(a) for a in self.axes)} != "
                f"values shape {self.values.shape}")
        for a in self.axes:
            if len(a) == 0 or np.any(np.diff(a) <= 0):
                raise ValueError("axes must be non-empty and strictly increasing")

    def __call__(self, *coords: float) -> float:
        return float(self.batch(np.asarray(coords, dtype=np.float64)[None])[0])

    def batch(self, pts: np.ndarray) -> np.ndarray:
        """pts: (n, k) -> (n,) interpolated values (clamped extrapolation)."""
        pts = np.atleast_2d(np.asarray(pts, dtype=np.float64))
        n, k = pts.shape
        if k != len(self.axes):
            raise ValueError(f"expected {len(self.axes)} coords, got {k}")
        los, fracs = [], []
        for i, ax in enumerate(self.axes):
            x = np.clip(pts[:, i], ax[0], ax[-1])
            hi_idx = np.searchsorted(ax, x, side="left")
            hi_idx = np.clip(hi_idx, 1, len(ax) - 1) if len(ax) > 1 else \
                np.zeros(n, dtype=int)
            lo_idx = hi_idx - 1 if len(ax) > 1 else np.zeros(n, dtype=int)
            if len(ax) > 1:
                denom = ax[hi_idx] - ax[lo_idx]
                frac = (x - ax[lo_idx]) / denom
            else:
                frac = np.zeros(n)
            los.append(lo_idx)
            fracs.append(frac)
        out = np.zeros(n)
        # sum over 2^k corners
        for corner in range(1 << k):
            idx = []
            weight = np.ones(n)
            for i in range(k):
                if corner >> i & 1 and len(self.axes[i]) > 1:
                    idx.append(los[i] + 1)
                    weight = weight * fracs[i]
                else:
                    idx.append(los[i])
                    if len(self.axes[i]) > 1:
                        weight = weight * (1.0 - fracs[i])
            out += weight * self.values[tuple(idx)]
        return out
