"""Roofline-calibrated analytic backend for the Model Profiler.

This container has no TPU, so the *numbers* that feed the profiling grids
come from a calibrated hardware model of the target (TPU v5e) instead of
wall-clock timers; the profiling *machinery* (grids, interpolation,
attn-vs-lin split, memory models) is identical to the measured path and is
exercised with real timers by ``MeasuredBackend`` on small models.

The model reproduces the qualitative behaviour the paper measures in Fig. 2:
throughput degrades with TP degree when per-chip workload fragments become
too small (MXU under-utilization) and from synchronization collectives.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.common.types import ModelConfig
from repro.core.profiling.flops import FlopCount, module_flops


@dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12        # bf16 per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    ici_bw: float = 50e9              # bytes/s per link
    ici_latency: float = 2e-6         # per-collective latency (s)
    mem_bytes: float = 16e9           # HBM per chip
    chips_per_node: int = 16          # TP domain (mesh "model" axis)
    base_mxu_util: float = 0.6
    bytes_per_param: int = 2          # bf16 weights
    bytes_per_act: int = 2


V5E = HardwareSpec()
# A100-like spec for reproducing the paper's own Fig. 2 curves
A100 = HardwareSpec(name="a100", peak_flops=312e12, hbm_bw=2039e9,
                    ici_bw=300e9, mem_bytes=80e9, chips_per_node=8)


class AnalyticBackend:
    """Produces FLOP/s throughputs and byte footprints for profiler grids."""

    def __init__(self, hw: HardwareSpec = V5E):
        self.hw = hw

    # ----------------------------------------------------------------- #
    def _util(self, cfg: ModelConfig, tokens: float, tp: int) -> float:
        """MXU utilization: shrinks when per-chip fragments get small."""
        hw = self.hw
        f_tokens = min(1.0, tokens / 1024.0)             # M-dim occupancy
        width = max(cfg.d_ff, cfg.n_heads * max(cfg.head_dim, 1)) / max(tp, 1)
        f_width = min(1.0, width / 512.0)                # N-dim occupancy
        return hw.base_mxu_util * max(0.05, f_tokens) * max(0.1, f_width)

    def _collective_time(self, cfg: ModelConfig, tokens: float, tp: int) -> float:
        """Megatron-style TP sync: ~4 all-reduces of activations per layer."""
        if tp <= 1:
            return 0.0
        bytes_act = tokens * cfg.d_model * self.hw.bytes_per_act
        per_ar = 2.0 * bytes_act * (tp - 1) / tp / self.hw.ici_bw \
            + self.hw.ici_latency          # fixed launch/sync latency: this
        # is what makes small effective batches scale worse with TP (Fig. 2)
        return 4.0 * cfg.n_layers * per_ar

    def _step_time(self, cfg: ModelConfig, fl: float, tokens: float,
                   tp: int, mem_bound_bytes: float) -> float:
        compute = fl / tp / (self.hw.peak_flops * self._util(cfg, tokens, tp))
        memory = mem_bound_bytes / tp / self.hw.hbm_bw
        return max(compute, memory) + self._collective_time(cfg, tokens / max(tp, 1), tp)

    # ----------------------------------------------------------------- #
    def throughput(self, cfg: ModelConfig, batch: float, seq: float, tp: int,
                   *, split: str = "all", mode: str = "train") -> float:
        """Achieved FLOP/s (per TP group) for the given input shape."""
        fl = module_flops(cfg, batch, seq, mode=mode,
                          cache_len=seq if mode == "decode" else 0)
        part = {"attn": fl.attn, "lin": fl.lin, "all": fl.total}[split]
        if part <= 0:
            return self.hw.peak_flops  # degenerate; never dominates
        tokens = batch * (1 if mode == "decode" else seq)
        params_bytes = cfg.param_count() * self.hw.bytes_per_param
        act_bytes = tokens * cfg.d_model * cfg.n_layers * 4 * self.hw.bytes_per_act
        t_total = self._step_time(cfg, fl.total, tokens, tp,
                                  params_bytes + act_bytes)
        # attribute time to the split proportionally to its FLOP share,
        # with the recurrent/attention part additionally penalized at small
        # per-instance lengths (it cannot batch across instances).
        share = part / fl.total
        t_part = t_total * share
        return part / max(t_part, 1e-12)

    # ----------------------------------------------------------------- #
    def memory(self, cfg: ModelConfig, n_layers: int, tp: int, batch: float,
               seq: float) -> tuple[float, float]:
        """(model_state_bytes, act_state_bytes) per chip for n_layers."""
        import dataclasses

        sub = dataclasses.replace(cfg, n_layers=max(1, int(n_layers)),
                                  layer_pattern=cfg.layer_pattern[:1],
                                  ffn_pattern=cfg.ffn_pattern[:1])
        params = sub.param_count()
        # params(bf16) + grads(fp32) + adam m,v(fp32) + fp32 master
        model_state = params / tp * (2 + 4 + 4 + 4 + 4)
        tokens = batch * seq
        # remat: keep layer-boundary activations + one layer's working set
        boundary = tokens * cfg.d_model * self.hw.bytes_per_act * n_layers
        working = tokens * (cfg.d_model * 6 + cfg.d_ff / max(tp, 1) * 3) \
            * self.hw.bytes_per_act
        return model_state, boundary + working
