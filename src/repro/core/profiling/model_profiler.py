"""Model Profiler (§3.2.1): grid measurement -> interpolated perf models.

Builds, for the modality encoder,
    E_thr(E_batch_size, E_tp)                       [FLOP/s]
and for the LLM (sequence-packed, effective batch 1),
    L_attn_thr(L_seq_len, L_tp), L_lin_thr(L_seq_len, L_tp)
plus memory models
    model_state(l, tp)   and   act_state(l, tp, batch_or_seq)
by measuring a backend on a sparse grid and interpolating (paper: "varying
the number of layers between two distinct small values and scaling the TP
degree in powers of two up to N_gpu_node").
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional, Protocol, Sequence

import numpy as np

from repro.common.types import ModelConfig
from repro.core.profiling.flops import FlopCount, module_flops
from repro.core.profiling.interpolation import GridInterpolator


class Backend(Protocol):
    def throughput(self, cfg: ModelConfig, batch: float, seq: float, tp: int,
                   *, split: str = "all", mode: str = "train") -> float: ...

    def memory(self, cfg: ModelConfig, n_layers: int, tp: int, batch: float,
               seq: float) -> tuple[float, float]: ...


@dataclass
class ThroughputModel:
    """FLOP/s as a function of (shape, tp); shape is batch (encoder) or
    seq len (LLM)."""

    grid: GridInterpolator

    def __call__(self, shape: float, tp: float) -> float:
        return max(self.grid(shape, tp), 1e3)

    def batch(self, shapes: np.ndarray, tp: float) -> np.ndarray:
        pts = np.stack([shapes, np.full_like(shapes, tp, dtype=np.float64)], 1)
        return np.maximum(self.grid.batch(pts), 1e3)


@dataclass
class MemoryModel:
    """Eq. 4/5 building blocks: model_state(l, tp), act_state(l, tp, x)."""

    model_state_grid: GridInterpolator      # (layers, tp) -> bytes
    act_state_grid: GridInterpolator        # (layers, tp, shape) -> bytes

    def model_state(self, n_layers: float, tp: float) -> float:
        return self.model_state_grid(n_layers, tp)

    def act_state(self, n_layers: float, tp: float, shape: float) -> float:
        return self.act_state_grid(n_layers, tp, shape)


@dataclass
class ModulePerf:
    cfg: ModelConfig
    thr_all: ThroughputModel
    thr_attn: Optional[ThroughputModel]
    thr_lin: Optional[ThroughputModel]
    memory: MemoryModel
    fixed_seq: float = 0.0   # encoder: E_seq_len (tokens per media item)

    # -- durations (paper §3.3.1): dur = FLOP / thr ---------------------- #
    def flops(self, batch: float, seq: float, mode: str = "train") -> FlopCount:
        return module_flops(self.cfg, batch, seq, mode=mode)

    def duration(self, batch: float, seq: float, tp: int,
                 mode: str = "train") -> float:
        fl = self.flops(batch, seq, mode)
        if self.thr_attn is not None and self.thr_lin is not None:
            shape = seq if self.fixed_seq == 0 else batch
            t = fl.attn / self.thr_attn(shape, tp) + \
                fl.lin / self.thr_lin(shape, tp)
            return t
        shape = seq if self.fixed_seq == 0 else batch
        return fl.total / self.thr_all(shape, tp)

    def duration_batch(self, shapes: np.ndarray, tp: int,
                       mode: str = "train") -> np.ndarray:
        """Vectorized `duration` over many shapes (encoder: effective batch;
        LLM: packed seq len).  FLOPs come from the attn/lin polynomial —
        attn(s) = a1·s + a2·s², lin(s) = b1·s — exactly the construction the
        optimizer's `_ModuleTables` uses, so table entries and per-item
        Monte-Carlo durations are the same computation.  Non-positive
        shapes map to duration 0."""
        shapes = np.asarray(shapes, dtype=np.float64)
        out = np.zeros_like(shapes)
        pos = shapes > 0
        if not pos.any():
            return out
        s = shapes[pos]
        if self.fixed_seq:
            # encoder: FLOPs linear in the effective batch at fixed seq
            per = module_flops(self.cfg, 1.0, self.fixed_seq, mode=mode)
            fl_attn = per.attn * s
            fl_lin = per.lin * s
        else:
            f1 = module_flops(self.cfg, 1.0, 1.0, mode=mode)
            f2 = module_flops(self.cfg, 1.0, 2.0, mode=mode)
            a2 = (f2.attn - 2 * f1.attn) / 2.0
            a1 = f1.attn - a2
            if self.cfg.attention_kind == "sliding" and self.cfg.window_size:
                # piecewise: quadratic until W, then linear — evaluate exact
                fl_attn = np.array([module_flops(self.cfg, 1.0, v,
                                                 mode=mode).attn for v in s])
            else:
                fl_attn = a1 * s + a2 * s ** 2
            fl_lin = f1.lin * s
        if self.thr_attn is not None and self.thr_lin is not None:
            dur = fl_attn / self.thr_attn.batch(s, tp) \
                + fl_lin / self.thr_lin.batch(s, tp)
        else:
            dur = (fl_attn + fl_lin) / self.thr_all.batch(s, tp)
        out[pos] = dur
        return out


@dataclass
class PerfModel:
    """Everything the optimizer and scheduler need (profiling output)."""

    encoder: Optional[ModulePerf]
    llm: ModulePerf

    def e_dur(self, eff_batch: float, tp: int, mode: str = "train") -> float:
        """Duration for `eff_batch` media items on the encoder."""
        if self.encoder is None or eff_batch <= 0:
            return 0.0
        return self.encoder.duration(eff_batch, self.encoder.fixed_seq, tp, mode)

    def l_dur(self, seq_len: float, tp: int, mode: str = "train") -> float:
        """Duration for a packed sequence of `seq_len` tokens on the LLM."""
        if seq_len <= 0:
            return 0.0
        return self.llm.duration(1.0, seq_len, tp, mode)

    def e_dur_batch(self, eff_batches: np.ndarray, tp: int,
                    mode: str = "train") -> np.ndarray:
        if self.encoder is None:
            return np.zeros_like(np.asarray(eff_batches, dtype=np.float64))
        return self.encoder.duration_batch(eff_batches, tp, mode)

    def l_dur_batch(self, seq_lens: np.ndarray, tp: int,
                    mode: str = "train") -> np.ndarray:
        return self.llm.duration_batch(seq_lens, tp, mode)


DEFAULT_TPS = (1, 2, 4, 8, 16)


class ModelProfiler:
    """Profiles a module on a (shape x tp) grid via a Backend."""

    def __init__(self, backend: Backend, *,
                 tp_degrees: Sequence[int] = DEFAULT_TPS,
                 shape_grid: Sequence[float] = (1, 2, 4, 8, 16, 32, 64),
                 layer_grid: Sequence[int] = (2, 4),
                 mode: str = "train"):
        self.backend = backend
        self.tp_degrees = tuple(sorted(tp_degrees))
        self.shape_grid = tuple(sorted(shape_grid))
        self.layer_grid = tuple(sorted(layer_grid))
        self.mode = mode

    # ------------------------------------------------------------------ #
    def _thr_grid(self, cfg: ModelConfig, split: str, *,
                  batch_of=None, seq_of=None) -> ThroughputModel:
        vals = np.zeros((len(self.shape_grid), len(self.tp_degrees)))
        for i, s in enumerate(self.shape_grid):
            for j, tp in enumerate(self.tp_degrees):
                vals[i, j] = self.backend.throughput(
                    cfg, batch_of(s), seq_of(s), tp, split=split,
                    mode=self.mode)
        return ThroughputModel(GridInterpolator(
            [np.array(self.shape_grid, float),
             np.array(self.tp_degrees, float)], vals))

    def _memory_model(self, cfg: ModelConfig, *, batch_of, seq_of) -> MemoryModel:
        L, T, S = self.layer_grid, self.tp_degrees, self.shape_grid
        ms = np.zeros((len(L), len(T)))
        act = np.zeros((len(L), len(T), len(S)))
        for i, l in enumerate(L):
            for j, tp in enumerate(T):
                for k, s in enumerate(S):
                    m, a = self.backend.memory(cfg, l, tp, batch_of(s), seq_of(s))
                    act[i, j, k] = a
                ms[i, j] = m
        return MemoryModel(
            GridInterpolator([np.array(L, float), np.array(T, float)], ms),
            GridInterpolator([np.array(L, float), np.array(T, float),
                              np.array(S, float)], act))

    # ------------------------------------------------------------------ #
    def profile_encoder(self, cfg: ModelConfig, e_seq_len: int) -> ModulePerf:
        """Encoder: variable effective batch, fixed per-item seq len."""
        batch_of = lambda s: float(s)
        seq_of = lambda s: float(e_seq_len)
        return ModulePerf(
            cfg=cfg,
            thr_all=self._thr_grid(cfg, "all", batch_of=batch_of, seq_of=seq_of),
            thr_attn=None, thr_lin=None,
            memory=self._memory_model(cfg, batch_of=batch_of, seq_of=seq_of),
            fixed_seq=float(e_seq_len))

    def profile_llm(self, cfg: ModelConfig,
                    seq_grid: Sequence[float] = (256, 512, 1024, 2048, 4096,
                                                 8192, 16384, 32768)) -> ModulePerf:
        """LLM: sequence packing -> batch 1, variable packed seq length."""
        prof = ModelProfiler(self.backend, tp_degrees=self.tp_degrees,
                             shape_grid=seq_grid, layer_grid=self.layer_grid,
                             mode=self.mode)
        batch_of = lambda s: 1.0
        seq_of = lambda s: float(s)
        return ModulePerf(
            cfg=cfg,
            thr_all=prof._thr_grid(cfg, "all", batch_of=batch_of, seq_of=seq_of),
            thr_attn=prof._thr_grid(cfg, "attn", batch_of=batch_of, seq_of=seq_of),
            thr_lin=prof._thr_grid(cfg, "lin", batch_of=batch_of, seq_of=seq_of),
            memory=prof._memory_model(cfg, batch_of=batch_of, seq_of=seq_of),
            fixed_seq=0.0)

    def profile_mllm(self, enc_cfg: Optional[ModelConfig],
                     llm_cfg: ModelConfig, e_seq_len: int = 0) -> PerfModel:
        enc = self.profile_encoder(enc_cfg, e_seq_len) if enc_cfg else None
        return PerfModel(encoder=enc, llm=self.profile_llm(llm_cfg))
