from repro.core.profiling.interpolation import GridInterpolator
from repro.core.profiling.data_profiler import DataProfiler, ShapeDistribution
from repro.core.profiling.model_profiler import (
    ModelProfiler,
    PerfModel,
    ThroughputModel,
    MemoryModel,
)

__all__ = [
    "GridInterpolator",
    "DataProfiler",
    "ShapeDistribution",
    "ModelProfiler",
    "PerfModel",
    "ThroughputModel",
    "MemoryModel",
]
