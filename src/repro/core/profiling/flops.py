"""Analytic FLOP / byte counting per architecture family.

The Profiling Engine (§3.2.1) profiles *attention* and *linear* operations
separately: "Attention operations are dependent on individual sequence
lengths ... In contrast, linear operations depend on the hidden size and can
be applied to the entire concatenated sequence at once."  We therefore split
every count into ``attn`` (per-instance-quadratic or recurrent) and ``lin``
(per-token linear) components.

All counts are *forward* FLOPs; training multiplies by 3 (backward ≈ 2×).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.common.types import FFNKind, LayerKind, ModelConfig

TRAIN_MULT = 3.0  # fwd + bwd(2x)


@dataclass(frozen=True)
class FlopCount:
    attn: float   # sequence-mixing FLOPs (quadratic / recurrent part)
    lin: float    # linear-layer FLOPs (projections, FFN, embed head)

    @property
    def total(self) -> float:
        return self.attn + self.lin

    def __add__(self, other: "FlopCount") -> "FlopCount":
        return FlopCount(self.attn + other.attn, self.lin + other.lin)

    def scale(self, s: float) -> "FlopCount":
        return FlopCount(self.attn * s, self.lin * s)


ZERO = FlopCount(0.0, 0.0)


def _attn_layer(cfg: ModelConfig, b: float, s: float, kv_len: float,
                causal: bool) -> FlopCount:
    h, kh, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    proj = 2.0 * b * s * d * (h + 2 * kh) * hd + 2.0 * b * s * h * hd * d
    if cfg.attention_kind == "sliding" and cfg.window_size:
        eff_kv = min(kv_len, cfg.window_size)
    else:
        eff_kv = kv_len
    score_av = 2.0 * 2.0 * b * s * eff_kv * h * hd
    if causal and s == kv_len and cfg.attention_kind != "sliding":
        score_av *= 0.5  # only the causal half is useful work
    return FlopCount(attn=score_av, lin=proj)


def _mamba_layer(cfg: ModelConfig, b: float, s: float) -> FlopCount:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    N = cfg.ssm_d_state
    R = max(1, -(-d // 16))
    lin = 2.0 * b * s * d * 2 * di          # in_proj
    lin += 2.0 * b * s * di * cfg.ssm_d_conv
    lin += 2.0 * b * s * di * (R + 2 * N)   # x_proj
    lin += 2.0 * b * s * R * di             # dt_proj
    lin += 2.0 * b * s * di * d             # out_proj
    attn = 6.0 * b * s * di * N             # selective scan
    return FlopCount(attn=attn, lin=lin)


def _rwkv_layer(cfg: ModelConfig, b: float, s: float) -> FlopCount:
    d, ff, m = cfg.d_model, cfg.d_ff, cfg.rwkv_head_dim
    lin = 2.0 * b * s * d * d * 5           # r,k,v,g,o
    lin += 2.0 * b * s * d * 5 * 32 * 2     # ddlerp lora
    lin += 2.0 * b * s * d * 64 * 2         # decay lora
    lin += 2.0 * b * s * (d * ff + ff * d + d * d)  # channel mix (+gate)
    attn = 6.0 * b * s * d * m              # wkv recurrence (state d x m)
    return FlopCount(attn=attn, lin=lin)


def _ffn_layer(cfg: ModelConfig, b: float, s: float, kind: FFNKind) -> FlopCount:
    d, ff = cfg.d_model, cfg.d_ff
    n_mat = 3 if cfg.activation in ("swiglu", "geglu") else 2
    if kind == FFNKind.MOE:
        lin = 2.0 * b * s * cfg.top_k * n_mat * d * ff
        lin += 2.0 * b * s * d * cfg.n_experts       # router
    else:
        lin = 2.0 * b * s * n_mat * d * ff
    return FlopCount(attn=0.0, lin=lin)


def module_flops(cfg: ModelConfig, batch: float, seq: float, *,
                 mode: str = "prefill", cache_len: float = 0.0) -> FlopCount:
    """Forward FLOPs for one step of the module.

    mode:
      train/prefill — process `seq` tokens (kv_len = seq)
      decode        — one new token against a cache of `cache_len`
    """
    if mode == "decode":
        s, kv = 1.0, max(1.0, cache_len)
    else:
        s, kv = float(seq), float(seq)
    b = float(batch)

    total = ZERO
    for lk, fk in zip(cfg.layer_kinds, cfg.ffn_kinds):
        if lk == LayerKind.ATTENTION:
            total = total + _attn_layer(cfg, b, s, kv, cfg.causal)
            total = total + _ffn_layer(cfg, b, s, fk)
        elif lk == LayerKind.MAMBA:
            total = total + _mamba_layer(cfg, b, s)
            total = total + _ffn_layer(cfg, b, s, fk)
        elif lk == LayerKind.RWKV6:
            total = total + _rwkv_layer(cfg, b, s)
    if cfg.has_lm_head and cfg.vocab_size:
        total = total + FlopCount(0.0, 2.0 * b * s * cfg.d_model * cfg.vocab_size)
    if mode == "train":
        total = total.scale(TRAIN_MULT)
    return total


def module_param_bytes(cfg: ModelConfig, bytes_per_param: int = 2) -> float:
    return float(cfg.param_count()) * bytes_per_param


def model_flops_6nd(cfg: ModelConfig, tokens: float) -> float:
    """The standard 6·N·D estimate (N = active params) for §Roofline."""
    return 6.0 * cfg.active_param_count() * tokens
