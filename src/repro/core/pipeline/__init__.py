from repro.core.pipeline.simulator import (
    PipelineTrace,
    simulate_1f1b,
    ideal_bubble_fraction,
)

__all__ = ["PipelineTrace", "simulate_1f1b", "ideal_bubble_fraction"]
