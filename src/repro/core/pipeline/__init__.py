from repro.core.pipeline.simulator import (
    BatchPipelineTrace,
    PipelineTrace,
    ideal_bubble_fraction,
    simulate_1f1b,
    simulate_1f1b_batch,
    simulate_bucket_ranks,
    simulate_bucket_ranks_batch,
)

__all__ = [
    "BatchPipelineTrace",
    "PipelineTrace",
    "ideal_bubble_fraction",
    "simulate_1f1b",
    "simulate_1f1b_batch",
    "simulate_bucket_ranks",
    "simulate_bucket_ranks_batch",
]
