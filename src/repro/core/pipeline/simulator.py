"""Discrete-event 1F1B pipeline simulator (paper Fig. 1 / Fig. 13).

Computes exact start/end times for every (stage, microbatch, fwd/bwd) op of
a 1F1B schedule given *per-microbatch, per-stage* durations — the
heterogeneous-cost generalization the paper studies.  Used to reproduce the
idle-time analysis (Fig. 13), stage-throughput distributions (Fig. 14) and
the end-to-end gains (Fig. 7) without hardware.

Two implementations share one definition of the schedule:

  * ``simulate_1f1b``       — the reference: a per-op event loop over one
                              (p, m) instance, recording the op list.
  * ``simulate_1f1b_batch`` — the production path: the same recurrence
                              evaluated as a vectorized wavefront over a
                              whole batch of instances at once (shape
                              ``(..., p, m)``, batched across e.g.
                              (trial, dp-rank)).  The search objectives and
                              the benchmark harness score through this one;
                              a property test pins it op-for-op to the
                              reference (`tests/test_simulator.py`).

See ``docs/simulator.md`` for the wavefront derivation and the bucket→rank
convention.

1F1B static order per stage s (0-based, p stages, m microbatches):
    warmup w_s = min(m, p - s) forwards, then alternate (bwd, fwd) until
    forwards are exhausted, then drain backwards.
Dependencies:
    F[s, i] after F[s-1, i];  B[s, i] after B[s+1, i] and after F[s, i].
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import List, Optional, Sequence, Tuple

import numpy as np

Op = Tuple[str, int, int, float, float]          # (kind, stage, mb, t0, t1)


@dataclass
class PipelineTrace:
    makespan: float
    stage_busy: np.ndarray           # (p,) total compute time per stage
    stage_idle: np.ndarray           # (p,) makespan - busy
    # op list (kind, stage, mb, t0, t1); None when recording was disabled
    # (the batched scoring path — see `record_ops`) so large runs don't
    # allocate B·p·m Python tuples nobody reads.
    ops: Optional[List[Op]] = None

    @property
    def total_idle(self) -> float:
        return float(self.stage_idle.sum())

    @property
    def idle_fraction(self) -> float:
        p = len(self.stage_busy)
        return self.total_idle / max(p * self.makespan, 1e-12)

    def stage_throughput(self, stage_flops: np.ndarray) -> np.ndarray:
        """FLOP/s per stage over pure compute time (Fig. 14 metric)."""
        return stage_flops / np.maximum(self.stage_busy, 1e-12)


def simulate_1f1b(fwd: np.ndarray, bwd: np.ndarray | None = None) -> PipelineTrace:
    """Reference event-loop simulator for one instance.

    fwd/bwd: (p, m) per-stage per-microbatch durations (bwd default 2x).
    Always records the op list — it is the ground truth the batched
    implementation is property-tested against, and the entry point the
    figure scripts use when they need per-op spans.
    """
    fwd = np.asarray(fwd, dtype=np.float64)
    p, m = fwd.shape
    bwd = 2.0 * fwd if bwd is None else np.asarray(bwd, dtype=np.float64)

    orders = _static_orders(p, m)

    f_end = np.full((p, m), -1.0)
    b_end = np.full((p, m), -1.0)
    stage_t = np.zeros(p)
    ptr = [0] * p
    ops: List[Op] = []

    remaining = sum(len(o) for o in orders)
    progress = True
    while remaining > 0:
        if not progress:
            raise RuntimeError("1F1B schedule deadlocked (bug)")
        progress = False
        for s in range(p):
            while ptr[s] < len(orders[s]):
                kind, i = orders[s][ptr[s]]
                if kind == "F":
                    dep = f_end[s - 1, i] if s > 0 else 0.0
                    if dep < 0:
                        break
                    t0 = max(stage_t[s], dep)
                    t1 = t0 + fwd[s, i]
                    f_end[s, i] = t1
                else:
                    dep = b_end[s + 1, i] if s < p - 1 else f_end[s, i]
                    if dep < 0 or f_end[s, i] < 0:
                        break
                    t0 = max(stage_t[s], dep)
                    t1 = t0 + bwd[s, i]
                    b_end[s, i] = t1
                stage_t[s] = t1
                ops.append((kind, s, i, t0, t1))
                ptr[s] += 1
                remaining -= 1
                progress = True
    makespan = float(b_end.max())
    busy = fwd.sum(axis=1) + bwd.sum(axis=1)
    idle = makespan - busy
    return PipelineTrace(makespan, busy, idle, ops)


# --------------------------------------------------------------------- #
# batched wavefront implementation
# --------------------------------------------------------------------- #
@lru_cache(maxsize=None)
def _static_orders(p: int, m: int) -> Tuple[Tuple[Tuple[str, int], ...], ...]:
    """The 1F1B static op order of every stage, built once per (p, m)."""
    orders = []
    for s in range(p):
        w = min(m, p - s)
        seq: List[Tuple[str, int]] = [("F", i) for i in range(w)]
        nf, nb = w, 0
        while nf < m:
            seq.append(("B", nb)); nb += 1
            seq.append(("F", nf)); nf += 1
        while nb < m:
            seq.append(("B", nb)); nb += 1
        orders.append(tuple(seq))
    return tuple(orders)


@lru_cache(maxsize=None)
def _wavefront_order(p: int, m: int) -> Tuple[Tuple[int, bool, int], ...]:
    """A topological order of the 1F1B op DAG, shared by every instance of
    shape (p, m) — the durations never change the *structure*, only the
    times, which is what makes the batched evaluation possible.

    Walk op positions j = 0..2m−1 in lockstep across stages ("passes").
    Within the static order, the cross-stage dependency of the op at
    position j always sits at position j−1 or j of its neighbour stage:

      * F[s, i] ← F[s−1, i]: same position during warmup (both stages are
        in their first w ops), one earlier in steady state — so passes with
        j < m (where every same-position dependency is a warmup forward)
        resolve stage 0 first;
      * B[s, i] ← B[s+1, i]: one earlier in steady state, same position in
        the drain (which only occupies positions j ≥ m) — so those passes
        resolve the last stage first.

    Hence: pass j < m walks stages top-down, pass j ≥ m bottom-up, and
    every dependency is evaluated before its dependent.
    """
    orders = _static_orders(p, m)
    topo: List[Tuple[int, bool, int]] = []
    for j in range(2 * m):
        stages = range(p) if j < m else range(p - 1, -1, -1)
        for s in stages:
            kind, i = orders[s][j]
            topo.append((s, kind == "F", i))
    return tuple(topo)


@dataclass
class BatchPipelineTrace:
    """Vectorized `PipelineTrace` over a batch of (p, m) instances.

    All arrays carry the input's leading batch shape ``lead`` (e.g.
    ``(n_trials, dp)``): makespan is ``lead``, stage_busy/stage_idle are
    ``lead + (p,)``.  Op start/end times are only materialized under
    ``record_ops=True`` as four ``lead + (p, m)`` arrays — never as
    per-op Python tuples.
    """
    makespan: np.ndarray
    stage_busy: np.ndarray
    stage_idle: np.ndarray
    f_start: Optional[np.ndarray] = None
    f_end: Optional[np.ndarray] = None
    b_start: Optional[np.ndarray] = None
    b_end: Optional[np.ndarray] = None

    @property
    def total_idle(self) -> np.ndarray:
        return self.stage_idle.sum(axis=-1)

    @property
    def idle_fraction(self) -> np.ndarray:
        p = self.stage_busy.shape[-1]
        return self.total_idle / np.maximum(p * self.makespan, 1e-12)

    def trace(self, index) -> PipelineTrace:
        """Scalar view of one instance (index into the leading batch
        shape).  Ops, when recorded, come back in static per-stage order
        rather than global start-time order."""
        ops = None
        if self.f_end is not None:
            p, m = self.f_end[index].shape
            ops = []
            for s, order in enumerate(_static_orders(p, m)):
                for kind, i in order:
                    t0s, t1s = ((self.f_start, self.f_end) if kind == "F"
                                else (self.b_start, self.b_end))
                    ops.append((kind, s, i, float(t0s[index][s, i]),
                                float(t1s[index][s, i])))
        return PipelineTrace(float(self.makespan[index]),
                             self.stage_busy[index],
                             self.stage_idle[index], ops)


def simulate_1f1b_batch(fwd: np.ndarray, bwd: np.ndarray | None = None,
                        *, record_ops: bool = False) -> BatchPipelineTrace:
    """Vectorized 1F1B simulation of a whole batch of instances.

    fwd/bwd: ``(..., p, m)`` per-stage per-microbatch durations (bwd
    default 2×fwd); the leading axes are independent instances — e.g.
    ``(n_trials, dp)`` when scoring Monte-Carlo trials across data-parallel
    ranks.  One call replaces ``prod(lead)`` reference-loop runs: the op
    DAG is identical for every instance, so each node of the cached
    wavefront order (`_wavefront_order`) is evaluated as a single
    max/add over the batch axis.  Start/end times equal the reference's
    bit-for-bit (same max/add, same association).

    >>> import numpy as np
    >>> fwd = np.ones((3, 2, 4))                  # 3 instances, p=2, m=4
    >>> tr = simulate_1f1b_batch(fwd)             # bwd defaults to 2*fwd
    >>> tr.makespan.shape
    (3,)
    >>> float(tr.makespan[0])                     # (m + p - 1) * 3
    15.0
    """
    fwd = np.asarray(fwd, dtype=np.float64)
    if fwd.ndim < 2:
        raise ValueError(f"fwd must be (..., p, m), got shape {fwd.shape}")
    lead = fwd.shape[:-2]
    p, m = fwd.shape[-2:]
    bwd = 2.0 * fwd if bwd is None else np.asarray(bwd, dtype=np.float64)
    if bwd.shape != fwd.shape:
        raise ValueError(f"bwd shape {bwd.shape} != fwd shape {fwd.shape}")
    fwd2 = np.ascontiguousarray(fwd.reshape((-1, p, m)))
    bwd2 = np.ascontiguousarray(bwd.reshape((-1, p, m)))
    # (p, m, B) layout: each op's batch vector is contiguous
    F = np.ascontiguousarray(np.moveaxis(fwd2, 0, -1))
    W = np.ascontiguousarray(np.moveaxis(bwd2, 0, -1))
    B = F.shape[-1]

    f_end = np.zeros((p, m, B))
    b_end = np.zeros((p, m, B))
    stage_t = np.zeros((p, B))
    rec = (np.zeros((2, 2, p, m, B)) if record_ops else None)  # [F/B][t0/t1]

    for s, is_f, i in _wavefront_order(p, m):
        if is_f:
            if s > 0:
                t0 = np.maximum(stage_t[s], f_end[s - 1, i])
            else:
                t0 = stage_t[s].copy()
            t1 = t0 + F[s, i]
            f_end[s, i] = t1
        else:
            dep = b_end[s + 1, i] if s < p - 1 else f_end[s, i]
            t0 = np.maximum(stage_t[s], dep)
            t1 = t0 + W[s, i]
            b_end[s, i] = t1
        stage_t[s] = t1
        if rec is not None:
            rec[0 if is_f else 1, :, s, i] = (t0, t1)

    makespan = b_end.reshape((p * m, B)).max(axis=0).reshape(lead)
    # summed over the contiguous m axis of the (B, p, m) layout, separately
    # per phase, so the float association (numpy's pairwise reduction)
    # matches the reference's fwd.sum(axis=1) + bwd.sum(axis=1) bit-for-bit
    busy = (fwd2.sum(axis=-1) + bwd2.sum(axis=-1)).reshape(lead + (p,))
    idle = makespan[..., None] - busy

    def _times(a):
        return np.moveaxis(a, -1, 0).reshape(lead + (p, m))

    return BatchPipelineTrace(
        makespan, busy, idle,
        f_start=_times(rec[0, 0]) if record_ops else None,
        f_end=_times(rec[0, 1]) if record_ops else None,
        b_start=_times(rec[1, 0]) if record_ops else None,
        b_end=_times(rec[1, 1]) if record_ops else None)


# --------------------------------------------------------------------- #
# scheduler-bucket → pipeline-rank convention
# --------------------------------------------------------------------- #
def bucket_rank_durations(e_b: np.ndarray, l_b: np.ndarray, *, n_mb: int,
                          dp: int, e_pp: int, l_pp: int) -> np.ndarray:
    """Scheduler bucket durations → per-rank stage rows, vectorized.

    e_b/l_b: ``(..., n_mb · dp)`` per-bucket encoder/LLM durations (already
    per-stage, i.e. divided by the module's PP degree).  Returns
    ``(..., dp, p, n_mb)`` rows where ``p = e_pp + l_pp``: bucket
    ``i·dp + r`` is microbatch i of data-parallel rank r (the order the
    data loader consumes `ScheduleOutput.groups`), each encoder stage takes
    the bucket's encoder value and each LLM stage its LLM value.
    """
    e_b = np.asarray(e_b, dtype=np.float64)
    l_b = np.asarray(l_b, dtype=np.float64)
    lead = l_b.shape[:-1]
    p = e_pp + l_pp
    rows = np.empty(lead + (dp, p, n_mb))
    # (..., n_mb·dp) → (..., n_mb, dp) → (..., dp, n_mb); broadcast over
    # the module's stages
    l_ri = np.moveaxis(l_b.reshape(lead + (n_mb, dp)), -1, -2)
    rows[..., e_pp:, :] = l_ri[..., None, :]
    if e_pp:
        e_ri = np.moveaxis(e_b.reshape(lead + (n_mb, dp)), -1, -2)
        rows[..., :e_pp, :] = e_ri[..., None, :]
    return rows


def simulate_bucket_ranks_batch(e_b: np.ndarray, l_b: np.ndarray, *,
                                n_mb: int, dp: int, e_pp: int, l_pp: int,
                                bwd_over_fwd: float = 2.0,
                                backward: bool = True,
                                record_ops: bool = False) -> BatchPipelineTrace:
    """Batched 1F1B traces for scheduler buckets; see `simulate_bucket_ranks`
    for the convention.  e_b/l_b may carry leading batch axes (e.g. one per
    Monte-Carlo trial); the result's batch shape is ``lead + (dp,)`` and
    the slowest rank per instance is ``out.makespan.max(axis=-1)``.
    """
    rows = bucket_rank_durations(e_b, l_b, n_mb=n_mb, dp=dp, e_pp=e_pp,
                                 l_pp=l_pp)
    if backward:
        fwd = rows / (1.0 + bwd_over_fwd)
        bwd = bwd_over_fwd * fwd
    else:
        fwd, bwd = rows, 0.0 * rows
    return simulate_1f1b_batch(fwd, bwd, record_ops=record_ops)


def simulate_bucket_ranks(e_b: np.ndarray, l_b: np.ndarray, *, n_mb: int,
                          dp: int, e_pp: int, l_pp: int,
                          bwd_over_fwd: float = 2.0, backward: bool = True,
                          record_ops: bool = False):
    """Per-rank 1F1B traces for m = n_mb · dp scheduler buckets.

    This is THE convention shared by the search objectives
    (`objective._SamplingObjective`) and the benchmark harness
    (`benchmarks.common.simulate_iteration`) — keep it in one place so
    predicted and "ground truth" simulations can never drift:

      * bucket i·dp + r is microbatch i of data-parallel rank r (the order
        the data loader consumes `ScheduleOutput.groups`);
      * bucket durations are per-stage (already divided by the module's PP
        degree): each of the module's stages takes the bucket value as-is;
      * with `backward`, durations are full fwd+bwd cost and are split
        1 : bwd_over_fwd over the 1F1B phases (so a homogeneous batch
        reproduces the closed form (n_mb + p − 1) · c); without, they are
        forward-only.

    Yields one `PipelineTrace` per rank (all dp ranks are simulated in a
    single `simulate_1f1b_batch` call; per-op spans only with
    `record_ops=True`).
    """
    batch = simulate_bucket_ranks_batch(
        e_b, l_b, n_mb=n_mb, dp=dp, e_pp=e_pp, l_pp=l_pp,
        bwd_over_fwd=bwd_over_fwd, backward=backward, record_ops=record_ops)
    for r in range(dp):
        yield batch.trace(r)


def ideal_bubble_fraction(p: int, m: int) -> float:
    """Theoretical 1F1B bubble (p−1)/m ... /(m + p − 1) of the makespan for
    homogeneous microbatches (paper cites (p−1)/m [Megatron]).

    >>> ideal_bubble_fraction(4, 12)
    0.2
    """
    return (p - 1) / (m + p - 1)
