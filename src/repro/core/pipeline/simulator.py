"""Discrete-event 1F1B pipeline simulator (paper Fig. 1 / Fig. 13).

Computes exact start/end times for every (stage, microbatch, fwd/bwd) op of
a 1F1B schedule given *per-microbatch, per-stage* durations — the
heterogeneous-cost generalization the paper studies.  Used to reproduce the
idle-time analysis (Fig. 13), stage-throughput distributions (Fig. 14) and
the end-to-end gains (Fig. 7) without hardware.

1F1B static order per stage s (0-based, p stages, m microbatches):
    warmup w_s = min(m, p - s) forwards, then alternate (bwd, fwd) until
    forwards are exhausted, then drain backwards.
Dependencies:
    F[s, i] after F[s-1, i];  B[s, i] after B[s+1, i] and after F[s, i].
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Sequence, Tuple

import numpy as np


@dataclass
class PipelineTrace:
    makespan: float
    stage_busy: np.ndarray           # (p,) total compute time per stage
    stage_idle: np.ndarray           # (p,) makespan - busy
    ops: List[Tuple[str, int, int, float, float]]  # (kind, stage, mb, t0, t1)

    @property
    def total_idle(self) -> float:
        return float(self.stage_idle.sum())

    @property
    def idle_fraction(self) -> float:
        p = len(self.stage_busy)
        return self.total_idle / max(p * self.makespan, 1e-12)

    def stage_throughput(self, stage_flops: np.ndarray) -> np.ndarray:
        """FLOP/s per stage over pure compute time (Fig. 14 metric)."""
        return stage_flops / np.maximum(self.stage_busy, 1e-12)


def simulate_1f1b(fwd: np.ndarray, bwd: np.ndarray | None = None) -> PipelineTrace:
    """fwd/bwd: (p, m) per-stage per-microbatch durations (bwd default 2x)."""
    fwd = np.asarray(fwd, dtype=np.float64)
    p, m = fwd.shape
    bwd = 2.0 * fwd if bwd is None else np.asarray(bwd, dtype=np.float64)

    # static 1F1B op order per stage
    orders: List[List[Tuple[str, int]]] = []
    for s in range(p):
        w = min(m, p - s)
        seq: List[Tuple[str, int]] = [("F", i) for i in range(w)]
        nf, nb = w, 0
        while nf < m:
            seq.append(("B", nb)); nb += 1
            seq.append(("F", nf)); nf += 1
        while nb < m:
            seq.append(("B", nb)); nb += 1
        orders.append(seq)

    f_end = np.full((p, m), -1.0)
    b_end = np.full((p, m), -1.0)
    stage_t = np.zeros(p)
    ptr = [0] * p
    ops: List[Tuple[str, int, int, float, float]] = []

    remaining = sum(len(o) for o in orders)
    progress = True
    while remaining > 0:
        if not progress:
            raise RuntimeError("1F1B schedule deadlocked (bug)")
        progress = False
        for s in range(p):
            while ptr[s] < len(orders[s]):
                kind, i = orders[s][ptr[s]]
                if kind == "F":
                    dep = f_end[s - 1, i] if s > 0 else 0.0
                    if dep < 0:
                        break
                    t0 = max(stage_t[s], dep)
                    t1 = t0 + fwd[s, i]
                    f_end[s, i] = t1
                else:
                    dep = b_end[s + 1, i] if s < p - 1 else f_end[s, i]
                    if dep < 0 or f_end[s, i] < 0:
                        break
                    t0 = max(stage_t[s], dep)
                    t1 = t0 + bwd[s, i]
                    b_end[s, i] = t1
                stage_t[s] = t1
                ops.append((kind, s, i, t0, t1))
                ptr[s] += 1
                remaining -= 1
                progress = True
    makespan = float(b_end.max())
    busy = fwd.sum(axis=1) + bwd.sum(axis=1)
    idle = makespan - busy
    return PipelineTrace(makespan, busy, idle, ops)


def simulate_bucket_ranks(e_b: np.ndarray, l_b: np.ndarray, *, n_mb: int,
                          dp: int, e_pp: int, l_pp: int,
                          bwd_over_fwd: float = 2.0, backward: bool = True):
    """Per-rank 1F1B traces for m = n_mb · dp scheduler buckets.

    This is THE convention shared by the search objectives
    (`objective._SamplingObjective.trial_makespan`) and the benchmark
    harness (`benchmarks.common.simulate_iteration`) — keep it in one
    place so predicted and "ground truth" simulations can never drift:

      * bucket i·dp + r is microbatch i of data-parallel rank r (the order
        the data loader consumes `ScheduleOutput.groups`);
      * bucket durations are per-stage (already divided by the module's PP
        degree): each of the module's stages takes the bucket value as-is;
      * with `backward`, durations are full fwd+bwd cost and are split
        1 : bwd_over_fwd over the 1F1B phases (so a homogeneous batch
        reproduces the closed form (n_mb + p − 1) · c); without, they are
        forward-only.

    Yields one `PipelineTrace` per rank.
    """
    p = e_pp + l_pp
    for r in range(dp):
        rows = np.empty((p, n_mb))
        for i in range(n_mb):
            b = i * dp + r
            rows[:e_pp, i] = e_b[b]
            rows[e_pp:, i] = l_b[b]
        if backward:
            fwd = rows / (1.0 + bwd_over_fwd)
            bwd = bwd_over_fwd * fwd
        else:
            fwd, bwd = rows, 0.0 * rows
        yield simulate_1f1b(fwd, bwd)


def ideal_bubble_fraction(p: int, m: int) -> float:
    """Theoretical 1F1B bubble (p−1)/m ... /(m + p − 1) of the makespan for
    homogeneous microbatches (paper cites (p−1)/m [Megatron])."""
    return (p - 1) / (m + p - 1)
