"""Discrete-event pipeline-schedule simulator (paper Fig. 1 / Fig. 13).

Computes exact start/end times for every (stage, microbatch, fwd/bwd) op of
a pipeline schedule given *per-microbatch, per-stage* durations — the
heterogeneous-cost generalization the paper studies.  Used to reproduce the
idle-time analysis (Fig. 13), stage-throughput distributions (Fig. 14) and
the end-to-end gains (Fig. 7) without hardware.

Two implementations share one definition of each schedule:

  * ``simulate_1f1b``       — the reference: a per-op event loop over one
                              (p, m) instance, recording the op list.
  * ``simulate_1f1b_batch`` — the production path: the same recurrence
                              evaluated as a vectorized wavefront over a
                              whole batch of instances at once (shape
                              ``(..., p, m)``, batched across e.g.
                              (trial, dp-rank)).  The search objectives and
                              the benchmark harness score through this one;
                              a property test pins it op-for-op to the
                              reference (`tests/test_simulator.py`).

Beyond 1F1B, the same split generalizes to the **schedule families** the
optimizer searches over (``docs/schedules.md``): the op DAG of a schedule
is *data* — a cached `ScheduleTopology` of (rank order, dependency lists,
topological evaluation order) — so every family shares one reference event
loop (`reference_schedule_times`) and one batched wavefront
(`simulate_schedule_batch`), pinned op-for-op against each other:

  * ``"interleaved"``  — Megatron-style interleaved 1F1B with ``v`` virtual
    model chunks per rank (`interleaved_topology`); the warmup/drain bubble
    shrinks by ``v``.  Needs ``m % p == 0``.
  * ``"encoder_fill"`` — Optimus-style encoder-in-bubble
    (`encoder_fill_topology`): the encoder is replicated across the LLM's
    ranks, each microbatch's encoder work splits into p chunks scheduled
    into the warmup (fwd chunks) and drain (bwd chunks) bubbles.

See ``docs/simulator.md`` for the wavefront derivation and the bucket→rank
convention.

1F1B static order per stage s (0-based, p stages, m microbatches):
    warmup w_s = min(m, p - s) forwards, then alternate (bwd, fwd) until
    forwards are exhausted, then drain backwards.
Dependencies:
    F[s, i] after F[s-1, i];  B[s, i] after B[s+1, i] and after F[s, i].
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# kept in sync with repro.core.optimizer.space.VIRTUAL_CHUNKS (this module
# stays import-free of the optimizer layer)
DEFAULT_VIRTUAL_CHUNKS = 2

Op = Tuple[str, int, int, float, float]          # (kind, stage, mb, t0, t1)


@dataclass
class PipelineTrace:
    makespan: float
    stage_busy: np.ndarray           # (p,) total compute time per stage
    stage_idle: np.ndarray           # (p,) makespan - busy
    # op list (kind, stage, mb, t0, t1); None when recording was disabled
    # (the batched scoring path — see `record_ops`) so large runs don't
    # allocate B·p·m Python tuples nobody reads.
    ops: Optional[List[Op]] = None

    @property
    def total_idle(self) -> float:
        return float(self.stage_idle.sum())

    @property
    def idle_fraction(self) -> float:
        p = len(self.stage_busy)
        return self.total_idle / max(p * self.makespan, 1e-12)

    def stage_throughput(self, stage_flops: np.ndarray) -> np.ndarray:
        """FLOP/s per stage over pure compute time (Fig. 14 metric)."""
        return stage_flops / np.maximum(self.stage_busy, 1e-12)


def simulate_1f1b(fwd: np.ndarray, bwd: np.ndarray | None = None) -> PipelineTrace:
    """Reference event-loop simulator for one instance.

    fwd/bwd: (p, m) per-stage per-microbatch durations (bwd default 2x).
    Always records the op list — it is the ground truth the batched
    implementation is property-tested against, and the entry point the
    figure scripts use when they need per-op spans.
    """
    fwd = np.asarray(fwd, dtype=np.float64)
    p, m = fwd.shape
    bwd = 2.0 * fwd if bwd is None else np.asarray(bwd, dtype=np.float64)

    orders = _static_orders(p, m)

    f_end = np.full((p, m), -1.0)
    b_end = np.full((p, m), -1.0)
    stage_t = np.zeros(p)
    ptr = [0] * p
    ops: List[Op] = []

    remaining = sum(len(o) for o in orders)
    progress = True
    while remaining > 0:
        if not progress:
            raise RuntimeError("1F1B schedule deadlocked (bug)")
        progress = False
        for s in range(p):
            while ptr[s] < len(orders[s]):
                kind, i = orders[s][ptr[s]]
                if kind == "F":
                    dep = f_end[s - 1, i] if s > 0 else 0.0
                    if dep < 0:
                        break
                    t0 = max(stage_t[s], dep)
                    t1 = t0 + fwd[s, i]
                    f_end[s, i] = t1
                else:
                    dep = b_end[s + 1, i] if s < p - 1 else f_end[s, i]
                    if dep < 0 or f_end[s, i] < 0:
                        break
                    t0 = max(stage_t[s], dep)
                    t1 = t0 + bwd[s, i]
                    b_end[s, i] = t1
                stage_t[s] = t1
                ops.append((kind, s, i, t0, t1))
                ptr[s] += 1
                remaining -= 1
                progress = True
    makespan = float(b_end.max())
    busy = fwd.sum(axis=1) + bwd.sum(axis=1)
    idle = makespan - busy
    return PipelineTrace(makespan, busy, idle, ops)


# --------------------------------------------------------------------- #
# batched wavefront implementation
# --------------------------------------------------------------------- #
@lru_cache(maxsize=None)
def _static_orders(p: int, m: int) -> Tuple[Tuple[Tuple[str, int], ...], ...]:
    """The 1F1B static op order of every stage, built once per (p, m)."""
    orders = []
    for s in range(p):
        w = min(m, p - s)
        seq: List[Tuple[str, int]] = [("F", i) for i in range(w)]
        nf, nb = w, 0
        while nf < m:
            seq.append(("B", nb)); nb += 1
            seq.append(("F", nf)); nf += 1
        while nb < m:
            seq.append(("B", nb)); nb += 1
        orders.append(tuple(seq))
    return tuple(orders)


@lru_cache(maxsize=None)
def _wavefront_order(p: int, m: int) -> Tuple[Tuple[int, bool, int], ...]:
    """A topological order of the 1F1B op DAG, shared by every instance of
    shape (p, m) — the durations never change the *structure*, only the
    times, which is what makes the batched evaluation possible.

    Walk op positions j = 0..2m−1 in lockstep across stages ("passes").
    Within the static order, the cross-stage dependency of the op at
    position j always sits at position j−1 or j of its neighbour stage:

      * F[s, i] ← F[s−1, i]: same position during warmup (both stages are
        in their first w ops), one earlier in steady state — so passes with
        j < m (where every same-position dependency is a warmup forward)
        resolve stage 0 first;
      * B[s, i] ← B[s+1, i]: one earlier in steady state, same position in
        the drain (which only occupies positions j ≥ m) — so those passes
        resolve the last stage first.

    Hence: pass j < m walks stages top-down, pass j ≥ m bottom-up, and
    every dependency is evaluated before its dependent.
    """
    orders = _static_orders(p, m)
    topo: List[Tuple[int, bool, int]] = []
    for j in range(2 * m):
        stages = range(p) if j < m else range(p - 1, -1, -1)
        for s in stages:
            kind, i = orders[s][j]
            topo.append((s, kind == "F", i))
    return tuple(topo)


@dataclass
class BatchPipelineTrace:
    """Vectorized `PipelineTrace` over a batch of (p, m) instances.

    All arrays carry the input's leading batch shape ``lead`` (e.g.
    ``(n_trials, dp)``): makespan is ``lead``, stage_busy/stage_idle are
    ``lead + (p,)``.  Op start/end times are only materialized under
    ``record_ops=True`` as four ``lead + (p, m)`` arrays — never as
    per-op Python tuples.
    """
    makespan: np.ndarray
    stage_busy: np.ndarray
    stage_idle: np.ndarray
    f_start: Optional[np.ndarray] = None
    f_end: Optional[np.ndarray] = None
    b_start: Optional[np.ndarray] = None
    b_end: Optional[np.ndarray] = None
    # generic-schedule recording (`simulate_schedule_batch`): per-op times
    # as ``lead + (n_ops,)`` arrays, op ids indexing the instance's
    # `ScheduleTopology.labels`.  The (p, m)-shaped f_*/b_* fields above
    # stay 1F1B-only (the op grid of the other families isn't (p, m)).
    op_start: Optional[np.ndarray] = None
    op_end: Optional[np.ndarray] = None

    @property
    def total_idle(self) -> np.ndarray:
        return self.stage_idle.sum(axis=-1)

    @property
    def idle_fraction(self) -> np.ndarray:
        p = self.stage_busy.shape[-1]
        return self.total_idle / np.maximum(p * self.makespan, 1e-12)

    def trace(self, index) -> PipelineTrace:
        """Scalar view of one instance (index into the leading batch
        shape).  Ops, when recorded, come back in static per-stage order
        rather than global start-time order."""
        ops = None
        if self.f_end is not None:
            p, m = self.f_end[index].shape
            ops = []
            for s, order in enumerate(_static_orders(p, m)):
                for kind, i in order:
                    t0s, t1s = ((self.f_start, self.f_end) if kind == "F"
                                else (self.b_start, self.b_end))
                    ops.append((kind, s, i, float(t0s[index][s, i]),
                                float(t1s[index][s, i])))
        return PipelineTrace(float(self.makespan[index]),
                             self.stage_busy[index],
                             self.stage_idle[index], ops)


def simulate_1f1b_batch(fwd: np.ndarray, bwd: np.ndarray | None = None,
                        *, record_ops: bool = False) -> BatchPipelineTrace:
    """Vectorized 1F1B simulation of a whole batch of instances.

    fwd/bwd: ``(..., p, m)`` per-stage per-microbatch durations (bwd
    default 2×fwd); the leading axes are independent instances — e.g.
    ``(n_trials, dp)`` when scoring Monte-Carlo trials across data-parallel
    ranks.  One call replaces ``prod(lead)`` reference-loop runs: the op
    DAG is identical for every instance, so each node of the cached
    wavefront order (`_wavefront_order`) is evaluated as a single
    max/add over the batch axis.  Start/end times equal the reference's
    bit-for-bit (same max/add, same association).

    >>> import numpy as np
    >>> fwd = np.ones((3, 2, 4))                  # 3 instances, p=2, m=4
    >>> tr = simulate_1f1b_batch(fwd)             # bwd defaults to 2*fwd
    >>> tr.makespan.shape
    (3,)
    >>> float(tr.makespan[0])                     # (m + p - 1) * 3
    15.0
    """
    fwd = np.asarray(fwd, dtype=np.float64)
    if fwd.ndim < 2:
        raise ValueError(f"fwd must be (..., p, m), got shape {fwd.shape}")
    lead = fwd.shape[:-2]
    p, m = fwd.shape[-2:]
    bwd = 2.0 * fwd if bwd is None else np.asarray(bwd, dtype=np.float64)
    if bwd.shape != fwd.shape:
        raise ValueError(f"bwd shape {bwd.shape} != fwd shape {fwd.shape}")
    fwd2 = np.ascontiguousarray(fwd.reshape((-1, p, m)))
    bwd2 = np.ascontiguousarray(bwd.reshape((-1, p, m)))
    # (p, m, B) layout: each op's batch vector is contiguous
    F = np.ascontiguousarray(np.moveaxis(fwd2, 0, -1))
    W = np.ascontiguousarray(np.moveaxis(bwd2, 0, -1))
    B = F.shape[-1]

    f_end = np.zeros((p, m, B))
    b_end = np.zeros((p, m, B))
    stage_t = np.zeros((p, B))
    rec = (np.zeros((2, 2, p, m, B)) if record_ops else None)  # [F/B][t0/t1]

    for s, is_f, i in _wavefront_order(p, m):
        if is_f:
            if s > 0:
                t0 = np.maximum(stage_t[s], f_end[s - 1, i])
            else:
                t0 = stage_t[s].copy()
            t1 = t0 + F[s, i]
            f_end[s, i] = t1
        else:
            dep = b_end[s + 1, i] if s < p - 1 else f_end[s, i]
            t0 = np.maximum(stage_t[s], dep)
            t1 = t0 + W[s, i]
            b_end[s, i] = t1
        stage_t[s] = t1
        if rec is not None:
            rec[0 if is_f else 1, :, s, i] = (t0, t1)

    makespan = b_end.reshape((p * m, B)).max(axis=0).reshape(lead)
    # summed over the contiguous m axis of the (B, p, m) layout, separately
    # per phase, so the float association (numpy's pairwise reduction)
    # matches the reference's fwd.sum(axis=1) + bwd.sum(axis=1) bit-for-bit
    busy = (fwd2.sum(axis=-1) + bwd2.sum(axis=-1)).reshape(lead + (p,))
    idle = makespan[..., None] - busy

    def _times(a):
        return np.moveaxis(a, -1, 0).reshape(lead + (p, m))

    return BatchPipelineTrace(
        makespan, busy, idle,
        f_start=_times(rec[0, 0]) if record_ops else None,
        f_end=_times(rec[0, 1]) if record_ops else None,
        b_start=_times(rec[1, 0]) if record_ops else None,
        b_end=_times(rec[1, 1]) if record_ops else None)


# --------------------------------------------------------------------- #
# schedule-family topologies (interleaved, encoder_fill)
# --------------------------------------------------------------------- #
# duration-source codes for ScheduleTopology.src
_SRC_FWD, _SRC_BWD, _SRC_EFWD, _SRC_EBWD = 0, 1, 2, 3


@dataclass(frozen=True)
class ScheduleTopology:
    """One schedule's op DAG as data, cached per (schedule, p, m, v).

    Every op is a node: ``labels[o] = (kind, rank, chunk, mb)`` with kind in
    {"F", "B", "EF", "EB"}.  ``rank_orders[r]`` is rank r's static execution
    order (op ids), ``deps[o]`` its cross-op dependencies, and ``order`` a
    linear extension of deps ∪ rank chains — the evaluation sequence both
    the reference event loop and the batched wavefront walk, so their
    max/add operands (and hence their float results) are identical.
    ``src/row/col/scale`` gather each op's duration from the caller's
    ``(p, m)`` arrays: ``dur[o] = arrays[src[o]][row[o], col[o]] · scale[o]``.
    """
    schedule: str
    p: int
    m: int
    v: int
    labels: Tuple[Tuple[str, int, int, int], ...]
    rank_orders: Tuple[Tuple[int, ...], ...]
    deps: Tuple[Tuple[int, ...], ...]
    order: Tuple[int, ...]
    rank: np.ndarray
    src: np.ndarray
    row: np.ndarray
    col: np.ndarray
    scale: np.ndarray

    @property
    def n_ops(self) -> int:
        return len(self.labels)


def _linear_order(rank_orders, deps, n_ops: int) -> Tuple[int, ...]:
    """Deterministic linear extension of deps ∪ rank chains (Kahn, smallest
    op id first).  Raises on a cyclic schedule — a topology-construction
    bug, caught at cache-build time rather than as a silent deadlock."""
    import heapq
    succ: List[List[int]] = [[] for _ in range(n_ops)]
    indeg = [0] * n_ops
    for o, ds in enumerate(deps):
        for d in ds:
            succ[d].append(o)
            indeg[o] += 1
    for seq in rank_orders:
        for a, b in zip(seq, seq[1:]):
            succ[a].append(b)
            indeg[b] += 1
    heap = [o for o in range(n_ops) if indeg[o] == 0]
    heapq.heapify(heap)
    out: List[int] = []
    while heap:
        o = heapq.heappop(heap)
        out.append(o)
        for s in succ[o]:
            indeg[s] -= 1
            if indeg[s] == 0:
                heapq.heappush(heap, s)
    if len(out) != n_ops:
        raise RuntimeError("schedule topology is cyclic (bug)")
    return tuple(out)


def _pack_topology(schedule: str, p: int, m: int, v: int, labels, rank_orders,
                   deps, srcs, rows, cols, scales) -> ScheduleTopology:
    return ScheduleTopology(
        schedule, p, m, v, tuple(labels),
        tuple(tuple(s) for s in rank_orders),
        tuple(tuple(d) for d in deps),
        _linear_order(rank_orders, deps, len(labels)),
        rank=np.array([lb[1] for lb in labels], dtype=np.int64),
        src=np.asarray(srcs, dtype=np.int64),
        row=np.asarray(rows, dtype=np.int64),
        col=np.asarray(cols, dtype=np.int64),
        scale=np.asarray(scales, dtype=np.float64))


@lru_cache(maxsize=None)
def interleaved_topology(p: int, m: int,
                         v: int = DEFAULT_VIRTUAL_CHUNKS) -> ScheduleTopology:
    """Megatron-style interleaved 1F1B with ``v`` virtual chunks per rank.

    Virtual stage k = c·p + s lives on rank s = k mod p; microbatches are
    walked in groups of p (``m % p == 0`` required).  Rank s runs
    ``min(m·v, 2(p−s−1) + (v−1)p)`` warmup forwards, then steady (F, B)
    pairs, then drains.  Per-chunk durations are the rank's stage duration
    divided by v (``scale = 1/v``): the same layers, cut v ways.

    Dependencies: F[c,s,i] ← F[c,s−1,i] (within chunk) or F[c−1,p−1,i]
    (chunk boundary); B mirrors downward, rooted at its own F[v−1,p−1,i];
    every B[c,s,i] also requires its own F[c,s,i].
    """
    if p < 1 or m < 1:
        raise ValueError(f"need p, m >= 1, got p={p}, m={m}")
    if v < 2:
        raise ValueError(f"interleaved needs v >= 2 chunks, got {v}")
    if m % p:
        raise ValueError(f"interleaved needs m % p == 0, got m={m}, p={p}")
    total = m * v                       # virtual microbatches per rank
    labels, srcs, rows, cols, scales = [], [], [], [], []
    fid: Dict[Tuple[int, int, int], int] = {}
    bid: Dict[Tuple[int, int, int], int] = {}

    def _add(kind, s, c, i, src):
        labels.append((kind, s, c, i))
        srcs.append(src); rows.append(s); cols.append(i)
        scales.append(1.0 / v)
        return len(labels) - 1

    for c in range(v):
        for s in range(p):
            for i in range(m):
                fid[(c, s, i)] = _add("F", s, c, i, _SRC_FWD)
    for c in range(v):
        for s in range(p):
            for i in range(m):
                bid[(c, s, i)] = _add("B", s, c, i, _SRC_BWD)

    deps: List[List[int]] = [[] for _ in labels]
    for (c, s, i), o in fid.items():
        if s > 0:
            deps[o].append(fid[(c, s - 1, i)])
        elif c > 0:
            deps[o].append(fid[(c - 1, p - 1, i)])
    for (c, s, i), o in bid.items():
        deps[o].append(fid[(c, s, i)])
        if s < p - 1:
            deps[o].append(bid[(c, s + 1, i)])
        elif c < v - 1:
            deps[o].append(bid[(c + 1, 0, i)])

    def _vmb(x: int, forward: bool) -> Tuple[int, int]:
        """x-th virtual microbatch of a rank → (chunk, microbatch)."""
        within = x % (p * v)
        c = within // p
        if not forward:
            c = v - 1 - c
        return c, (x // (p * v)) * p + within % p

    rank_orders: List[List[int]] = []
    for s in range(p):
        warm = min(total, 2 * (p - s - 1) + (v - 1) * p)
        seq: List[int] = []
        nf = nb = 0
        while nf < warm:
            c, i = _vmb(nf, True); seq.append(fid[(c, s, i)]); nf += 1
        while nf < total:
            c, i = _vmb(nf, True); seq.append(fid[(c, s, i)]); nf += 1
            c, i = _vmb(nb, False); seq.append(bid[(c, s, i)]); nb += 1
        while nb < total:
            c, i = _vmb(nb, False); seq.append(bid[(c, s, i)]); nb += 1
        rank_orders.append(seq)

    return _pack_topology("interleaved", p, m, v, labels, rank_orders, deps,
                          srcs, rows, cols, scales)


@lru_cache(maxsize=None)
def encoder_fill_topology(p: int, m: int) -> ScheduleTopology:
    """Optimus-style encoder-in-bubble over a p-stage LLM 1F1B skeleton.

    The encoder holds no pipeline stages: each of the p LLM ranks hosts a
    replica and runs one encoder chunk per microbatch (durations come from
    the ``e_fwd``/``e_bwd`` arrays, already per-chunk).  Chunk placement
    fills the 1F1B bubbles statically:

      * EF[s,i] runs just before F[s, max(i−s, 0)] — rank s's warmup idle
        absorbs its first s+1 chunks, later chunks slot one forward ahead;
      * EB[s,i] runs just after B[s, min(i+s, m−1)] — the mirror image in
        the drain.

    Dependencies: F[0,i] ← EF[s,i] for every rank s (the LLM consumes the
    full encoder output), EB[s,i] ← B[0,i] (encoder backward needs the
    LLM's input gradient), plus the plain 1F1B deps.  Deadlock-freedom:
    every dependency chain strictly decreases in microbatch index (see
    docs/schedules.md); the reference event loop raises if violated.
    """
    if p < 1 or m < 1:
        raise ValueError(f"need p, m >= 1, got p={p}, m={m}")
    labels, srcs, rows, cols, scales = [], [], [], [], []

    def _add(kind, s, i, src):
        labels.append((kind, s, 0, i))
        srcs.append(src); rows.append(s); cols.append(i)
        scales.append(1.0)
        return len(labels) - 1

    fid = {(s, i): _add("F", s, i, _SRC_FWD)
           for s in range(p) for i in range(m)}
    bid = {(s, i): _add("B", s, i, _SRC_BWD)
           for s in range(p) for i in range(m)}
    efid = {(s, i): _add("EF", s, i, _SRC_EFWD)
            for s in range(p) for i in range(m)}
    ebid = {(s, i): _add("EB", s, i, _SRC_EBWD)
            for s in range(p) for i in range(m)}

    deps: List[List[int]] = [[] for _ in labels]
    for (s, i), o in fid.items():
        if s > 0:
            deps[o].append(fid[(s - 1, i)])
        else:
            deps[o].extend(efid[(r, i)] for r in range(p))
    for (s, i), o in bid.items():
        deps[o].append(fid[(s, i)])
        if s < p - 1:
            deps[o].append(bid[(s + 1, i)])
    for (s, i), o in ebid.items():
        deps[o].append(bid[(0, i)])

    ef_before: Dict[Tuple[int, int], List[int]] = {}
    eb_after: Dict[Tuple[int, int], List[int]] = {}
    for s in range(p):
        for i in range(m):
            ef_before.setdefault((s, max(i - s, 0)), []).append(efid[(s, i)])
            eb_after.setdefault((s, min(i + s, m - 1)), []).append(ebid[(s, i)])

    rank_orders: List[List[int]] = []
    for s, order in enumerate(_static_orders(p, m)):
        seq: List[int] = []
        for kind, i in order:
            if kind == "F":
                seq.extend(ef_before.get((s, i), ()))
                seq.append(fid[(s, i)])
            else:
                seq.append(bid[(s, i)])
                seq.extend(eb_after.get((s, i), ()))
        rank_orders.append(seq)

    return _pack_topology("encoder_fill", p, m, 1, labels, rank_orders,
                          deps, srcs, rows, cols, scales)


def schedule_topology(schedule: str, p: int, m: int, *,
                      v: int = DEFAULT_VIRTUAL_CHUNKS) -> ScheduleTopology:
    """Cached topology for one (schedule, p, m[, v]) instance shape."""
    if schedule == "interleaved":
        return interleaved_topology(p, m, v)
    if schedule == "encoder_fill":
        return encoder_fill_topology(p, m)
    raise ValueError(f"no generic topology for schedule {schedule!r} "
                     f"(1f1b uses the dedicated wavefront)")


def _op_durations(topo: ScheduleTopology, fwd: np.ndarray, bwd: np.ndarray,
                  e_fwd: Optional[np.ndarray],
                  e_bwd: Optional[np.ndarray]) -> np.ndarray:
    """(n_ops, B) per-op durations gathered from (p, m, B) source arrays —
    one shared gather so the reference and the batch see identical floats."""
    arrays = {_SRC_FWD: fwd, _SRC_BWD: bwd, _SRC_EFWD: e_fwd,
              _SRC_EBWD: e_bwd}
    B = fwd.shape[-1]
    dur = np.empty((topo.n_ops, B))
    for code, arr in arrays.items():
        sel = topo.src == code
        if not sel.any():
            continue
        if arr is None:
            raise ValueError(f"schedule {topo.schedule!r} needs encoder "
                             f"duration arrays")
        dur[sel] = arr[topo.row[sel], topo.col[sel], :] \
            * topo.scale[sel][:, None]
    return dur


def _rank_busy(topo: ScheduleTopology, dur: np.ndarray) -> np.ndarray:
    """(p, B) per-rank busy time: each rank's ops summed in static order
    via one `np.add.reduce` — shared by both implementations so the float
    association can never differ between them."""
    B = dur.shape[-1]
    busy = np.zeros((topo.p, B))
    for r, seq in enumerate(topo.rank_orders):
        if seq:
            busy[r] = np.add.reduce(dur[list(seq)], axis=0)
    return busy


def reference_schedule_times(topo: ScheduleTopology, fwd: np.ndarray,
                             bwd: np.ndarray,
                             e_fwd: Optional[np.ndarray] = None,
                             e_bwd: Optional[np.ndarray] = None,
                             ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-op reference event loop over one instance (all arrays (p, m)).

    Walks each rank's static order behind per-rank pointers, firing an op
    once all its dependencies have finished — the ground truth the batched
    wavefront is property-pinned against.  Returns (start, end) arrays
    indexed by op id; raises RuntimeError if the schedule deadlocks.
    """
    def _col(a):
        return None if a is None else \
            np.asarray(a, dtype=np.float64)[:, :, None]
    dur = _op_durations(topo, _col(fwd), _col(bwd), _col(e_fwd),
                        _col(e_bwd))[:, 0]
    start = np.full(topo.n_ops, -1.0)
    end = np.full(topo.n_ops, -1.0)
    rank_t = np.zeros(topo.p)
    ptr = [0] * topo.p
    remaining = topo.n_ops
    progress = True
    while remaining > 0:
        if not progress:
            raise RuntimeError(
                f"{topo.schedule} schedule deadlocked (bug)")
        progress = False
        for s in range(topo.p):
            seq = topo.rank_orders[s]
            while ptr[s] < len(seq):
                o = seq[ptr[s]]
                ds = topo.deps[o]
                if any(end[d] < 0 for d in ds):
                    break
                t0 = rank_t[s]
                for d in ds:
                    t0 = max(t0, end[d])
                t1 = t0 + dur[o]
                start[o], end[o] = t0, t1
                rank_t[s] = t1
                ptr[s] += 1
                remaining -= 1
                progress = True
    return start, end


def simulate_schedule_batch(schedule: str, fwd: np.ndarray,
                            bwd: Optional[np.ndarray] = None, *,
                            e_fwd: Optional[np.ndarray] = None,
                            e_bwd: Optional[np.ndarray] = None,
                            v: int = DEFAULT_VIRTUAL_CHUNKS,
                            record_ops: bool = False) -> "BatchPipelineTrace":
    """Vectorized wavefront over a batch of instances of any schedule.

    fwd/bwd (and, for ``encoder_fill``, e_fwd/e_bwd — already per-rank
    *chunk* durations): ``(..., p, m)`` arrays with independent leading
    batch axes.  ``schedule="1f1b"`` dispatches to the dedicated
    `simulate_1f1b_batch` wavefront unchanged (bit-for-bit the historical
    path); the generic families walk the cached `ScheduleTopology` in its
    linear order — each op one max/add over the batch axis, exactly the
    operands of `reference_schedule_times`.  With ``record_ops`` the trace
    carries ``op_start``/``op_end`` as ``lead + (n_ops,)`` arrays (op ids
    index `schedule_topology(...)`'s labels).
    """
    fwd = np.asarray(fwd, dtype=np.float64)
    if fwd.ndim < 2:
        raise ValueError(f"fwd must be (..., p, m), got shape {fwd.shape}")
    bwd = 2.0 * fwd if bwd is None else np.asarray(bwd, dtype=np.float64)
    if schedule == "1f1b":
        if e_fwd is not None or e_bwd is not None:
            raise ValueError("1f1b takes encoder stages via fwd/bwd rows, "
                             "not e_fwd/e_bwd")
        return simulate_1f1b_batch(fwd, bwd, record_ops=record_ops)
    lead = fwd.shape[:-2]
    p, m = fwd.shape[-2:]
    topo = schedule_topology(schedule, p, m, v=v)

    def _layout(a):
        if a is None:
            return None
        a = np.asarray(a, dtype=np.float64)
        if a.shape != fwd.shape:
            raise ValueError(f"duration array shape {a.shape} != {fwd.shape}")
        return np.ascontiguousarray(
            np.moveaxis(a.reshape((-1, p, m)), 0, -1))

    F, W = _layout(fwd), _layout(bwd)
    EF, EW = _layout(e_fwd), _layout(e_bwd)
    B = F.shape[-1]
    dur = _op_durations(topo, F, W, EF, EW)

    end = np.zeros((topo.n_ops, B))
    start = np.zeros((topo.n_ops, B)) if record_ops else None
    rank_t = np.zeros((topo.p, B))
    for o in topo.order:
        r = topo.rank[o]
        t0 = rank_t[r]
        for d in topo.deps[o]:
            t0 = np.maximum(t0, end[d])
        t1 = t0 + dur[o]
        if start is not None:
            start[o] = t0
        end[o] = t1
        rank_t[r] = t1

    makespan = end.max(axis=0).reshape(lead)
    busy = np.moveaxis(_rank_busy(topo, dur), -1, 0).reshape(lead + (p,))
    idle = makespan[..., None] - busy
    return BatchPipelineTrace(
        makespan, busy, idle,
        op_start=(np.moveaxis(start, -1, 0).reshape(lead + (topo.n_ops,))
                  if record_ops else None),
        op_end=(np.moveaxis(end, -1, 0).reshape(lead + (topo.n_ops,))
                if record_ops else None))


def _topo_trace(topo: ScheduleTopology, start: np.ndarray,
                end: np.ndarray, dur: np.ndarray) -> PipelineTrace:
    """Assemble a scalar `PipelineTrace` from reference per-op times."""
    ops = [(topo.labels[o][0], int(topo.labels[o][1]),
            int(topo.labels[o][3]), float(start[o]), float(end[o]))
           for s in range(topo.p) for o in topo.rank_orders[s]]
    busy = _rank_busy(topo, dur[:, None])[:, 0]
    makespan = float(end.max())
    return PipelineTrace(makespan, busy, makespan - busy, ops)


def simulate_interleaved(fwd: np.ndarray, bwd: np.ndarray | None = None, *,
                         v: int = DEFAULT_VIRTUAL_CHUNKS) -> PipelineTrace:
    """Reference interleaved-1F1B simulation of one (p, m) instance.

    fwd/bwd: (p, m) full per-rank stage durations (each virtual chunk costs
    1/v of its rank's row); bwd defaults to 2×fwd.  Op list entries are
    (kind, rank, mb, t0, t1) with v ops per (kind, rank, mb) triple.
    """
    fwd = np.asarray(fwd, dtype=np.float64)
    p, m = fwd.shape
    bwd = 2.0 * fwd if bwd is None else np.asarray(bwd, dtype=np.float64)
    topo = interleaved_topology(p, m, v)
    start, end = reference_schedule_times(topo, fwd, bwd)
    dur = _op_durations(topo, fwd[:, :, None], bwd[:, :, None],
                        None, None)[:, 0]
    return _topo_trace(topo, start, end, dur)


def simulate_encoder_fill(fwd: np.ndarray, bwd: np.ndarray,
                          e_fwd: np.ndarray,
                          e_bwd: np.ndarray) -> PipelineTrace:
    """Reference encoder-in-bubble simulation of one (p, m) instance.

    fwd/bwd: (p, m) LLM stage durations; e_fwd/e_bwd: (p, m) *per-rank
    encoder chunk* durations (a microbatch's total encoder cost split over
    the p replicas).  Ops "EF"/"EB" are the bubble-filling chunks.
    """
    fwd = np.asarray(fwd, dtype=np.float64)
    p, m = fwd.shape
    topo = encoder_fill_topology(p, m)
    start, end = reference_schedule_times(topo, fwd, bwd, e_fwd, e_bwd)
    dur = _op_durations(topo, fwd[:, :, None],
                        np.asarray(bwd, np.float64)[:, :, None],
                        np.asarray(e_fwd, np.float64)[:, :, None],
                        np.asarray(e_bwd, np.float64)[:, :, None])[:, 0]
    return _topo_trace(topo, start, end, dur)


# --------------------------------------------------------------------- #
# scheduler-bucket → pipeline-rank convention
# --------------------------------------------------------------------- #
def bucket_rank_durations(e_b: np.ndarray, l_b: np.ndarray, *, n_mb: int,
                          dp: int, e_pp: int, l_pp: int) -> np.ndarray:
    """Scheduler bucket durations → per-rank stage rows, vectorized.

    e_b/l_b: ``(..., n_mb · dp)`` per-bucket encoder/LLM durations (already
    per-stage, i.e. divided by the module's PP degree).  Returns
    ``(..., dp, p, n_mb)`` rows where ``p = e_pp + l_pp``: bucket
    ``i·dp + r`` is microbatch i of data-parallel rank r (the order the
    data loader consumes `ScheduleOutput.groups`), each encoder stage takes
    the bucket's encoder value and each LLM stage its LLM value.
    """
    e_b = np.asarray(e_b, dtype=np.float64)
    l_b = np.asarray(l_b, dtype=np.float64)
    lead = l_b.shape[:-1]
    p = e_pp + l_pp
    rows = np.empty(lead + (dp, p, n_mb))
    # (..., n_mb·dp) → (..., n_mb, dp) → (..., dp, n_mb); broadcast over
    # the module's stages
    l_ri = np.moveaxis(l_b.reshape(lead + (n_mb, dp)), -1, -2)
    rows[..., e_pp:, :] = l_ri[..., None, :]
    if e_pp:
        e_ri = np.moveaxis(e_b.reshape(lead + (n_mb, dp)), -1, -2)
        rows[..., :e_pp, :] = e_ri[..., None, :]
    return rows


def simulate_bucket_ranks_batch(e_b: np.ndarray, l_b: np.ndarray, *,
                                n_mb: int, dp: int, e_pp: int, l_pp: int,
                                bwd_over_fwd: float = 2.0,
                                backward: bool = True,
                                record_ops: bool = False,
                                schedule: str = "1f1b",
                                virtual_chunks: int = DEFAULT_VIRTUAL_CHUNKS,
                                ) -> BatchPipelineTrace:
    """Batched schedule traces for scheduler buckets; see
    `simulate_bucket_ranks` for the convention.  e_b/l_b may carry leading
    batch axes (e.g. one per Monte-Carlo trial); the result's batch shape
    is ``lead + (dp,)`` and the slowest rank per instance is
    ``out.makespan.max(axis=-1)``.

    ``schedule`` selects the family (``ParallelismPlan.schedule``):

      * ``"1f1b"`` — the historical path, unchanged bit-for-bit;
      * ``"interleaved"`` — same per-rank rows, walked as ``virtual_chunks``
        virtual stages per rank (needs ``n_mb % (e_pp + l_pp) == 0``);
      * ``"encoder_fill"`` — ``e_b`` holds each bucket's *full* encoder
        duration (the scheduler's per-item ``e_dur`` under the colocated
        plan, summed); it is split evenly into ``l_pp`` per-rank chunks and
        scheduled into the LLM bubbles (``e_pp`` is ignored — the encoder
        holds no stages).
    """
    if schedule == "encoder_fill":
        lead = np.asarray(l_b, dtype=np.float64).shape[:-1]
        rows = bucket_rank_durations(
            np.zeros_like(np.asarray(l_b, dtype=np.float64)), l_b,
            n_mb=n_mb, dp=dp, e_pp=0, l_pp=l_pp)
        e_rows = bucket_rank_durations(
            np.zeros_like(np.asarray(e_b, dtype=np.float64)), e_b,
            n_mb=n_mb, dp=dp, e_pp=0, l_pp=l_pp) / l_pp
        if backward:
            fwd = rows / (1.0 + bwd_over_fwd)
            bwd = bwd_over_fwd * fwd
            e_fwd = e_rows / (1.0 + bwd_over_fwd)
            e_bwd = bwd_over_fwd * e_fwd
        else:
            fwd, bwd = rows, 0.0 * rows
            e_fwd, e_bwd = e_rows, 0.0 * e_rows
        return simulate_schedule_batch("encoder_fill", fwd, bwd,
                                       e_fwd=e_fwd, e_bwd=e_bwd,
                                       record_ops=record_ops)
    rows = bucket_rank_durations(e_b, l_b, n_mb=n_mb, dp=dp, e_pp=e_pp,
                                 l_pp=l_pp)
    if backward:
        fwd = rows / (1.0 + bwd_over_fwd)
        bwd = bwd_over_fwd * fwd
    else:
        fwd, bwd = rows, 0.0 * rows
    if schedule == "1f1b":
        return simulate_1f1b_batch(fwd, bwd, record_ops=record_ops)
    return simulate_schedule_batch(schedule, fwd, bwd, v=virtual_chunks,
                                   record_ops=record_ops)


def simulate_bucket_ranks(e_b: np.ndarray, l_b: np.ndarray, *, n_mb: int,
                          dp: int, e_pp: int, l_pp: int,
                          bwd_over_fwd: float = 2.0, backward: bool = True,
                          record_ops: bool = False, schedule: str = "1f1b"):
    """Per-rank schedule traces for m = n_mb · dp scheduler buckets.

    This is THE convention shared by the search objectives
    (`objective._SamplingObjective`) and the benchmark harness
    (`benchmarks.common.simulate_iteration`) — keep it in one place so
    predicted and "ground truth" simulations can never drift:

      * bucket i·dp + r is microbatch i of data-parallel rank r (the order
        the data loader consumes `ScheduleOutput.groups`);
      * bucket durations are per-stage (already divided by the module's PP
        degree): each of the module's stages takes the bucket value as-is;
      * with `backward`, durations are full fwd+bwd cost and are split
        1 : bwd_over_fwd over the 1F1B phases (so a homogeneous batch
        reproduces the closed form (n_mb + p − 1) · c); without, they are
        forward-only.

    Yields one `PipelineTrace` per rank (all dp ranks are simulated in a
    single `simulate_1f1b_batch` call; per-op spans only with
    `record_ops=True`).
    """
    batch = simulate_bucket_ranks_batch(
        e_b, l_b, n_mb=n_mb, dp=dp, e_pp=e_pp, l_pp=l_pp,
        bwd_over_fwd=bwd_over_fwd, backward=backward, record_ops=record_ops,
        schedule=schedule)
    for r in range(dp):
        yield batch.trace(r)


def ideal_bubble_fraction(p: int, m: int) -> float:
    """Theoretical 1F1B bubble (p−1)/m ... /(m + p − 1) of the makespan for
    homogeneous microbatches (paper cites (p−1)/m [Megatron]).

    >>> ideal_bubble_fraction(4, 12)
    0.2
    """
    return (p - 1) / (m + p - 1)
