"""shard_map pipeline executor: TPU-native pipeline parallelism.

Layers are sharded over a `stage` mesh axis; microbatch activations rotate
through stages with ``jax.lax.ppermute`` inside a ``lax.scan`` over
T = m + p − 1 ticks (the circular-pipeline idiom).  The steady-state bubble
structure matches 1F1B's (p−1)/(m+p−1); the discrete-event simulator
(`simulator.py`) models the full 1F1B order for schedule studies, while this
executor provides a *runnable, differentiable* pipeline on a real mesh —
the piece a GPU framework implements with P2P sends.

Homogeneous stages (equal layers per stage).  The DFLOP heterogeneous
encoder/LLM split is realized in SPMD mode via per-module sharding
(`repro.core.communicator`); the pipeline axis is exercised for the LLM
stack, with scheduler-balanced microbatches entering through stage 0.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
from repro.common import compat
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def build_stage_fn(layer_apply: Callable, layers_per_stage: int) -> Callable:
    """stage_fn(stage_params, x) applying `layers_per_stage` stacked layers.

    `stage_params` leaves have leading dim layers_per_stage."""

    def stage_fn(stage_params, x):
        def body(h, lp):
            return layer_apply(lp, h), None

        out, _ = jax.lax.scan(body, x, stage_params)
        return out

    return stage_fn


def pipeline_forward(mesh: Mesh, stage_fn: Callable, axis: str = "stage"):
    """Returns f(stacked_stage_params, microbatches) -> outputs.

    stacked_stage_params: leaves (p, layers_per_stage, ...), sharded dim0
    over `axis`.  microbatches: (m, mb, seq, d) replicated.  outputs:
    (m, mb, seq, d) replicated (psum-collected from the last stage).
    """
    p = mesh.shape[axis]

    def inner(params_local, mbs):
        # params_local leaves: (1, layers_per_stage, ...) -> drop stage dim
        params_local = jax.tree.map(lambda a: a[0], params_local)
        idx = jax.lax.axis_index(axis)
        m = mbs.shape[0]
        T = m + p - 1
        state = jnp.zeros_like(mbs[0])
        outputs = jnp.zeros_like(mbs)

        def tick(carry, t):
            state, outputs = carry
            inject = jnp.take(mbs, jnp.clip(t, 0, m - 1), axis=0)
            x = jnp.where(idx == 0, inject, state)
            y = stage_fn(params_local, x)
            nxt = jax.lax.ppermute(y, axis,
                                   [(i, (i + 1) % p) for i in range(p)])
            out_t = t - (p - 1)
            is_emit = (idx == p - 1) & (out_t >= 0)
            upd = jnp.where(is_emit, y, jnp.take(outputs,
                                                 jnp.clip(out_t, 0, m - 1),
                                                 axis=0))
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs, upd, jnp.clip(out_t, 0, m - 1), 0)
            return (nxt, outputs), None

        (state, outputs), _ = jax.lax.scan(tick, (state, outputs),
                                           jnp.arange(T))
        # collect from the last stage; other stages contribute zeros
        outputs = jnp.where(idx == p - 1, outputs, jnp.zeros_like(outputs))
        return jax.lax.psum(outputs, axis)

    in_specs = (P(axis), P())
    out_specs = P()
    return compat.shard_map(inner, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)


def stack_stage_params(per_layer_params, p: int, *, from_p=None):
    """(n_layers, ...) stacked layer params -> (p, n_layers/p, ...).

    With ``from_p`` set (any integer, including 1) the leaves are already
    stage-stacked as (from_p, n_layers/from_p, ...) and are re-partitioned
    for the new stage count — the layout transition a physical plan
    hot-swap needs (`repro.launch.reshard`)."""

    def reshape(a):
        if from_p is not None:
            assert a.shape[0] == from_p, (
                f"leaf leading dim {a.shape[0]} != from_p={from_p}")
            a = a.reshape(from_p * a.shape[1], *a.shape[2:])
        n = a.shape[0]
        assert n % p == 0, f"{n} layers not divisible by {p} stages"
        return a.reshape(p, n // p, *a.shape[1:])

    return jax.tree.map(reshape, per_layer_params)


def unstack_stage_params(stacked_params):
    """(p, n_layers/p, ...) stage-stacked leaves -> flat (n_layers, ...)."""
    return jax.tree.map(lambda a: a.reshape(a.shape[0] * a.shape[1],
                                            *a.shape[2:]), stacked_params)
