"""Online Microbatch Scheduler (paper §3.4).

Per global batch: predict per-item (E_dur, L_dur) from the profiled models
under the active plan θ*, partition the N items into m = N_mb · L_dp buckets
with the hybrid exact-then-LPT solver, and hand the index groups to the data
loader.  Runs asynchronously on host CPU — batch t+1 is scheduled while step
t computes (§3.4.2: "the scheduler operates asynchronously to eliminate
scheduling overhead").
"""
from __future__ import annotations

import concurrent.futures
import time
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from repro.core.optimizer.objective import corrected_item_durations
from repro.core.optimizer.space import ParallelismPlan
from repro.core.profiling.model_profiler import PerfModel
from repro.core.scheduler.adaptive import AdaptiveCorrection
from repro.core.scheduler.ilp import solve_makespan_bnb
from repro.core.scheduler.lpt import cmax, lower_bound, lpt_schedule
from repro.data.items import DataItem


@dataclass
class ScheduleOutput:
    groups: List[List[int]]          # m index groups over the global batch
    cmax: float                      # predicted bottleneck duration
    lower_bound: float
    solver: str                      # "ilp" | "lpt" | "ilp-timeout"
    elapsed_s: float
    e_dur: np.ndarray
    l_dur: np.ndarray
    plan: Optional[ParallelismPlan] = None   # plan θ this batch was balanced for

    @property
    def imbalance(self) -> float:
        """Relative gap to the load lower bound (<1% at GBS 2048, Fig. 16b)."""
        return self.cmax / max(self.lower_bound, 1e-12) - 1.0

    @property
    def step_makespan(self) -> float:
        """Pipeline-makespan estimate (N_mb + bubble_slots) · cmax —
        comparable across plans with different bucket counts *and* schedule
        families, unlike raw cmax.  cmax is the solver's bucket bottleneck
        over `_solver_durations`, i.e. already the per-slot cost of the
        plan's own family (combined serial cost under encoder_fill)."""
        if self.plan is None:
            return self.cmax
        return (self.plan.n_mb + self.plan.bubble_slots) * self.cmax


def _solver_durations(plan: Optional[ParallelismPlan], e_dur: np.ndarray,
                      l_dur: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Per-item durations the balancing solver should weigh.

    For the staged families a bucket costs max(ΣE, ΣL) — encoder and LLM
    stages run on *different* ranks, so the solver balances the two module
    loads independently.  Under ``encoder_fill`` the encoder chunk (its full
    duration split over the L_pp replicas) runs *serially* with the LLM
    stage on the same ranks, so the bucket cost is the combined sum — pass
    it as both module loads and max(Σc, Σc) degenerates to Σc."""
    if plan is not None and plan.schedule == "encoder_fill":
        comb = l_dur + e_dur / plan.llm.pp
        return comb, comb
    return e_dur, l_dur


class OnlineMicrobatchScheduler:
    def __init__(self, plan: ParallelismPlan, perf: PerfModel,
                 tokens_per_media_item: int, *,
                 ilp_time_limit_s: float = 0.25,
                 adaptive: Optional[AdaptiveCorrection] = None,
                 calibration=None,
                 mode: str = "train"):
        """calibration: optional duck-typed refiner with
        ``correct(module, shape, tp, predicted)`` / ``observe(module, shape,
        tp, predicted, actual)`` (see repro.runtime.calibration)."""
        self.plan = plan
        self.perf = perf
        self.tpm = tokens_per_media_item
        self.ilp_time_limit_s = ilp_time_limit_s
        self.adaptive = adaptive
        self.calibration = calibration
        self.mode = mode
        # roster_chips: chips the fleet can actually field right now (None
        # = single-host, no roster tracking).  Elastic runs shrink it on
        # host loss so a plan sized for the old fleet is rejected loudly
        # instead of silently over-subscribing the survivors.
        self.roster_chips: Optional[int] = None
        self._pool = concurrent.futures.ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[concurrent.futures.Future] = None

    # ------------------------------------------------------------------ #
    @property
    def n_buckets(self) -> int:
        return self.plan.n_buckets

    def set_roster(self, n_chips: Optional[int]) -> None:
        """Update the fleet capacity the scheduler plans against (None
        disables the check).  The *current* plan is left untouched — the
        controller's recovery path decides what to run on the survivors;
        only future `set_plan()` calls validate against the new roster."""
        self.roster_chips = None if n_chips is None else int(n_chips)

    def set_plan(self, plan: ParallelismPlan) -> None:
        """Hot-swap the active plan θ*.  Takes effect on the next
        `schedule()` call — in-flight work keeps the plan it was scheduled
        under (each call captures `self.plan` once on entry).  With a
        roster attached (`set_roster`), a plan needing more chips than the
        fleet can field is rejected."""
        if self.roster_chips is not None and plan.chips > self.roster_chips:
            raise ValueError(
                f"plan needs {plan.chips} chips but the roster has "
                f"{self.roster_chips}; re-plan for the surviving fleet")
        self.plan = plan

    def item_durations(self, items: Sequence[DataItem],
                       plan: Optional[ParallelismPlan] = None) -> tuple[np.ndarray, np.ndarray]:
        """Predicted per-item stage durations under θ* (§3.4.2 step 1).

        Delegates to the duration path shared with the optimizer's sampling
        objectives (`objective.corrected_item_durations`), so search-time
        Monte-Carlo and schedule-time predictions agree on identical shapes
        by construction."""
        plan = plan if plan is not None else self.plan
        b = np.array([it.encoder_batch() for it in items], np.float64)
        s = np.array([it.llm_seq_len(self.tpm) for it in items], np.float64)
        return corrected_item_durations(self.perf, plan, b, s,
                                        mode=self.mode,
                                        adaptive=self.adaptive,
                                        corrector=self.calibration)

    # ------------------------------------------------------------------ #
    def schedule(self, items: Sequence[DataItem]) -> ScheduleOutput:
        t0 = time.monotonic()
        plan = self.plan                 # capture once: hot-swap safe
        e_dur, l_dur = self.item_durations(items, plan)
        m = plan.n_buckets
        se, sl = _solver_durations(plan, e_dur, l_dur)
        res = solve_makespan_bnb(se, sl, m,
                                 time_limit_s=self.ilp_time_limit_s)
        if res.timed_out:
            # hybrid contract: on timeout the incumbent is the LPT solution
            # possibly improved by partial search — keep the better one.
            solver = "ilp-timeout"
        else:
            solver = "ilp"
        lb = lower_bound(se, sl, m)
        return ScheduleOutput(res.groups, res.cmax, lb, solver,
                              time.monotonic() - t0, e_dur, l_dur, plan)

    def schedule_random(self, items: Sequence[DataItem],
                        seed: int = 0) -> ScheduleOutput:
        """Data-agnostic baseline: random assignment (what PyTorch/Megatron
        loaders do) — used in Fig. 4/13 comparisons."""
        t0 = time.monotonic()
        plan = self.plan
        e_dur, l_dur = self.item_durations(items, plan)
        m = plan.n_buckets
        rng = np.random.default_rng(seed)
        perm = rng.permutation(len(items))
        groups: List[List[int]] = [[] for _ in range(m)]
        for pos, i in enumerate(perm):
            groups[pos % m].append(int(i))
        se, sl = _solver_durations(plan, e_dur, l_dur)
        return ScheduleOutput(groups, cmax(se, sl, groups),
                              lower_bound(se, sl, m), "random",
                              time.monotonic() - t0, e_dur, l_dur, plan)

    # ------------------------------------------------------------------ #
    # Asynchronous operation: schedule batch t+1 while step t runs.
    def submit(self, items: Sequence[DataItem]) -> None:
        if self._pending is not None:
            raise RuntimeError(
                "submit() called with a schedule still pending; "
                "collect() the previous batch first")
        self._pending = self._pool.submit(self.schedule, list(items))

    def collect(self) -> Optional[ScheduleOutput]:
        if self._pending is None:
            return None
        out = self._pending.result()
        self._pending = None
        return out

    @property
    def has_pending(self) -> bool:
        return self._pending is not None

    # ------------------------------------------------------------------ #
    def observe(self, module: str, shape: float, predicted: float,
                actual: float,
                plan: Optional[ParallelismPlan] = None) -> None:
        """Runtime feedback for Adaptive Correction + online calibration.

        `plan`: the plan the measured batch was scheduled under (defaults to
        the current one) — after a hot-swap, pass `ScheduleOutput.plan` so
        calibration keys the measurement to the TP degree it ran at.
        The calibrator observes the residual left *after* adaptive
        correction, mirroring the order item_durations() applies them —
        otherwise both learn the same ratio and compound to its square."""
        adjusted = predicted
        if self.adaptive is not None:
            self.adaptive.observe(module, shape, predicted, actual)
            adjusted = self.adaptive.correct(module, shape, predicted)
        if self.calibration is not None:
            plan = plan if plan is not None else self.plan
            mp = plan.encoder if module == "encoder" else plan.llm
            if mp is not None:
                self.calibration.observe(module, shape, mp.tp, adjusted,
                                         actual)
