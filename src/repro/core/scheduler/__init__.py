from repro.core.scheduler.lpt import lpt_schedule
from repro.core.scheduler.ilp import BnBResult, solve_makespan_bnb
from repro.core.scheduler.online import OnlineMicrobatchScheduler, ScheduleOutput
from repro.core.scheduler.adaptive import AdaptiveCorrection

__all__ = [
    "lpt_schedule",
    "BnBResult",
    "solve_makespan_bnb",
    "OnlineMicrobatchScheduler",
    "ScheduleOutput",
    "AdaptiveCorrection",
]
