"""Adaptive Correction (paper §3.4.3).

GPU/TPU stacks pick different kernels per input shape, so a small set of
shapes deviates persistently from interpolation-based predictions.  The
mechanism tracks B = Th_actual − Th_pred per shape bucket, feeds a
multiplicative penalty back to the scheduler's duration estimates, and
toggles itself off when the measured average benefit fails to exceed the
monitoring cost C (cost-benefit analysis, Fig. 15).
"""
from __future__ import annotations

from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Deque, Dict, Tuple


@dataclass
class _BucketStats:
    n: int = 0
    ratio_sum: float = 0.0           # sum of actual/pred throughput ratios

    @property
    def correction(self) -> float:
        return self.ratio_sum / self.n if self.n else 1.0


class AdaptiveCorrection:
    def __init__(self, *, monitoring_cost: float = 0.04,
                 window: int = 64, min_obs: int = 3,
                 deviation_threshold: float = 0.05,
                 probe_interval: int = 512, probe_window: int = 16):
        """monitoring_cost: recurring relative overhead C of tracking
        (paper measures ~4%); window: iterations I for the benefit average;
        probe_interval/probe_window: while deactivated, every
        `probe_interval` observations a `probe_window`-long probe re-runs
        the cost-benefit test so the mechanism recovers when deviations
        return (the paper's loop is continuous, not one-way)."""
        self.cost = monitoring_cost
        self.window = window
        self.min_obs = min_obs
        self.threshold = deviation_threshold
        self.probe_interval = probe_interval
        self.probe_window = probe_window
        self.enabled = True
        self.probing = False
        self.stats: Dict[Tuple[str, int], _BucketStats] = defaultdict(_BucketStats)
        self.benefits: Deque[float] = deque(maxlen=window)
        self._iters = 0
        self._disabled_iters = 0
        self._probe_seen = 0

    # ------------------------------------------------------------------ #
    @staticmethod
    def bucket(shape: float) -> int:
        import math
        return int(2 ** round(math.log2(max(1.0, float(shape)))))

    def observe(self, module: str, shape: float, predicted_dur: float,
                actual_dur: float) -> None:
        """Record one execution. Durations are interchangeable with inverse
        throughputs for a fixed workload: B = Th_act − Th_pred ∝
        pred_dur/act_dur − 1."""
        if predicted_dur <= 0 or actual_dur <= 0:
            return
        if not self.enabled:
            # Deactivated: only count iterations (near-zero cost) until the
            # next probe window opens.
            self._disabled_iters += 1
            if self._disabled_iters >= self.probe_interval:
                self.enabled = True
                self.probing = True
                self._probe_seen = 0
                self._disabled_iters = 0
                self.benefits.clear()
            else:
                return
        key = (module, self.bucket(shape))
        st = self.stats[key]
        st.n += 1
        st.ratio_sum += actual_dur / predicted_dur
        # relative benefit of having the corrected estimate for this shape
        self.benefits.append(abs(actual_dur / predicted_dur - 1.0))
        self._iters += 1
        if self.probing:
            self._probe_seen += 1
            if self._probe_seen >= self.probe_window:
                self.probing = False
                avg_b = sum(self.benefits) / len(self.benefits)
                if avg_b < self.cost:
                    self._deactivate()
                else:
                    self._iters = 0          # fresh full window before the
                                             # next cost-benefit re-check
        else:
            self._maybe_toggle()

    def _deactivate(self) -> None:
        self.enabled = False
        self.probing = False
        self._disabled_iters = 0

    def _maybe_toggle(self) -> None:
        if self._iters >= self.window and len(self.benefits) == self.benefits.maxlen:
            avg_b = sum(self.benefits) / len(self.benefits)
            if avg_b < self.cost:
                # benefit does not justify monitoring overhead: deactivate
                self._deactivate()

    # ------------------------------------------------------------------ #
    def correct(self, module: str, shape: float, predicted_dur: float) -> float:
        """Apply the learned penalty to a predicted duration."""
        st = self.stats.get((module, self.bucket(shape)))
        if st is None or st.n < self.min_obs:
            return predicted_dur
        corr = st.correction
        if abs(corr - 1.0) < self.threshold:
            return predicted_dur
        return predicted_dur * corr

    def net_speedup(self) -> float:
        """Average benefit minus monitoring cost (Fig. 15 metric)."""
        if not self.benefits:
            return -self.cost
        return sum(self.benefits) / len(self.benefits) - self.cost
