"""Exact microbatch partitioning (paper §3.4.1) via branch-and-bound.

The paper formulates the partition as an ILP minimizing
    C_max = max_j max(E_j, L_j)
and solves it with a commercial solver under a strict time limit, falling
back to LPT on timeout.  We implement the same contract with an in-repo
depth-first branch-and-bound:

  * items processed in LPT order (largest first) — strong early incumbents;
  * incumbent initialized with the LPT solution, so the anytime result is
    never worse than the fallback;
  * pruning on  max(current C_max, remaining-load lower bound) ≥ incumbent;
  * bucket-symmetry breaking (an item may open at most one empty bucket);
  * deadline checks every node; on timeout returns the incumbent with
    optimal=False (the paper's "reverts to LPT" path, §3.4.2 / Fig. 16b).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from repro.core.scheduler.lpt import cmax, lower_bound, lpt_schedule


@dataclass
class BnBResult:
    groups: List[List[int]]
    cmax: float
    optimal: bool
    nodes: int
    elapsed_s: float
    timed_out: bool


def solve_makespan_bnb(e_dur: Sequence[float], l_dur: Sequence[float], m: int,
                       *, time_limit_s: float = 0.25,
                       node_limit: int = 2_000_000,
                       max_exact_n: int = 768) -> BnBResult:
    t0 = time.monotonic()
    e = np.asarray(e_dur, dtype=np.float64)
    l = np.asarray(l_dur, dtype=np.float64)
    n = len(e)
    if n == 0 or m <= 0:
        return BnBResult([[] for _ in range(max(m, 0))], 0.0, True, 0, 0.0, False)
    if m == 1:
        return BnBResult([list(range(n))], max(e.sum(), l.sum()), True, 1,
                         time.monotonic() - t0, False)
    if n > max_exact_n:
        # very large instances: exact search is pointless within the budget
        # (and recursion-deep); go straight to the LPT fallback — the paper's
        # GBS-2048 regime, where LPT lands <1% from the lower bound.
        groups = lpt_schedule(e, l, m)
        val = cmax(e, l, groups)
        lb = lower_bound(e, l, m)
        return BnBResult(groups, val, val <= lb * (1 + 1e-9), 1,
                         time.monotonic() - t0, True)

    order = np.argsort(-(np.maximum(e, l)))
    e_s, l_s = e[order], l[order]
    # suffix sums for the load lower bound
    suf_e = np.concatenate([np.cumsum(e_s[::-1])[::-1], [0.0]])
    suf_l = np.concatenate([np.cumsum(l_s[::-1])[::-1], [0.0]])

    incumbent = lpt_schedule(e, l, m)
    best_val = cmax(e, l, incumbent)
    lb_global = lower_bound(e, l, m)
    if best_val <= lb_global * (1 + 1e-12):
        return BnBResult(incumbent, best_val, True, 1,
                         time.monotonic() - t0, False)

    assign = np.full(n, -1, dtype=np.int64)
    best_assign: Optional[np.ndarray] = None
    loads_e = np.zeros(m)
    loads_l = np.zeros(m)
    nodes = 0
    timed_out = False
    deadline = t0 + time_limit_s

    def dfs(i: int, used: int, cur_max: float):
        nonlocal best_val, best_assign, nodes, timed_out
        if timed_out:
            return
        nodes += 1
        if nodes % 1024 == 0 and (time.monotonic() > deadline
                                  or nodes > node_limit):
            timed_out = True
            return
        if i == n:
            if cur_max < best_val - 1e-12:
                best_val = cur_max
                best_assign = assign.copy()
            return
        # remaining-load bound: even perfectly balanced, the future load
        # plus current loads cannot beat this
        rem_bound = max(
            (loads_e.sum() + suf_e[i]) / m,
            (loads_l.sum() + suf_l[i]) / m,
        )
        if max(cur_max, rem_bound) >= best_val - 1e-12:
            return
        tried_empty = False
        # visit buckets in order of resulting bottleneck (best-first)
        cand = np.maximum(loads_e[:min(used + 1, m)] + e_s[i],
                          loads_l[:min(used + 1, m)] + l_s[i])
        for j in np.argsort(cand):
            j = int(j)
            empty = loads_e[j] == 0 and loads_l[j] == 0
            if empty:
                if tried_empty:
                    continue
                tried_empty = True
            new_max = max(cur_max, loads_e[j] + e_s[i], loads_l[j] + l_s[i])
            if new_max >= best_val - 1e-12:
                continue
            loads_e[j] += e_s[i]
            loads_l[j] += l_s[i]
            assign[i] = j
            dfs(i + 1, max(used, j + 1), new_max)
            loads_e[j] -= e_s[i]
            loads_l[j] -= l_s[i]
            assign[i] = -1
            if timed_out:
                return

    dfs(0, 0, 0.0)

    if best_assign is not None:
        groups: List[List[int]] = [[] for _ in range(m)]
        for sorted_i, bucket in enumerate(best_assign):
            groups[int(bucket)].append(int(order[sorted_i]))
        val = cmax(e, l, groups)
    else:
        groups, val = incumbent, best_val
    optimal = (not timed_out) or val <= lb_global * (1 + 1e-9)
    return BnBResult(groups, val, optimal, nodes,
                     time.monotonic() - t0, timed_out)
