"""Longest-Processing-Time fallback heuristic (paper §3.4.2, Graham 1969).

Generalized to DFLOP's two-stage objective: each item carries an
(encoder, LLM) duration pair and the bucket cost is max(E_j, L_j); LPT
sorts by the dominant duration and greedily assigns each item to the bucket
whose resulting bottleneck is smallest.  O(N·log m) with a heap when only
one stage matters; O(N·m) in the general coupled case (still microseconds
at GBS 2048).
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np


def lpt_schedule(e_dur: Sequence[float], l_dur: Sequence[float],
                 m: int, refine: bool = True) -> List[List[int]]:
    """Partition items into m buckets. Returns index groups.

    `refine` adds a bounded move-from-bottleneck local search — at GBS 2048
    this is what keeps the fallback within 1% of the lower bound (Fig. 16b).
    """
    e = np.asarray(e_dur, dtype=np.float64)
    l = np.asarray(l_dur, dtype=np.float64)
    n = len(e)
    order = np.argsort(-(np.maximum(e, l)))
    loads_e = np.zeros(m)
    loads_l = np.zeros(m)
    groups: List[List[int]] = [[] for _ in range(m)]
    for i in order:
        cand = np.maximum(loads_e + e[i], loads_l + l[i])
        j = int(np.argmin(cand))
        loads_e[j] += e[i]
        loads_l[j] += l[i]
        groups[j].append(int(i))
    if not refine or n == 0:
        return groups
    # local search: move any item out of the bottleneck bucket if that
    # strictly lowers the global C_max
    for _ in range(4 * m):
        cur = np.maximum(loads_e, loads_l)
        b = int(np.argmax(cur))
        best_gain, best = 0.0, None
        for i in groups[b]:
            cand = np.maximum(loads_e + e[i], loads_l + l[i])
            cand[b] = np.inf
            j = int(np.argmin(cand))
            new_e, new_l = loads_e.copy(), loads_l.copy()
            new_e[b] -= e[i]; new_l[b] -= l[i]
            new_e[j] += e[i]; new_l[j] += l[i]
            val = float(np.max(np.maximum(new_e, new_l)))
            gain = float(cur.max()) - val
            if gain > best_gain + 1e-15:
                best_gain, best = gain, (i, j)
        if best is None:
            break
        i, j = best
        groups[b].remove(i)
        groups[j].append(i)
        loads_e[b] -= e[i]; loads_l[b] -= l[i]
        loads_e[j] += e[i]; loads_l[j] += l[i]
    return groups


def cmax(e_dur, l_dur, groups) -> float:
    """Objective value (Eq. 6) of a partition."""
    e = np.asarray(e_dur, dtype=np.float64)
    l = np.asarray(l_dur, dtype=np.float64)
    worst = 0.0
    for g in groups:
        if g:
            worst = max(worst, e[g].sum(), l[g].sum())
    return worst


def lower_bound(e_dur, l_dur, m: int) -> float:
    """C_max ≥ max(mean load per bucket, largest single item)."""
    e = np.asarray(e_dur, dtype=np.float64)
    l = np.asarray(l_dur, dtype=np.float64)
    lb = max(e.sum() / m, l.sum() / m)
    if len(e):
        lb = max(lb, float(np.max(np.maximum(e, l))))
    return lb
