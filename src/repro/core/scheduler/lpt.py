"""Longest-Processing-Time fallback heuristic (paper §3.4.2, Graham 1969).

Generalized to DFLOP's two-stage objective: each item carries an
(encoder, LLM) duration pair and the bucket cost is max(E_j, L_j); LPT
sorts by the dominant duration and greedily assigns each item to the bucket
whose resulting bottleneck is smallest.  O(N·log m) with a heap when only
one stage matters; O(N·m) in the general coupled case (still microseconds
at GBS 2048).
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np


def lpt_schedule(e_dur: Sequence[float], l_dur: Sequence[float],
                 m: int, refine: bool = True) -> List[List[int]]:
    """Partition items into m buckets. Returns index groups.

    `refine` adds a bounded move-from-bottleneck local search — at GBS 2048
    this is what keeps the fallback within 1% of the lower bound (Fig. 16b).
    """
    e = np.asarray(e_dur, dtype=np.float64)
    l = np.asarray(l_dur, dtype=np.float64)
    n = len(e)
    order = np.argsort(-(np.maximum(e, l)))
    loads_e = np.zeros(m)
    loads_l = np.zeros(m)
    groups: List[List[int]] = [[] for _ in range(m)]
    for i in order:
        cand = np.maximum(loads_e + e[i], loads_l + l[i])
        j = int(np.argmin(cand))
        loads_e[j] += e[i]
        loads_l[j] += l[i]
        groups[j].append(int(i))
    if not refine or n == 0:
        return groups
    # local search: move any item out of the bottleneck bucket if that
    # strictly lowers the global C_max
    for _ in range(4 * m):
        cur = np.maximum(loads_e, loads_l)
        b = int(np.argmax(cur))
        best_gain, best = 0.0, None
        for i in groups[b]:
            cand = np.maximum(loads_e + e[i], loads_l + l[i])
            cand[b] = np.inf
            j = int(np.argmin(cand))
            new_e, new_l = loads_e.copy(), loads_l.copy()
            new_e[b] -= e[i]; new_l[b] -= l[i]
            new_e[j] += e[i]; new_l[j] += l[i]
            val = float(np.max(np.maximum(new_e, new_l)))
            gain = float(cur.max()) - val
            if gain > best_gain + 1e-15:
                best_gain, best = gain, (i, j)
        if best is None:
            break
        i, j = best
        groups[b].remove(i)
        groups[j].append(i)
        loads_e[b] -= e[i]; loads_l[b] -= l[i]
        loads_e[j] += e[i]; loads_l[j] += l[i]
    return groups


def lpt_assign_batch(e_dur: np.ndarray, l_dur: np.ndarray, m: int
                     ) -> tuple:
    """Vectorized-over-trials LPT: partition each row independently.

    e_dur/l_dur: ``(T, n)`` per-item duration pairs for T independent
    instances (e.g. Monte-Carlo trials in
    `objective.BalancedQuantileObjective`).  Per row this computes exactly
    ``lpt_schedule(e, l, m, refine=False)`` — same sort, same greedy
    argmin tie-breaking — but the per-item step runs once over all T rows,
    which is what keeps the search objectives' re-rank fast at large GBS
    (the per-item Python loop was the bottleneck, not the simulator).

    Returns ``(assign, loads_e, loads_l)``: ``assign[t, i]`` is item i's
    bucket, and the ``(T, m)`` load matrices are the per-bucket duration
    sums (the LPT loop maintains them anyway — callers that only need
    bucket totals skip a second reduction).
    """
    e = np.asarray(e_dur, dtype=np.float64)
    l = np.asarray(l_dur, dtype=np.float64)
    if e.ndim != 2:
        raise ValueError(f"expected (T, n) durations, got shape {e.shape}")
    T, n = e.shape
    order = np.argsort(-np.maximum(e, l), axis=1)
    eo = np.take_along_axis(e, order, axis=1)         # durations in LPT order
    lo = np.take_along_axis(l, order, axis=1)
    rows = np.arange(T)
    loads_e = np.zeros((T, m))
    loads_l = np.zeros((T, m))
    assign = np.empty((T, n), dtype=np.int64)
    # The first min(m, n) items each open a fresh bucket: empty buckets tie
    # at max(e_i, l_i) and argmin breaks ties toward the lowest index, while
    # any non-empty bucket is strictly more expensive — so sorted item k
    # lands in bucket k.  One vectorized step instead of a third to half of
    # the sequential argmin passes.  The strictness argument needs every
    # LLM duration positive (a loaded bucket j could otherwise tie an
    # e-dominant item: l_j ≥ d_k by sort order forces l_k ≤ 0); durations
    # from `PerfModel` always are, but fall back to the plain loop if not.
    head = min(m, n)
    if head and not (lo[:, :head] > 0.0).all():
        head = 0
    loads_e[:, :head] = eo[:, :head]
    loads_l[:, :head] = lo[:, :head]
    np.put_along_axis(assign, order[:, :head],
                      np.broadcast_to(np.arange(head), (T, head)), axis=1)
    # sequential tail: one fused argmin step per item across all T rows
    cand_e = np.empty((T, m))
    cand_l = np.empty((T, m))
    flat_e, flat_l = loads_e.reshape(-1), loads_l.reshape(-1)
    for k in range(head, n):
        ei, li = eo[:, k], lo[:, k]
        np.add(loads_e, ei[:, None], out=cand_e)
        np.add(loads_l, li[:, None], out=cand_l)
        np.maximum(cand_e, cand_l, out=cand_e)
        j = np.argmin(cand_e, axis=1)
        flat = rows * m + j
        flat_e[flat] += ei
        flat_l[flat] += li
        assign[rows, order[:, k]] = j
    return assign, loads_e, loads_l


def cmax(e_dur, l_dur, groups) -> float:
    """Objective value (Eq. 6) of a partition."""
    e = np.asarray(e_dur, dtype=np.float64)
    l = np.asarray(l_dur, dtype=np.float64)
    worst = 0.0
    for g in groups:
        if g:
            worst = max(worst, e[g].sum(), l[g].sum())
    return worst


def lower_bound(e_dur, l_dur, m: int) -> float:
    """C_max ≥ max(mean load per bucket, largest single item)."""
    e = np.asarray(e_dur, dtype=np.float64)
    l = np.asarray(l_dur, dtype=np.float64)
    lb = max(e.sum() / m, l.sum() / m)
    if len(e):
        lb = max(lb, float(np.max(np.maximum(e, l))))
    return lb
