"""DFLOP core: the paper's contribution.

  profiling/   — Profiling Engine (§3.2): Model Profiler + Data Profiler
  optimizer/   — Data-aware 3D Parallelism Optimizer (§3.3, Algorithm 1)
  scheduler/   — Online Microbatch Scheduler (§3.4): hybrid ILP/LPT +
                 Adaptive Correction
  pipeline/    — 1F1B simulator + shard_map pipeline executor
  communicator — Inter-model Communicator (§4) as SPMD reshard / shard_map
  engine       — façade wiring profile -> plan -> schedule
"""
