"""Memory feasibility (paper Eq. 4 / Eq. 5).

Encoder activations are retained for the *entire* pipeline lifetime, so
their cost scales with total depth (E_pp + L_pp); the LLM's activations
scale with its own depth only.
"""
from __future__ import annotations

from typing import Optional

from repro.core.optimizer.space import ModuleParallelism
from repro.core.profiling.model_profiler import ModulePerf


def encoder_mem(perf_e: ModulePerf, ep: ModuleParallelism, l_pp: int,
                t_bsz: float) -> float:
    """Eq. 4: model_state(E_l/E_pp, E_tp) + (E_pp+L_pp)·act_state(...)."""
    layers = perf_e.cfg.n_layers / ep.pp
    ms = perf_e.memory.model_state(layers, ep.tp)
    act = perf_e.memory.act_state(layers, ep.tp, t_bsz)
    return ms + (ep.pp + l_pp) * act


def llm_mem(perf_l: ModulePerf, lp: ModuleParallelism, t_seq: float) -> float:
    """Eq. 5: model_state(L_l/L_pp, L_tp) + L_pp·act_state(...)."""
    layers = perf_l.cfg.n_layers / lp.pp
    ms = perf_l.memory.model_state(layers, lp.tp)
    act = perf_l.memory.act_state(layers, lp.tp, t_seq)
    return ms + lp.pp * act


def feasible(perf_e: Optional[ModulePerf], perf_l: ModulePerf,
             ep: Optional[ModuleParallelism], lp: ModuleParallelism,
             t_bsz: float, t_seq: float, mem_cap: float) -> bool:
    if perf_e is not None and ep is not None:
        if encoder_mem(perf_e, ep, lp.pp, t_bsz) > mem_cap:
            return False
    return llm_mem(perf_l, lp, t_seq) <= mem_cap
