"""Makespan model primitives (paper §3.3.1).

    T = (N_mb + E_pp + L_pp − 1) · max(E_dur, L_dur)

Stage durations follow Algorithm 1 lines 25–26: module FLOPs for the
microbatch's (mean) shape, divided by the profiled throughput of its TP
group and by its pipeline degree.

How a *plan* is scored against a whole shape distribution lives in
`repro.core.optimizer.objective` (the pluggable Eq. 1 estimators: ``mean``,
``expected-random``, ``balanced-quantile``).  This module keeps the closed
forms they build on, plus the legacy aggregate-shape Monte-Carlo
(`expected_makespan`) retained for reference comparisons.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core.optimizer.space import ModuleParallelism, ParallelismPlan
from repro.core.profiling.data_profiler import ShapeDistribution
from repro.core.profiling.model_profiler import PerfModel


def pipeline_makespan(n_mb: int, e_pp: int, l_pp: int, e_dur, l_dur):
    """(N_mb + depth − 1) · max(E_dur, L_dur) — elementwise over arrays, so
    the sampling objectives score a whole batch of Monte-Carlo trials in
    one call (scalars in → numpy scalar out, a drop-in ``float``).

    >>> float(pipeline_makespan(4, 1, 2, 1.0, 3.0))
    18.0
    """
    return (n_mb + e_pp + l_pp - 1) * np.maximum(e_dur, l_dur)


def schedule_makespan(plan: ParallelismPlan, e_dur, l_dur):
    """Closed-form step estimate (N_mb + bubble_slots) · slot cost for any
    schedule family, elementwise over arrays (see ``docs/schedules.md``).

    ``e_dur`` follows each family's own per-stage convention: already
    divided by E_pp for the staged families; the *full* per-microbatch
    encoder duration under ``encoder_fill`` (its colocated E_pp is 1),
    which this function splits over the L_pp replicas.  The chunk then
    runs *serial* with the rank's LLM work, so the encoder_fill slot costs
    the sum — a (deliberately conservative: real bubble-filling overlaps
    part of it) upper estimate the sampling objectives' ``"simulate"``
    mode refines.  For ``schedule="1f1b"`` this is exactly
    `pipeline_makespan`.

    >>> lp = ModuleParallelism(1, 2, 1)
    >>> ep = ModuleParallelism(1, 1, 1)
    >>> float(schedule_makespan(ParallelismPlan(llm=lp, encoder=ep, n_mb=4,
    ...                                         schedule="encoder_fill"),
    ...                         1.0, 3.0))                # (4+1)·(3 + 1/2)
    17.5
    """
    if plan.schedule == "encoder_fill":
        return (plan.n_mb + plan.bubble_slots) \
            * (np.asarray(e_dur) / plan.llm.pp + l_dur)
    return (plan.n_mb + plan.bubble_slots) * np.maximum(e_dur, l_dur)


def accepts_fallback(fn) -> bool:
    """True if a corrector function takes a `fallback_shape` keyword —
    checked via signature, never by a trial call (a probe call would
    double-invoke stateful correctors and mask their real TypeErrors)."""
    import inspect
    try:
        return "fallback_shape" in inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return False


def correct_scalar(corrector, module: str, shape: float, tp: int,
                   dur: float, fallback_shape: Optional[float] = None) -> float:
    """Scalar `DurationCorrector` application, forwarding `fallback_shape`
    only to correctors whose `correct` accepts it (see
    `OnlineCalibrator.correct` for the fallback semantics)."""
    if corrector is None:
        return dur
    if fallback_shape is not None and accepts_fallback(corrector.correct):
        return corrector.correct(module, shape, tp, dur,
                                 fallback_shape=fallback_shape)
    return corrector.correct(module, shape, tp, dur)


def stage_durations(perf: PerfModel, ep: Optional[ModuleParallelism],
                    lp: ModuleParallelism, t_bsz: float, t_seq: float,
                    mode: str = "train") -> Tuple[float, float]:
    """(E_dur, L_dur) for one microbatch of mean shape (t_bsz, t_seq)."""
    e_dur = 0.0
    if perf.encoder is not None and ep is not None and t_bsz > 0:
        fl = perf.encoder.flops(t_bsz, perf.encoder.fixed_seq, mode).total
        thr = perf.encoder.thr_all(t_bsz, ep.tp)
        e_dur = fl / (thr * ep.pp)
    fl_l = perf.llm.flops(1.0, t_seq, mode)
    if perf.llm.thr_attn is not None:
        l_dur = (fl_l.attn / perf.llm.thr_attn(t_seq, lp.tp)
                 + fl_l.lin / perf.llm.thr_lin(t_seq, lp.tp)) / lp.pp
    else:
        l_dur = fl_l.total / (perf.llm.thr_all(t_seq, lp.tp) * lp.pp)
    return e_dur, l_dur


def mean_makespan(perf: PerfModel, plan: ParallelismPlan,
                  mean_bsz: float, mean_seq: float, gbs: int,
                  mode: str = "train", corrector=None) -> float:
    """Algorithm 1's mean-shape estimate for plan θ.

    corrector: optional `objective.DurationCorrector`.  Corrections are
    multiplicative ratios, so applying them to the per-stage (already /pp)
    duration equals correcting the TP-group duration — the same keying
    `search._ModuleTables` uses."""
    i = plan.n_mb
    ep, lp = plan.encoder, plan.llm
    t_bsz = mean_bsz * gbs / (i * ep.dp) if ep else 0.0
    t_seq = mean_seq * gbs / (i * lp.dp)
    e_dur, l_dur = stage_durations(perf, ep, lp, t_bsz, t_seq, mode)
    if corrector is not None:
        if ep is not None and e_dur > 0:
            e_dur = correct_scalar(corrector, "encoder", t_bsz, ep.tp,
                                   e_dur, fallback_shape=mean_bsz)
        l_dur = correct_scalar(corrector, "llm", t_seq, lp.tp, l_dur,
                               fallback_shape=mean_seq)
    return float(schedule_makespan(plan, e_dur, l_dur))


def expected_makespan(perf: PerfModel, plan: ParallelismPlan,
                      dist: ShapeDistribution, gbs: int, *,
                      n_trials: int = 16, seed: int = 0,
                      mode: str = "train") -> float:
    """Legacy Eq. 1 Monte-Carlo (aggregate-shape semantics).

    Samples `n_trials` random global batches from the empirical
    distribution, randomly partitions each into N_mb·L_dp buckets and takes
    the slowest bucket as the stage duration (random assignment — the
    baseline the Online Scheduler improves on).  Bucket durations are
    computed from the *summed* shape; the objective subsystem
    (`objective.ExpectedRandomObjective`) instead sums per-item durations,
    matching what the scheduler's C_max actually measures — prefer it."""
    rng = np.random.default_rng(seed)
    i, ep, lp = plan.n_mb, plan.encoder, plan.llm
    m = i * lp.dp
    n = len(dist)
    if n == 0:
        mean_bsz, mean_seq = 1.0, 1.0
        return mean_makespan(perf, plan, mean_bsz, mean_seq, gbs, mode)
    total = 0.0
    for _ in range(n_trials):
        idx = rng.integers(0, n, size=gbs)
        buckets = rng.integers(0, m, size=gbs)
        e_b = np.bincount(buckets, weights=dist.enc_batches[idx], minlength=m)
        s_b = np.bincount(buckets, weights=dist.llm_seqs[idx], minlength=m)
        # encoder buckets are grouped over E_dp, LLM buckets over L_dp: use
        # the per-bucket mean shape within each module's own grouping.
        if ep is not None:
            scale = lp.dp / ep.dp     # rebalance bucket count mismatch
            e_shapes = e_b * scale
            e_durs = np.array([
                perf.encoder.flops(b, perf.encoder.fixed_seq, mode).total
                / (perf.encoder.thr_all(b, ep.tp) * ep.pp)
                if b > 0 else 0.0 for b in e_shapes])
            e_dur = float(e_durs.max())
            e_pp = ep.pp
        else:
            e_dur, e_pp = 0.0, 0
        l_durs = perf.l_dur_batch(s_b, lp.tp, mode) / lp.pp
        l_dur = float(l_durs.max())
        total += pipeline_makespan(i, e_pp, lp.pp, e_dur, l_dur)
    return total / n_trials
