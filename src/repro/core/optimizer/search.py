"""Data-aware 3D Parallelism Optimizer (paper §3.3, Algorithm 1).

Finds θ* = (E_tp, E_pp, E_dp, L_tp, L_pp, L_dp, N_mb, schedule) minimizing
the expected makespan subject to chip-count (Eq. 3) and memory (Eq. 4/5)
constraints, using the Profiling Engine's throughput/memory models and the
Data Profiler's shape statistics.  The schedule family (1F1B, interleaved
virtual stages, encoder-in-bubble — see ``docs/schedules.md``) is searched
*jointly* with the partition: each family reuses the same duration/memory
tables and only changes the closed-form step estimate.

Implementation note: Algorithm 1's inner loop evaluates shapes of the form
    t_seq = mean_seq · GBS / (i · L_dp)
whose value depends only on k = i · L_dp ∈ {1..GBS}.  We therefore
precompute duration and memory tables indexed by (tp[, pp], k) once and
evaluate every candidate configuration with vectorized lookups — this keeps
the optimizer sub-second at 1024 chips (Fig. 16a) while remaining exactly
Algorithm 1.  Complexity matches the paper: O(GBS · N_chips^(1+ε)).

The scoring rule is pluggable (`repro.core.optimizer.objective`): the
vectorized mean-shape pass is always the prefilter; for a sampling
objective (``expected-random``, ``balanced-quantile``) the top candidates
are re-ranked — including alternative N_mb, since heterogeneity-aware
scores systematically prefer *fewer* buckets than the mean-shape estimate.
A `DurationCorrector` (e.g. the runtime's `OnlineCalibrator`) refines both
the tables and the Monte-Carlo durations, so the search sees the same
corrected durations the Online Scheduler trusts.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.optimizer.makespan import mean_makespan
from repro.core.optimizer.objective import (
    DurationCorrector,
    Objective,
    correct_durations,
    get_objective,
)
from repro.core.optimizer.space import (
    SCHEDULES,
    VIRTUAL_CHUNKS,
    ClusterSpec,
    ModuleParallelism,
    ParallelismPlan,
    enumerate_configs,
)
from repro.core.profiling.data_profiler import ShapeDistribution
from repro.core.profiling.model_profiler import ModulePerf, PerfModel


def _pow2s_up_to(n: int):
    v, out = 1, []
    while v <= n:
        out.append(v)
        v *= 2
    return out


class _ModuleTables:
    """Vectorized duration/memory tables for one module.

    dur[tp][k]          — stage duration for shape(k) on one TP group
    model_state[tp][pp] — Eq.4/5 model-state bytes
    act[tp][pp][k]      — activation bytes for shape(k)
    where shape(k) = mean_shape · GBS / k.

    With a `corrector`, every duration entry is refined at its own shape —
    the per-(module, shape-bucket, tp) path the Online Scheduler applies to
    its predictions, so search-time and schedule-time durations agree.
    """

    def __init__(self, perf: ModulePerf, mean_shape: float, gbs: int,
                 tps, pps, mode: str, is_encoder: bool, *,
                 corrector: Optional[DurationCorrector] = None):
        self.gbs = gbs
        self.module = "encoder" if is_encoder else "llm"
        ks = np.arange(1, gbs + 1, dtype=np.float64)
        shapes = mean_shape * gbs / ks                     # shape(k)
        self.shapes = shapes
        n_layers = perf.cfg.n_layers

        # the same vectorized attn/lin-polynomial duration path the
        # scheduler's per-item predictions use (ModulePerf.duration_batch).
        # Corrections key on shape(k); aggregate sizes the per-item
        # calibration never observed fall back to the mean item shape's
        # cell (see OnlineCalibrator.correct) so a uniform runtime
        # slowdown reaches every table entry, not just item-scale ones.
        self.dur: Dict[int, np.ndarray] = {}
        for tp in tps:
            dur = perf.duration_batch(shapes, tp, mode)
            self.dur[tp] = correct_durations(corrector, self.module, shapes,
                                             tp, dur,
                                             fallback_shape=mean_shape)

        self.model_state: Dict[Tuple[int, int], float] = {}
        self.act: Dict[Tuple[int, int], np.ndarray] = {}
        for tp in tps:
            for pp in pps:
                layers = n_layers / pp
                self.model_state[(tp, pp)] = perf.memory.model_state(layers, tp)
                pts = np.stack([np.full_like(shapes, layers),
                                np.full_like(shapes, tp), shapes], axis=1)
                self.act[(tp, pp)] = perf.memory.act_state_grid.batch(pts)


@dataclass
class SearchResult:
    plan: Optional[ParallelismPlan]
    makespan: float
    n_configs: int
    n_feasible: int
    elapsed_s: float
    history: list = field(default_factory=list)

    @property
    def found(self) -> bool:
        return self.plan is not None


class ParallelismOptimizer:
    def __init__(self, cluster: ClusterSpec, perf: PerfModel, *,
                 max_pp: Optional[int] = None, mode: str = "train",
                 objective: str | Objective = "mean",
                 n_trials: Optional[int] = None,
                 quantile: Optional[float] = None, seed: int = 0,
                 calibrator: Optional[DurationCorrector] = None,
                 partition_step: int = 0, keep_history: bool = False,
                 refine_expected_top_k: int = 32,
                 schedules: Sequence[str] = SCHEDULES):
        """objective: 'mean' (Algorithm 1), 'expected-random' (Eq. 1 via
        Monte-Carlo over random round-robin assignment), 'balanced-quantile'
        (LPT-balanced assignment scored at `quantile`), or any
        `objective.Objective` instance.  Sampling objectives use the
        mean-based prefilter, then re-rank the top candidates.
        n_trials/quantile default to the objective's own configuration
        (None = leave untouched; for an instance a provided value yields a
        reconfigured copy).

        seed: base seed for the Monte-Carlo draws — equal seeds reproduce
        the search exactly, distinct seeds resample the trial batches.
        calibrator: optional `DurationCorrector` refining every duration
        the search evaluates (tables and Monte-Carlo alike).
        schedules: schedule families to enumerate (default: all of
        `space.SCHEDULES`); pass ("1f1b",) to reproduce the fixed-schedule
        search the paper's Algorithm 1 describes."""
        self.cluster = cluster
        self.perf = perf
        self.mode = mode
        self.objective_obj = get_objective(objective, n_trials=n_trials,
                                           q=quantile)
        self.objective = self.objective_obj.name
        self.n_trials = getattr(self.objective_obj, "n_trials", n_trials)
        self.seed = seed
        self.calibrator = calibrator
        self.schedules = tuple(schedules)
        self.keep_history = keep_history
        self.refine_top_k = refine_expected_top_k
        self.max_pp = max_pp if max_pp is not None else \
            min(64, perf.llm.cfg.n_layers)
        if partition_step:
            self.partition_step = partition_step
        else:
            self.partition_step = max(1, cluster.n_chips // 256)

    # ------------------------------------------------------------------ #
    def _divisor_pps(self, n_layers_cap: int):
        return list(range(1, min(self.max_pp, n_layers_cap) + 1))

    def build_tables(self, dist: ShapeDistribution, gbs: int
                     ) -> Tuple[_ModuleTables, Optional[_ModuleTables]]:
        """(llm_tables, encoder_tables) for `search()` — public so tests can
        assert the calibrator-refined durations match the scheduler's."""
        perf, cluster = self.perf, self.cluster
        mean_bsz, mean_seq = dist.mean() if len(dist) else (1.0, 1.0)
        tps = _pow2s_up_to(cluster.chips_per_node)
        l_pps = self._divisor_pps(perf.llm.cfg.n_layers)
        l_tab = _ModuleTables(perf.llm, mean_seq, gbs, tps, l_pps,
                              self.mode, is_encoder=False,
                              corrector=self.calibrator)
        e_tab = None
        if perf.encoder is not None:
            e_pps = self._divisor_pps(perf.encoder.cfg.n_layers)
            e_tab = _ModuleTables(perf.encoder, mean_bsz, gbs, tps, e_pps,
                                  self.mode, is_encoder=True,
                                  corrector=self.calibrator)
        return l_tab, e_tab

    @staticmethod
    def _k_index(tab: _ModuleTables, mp: ModuleParallelism, gbs: int,
                 n_max: int):
        """(dur, act) table rows at k = min(i·dp, gbs) − 1 for i = 1..n_max.
        The common case (i·dp ≤ gbs throughout) is a dp-strided *view* —
        this lookup runs once per enumerated config, so avoiding the fancy
        copy is what keeps the prefilter sub-second at 1024 chips."""
        if mp.dp * n_max <= gbs:
            sl = slice(mp.dp - 1, mp.dp * n_max, mp.dp)
            return tab.dur[mp.tp][sl], tab.act[(mp.tp, mp.pp)][sl]
        k = np.minimum(np.arange(1, n_max + 1) * mp.dp, gbs) - 1
        return tab.dur[mp.tp][k], tab.act[(mp.tp, mp.pp)][k]

    def _eval_config(self, ep: Optional[ModuleParallelism],
                     lp: ModuleParallelism, sched: str, gbs: int,
                     l_tab: _ModuleTables, e_tab: Optional[_ModuleTables]):
        """Mean-shape makespan + feasibility for every N_mb of one
        (config, schedule-family) pair.  Returns (i, T, feas) arrays with
        infeasible or family-invalid N_mb (interleaved divisibility) scored
        inf, or None when no N_mb fits in memory (short-circuits before the
        makespan math — the search hot path).  Candidate validity is
        `np.isfinite(T)`, which is `feas` *and* the family constraint."""
        mem_cap = self.cluster.mem_bytes
        n_max = max(1, gbs // lp.dp)
        l_dur, l_act = self._k_index(l_tab, lp, gbs, n_max)
        l_mem = l_tab.model_state[(lp.tp, lp.pp)] + lp.pp * l_act
        i = np.arange(1, n_max + 1)
        if sched == "encoder_fill":
            # the replicated encoder shares the LLM's chips, so the memory
            # budgets add; its act window matches the LLM's in-flight depth.
            e_dur, e_act = self._k_index(e_tab, ep, gbs, n_max)
            feas = (l_mem + e_tab.model_state[(ep.tp, 1)]
                    + lp.pp * e_act <= mem_cap)
            if not feas.any():
                return None
            # per-slot cost is *serial* LLM stage + encoder chunk (the
            # conservative closed form — `schedule_makespan` convention).
            T = (i + lp.pp - 1) * (l_dur + e_dur) / lp.pp
            T[~feas] = np.inf
            return i, T, feas
        feas = l_mem <= mem_cap
        if ep is not None:
            e_dur, e_act = self._k_index(e_tab, ep, gbs, n_max)
            feas &= (e_tab.model_state[(ep.tp, ep.pp)]
                     + (ep.pp + lp.pp) * e_act <= mem_cap)
        if not feas.any():
            return None
        if ep is not None:
            dur = np.maximum(e_dur / ep.pp, l_dur / lp.pp)
            e_pp = ep.pp
        else:
            dur = l_dur / lp.pp
            e_pp = 0
        depth = e_pp + lp.pp
        if sched == "interleaved":
            T = (i + (depth - 1) / VIRTUAL_CHUNKS) * dur
            T[i % depth != 0] = np.inf       # family divisibility constraint
        else:
            T = (i + depth - 1) * dur
        T[~feas] = np.inf
        return i, T, feas

    def search(self, dist: ShapeDistribution, gbs: int) -> SearchResult:
        t0 = time.monotonic()
        perf, cluster = self.perf, self.cluster
        has_encoder = perf.encoder is not None
        l_tab, e_tab = self.build_tables(dist, gbs)

        best_T = float("inf")
        best: Optional[ParallelismPlan] = None
        n_configs = n_feasible = 0
        history = []
        rerank = self.objective != "mean" and len(dist) > 0
        top: list = []       # (T_mean, ep, lp, sched) candidates to re-rank

        for ep, lp, sched in enumerate_configs(
                cluster, has_encoder=has_encoder, max_pp=self.max_pp,
                partition_step=self.partition_step,
                schedules=self.schedules):
            if lp.pp > perf.llm.cfg.n_layers:
                continue
            if ep is not None and ep.pp > perf.encoder.cfg.n_layers:
                continue
            if sched == "interleaved" and (
                    lp.pp * VIRTUAL_CHUNKS > perf.llm.cfg.n_layers
                    or (ep is not None
                        and ep.pp * VIRTUAL_CHUNKS > perf.encoder.cfg.n_layers)):
                continue      # each rank hosts v chunks: needs pp·v layers
            n_configs += 1
            evald = self._eval_config(ep, lp, sched, gbs, l_tab, e_tab)
            if evald is None:
                continue
            i, T, feas = evald
            n_feasible += int(feas.sum())
            j = int(np.argmin(T))
            if not np.isfinite(T[j]):
                continue      # feasible N_mb exist but none family-valid
            if self.keep_history:
                plan_j = ParallelismPlan(llm=lp, encoder=ep, n_mb=int(i[j]),
                                         schedule=sched)
                history.append((plan_j.as_tuple(), float(T[j])))
            if T[j] < best_T:
                best_T = float(T[j])
                best = ParallelismPlan(llm=lp, encoder=ep, n_mb=int(i[j]),
                                       schedule=sched)
            if rerank:
                top.append((float(T[j]), ep, lp, sched))

        if rerank and top:
            best, best_T = self._rerank(top, dist, gbs, l_tab, e_tab,
                                        fallback=(best, best_T))

        return SearchResult(best, best_T, n_configs, n_feasible,
                            time.monotonic() - t0, history)

    def _rerank(self, top: list, dist: ShapeDistribution, gbs: int,
                l_tab: _ModuleTables, e_tab: Optional[_ModuleTables],
                fallback):
        """Re-score the best mean-prefiltered configs under the sampling
        objective.  Each config is expanded over alternative feasible N_mb
        (powers of two plus the mean pick): the mean-shape estimate
        systematically overrates many-bucket plans under fat-tailed shape
        distributions, so the objective must be free to choose fewer."""
        top.sort(key=lambda t: t[0])
        plans = []
        for _, ep, lp, sched in top[: self.refine_top_k]:
            evald = self._eval_config(ep, lp, sched, gbs, l_tab, e_tab)
            if evald is None:
                continue
            i, _T, _feas = evald
            ok = np.isfinite(_T)      # feasible AND family-valid N_mb
            if not ok.any():
                continue
            cands = {int(i[int(np.argmin(_T))])}
            cands.update(v for v in _pow2s_up_to(int(i[-1])) if ok[v - 1])
            plans.extend(ParallelismPlan(llm=lp, encoder=ep, n_mb=n_mb,
                                         schedule=sched)
                         for n_mb in sorted(cands) if ok[n_mb - 1])
        if not plans:
            return fallback
        # the cache carries per-(tp, pp) item durations AND the sampled
        # trial indices, both plan-independent, across every candidate —
        # each plan evaluation is then one batched partition + one
        # schedule-family wavefront over all (trial, rank) instances.
        obj = self.objective_obj
        best, best_T = None, float("inf")
        dur_cache: Dict = {}
        for plan in plans:
            T = obj.evaluate(self.perf, plan, dist, gbs, mode=self.mode,
                             corrector=self.calibrator, seed=self.seed,
                             cache=dur_cache)
            if T < best_T:
                best_T, best = T, plan
        return (best, best_T) if best is not None else fallback

    # ------------------------------------------------------------------ #
    def baseline_uniform(self, dist: ShapeDistribution, gbs: int,
                         tp: int, pp: int) -> SearchResult:
        """Data-agnostic baseline: one uniform (tp, pp, dp) over the whole
        model (what Megatron/PyTorch enforce), maximal N_mb — the Fig. 7/10
        comparison point.  The encoder occupies pipeline stage 0 (Fig. 1)."""
        t0 = time.monotonic()
        N = self.cluster.n_chips
        if N % (tp * pp):
            return SearchResult(None, float("inf"), 0, 0, 0.0)
        dp = N // (tp * pp)
        mean_bsz, mean_seq = dist.mean() if len(dist) else (1.0, 1.0)
        n_mb = max(1, gbs // dp)
        from repro.core.optimizer import memory_model as _mm

        def _mem_ok(ep, lp):
            t_bsz = mean_bsz * gbs / (n_mb * dp)
            t_seq = mean_seq * gbs / (n_mb * dp)
            return _mm.feasible(self.perf.encoder, self.perf.llm, ep, lp,
                                t_bsz, t_seq, self.cluster.mem_bytes)

        if self.perf.encoder is None:
            lp = ModuleParallelism(tp, pp, dp)
            plan = ParallelismPlan(llm=lp, encoder=None, n_mb=n_mb)
            if not _mem_ok(None, lp):
                return SearchResult(plan, float("inf"), 1, 0,
                                    time.monotonic() - t0)
            T = mean_makespan(self.perf, plan, mean_bsz, mean_seq, gbs,
                              self.mode)
            return SearchResult(plan, T, 1, 1, time.monotonic() - t0)
        if pp >= 2:
            # Fig. 1 layout: encoder = stage 0 (tp·dp chips), LLM = the
            # remaining pp−1 stages.  Total chips = tp·pp·dp = N exactly.
            ep = ModuleParallelism(tp, 1, dp)
            lp = ModuleParallelism(tp, pp - 1, dp)
            plan = ParallelismPlan(llm=lp, encoder=ep, n_mb=n_mb)
            if not _mem_ok(ep, lp):
                return SearchResult(plan, float("inf"), 1, 0,
                                    time.monotonic() - t0)
            T = mean_makespan(self.perf, plan, mean_bsz, mean_seq, gbs,
                              self.mode)
        else:
            # pp == 1: encoder and LLM colocated, executed sequentially.
            ep = ModuleParallelism(tp, 1, dp)
            lp = ModuleParallelism(tp, 1, dp)
            plan = ParallelismPlan(llm=lp, encoder=ep, n_mb=n_mb)
            if not _mem_ok(ep, lp):
                return SearchResult(plan, float("inf"), 1, 0,
                                    time.monotonic() - t0)
            from repro.core.optimizer.makespan import stage_durations
            t_bsz = mean_bsz * gbs / (n_mb * dp)
            t_seq = mean_seq * gbs / (n_mb * dp)
            e_dur, l_dur = stage_durations(self.perf, ep, lp, t_bsz, t_seq,
                                           self.mode)
            T = n_mb * (e_dur + l_dur)
        return SearchResult(plan, T, 1, 1, time.monotonic() - t0)
