"""Data-aware 3D Parallelism Optimizer (paper §3.3, Algorithm 1).

Finds θ* = (E_tp, E_pp, E_dp, L_tp, L_pp, L_dp, N_mb) minimizing the
expected makespan subject to chip-count (Eq. 3) and memory (Eq. 4/5)
constraints, using the Profiling Engine's throughput/memory models and the
Data Profiler's shape statistics.

Implementation note: Algorithm 1's inner loop evaluates shapes of the form
    t_seq = mean_seq · GBS / (i · L_dp)
whose value depends only on k = i · L_dp ∈ {1..GBS}.  We therefore
precompute duration and memory tables indexed by (tp[, pp], k) once and
evaluate every candidate configuration with vectorized lookups — this keeps
the optimizer sub-second at 1024 chips (Fig. 16a) while remaining exactly
Algorithm 1.  Complexity matches the paper: O(GBS · N_chips^(1+ε)).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.optimizer.makespan import (
    expected_makespan,
    mean_makespan,
    pipeline_makespan,
)
from repro.core.optimizer.space import (
    ClusterSpec,
    ModuleParallelism,
    ParallelismPlan,
    enumerate_configs,
)
from repro.core.profiling.data_profiler import ShapeDistribution
from repro.core.profiling.flops import module_flops
from repro.core.profiling.model_profiler import ModulePerf, PerfModel


def _pow2s_up_to(n: int):
    v, out = 1, []
    while v <= n:
        out.append(v)
        v *= 2
    return out


class _ModuleTables:
    """Vectorized duration/memory tables for one module.

    dur[tp][k]          — stage duration for shape(k) on one TP group
    model_state[tp][pp] — Eq.4/5 model-state bytes
    act[tp][pp][k]      — activation bytes for shape(k)
    where shape(k) = mean_shape · GBS / k.
    """

    def __init__(self, perf: ModulePerf, mean_shape: float, gbs: int,
                 tps, pps, mode: str, is_encoder: bool):
        self.gbs = gbs
        ks = np.arange(1, gbs + 1, dtype=np.float64)
        shapes = mean_shape * gbs / ks                     # shape(k)
        n_layers = perf.cfg.n_layers

        # --- FLOPs per shape (vectorized via the attn/lin split) -------- #
        if is_encoder:
            per_item = module_flops(perf.cfg, 1.0, perf.fixed_seq, mode=mode)
            fl_attn = per_item.attn * shapes
            fl_lin = per_item.lin * shapes
        else:
            # attn(s) = a1·s + a2·s², lin(s) = b1·s  (exact: polynomial)
            f1 = module_flops(perf.cfg, 1.0, 1.0, mode=mode)
            f2 = module_flops(perf.cfg, 1.0, 2.0, mode=mode)
            a2 = (f2.attn - 2 * f1.attn) / 2.0
            a1 = f1.attn - a2
            if perf.cfg.attention_kind == "sliding" and perf.cfg.window_size:
                # piecewise: quadratic until W, then linear — evaluate exact
                fl_attn = np.array([module_flops(perf.cfg, 1.0, s, mode=mode).attn
                                    for s in shapes])
            else:
                fl_attn = a1 * shapes + a2 * shapes ** 2
            fl_lin = f1.lin * shapes
        self.dur: Dict[int, np.ndarray] = {}
        for tp in tps:
            if perf.thr_attn is not None and perf.thr_lin is not None:
                thr_a = perf.thr_attn.batch(shapes, tp)
                thr_l = perf.thr_lin.batch(shapes, tp)
                self.dur[tp] = fl_attn / thr_a + fl_lin / thr_l
            else:
                thr = perf.thr_all.batch(shapes, tp)
                self.dur[tp] = (fl_attn + fl_lin) / thr

        self.model_state: Dict[Tuple[int, int], float] = {}
        self.act: Dict[Tuple[int, int], np.ndarray] = {}
        for tp in tps:
            for pp in pps:
                layers = n_layers / pp
                self.model_state[(tp, pp)] = perf.memory.model_state(layers, tp)
                pts = np.stack([np.full_like(shapes, layers),
                                np.full_like(shapes, tp), shapes], axis=1)
                self.act[(tp, pp)] = perf.memory.act_state_grid.batch(pts)


@dataclass
class SearchResult:
    plan: Optional[ParallelismPlan]
    makespan: float
    n_configs: int
    n_feasible: int
    elapsed_s: float
    history: list = field(default_factory=list)

    @property
    def found(self) -> bool:
        return self.plan is not None


class ParallelismOptimizer:
    def __init__(self, cluster: ClusterSpec, perf: PerfModel, *,
                 max_pp: Optional[int] = None, mode: str = "train",
                 objective: str = "mean", n_trials: int = 8,
                 partition_step: int = 0, keep_history: bool = False,
                 refine_expected_top_k: int = 32):
        """objective: 'mean' (Algorithm 1) or 'expected' (Eq. 1: mean-based
        prefilter, then Monte-Carlo re-rank of the top candidates)."""
        self.cluster = cluster
        self.perf = perf
        self.mode = mode
        self.objective = objective
        self.n_trials = n_trials
        self.keep_history = keep_history
        self.refine_top_k = refine_expected_top_k
        self.max_pp = max_pp if max_pp is not None else \
            min(64, perf.llm.cfg.n_layers)
        if partition_step:
            self.partition_step = partition_step
        else:
            self.partition_step = max(1, cluster.n_chips // 256)

    # ------------------------------------------------------------------ #
    def _divisor_pps(self, n_layers_cap: int):
        return list(range(1, min(self.max_pp, n_layers_cap) + 1))

    def search(self, dist: ShapeDistribution, gbs: int) -> SearchResult:
        t0 = time.monotonic()
        perf, cluster = self.perf, self.cluster
        has_encoder = perf.encoder is not None
        mean_bsz, mean_seq = dist.mean() if len(dist) else (1.0, 1.0)
        tps = _pow2s_up_to(cluster.chips_per_node)

        l_pps = self._divisor_pps(perf.llm.cfg.n_layers)
        l_tab = _ModuleTables(perf.llm, mean_seq, gbs, tps, l_pps,
                              self.mode, is_encoder=False)
        e_tab = None
        if has_encoder:
            e_pps = self._divisor_pps(perf.encoder.cfg.n_layers)
            e_tab = _ModuleTables(perf.encoder, mean_bsz, gbs, tps, e_pps,
                                  self.mode, is_encoder=True)

        best_T = float("inf")
        best: Optional[ParallelismPlan] = None
        best_i = 1
        n_configs = n_feasible = 0
        history = []
        mem_cap = cluster.mem_bytes
        top: list = []       # (T, plan) candidates for expected re-rank

        for ep, lp in enumerate_configs(cluster, has_encoder=has_encoder,
                                        max_pp=self.max_pp,
                                        partition_step=self.partition_step):
            if lp.pp > perf.llm.cfg.n_layers:
                continue
            if ep is not None and ep.pp > perf.encoder.cfg.n_layers:
                continue
            n_configs += 1
            n_max = max(1, gbs // lp.dp)
            i = np.arange(1, n_max + 1)
            k_l = np.minimum(i * lp.dp, gbs) - 1            # table index
            l_dur = l_tab.dur[lp.tp][k_l] / lp.pp
            l_mem = l_tab.model_state[(lp.tp, lp.pp)] \
                + lp.pp * l_tab.act[(lp.tp, lp.pp)][k_l]
            feas = l_mem <= mem_cap
            if ep is not None:
                k_e = np.minimum(i * ep.dp, gbs) - 1
                e_dur = e_tab.dur[ep.tp][k_e] / ep.pp
                e_mem = e_tab.model_state[(ep.tp, ep.pp)] \
                    + (ep.pp + lp.pp) * e_tab.act[(ep.tp, ep.pp)][k_e]
                feas &= e_mem <= mem_cap
                e_pp = ep.pp
            else:
                e_dur = np.zeros_like(l_dur)
                e_pp = 0
            if not feas.any():
                continue
            T = (i + e_pp + lp.pp - 1) * np.maximum(e_dur, l_dur)
            T = np.where(feas, T, np.inf)
            n_feasible += int(feas.sum())
            j = int(np.argmin(T))
            if self.keep_history:
                plan_j = ParallelismPlan(llm=lp, encoder=ep, n_mb=int(i[j]))
                history.append((plan_j.as_tuple(), float(T[j])))
            if T[j] < best_T:
                best_T = float(T[j])
                best = ParallelismPlan(llm=lp, encoder=ep, n_mb=int(i[j]))
            if self.objective == "expected":
                top.append((float(T[j]),
                            ParallelismPlan(llm=lp, encoder=ep, n_mb=int(i[j]))))

        if self.objective == "expected" and len(dist) and top:
            top.sort(key=lambda t: t[0])
            best_T = float("inf")
            for _, plan in top[: self.refine_top_k]:
                T = expected_makespan(perf, plan, dist, gbs,
                                      n_trials=self.n_trials, mode=self.mode)
                if T < best_T:
                    best_T, best = T, plan

        return SearchResult(best, best_T, n_configs, n_feasible,
                            time.monotonic() - t0, history)

    # ------------------------------------------------------------------ #
    def baseline_uniform(self, dist: ShapeDistribution, gbs: int,
                         tp: int, pp: int) -> SearchResult:
        """Data-agnostic baseline: one uniform (tp, pp, dp) over the whole
        model (what Megatron/PyTorch enforce), maximal N_mb — the Fig. 7/10
        comparison point.  The encoder occupies pipeline stage 0 (Fig. 1)."""
        t0 = time.monotonic()
        N = self.cluster.n_chips
        if N % (tp * pp):
            return SearchResult(None, float("inf"), 0, 0, 0.0)
        dp = N // (tp * pp)
        mean_bsz, mean_seq = dist.mean() if len(dist) else (1.0, 1.0)
        n_mb = max(1, gbs // dp)
        from repro.core.optimizer import memory_model as _mm

        def _mem_ok(ep, lp):
            t_bsz = mean_bsz * gbs / (n_mb * dp)
            t_seq = mean_seq * gbs / (n_mb * dp)
            return _mm.feasible(self.perf.encoder, self.perf.llm, ep, lp,
                                t_bsz, t_seq, self.cluster.mem_bytes)

        if self.perf.encoder is None:
            lp = ModuleParallelism(tp, pp, dp)
            plan = ParallelismPlan(llm=lp, encoder=None, n_mb=n_mb)
            if not _mem_ok(None, lp):
                return SearchResult(plan, float("inf"), 1, 0,
                                    time.monotonic() - t0)
            T = mean_makespan(self.perf, plan, mean_bsz, mean_seq, gbs,
                              self.mode)
            return SearchResult(plan, T, 1, 1, time.monotonic() - t0)
        if pp >= 2:
            # Fig. 1 layout: encoder = stage 0 (tp·dp chips), LLM = the
            # remaining pp−1 stages.  Total chips = tp·pp·dp = N exactly.
            ep = ModuleParallelism(tp, 1, dp)
            lp = ModuleParallelism(tp, pp - 1, dp)
            plan = ParallelismPlan(llm=lp, encoder=ep, n_mb=n_mb)
            if not _mem_ok(ep, lp):
                return SearchResult(plan, float("inf"), 1, 0,
                                    time.monotonic() - t0)
            T = mean_makespan(self.perf, plan, mean_bsz, mean_seq, gbs,
                              self.mode)
        else:
            # pp == 1: encoder and LLM colocated, executed sequentially.
            ep = ModuleParallelism(tp, 1, dp)
            lp = ModuleParallelism(tp, 1, dp)
            plan = ParallelismPlan(llm=lp, encoder=ep, n_mb=n_mb)
            if not _mem_ok(ep, lp):
                return SearchResult(plan, float("inf"), 1, 0,
                                    time.monotonic() - t0)
            from repro.core.optimizer.makespan import stage_durations
            t_bsz = mean_bsz * gbs / (n_mb * dp)
            t_seq = mean_seq * gbs / (n_mb * dp)
            e_dur, l_dur = stage_durations(self.perf, ep, lp, t_bsz, t_seq,
                                           self.mode)
            T = n_mb * (e_dur + l_dur)
        return SearchResult(plan, T, 1, 1, time.monotonic() - t0)
