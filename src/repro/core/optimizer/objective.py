"""Pluggable search objectives for the Parallelism Optimizer (paper Eq. 1).

Eq. 1 ranks plans by E_D[T(d; θ)] — an expectation over the *data-induced*
variation in computation.  Three interchangeable estimators of that
expectation live here:

  * ``mean``              — Algorithm 1's mean-shape approximation: one
                            aggregate shape per bucket, closed form.  Fast
                            (it is the vectorized prefilter in ``search()``)
                            but blind to heterogeneity: with ~1 item per
                            bucket under a fat-tailed shape distribution it
                            underestimates the bottleneck bucket badly.
  * ``expected-random``   — Monte-Carlo over sampled global batches with the
                            *data-agnostic* round-robin assignment real
                            loaders perform (``schedule_random``), scored by
                            the mean over trials.  Pessimistic: the Online
                            Scheduler will do better than random.
  * ``balanced-quantile`` — models what the Online Scheduler actually does:
                            sample global batches from the empirical
                            `ShapeDistribution`, partition each into the
                            plan's N_mb · L_dp buckets with ``lpt_schedule``
                            (optionally the hybrid BnB solver), and score
                            the plan by a configurable quantile (default
                            p90) of the per-trial pipeline makespans.

All three share one duration model — *per-item* stage durations summed per
bucket, exactly what the scheduler's ``cmax`` computes — and one correction
hook: a ``DurationCorrector`` (duck-typed to `OnlineCalibrator`) refines
every predicted duration, so the optimizer ranks plans with the same
corrected durations the scheduler trusts at runtime.

Sampling is seeded per trial with ``default_rng([seed, trial, ...])`` so
two objectives given the same seed see the *same* sampled batches (the
property tests rely on this), and so ``search(seed=…)`` can perturb the
Monte-Carlo draw without re-seeding global state.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Protocol, Tuple

import numpy as np

from repro.core.optimizer.makespan import (
    accepts_fallback,
    correct_scalar,
    mean_makespan,
    pipeline_makespan,  # noqa: F401  (re-exported for the property harness)
    schedule_makespan,
)
from repro.core.optimizer.space import ParallelismPlan
from repro.core.profiling.data_profiler import ShapeDistribution
from repro.core.profiling.model_profiler import PerfModel

# NOTE: repro.core.scheduler imports this module (the scheduler shares the
# corrected-duration path), so scheduler solvers are imported lazily at
# call time to keep the package import acyclic.


class DurationCorrector(Protocol):
    """Multiplicative refinement of a predicted duration, keyed by
    (module, shape, tp) — `repro.runtime.calibration.OnlineCalibrator` is
    the canonical implementation."""

    def correct(self, module: str, shape: float, tp: int,
                predicted: float) -> float: ...


def correct_durations(corrector, module: str, shapes: np.ndarray, tp: int,
                      durs: np.ndarray,
                      fallback_shape: Optional[float] = None) -> np.ndarray:
    """Vectorized corrector application with a scalar fallback.

    fallback_shape: forwarded to correctors that support it (see
    `OnlineCalibrator.correct`) — used by the mean-shape search tables,
    whose aggregate bucket sizes the per-item calibration never observed.
    Correctors with the plain 4-argument protocol simply don't get it."""
    if corrector is None:
        return durs
    fn = getattr(corrector, "correct_array", None)
    if fn is not None:
        if fallback_shape is not None and accepts_fallback(fn):
            return fn(module, shapes, tp, durs,
                      fallback_shape=fallback_shape)
        return fn(module, shapes, tp, durs)
    return np.array([correct_scalar(corrector, module, float(s), tp,
                                    float(d), fallback_shape)
                     for s, d in zip(shapes, durs)])


def corrected_item_durations(perf: PerfModel, plan: ParallelismPlan,
                             enc_batches: np.ndarray, llm_seqs: np.ndarray,
                             *, mode: str = "train", adaptive=None,
                             corrector: Optional[DurationCorrector] = None,
                             ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-item (E_dur, L_dur) under plan θ, refined by the correction
    hooks in scheduler order (adaptive first, then calibration).

    This is the single duration path shared by
    `OnlineMicrobatchScheduler.item_durations` and the sampling objectives,
    so the optimizer's Monte-Carlo sees byte-identical durations to the
    scheduler's predictions on identical shapes.
    """
    ep, lp = plan.encoder, plan.llm
    enc_batches = np.asarray(enc_batches, dtype=np.float64)
    llm_seqs = np.asarray(llm_seqs, dtype=np.float64)
    has_enc = perf.encoder is not None and ep is not None
    if has_enc:
        e_dur = perf.e_dur_batch(enc_batches, ep.tp, mode) / max(ep.pp, 1)
    else:
        e_dur = np.zeros(len(llm_seqs))
    l_dur = perf.l_dur_batch(llm_seqs, lp.tp, mode) / max(lp.pp, 1)
    if adaptive is not None:
        for i in range(len(llm_seqs)):
            if e_dur[i] > 0:
                e_dur[i] = adaptive.correct("encoder", float(enc_batches[i]),
                                            e_dur[i])
            l_dur[i] = adaptive.correct("llm", float(llm_seqs[i]), l_dur[i])
    if corrector is not None:
        if has_enc:
            e_dur = correct_durations(corrector, "encoder", enc_batches,
                                      ep.tp, e_dur)
        l_dur = correct_durations(corrector, "llm", llm_seqs, lp.tp, l_dur)
    return e_dur, l_dur


@dataclass
class ObjectiveResult:
    score: float
    samples: np.ndarray          # per-trial pipeline makespans


# Cache key for per-item duration arrays: durations depend only on the
# module parallelisms, not on n_mb, so a re-rank over many (plan, n_mb)
# candidates reuses them.
def _dur_key(plan: ParallelismPlan):
    ep = plan.encoder
    return ((ep.tp, ep.pp) if ep is not None else None,
            (plan.llm.tp, plan.llm.pp))


class Objective:
    """A plan-scoring rule. Lower is better."""

    name: str = "base"

    def evaluate(self, perf: PerfModel, plan: ParallelismPlan,
                 dist: ShapeDistribution, gbs: int, *, mode: str = "train",
                 corrector: Optional[DurationCorrector] = None,
                 seed: int = 0, cache: Optional[Dict] = None) -> float:
        return self.evaluate_samples(perf, plan, dist, gbs, mode=mode,
                                     corrector=corrector, seed=seed,
                                     cache=cache).score

    def evaluate_samples(self, perf, plan, dist, gbs, *, mode="train",
                         corrector=None, seed: int = 0,
                         cache=None) -> ObjectiveResult:
        raise NotImplementedError

    # ------------------------------------------------------------------ #
    def _item_durations(self, perf, plan, dist, mode, corrector, cache):
        key = _dur_key(plan)
        if cache is not None and key in cache:
            return cache[key]
        out = corrected_item_durations(perf, plan, dist.enc_batches,
                                       dist.llm_seqs, mode=mode,
                                       corrector=corrector)
        if cache is not None:
            cache[key] = out
        return out


class MeanObjective(Objective):
    """Algorithm 1: one mean shape per bucket, closed form (no sampling)."""

    name = "mean"

    def evaluate_samples(self, perf, plan, dist, gbs, *, mode="train",
                         corrector=None, seed: int = 0,
                         cache=None) -> ObjectiveResult:
        mean_bsz, mean_seq = dist.mean() if len(dist) else (1.0, 1.0)
        T = mean_makespan(perf, plan, mean_bsz, mean_seq, gbs, mode,
                          corrector=corrector)
        return ObjectiveResult(T, np.array([T]))


class _SamplingObjective(Objective):
    """Shared Monte-Carlo kernel: sample `n_trials` global batches from the
    empirical distribution, partition each into m = N_mb · L_dp buckets,
    and score the per-trial step.

    score:
      * ``"simulate"`` (default) — hand each rank's buckets to the
        event-driven 1F1B simulator (buckets map to (mb, rank) slots the
        way the data loader consumes `ScheduleOutput.groups`: bucket
        i·L_dp + r is microbatch i of rank r) and take the slowest rank.
        This anchors the objective to the 1F1B simulator: the closed
        formula charges the fattest bucket to *every* pipeline slot, which
        badly misprices fat-tailed batches where only one microbatch is
        fat.  All trials and ranks run in one `simulate_1f1b_batch`
        wavefront call, so this holds at every GBS — there is no
        large-GBS fallback to the closed form, and scores at different
        GBS are always produced by the same estimator.
      * ``"pipeline"`` — the paper's closed form
        (N_mb + depth − 1) · C_max, i.e. exactly the scheduler's
        `ScheduleOutput.step_makespan`.  Monotone in C_max, which makes the
        partition-dominance invariants provable — the property harness
        uses this mode.
    """

    def __init__(self, n_trials: int = 16, score: str = "simulate",
                 bwd_over_fwd: float = 2.0):
        self.n_trials = n_trials
        self.score = score
        self.bwd_over_fwd = bwd_over_fwd
        self._validate()

    def _validate(self) -> None:
        """Configuration invariants — re-checked by `get_objective` after
        reconfiguring a copy, so setattr can't smuggle in invalid values."""
        if self.score not in ("simulate", "pipeline"):
            raise ValueError(f"score must be 'simulate' or 'pipeline', "
                             f"got {self.score!r}")
        if self.n_trials < 1:
            raise ValueError(f"n_trials must be >= 1, got {self.n_trials}")

    def _partition(self, e: np.ndarray, l: np.ndarray, m: int, rng):
        """Return m index groups over one sampled batch (per-trial path)."""
        raise NotImplementedError

    def _partition_loads(self, e_s: np.ndarray, l_s: np.ndarray, m: int,
                         seed: int) -> Tuple[np.ndarray, np.ndarray]:
        """(T, gbs) sampled per-item durations → (T, m) per-bucket sums.

        Default: loop `_partition` per trial (exact solvers and custom
        subclasses).  The LPT and round-robin partitioners override this
        with fully vectorized versions — the per-item Python loop, not the
        simulator, is what made large-GBS re-ranks slow."""
        T = e_s.shape[0]
        e_b = np.zeros((T, m))
        l_b = np.zeros((T, m))
        for t in range(T):
            rng_p = np.random.default_rng([seed, t, 1])
            for j, g in enumerate(self._partition(e_s[t], l_s[t], m, rng_p)):
                if len(g):
                    e_b[t, j] = e_s[t][g].sum()
                    l_b[t, j] = l_s[t][g].sum()
        return e_b, l_b

    def _aggregate(self, samples: np.ndarray) -> float:
        raise NotImplementedError

    # ------------------------------------------------------------------ #
    def _score_trials(self, plan: ParallelismPlan, e_b: np.ndarray,
                      l_b: np.ndarray, mode: str,
                      score: Optional[str] = None) -> np.ndarray:
        """(T, m) bucket-duration matrices → (T,) step makespans."""
        score = score or self.score
        e_pp = plan.encoder.pp if plan.encoder else 0
        if score == "pipeline":
            # bottleneck bucket priced by the plan's schedule family: the
            # staged families pay max(E, L) per slot, encoder_fill pays the
            # serial chunk+LLM sum (schedule_makespan does the /L_pp split)
            if plan.schedule == "encoder_fill":
                c = (l_b + e_b / plan.llm.pp).max(axis=-1)
                return (plan.n_mb + plan.bubble_slots) * c
            c = np.maximum(e_b, l_b).max(axis=-1)
            return schedule_makespan(plan, c, c)
        from repro.core.pipeline.simulator import simulate_bucket_ranks_batch
        batch = simulate_bucket_ranks_batch(
            e_b, l_b, n_mb=plan.n_mb, dp=plan.llm.dp, e_pp=e_pp,
            l_pp=plan.llm.pp, bwd_over_fwd=self.bwd_over_fwd,
            backward=(mode == "train"), schedule=plan.schedule)
        return batch.makespan.max(axis=-1)       # slowest dp rank per trial

    def trial_makespan(self, plan: ParallelismPlan, groups,
                       e: np.ndarray, l: np.ndarray,
                       mode: str = "train", score: Optional[str] = None) -> float:
        """Step makespan of one partitioned batch — the standalone entry
        point (`evaluate_samples` scores whole trial batches at once via
        `_score_trials`)."""
        e_b = np.array([e[g].sum() if len(g) else 0.0 for g in groups])
        l_b = np.array([l[g].sum() if len(g) else 0.0 for g in groups])
        return float(self._score_trials(plan, e_b[None], l_b[None], mode,
                                        score)[0])

    def _sample_indices(self, n: int, gbs: int, seed: int,
                        cache: Optional[Dict]) -> np.ndarray:
        """(T, gbs) item indices; per-trial streams so objectives sharing
        `seed` sample identical batches regardless of how many draws their
        partitioners use.  Plan-independent, hence shared across a whole
        re-rank through `cache`."""
        key = ("idx", n, gbs, seed, self.n_trials)
        if cache is not None and key in cache:
            return cache[key]
        idx = np.stack([np.random.default_rng([seed, t]).integers(
            0, n, size=gbs) for t in range(self.n_trials)])
        if cache is not None:
            cache[key] = idx
        return idx

    def evaluate_samples(self, perf, plan, dist, gbs, *, mode="train",
                         corrector=None, seed: int = 0,
                         cache=None) -> ObjectiveResult:
        n = len(dist)
        if n == 0:
            mean_bsz, mean_seq = 1.0, 1.0
            T = mean_makespan(perf, plan, mean_bsz, mean_seq, gbs, mode,
                              corrector=corrector)
            return ObjectiveResult(T, np.array([T]))
        e_it, l_it = self._item_durations(perf, plan, dist, mode, corrector,
                                          cache)
        m = plan.n_buckets
        idx = self._sample_indices(n, gbs, seed, cache)
        e_s, l_s = e_it[idx], l_it[idx]
        e_b, l_b = self._partition_loads(e_s, l_s, m, seed)
        samples = self._score_trials(plan, e_b, l_b, mode)
        return ObjectiveResult(self._aggregate(samples), samples)


class ExpectedRandomObjective(_SamplingObjective):
    """Eq. 1 with the data-agnostic baseline assignment: a random
    permutation dealt round-robin into the buckets (exactly what
    ``OnlineMicrobatchScheduler.schedule_random`` and stock PyTorch /
    Megatron loaders do), scored by the mean over trials."""

    name = "expected-random"

    def _partition(self, e, l, m, rng):
        gbs = len(e)
        buckets = np.empty(gbs, dtype=np.int64)
        buckets[rng.permutation(gbs)] = np.arange(gbs) % m
        groups = [[] for _ in range(m)]
        for i, b in enumerate(buckets):
            groups[int(b)].append(i)
        return groups

    def _partition_loads(self, e_s, l_s, m, seed):
        # same rng stream as `_partition` (one permutation per trial), but
        # bucket sums land via one bincount over all trials
        T, gbs = e_s.shape
        buckets = np.empty((T, gbs), dtype=np.int64)
        deal = np.arange(gbs) % m
        for t in range(T):
            rng = np.random.default_rng([seed, t, 1])
            buckets[t, rng.permutation(gbs)] = deal
        flat = (np.arange(T)[:, None] * m + buckets).ravel()
        e_b = np.bincount(flat, weights=e_s.ravel(),
                          minlength=T * m).reshape(T, m)
        l_b = np.bincount(flat, weights=l_s.ravel(),
                          minlength=T * m).reshape(T, m)
        return e_b, l_b

    def _aggregate(self, samples: np.ndarray) -> float:
        return float(samples.mean())


class BalancedQuantileObjective(_SamplingObjective):
    """Heterogeneity-aware objective: partition each sampled batch the way
    the Online Scheduler will (`lpt_schedule`; ``solver='hybrid'`` uses the
    scheduler's exact-then-LPT BnB) and score by the q-quantile of the
    per-trial step makespans.  The quantile — not the mean — is what
    makes re-plan decisions sharp at small GBS: with ~1 item per bucket a
    fat tail lands in *some* bucket almost every batch, and p90 prices
    that in where the mean-shape estimate cannot."""

    name = "balanced-quantile"

    # NOTE on determinism: with the default solver='lpt', equal seeds
    # reproduce scores bit-for-bit.  solver='hybrid' partitions with the
    # wall-clock-limited BnB, which is only deterministic when the
    # instance is small enough to be solved to optimality within
    # `time_limit_s` (the property harness uses tiny instances with a
    # generous limit for exactly that reason).

    def __init__(self, n_trials: int = 16, q: float = 0.9,
                 solver: str = "lpt", refine: bool = False,
                 time_limit_s: float = 0.05, score: str = "simulate",
                 bwd_over_fwd: float = 2.0):
        self.q = q
        self.solver = solver
        self.refine = refine
        self.time_limit_s = time_limit_s
        super().__init__(n_trials, score, bwd_over_fwd)

    def _validate(self) -> None:
        super()._validate()
        if not 0.0 <= self.q <= 1.0:
            raise ValueError(f"quantile q must be in [0, 1], got {self.q}")
        if self.solver not in ("lpt", "hybrid"):
            raise ValueError(
                f"solver must be 'lpt' or 'hybrid', got {self.solver!r}")

    def _partition(self, e, l, m, rng):
        if self.solver == "hybrid":
            from repro.core.scheduler.ilp import solve_makespan_bnb
            return solve_makespan_bnb(e, l, m,
                                      time_limit_s=self.time_limit_s).groups
        from repro.core.scheduler.lpt import lpt_schedule
        return lpt_schedule(e, l, m, refine=self.refine)

    def _partition_loads(self, e_s, l_s, m, seed):
        if self.solver == "hybrid" or self.refine:
            # exact / refining solvers stay per-trial
            return super()._partition_loads(e_s, l_s, m, seed)
        from repro.core.scheduler.lpt import lpt_assign_batch
        _assign, e_b, l_b = lpt_assign_batch(e_s, l_s, m)
        return e_b, l_b

    def _aggregate(self, samples: np.ndarray) -> float:
        return float(np.quantile(samples, self.q))


# --------------------------------------------------------------------- #
_REGISTRY = {
    "mean": MeanObjective,
    "expected": ExpectedRandomObjective,          # legacy alias
    "expected-random": ExpectedRandomObjective,
    "balanced-quantile": BalancedQuantileObjective,
    "quantile": BalancedQuantileObjective,
}

OBJECTIVE_NAMES = ("mean", "expected-random", "balanced-quantile")


def get_objective(objective, **kwargs) -> Objective:
    """Resolve an objective name (or an instance).

    kwargs (``n_trials``, ``q``, ``solver``, ...) are forwarded to the
    class; keys a class does not accept are dropped so callers can pass a
    uniform configuration regardless of which objective is selected.
    An instance passes through untouched unless a provided kwarg differs
    from its current configuration, in which case a reconfigured *copy*
    is returned (the original is never mutated) — this is how the runtime
    controller applies its re-plan trial budget to an engine-pinned
    objective without losing the rest of its configuration."""
    if isinstance(objective, Objective):
        updates = {k: v for k, v in kwargs.items()
                   if v is not None and hasattr(objective, k)
                   and getattr(objective, k) != v}
        if not updates:
            return objective
        import copy
        out = copy.copy(objective)
        for k, v in updates.items():
            setattr(out, k, v)
        validate = getattr(out, "_validate", None)
        if validate is not None:
            validate()
        return out
    try:
        cls = _REGISTRY[objective]
    except KeyError:
        raise ValueError(
            f"unknown objective {objective!r}; expected one of "
            f"{sorted(set(_REGISTRY))}") from None
    import inspect
    accepted = inspect.signature(cls.__init__).parameters
    return cls(**{k: v for k, v in kwargs.items()
                  if k in accepted and v is not None})
