"""Search-space generation (Algorithm 1, phase 1).

Enumerates GPU partitions between the modality encoder and the LLM and all
(TP, PP, DP) factorizations per module.  TP degrees are limited to powers of
two within one high-bandwidth domain (paper Eq. 2: TP "typically limited to
GPUs within the same node"; on TPU the analogue is the mesh's "model" axis).

The plan additionally carries a **schedule family** axis (see
``docs/schedules.md``):

  * ``"1f1b"``         — classic one-forward-one-backward (the default; the
    encoder occupies its own leading pipeline stages).
  * ``"interleaved"``  — Megatron-style interleaved virtual stages: each
    rank hosts ``VIRTUAL_CHUNKS`` model chunks, shrinking the warmup/drain
    bubble by that factor.  Requires ``n_mb % pipeline_depth == 0``.
  * ``"encoder_fill"`` — Optimus-style encoder-in-bubble: the encoder is
    *replicated* across the LLM's pipeline ranks (no dedicated stages) and
    its per-microbatch work, split evenly over the ranks, executes inside
    the 1F1B warmup/drain bubbles.  Requires a colocated encoder
    parallelism ``(tp=L_tp, pp=1, dp=L_dp)``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List, Optional, Sequence, Tuple

SCHEDULES = ("1f1b", "interleaved", "encoder_fill")

# Virtual model chunks per rank under the interleaved schedule.  A plan
# does not carry its own chunk count — the search treats the family as one
# axis and the simulator takes `v` explicitly — so the bubble arithmetic
# below and the simulator default stay in sync through this constant.
VIRTUAL_CHUNKS = 2


@dataclass(frozen=True)
class ClusterSpec:
    n_chips: int
    chips_per_node: int = 16          # TP domain size
    mem_bytes: float = 16e9           # per-chip HBM (v5e)
    name: str = "tpu-v5e-pod"

    @property
    def n_nodes(self) -> int:
        return self.n_chips // self.chips_per_node


@dataclass(frozen=True)
class ModuleParallelism:
    tp: int
    pp: int
    dp: int

    @property
    def chips(self) -> int:
        return self.tp * self.pp * self.dp


@dataclass(frozen=True)
class ParallelismPlan:
    """θ = (E_tp, E_pp, E_dp, L_tp, L_pp, L_dp, N_mb, schedule).

    >>> p = ParallelismPlan(llm=ModuleParallelism(2, 4, 1), n_mb=8,
    ...                     schedule="interleaved")
    >>> p.as_tuple()
    (0, 0, 0, 2, 4, 1, 8, 'interleaved')
    >>> p.pipeline_depth, p.bubble_slots
    (4, 1.5)
    """

    llm: ModuleParallelism
    encoder: Optional[ModuleParallelism] = None
    n_mb: int = 1
    schedule: str = "1f1b"

    def __post_init__(self):
        if self.schedule not in SCHEDULES:
            raise ValueError(f"unknown schedule {self.schedule!r}; "
                             f"expected one of {SCHEDULES}")
        if self.schedule == "interleaved":
            depth = self.pipeline_depth
            if depth < 2:
                raise ValueError("interleaved schedule needs pipeline "
                                 f"depth >= 2, got {depth}")
            if self.n_mb % depth:
                raise ValueError(
                    f"interleaved schedule needs n_mb divisible by the "
                    f"pipeline depth ({depth}), got n_mb={self.n_mb}")
        if self.schedule == "encoder_fill":
            ep, lp = self.encoder, self.llm
            if ep is None:
                raise ValueError("encoder_fill schedule needs an encoder")
            if (ep.tp, ep.pp, ep.dp) != (lp.tp, 1, lp.dp):
                raise ValueError(
                    "encoder_fill colocates a replicated encoder on the "
                    f"LLM ranks: encoder parallelism must be "
                    f"(tp={lp.tp}, pp=1, dp={lp.dp}), got "
                    f"({ep.tp}, {ep.pp}, {ep.dp})")

    @property
    def pipeline_depth(self) -> int:
        """Number of physical pipeline ranks.  Under ``encoder_fill`` the
        encoder holds no stages of its own — depth is the LLM's alone."""
        if self.schedule == "encoder_fill":
            return self.llm.pp
        e_pp = self.encoder.pp if self.encoder else 0
        return e_pp + self.llm.pp

    @property
    def bubble_slots(self) -> float:
        """Closed-form pipeline fill/drain overhead in units of one
        microbatch slot: step ≈ (n_mb + bubble_slots) · slot cost.  1F1B
        pays depth − 1 slots; interleaving v chunks shrinks that by v;
        encoder_fill keeps the LLM's 1F1B shape (its bubbles are *filled*,
        which the slot cost — see the scheduler — accounts for instead)."""
        slots = self.pipeline_depth - 1
        if self.schedule == "interleaved":
            return slots / VIRTUAL_CHUNKS
        return slots

    @property
    def n_buckets(self) -> int:
        """m = N_mb · L_dp — the partition arity the Online Scheduler (and
        every sampling objective) balances a global batch into."""
        return self.n_mb * self.llm.dp

    @property
    def chips(self) -> int:
        """Physical chips the plan occupies.  The encoder_fill encoder is
        replicated *on* the LLM's chips, so it adds none."""
        if self.schedule == "encoder_fill":
            return self.llm.chips
        return self.llm.chips + (self.encoder.chips if self.encoder else 0)

    def as_tuple(self):
        e = self.encoder or ModuleParallelism(0, 0, 0)
        return (e.tp, e.pp, e.dp, self.llm.tp, self.llm.pp, self.llm.dp,
                self.n_mb, self.schedule)


def _pow2s_up_to(n: int) -> List[int]:
    out, v = [], 1
    while v <= n:
        out.append(v)
        v *= 2
    return out


def find_combs(n_chips: int, max_tp: int, *, max_pp: int = 64) -> List[ModuleParallelism]:
    """All (tp, pp, dp) with tp·pp·dp == n_chips (paper's FindCombs)."""
    out = []
    for tp in _pow2s_up_to(min(max_tp, n_chips)):
        if n_chips % tp:
            continue
        rest = n_chips // tp
        for pp in range(1, min(max_pp, rest) + 1):
            if rest % pp:
                continue
            out.append(ModuleParallelism(tp, pp, rest // pp))
    return out


def enumerate_configs(cluster: ClusterSpec, *, has_encoder: bool,
                      max_pp: int = 64, partition_step: int = 1,
                      schedules: Sequence[str] = ("1f1b",),
                      ) -> Iterator[Tuple[Optional[ModuleParallelism], ModuleParallelism, str]]:
    """Phase 1: yield (encoder_parallelism | None, llm_parallelism, schedule).

    The partitioned families (``1f1b``, ``interleaved``) share the same
    chip-split enumeration — the schedule only changes how the candidate is
    scored.  ``encoder_fill`` is its own enumeration: the encoder takes no
    chips of its own (it is replicated on the LLM ranks), so the LLM gets
    the *whole* cluster and the colocated encoder parallelism
    ``(L_tp, 1, L_dp)`` is implied by the LLM's.
    """
    unknown = set(schedules) - set(SCHEDULES)
    if unknown:
        raise ValueError(f"unknown schedule(s) {sorted(unknown)}; "
                         f"expected a subset of {SCHEDULES}")
    N = cluster.n_chips
    max_tp = cluster.chips_per_node
    partitioned = [s for s in schedules if s in ("1f1b", "interleaved")]
    if not has_encoder:
        for lp in find_combs(N, max_tp, max_pp=max_pp):
            for sched in partitioned:
                if sched == "interleaved" and lp.pp < 2:
                    continue
                yield None, lp, sched
        return
    if partitioned:
        for e_chips in range(1, N, partition_step):
            l_chips = N - e_chips
            e_combs = find_combs(e_chips, max_tp, max_pp=max_pp)
            if not e_combs:
                continue
            l_combs = find_combs(l_chips, max_tp, max_pp=max_pp)
            for ep in e_combs:
                for lp in l_combs:
                    for sched in partitioned:
                        if sched == "interleaved" and ep.pp + lp.pp < 2:
                            continue
                        yield ep, lp, sched
    if "encoder_fill" in schedules:
        for lp in find_combs(N, max_tp, max_pp=max_pp):
            if lp.pp < 2:        # no bubbles to fill — degenerate
                continue
            yield ModuleParallelism(lp.tp, 1, lp.dp), lp, "encoder_fill"
