"""Search-space generation (Algorithm 1, phase 1).

Enumerates GPU partitions between the modality encoder and the LLM and all
(TP, PP, DP) factorizations per module.  TP degrees are limited to powers of
two within one high-bandwidth domain (paper Eq. 2: TP "typically limited to
GPUs within the same node"; on TPU the analogue is the mesh's "model" axis).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List, Optional, Tuple


@dataclass(frozen=True)
class ClusterSpec:
    n_chips: int
    chips_per_node: int = 16          # TP domain size
    mem_bytes: float = 16e9           # per-chip HBM (v5e)
    name: str = "tpu-v5e-pod"

    @property
    def n_nodes(self) -> int:
        return self.n_chips // self.chips_per_node


@dataclass(frozen=True)
class ModuleParallelism:
    tp: int
    pp: int
    dp: int

    @property
    def chips(self) -> int:
        return self.tp * self.pp * self.dp


@dataclass(frozen=True)
class ParallelismPlan:
    """θ = (E_tp, E_pp, E_dp, L_tp, L_pp, L_dp, N_mb)."""

    llm: ModuleParallelism
    encoder: Optional[ModuleParallelism] = None
    n_mb: int = 1

    @property
    def pipeline_depth(self) -> int:
        e_pp = self.encoder.pp if self.encoder else 0
        return e_pp + self.llm.pp

    @property
    def n_buckets(self) -> int:
        """m = N_mb · L_dp — the partition arity the Online Scheduler (and
        every sampling objective) balances a global batch into."""
        return self.n_mb * self.llm.dp

    @property
    def chips(self) -> int:
        return self.llm.chips + (self.encoder.chips if self.encoder else 0)

    def as_tuple(self):
        e = self.encoder or ModuleParallelism(0, 0, 0)
        return (e.tp, e.pp, e.dp, self.llm.tp, self.llm.pp, self.llm.dp,
                self.n_mb)


def _pow2s_up_to(n: int) -> List[int]:
    out, v = [], 1
    while v <= n:
        out.append(v)
        v *= 2
    return out


def find_combs(n_chips: int, max_tp: int, *, max_pp: int = 64) -> List[ModuleParallelism]:
    """All (tp, pp, dp) with tp·pp·dp == n_chips (paper's FindCombs)."""
    out = []
    for tp in _pow2s_up_to(min(max_tp, n_chips)):
        if n_chips % tp:
            continue
        rest = n_chips // tp
        for pp in range(1, min(max_pp, rest) + 1):
            if rest % pp:
                continue
            out.append(ModuleParallelism(tp, pp, rest // pp))
    return out


def enumerate_configs(cluster: ClusterSpec, *, has_encoder: bool,
                      max_pp: int = 64,
                      partition_step: int = 1) -> Iterator[Tuple[Optional[ModuleParallelism], ModuleParallelism]]:
    """Phase 1: yield (encoder_parallelism | None, llm_parallelism)."""
    N = cluster.n_chips
    max_tp = cluster.chips_per_node
    if not has_encoder:
        for lp in find_combs(N, max_tp, max_pp=max_pp):
            yield None, lp
        return
    for e_chips in range(1, N, partition_step):
        l_chips = N - e_chips
        e_combs = find_combs(e_chips, max_tp, max_pp=max_pp)
        if not e_combs:
            continue
        l_combs = find_combs(l_chips, max_tp, max_pp=max_pp)
        for ep in e_combs:
            for lp in l_combs:
                yield ep, lp
