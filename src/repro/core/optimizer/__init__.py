from repro.core.optimizer.space import (
    ClusterSpec,
    ModuleParallelism,
    ParallelismPlan,
    find_combs,
    enumerate_configs,
)
from repro.core.optimizer.search import ParallelismOptimizer, SearchResult

__all__ = [
    "ClusterSpec",
    "ModuleParallelism",
    "ParallelismPlan",
    "find_combs",
    "enumerate_configs",
    "ParallelismOptimizer",
    "SearchResult",
]
