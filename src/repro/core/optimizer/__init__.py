from repro.core.optimizer.space import (
    SCHEDULES,
    VIRTUAL_CHUNKS,
    ClusterSpec,
    ModuleParallelism,
    ParallelismPlan,
    find_combs,
    enumerate_configs,
)
from repro.core.optimizer.objective import (
    BalancedQuantileObjective,
    ExpectedRandomObjective,
    MeanObjective,
    Objective,
    ObjectiveResult,
    OBJECTIVE_NAMES,
    get_objective,
)
from repro.core.optimizer.search import ParallelismOptimizer, SearchResult

__all__ = [
    "SCHEDULES",
    "VIRTUAL_CHUNKS",
    "ClusterSpec",
    "ModuleParallelism",
    "ParallelismPlan",
    "find_combs",
    "enumerate_configs",
    "ParallelismOptimizer",
    "SearchResult",
    "Objective",
    "ObjectiveResult",
    "MeanObjective",
    "ExpectedRandomObjective",
    "BalancedQuantileObjective",
    "get_objective",
    "OBJECTIVE_NAMES",
]
