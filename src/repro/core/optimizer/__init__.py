from repro.core.optimizer.space import (
    ClusterSpec,
    ModuleParallelism,
    ParallelismPlan,
    find_combs,
    enumerate_configs,
)
from repro.core.optimizer.objective import (
    BalancedQuantileObjective,
    ExpectedRandomObjective,
    MeanObjective,
    Objective,
    ObjectiveResult,
    OBJECTIVE_NAMES,
    get_objective,
)
from repro.core.optimizer.search import ParallelismOptimizer, SearchResult

__all__ = [
    "ClusterSpec",
    "ModuleParallelism",
    "ParallelismPlan",
    "find_combs",
    "enumerate_configs",
    "ParallelismOptimizer",
    "SearchResult",
    "Objective",
    "ObjectiveResult",
    "MeanObjective",
    "ExpectedRandomObjective",
    "BalancedQuantileObjective",
    "get_objective",
    "OBJECTIVE_NAMES",
]
