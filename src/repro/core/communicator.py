"""Inter-model Communicator (paper §4, Fig. 6).

The paper's problem: the encoder's data-parallel groups and the LLM's
data-parallel groups differ in size (e.g. E_dp=4 vs L_dp=2), so activations
must be gathered from the encoder groups and re-scattered to the LLM groups
in the forward pass (reversed for gradients).

TPU-native realization: within one SPMD program, the "communicator" is a
resharding of the activation tensor from the encoder module's layout to the
LLM module's layout.  ``jax.lax.with_sharding_constraint`` marks the
boundary; the XLA SPMD partitioner emits the all-to-all / collective-permute
(and its transpose emits the reverse path for gradients automatically —
the backward of a reshard is the reverse reshard, exactly Fig. 6's gradient
path).

An explicit ``shard_map`` gather/scatter mirroring the paper's designated-
rank implementation is provided for validation on host-device meshes.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
from repro.common import compat
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.partition import AxisAssignment, sanitize_spec


def make_communicator(mesh: Mesh, enc: AxisAssignment,
                      llm: AxisAssignment) -> Callable:
    """Returns f(x) resharding (B, T, D) activations from the encoder
    layout to the LLM layout (identity if the layouts coincide)."""

    def communicate(x):
        spec = P(tuple(llm.batch) if llm.batch else None, None, None)
        spec = sanitize_spec(spec, x.shape, mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return communicate


# --------------------------------------------------------------------------- #
# Explicit gather/scatter (paper's designated-rank mechanism) for validation
# --------------------------------------------------------------------------- #
def explicit_gather_scatter(mesh: Mesh, axis: str):
    """shard_map gather→scatter along `axis`: every device gathers the full
    batch then keeps its new shard — semantically the Fig. 6 data movement
    (gather from E_dp groups, scatter to L_dp groups) when the two layouts
    shard the same logical batch differently."""

    def fn(x):
        def inner(xs):
            full = jax.lax.all_gather(xs, axis, axis=0, tiled=True)
            n = jax.lax.axis_size(axis)
            idx = jax.lax.axis_index(axis)
            shard = full.shape[0] // n
            return jax.lax.dynamic_slice_in_dim(full, idx * shard, shard, 0)

        return compat.shard_map(inner, mesh=mesh, in_specs=P(axis),
                             out_specs=P(axis))(x)

    return fn
