"""Low-overhead span recorder with Chrome-trace (Perfetto) JSON export.

The runtime control loop needs to *see* bubble structure, not just infer it:
every step / stage / microbatch event is recorded as a (category, name,
ts, dur) tuple on the hot path — one list append, no dict construction,
no I/O — and formatted into the Chrome ``traceEvents`` schema only at
export time.  Load the exported file in Perfetto (https://ui.perfetto.dev)
or ``chrome://tracing`` to inspect pipeline bubbles span-by-span.

Event kinds map onto trace phases:
  span()/complete() -> "X" (complete slice: ts + dur)
  instant()         -> "i" (e.g. plan hot-swap markers)
  counter()         -> "C" (rolling metrics: imbalance, bubble fraction)
"""
from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager
from typing import Dict, List, Optional

_PID = 1


class TraceRecorder:
    """Append-only event buffer; thread-safe, bounded, cheap when disabled."""

    def __init__(self, *, enabled: bool = True, max_events: int = 1_000_000,
                 process_name: str = "dflop-runtime",
                 clock=time.monotonic):
        self.enabled = enabled
        self.max_events = max_events
        self.process_name = process_name
        self._clock = clock
        self._t0 = clock()
        self._events: List[tuple] = []      # (ph, name, cat, ts_us, dur_us, tid, args)
        self._dropped = 0
        self._lock = threading.Lock()
        self._thread_names: Dict[int, str] = {}

    # ------------------------------------------------------------------ #
    def now_us(self) -> float:
        return (self._clock() - self._t0) * 1e6

    def name_thread(self, tid: int, name: str) -> None:
        self._thread_names[tid] = name

    def _push(self, ev: tuple) -> None:
        with self._lock:
            if len(self._events) >= self.max_events:
                self._dropped += 1
                return
            self._events.append(ev)

    # ------------------------------------------------------------------ #
    @contextmanager
    def span(self, name: str, *, cat: str = "runtime", tid: int = 0, **args):
        """Time a block as a complete slice.  ~1 µs overhead when enabled."""
        if not self.enabled:
            yield self
            return
        ts = self.now_us()
        try:
            yield self
        finally:
            self._push(("X", name, cat, ts, self.now_us() - ts, tid,
                        args or None))

    def complete(self, name: str, ts_us: float, dur_us: float, *,
                 cat: str = "runtime", tid: int = 0,
                 args: Optional[dict] = None) -> None:
        """Record a slice with explicit timestamps (simulated schedules,
        device timelines reconstructed after the fact)."""
        if self.enabled:
            self._push(("X", name, cat, ts_us, dur_us, tid, args))

    def instant(self, name: str, *, cat: str = "runtime", tid: int = 0,
                args: Optional[dict] = None) -> None:
        if self.enabled:
            self._push(("i", name, cat, self.now_us(), 0.0, tid, args))

    def counter(self, name: str, value: float, *, cat: str = "metrics",
                tid: int = 0) -> None:
        if self.enabled:
            self._push(("C", name, cat, self.now_us(), 0.0, tid,
                        {"value": float(value)}))

    # ------------------------------------------------------------------ #
    def __len__(self) -> int:
        return len(self._events)

    @property
    def dropped(self) -> int:
        return self._dropped

    def to_chrome(self) -> dict:
        """Format the buffer as a Chrome-trace JSON object."""
        out: List[dict] = [{
            "ph": "M", "name": "process_name", "pid": _PID, "tid": 0,
            "args": {"name": self.process_name},
        }]
        for tid, name in sorted(self._thread_names.items()):
            out.append({"ph": "M", "name": "thread_name", "pid": _PID,
                        "tid": tid, "args": {"name": name}})
        with self._lock:
            events = list(self._events)
        for ph, name, cat, ts, dur, tid, args in events:
            ev = {"ph": ph, "name": name, "cat": cat, "ts": ts,
                  "pid": _PID, "tid": tid}
            if ph == "X":
                ev["dur"] = max(dur, 0.0)
            if ph == "i":
                ev["s"] = "p"               # process-scoped instant
            if args:
                ev["args"] = args
            out.append(ev)
        return {"traceEvents": out, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self._dropped}}

    def export(self, path: str) -> str:
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path
