"""Rolling runtime counters for the control loop.

Tracks, over a sliding window of recent global batches:
  * scheduler imbalance   — ``ScheduleOutput.cmax / lower_bound − 1``
  * bubble fraction       — pipeline idle / (idle + busy) per step
  * per-stage utilization — stage busy time / step makespan
  * prediction error      — |actual/predicted − 1| per module

These are the observability half of the profile → plan → schedule →
observe → re-plan loop: the controller reads them for re-plan decisions
and mirrors them into the trace as counter tracks.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, Optional

import numpy as np


class RollingStat:
    """Bounded-window scalar stream with O(1) append.

    An *empty* window has no statistics: ``mean/max/quantile`` return NaN,
    never a fake 0.0 — a fully-overloaded serve run that completed nothing
    must report p99 latency as *missing*, not as a perfect 0 ms.  Renderers
    map NaN to absent (`nan_to_none`); ``last()`` likewise returns NaN so
    display paths can tell "no data yet" from a measured zero."""

    __slots__ = ("_buf", "count")

    def __init__(self, window: int = 256):
        self._buf: Deque[float] = deque(maxlen=window)
        self.count = 0                     # lifetime observations

    def add(self, x: float) -> None:
        self._buf.append(float(x))
        self.count += 1

    def mean(self) -> float:
        return float(np.mean(self._buf)) if self._buf else float("nan")

    def max(self) -> float:
        return float(np.max(self._buf)) if self._buf else float("nan")

    def last(self) -> float:
        return self._buf[-1] if self._buf else float("nan")

    def quantile(self, q: float) -> float:
        """Windowed quantile (serving p50/p99 tails).  O(window log window)
        — called at snapshot/report time, never on the hot path."""
        return float(np.quantile(self._buf, q)) if self._buf else float("nan")

    def __len__(self) -> int:
        return len(self._buf)


def nan_to_none(x: float):
    """NaN → None, so JSON-bound snapshots stay valid JSON (`json.dumps`
    would emit the non-standard literal ``NaN``) and missing stats render
    as absent rather than numeric."""
    return None if isinstance(x, float) and np.isnan(x) else x


class RuntimeMetrics:
    def __init__(self, window: int = 256):
        self.window = window
        self.imbalance = RollingStat(window)
        self.sched_elapsed_s = RollingStat(window)
        self.pred_cmax_s = RollingStat(window)
        self.bubble_fraction = RollingStat(window)
        self.step_time_s = RollingStat(window)
        self.reshard_s = RollingStat(window)
        self.compose_elapsed_s = RollingStat(window)
        self.compose_pred_gain = RollingStat(window)
        self.compose_window_fill = RollingStat(window)
        self.truncated_tokens = RollingStat(window)
        self.stage_util: Dict[int, RollingStat] = {}
        self.pred_error: Dict[str, RollingStat] = {}
        self.n_schedules = 0
        self.n_steps = 0
        self.n_replans = 0
        self.n_drift_events = 0
        self.n_physical_swaps = 0
        # -- fleet membership (repro.launch.fleet) ---------------------- #
        self.n_host_joins = 0
        self.n_host_leaves = 0          # graceful leaves + failures
        self.n_host_failures = 0
        self.n_recoveries = 0           # checkpoint-free roster recoveries
        self.n_degraded = 0             # recoveries that fell back to the
        #                                 stale/re-placed plan (no better
        #                                 plan adoptable on the survivors)
        self.recovery_s = RollingStat(window)
        self.n_composed = 0
        self.n_forced_items = 0
        self.n_truncated_tokens = 0
        # -- MoE dispatch (models/layers/moe.py capacity paths) --------- #
        # NaN observations (no MoE layers / unmeasured shard_map dispatch)
        # are skipped at record time; an all-NaN run leaves the windows
        # empty, so the snapshot reports None rather than a fake 0.0.
        self.moe_drop_rate = RollingStat(window)
        self.moe_imbalance = RollingStat(window)
        # -- serving (repro.serve.engine) ------------------------------- #
        # latency/ttft keep a wider window: p99 over 256 samples is noise
        self.queue_depth = RollingStat(window)
        self.batch_occupancy = RollingStat(window)   # decode rows / slots
        self.prefill_batch_s = RollingStat(window)
        self.decode_step_s = RollingStat(window)
        self.latency_s = RollingStat(max(window, 2048))
        self.ttft_s = RollingStat(max(window, 2048))
        self.n_requests = 0
        self.n_admitted = 0
        self.n_prefill_batches = 0
        self.n_decode_steps = 0
        self.n_handoffs = 0
        self.n_completed = 0
        self.n_slo_met = 0
        self.n_serve_compiles = 0
        self.n_preemptions = 0          # decode-slot evictions (SLO rescue)
        self.n_prefill_chunks = 0       # chunk events from chunked prefill

    # ------------------------------------------------------------------ #
    def record_schedule(self, out) -> None:
        """`out`: a ScheduleOutput (duck-typed to avoid a core import)."""
        self.imbalance.add(out.imbalance)
        self.sched_elapsed_s.add(out.elapsed_s)
        self.pred_cmax_s.add(out.cmax)
        self.n_schedules += 1

    def record_step(self, step_time_s: float, idle_s: float,
                    busy_s: Optional[float] = None,
                    stage_busy: Optional[np.ndarray] = None) -> None:
        """``busy_s=None`` (not measured) defaults to the non-idle
        remainder of the step; an explicit ``0.0`` means a fully idle step
        (bubble fraction 1.0) — the two must not be conflated."""
        if busy_s is None:
            busy_s = max(step_time_s - idle_s, 0.0)
        self.step_time_s.add(step_time_s)
        self.bubble_fraction.add(idle_s / max(idle_s + busy_s, 1e-12))
        if stage_busy is not None and step_time_s > 0:
            for p, b in enumerate(np.asarray(stage_busy, dtype=float)):
                self.stage_util.setdefault(
                    p, RollingStat(self.window)).add(b / step_time_s)
        self.n_steps += 1

    def record_reshard(self, elapsed_s: float) -> None:
        """One physical param re-layout (plan hot-swap's device half)."""
        self.reshard_s.add(elapsed_s)
        self.n_physical_swaps += 1

    def record_membership(self, kind: str) -> None:
        """One fleet roster transition ("join" | "leave" | "fail")."""
        if kind == "join":
            self.n_host_joins += 1
        elif kind == "leave":
            self.n_host_leaves += 1
        elif kind == "fail":
            self.n_host_leaves += 1
            self.n_host_failures += 1
        else:
            raise ValueError(f"unknown membership kind {kind!r}")

    def record_recovery(self, elapsed_s: float, *,
                        degraded: bool = False) -> None:
        """One checkpoint-free roster recovery (re-plan + reshard onto the
        new roster).  ``degraded``: the controller fell back to the stale
        or re-placed plan instead of adopting a fresh search result."""
        self.recovery_s.add(elapsed_s)
        self.n_recoveries += 1
        self.n_degraded += bool(degraded)

    def record_compose(self, stats) -> None:
        """`stats`: a `repro.data.composer.ComposeStats` (duck-typed to
        avoid a core import)."""
        self.compose_elapsed_s.add(stats.elapsed_s)
        self.compose_pred_gain.add(stats.pred_gain)
        self.compose_window_fill.add(stats.window_fill)
        self.n_composed += 1
        self.n_forced_items += stats.n_forced

    def record_moe(self, drop_rate: float, imbalance: float) -> None:
        """Per-step MoE dispatch stats from the train step's aux
        (``moe_drop_rate`` / ``moe_imbalance``): the fraction of routed
        (token, expert) assignments dropped by the capacity clip, and the
        expert-load skew ``E·max(f) − 1``.  NaN means "not measured"
        (no MoE layers, or shard_map dispatch) and is not recorded —
        the window must never mistake missing data for perfect balance."""
        if not np.isnan(drop_rate):
            self.moe_drop_rate.add(drop_rate)
        if not np.isnan(imbalance):
            self.moe_imbalance.add(imbalance)

    def record_pack(self, truncated: int) -> None:
        """Per-global-batch truncated-token count from the packing path —
        silent truncation is a correctness smell, so it is first-class in
        the step telemetry."""
        self.truncated_tokens.add(truncated)
        self.n_truncated_tokens += int(truncated)

    # ------------------------------------------------------------------ #
    # Serving-side counters (`repro.serve.engine` is the only writer).
    def record_admission(self, queue_depth: int, batch_size: int,
                         duration_s: float) -> None:
        """One prefill batch admitted (duration_s: emulated batch time)."""
        self.queue_depth.add(queue_depth)
        self.prefill_batch_s.add(duration_s)
        self.n_admitted += batch_size
        self.n_prefill_batches += 1

    def record_decode_step(self, occupancy: float, duration_s: float) -> None:
        """One continuous-batch decode step (occupancy: rows / slots)."""
        self.batch_occupancy.add(occupancy)
        self.decode_step_s.add(duration_s)
        self.n_decode_steps += 1

    def record_completion(self, latency_s: float, ttft_s: float,
                          slo_met: bool) -> None:
        self.latency_s.add(latency_s)
        if ttft_s >= 0:
            self.ttft_s.add(ttft_s)
        self.n_completed += 1
        self.n_slo_met += bool(slo_met)

    def record_prediction(self, module: str, predicted: float,
                          actual: float) -> None:
        if predicted <= 0 or actual <= 0:
            return
        self.pred_error.setdefault(
            module, RollingStat(self.window)).add(abs(actual / predicted - 1.0))

    # ------------------------------------------------------------------ #
    def snapshot(self) -> dict:
        """JSON-safe counter snapshot.  Stats whose window is empty appear
        as None ("no data"), never as a fake 0.0."""
        _n = nan_to_none
        return {
            "n_schedules": self.n_schedules,
            "n_steps": self.n_steps,
            "n_replans": self.n_replans,
            "n_drift_events": self.n_drift_events,
            "n_physical_swaps": self.n_physical_swaps,
            "n_composed": self.n_composed,
            "n_forced_items": self.n_forced_items,
            "n_truncated_tokens": self.n_truncated_tokens,
            "compose_elapsed_mean_s": _n(self.compose_elapsed_s.mean()),
            "compose_pred_gain_mean": _n(self.compose_pred_gain.mean()),
            "truncated_tokens_mean": _n(self.truncated_tokens.mean()),
            "reshard_mean_s": _n(self.reshard_s.mean()),
            "moe_drop_rate_mean": _n(self.moe_drop_rate.mean()),
            "moe_drop_rate_last": _n(self.moe_drop_rate.last()),
            "moe_imbalance_mean": _n(self.moe_imbalance.mean()),
            "moe_imbalance_max": _n(self.moe_imbalance.max()),
            "imbalance_mean": _n(self.imbalance.mean()),
            "imbalance_last": _n(self.imbalance.last()),
            "sched_elapsed_mean_s": _n(self.sched_elapsed_s.mean()),
            "pred_cmax_mean_s": _n(self.pred_cmax_s.mean()),
            "bubble_fraction_mean": _n(self.bubble_fraction.mean()),
            "step_time_mean_s": _n(self.step_time_s.mean()),
            "stage_utilization": {p: _n(s.mean())
                                  for p, s in sorted(self.stage_util.items())},
            "pred_error": {m: _n(s.mean())
                           for m, s in sorted(self.pred_error.items())},
            "fleet": {
                "n_host_joins": self.n_host_joins,
                "n_host_leaves": self.n_host_leaves,
                "n_host_failures": self.n_host_failures,
                "n_recoveries": self.n_recoveries,
                "n_degraded": self.n_degraded,
                "recovery_mean_s": _n(self.recovery_s.mean()),
            },
            "serve": {
                "n_requests": self.n_requests,
                "n_admitted": self.n_admitted,
                "n_prefill_batches": self.n_prefill_batches,
                "n_decode_steps": self.n_decode_steps,
                "n_handoffs": self.n_handoffs,
                "n_completed": self.n_completed,
                "n_slo_met": self.n_slo_met,
                "n_serve_compiles": self.n_serve_compiles,
                "n_preemptions": self.n_preemptions,
                "n_prefill_chunks": self.n_prefill_chunks,
                "queue_depth_mean": _n(self.queue_depth.mean()),
                "batch_occupancy_mean": _n(self.batch_occupancy.mean()),
                "prefill_batch_mean_s": _n(self.prefill_batch_s.mean()),
                "decode_step_mean_s": _n(self.decode_step_s.mean()),
                "latency_p50_s": _n(self.latency_s.quantile(0.50)),
                "latency_p99_s": _n(self.latency_s.quantile(0.99)),
                "ttft_p50_s": _n(self.ttft_s.quantile(0.50)),
            },
        }
