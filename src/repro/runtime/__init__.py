"""repro.runtime — telemetry & continuous re-planning.

Turns the one-shot profile → plan → schedule façade into a closed control
loop (the paper's "continuously profiles runtime behavior" claim):

  trace       — low-overhead span recorder, Chrome-trace (Perfetto) export
  metrics     — rolling bubble-fraction / utilization / imbalance counters
  calibration — online per-(module, shape-bucket, tp) EWMA residual model
  drift       — Page–Hinkley + KS drift detection over shapes & residuals
  controller  — RuntimeController: background re-plan + plan hot-swap

Entry point: ``DFLOPEngine.runtime(gbs)`` returns a wired controller.
"""
from repro.runtime.calibration import OnlineCalibrator, shape_bucket
from repro.runtime.controller import (
    RecoveryRecord,
    ReplanRecord,
    RuntimeController,
)
from repro.runtime.drift import (
    DriftDetector,
    DriftEvent,
    PageHinkley,
    ks_distance,
)
from repro.runtime.metrics import RollingStat, RuntimeMetrics
from repro.runtime.trace import TraceRecorder

__all__ = [
    "DriftDetector",
    "DriftEvent",
    "OnlineCalibrator",
    "PageHinkley",
    "RecoveryRecord",
    "ReplanRecord",
    "RollingStat",
    "RuntimeController",
    "RuntimeMetrics",
    "TraceRecorder",
    "ks_distance",
    "shape_bucket",
]
