"""Online per-(module, shape-bucket, tp) duration calibration.

`AdaptiveCorrection` (§3.4.3) applies a flat multiplicative penalty per
shape bucket, averaged over the whole run.  This module keeps an EWMA of
the observed/predicted duration ratio *per (module, shape bucket, TP
degree)* instead, so the refinement (a) forgets stale kernels after a plan
hot-swap changes TP, and (b) tracks slow residual drift that a lifetime
average would smear.  It is duck-type compatible with the scheduler's
corrector hook: ``correct(module, shape, tp, predicted) -> refined``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np

from repro.core.scheduler.adaptive import AdaptiveCorrection


def shape_bucket(shape: float) -> int:
    """Shared log2 bucketing — delegates to AdaptiveCorrection.bucket so the
    two correctors can never bucket the same shape differently."""
    return AdaptiveCorrection.bucket(shape)


def shape_bucket_array(shapes) -> np.ndarray:
    """Vectorized `shape_bucket`.  Must implement the exact same
    round-half-even log2 rule as `AdaptiveCorrection.bucket` (np.rint and
    Python round() both round half to even) — the parity is pinned by
    tests/test_objective.py::test_correct_array_matches_scalar_correct, so
    change both or neither."""
    shapes = np.asarray(shapes, dtype=np.float64)
    return (2.0 ** np.rint(np.log2(np.maximum(shapes, 1.0)))).astype(np.int64)


@dataclass
class _Cell:
    ratio: float = 1.0       # EWMA of actual/predicted
    abs_err: float = 0.0     # EWMA of |actual/predicted − 1|
    n: int = 0


class OnlineCalibrator:
    def __init__(self, *, alpha: float = 0.25, min_obs: int = 2,
                 max_ratio: float = 8.0, deadband: float = 0.02):
        """alpha: EWMA smoothing; min_obs: observations before a cell's
        correction is trusted; max_ratio: clip for outlier measurements;
        deadband: corrections within ±deadband of 1 are not applied."""
        self.alpha = alpha
        self.min_obs = min_obs
        self.max_ratio = max_ratio
        self.deadband = deadband
        self.cells: Dict[Tuple[str, int, int], _Cell] = {}

    # ------------------------------------------------------------------ #
    def observe(self, module: str, shape: float, tp: int,
                predicted: float, actual: float) -> None:
        if predicted <= 0 or actual <= 0:
            return
        r = min(max(actual / predicted, 1.0 / self.max_ratio), self.max_ratio)
        cell = self.cells.setdefault((module, shape_bucket(shape), int(tp)),
                                     _Cell())
        if cell.n == 0:
            cell.ratio = r
            cell.abs_err = abs(r - 1.0)
        else:
            a = self.alpha
            cell.ratio += a * (r - cell.ratio)
            cell.abs_err += a * (abs(r - 1.0) - cell.abs_err)
        cell.n += 1

    def _usable(self, module: str, bucket: int, tp: int):
        cell = self.cells.get((module, bucket, int(tp)))
        if cell is None or cell.n < self.min_obs:
            return None
        if abs(cell.ratio - 1.0) < self.deadband:
            return None
        return cell

    def correct(self, module: str, shape: float, tp: int,
                predicted: float, fallback_shape: float = None) -> float:
        """fallback_shape: where to borrow a ratio when `shape`'s own
        bucket was *never observed*.  The optimizer's mean-shape path asks
        about aggregate bucket sizes the scheduler never predicts (and
        hence the calibrator never observes); the per-item mean-shape
        residual is the best available estimate there.  A bucket that has
        been observed — even immature or inside the deadband — keeps its
        own verdict.  Per-item callers (the scheduler) leave it unset."""
        cell = self._usable(module, shape_bucket(shape), tp)
        if (cell is None and fallback_shape is not None
                and (module, shape_bucket(shape), int(tp)) not in self.cells):
            cell = self._usable(module, shape_bucket(fallback_shape), tp)
        return predicted if cell is None else predicted * cell.ratio

    def correct_array(self, module: str, shapes, tp: int, predicted,
                      fallback_shape: float = None) -> np.ndarray:
        """Vectorized `correct` over parallel (shapes, predicted) arrays —
        the Parallelism Optimizer's duration tables hold one entry per
        k ∈ {1..GBS}, so refinement there must not pay a dict lookup per
        scalar.  Buckets via the same round-log2 rule as `shape_bucket`."""
        shapes = np.asarray(shapes, dtype=np.float64)
        out = np.array(predicted, dtype=np.float64, copy=True)
        if out.size == 0:
            return out
        fb_cell = None
        if fallback_shape is not None:
            fb_cell = self._usable(module, shape_bucket(fallback_shape), tp)
        buckets = shape_bucket_array(shapes)
        for b in np.unique(buckets):
            cell = self._usable(module, int(b), tp)
            if cell is None and (module, int(b), int(tp)) not in self.cells:
                cell = fb_cell           # only truly unobserved buckets
            if cell is not None:
                out[buckets == b] *= cell.ratio
        return out

    # ------------------------------------------------------------------ #
    def residual(self, module: str | None = None) -> float:
        """Mean EWMA |rel error| over mature cells (drift-detector input)."""
        vals = [c.abs_err for (m, _, _), c in self.cells.items()
                if c.n >= self.min_obs and (module is None or m == module)]
        return sum(vals) / len(vals) if vals else 0.0

    def snapshot(self) -> dict:
        return {f"{m}/b{b}/tp{t}": {"ratio": c.ratio, "abs_err": c.abs_err,
                                    "n": c.n}
                for (m, b, t), c in sorted(self.cells.items())}
