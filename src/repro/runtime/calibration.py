"""Online per-(module, shape-bucket, tp) duration calibration.

`AdaptiveCorrection` (§3.4.3) applies a flat multiplicative penalty per
shape bucket, averaged over the whole run.  This module keeps an EWMA of
the observed/predicted duration ratio *per (module, shape bucket, TP
degree)* instead, so the refinement (a) forgets stale kernels after a plan
hot-swap changes TP, and (b) tracks slow residual drift that a lifetime
average would smear.  It is duck-type compatible with the scheduler's
corrector hook: ``correct(module, shape, tp, predicted) -> refined``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

from repro.core.scheduler.adaptive import AdaptiveCorrection


def shape_bucket(shape: float) -> int:
    """Shared log2 bucketing — delegates to AdaptiveCorrection.bucket so the
    two correctors can never bucket the same shape differently."""
    return AdaptiveCorrection.bucket(shape)


@dataclass
class _Cell:
    ratio: float = 1.0       # EWMA of actual/predicted
    abs_err: float = 0.0     # EWMA of |actual/predicted − 1|
    n: int = 0


class OnlineCalibrator:
    def __init__(self, *, alpha: float = 0.25, min_obs: int = 2,
                 max_ratio: float = 8.0, deadband: float = 0.02):
        """alpha: EWMA smoothing; min_obs: observations before a cell's
        correction is trusted; max_ratio: clip for outlier measurements;
        deadband: corrections within ±deadband of 1 are not applied."""
        self.alpha = alpha
        self.min_obs = min_obs
        self.max_ratio = max_ratio
        self.deadband = deadband
        self.cells: Dict[Tuple[str, int, int], _Cell] = {}

    # ------------------------------------------------------------------ #
    def observe(self, module: str, shape: float, tp: int,
                predicted: float, actual: float) -> None:
        if predicted <= 0 or actual <= 0:
            return
        r = min(max(actual / predicted, 1.0 / self.max_ratio), self.max_ratio)
        cell = self.cells.setdefault((module, shape_bucket(shape), int(tp)),
                                     _Cell())
        if cell.n == 0:
            cell.ratio = r
            cell.abs_err = abs(r - 1.0)
        else:
            a = self.alpha
            cell.ratio += a * (r - cell.ratio)
            cell.abs_err += a * (abs(r - 1.0) - cell.abs_err)
        cell.n += 1

    def correct(self, module: str, shape: float, tp: int,
                predicted: float) -> float:
        cell = self.cells.get((module, shape_bucket(shape), int(tp)))
        if cell is None or cell.n < self.min_obs:
            return predicted
        if abs(cell.ratio - 1.0) < self.deadband:
            return predicted
        return predicted * cell.ratio

    # ------------------------------------------------------------------ #
    def residual(self, module: str | None = None) -> float:
        """Mean EWMA |rel error| over mature cells (drift-detector input)."""
        vals = [c.abs_err for (m, _, _), c in self.cells.items()
                if c.n >= self.min_obs and (module is None or m == module)]
        return sum(vals) / len(vals) if vals else 0.0

    def snapshot(self) -> dict:
        return {f"{m}/b{b}/tp{t}": {"ratio": c.ratio, "abs_err": c.abs_err,
                                    "n": c.n}
                for (m, b, t), c in sorted(self.cells.items())}
