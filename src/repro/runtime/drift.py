"""Drift detection over observed shapes and prediction residuals.

Two complementary detectors feed the re-planning trigger:

  * ``PageHinkley`` — sequential change-point test on a scalar stream
    (prediction residuals).  Fires when the cumulative deviation from the
    running mean exceeds ``threshold``; robust to noise via the ``delta``
    slack term.
  * KS distance — two-sample Kolmogorov–Smirnov statistic between the
    profiled reference `ShapeDistribution` and a sliding window of shapes
    observed at runtime.  Fires when either the encoder-batch or the
    LLM-sequence marginal moves by more than ``ks_threshold``.

`DriftDetector` combines both, debounces with a cooldown, and snapshots
the current window as the empirical distribution to re-plan against.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, Optional, Sequence

import numpy as np

from repro.core.profiling.data_profiler import ShapeDistribution
from repro.data.items import DataItem


class PageHinkley:
    """Two-sided Page–Hinkley test with a burn-in period."""

    def __init__(self, *, delta: float = 0.005, threshold: float = 0.5,
                 burn_in: int = 30):
        self.delta = delta
        self.threshold = threshold
        self.burn_in = burn_in
        self.reset()

    def reset(self) -> None:
        self.n = 0
        self.mean = 0.0
        self._m_up = 0.0        # cumulative upward deviation
        self._m_dn = 0.0        # cumulative downward deviation
        self._min_up = 0.0
        self._max_dn = 0.0
        self.statistic = 0.0

    def update(self, x: float) -> bool:
        self.n += 1
        self.mean += (x - self.mean) / self.n
        self._m_up += x - self.mean - self.delta
        self._m_dn += x - self.mean + self.delta
        self._min_up = min(self._min_up, self._m_up)
        self._max_dn = max(self._max_dn, self._m_dn)
        self.statistic = max(self._m_up - self._min_up,
                             self._max_dn - self._m_dn)
        return self.n > self.burn_in and self.statistic > self.threshold


def ks_distance(a: np.ndarray, b: np.ndarray) -> float:
    """Two-sample KS statistic: sup |ECDF_a − ECDF_b|."""
    a = np.sort(np.asarray(a, dtype=np.float64))
    b = np.sort(np.asarray(b, dtype=np.float64))
    if len(a) == 0 or len(b) == 0:
        return 0.0
    grid = np.concatenate([a, b])
    cdf_a = np.searchsorted(a, grid, side="right") / len(a)
    cdf_b = np.searchsorted(b, grid, side="right") / len(b)
    return float(np.abs(cdf_a - cdf_b).max())


@dataclass(frozen=True)
class DriftEvent:
    kind: str                   # "shape-ks" | "residual-ph"
    statistic: float
    threshold: float
    n_obs: int                  # item/residual count when the test fired


class DriftDetector:
    def __init__(self, *, window: int = 256, ks_threshold: float = 0.2,
                 check_every: int = 32, cooldown: int = 128,
                 ph_delta: float = 0.01, ph_threshold: float = 1.0,
                 ph_burn_in: int = 30):
        self.window = window
        self.ks_threshold = ks_threshold
        self.check_every = check_every
        self.cooldown = cooldown
        self._win_bsz: Deque[float] = deque(maxlen=window)
        self._win_seq: Deque[float] = deque(maxlen=window)
        self._ref_bsz: Optional[np.ndarray] = None
        self._ref_seq: Optional[np.ndarray] = None
        self.ph = PageHinkley(delta=ph_delta, threshold=ph_threshold,
                              burn_in=ph_burn_in)
        self._n_items = 0
        self._since_check = 0
        self._since_event = cooldown        # allow an immediate first event
        self.events: list[DriftEvent] = []

    # ------------------------------------------------------------------ #
    def set_reference(self, dist: ShapeDistribution) -> None:
        self._ref_bsz = np.asarray(dist.enc_batches, dtype=np.float64)
        self._ref_seq = np.asarray(dist.llm_seqs, dtype=np.float64)

    def _fire(self, event: DriftEvent) -> DriftEvent:
        self.events.append(event)
        self._since_event = 0
        return event

    # ------------------------------------------------------------------ #
    def observe_items(self, items: Sequence[DataItem],
                      tokens_per_media_item: int) -> Optional[DriftEvent]:
        for it in items:
            self._win_bsz.append(float(it.encoder_batch()))
            self._win_seq.append(float(it.llm_seq_len(tokens_per_media_item)))
        self._n_items += len(items)
        self._since_check += len(items)
        self._since_event += len(items)
        if (self._ref_seq is None or len(self._win_seq) < self.window
                or self._since_check < self.check_every
                or self._since_event < self.cooldown):
            return None
        self._since_check = 0
        stat = max(ks_distance(self._ref_seq, np.fromiter(self._win_seq, float)),
                   ks_distance(self._ref_bsz, np.fromiter(self._win_bsz, float)))
        if stat > self.ks_threshold:
            return self._fire(DriftEvent("shape-ks", stat, self.ks_threshold,
                                         self._n_items))
        return None

    def observe_residual(self, rel_error: float) -> Optional[DriftEvent]:
        """Feed one |actual/predicted − 1|-style residual."""
        fired = self.ph.update(float(rel_error))
        if fired and self._since_event >= self.cooldown:
            stat = self.ph.statistic
            self.ph.reset()
            return self._fire(DriftEvent("residual-ph", stat,
                                         self.ph.threshold, self._n_items))
        return None

    # ------------------------------------------------------------------ #
    def window_distribution(self) -> ShapeDistribution:
        """Empirical distribution of the recent window (re-plan input)."""
        return ShapeDistribution(np.fromiter(self._win_bsz, float),
                                 np.fromiter(self._win_seq, float))

    def rebase(self, dist: Optional[ShapeDistribution] = None) -> None:
        """Adopt a new reference after a re-plan so the test re-arms
        against the post-drift regime instead of refiring forever."""
        self.set_reference(dist if dist is not None
                           else self.window_distribution())
        self._win_bsz.clear()
        self._win_seq.clear()
        self.ph.reset()
        self._since_check = 0
        self._since_event = 0
