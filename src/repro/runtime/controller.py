"""RuntimeController: the closed profile → plan → schedule → observe →
re-plan control loop.

Wraps a `DFLOPEngine` and its `OnlineMicrobatchScheduler`:

  * every global batch flows through ``schedule()``, which feeds the
    observed shapes to the drift detector and the rolling metrics, and
    records trace spans;
  * measured durations come back through ``observe()`` /
    ``observe_step()``, refining predictions via `OnlineCalibrator` (and
    the paper's `AdaptiveCorrection`) and feeding residual drift;
  * when drift fires, `ParallelismOptimizer.search()` re-runs in a
    background thread over the *recent* shape window; the resulting plan
    is hot-swapped between global batches iff its predicted makespan
    beats the stale plan's by ``min_improvement``.

The swap is deliberately confined to batch boundaries: `schedule()` polls
the background future before scheduling, so in-flight microbatches always
complete under the plan they were balanced for.

Background searches score candidates (and the stale incumbent — same
objective, same calibrator, same seed) through the batched Monte-Carlo
path: per candidate, one vectorized LPT partition and one
`simulate_1f1b_batch` wavefront over every (trial, dp-rank) instance, at
any GBS — which is what keeps high-frequency re-planning affordable
(docs/simulator.md).
"""
from __future__ import annotations

import concurrent.futures
import threading
import time
from dataclasses import dataclass
from typing import List, Optional, Sequence

from repro.core.optimizer.objective import get_objective
from repro.core.optimizer.search import ParallelismOptimizer, SearchResult
from repro.core.profiling.data_profiler import ShapeDistribution
from repro.core.scheduler.online import OnlineMicrobatchScheduler, ScheduleOutput
from repro.data.items import DataItem
from repro.runtime.calibration import OnlineCalibrator
from repro.runtime.drift import DriftDetector, DriftEvent
from repro.runtime.metrics import RuntimeMetrics
from repro.runtime.trace import TraceRecorder


@dataclass
class ReplanRecord:
    trigger: DriftEvent
    stale_makespan: float       # current plan evaluated on the drifted dist
    new_makespan: float         # best plan found (inf when none feasible)
    swapped: bool
    search_elapsed_s: float
    plan_tuple: Optional[tuple] = None
    gated: Optional[str] = None     # why a better plan was NOT adopted
    reshard: Optional[object] = None  # ReshardReport of the physical swap


@dataclass
class RecoveryRecord:
    """One checkpoint-free roster recovery (`poll_fleet`): the membership
    events it coalesced, what plan survived, and how."""

    events: tuple                   # MembershipEvents drained together
    n_chips: int                    # roster capacity after the events
    old_plan_tuple: tuple
    new_plan_tuple: Optional[tuple]  # adopted plan (None = kept the old θ*)
    adopted: bool                   # a fresh search result was adopted
    degraded: bool                  # fell back: re-placed/stale old plan
    elapsed_s: float
    reshard: Optional[object] = None   # ReshardReport of the migration
    error: Optional[str] = None        # first search/reshard failure seen


class RuntimeController:
    def __init__(self, engine, scheduler: OnlineMicrobatchScheduler,
                 gbs: int, *,
                 trace: Optional[TraceRecorder] = None,
                 metrics: Optional[RuntimeMetrics] = None,
                 calibration: Optional[OnlineCalibrator] = None,
                 drift: Optional[DriftDetector] = None,
                 auto_replan: bool = True,
                 min_improvement: float = 0.02,
                 replan_n_trials: int = 8,
                 param_swapper=None,
                 swap_horizon_batches: int = 50,
                 composer=None,
                 fleet=None):
        """param_swapper: optional physical-reshard hook (duck-typed to
        `repro.launch.reshard.ParamSwapper`: ``swap(old_plan, new_plan) ->
        ReshardReport`` plus optional ``estimate_cost_s``/``compatible``).
        When set, `maybe_swap()` re-lays-out the live params at the batch
        boundary and only adopts a plan whose predicted per-batch makespan
        advantage, amortized over ``swap_horizon_batches``, exceeds the
        measured/estimated reshard cost.

        composer: optional `repro.data.composer.LookaheadComposer`.  The
        controller wires its telemetry (compose spans + counters land in
        this trace/metrics) and flushes its cached window durations on
        every plan hot-swap, so composition never targets a stale θ*.

        fleet: optional `repro.launch.fleet.FleetManager`.  `poll_fleet()`
        (called from `schedule()` at every batch boundary; physically-
        backed pipelined loops call it alongside `maybe_swap()`) drains
        its membership events and runs checkpoint-free recovery: re-plan
        for the new roster, migrate live params through `param_swapper`,
        degrade to the surviving roster when either fails (docs/fleet.md).
        Background re-plans are additionally gated on roster capacity so
        a search raced by a host loss can never adopt an over-sized plan."""
        self.engine = engine
        self.scheduler = scheduler
        self.gbs = gbs
        self.param_swapper = param_swapper
        self.swap_horizon_batches = swap_horizon_batches
        self.composer = composer
        self.fleet = fleet
        self.recoveries: List[RecoveryRecord] = []
        if fleet is not None:
            scheduler.set_roster(fleet.n_chips)
        self._pending_items: Optional[list] = None
        self.trace = trace if trace is not None else TraceRecorder(enabled=False)
        self.metrics = metrics if metrics is not None else RuntimeMetrics()
        self.calibration = calibration
        self.drift = drift if drift is not None else DriftDetector()
        self.auto_replan = auto_replan
        self.min_improvement = min_improvement
        self.replan_n_trials = replan_n_trials
        self.replans: List[ReplanRecord] = []
        self.batch_idx = 0
        self._replan_seed = 0     # varies per search; see _on_drift
        if calibration is not None:
            scheduler.calibration = calibration
        if engine.dist is not None:
            self.drift.set_reference(engine.dist)
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="dflop-replan")
        self._replan_future: Optional[concurrent.futures.Future] = None
        self._lock = threading.Lock()
        self.trace.name_thread(0, "control-loop")
        self.trace.name_thread(1, "replan-search")
        if composer is not None:
            composer.trace = self.trace
            composer.metrics = self.metrics

    # ------------------------------------------------------------------ #
    @property
    def plan(self):
        return self.scheduler.plan

    def schedule(self, items: Sequence[DataItem]) -> ScheduleOutput:
        """Schedule one global batch through the control loop."""
        self.poll_fleet()                   # roster changes outrank re-plans
        self.maybe_swap()                   # adopt a finished re-plan first
        with self.trace.span("schedule", cat="scheduler",
                             batch=self.batch_idx, n_items=len(items)):
            out = self.scheduler.schedule(items)
        self.metrics.record_schedule(out)
        self.trace.counter("imbalance", out.imbalance)
        self.trace.counter("pred_cmax_s", out.cmax)
        ev = self.drift.observe_items(items, self.scheduler.tpm)
        if ev is not None:
            self._on_drift(ev)
        self.batch_idx += 1
        return out

    def compose(self, items: Optional[Sequence[DataItem]] = None, *,
                draw=None):
        """Emit the next composed global batch (requires a ``composer``).

        ``draw``: a zero-arg callable returning one global batch of
        items — the canonical per-step form.  It refills the window to
        capacity before composing, so the very first call warms the full
        ``window·gbs`` lookahead and every subsequent call draws exactly
        one batch: ``ctl.compose(draw=lambda: ds.sample(gbs))``.

        ``items``: push one pre-drawn cohort instead.  With this form
        the caller owns the warm-up — composing per-step from an
        initially empty window degenerates to FIFO with zero lookahead
        (each compose sees exactly the cohort just pushed), so a
        ``compose-cold-window`` trace instant marks any compose below
        capacity."""
        comp = self.composer
        if comp is None:
            raise RuntimeError("no composer attached; pass composer= (or "
                               "engine.runtime(compose_window=...))")
        if draw is not None:
            while not comp.ready:
                comp.push(draw())
        if items is not None:
            comp.push(items)
        if not comp.ready:
            self.trace.instant("compose-cold-window", cat="compose",
                               args={"pending": comp.pending,
                                     "capacity": comp.capacity})
        return comp.compose()

    # Pipelined variant mirroring the scheduler's submit/collect pair.
    # Telemetry parity with schedule(): the span/counters/drift feed all
    # happen at collect() time, when the batch's ScheduleOutput exists —
    # feeding drift at submit() would run the drift window one batch ahead
    # of the metrics stream.
    def submit(self, items: Sequence[DataItem]) -> None:
        """Schedule a batch asynchronously (batch t+1 while step t runs).

        With a `param_swapper`, plan adoption is NOT attempted here:
        submit() runs concurrently with the previous training step, and a
        physical re-layout now would be clobbered when that step writes
        its (old-layout) outputs back into the live pytree — diverging
        the logical and physical plans.  Physically-backed pipelined loops
        must call `maybe_swap()` themselves at a true step boundary
        (after the step's write-back, before the next dispatch); the sync
        `schedule()` path swaps automatically."""
        if self.param_swapper is None:
            self.maybe_swap()
        self.scheduler.submit(items)
        self._pending_items = list(items)

    def collect(self) -> Optional[ScheduleOutput]:
        out = self.scheduler.collect()
        if out is None:
            return None
        items, self._pending_items = self._pending_items or [], None
        self.trace.complete("schedule",
                            self.trace.now_us() - out.elapsed_s * 1e6,
                            out.elapsed_s * 1e6, cat="scheduler",
                            args={"batch": self.batch_idx,
                                  "n_items": len(items)})
        self.metrics.record_schedule(out)
        self.trace.counter("imbalance", out.imbalance)
        self.trace.counter("pred_cmax_s", out.cmax)
        ev = self.drift.observe_items(items, self.scheduler.tpm)
        if ev is not None:
            self._on_drift(ev)
        self.batch_idx += 1
        return out

    # ------------------------------------------------------------------ #
    def observe(self, module: str, shape: float, predicted: float,
                actual: float, plan=None) -> None:
        """Per-(module, shape) measured duration feedback.  Pass the
        producing `ScheduleOutput.plan` as `plan` so measurements taken
        under a pre-swap plan are keyed to the TP they actually ran at."""
        self.scheduler.observe(module, shape, predicted, actual, plan=plan)
        self.metrics.record_prediction(module, predicted, actual)
        if predicted > 0 and actual > 0:
            ev = self.drift.observe_residual(abs(actual / predicted - 1.0))
            if ev is not None:
                self._on_drift(ev)

    def observe_step(self, out: ScheduleOutput, measured_s: float, *,
                     idle_s: float = 0.0, busy_s: Optional[float] = None,
                     stage_busy=None) -> None:
        """Whole-step feedback: wall time vs. the predicted makespan.

        ``busy_s=None`` means "not measured" (the non-idle remainder of the
        step is assumed busy); an explicit ``0.0`` is a fully *idle* step
        and must yield bubble fraction 1.0, not 0.0."""
        self.trace.complete("step", self.trace.now_us() - measured_s * 1e6,
                            measured_s * 1e6, cat="step",
                            args={"pred_cmax_s": out.cmax})
        self.metrics.record_step(measured_s, idle_s, busy_s, stage_busy)
        self.trace.counter("bubble_fraction",
                           self.metrics.bubble_fraction.last())
        if out.cmax > 0 and measured_s > 0:
            ev = self.drift.observe_residual(abs(measured_s / out.cmax - 1.0))
            if ev is not None:
                self._on_drift(ev)

    # ------------------------------------------------------------------ #
    def _on_drift(self, event: DriftEvent) -> None:
        self.metrics.n_drift_events += 1
        self.trace.instant(f"drift:{event.kind}", cat="drift",
                           args={"statistic": event.statistic,
                                 "n_obs": event.n_obs})
        if not self.auto_replan:
            return
        with self._lock:
            if self._replan_future is not None:
                return                      # a search is already in flight
            dist = self.drift.window_distribution()
            if len(dist) == 0:
                dist = self.engine.dist
            # deterministic but distinct per firing: successive re-plans must
            # not resample the exact Monte-Carlo batches of the last one.
            self._replan_seed = self.batch_idx
            self._replan_future = self._pool.submit(self._search, dist, event)

    def _objective(self):
        """The engine's objective with the controller's re-plan trial
        budget.  An engine-pinned `Objective` instance keeps its
        configuration (quantile, solver, score) so re-plan decisions use
        the same risk level the initial plan was chosen under — only
        n_trials is overridden (get_objective copies, never mutates)."""
        return get_objective(self.engine.objective,
                             n_trials=self.replan_n_trials)

    def _search(self, dist: ShapeDistribution, event: DriftEvent):
        with self.trace.span("replan-search", cat="replan", tid=1,
                             kind=event.kind):
            # The calibrator couples the loop: the background search ranks
            # plans with the same refined durations the scheduler trusts.
            opt = ParallelismOptimizer(self.engine.cluster, self.engine.perf,
                                       mode=self.engine.mode,
                                       objective=self._objective(),
                                       calibrator=self.calibration,
                                       seed=self._replan_seed)
            res = opt.search(dist, self.gbs)
            # Score the incumbent here too: a sampling objective costs
            # real CPU, and maybe_swap() runs on the training-loop thread.
            # Only maybe_swap() mutates the plan and only one search is in
            # flight, so the plan captured here is the one compared at the
            # swap boundary.
            stale = self._plan_makespan(self.scheduler.plan, dist)
        return event, dist, res, stale

    def _plan_makespan(self, plan, dist: ShapeDistribution) -> float:
        """Evaluate a plan on `dist` under the engine's search objective —
        same objective, same calibrator, same Monte-Carlo seed — so
        stale-vs-new comparisons are like-for-like with `res.makespan`."""
        eng = self.engine
        return self._objective().evaluate(
            eng.perf, plan, dist, self.gbs, mode=eng.mode,
            corrector=self.calibration, seed=self._replan_seed)

    def maybe_swap(self) -> bool:
        """Adopt a finished background re-plan (batch-boundary only).

        With a `param_swapper`, adoption is *physical*: the live params
        are re-laid-out for the new plan before the logical swap (so the
        two never diverge — a failed reshard keeps the stale plan), and
        the decision is additionally gated on amortized cost: the
        predicted per-batch makespan advantage over
        ``swap_horizon_batches`` must exceed the measured/estimated
        reshard time (layout reconfiguration is not free)."""
        with self._lock:
            fut = self._replan_future
            if fut is None or not fut.done():
                return False
            self._replan_future = None
        try:
            event, dist, res, stale = fut.result()
        except Exception as e:  # noqa: BLE001 — a failed background search
            # must not take down the training loop; the detector stays armed
            # and the next drift event retries.
            self.trace.instant("replan-error", cat="replan",
                               args={"error": f"{type(e).__name__}: {e}"})
            return False
        # Guard the not-found path: res.makespan is meaningless without a
        # feasible plan — record inf, never compare against `stale`.
        new_mk = res.makespan if res.found else float("inf")
        swapped = res.found and new_mk < stale * (1.0 - self.min_improvement)
        gated: Optional[str] = None
        report = None
        old_plan = self.scheduler.plan
        roster = getattr(self.scheduler, "roster_chips", None)
        if swapped and roster is not None and res.plan.chips > roster:
            # the background search raced a roster shrink: its plan was
            # sized for the pre-failure fleet and cannot be fielded now
            swapped = False
            gated = "roster"
            self.trace.instant("swap-gated", cat="replan",
                               args={"reason": gated,
                                     "plan_chips": res.plan.chips,
                                     "roster_chips": roster})
        if swapped and self.param_swapper is not None:
            gated = self._physical_gate(old_plan, res.plan, stale, new_mk)
            if gated is None:
                # span recorded manually, on success only: a "reshard"
                # slice in the trace must mean a re-layout actually
                # happened (consumers count them as physical swaps)
                t_us = self.trace.now_us()
                try:
                    report = self.param_swapper.swap(old_plan, res.plan)
                    self.trace.complete(
                        "reshard", t_us, self.trace.now_us() - t_us,
                        cat="reshard",
                        args={"old": list(old_plan.as_tuple()),
                              "new": list(res.plan.as_tuple())})
                except Exception as e:  # noqa: BLE001 — same contract as a
                    # failed search: never take down the training loop...
                    self.trace.instant(
                        "reshard-error", cat="reshard",
                        args={"error": f"{type(e).__name__}: {e}"})
                    # ...unless a failed *donated* transfer already
                    # consumed the live buffers — the stale layout is gone
                    # too, so continuing would train on a deleted pytree.
                    # Fail fast instead of silently keeping a broken plan.
                    if getattr(self.param_swapper, "damaged", False):
                        raise
                    gated = "reshard-error"
            if gated is not None:
                swapped = False
                self.trace.instant("swap-gated", cat="replan",
                                   args={"reason": gated,
                                         "stale_makespan_s": stale,
                                         "new_makespan_s": new_mk})
            else:
                self.metrics.record_reshard(report.elapsed_s)
                self.trace.counter("reshard_s", report.elapsed_s)
        if swapped:
            self.scheduler.set_plan(res.plan)
            self.engine.plan_result = res
            self.metrics.n_replans += 1
            self.trace.instant("plan-swap", cat="replan",
                               args={"stale_makespan_s": stale,
                                     "new_makespan_s": new_mk,
                                     "plan": list(res.plan.as_tuple())})
            if self.composer is not None:
                # the window was priced under the old θ*; re-price before
                # the next composition targets the swapped plan
                self.composer.flush_plan()
                self.trace.instant("composer-flush", cat="compose",
                                   args={"pending": self.composer.pending})
        # Re-arm against the drifted regime either way, otherwise the same
        # shift keeps firing the detector every cooldown window.
        self.drift.rebase(dist)
        self.replans.append(ReplanRecord(
            event, stale, new_mk, swapped, res.elapsed_s,
            res.plan.as_tuple() if res.found else None,
            gated=gated, reshard=report))
        return swapped

    def _physical_gate(self, old_plan, new_plan, stale: float,
                       new_mk: float) -> Optional[str]:
        """Why a physically-backed swap must NOT happen (None = allowed).

        The amortization gate compares the predicted makespan advantage
        accumulated over the horizon against the swapper's cost estimate —
        measured reshard time once a swap has happened, a bytes/bandwidth
        model before that."""
        sw = self.param_swapper
        compat = getattr(sw, "compatible", None)
        if compat is not None and not compat(old_plan, new_plan):
            return "incompatible"
        est = getattr(sw, "estimate_cost_s", None)
        cost = float(est(old_plan, new_plan)) if est is not None else 0.0
        gain = (stale - new_mk) * self.swap_horizon_batches
        if gain <= cost:
            return "amortization"
        return None

    # ------------------------------------------------------------------ #
    def poll_fleet(self) -> List[RecoveryRecord]:
        """Drain fleet membership events and recover (batch boundary).

        Events queued since the last poll are coalesced into ONE recovery
        — a simultaneous fail+fail (or a fail raced by a join) re-plans
        once, for the roster that results.  No fleet or no events: no-op.
        Physically-backed pipelined loops must call this at a true step
        boundary, same contract as `maybe_swap()`."""
        if self.fleet is None:
            return []
        events = self.fleet.poll_events()
        if not events:
            return []
        for ev in events:
            self.metrics.record_membership(ev.kind)
            self.trace.instant(f"fleet:{ev.kind}", cat="fleet",
                               args={"host": ev.host_id, "step": ev.step,
                                     "n_alive_after": ev.n_alive_after})
        rec = self._recover_roster(tuple(events))
        self.recoveries.append(rec)
        self.metrics.record_recovery(rec.elapsed_s, degraded=rec.degraded)
        self.trace.counter("fleet_chips", rec.n_chips)
        return [rec]

    def _recover_roster(self, events: tuple) -> RecoveryRecord:
        """Checkpoint-free recovery onto the current roster.

        Fallback chain — degrade, never crash: (1) re-plan for the new
        roster's chip count and migrate the live params to the winner;
        (2) if the search fails, finds nothing, or its plan can't be
        fielded/reshard, *re-place* the old plan onto the survivors
        (`ParamSwapper.refresh` through the fleet mesh factory); (3) if
        even re-placement fails, continue on the stale layout.  The only
        raise is a swapper marked ``damaged`` — donated buffers are gone
        and there is nothing left to train on."""
        t0 = time.monotonic()
        old_plan = self.scheduler.plan
        n_chips = self.fleet.n_chips
        self.scheduler.set_roster(n_chips)
        error: Optional[str] = None
        res = None
        with self.trace.span("fleet-recovery", cat="fleet",
                             n_chips=n_chips, n_events=len(events)):
            dist = self.drift.window_distribution()
            if len(dist) == 0:
                dist = self.engine.dist
            try:
                opt = ParallelismOptimizer(
                    self.fleet.cluster_spec(self.engine.cluster),
                    self.engine.perf, mode=self.engine.mode,
                    objective=self._objective(),
                    calibrator=self.calibration, seed=self.batch_idx)
                res = opt.search(dist, self.gbs)
            except Exception as e:  # noqa: BLE001 — an infeasible search
                # degrades to the surviving roster, never crashes the loop
                error = f"{type(e).__name__}: {e}"
            candidate = (res.plan if res is not None and res.found
                         and res.plan.chips <= n_chips else None)
            if (candidate is not None
                    and candidate.as_tuple() == old_plan.as_tuple()):
                candidate = None      # same θ — a re-placement, not a swap
            target = candidate if candidate is not None else old_plan
            report = None
            if self.param_swapper is not None:
                attempts = ([old_plan] if target is old_plan
                            else [target, old_plan])
                for attempt in attempts:
                    t_us = self.trace.now_us()
                    try:
                        if attempt is old_plan:
                            report = self.param_swapper.refresh(old_plan)
                        else:
                            report = self.param_swapper.swap(old_plan,
                                                             attempt)
                        target = attempt
                        self.trace.complete(
                            "fleet-reshard", t_us,
                            self.trace.now_us() - t_us, cat="fleet",
                            args={"old": list(old_plan.as_tuple()),
                                  "new": list(attempt.as_tuple())})
                        self.metrics.record_reshard(report.elapsed_s)
                        break
                    except Exception as e:  # noqa: BLE001 — fall through
                        # the chain; stale layout is the last resort
                        self.trace.instant(
                            "fleet-reshard-error", cat="fleet",
                            args={"error": f"{type(e).__name__}: {e}"})
                        if getattr(self.param_swapper, "damaged", False):
                            raise
                        error = error or f"{type(e).__name__}: {e}"
                        target = old_plan
        adopted = target is not old_plan
        if adopted:
            self.scheduler.set_plan(target)
            self.engine.plan_result = res
            if self.composer is not None:
                self.composer.flush_plan()
        degraded = not adopted and (n_chips < old_plan.chips
                                    or error is not None)
        return RecoveryRecord(
            events=events, n_chips=n_chips,
            old_plan_tuple=old_plan.as_tuple(),
            new_plan_tuple=target.as_tuple() if adopted else None,
            adopted=adopted, degraded=degraded,
            elapsed_s=time.monotonic() - t0,
            reshard=report, error=error)

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until any in-flight search finishes, then try to swap.
        Returns True if a swap happened (test/benchmark hook)."""
        with self._lock:
            fut = self._replan_future
        if fut is not None:
            concurrent.futures.wait([fut], timeout=timeout)
        return self.maybe_swap()

    @property
    def replan_in_flight(self) -> bool:
        with self._lock:
            return self._replan_future is not None

    # ------------------------------------------------------------------ #
    def export_trace(self, path: str) -> str:
        return self.trace.export(path)

    def close(self) -> None:
        self._pool.shutdown(wait=True)
        self.maybe_swap()

    def __enter__(self) -> "RuntimeController":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
